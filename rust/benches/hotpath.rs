//! `cargo bench --bench hotpath` — L3 hot-path micro-benchmarks backing
//! the §Perf log in EXPERIMENTS.md: GPT radix ops, mempool alloc/reclaim,
//! staging queue, zipfian sampling, histogram recording, fabric verbs and
//! a full write-path iteration.

use std::hint::black_box;

use valet::backends::{valet::ValetBackend, ClusterState, PagingBackend};
use valet::bench::timing::bench;
use valet::config::Config;
use valet::gpt::RadixGpt;
use valet::mempool::Mempool;
use valet::metrics::Histogram;
use valet::queues::{StagingQueue, WriteSet};
use valet::simnet::Fabric;
use valet::util::{Rng, Zipfian};

fn main() {
    let mut results = Vec::new();

    // GPT
    {
        let mut t = RadixGpt::new();
        for p in 0..100_000u64 {
            t.insert(p * 7, p as u32);
        }
        let mut i = 0u64;
        results.push(bench("gpt/lookup_hit (100k keys)", 1_000_000, || {
            i = (i + 1) % 100_000;
            black_box(t.get(i * 7));
        }));
        let mut j = 0u64;
        results.push(bench("gpt/insert+remove", 1_000_000, || {
            j += 1;
            let k = 1_000_000_000 + (j % 4096);
            t.insert(k, 1);
            black_box(t.remove(k));
        }));
        // the write path's actual pattern: 16 consecutive page inserts
        // + lookups per 64 KB block (leaf-cache target)
        let mut base = 2_000_000_000u64;
        results.push(bench("gpt/sequential_block16", 200_000, || {
            base += 16;
            for p in base..base + 16 {
                black_box(t.get(p));
                t.insert(p, 1);
            }
        }));
        // the serve fast path's pattern: repeated hot-set re-reads;
        // `lookup` refreshes the leaf cache where `get` cannot
        let mut lb = 0u64;
        results.push(bench("gpt/lookup hot block (cached)", 1_000_000, || {
            lb += 1;
            black_box(t.lookup((lb % 64) * 7));
        }));
        // dense-range `get` — the shard-worker &self pattern (16
        // consecutive pages per block). The Cell leaf cache lets `get`
        // warm itself, so only the first page of each 64-page group
        // descends; the cache-busted variant forces every access to
        // descend (the pre-Cell cost of `get` on this pattern).
        let mut dense = RadixGpt::new();
        for p in 0..4096u64 {
            dense.insert(p, p as u32);
            dense.insert(1_000_000 + p, p as u32);
        }
        let mut dp = 0u64;
        results.push(bench("gpt/get dense range (warming)", 1_000_000, || {
            dp = (dp + 1) % 4096;
            black_box(dense.get(dp));
        }));
        let mut cp = 0u64;
        results.push(bench("gpt/get dense range (cache-busted)", 1_000_000, || {
            // one get per iter, ping-ponging between two far-apart
            // dense regions so the one-entry leaf cache never hits —
            // the pre-Cell descent cost, directly comparable to
            // "warming" above
            cp += 1;
            let p = (cp / 2) % 4096 + (cp & 1) * 1_000_000;
            black_box(dense.get(p));
        }));
    }

    // Mempool
    {
        let mut mp = Mempool::new(4096, 8192, 0.8, 1.0);
        let mut p = 0u64;
        results.push(bench("mempool/alloc+reclaim", 1_000_000, || {
            p += 1;
            if let Ok(a) = mp.alloc(p, 1 << 20) {
                mp.mark_reclaimable(a.slot);
            }
            black_box(());
        }));
    }

    // Staging queue
    {
        let mut q = StagingQueue::new();
        let mut n = 0u64;
        results.push(bench("staging/push+pop_batch", 300_000, || {
            n += 1;
            q.push(WriteSet {
                page: n,
                slots: vec![n as u32],
                bytes: 4096,
                enqueued_at: n,
            });
            if n % 8 == 0 {
                black_box(q.pop_batch(1 << 19));
            }
        }));
    }

    // Zipfian + histogram
    {
        let z = Zipfian::new(10_000_000, 0.99);
        let mut rng = Rng::new(1);
        results.push(bench("zipfian/sample (10M keys)", 3_000_000, || {
            black_box(z.sample_scattered(&mut rng));
        }));
        let mut h = Histogram::new();
        let mut v = 1u64;
        results.push(bench("histogram/record", 3_000_000, || {
            v = v.wrapping_mul(6364136223846793005).wrapping_add(1);
            h.record(black_box(v >> 40));
        }));
    }

    // Fabric verb
    {
        let cfg = Config::default();
        let mut f = Fabric::new(4, cfg.latency.clone());
        let (t, _) = f.ensure_connected(0, 0, 1);
        let mut now = t;
        results.push(bench("fabric/rdma_write(4k)", 1_000_000, || {
            let d = f.rdma_write(now, 0, 1, 4096);
            now = d.end;
            black_box(d);
        }));
    }

    // Pool-tier read hit: the tier-dispatched read of a pool-resident
    // block (no queue pair, NUMA-hop base latency) vs the rdma verb
    // above — the per-access cost the tiering experiment banks on.
    {
        use valet::mrpool::MemTier;
        let mut cfg = Config::default();
        cfg.cluster.nodes = 4;
        cfg.valet.pool_tier.enabled = true;
        cfg.valet.pool_tier.capacity_bytes = 64 << 20;
        let mut cl = ClusterState::new(&cfg);
        let blk = cl.mrpools[1].register_tier(0, 1 << 20, 0, MemTier::Pool);
        let mut now = 0;
        results.push(bench("valet/pool-tier read hit (4k)", 1_000_000, || {
            let d = cl.tiered_read(now, 1, blk, 4096);
            now = d.end;
            black_box(d);
        }));
    }

    // Full Valet write path (sim)
    {
        let mut cfg = Config::default();
        cfg.cluster.nodes = 4;
        cfg.valet.mr_block_bytes = 64 << 20;
        cfg.valet.min_pool_pages = 1 << 16;
        cfg.valet.max_pool_pages = 1 << 16;
        let mut cl = ClusterState::new(&cfg);
        let mut be = ValetBackend::new(&cfg);
        let mut now = 0;
        let mut p = 0u64;
        results.push(bench("valet/write_path e2e", 200_000, || {
            p = (p + 16) % (1 << 14);
            let a = be.write(&mut cl, now, p, 65536);
            now = a.end;
            black_box(a.end);
        }));
        let mut rp = 0u64;
        results.push(bench("valet/read_path local hit", 500_000, || {
            rp = (rp + 1) % (1 << 14);
            let a = be.read(&mut cl, now, rp);
            now = a.end;
            black_box(a.end);
        }));
    }

    // Serve roundtrip: pooled per-handle reply channel (call) vs a
    // fresh mpsc channel allocated per request (submit — the pre-pool
    // behavior). The delta is the measured win of the reply-channel
    // reuse on the live hot path.
    {
        use valet::config::BackendKind;
        use valet::serve::{spawn, Request};
        let mut cfg = Config::default();
        cfg.cluster.nodes = 3;
        cfg.valet.mr_block_bytes = 16 << 20;
        cfg.valet.min_pool_pages = 4096;
        cfg.valet.max_pool_pages = 4096;
        let h = spawn(&cfg, BackendKind::Valet);
        let _ = h.call(Request::Write { page: 0, bytes: 65536 });
        results.push(bench("serve/call (pooled reply chan)", 50_000, || {
            black_box(h.call(Request::Read { page: 0 }).unwrap());
        }));
        results.push(bench("serve/submit (fresh chan per op)", 50_000, || {
            let rx = h.submit(Request::Read { page: 0 }).unwrap();
            black_box(rx.recv().unwrap());
        }));
        drop(h);
    }

    println!("\n=== hotpath results ===");
    for r in &results {
        println!("{}", r.render());
    }
}
