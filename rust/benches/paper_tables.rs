//! `cargo bench --bench paper_tables` — end-to-end regeneration of every
//! paper table/figure at small scale, with wall-clock timing per
//! experiment (the "one criterion bench per paper table" requirement,
//! adapted to the offline toolchain: criterion is unavailable, so this
//! is a plain harness=false bench binary).

use valet::bench::experiments::{all_ids, run, Scale};

fn main() {
    let scale = Scale::small();
    println!("paper-table regeneration bench (small scale)\n");
    let mut total = 0.0;
    for id in all_ids() {
        let t0 = std::time::Instant::now();
        let report = run(id, &scale).expect("known id");
        let dt = t0.elapsed().as_secs_f64();
        total += dt;
        println!(
            "bench {id:<10} {dt:>8.2}s   ({} rows)",
            report.rows.len()
        );
    }
    println!("\ntotal {total:.2}s");
}
