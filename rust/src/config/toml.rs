//! Mini TOML parser covering the subset our configs use:
//! `[section]` headers, `key = value` lines with integer / float / bool /
//! quoted-string values, `#` comments and blank lines. No tables-in-tables,
//! no arrays — config stays flat and obvious.

/// A parsed scalar value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    /// Integer literal (also accepts `1_000` separators).
    Int(i64),
    /// Float literal.
    Float(f64),
    /// `true` / `false`.
    Bool(bool),
    /// Double-quoted string.
    Str(String),
}

impl Value {
    /// As unsigned integer (floats with zero fraction coerce).
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Value::Int(i) if *i >= 0 => Some(*i as u64),
            Value::Float(f) if *f >= 0.0 && f.fract() == 0.0 => {
                Some(*f as u64)
            }
            _ => None,
        }
    }

    /// As float (ints coerce).
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Int(i) => Some(*i as f64),
            Value::Float(f) => Some(*f),
            _ => None,
        }
    }

    /// As bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// As string slice.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Parse a scalar literal.
    pub fn parse(raw: &str) -> Result<Value, String> {
        let s = raw.trim();
        if s == "true" {
            return Ok(Value::Bool(true));
        }
        if s == "false" {
            return Ok(Value::Bool(false));
        }
        if s.starts_with('"') && s.ends_with('"') && s.len() >= 2 {
            return Ok(Value::Str(s[1..s.len() - 1].to_string()));
        }
        let cleaned: String = s.chars().filter(|&c| c != '_').collect();
        if let Ok(i) = cleaned.parse::<i64>() {
            return Ok(Value::Int(i));
        }
        if let Ok(f) = cleaned.parse::<f64>() {
            return Ok(Value::Float(f));
        }
        Err(format!("cannot parse value: {raw:?}"))
    }
}

/// Parse the full document into ((section, key), value) pairs in file
/// order. Keys before any `[section]` get section `""`.
pub fn parse_toml(
    text: &str,
) -> Result<Vec<((String, String), Value)>, String> {
    let mut out = Vec::new();
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = match raw.find('#') {
            // avoid cutting '#' inside quotes — good enough for our subset:
            Some(i) if !raw[..i].contains('"') => &raw[..i],
            _ => raw,
        }
        .trim();
        if line.is_empty() {
            continue;
        }
        if line.starts_with('[') {
            if !line.ends_with(']') {
                return Err(format!("line {}: bad section", lineno + 1));
            }
            section = line[1..line.len() - 1].trim().to_string();
            continue;
        }
        let eq = line
            .find('=')
            .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
        let key = line[..eq].trim().to_string();
        let value = Value::parse(&line[eq + 1..])
            .map_err(|e| format!("line {}: {e}", lineno + 1))?;
        out.push(((section.clone(), key), value));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_keys_comments() {
        let doc = "# top comment\nglobal = 1\n[a]\nx = 2\n y = 3.5 # trailing\n[b]\nflag = true\nname = \"hi\"\n";
        let kv = parse_toml(doc).unwrap();
        assert_eq!(kv.len(), 5);
        assert_eq!(
            kv[0],
            (("".into(), "global".into()), Value::Int(1))
        );
        assert_eq!(kv[1], (("a".into(), "x".into()), Value::Int(2)));
        assert_eq!(kv[2], (("a".into(), "y".into()), Value::Float(3.5)));
        assert_eq!(kv[3], (("b".into(), "flag".into()), Value::Bool(true)));
        assert_eq!(
            kv[4],
            (("b".into(), "name".into()), Value::Str("hi".into()))
        );
    }

    #[test]
    fn underscore_separators() {
        assert_eq!(Value::parse("1_000_000").unwrap(), Value::Int(1_000_000));
    }

    #[test]
    fn coercions() {
        assert_eq!(Value::Int(5).as_f64(), Some(5.0));
        assert_eq!(Value::Float(5.0).as_u64(), Some(5));
        assert_eq!(Value::Float(5.5).as_u64(), None);
        assert_eq!(Value::Int(-1).as_u64(), None);
        assert_eq!(Value::Str("x".into()).as_str(), Some("x"));
    }

    #[test]
    fn errors_have_line_numbers() {
        let e = parse_toml("ok = 1\nbroken line\n").unwrap_err();
        assert!(e.contains("line 2"), "{e}");
    }
}
