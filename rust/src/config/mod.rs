//! Configuration system: every latency constant, policy knob, cluster
//! shape and workload parameter in one tree, loadable from a TOML-subset
//! file (`--config path`) plus `section.key=value` CLI overrides.
//!
//! Defaults are calibrated to the paper's own measurements (Table 1 and
//! Table 7) and evaluation setup (§6 "Setup"): 64 KB block I/O, 512 KB
//! RDMA message, 1 GB MR block unit, 32-node cluster.

mod toml;

pub use toml::{parse_toml, Value};

use crate::sim::{ms, us_f, Ns};

/// Which paging backend to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// The paper's system (§3–§4).
    Valet,
    /// Infiniswap-like baseline [6]: one-sided RDMA on the critical path,
    /// disk redirect during connection/mapping windows, delete-on-evict.
    Infiniswap,
    /// nbdX-like baseline [11]: two-sided verbs, bounded message pools,
    /// remote ramdisk.
    Nbdx,
    /// Conventional OS swap to local disk.
    LinuxSwap,
}

impl BackendKind {
    /// Parse from CLI string.
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "valet" => Some(Self::Valet),
            "infiniswap" => Some(Self::Infiniswap),
            "nbdx" => Some(Self::Nbdx),
            "linux" | "linux_swap" | "swap" | "disk" => Some(Self::LinuxSwap),
            _ => None,
        }
    }

    /// Display name matching the paper's figures.
    pub fn name(&self) -> &'static str {
        match self {
            Self::Valet => "Valet",
            Self::Infiniswap => "Infiniswap",
            Self::Nbdx => "nbdX",
            Self::LinuxSwap => "Linux",
        }
    }

    /// All four systems, in the order the paper's figures list them.
    pub fn all() -> [BackendKind; 4] {
        [Self::Nbdx, Self::Infiniswap, Self::Valet, Self::LinuxSwap]
    }
}

/// Latency model, calibrated to the paper's Table 1 / Table 7. All values
/// in ns; `*_per_byte` values are in ns per byte (f64 — sub-ns rates).
#[derive(Clone, Debug)]
pub struct LatencyConfig {
    /// Radix-tree (GPT) insert on the write path (Table 7a: 23.9 µs).
    pub radix_insert: Ns,
    /// Radix-tree lookup on the read path (Table 7a: 1.39 µs).
    pub radix_lookup: Ns,
    /// Copy block-I/O buffer → local mempool, per byte (Table 7a:
    /// 9.73 µs per 64 KB block ⇒ ~0.148 ns/B).
    pub copy_per_byte: f64,
    /// Fixed per-copy setup cost.
    pub copy_base: Ns,
    /// Enqueue a write set into the staging queue (Table 7a: 1.68 µs).
    pub staging_enqueue: Ns,
    /// Get a unit MR from the MR pool (Table 7a: 0.14 µs).
    pub mrpool_get: Ns,
    /// One-sided RDMA WRITE base latency (Table 1: 51.35 µs for the
    /// 512 KB default message; we split into base + per-byte so different
    /// message sizes sweep correctly in Figure 9).
    pub rdma_write_base: Ns,
    /// One-sided RDMA READ base latency (Table 1: 36.48 µs @ 4 KB page).
    pub rdma_read_base: Ns,
    /// RDMA wire time per byte (56 Gbps FDR ≈ 0.0903 ns/B effective —
    /// calibrated so 512 KB WRITE lands on 51.35 µs with a 4 µs base).
    pub rdma_per_byte: f64,
    /// Extra round-trip + receiver-CPU latency for two-sided verbs (nbdX).
    pub two_sided_extra: Ns,
    /// QP connection establishment (Table 1: 200.668 ms).
    pub connect: Ns,
    /// Remote MR mapping: query N nodes, exchange keys (Table 1:
    /// 62.276 ms).
    pub map_mr: Ns,
    /// Disk seek + rotational positioning per I/O.
    pub disk_seek: Ns,
    /// Disk transfer per byte (SATA HDD ≈ 100 MB/s ⇒ 10 ns/B).
    pub disk_per_byte: f64,
    /// Number of WQEs the RNIC caches before misses add latency [12].
    pub wqe_cache_entries: usize,
    /// Added latency per verb when the WQE cache thrashes.
    pub wqe_miss_penalty: Ns,
    /// Read-side copy of one 4 KB page out of the mempool (Table 7a:
    /// 2.11 µs local hit / 2.13 µs remote).
    pub copy_read_page: Ns,
    /// Infiniswap's shared BIO/MR buffer copy (Table 7b: 37.57 µs —
    /// larger than Valet's because the buffer is tied to the disk path).
    pub copy_fixed_slow: Ns,
    /// Infiniswap's MR-pool get under load (Table 7b: 8.37 µs on the
    /// write path vs Valet's 0.14 µs).
    pub mrpool_get_slow: Ns,
    /// Pool-tier (CXL-style) READ base latency — ~a NUMA hop (Pond
    /// measures 180–250 ns for a CXL load; we charge 0.6 µs to cover
    /// the page-granular request setup), an order of magnitude below
    /// the 36 µs fabric round trip.
    pub pool_read_base: Ns,
    /// Pool-tier WRITE base latency (same NUMA-hop class).
    pub pool_write_base: Ns,
    /// Pool-tier wire time per byte. CXL bandwidth is a memory-bus
    /// fraction, well above the 56 Gbps fabric: half the RDMA rate.
    pub pool_per_byte: f64,
    /// Attach a pool-tier slice (HDM decoder + address window): 1 ms,
    /// vs 62 ms for the full MR mapping exchange.
    pub pool_map: Ns,
}

impl Default for LatencyConfig {
    fn default() -> Self {
        LatencyConfig {
            radix_insert: us_f(23.9),
            radix_lookup: us_f(1.39),
            copy_per_byte: 9.73 * 1000.0 / (64.0 * 1024.0), // 9.73µs / 64KB
            copy_base: 0,
            staging_enqueue: us_f(1.68),
            mrpool_get: us_f(0.14),
            rdma_write_base: us_f(4.0),
            rdma_read_base: us_f(36.3),
            rdma_per_byte: (51.35 - 4.0) * 1000.0 / (512.0 * 1024.0),
            two_sided_extra: us_f(25.0),
            connect: us_f(200_668.0),
            map_mr: us_f(62_276.0),
            disk_seek: ms(8),
            disk_per_byte: 10.0,
            wqe_cache_entries: 256,
            wqe_miss_penalty: us_f(10.0),
            copy_read_page: us_f(2.11),
            copy_fixed_slow: us_f(37.57),
            mrpool_get_slow: us_f(8.37),
            pool_read_base: us_f(0.6),
            pool_write_base: us_f(0.6),
            pool_per_byte: (51.35 - 4.0) * 1000.0 / (512.0 * 1024.0) / 2.0,
            pool_map: us_f(1_000.0),
        }
    }
}

impl LatencyConfig {
    /// Copy time for `bytes` bytes through the CPU.
    pub fn copy(&self, bytes: u64) -> Ns {
        self.copy_base + (self.copy_per_byte * bytes as f64) as Ns
    }

    /// One-sided RDMA WRITE service time for a message of `bytes`.
    pub fn rdma_write(&self, bytes: u64) -> Ns {
        self.rdma_write_base + (self.rdma_per_byte * bytes as f64) as Ns
    }

    /// One-sided RDMA READ service time.
    pub fn rdma_read(&self, bytes: u64) -> Ns {
        self.rdma_read_base + (self.rdma_per_byte * bytes as f64) as Ns
    }

    /// Disk service time for one I/O of `bytes`.
    pub fn disk_io(&self, bytes: u64) -> Ns {
        self.disk_seek + (self.disk_per_byte * bytes as f64) as Ns
    }

    /// Pool-tier READ service time for `bytes`.
    pub fn pool_read(&self, bytes: u64) -> Ns {
        self.pool_read_base + (self.pool_per_byte * bytes as f64) as Ns
    }

    /// Pool-tier WRITE service time for `bytes`.
    pub fn pool_write(&self, bytes: u64) -> Ns {
        self.pool_write_base + (self.pool_per_byte * bytes as f64) as Ns
    }
}

/// Mempool cache-replacement policy. The paper uses LRU and names MRU as
/// promising future work for repetitive access patterns (§6.2); both are
/// implemented (see the `ablations` experiment).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Replacement {
    /// Evict the least-recently-used reclaimable page (paper default).
    Lru,
    /// Evict the most-recently-used reclaimable page.
    Mru,
}

/// The CXL-style pooled middle tier (ROADMAP item 2, Pond/DOLMA). OFF
/// by default: with `enabled = false` no pool candidate is ever
/// emitted, no pool verb is ever charged and the whole pipeline is
/// bit-for-bit the two-tier system (pinned by `tests/tiering.rs`, the
/// same way `prefetch` and `sender_lanes` were pinned).
#[derive(Clone, Debug)]
pub struct PoolTierConfig {
    /// Master switch for the pooled tier.
    pub enabled: bool,
    /// Each node's slice of the pooled appliance, bytes.
    pub capacity_bytes: u64,
    /// A pool-tier block whose last demand read is within this window
    /// of a tier scan counts as warm-hot; a *Remote*-tier block this
    /// recently read is promoted into the pool.
    pub promote_max_idle: Ns,
    /// A pool-tier block idle longer than this demotes to RDMA-remote,
    /// freeing appliance capacity for warmer data.
    pub demote_after: Ns,
    /// Virtual-time period between tier scans (the promotion/demotion
    /// pump cadence).
    pub scan_period: Ns,
    /// Pond-style admission predictor: classify a fresh write set as
    /// latency-insensitive from early activity and place it cold-first
    /// (straight to RDMA-remote), saving pool capacity for data that
    /// will be read back.
    pub predictor: bool,
    /// A freshly mapped unit with no demand read within this window of
    /// its mapping counts as a latency-insensitive allocation.
    pub predictor_window: Ns,
}

impl Default for PoolTierConfig {
    fn default() -> Self {
        PoolTierConfig {
            enabled: false,
            capacity_bytes: 8 << 30,
            promote_max_idle: ms(200),
            demote_after: ms(2_000),
            scan_period: ms(500),
            predictor: true,
            predictor_window: ms(500),
        }
    }
}

/// The failure-domain layer (ROADMAP item 1, FluidMem/EDGELESS). OFF
/// by default: with `enabled = false` the health ledger never ticks,
/// every peer stays Healthy, no failover/repair/rebalance work is ever
/// scheduled and the whole pipeline is bit-for-bit the PR-8 system
/// (pinned by `tests/churn.rs`, the same way `prefetch`,
/// `sender_lanes` and `pool_tier` were pinned).
#[derive(Clone, Debug)]
pub struct HealthConfig {
    /// Master switch for health tracking, failover reads, the
    /// re-replication pump and join rebalancing.
    pub enabled: bool,
    /// A peer that misses this many expected cluster events (no event
    /// originated by it while others kept arriving) turns Suspect;
    /// at `2 × max_missed` it is declared Dead. An explicit
    /// [`crate::cluster::ClusterEvent::PeerDown`] kills immediately.
    pub max_missed: u64,
    /// Virtual-time period between re-replication pump scans (restores
    /// `FtPolicy.copies` for units that lost replicas to a dead peer).
    pub repair_period: Ns,
    /// Maximum units migrated onto a freshly joined peer per join
    /// event (bounds the rebalance burst a join injects).
    pub rebalance_max: usize,
}

impl Default for HealthConfig {
    fn default() -> Self {
        HealthConfig {
            enabled: false,
            max_missed: 8,
            repair_period: ms(200),
            rebalance_max: 4,
        }
    }
}

/// Valet-specific policy knobs (§3.4, §4.1, Table 2).
#[derive(Clone, Debug)]
pub struct ValetConfig {
    /// Guaranteed minimum mempool size (pages). `min_pool_pages` in §4.1.
    pub min_pool_pages: u64,
    /// Hard maximum (pages); the effective cap is
    /// `min(max_pool_pages, host_free_fraction × host free pages)`.
    pub max_pool_pages: u64,
    /// Grow when usage exceeds this fraction of the current size (0.8).
    pub grow_threshold: f64,
    /// Cap relative to host free memory (0.5 = "50% of the total free
    /// memory on the host node").
    pub host_free_fraction: f64,
    /// Block I/O request size in bytes (64 KB default; Figure 9 sweeps).
    pub block_io_bytes: u64,
    /// RDMA message size for coalesced batch sends (512 KB default).
    pub rdma_msg_bytes: u64,
    /// Unit MR block size on remote nodes (1 GB default).
    pub mr_block_bytes: u64,
    /// Number of remote copies of each page (1 = no extra replicas).
    pub replicas: usize,
    /// Also write pages to local disk (Table 3 fault-tolerance matrix).
    pub disk_backup: bool,
    /// Message coalescing + batch sending (§3.3). Disabling it sends one
    /// RDMA message per block I/O — the ablation knob.
    pub coalescing: bool,
    /// Mempool replacement policy (LRU default; MRU per §6.2).
    pub replacement: Replacement,
    /// Adaptive stride prefetcher on the read miss path (see
    /// [`crate::prefetch`]). OFF by default: the demand miss path is
    /// then bit-for-bit the pre-prefetch pipeline.
    pub prefetch: bool,
    /// Miss-delta window for the prefetcher's majority vote.
    pub prefetch_window: usize,
    /// Pages fetched per readahead batch.
    pub prefetch_degree: u64,
    /// The prefetcher auto-disables below this accuracy over completed
    /// (hit-or-evicted) prefetches.
    pub prefetch_min_accuracy: f64,
    /// Completed prefetches before accuracy is judged.
    pub prefetch_min_samples: u64,
    /// Migrations the reclaim pipeline runs concurrently (§3.5). Blocks
    /// selected beyond this stay queued (victim-marked, writes still
    /// flowing) until a slot frees; `1` serializes migrations — the
    /// ablation baseline of the `reclaim` experiment.
    pub max_concurrent_migrations: usize,
    /// EWMA weight for the per-peer pressure score the placement layer
    /// reads (0 = frozen, 1 = instantaneous).
    pub pressure_ewma: f64,
    /// Sender lanes the slow path is partitioned into (each lane owns
    /// one peer set's timeline, batcher, read table and migration
    /// machines). `0` = one lane per remote peer; `1` (the default) =
    /// the single pre-split sender timeline — the differential-test
    /// oracle configuration; capped at 64.
    pub sender_lanes: usize,
    /// Slow-path drain threads `serve::spawn_sharded` runs next to the
    /// pump driver (each owns a disjoint set of lanes and drains their
    /// admission rings under short sequencer-lock holds). `1` (the
    /// default) = no drain threads and no admission detour — the
    /// pre-split single-mutex serve, bit-for-bit; `0` = one thread per
    /// lane; `n` = n threads, capped at the lane count. Ignored by
    /// purely virtual-time runs except that any non-`1` value routes
    /// sends through the admission rings (a synchronous, bit-identical
    /// detour that keeps the ring machinery and its audit law hot).
    pub slow_path_threads: usize,
    /// The pooled middle tier (`[valet.pool_tier]`; off by default).
    pub pool_tier: PoolTierConfig,
    /// The failure-domain layer (`[valet.health]`; off by default).
    pub health: HealthConfig,
}

impl Default for ValetConfig {
    fn default() -> Self {
        ValetConfig {
            min_pool_pages: 16 * 1024,        // 64 MB
            max_pool_pages: 8 * 1024 * 1024,  // 32 GB cap
            grow_threshold: 0.8,
            host_free_fraction: 0.5,
            block_io_bytes: 64 * 1024,
            rdma_msg_bytes: 512 * 1024,
            mr_block_bytes: 1 << 30,
            replicas: 1,
            disk_backup: false,
            coalescing: true,
            replacement: Replacement::Lru,
            prefetch: false,
            prefetch_window: 8,
            prefetch_degree: 8,
            prefetch_min_accuracy: 0.5,
            prefetch_min_samples: 32,
            max_concurrent_migrations: 4,
            pressure_ewma: 0.3,
            sender_lanes: 1,
            slow_path_threads: 1,
            pool_tier: PoolTierConfig::default(),
            health: HealthConfig::default(),
        }
    }
}

/// Cluster shape (§6 "Setup": 32 machines, 64 GB each).
#[derive(Clone, Debug)]
pub struct ClusterConfig {
    /// Number of nodes (sender + peers; symmetric model §3.2).
    pub nodes: usize,
    /// Physical memory per node, bytes.
    pub node_mem_bytes: u64,
    /// Deterministic seed for placement and workload generation.
    pub seed: u64,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            nodes: 7, // 1 sender + 6 peers, the paper's Figure 4 setup
            node_mem_bytes: 64 << 30,
            seed: 0x0A1E7,
        }
    }
}

/// Everything together.
#[derive(Clone, Debug, Default)]
pub struct Config {
    /// Cluster shape.
    pub cluster: ClusterConfig,
    /// Latency model.
    pub latency: LatencyConfig,
    /// Valet policy knobs.
    pub valet: ValetConfig,
}

impl Config {
    /// Apply one `section.key = value` assignment; unknown keys error so
    /// typos don't silently no-op.
    pub fn set(&mut self, section: &str, key: &str, v: &Value) -> Result<(), String> {
        let err = || format!("unknown config key {section}.{key}");
        match section {
            "cluster" => match key {
                "nodes" => self.cluster.nodes = v.as_u64().ok_or_else(err)? as usize,
                "node_mem_gb" => {
                    self.cluster.node_mem_bytes = v.as_u64().ok_or_else(err)? << 30
                }
                "seed" => self.cluster.seed = v.as_u64().ok_or_else(err)?,
                _ => return Err(err()),
            },
            "valet" => match key {
                "min_pool_pages" => self.valet.min_pool_pages = v.as_u64().ok_or_else(err)?,
                "max_pool_pages" => self.valet.max_pool_pages = v.as_u64().ok_or_else(err)?,
                "grow_threshold" => self.valet.grow_threshold = v.as_f64().ok_or_else(err)?,
                "host_free_fraction" => {
                    self.valet.host_free_fraction = v.as_f64().ok_or_else(err)?
                }
                "block_io_kb" => self.valet.block_io_bytes = v.as_u64().ok_or_else(err)? << 10,
                "rdma_msg_kb" => self.valet.rdma_msg_bytes = v.as_u64().ok_or_else(err)? << 10,
                "mr_block_mb" => self.valet.mr_block_bytes = v.as_u64().ok_or_else(err)? << 20,
                "replicas" => self.valet.replicas = v.as_u64().ok_or_else(err)? as usize,
                "disk_backup" => self.valet.disk_backup = v.as_bool().ok_or_else(err)?,
                "coalescing" => self.valet.coalescing = v.as_bool().ok_or_else(err)?,
                "replacement" => {
                    self.valet.replacement =
                        match v.as_str().ok_or_else(err)? {
                            "lru" => Replacement::Lru,
                            "mru" => Replacement::Mru,
                            _ => return Err(err()),
                        }
                }
                "prefetch" => {
                    self.valet.prefetch = v.as_bool().ok_or_else(err)?
                }
                "prefetch_window" => {
                    self.valet.prefetch_window =
                        v.as_u64().ok_or_else(err)? as usize
                }
                "prefetch_degree" => {
                    self.valet.prefetch_degree =
                        v.as_u64().ok_or_else(err)?
                }
                "prefetch_min_accuracy" => {
                    self.valet.prefetch_min_accuracy =
                        v.as_f64().ok_or_else(err)?
                }
                "prefetch_min_samples" => {
                    self.valet.prefetch_min_samples =
                        v.as_u64().ok_or_else(err)?
                }
                "max_concurrent_migrations" => {
                    self.valet.max_concurrent_migrations =
                        v.as_u64().ok_or_else(err)? as usize
                }
                "pressure_ewma" => {
                    self.valet.pressure_ewma =
                        v.as_f64().ok_or_else(err)?
                }
                "sender_lanes" => {
                    self.valet.sender_lanes =
                        v.as_u64().ok_or_else(err)? as usize
                }
                "slow_path_threads" => {
                    self.valet.slow_path_threads =
                        v.as_u64().ok_or_else(err)? as usize
                }
                _ => return Err(err()),
            },
            "valet.pool_tier" => {
                let pt = &mut self.valet.pool_tier;
                match key {
                    "enabled" => pt.enabled = v.as_bool().ok_or_else(err)?,
                    "capacity_gb" => {
                        pt.capacity_bytes = v.as_u64().ok_or_else(err)? << 30
                    }
                    "capacity_mb" => {
                        pt.capacity_bytes = v.as_u64().ok_or_else(err)? << 20
                    }
                    "promote_max_idle_ms" => {
                        pt.promote_max_idle = ms(v.as_u64().ok_or_else(err)?)
                    }
                    "demote_after_ms" => {
                        pt.demote_after = ms(v.as_u64().ok_or_else(err)?)
                    }
                    "scan_period_ms" => {
                        pt.scan_period = ms(v.as_u64().ok_or_else(err)?)
                    }
                    "predictor" => {
                        pt.predictor = v.as_bool().ok_or_else(err)?
                    }
                    "predictor_window_ms" => {
                        pt.predictor_window = ms(v.as_u64().ok_or_else(err)?)
                    }
                    _ => return Err(err()),
                }
            }
            "valet.health" => {
                let h = &mut self.valet.health;
                match key {
                    "enabled" => h.enabled = v.as_bool().ok_or_else(err)?,
                    "max_missed" => {
                        h.max_missed = v.as_u64().ok_or_else(err)?
                    }
                    "repair_period_ms" => {
                        h.repair_period = ms(v.as_u64().ok_or_else(err)?)
                    }
                    "rebalance_max" => {
                        h.rebalance_max =
                            v.as_u64().ok_or_else(err)? as usize
                    }
                    _ => return Err(err()),
                }
            }
            "latency" => {
                let f = v.as_f64().ok_or_else(err)?;
                let ns = us_f(f); // latency keys are specified in µs
                match key {
                    "radix_insert_us" => self.latency.radix_insert = ns,
                    "radix_lookup_us" => self.latency.radix_lookup = ns,
                    "staging_enqueue_us" => self.latency.staging_enqueue = ns,
                    "mrpool_get_us" => self.latency.mrpool_get = ns,
                    "rdma_write_base_us" => self.latency.rdma_write_base = ns,
                    "rdma_read_base_us" => self.latency.rdma_read_base = ns,
                    "two_sided_extra_us" => self.latency.two_sided_extra = ns,
                    "connect_us" => self.latency.connect = ns,
                    "map_mr_us" => self.latency.map_mr = ns,
                    "disk_seek_us" => self.latency.disk_seek = ns,
                    "wqe_miss_penalty_us" => self.latency.wqe_miss_penalty = ns,
                    "pool_read_base_us" => self.latency.pool_read_base = ns,
                    "pool_write_base_us" => {
                        self.latency.pool_write_base = ns
                    }
                    "pool_map_us" => self.latency.pool_map = ns,
                    "pool_per_byte_ns" => self.latency.pool_per_byte = f,
                    "rdma_per_byte_ns" => self.latency.rdma_per_byte = f,
                    "copy_per_byte_ns" => self.latency.copy_per_byte = f,
                    "disk_per_byte_ns" => self.latency.disk_per_byte = f,
                    "wqe_cache_entries" => {
                        self.latency.wqe_cache_entries = f as usize
                    }
                    _ => return Err(err()),
                }
            }
            _ => return Err(format!("unknown config section {section}")),
        }
        Ok(())
    }

    /// Range-check every knob that has a meaningful domain; returns the
    /// first violation. Called by the TOML loaders so a bad config file
    /// fails at build time, not as a silent mis-simulation; CLI paths
    /// that assemble a [`Config`] by hand call it before running.
    pub fn validate(&self) -> Result<(), String> {
        let v = &self.valet;
        if !(v.pressure_ewma > 0.0 && v.pressure_ewma <= 1.0) {
            return Err(format!(
                "valet.pressure_ewma must be in (0, 1], got {}",
                v.pressure_ewma
            ));
        }
        if !(0.0..=1.0).contains(&v.prefetch_min_accuracy) {
            return Err(format!(
                "valet.prefetch_min_accuracy must be in [0, 1], got {}",
                v.prefetch_min_accuracy
            ));
        }
        let pt = &v.pool_tier;
        if pt.enabled {
            if pt.capacity_bytes == 0 {
                return Err(
                    "valet.pool_tier.capacity_bytes must be > 0 when the \
                     pool tier is enabled"
                        .into(),
                );
            }
            if pt.capacity_bytes < v.mr_block_bytes {
                return Err(format!(
                    "valet.pool_tier capacity ({} B) cannot hold even one \
                     MR block ({} B)",
                    pt.capacity_bytes, v.mr_block_bytes
                ));
            }
        }
        if pt.promote_max_idle > pt.demote_after {
            return Err(format!(
                "valet.pool_tier.promote_max_idle_ms ({}) must not exceed \
                 demote_after_ms ({}): a block would promote and demote in \
                 the same scan",
                pt.promote_max_idle / 1_000_000,
                pt.demote_after / 1_000_000
            ));
        }
        if pt.scan_period == 0 {
            return Err("valet.pool_tier.scan_period_ms must be > 0".into());
        }
        if pt.predictor_window == 0 {
            return Err(
                "valet.pool_tier.predictor_window_ms must be > 0".into()
            );
        }
        let h = &v.health;
        if h.enabled {
            if h.max_missed == 0 {
                return Err(
                    "valet.health.max_missed must be > 0 when health \
                     tracking is enabled (0 would kill every peer on the \
                     first event)"
                        .into(),
                );
            }
            if h.repair_period == 0 {
                return Err(
                    "valet.health.repair_period_ms must be > 0 when \
                     health tracking is enabled"
                        .into(),
                );
            }
        }
        Ok(())
    }

    /// Load from TOML-subset text.
    pub fn from_toml(text: &str) -> Result<Self, String> {
        let mut cfg = Config::default();
        for ((section, key), value) in parse_toml(text)? {
            cfg.set(&section, &key, &value)?;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Load from a file path.
    pub fn from_file(path: &str) -> Result<Self, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("reading {path}: {e}"))?;
        Self::from_toml(&text)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper_table1() {
        let l = LatencyConfig::default();
        // RDMA WRITE of the default 512 KB message ≈ 51.35 µs
        let w = l.rdma_write(512 * 1024);
        assert!((w as f64 - 51_350.0).abs() < 200.0, "{w}");
        // RDMA READ of a 4 KB page ≈ 36.48 µs
        let r = l.rdma_read(4096);
        assert!((r as f64 - 36_480.0).abs() < 400.0, "{r}");
        // copy of a 64 KB block ≈ 9.73 µs
        let c = l.copy(64 * 1024);
        assert!((c as f64 - 9_730.0).abs() < 50.0, "{c}");
        assert_eq!(l.connect, 200_668_000);
        assert_eq!(l.map_mr, 62_276_000);
    }

    #[test]
    fn toml_roundtrip_sets_fields() {
        let cfg = Config::from_toml(
            "[cluster]\nnodes = 12\nnode_mem_gb = 32\n\
             [valet]\nblock_io_kb = 32\nreplicas = 2\ndisk_backup = true\n\
             [latency]\nconnect_us = 1000.0\n",
        )
        .unwrap();
        assert_eq!(cfg.cluster.nodes, 12);
        assert_eq!(cfg.cluster.node_mem_bytes, 32 << 30);
        assert_eq!(cfg.valet.block_io_bytes, 32 * 1024);
        assert_eq!(cfg.valet.replicas, 2);
        assert!(cfg.valet.disk_backup);
        assert_eq!(cfg.latency.connect, 1_000_000);
    }

    #[test]
    fn slow_path_threads_defaults_inline_and_loads_from_toml() {
        // 1 = the pre-split single-mutex serve, the bit-for-bit default
        assert_eq!(Config::default().valet.slow_path_threads, 1);
        let cfg = Config::from_toml("[valet]\nslow_path_threads = 0\n")
            .unwrap();
        assert_eq!(cfg.valet.slow_path_threads, 0);
        let cfg = Config::from_toml("[valet]\nslow_path_threads = 3\n")
            .unwrap();
        assert_eq!(cfg.valet.slow_path_threads, 3);
    }

    #[test]
    fn unknown_key_is_error() {
        assert!(Config::from_toml("[valet]\nbogus = 1\n").is_err());
        assert!(Config::from_toml("[nope]\nx = 1\n").is_err());
    }

    #[test]
    fn pool_tier_is_off_by_default_and_loads_from_toml() {
        let d = Config::default();
        assert!(!d.valet.pool_tier.enabled);
        let cfg = Config::from_toml(
            "[valet.pool_tier]\nenabled = true\ncapacity_gb = 4\n\
             promote_max_idle_ms = 100\ndemote_after_ms = 1500\n\
             scan_period_ms = 250\npredictor = false\n\
             predictor_window_ms = 300\n",
        )
        .unwrap();
        let pt = &cfg.valet.pool_tier;
        assert!(pt.enabled);
        assert_eq!(pt.capacity_bytes, 4 << 30);
        assert_eq!(pt.promote_max_idle, ms(100));
        assert_eq!(pt.demote_after, ms(1500));
        assert_eq!(pt.scan_period, ms(250));
        assert!(!pt.predictor);
        assert_eq!(pt.predictor_window, ms(300));
        assert!(
            Config::from_toml("[valet.pool_tier]\nbogus = 1\n").is_err()
        );
    }

    #[test]
    fn health_is_off_by_default_and_loads_from_toml() {
        let d = Config::default();
        assert!(!d.valet.health.enabled);
        let cfg = Config::from_toml(
            "[valet.health]\nenabled = true\nmax_missed = 3\n\
             repair_period_ms = 50\nrebalance_max = 2\n",
        )
        .unwrap();
        let h = &cfg.valet.health;
        assert!(h.enabled);
        assert_eq!(h.max_missed, 3);
        assert_eq!(h.repair_period, ms(50));
        assert_eq!(h.rebalance_max, 2);
        assert!(Config::from_toml("[valet.health]\nbogus = 1\n").is_err());
    }

    #[test]
    fn validate_rejects_out_of_range_knobs() {
        // the default tree is valid
        Config::default().validate().unwrap();
        let bad = |toml: &str| {
            assert!(Config::from_toml(toml).is_err(), "accepted: {toml}");
        };
        // existing knobs gain range checks
        bad("[valet]\npressure_ewma = 0.0\n");
        bad("[valet]\npressure_ewma = 1.5\n");
        bad("[valet]\nprefetch_min_accuracy = 1.1\n");
        // pool-tier knobs
        bad("[valet.pool_tier]\nenabled = true\ncapacity_mb = 0\n");
        // capacity below one MR block cannot hold anything
        bad("[valet.pool_tier]\nenabled = true\ncapacity_mb = 512\n");
        bad("[valet.pool_tier]\npromote_max_idle_ms = 5000\n");
        bad("[valet.pool_tier]\nscan_period_ms = 0\n");
        bad("[valet.pool_tier]\npredictor_window_ms = 0\n");
        // health knobs: only constrained while enabled
        bad("[valet.health]\nenabled = true\nmax_missed = 0\n");
        bad("[valet.health]\nenabled = true\nrepair_period_ms = 0\n");
        Config::from_toml("[valet.health]\nmax_missed = 0\n").unwrap();
        // in-range values pass
        Config::from_toml(
            "[valet]\npressure_ewma = 1.0\nprefetch_min_accuracy = 0.0\n",
        )
        .unwrap();
    }

    #[test]
    fn backend_kind_parsing() {
        assert_eq!(BackendKind::parse("valet"), Some(BackendKind::Valet));
        assert_eq!(BackendKind::parse("NBDX"), Some(BackendKind::Nbdx));
        assert_eq!(BackendKind::parse("linux"), Some(BackendKind::LinuxSwap));
        assert_eq!(BackendKind::parse("wat"), None);
    }
}
