//! Remote Memory module (§4.2): the MR block pool a receiver node exposes
//! to sender nodes, with the per-block metadata tag of Figure 11 (owner +
//! last-write timestamp) that makes activity-based victim selection a
//! local decision — no queries to N sender nodes.
//!
//! The pool expands and shrinks with the node's free memory ("It can
//! dynamically expand and shrink MR blocks based on the free memory") and
//! its activity monitor reports pressure when native applications claim
//! memory back.

use crate::sim::Ns;
use crate::NodeId;

/// Identifier of an MR block on some node.
pub type MrBlockId = u64;

/// Which donated-memory tier a block lives in on its node. The tier is
/// part of the block's *address*: verbs, capacity accounting and victim
/// selection all dispatch on it.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum MemTier {
    /// CXL-style pooled memory at ~NUMA-hop latency (§Pond). Capacity
    /// is the node's slice of the pooled appliance
    /// (`valet.pool_tier.capacity_bytes`), separate from its DRAM.
    Pool,
    /// Classic RDMA-registered remote memory (the paper's only tier).
    /// Capacity is the node's donatable DRAM.
    Remote,
}

/// State of one registered MR block.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MrState {
    /// Serving reads/writes for its owner.
    Active,
    /// Being migrated away; reads allowed, writes parked at the sender.
    Migrating,
}

/// One unit-sized MR block (Figure 11's format: data + tag).
#[derive(Clone, Debug)]
pub struct MrBlock {
    /// Block id (unique per node).
    pub id: MrBlockId,
    /// Sender node that owns the data.
    pub owner: NodeId,
    /// Block size in bytes (the 1 GB unit by default).
    pub bytes: u64,
    /// Tag: virtual time of the last write from the owner.
    pub last_write: Ns,
    /// Tag: virtual time of the last *demand* read from the owner.
    /// Speculative prefetch fetches deliberately do not stamp this —
    /// only a prefetch that is later consumed counts — so a block whose
    /// pages were fetched ahead but never used ranks first as a victim.
    pub last_read: Ns,
    /// Tag: when the block was registered.
    pub registered_at: Ns,
    /// Current state.
    pub state: MrState,
    /// Which memory tier the block occupies on this node.
    pub tier: MemTier,
}

impl MrBlock {
    /// Last activity of either kind (write or demand read).
    pub fn last_activity(&self) -> Ns {
        self.last_write.max(self.last_read)
    }

    /// §3.5: `Non-Activity-Duration = Time_cur − Time_last_activity`.
    /// Activity covers writes *and* demand reads, so the victim ranking
    /// sees read phases, not just write phases.
    pub fn non_activity_duration(&self, now: Ns) -> Ns {
        now.saturating_sub(self.last_activity())
    }
}

/// The MR block pool of one receiver node.
#[derive(Clone, Debug, Default)]
pub struct MrBlockPool {
    blocks: Vec<MrBlock>,
    next_id: MrBlockId,
    /// Total registrations (stats).
    pub registered: u64,
    /// Total blocks released (evicted or migrated away) (stats).
    pub released: u64,
    /// Cached pool-tier resident bytes (kept in lockstep with the block
    /// list; audited against the recount by the `tier-accounting` law).
    pool_bytes: u64,
}

impl MrBlockPool {
    /// Empty pool.
    pub fn new() -> Self {
        Self::default()
    }

    /// Bytes currently registered as RDMA remote memory (the Remote
    /// tier only — pool-tier blocks live on the pooled appliance, not
    /// this node's donatable DRAM, so they never count against it).
    pub fn registered_bytes(&self) -> u64 {
        self.blocks
            .iter()
            .filter(|b| b.tier == MemTier::Remote)
            .map(|b| b.bytes)
            .sum()
    }

    /// Cached bytes resident in this node's pool-tier slice (the value
    /// the placement path charges against `pool_tier.capacity_bytes`).
    pub fn pool_bytes(&self) -> u64 {
        self.pool_bytes
    }

    /// Recount pool-tier resident bytes from the block list — the
    /// auditor's ground truth for [`Self::pool_bytes`].
    pub fn pool_bytes_recount(&self) -> u64 {
        self.blocks
            .iter()
            .filter(|b| b.tier == MemTier::Pool)
            .map(|b| b.bytes)
            .sum()
    }

    /// Register a new unit MR block for `owner` in the Remote tier. The
    /// receiver-side cost is charged by the caller (user-space
    /// registration, §4.2).
    pub fn register(
        &mut self,
        owner: NodeId,
        bytes: u64,
        now: Ns,
    ) -> MrBlockId {
        self.register_tier(owner, bytes, now, MemTier::Remote)
    }

    /// Register a new unit MR block for `owner` in an explicit tier.
    pub fn register_tier(
        &mut self,
        owner: NodeId,
        bytes: u64,
        now: Ns,
        tier: MemTier,
    ) -> MrBlockId {
        let id = self.next_id;
        self.next_id += 1;
        self.blocks.push(MrBlock {
            id,
            owner,
            bytes,
            last_write: now,
            last_read: 0,
            registered_at: now,
            state: MrState::Active,
            tier,
        });
        self.registered += 1;
        if tier == MemTier::Pool {
            self.pool_bytes += bytes;
        }
        id
    }

    /// Stamp a write into `block` ("TimeStamp on the MR block is updated
    /// by write request", Figure 13).
    pub fn touch_write(&mut self, block: MrBlockId, now: Ns) {
        if let Some(b) = self.get_mut(block) {
            b.last_write = b.last_write.max(now);
        }
    }

    /// Stamp a *demand* read into `block`: the read-side half of the
    /// activity tag, fed by the miss pipeline's RDMA READs and by
    /// consumed prefetches (never by speculative fetches), so read-heavy
    /// phases keep a block off the victim list.
    pub fn touch_read(&mut self, block: MrBlockId, now: Ns) {
        if let Some(b) = self.get_mut(block) {
            b.last_read = b.last_read.max(now);
        }
    }

    /// Lookup.
    pub fn get(&self, block: MrBlockId) -> Option<&MrBlock> {
        self.blocks.iter().find(|b| b.id == block)
    }

    /// Mutable lookup.
    pub fn get_mut(&mut self, block: MrBlockId) -> Option<&mut MrBlock> {
        self.blocks.iter_mut().find(|b| b.id == block)
    }

    /// Remove a block (eviction-by-delete or migration completion).
    pub fn release(&mut self, block: MrBlockId) -> Option<MrBlock> {
        let i = self.blocks.iter().position(|b| b.id == block)?;
        self.released += 1;
        let b = self.blocks.swap_remove(i);
        if b.tier == MemTier::Pool {
            self.pool_bytes = self.pool_bytes.saturating_sub(b.bytes);
        }
        Some(b)
    }

    /// The least-active block (max Non-Activity-Duration) among Active
    /// **Remote-tier** blocks — §3.5's victim, computed purely from
    /// local tags. Native-memory pressure reclaims DRAM; pool-tier
    /// blocks occupy the pooled appliance, so evicting one would not
    /// relieve the node and they are exempt here (the tier pump demotes
    /// them on its own schedule).
    pub fn least_active(&self, now: Ns) -> Option<&MrBlock> {
        self.blocks
            .iter()
            .filter(|b| {
                b.state == MrState::Active && b.tier == MemTier::Remote
            })
            .max_by_key(|b| (b.non_activity_duration(now), b.id))
    }

    /// A filtered clone containing only `owner`'s blocks (ids
    /// preserved) — the victim-selection view a tenant-tagged
    /// [`crate::coordinator::Coordinator`] hands to its
    /// [`crate::eviction::VictimPolicy`] so one tenant never evicts
    /// another tenant's blocks.
    pub fn owned_by(&self, owner: NodeId) -> MrBlockPool {
        let blocks: Vec<MrBlock> = self
            .blocks
            .iter()
            .filter(|b| b.owner == owner)
            .cloned()
            .collect();
        let pool_bytes = blocks
            .iter()
            .filter(|b| b.tier == MemTier::Pool)
            .map(|b| b.bytes)
            .sum();
        MrBlockPool {
            blocks,
            next_id: self.next_id,
            registered: self.registered,
            released: self.released,
            pool_bytes,
        }
    }

    /// All blocks (iteration for monitors/tests).
    pub fn blocks(&self) -> &[MrBlock] {
        &self.blocks
    }

    /// Number of blocks.
    pub fn len(&self) -> usize {
        self.blocks.len()
    }

    /// True if no blocks are registered.
    pub fn is_empty(&self) -> bool {
        self.blocks.is_empty()
    }

    /// Test-only corruption hook for the `tier-accounting` law: claim
    /// pool-tier bytes that no resident block backs.
    #[cfg(any(feature = "audit", debug_assertions))]
    #[doc(hidden)]
    pub fn audit_corrupt_pool_bytes(&mut self) {
        self.pool_bytes += 1;
    }
}

/// Activity monitor (Figure 16): watches a node's free memory and decides
/// how many MR blocks must be reclaimed to satisfy native applications.
#[derive(Clone, Debug)]
pub struct ActivityMonitor {
    /// Total physical memory of the node.
    pub total_bytes: u64,
    /// Memory currently used by native applications (containers).
    pub native_bytes: u64,
    /// Free-memory floor the node must keep for itself.
    pub reserve_bytes: u64,
}

impl ActivityMonitor {
    /// Monitor for a node of `total_bytes`, keeping `reserve_bytes` free.
    pub fn new(total_bytes: u64, reserve_bytes: u64) -> Self {
        ActivityMonitor {
            total_bytes,
            native_bytes: 0,
            reserve_bytes,
        }
    }

    /// Free bytes available for (additional) MR registration.
    pub fn free_for_mr(&self, registered: u64) -> u64 {
        self.total_bytes
            .saturating_sub(self.native_bytes)
            .saturating_sub(self.reserve_bytes)
            .saturating_sub(registered)
    }

    /// Bytes of MR that must be reclaimed to satisfy current native
    /// usage (0 when no pressure).
    pub fn pressure(&self, registered: u64) -> u64 {
        let available = self
            .total_bytes
            .saturating_sub(self.native_bytes)
            .saturating_sub(self.reserve_bytes);
        registered.saturating_sub(available)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn register_touch_release_roundtrip() {
        let mut p = MrBlockPool::new();
        let a = p.register(1, 1 << 30, 100);
        let b = p.register(2, 1 << 30, 100);
        assert_eq!(p.len(), 2);
        assert_eq!(p.registered_bytes(), 2 << 30);
        p.touch_write(a, 500);
        assert_eq!(p.get(a).unwrap().last_write, 500);
        let released = p.release(b).unwrap();
        assert_eq!(released.owner, 2);
        assert_eq!(p.len(), 1);
    }

    #[test]
    fn least_active_is_max_non_activity_duration() {
        // Figure 13's example: blocks with last-write stamps 15, 9, 3 —
        // the block stamped 3 is the victim.
        let mut p = MrBlockPool::new();
        let b15 = p.register(0, 1, 0);
        let b9 = p.register(0, 1, 0);
        let b3 = p.register(0, 1, 0);
        p.touch_write(b15, 15);
        p.touch_write(b9, 9);
        p.touch_write(b3, 3);
        assert_eq!(p.least_active(20).unwrap().id, b3);
    }

    #[test]
    fn touch_write_never_moves_time_backwards() {
        let mut p = MrBlockPool::new();
        let b = p.register(0, 1, 0);
        p.touch_write(b, 100);
        p.touch_write(b, 50); // stale stamp ignored
        assert_eq!(p.get(b).unwrap().last_write, 100);
    }

    #[test]
    fn demand_reads_count_as_activity() {
        // Figure-13 ranking extended with the read tag: a block in a
        // read-only phase must not be the victim just because it has
        // not been written lately.
        let mut p = MrBlockPool::new();
        let read_hot = p.register(0, 1, 0);
        let idle = p.register(0, 1, 0);
        p.touch_write(read_hot, 10);
        p.touch_write(idle, 50);
        p.touch_read(read_hot, 900);
        assert_eq!(p.least_active(1000).unwrap().id, idle);
        // stale read stamps never move time backwards
        p.touch_read(read_hot, 100);
        assert_eq!(p.get(read_hot).unwrap().last_read, 900);
        assert_eq!(p.get(read_hot).unwrap().last_activity(), 900);
    }

    #[test]
    fn migrating_blocks_are_not_victims() {
        let mut p = MrBlockPool::new();
        let old = p.register(0, 1, 0);
        let newer = p.register(0, 1, 0);
        p.touch_write(newer, 1000);
        p.get_mut(old).unwrap().state = MrState::Migrating;
        assert_eq!(p.least_active(2000).unwrap().id, newer);
    }

    #[test]
    fn owned_by_filters_but_preserves_ids() {
        let mut p = MrBlockPool::new();
        let a1 = p.register(1, 1 << 20, 0);
        let b1 = p.register(2, 1 << 20, 0);
        let a2 = p.register(1, 1 << 20, 0);
        p.touch_write(a1, 10);
        p.touch_write(b1, 5);
        let view = p.owned_by(1);
        assert_eq!(view.len(), 2);
        assert!(view.get(a1).is_some() && view.get(a2).is_some());
        assert!(view.get(b1).is_none());
        // least-active within the view is owner 1's oldest, not b1
        assert_eq!(view.least_active(100).unwrap().id, a2);
    }

    #[test]
    fn pool_tier_bytes_tracked_separately_from_remote() {
        let mut p = MrBlockPool::new();
        let r = p.register(1, 4 << 20, 0);
        let q = p.register_tier(1, 1 << 20, 0, MemTier::Pool);
        // Remote-tier bytes are the node's donated DRAM; pool-tier
        // bytes charge the appliance slice. Neither leaks into the
        // other's ledger.
        assert_eq!(p.registered_bytes(), 4 << 20);
        assert_eq!(p.pool_bytes(), 1 << 20);
        assert_eq!(p.pool_bytes_recount(), 1 << 20);
        assert_eq!(p.get(q).unwrap().tier, MemTier::Pool);
        assert_eq!(p.get(r).unwrap().tier, MemTier::Remote);
        p.release(q);
        assert_eq!(p.pool_bytes(), 0);
        assert_eq!(p.pool_bytes_recount(), 0);
        assert_eq!(p.registered_bytes(), 4 << 20);
    }

    #[test]
    fn pressure_victims_come_from_the_remote_tier_only() {
        // An ancient pool-tier block must not be selected to relieve
        // native-DRAM pressure: releasing it frees appliance capacity,
        // not node memory.
        let mut p = MrBlockPool::new();
        let pool_old = p.register_tier(0, 1, 0, MemTier::Pool);
        let remote_new = p.register(0, 1, 0);
        p.touch_write(remote_new, 1000);
        assert_ne!(p.least_active(2000).unwrap().id, pool_old);
        assert_eq!(p.least_active(2000).unwrap().id, remote_new);
    }

    #[test]
    fn owned_by_recomputes_the_pool_ledger() {
        let mut p = MrBlockPool::new();
        p.register_tier(1, 100, 0, MemTier::Pool);
        p.register_tier(2, 7, 0, MemTier::Pool);
        let view = p.owned_by(1);
        assert_eq!(view.pool_bytes(), 100);
        assert_eq!(view.pool_bytes(), view.pool_bytes_recount());
    }

    #[test]
    fn monitor_pressure_math() {
        let mut m = ActivityMonitor::new(64 << 30, 2 << 30);
        // 20 GB registered, native apps idle → no pressure
        assert_eq!(m.pressure(20 << 30), 0);
        assert_eq!(m.free_for_mr(20 << 30), 42 << 30);
        // native apps claim 50 GB → 64-50-2 = 12 GB available < 20 GB
        m.native_bytes = 50 << 30;
        assert_eq!(m.pressure(20 << 30), 8 << 30);
        assert_eq!(m.free_for_mr(20 << 30), 0);
    }
}
