//! The sharded request engine: `S` shard-local fast paths
//! ([`crate::coordinator::fast::ShardFastPath`]) behind one shared slow
//! path ([`crate::coordinator::sender::RemoteSender`]).
//!
//! Valet's §4.1 design allows parallel reads while serializing only
//! writes for consistency. The single [`crate::coordinator::Coordinator`]
//! realizes that design for one execution context; this engine partitions
//! the page space so `S` contexts can run the fast path concurrently:
//!
//! ```text
//!            requests (page-routed: shard_of = (page / stripe) % S)
//!      ┌───────────┬───────────┬───────────┐
//!      ▼           ▼           ▼           ▼
//!  ┌────────┐  ┌────────┐  ┌────────┐  ┌────────┐   shard-local FAST path
//!  │shard 0 │  │shard 1 │  │shard 2 │  │shard 3 │   (GPT + mempool +
//!  │GPT     │  │GPT     │  │GPT     │  │GPT     │    staging queue;
//!  │mempool │  │mempool │  │mempool │  │mempool │    write ORDER is a
//!  │staging │  │staging │  │staging │  │staging │    per-shard property)
//!  └───┬────┘  └───┬────┘  └───┬────┘  └───┬────┘
//!      └───────────┴─────┬─────┴───────────┘
//!                        ▼                           shared SLOW path
//!            ┌──────────────────────┐
//!            │ RemoteSender          │  one sender-thread timeline,
//!            │  coalescing batcher   │  per-shard completion mailboxes,
//!            │  unit map + placement │  migration / remote pressure,
//!            │  victim policy        │  arbiter leases split per shard
//!            └──────────────────────┘
//! ```
//!
//! ## Partitioning
//!
//! The page space is interleaved at *stripe* granularity, where one
//! stripe is one block-I/O request (`block_io_bytes / PAGE_SIZE` pages):
//! `shard_of(page) = (page / stripe) % S`. Stripe (rather than raw
//! `page % S`) interleaving keeps every page of one block-I/O request in
//! one shard, so a request is handled by exactly one worker and a read
//! of any page routes to the shard that cached it. Writes larger than a
//! stripe are split at stripe boundaries and land on consecutive shards
//! (which is where multi-shard write parallelism comes from).
//!
//! ## `S = 1` is the PR-1 Coordinator
//!
//! With one shard the engine executes the identical sequence of
//! operations as the pre-shard `Coordinator` (which is now a thin
//! wrapper over this engine): same latencies, same metrics, same hit
//! splits, bit for bit. `tests/sharding.rs` pins this equivalence.
//!
//! ## Resource splitting
//!
//! The mempool floor/cap, the host-free share and the arbiter lease are
//! split across shards with [`crate::arbiter::split_pages`] (remainder
//! to the lowest shards), so shard totals always equal the single-shard
//! budget.

use crate::arbiter::{share_of, split_pages};
use crate::audit::{self, Law, Violation};
use crate::backends::{Access, ClusterState, PressureOutcome, Source};
use crate::config::Config;
use crate::coordinator::fast::ShardFastPath;
use crate::coordinator::sender::RemoteSender;
use crate::mempool::AllocFail;
use crate::metrics::RunMetrics;
use crate::prefetch::PrefetchConfig;
use crate::queues::WriteSet;
use crate::sim::Ns;
use crate::{pages_for, NodeId, PAGE_SIZE};

/// How the read pipeline sees the page-space partition: which shard is
/// running, how many exist, and the stripe size. The miss path needs it
/// to keep readahead shard-local (a prefetcher may only land pages its
/// own shard owns — see [`shard_of_page`]).
#[derive(Clone, Copy, Debug)]
pub struct ShardRoute {
    /// The shard executing the request.
    pub shard: usize,
    /// Total shards in the engine.
    pub shards: usize,
    /// Pages per stripe (the interleave granularity).
    pub stripe_pages: u64,
}

/// The worse of two read sources (LocalPool < Remote < Disk) — a block
/// read spanning tiers reports the slowest tier it touched. Shared with
/// the default [`crate::backends::PagingBackend::read_block`] so the
/// severity ordering lives in one place.
pub(crate) fn worse_source(a: Source, b: Source) -> Source {
    fn rank(s: Source) -> u8 {
        match s {
            Source::LocalPool => 0,
            Source::Remote => 1,
            Source::Disk => 2,
        }
    }
    if rank(b) > rank(a) {
        b
    } else {
        a
    }
}

// ---------------------------------------------------------------------
// Per-shard request orchestration (shared by the simulated engine and
// the live serve workers — exactly one implementation of each stage).
// ---------------------------------------------------------------------

/// Drain `shard`'s completion mailbox into its fast path.
pub fn apply_mailbox(
    sender: &mut RemoteSender,
    fast: &mut ShardFastPath,
    shard: usize,
) {
    for ws in sender.take_done(shard) {
        fast.apply_durable(ws);
    }
}

/// Stamp deferred read activity onto MR blocks: the lock-free prefetch
/// hit path parked `(page, time)` pairs in the shard's `activity_due`
/// buffer (it cannot reach the cluster substrate without the slow-path
/// lock); every slow-path crossing drains them here so a consumed
/// prefetch counts as demand-read activity for §3.5 victim ranking.
pub fn flush_activity(
    sender: &RemoteSender,
    fast: &mut ShardFastPath,
    cl: &mut ClusterState,
) {
    for (page, t) in fast.activity_due.drain(..) {
        let unit = sender.units().unit_of(page);
        if let Some(u) = sender.units().get(unit) {
            if let (Some(&n), Some(&b)) = (u.nodes.first(), u.blocks.first())
            {
                cl.mrpools[n].touch_read(b, t);
            }
        }
    }
    if audit::enabled() {
        fast.audit_tick = fast.audit_tick.wrapping_add(1);
        if fast.audit_tick % 32 == 0 {
            audit::enforce(&fast.audit_check(None));
        }
    }
}

/// One slow-path crossing's audit: crossing-clock monotonicity on every
/// call ([`Law::TimeMonotonic`] — a shard's slow-path crossings may
/// never travel backwards in virtual time, or activity stamps and
/// staging starts would reorder) plus a sampled deep sweep of the
/// shard's fast-path catalog (every 32nd crossing; O(slots) each, so
/// per-crossing it would make debug tests quadratic). Advances the
/// shard's watermark. A no-op unless auditing is enabled.
pub fn audit_crossing(fast: &mut ShardFastPath, shard: usize, now: Ns) {
    if !audit::enabled() {
        return;
    }
    fast.audit_tick = fast.audit_tick.wrapping_add(1);
    let mut v = if fast.audit_tick % 32 == 0 {
        fast.audit_check(Some(shard))
    } else {
        Vec::new()
    };
    let watermark = fast.audit_last_now;
    audit::check(
        &mut v,
        now >= watermark,
        Law::TimeMonotonic,
        Some(shard),
        || format!("crossing at t={now} behind watermark {watermark}"),
        || format!("now={now} watermark={watermark}"),
    );
    fast.audit_last_now = watermark.max(now);
    audit::enforce(&v);
}

/// Find the earliest staged write set of `fast` that some *idle* sender
/// lane can take at `now`: returns `(staging index, service start,
/// enqueued_at)` of the first set (queue order) whose lane is free, or
/// `None` when nothing is sendable. The scan walks past sets whose lane
/// is busy — a saturated lane never blocks submissions routed to other
/// lanes — but only the *first* set per lane is a candidate, so each
/// lane stays FIFO in enqueue order.
///
/// With one lane this degenerates to the pre-split gate exactly: every
/// set routes to lane 0, so the scan looks at the front only, and the
/// all-lanes-busy early return fires *before any routing* — an unmapped
/// unit's placement pick still happens at send time, not earlier.
fn next_sendable(
    sender: &mut RemoteSender,
    fast: &ShardFastPath,
    cl: &ClusterState,
    now: Ns,
) -> Option<(usize, Ns, Ns)> {
    let nlanes = sender.lane_count();
    if (0..nlanes).all(|l| sender.lane_busy_until(l) > now) {
        return None;
    }
    let mut seen: u64 = 0;
    for idx in 0..fast.staging.len() {
        let ws = fast.staging.get(idx)?;
        let enq = ws.enqueued_at;
        if enq > now {
            // staging is FIFO in enqueue time: everything behind this
            // set entered even later
            break;
        }
        let lane = sender.route_page(cl, ws.page);
        if seen & (1u64 << lane) != 0 {
            // an earlier set already owns this lane's next slot
            continue;
        }
        seen |= 1u64 << lane;
        let busy = sender.lane_busy_until(lane);
        if busy <= now {
            return Some((idx, busy.max(enq), enq));
        }
        if seen.count_ones() as usize >= nlanes {
            break; // every lane's next candidate is gated
        }
    }
    None
}

/// Drive the shared sender for one shard: apply completions, advance
/// the migration tables (the reclaim pipeline rides the same pump),
/// then send coalesced batches from this shard's staging queue whose
/// service can start at or before `now` — each on its target peer's
/// lane, scanning past sets whose lane is busy.
pub fn drive_shard(
    sender: &mut RemoteSender,
    fast: &mut ShardFastPath,
    cl: &mut ClusterState,
    now: Ns,
    shard: usize,
) {
    sender.complete_inflight(cl, now);
    sender.advance_migrations(cl, now);
    flush_activity(sender, fast, cl);
    apply_mailbox(sender, fast, shard);
    while let Some((idx, start, _)) = next_sendable(sender, fast, cl, now) {
        sender.send_batch_at(cl, start, shard, fast, idx);
        // a batch may have parked against (or completed) a migration;
        // keep the two pipelines interleaved on the same timeline
        sender.advance_migrations(cl, now);
    }
    audit_crossing(fast, shard, now);
}

/// Block until at least one of this shard's mempool slots can be
/// recycled: force the sender pipeline forward and apply the earliest
/// completion carrying this shard's write sets. Returns the time the
/// caller may retry.
fn wait_for_reclaimable(
    sender: &mut RemoteSender,
    fast: &mut ShardFastPath,
    cl: &mut ClusterState,
    now: Ns,
    shard: usize,
) -> Ns {
    // Durable write sets may already sit in this shard's mailbox (a
    // DIFFERENT shard's drive completed our batches without applying
    // them): applying them frees slots with no time passing. Without
    // this check the alloc-retry loop would spin forever — the sets are
    // neither in flight nor staged. A no-op at S=1, where every
    // complete_inflight is immediately followed by an apply.
    let parked = sender.take_done(shard);
    if !parked.is_empty() {
        for ws in parked {
            fast.apply_durable(ws);
        }
        return now;
    }
    // Earliest in-flight completion with our write sets?
    if let Some(min_done) = sender.inflight_min_done(shard) {
        let t = min_done.max(now);
        sender.complete_inflight(cl, min_done);
        apply_mailbox(sender, fast, shard);
        return t;
    }
    if !fast.staging.is_empty() {
        // Forced send: this is a blocking wait, so jump to whichever
        // lane frees first among the queued sets' target lanes (first
        // set per lane only — per-lane FIFO — and queue order breaks
        // ties). With one lane this is exactly the pre-split
        // `busy_until().max(now)` front send.
        let mut best: Option<(Ns, usize)> = None;
        let mut seen: u64 = 0;
        for idx in 0..fast.staging.len() {
            let Some(ws) = fast.staging.get(idx) else { break };
            let lane = sender.route_page(cl, ws.page);
            if seen & (1u64 << lane) != 0 {
                continue;
            }
            seen |= 1u64 << lane;
            let start = sender.lane_busy_until(lane).max(now);
            let better = match best {
                Some((bs, _)) => start < bs,
                None => true,
            };
            if better {
                best = Some((start, idx));
            }
            if seen.count_ones() as usize >= sender.lane_count() {
                break;
            }
        }
        let (start, idx) = best.expect("staging checked non-empty");
        let done = sender.send_batch_at(cl, start, shard, fast, idx);
        sender.complete_inflight(cl, done);
        apply_mailbox(sender, fast, shard);
        return done.max(now);
    }
    // Write sets may be parked against an in-flight migration (neither
    // staged, in flight, nor in the mailbox): jump to the table's next
    // milestone and advance it — at COMMIT the parked sets flush into
    // `inflight`, where the arm above picks them up. Without this the
    // alloc-retry loop would crawl 1 ns at a time toward the commit.
    if let Some(t) = sender.next_migration_event() {
        let t = t.max(now);
        sender.advance_migrations(cl, t);
        sender.complete_inflight(cl, t);
        apply_mailbox(sender, fast, shard);
        return t;
    }
    // Nothing pending: caller's alloc should succeed after growth or
    // is genuinely out of memory; avoid infinite loops by advancing.
    now + 1
}

/// One shard's write critical path (Figure 7): GPT insert, copy into the
/// shard's mempool (with grow/backpressure per §3.4), staging-queue push
/// — then the request ends; the shared sender drains in the background.
#[allow(clippy::too_many_arguments)]
pub fn shard_write(
    sender: &mut RemoteSender,
    fast: &mut ShardFastPath,
    cl: &mut ClusterState,
    shard: usize,
    now: Ns,
    page: u64,
    bytes: u64,
    host_free_pages: u64,
) -> Access {
    let radix_insert = sender.lat().radix_insert;
    let staging_enqueue = sender.lat().staging_enqueue;
    let copy = sender.lat().copy(bytes);
    let npages = pages_for(bytes);
    let mut t = now + radix_insert;
    fast.metrics.write_parts.add("radix", radix_insert);

    let mut slots = Vec::with_capacity(npages as usize);
    for p in page..page + npages {
        if let Some(slot) = fast.gpt.lookup(p) {
            // Overwrite in place (§5.2): newer write set supersedes.
            let flags = fast.mempool.flags(slot);
            if flags.prefetched {
                // Read-your-writes vs an in-flight prefetch: the write
                // wins — the stale remote data must neither be waited
                // for nor count as a future hit (unmark below clears
                // the tag and books the waste).
                fast.pending_arrivals.remove(&p);
            }
            if flags.reclaimable {
                fast.mempool.unmark_reclaimable(slot);
            } else {
                fast.mempool.bump_update(slot);
            }
            fast.remote_ready.clear(p); // remote copy now stale
            slots.push(slot);
            continue;
        }
        // Allocate a slot, stalling on backpressure if required.
        loop {
            match fast.mempool.alloc(p, host_free_pages) {
                Ok(a) => {
                    if let Some(evicted) = a.evicted_page {
                        fast.gpt.remove(evicted);
                        // an evicted prefetched page may still have an
                        // arrival tracked — drop it with the page
                        fast.pending_arrivals.remove(&evicted);
                    }
                    fast.gpt.insert(p, a.slot);
                    slots.push(a.slot);
                    break;
                }
                Err(AllocFail::NoReclaimable) => {
                    let retry =
                        wait_for_reclaimable(sender, fast, cl, t, shard);
                    if retry > t {
                        fast.metrics.write_parts.add("stall", retry - t);
                        t = retry;
                    }
                }
            }
        }
    }

    t += copy;
    fast.metrics.write_parts.add("copy", copy);
    t += staging_enqueue;
    fast.metrics.write_parts.add("enqueue", staging_enqueue);

    fast.staging.push(WriteSet {
        page,
        slots,
        bytes,
        enqueued_at: t,
    });
    fast.metrics.write_latency.record(t - now);
    // opportunistically push the background pipeline forward
    drive_shard(sender, fast, cl, t, shard);
    Access {
        end: t,
        source: Source::LocalPool,
    }
}

/// The shard-local half of [`shard_write`], runnable without the slow
/// path: GPT insert, mempool copy and staging-queue push — everything
/// the critical path in Figure 7 actually touches — using only the
/// shard's own state. The concurrent serve front-end calls this
/// lock-free (the staged sets then travel through the lane admission
/// rings); the latency charges are identical to [`shard_write`]'s.
///
/// Returns `None` when an allocation hits backpressure
/// ([`AllocFail::NoReclaimable`]): making progress there *requires* the
/// slow path (forced sends, migration stepping), so the caller falls
/// back to the locked [`shard_write`]. Fast-path mutations already made
/// (overwrite bookkeeping, allocated slots for earlier pages) are
/// benign across the retry — the locked pass resolves those pages via
/// the GPT-overwrite arm (the shard's diagnostic `write_parts` radix
/// charge double-counts on that rare retry; latencies do not).
pub fn shard_stage_write(
    fast: &mut ShardFastPath,
    lat: &crate::config::LatencyConfig,
    now: Ns,
    page: u64,
    bytes: u64,
    host_free_pages: u64,
) -> Option<Access> {
    let radix_insert = lat.radix_insert;
    let staging_enqueue = lat.staging_enqueue;
    let copy = lat.copy(bytes);
    let npages = pages_for(bytes);
    let mut t = now + radix_insert;
    fast.metrics.write_parts.add("radix", radix_insert);

    let mut slots = Vec::with_capacity(npages as usize);
    for p in page..page + npages {
        if let Some(slot) = fast.gpt.lookup(p) {
            // overwrite in place (§5.2) — same arm as `shard_write`
            let flags = fast.mempool.flags(slot);
            if flags.prefetched {
                fast.pending_arrivals.remove(&p);
            }
            if flags.reclaimable {
                fast.mempool.unmark_reclaimable(slot);
            } else {
                fast.mempool.bump_update(slot);
            }
            fast.remote_ready.clear(p);
            slots.push(slot);
            continue;
        }
        match fast.mempool.alloc(p, host_free_pages) {
            Ok(a) => {
                if let Some(evicted) = a.evicted_page {
                    fast.gpt.remove(evicted);
                    fast.pending_arrivals.remove(&evicted);
                }
                fast.gpt.insert(p, a.slot);
                slots.push(a.slot);
            }
            // backpressure needs the slow path: bail to the locked run
            Err(AllocFail::NoReclaimable) => return None,
        }
    }

    t += copy;
    fast.metrics.write_parts.add("copy", copy);
    t += staging_enqueue;
    fast.metrics.write_parts.add("enqueue", staging_enqueue);

    fast.staging.push(WriteSet {
        page,
        slots,
        bytes,
        enqueued_at: t,
    });
    fast.metrics.write_latency.record(t - now);
    Some(Access {
        end: t,
        source: Source::LocalPool,
    })
}

/// One shard's read miss path: coalesce with an outstanding fetch of
/// the same page if one is in flight, else one-sided RDMA READ from the
/// unit's first *live* replica (the primary, unless the health ledger
/// declared its peer Dead), else disk (Table 3 fallback). Every miss also feeds
/// the shard's stride prefetcher, which may post an asynchronous
/// readahead batch — posted *after* the demand fetch so speculation
/// never queues ahead of demand on the NIC, and never charged to this
/// request's latency. The local-hit fast path is
/// [`ShardFastPath::try_read_local`] — call that first; this function
/// assumes it returned `None`.
pub fn shard_read_miss(
    sender: &mut RemoteSender,
    fast: &mut ShardFastPath,
    cl: &mut ClusterState,
    now: Ns,
    page: u64,
    route: ShardRoute,
) -> Access {
    let lat = sender.lat();
    let radix_lookup = lat.radix_lookup;
    let copy_read_page = lat.copy_read_page;
    let mrpool_get = lat.mrpool_get;
    let mut t = now + radix_lookup;
    fast.metrics.read_parts.add("radix", radix_lookup);
    flush_activity(sender, fast, cl);
    // Miss coalescing: piggyback on an in-flight fetch of this page
    // instead of posting a duplicate READ.
    if let Some(done) = sender.inflight_read_done(page, t) {
        fast.metrics.read_parts.add("coalesce", done.saturating_sub(t));
        let end = done.max(t) + copy_read_page;
        fast.metrics.read_parts.add("copy", copy_read_page);
        fast.metrics.coalesced_reads += 1;
        fast.metrics.remote_hits += 1;
        fast.metrics.read_latency.record(end - now);
        maybe_prefetch(sender, fast, cl, now, page, route);
        return Access {
            end,
            source: Source::Remote,
        };
    }
    let unit_id = sender.units().unit_of(page);
    // Failover ladder, rung 1: a live replica slot. With health off
    // this is exactly the unit's primary; with health on, a read whose
    // primary peer died fails over to the first surviving replica
    // (`replication::read_source` inside `read_slot`).
    let slot = if fast.remote_ready.get(page) {
        sender.read_slot(unit_id)
    } else {
        None
    };
    if let Some((primary, primary_block, ready_at)) = slot {
        t = t.max(ready_at);
        t += mrpool_get;
        fast.metrics.read_parts.add("mrpool", mrpool_get);
        // fetch with the verb of the primary block's tier: a pool-tier
        // hit takes the NUMA-hop appliance access, not an RDMA READ
        let pool_hit = cl.block_tier(primary, primary_block)
            == crate::mrpool::MemTier::Pool;
        let verb = cl.tiered_read(t, primary, primary_block, PAGE_SIZE);
        // demand-read activity: §3.5 victim ranking sees read phases
        cl.mrpools[primary].touch_read(primary_block, verb.end);
        sender.note_demand_read(cl, unit_id);
        sender.note_inflight_read(now, page, verb.end);
        if pool_hit {
            fast.metrics.read_parts.add("pool", verb.end - t);
            fast.metrics.pool_hits += 1;
        } else {
            fast.metrics.read_parts.add("rdma", verb.end - t);
        }
        t = verb.end + copy_read_page;
        fast.metrics.read_parts.add("copy", copy_read_page);
        fast.metrics.remote_hits += 1;
        fast.metrics.read_latency.record(t - now);
        maybe_prefetch(sender, fast, cl, now, page, route);
        return Access {
            end: t,
            source: Source::Remote,
        };
    }
    // Rungs 2–3: disk backup, else the data is gone. A page the remote
    // side acknowledged but no live replica or disk copy can serve is a
    // *lost read* — the churn gate's headline number. The disk access
    // is charged either way so virtual time flows identically.
    if sender.health_on()
        && fast.remote_ready.get(page)
        && !fast.disk_valid.get(page)
    {
        fast.metrics.lost_reads += 1;
    }
    let end = cl.disks[cl.sender].read(t, PAGE_SIZE);
    fast.metrics.read_parts.add("disk", end - t);
    fast.metrics.disk_reads += 1;
    fast.metrics.read_latency.record(end - now);
    maybe_prefetch(sender, fast, cl, now, page, route);
    Access {
        end,
        source: Source::Disk,
    }
}

/// Feed one demand miss into the shard's prefetcher and, when it
/// proposes readahead, land the predicted pages: allocate
/// prefetch-tagged slots (never displacing demand-cached data — see
/// [`crate::mempool::Mempool::alloc_prefetched`]), insert them into the
/// GPT so later demand reads hit locally, and post one per-unit
/// coalesced fetch batch for the pages not already in flight. Arrival
/// times land in the shard's `pending_arrivals` so a demand read that
/// beats the wire waits only for the remainder. Entirely asynchronous:
/// nothing here extends the triggering request.
fn maybe_prefetch(
    sender: &mut RemoteSender,
    fast: &mut ShardFastPath,
    cl: &mut ClusterState,
    now: Ns,
    page: u64,
    route: ShardRoute,
) {
    // Waste feedback first, so a misfiring prefetcher trips its
    // accuracy governor before proposing more work.
    fast.sync_prefetch_waste();
    let Some(ra) = fast.prefetcher.observe_miss(page) else {
        return;
    };
    land_readahead(sender, fast, cl, now, page, ra, route);
}

/// Extend the readahead window after a prefetch hit (trend
/// continuation): the lock-free hit path parked the hit page in the
/// shard's `readahead_due`; this consumes it and lands the next
/// `degree` pages along the standing stride. Call whenever the slow
/// path is (or may cheaply be) available — the engine does it right
/// after a hit, the sharded serve worker on the next lock acquisition.
/// A no-op when nothing is due.
pub fn drive_readahead(
    sender: &mut RemoteSender,
    fast: &mut ShardFastPath,
    cl: &mut ClusterState,
    now: Ns,
    route: ShardRoute,
) {
    flush_activity(sender, fast, cl);
    let Some(page) = fast.readahead_due.take() else {
        return;
    };
    fast.sync_prefetch_waste();
    let Some(ra) = fast.prefetcher.continuation() else {
        return;
    };
    land_readahead(sender, fast, cl, now, page, ra, route);
}

/// Land one readahead proposal (see [`maybe_prefetch`] for the policy
/// preamble): filter candidates, allocate prefetch-tagged slots, post
/// one per-unit coalesced fetch for pages not already in flight.
fn land_readahead(
    sender: &mut RemoteSender,
    fast: &mut ShardFastPath,
    cl: &mut ClusterState,
    now: Ns,
    page: u64,
    ra: crate::prefetch::Readahead,
    route: ShardRoute,
) {
    // Collect candidates along the stride: pages this shard owns, not
    // cached, with a valid remote copy on a live unit. The fetch list
    // lives in a reusable shard buffer — readahead fires on every
    // prefetch hit in steady state and must not allocate there.
    let mut landed = 0u64;
    let mut fetch = std::mem::take(&mut fast.scratch_fetch);
    fetch.clear();
    for k in 1..=ra.degree.min(i64::MAX as u64) as i64 {
        let Some(step) = ra.stride.checked_mul(k) else {
            break;
        };
        let Some(p) = page.checked_add_signed(step) else {
            break;
        };
        if shard_of_page(p, route.stripe_pages, route.shards)
            != route.shard
        {
            continue;
        }
        if fast.gpt.get(p).is_some() || !fast.remote_ready.get(p) {
            continue;
        }
        let unit = sender.units().unit_of(p);
        if sender.read_slot(unit).is_none() {
            continue;
        }
        // A slot for the speculation, or stop: the pool has no room.
        let Some(a) = fast.mempool.alloc_prefetched(p) else {
            break;
        };
        if let Some(evicted) = a.evicted_page {
            fast.gpt.remove(evicted);
            fast.pending_arrivals.remove(&evicted);
        }
        fast.gpt.insert(p, a.slot);
        landed += 1;
        // Free ride: a fetch of this page is already in flight — land
        // at its completion without posting any wire work.
        if let Some(done) = sender.inflight_read_done(p, now) {
            fast.pending_arrivals.insert(p, done);
        } else {
            fetch.push(p);
        }
    }
    if landed > 0 {
        if !fetch.is_empty() {
            let mut arrivals = std::mem::take(&mut fast.scratch_arrivals);
            // speculative: arrival bookkeeping only, no activity stamp
            sender.read_batch(cl, now, &fetch, false, &mut arrivals);
            for &(p, done) in &arrivals {
                fast.pending_arrivals.insert(p, done);
            }
            fast.scratch_arrivals = arrivals;
            fast.metrics.prefetch_batches += 1;
        }
        fast.metrics.prefetch_issued += landed;
        fast.prefetcher.note_issued(landed);
    }
    fast.scratch_fetch = fetch;
}

/// One shard's *block* read miss path: every page of the block is
/// served in a single slow-path crossing — cached pages from the
/// mempool, in-flight pages by coalescing, remote pages through **one**
/// per-unit batched READ (one base round trip + per-page wire time,
/// the read-side mirror of the write coalescing batcher), disk pages
/// last. The fast path ([`ShardFastPath::try_read_block_local`])
/// handles the all-cached case without the lock; this function assumes
/// at least one page missed.
pub fn shard_read_block(
    sender: &mut RemoteSender,
    fast: &mut ShardFastPath,
    cl: &mut ClusterState,
    now: Ns,
    page: u64,
    npages: u64,
    route: ShardRoute,
) -> Access {
    let lat = sender.lat();
    let radix_lookup = lat.radix_lookup;
    let copy_read_page = lat.copy_read_page;
    let mrpool_get = lat.mrpool_get;
    let mut t = now + radix_lookup;
    fast.metrics.read_parts.add("radix", radix_lookup);
    flush_activity(sender, fast, cl);
    // Pass 1 (the fast-path collect): serve cached pages, gather every
    // miss of the block before crossing further. Scratch buffers are
    // reused across requests — the miss path allocates nothing in
    // steady state.
    let mut misses = std::mem::take(&mut fast.scratch_misses);
    misses.clear();
    let mut local = 0u64;
    for p in page..page + npages {
        if let Some(slot) = fast.gpt.get(p) {
            t = fast.serve_cached_page(t, p, slot);
            local += 1;
        } else {
            misses.push(p);
        }
    }
    if local > 0 {
        let copy = local * copy_read_page;
        fast.metrics.read_parts.add("copy", copy);
        t += copy;
    }
    if misses.is_empty() {
        fast.scratch_misses = misses;
        fast.metrics.read_latency.record(t - now);
        fast.metrics.batched_reads += 1;
        return Access {
            end: t,
            source: Source::LocalPool,
        };
    }
    let first_miss = misses[0];
    // Pass 2 (coalesce + batch): piggyback on in-flight fetches, batch
    // the rest per unit, disk for pages with no remote copy.
    let mut wait_until = t;
    let mut fetch = std::mem::take(&mut fast.scratch_fetch);
    fetch.clear();
    let mut disk_pages = 0u64;
    let mut source = if local > 0 {
        Source::LocalPool
    } else {
        Source::Remote
    };
    for &p in &misses {
        if let Some(done) = sender.inflight_read_done(p, t) {
            fast.metrics.coalesced_reads += 1;
            fast.metrics.remote_hits += 1;
            wait_until = wait_until.max(done);
            source = worse_source(source, Source::Remote);
            continue;
        }
        let unit = sender.units().unit_of(p);
        let remote_ok =
            fast.remote_ready.get(p) && sender.read_slot(unit).is_some();
        if remote_ok {
            fetch.push(p);
        } else {
            if sender.health_on()
                && fast.remote_ready.get(p)
                && !fast.disk_valid.get(p)
            {
                fast.metrics.lost_reads += 1;
            }
            disk_pages += 1;
        }
    }
    let fetched = fetch.len() as u64;
    if !fetch.is_empty() {
        let mut arrivals = std::mem::take(&mut fast.scratch_arrivals);
        let done = sender.read_batch(cl, t, &fetch, true, &mut arrivals);
        fast.scratch_arrivals = arrivals;
        fast.metrics.read_parts.add("mrpool", mrpool_get);
        fast.metrics.read_parts.add("rdma", done.saturating_sub(t));
        fast.metrics.remote_hits += fetched;
        if cl.pool_cfg.enabled {
            // attribute pool-tier hits: pages whose unit primary is
            // pool-resident were served by the appliance verb
            for &p in fetch.iter() {
                let unit = sender.units().unit_of(p);
                if let Some(u) = sender.units().get(unit) {
                    if let (Some(&n), Some(&b)) =
                        (u.nodes.first(), u.blocks.first())
                    {
                        if cl.block_tier(n, b)
                            == crate::mrpool::MemTier::Pool
                        {
                            fast.metrics.pool_hits += 1;
                        }
                    }
                }
            }
        }
        wait_until = wait_until.max(done);
        source = worse_source(source, Source::Remote);
    }
    // Copies of the fetched/coalesced pages happen once data arrives.
    let copied = (misses.len() as u64) - disk_pages;
    fast.scratch_fetch = fetch;
    fast.scratch_misses = misses;
    let mut end = wait_until;
    if copied > 0 {
        let copy = copied * copy_read_page;
        fast.metrics.read_parts.add("copy", copy);
        end += copy;
    }
    // Disk stragglers (Table 3 fallback), served sequentially.
    for _ in 0..disk_pages {
        let t0 = end;
        end = cl.disks[cl.sender].read(t0, PAGE_SIZE);
        fast.metrics.read_parts.add("disk", end - t0);
        fast.metrics.disk_reads += 1;
        source = worse_source(source, Source::Disk);
    }
    fast.metrics.read_latency.record(end - now);
    fast.metrics.batched_reads += 1;
    // The prefetcher sees one miss event per block (its first missing
    // page), posted after the demand batch so readahead never queues
    // ahead of demand — and any continuation a prefetch hit inside
    // this block requested is driven now, while the slow path is held.
    maybe_prefetch(sender, fast, cl, now, first_miss, route);
    drive_readahead(sender, fast, cl, now, route);
    Access { end, source }
}

/// The one routing rule: the shard owning `page` is
/// `(page / stripe) % shards`. Every router (the engine and the sharded
/// serve front-end) must call this — hand-copies would silently drift.
pub fn shard_of_page(page: u64, stripe_pages: u64, shards: usize) -> usize {
    ((page / stripe_pages.max(1)) % shards.max(1) as u64) as usize
}

/// Split a write request at stripe boundaries into contiguous pieces,
/// each of which maps to exactly one shard (also used by the sharded
/// serve front-end to fan a large write out to its workers).
pub(crate) fn split_stripes(
    page: u64,
    bytes: u64,
    stripe: u64,
) -> Vec<(u64, u64)> {
    let npages = pages_for(bytes);
    if npages == 0 {
        return vec![(page, bytes)];
    }
    let end_page = page + npages;
    let mut out = Vec::new();
    let mut p = page;
    let mut remaining = bytes;
    while p < end_page {
        let stripe_end = (p / stripe + 1) * stripe;
        let piece_pages = stripe_end.min(end_page) - p;
        let piece_bytes = remaining.min(piece_pages * PAGE_SIZE);
        out.push((p, piece_bytes));
        remaining -= piece_bytes;
        p += piece_pages;
    }
    out
}

// ---------------------------------------------------------------------
// The engine
// ---------------------------------------------------------------------

/// `S` shard fast paths behind one shared remote sender (module docs).
pub struct ShardedEngine {
    shards: Vec<ShardFastPath>,
    sender: RemoteSender,
    /// Pages per stripe (one block-I/O request).
    stripe_pages: u64,
    /// Host free pages available to the mempools (split per shard).
    host_free_pages: u64,
    /// Arbiter lease total (`u64::MAX` = unleased; split per shard).
    lease_total: u64,
    /// True when configured with no mempool (Valet-RemoteOnly ablation):
    /// writes go synchronously to remote memory.
    sync_mode: bool,
}

impl ShardedEngine {
    /// Build an engine with `shards` partitions from config. `shards = 1`
    /// reproduces the single [`crate::coordinator::Coordinator`] exactly.
    pub fn new(cfg: &Config, shards: usize) -> Self {
        let shards = shards.max(1);
        let sync_mode =
            cfg.valet.min_pool_pages == 0 && cfg.valet.max_pool_pages == 0;
        let stripe_pages = (cfg.valet.block_io_bytes / PAGE_SIZE).max(1);
        // With S > 1, clamp each shard's pool to at least one stripe:
        // a block-I/O write must always fit its shard's pool, or the
        // alloc-backpressure loop could never make progress (nothing
        // staged, nothing in flight, nothing reclaimable). Splitting
        // can push a previously-safe `max_pool_pages` under that line.
        // S = 1 is left exactly as configured (PR-1 equivalence).
        let clamp = if shards > 1 { stripe_pages } else { 1 };
        let mins = split_pages(cfg.valet.min_pool_pages, shards);
        let maxs = split_pages(cfg.valet.max_pool_pages, shards);
        let prefetch = PrefetchConfig::from_valet(&cfg.valet);
        let fasts = (0..shards)
            .map(|i| {
                ShardFastPath::new(
                    mins[i].max(clamp),
                    maxs[i].max(clamp),
                    cfg.valet.grow_threshold,
                    cfg.valet.host_free_fraction,
                    cfg.valet.replacement,
                    prefetch.clone(),
                )
            })
            .collect();
        ShardedEngine {
            shards: fasts,
            sender: RemoteSender::new(cfg, shards),
            stripe_pages,
            host_free_pages: (cfg.cluster.node_mem_bytes / PAGE_SIZE) / 2,
            lease_total: u64::MAX,
            sync_mode,
        }
    }

    // -- partitioning -------------------------------------------------

    /// Number of shards.
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Pages per stripe (the interleave granularity).
    pub fn stripe_pages(&self) -> u64 {
        self.stripe_pages
    }

    /// The shard owning `page`: see [`shard_of_page`].
    pub fn shard_of(&self, page: u64) -> usize {
        shard_of_page(page, self.stripe_pages, self.shards.len())
    }

    /// True when configured with no mempool (Valet-RemoteOnly ablation,
    /// `min_pool_pages == max_pool_pages == 0`): writes go synchronously
    /// to remote memory. The serve front-ends must honor this too.
    pub fn is_sync_mode(&self) -> bool {
        self.sync_mode
    }

    // -- configuration hooks (mirror the Coordinator builders) --------

    /// Tag MR registrations with a distinct owner id (multi-tenant).
    pub fn set_owner_tag(&mut self, owner: NodeId) {
        self.sender.set_owner_tag(owner);
    }

    /// Swap in a different eviction policy (§3.5 hook).
    pub fn set_victim_policy(
        &mut self,
        policy: Box<dyn crate::eviction::VictimPolicy + Send>,
    ) {
        self.sender.set_victim_policy(policy);
    }

    /// Swap in a different placement policy (§4.3 hook).
    pub fn set_placement(
        &mut self,
        placement: Box<dyn crate::placement::Placement + Send>,
    ) {
        self.sender.set_placement(placement);
    }

    /// Swap in a different migration-destination policy (§3.5 hook;
    /// [`crate::placement::LeastPressured`] by default).
    pub fn set_reclaim_placement(
        &mut self,
        placement: Box<dyn crate::placement::Placement + Send>,
    ) {
        self.sender.set_reclaim_placement(placement);
    }

    // -- diagnostics --------------------------------------------------

    /// Shard fast paths, index order.
    pub fn shards(&self) -> &[ShardFastPath] {
        &self.shards
    }

    /// One shard's fast path.
    pub fn shard(&self, i: usize) -> &ShardFastPath {
        &self.shards[i]
    }

    /// Mutable access to one shard's fast path.
    pub fn shard_mut(&mut self, i: usize) -> &mut ShardFastPath {
        &mut self.shards[i]
    }

    /// The shared slow path.
    pub fn sender(&self) -> &RemoteSender {
        &self.sender
    }

    /// Mutable access to the shared slow path.
    pub fn sender_mut(&mut self) -> &mut RemoteSender {
        &mut self.sender
    }

    /// Take the engine apart into its layers (the sharded serve mode
    /// hands each fast path to its worker thread and puts the sender
    /// behind the shared lock).
    pub fn into_parts(self) -> (Vec<ShardFastPath>, RemoteSender) {
        (self.shards, self.sender)
    }

    /// Reassemble an engine from parts (serve shutdown), preserving the
    /// host-free level the session actually ran with. The lease resets
    /// to unleased — the sharded serve mode has no arbiter lease path.
    pub fn from_parts(
        cfg: &Config,
        shards: Vec<ShardFastPath>,
        sender: RemoteSender,
        host_free_pages: u64,
    ) -> Self {
        let sync_mode =
            cfg.valet.min_pool_pages == 0 && cfg.valet.max_pool_pages == 0;
        ShardedEngine {
            shards,
            sender,
            stripe_pages: (cfg.valet.block_io_bytes / PAGE_SIZE).max(1),
            host_free_pages,
            lease_total: u64::MAX,
            sync_mode,
        }
    }

    /// Staged (not yet remotely durable) bytes across all shards.
    pub fn staged_bytes(&self) -> u64 {
        self.shards.iter().map(|s| s.staging.bytes()).sum()
    }

    /// Number of mapped address-space units.
    pub fn mapped_units(&self) -> usize {
        self.sender.units().len()
    }

    /// Mempool slot currently holding `page`, if it is locally cached
    /// (GPT lookup without charging latency — diagnostics only).
    pub fn slot_of(&self, page: u64) -> Option<u32> {
        self.shards[self.shard_of(page)].gpt.get(page)
    }

    /// Write sets not yet durable: staged + carried by in-flight RDMA.
    pub fn pending_write_sets(&self) -> usize {
        self.shards.iter().map(|s| s.staging.len()).sum::<usize>()
            + self.sender.inflight_write_sets()
    }

    /// Run metrics merged across all shards. Prefetch waste the
    /// mempools observed but the per-shard metrics have not folded in
    /// yet (waste syncs lazily, on the next miss) is added here, so the
    /// aggregate `prefetch_wasted` / accuracy are exact at any point.
    pub fn combined_metrics(&self) -> RunMetrics {
        let mut m = RunMetrics::default();
        for s in &self.shards {
            m.merge(&s.metrics);
            m.prefetch_wasted += s.unsynced_prefetch_waste();
        }
        m
    }

    // -- host/lease accounting ----------------------------------------

    /// Host free pages currently granted to the mempools.
    pub fn host_free_pages(&self) -> u64 {
        self.host_free_pages
    }

    /// Update host free memory (container churn on the sender node); the
    /// next pump's grow/shrink check runs against each shard's split.
    pub fn set_host_free_pages(&mut self, pages: u64) {
        self.host_free_pages = pages;
    }

    /// This shard's split of the current host free pages (allocation-
    /// free — computed per request on the write path).
    pub fn host_share(&self, shard: usize) -> u64 {
        share_of(self.host_free_pages, self.shards.len(), shard)
    }

    /// Pages the host arbiter currently leases to this engine
    /// (`u64::MAX` when unleased — single-tenant operation).
    pub fn lease_pages(&self) -> u64 {
        self.lease_total
    }

    /// Update the arbiter lease, splitting it across the shard mempools
    /// ([`split_pages`]); each shard enforces its slice on the next pump.
    pub fn set_lease_pages(&mut self, pages: u64) {
        self.lease_total = pages;
        let leases = split_pages(pages, self.shards.len());
        for (fast, &l) in self.shards.iter_mut().zip(leases.iter()) {
            fast.mempool.set_lease(l);
        }
    }

    /// Give back up to `want` idle pages to the host pool, draining
    /// shards in index order. Returns pages donated.
    pub fn donate_idle_pages(&mut self, want: u64) -> u64 {
        let mut donated = 0;
        for fast in &mut self.shards {
            if donated >= want {
                break;
            }
            donated += fast.donate_idle_pages(want - donated);
        }
        donated
    }

    // -- the request path ---------------------------------------------

    /// Front-end write (swap-out). A request larger than one stripe is
    /// split at stripe boundaries; in virtual time the pieces start
    /// concurrently on their shards (write *ordering* is a per-shard
    /// property) and the request completes when the slowest piece does.
    /// In the live serve mode the pieces' workers still serialize on
    /// the shared slow-path lock — see [`crate::serve`]. With `S = 1`
    /// there is no split and this is exactly the single-coordinator
    /// write.
    pub fn write(
        &mut self,
        cl: &mut ClusterState,
        now: Ns,
        page: u64,
        bytes: u64,
    ) -> Access {
        if self.shards.len() == 1 {
            return self.write_piece(cl, now, 0, page, bytes);
        }
        let mut end = now;
        let mut source = Source::LocalPool;
        for (p0, b) in split_stripes(page, bytes, self.stripe_pages) {
            let s = self.shard_of(p0);
            let a = self.write_piece(cl, now, s, p0, b);
            end = end.max(a.end);
            source = a.source;
        }
        Access { end, source }
    }

    fn write_piece(
        &mut self,
        cl: &mut ClusterState,
        now: Ns,
        shard: usize,
        page: u64,
        bytes: u64,
    ) -> Access {
        let host = self.host_share(shard);
        let sync = self.sync_mode;
        let ShardedEngine { shards, sender, .. } = self;
        let fast = &mut shards[shard];
        if sync {
            return sender.write_sync(cl, now, page, bytes, fast);
        }
        shard_write(sender, fast, cl, shard, now, page, bytes, host)
    }

    /// This engine's routing view for `shard` (the read pipeline needs
    /// it to keep readahead shard-local).
    fn route(&self, shard: usize) -> ShardRoute {
        ShardRoute {
            shard,
            shards: self.shards.len(),
            stripe_pages: self.stripe_pages,
        }
    }

    /// Front-end read (swap-in): route to the owning shard; GPT hit →
    /// mempool (the lock-free fast path in serve mode), else the shared
    /// slow path (coalesce with an in-flight fetch / remote RDMA READ /
    /// disk, plus the stride prefetcher's readahead).
    pub fn read(
        &mut self,
        cl: &mut ClusterState,
        now: Ns,
        page: u64,
    ) -> Access {
        let shard = self.shard_of(page);
        let route = self.route(shard);
        let ShardedEngine { shards, sender, .. } = self;
        let fast = &mut shards[shard];
        if let Some(a) = fast.try_read_local(sender.lat(), now, page) {
            // a prefetch hit may have asked to extend the window
            drive_readahead(sender, fast, cl, now, route);
            return a;
        }
        shard_read_miss(sender, fast, cl, now, page, route)
    }

    /// Front-end **block** read: all `pages_for(bytes)` pages as one
    /// request. Pieces split at stripe boundaries like [`Self::write`];
    /// per piece, the all-cached fast path
    /// ([`ShardFastPath::try_read_block_local`]) is tried first, then
    /// the whole piece crosses into the slow path **once** — cached
    /// pages served, in-flight pages coalesced, the rest fetched with
    /// one per-unit batched READ (one base round trip instead of one
    /// per page). The single-page [`Self::read`] is unchanged; this is
    /// the API block-I/O callers use to stop paying 16 serialized round
    /// trips per block miss.
    pub fn read_block(
        &mut self,
        cl: &mut ClusterState,
        now: Ns,
        page: u64,
        bytes: u64,
    ) -> Access {
        let npages = pages_for(bytes).max(1);
        if self.shards.len() == 1 {
            return self.read_block_piece(cl, now, 0, page, npages);
        }
        let mut end = now;
        let mut source = Source::LocalPool;
        for (p0, b) in
            split_stripes(page, bytes.max(1), self.stripe_pages)
        {
            let s = self.shard_of(p0);
            let a =
                self.read_block_piece(cl, now, s, p0, pages_for(b).max(1));
            end = end.max(a.end);
            source = worse_source(source, a.source);
        }
        Access { end, source }
    }

    fn read_block_piece(
        &mut self,
        cl: &mut ClusterState,
        now: Ns,
        shard: usize,
        page: u64,
        npages: u64,
    ) -> Access {
        let route = self.route(shard);
        let ShardedEngine { shards, sender, .. } = self;
        let fast = &mut shards[shard];
        if let Some(a) =
            fast.try_read_block_local(sender.lat(), now, page, npages)
        {
            drive_readahead(sender, fast, cl, now, route);
            return a;
        }
        shard_read_block(sender, fast, cl, now, page, npages, route)
    }

    /// Drive background machinery up to `now`: drain every shard's
    /// staging queue through the shared sender (globally oldest-first,
    /// deterministic) plus each shard's mempool shrink check against its
    /// host-free split (§3.4).
    pub fn pump(&mut self, cl: &mut ClusterState, now: Ns) {
        self.drive_all(cl, now);
        let (hf, n) = (self.host_free_pages, self.shards.len());
        for (i, fast) in self.shards.iter_mut().enumerate() {
            fast.resize_for_host(share_of(hf, n, i));
        }
    }

    /// The single pump/sender driver: apply completions, advance the
    /// migration tables, then repeatedly pick — across every shard —
    /// the earliest-enqueued staged set whose target lane is idle and
    /// send one coalesced batch from it (ties break to the lowest shard
    /// index, so the drain order is deterministic), re-advancing
    /// migrations between batches so the reclaim pipeline and the write
    /// pipeline interleave on one timeline. With one lane this is the
    /// pre-split globally-oldest-first funnel exactly; with more, a
    /// shard blocked on one peer no longer holds up batches bound for
    /// the others.
    fn drive_all(&mut self, cl: &mut ClusterState, now: Ns) {
        let ShardedEngine { shards, sender, .. } = self;
        sender.complete_inflight(cl, now);
        sender.advance_migrations(cl, now);
        for (i, fast) in shards.iter_mut().enumerate() {
            flush_activity(sender, fast, cl);
            apply_mailbox(sender, fast, i);
        }
        loop {
            // (enqueued_at, shard, staging idx, service start)
            let mut best: Option<(Ns, usize, usize, Ns)> = None;
            for (s, fast) in shards.iter().enumerate() {
                if let Some((idx, start, enq)) =
                    next_sendable(sender, fast, cl, now)
                {
                    let better = match best {
                        Some((be, bs, _, _)) => (enq, s) < (be, bs),
                        None => true,
                    };
                    if better {
                        best = Some((enq, s, idx, start));
                    }
                }
            }
            let Some((_, s, idx, start)) = best else {
                break;
            };
            sender.send_batch_at(cl, start, s, &mut shards[s], idx);
            sender.advance_migrations(cl, now);
        }
    }

    /// A peer needs `bytes` of its donated memory back (§3.5): victims
    /// are selected and enqueued into the sender's migration table
    /// immediately; the live protocol machines then advance only on
    /// pump ticks ([`Self::pump`] / the serve drivers), overlapping
    /// demand traffic instead of blocking this call.
    pub fn remote_pressure(
        &mut self,
        cl: &mut ClusterState,
        now: Ns,
        node: NodeId,
        bytes: u64,
    ) -> PressureOutcome {
        self.sender.remote_pressure(cl, now, node, bytes)
    }

    /// Migrations currently in the sender's table (queued + in flight).
    pub fn migrations_inflight(&self) -> usize {
        self.sender.migrations_inflight()
    }

    /// Aggregate reclaim-pipeline counters.
    pub fn migration_stats(&self) -> crate::coordinator::sender::MigStats {
        self.sender.migration_stats()
    }

    /// Milestones of completed migrations, in completion order.
    pub fn migration_records(
        &self,
    ) -> &[crate::coordinator::sender::MigrationRecord] {
        self.sender.migration_records()
    }

    // -- the invariant auditor ----------------------------------------

    /// Whole-engine audit sweep: every shard's fast-path laws, the
    /// shared sender's migration/replica laws (thorough mode), clock
    /// monotonicity against `now`, and the engine-level
    /// [`Law::LeaseSplit`] — with a finite arbiter lease, the per-shard
    /// mempool leases must sum exactly to the engine's lease total
    /// ([`split_pages`] conservation). The `u64::MAX` sentinel
    /// (unleased) is unconstrained: [`Self::from_parts`] legitimately
    /// resets the total while shards keep their last split.
    pub fn audit_check(
        &self,
        cl: &ClusterState,
        now: Ns,
    ) -> Vec<Violation> {
        let mut out = Vec::new();
        for (i, fast) in self.shards.iter().enumerate() {
            out.extend(fast.audit_check(Some(i)));
            let watermark = fast.audit_last_now;
            audit::check(
                &mut out,
                now >= watermark,
                Law::TimeMonotonic,
                Some(i),
                || format!("sweep at t={now} behind watermark {watermark}"),
                || format!("now={now} watermark={watermark}"),
            );
        }
        out.extend(self.sender.audit_check(cl, true));
        if self.lease_total != u64::MAX {
            let sum = self
                .shards
                .iter()
                .map(|s| s.mempool.lease())
                .try_fold(0u64, u64::checked_add);
            audit::check(
                &mut out,
                sum == Some(self.lease_total),
                Law::LeaseSplit,
                None,
                || {
                    format!(
                        "shard leases sum to {sum:?}, engine lease total \
                         is {}",
                        self.lease_total
                    )
                },
                || {
                    format!(
                        "per-shard leases: {:?}",
                        self.shards
                            .iter()
                            .map(|s| s.mempool.lease())
                            .collect::<Vec<_>>()
                    )
                },
            );
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::secs;

    fn cfg(shards_pool: u64) -> Config {
        let mut cfg = Config::default();
        cfg.cluster.nodes = 4;
        cfg.valet.mr_block_bytes = 1 << 20;
        cfg.valet.min_pool_pages = shards_pool;
        cfg.valet.max_pool_pages = shards_pool;
        cfg
    }

    #[test]
    fn stripe_routing_keeps_a_block_in_one_shard() {
        let e = ShardedEngine::new(&cfg(256), 4);
        assert_eq!(e.stripe_pages(), 16);
        // all 16 pages of one 64 KB block route to the same shard
        for blk in 0..8u64 {
            let s0 = e.shard_of(blk * 16);
            for p in blk * 16..blk * 16 + 16 {
                assert_eq!(e.shard_of(p), s0, "page {p}");
            }
        }
        // consecutive blocks land on consecutive shards
        assert_ne!(e.shard_of(0), e.shard_of(16));
    }

    #[test]
    fn split_stripes_covers_exactly_the_request() {
        let pieces = split_stripes(0, 64 * 4096, 16);
        assert_eq!(pieces, vec![
            (0, 16 * 4096),
            (16, 16 * 4096),
            (32, 16 * 4096),
            (48, 16 * 4096)
        ]);
        // unaligned start + partial tail page
        let pieces = split_stripes(10, 10 * 4096 + 100, 16);
        assert_eq!(pieces[0], (10, 6 * 4096));
        assert_eq!(pieces[1], (16, 4 * 4096 + 100));
        let total: u64 = pieces.iter().map(|p| p.1).sum();
        assert_eq!(total, 10 * 4096 + 100);
        // zero-byte request still routes somewhere
        assert_eq!(split_stripes(5, 0, 16), vec![(5, 0)]);
    }

    #[test]
    fn multi_shard_writes_spread_and_read_back_locally() {
        let cfg = cfg(1024);
        let mut cl = ClusterState::new(&cfg);
        let mut e = ShardedEngine::new(&cfg, 4);
        let mut t = 0;
        for blk in 0..16u64 {
            let a = e.write(&mut cl, t, blk * 16, 16 * PAGE_SIZE);
            assert_eq!(a.source, Source::LocalPool);
            t = a.end;
        }
        // every shard holds some pages
        for (i, s) in e.shards().iter().enumerate() {
            assert!(!s.gpt.is_empty(), "shard {i} empty");
        }
        // reads route to the owning shard and hit locally
        for blk in 0..16u64 {
            let r = e.read(&mut cl, t, blk * 16 + 3);
            assert_eq!(r.source, Source::LocalPool, "block {blk}");
            t = r.end;
        }
        assert_eq!(e.combined_metrics().local_hits, 16);
    }

    #[test]
    fn one_big_write_lands_on_every_shard_and_drains() {
        let cfg = cfg(1024);
        let mut cl = ClusterState::new(&cfg);
        let mut e = ShardedEngine::new(&cfg, 4);
        // 4 stripes in one request → one piece per shard
        let a = e.write(&mut cl, 0, 0, 4 * 16 * PAGE_SIZE);
        assert_eq!(a.source, Source::LocalPool);
        assert_eq!(e.pending_write_sets(), 4);
        e.pump(&mut cl, secs(2));
        assert_eq!(e.pending_write_sets(), 0);
        assert_eq!(e.staged_bytes(), 0);
        for s in e.shards() {
            assert_eq!(s.reclaim_q.completed, 1);
        }
    }

    #[test]
    fn tiny_split_pools_clamp_to_one_stripe() {
        // max_pool_pages = 64 is fine unsharded but splits to 8 pages
        // at S=8 — under one 16-page stripe. The clamp keeps every
        // shard able to hold a full block-I/O write (no livelock).
        let cfg = cfg(64);
        let mut cl = ClusterState::new(&cfg);
        let mut e = ShardedEngine::new(&cfg, 8);
        for s in e.shards() {
            assert!(s.mempool.capacity() >= e.stripe_pages());
        }
        let a = e.write(&mut cl, 0, 0, 16 * PAGE_SIZE);
        assert_eq!(a.source, Source::LocalPool);
    }

    #[test]
    fn lease_split_sums_to_total() {
        let mut e = ShardedEngine::new(&cfg(256), 4);
        assert_eq!(e.lease_pages(), u64::MAX);
        e.set_lease_pages(103);
        assert_eq!(e.lease_pages(), 103);
        let sum: u64 =
            e.shards().iter().map(|s| s.mempool.lease()).sum();
        assert_eq!(sum, 103);
    }

    #[test]
    fn sync_mode_split_still_goes_remote() {
        let mut cfg = cfg(0);
        cfg.valet.min_pool_pages = 0;
        cfg.valet.max_pool_pages = 0;
        let mut cl = ClusterState::new(&cfg);
        let mut e = ShardedEngine::new(&cfg, 2);
        let a = e.write(&mut cl, 0, 0, 32 * PAGE_SIZE);
        assert_eq!(a.source, Source::Remote);
    }
}
