//! `valet` — the leader CLI.
//!
//! ```text
//! valet run   [--backend valet|infiniswap|nbdx|linux] [--app redis]
//!             [--mix sys] [--fit 0.25] [--records N] [--ops N]
//!             [--config file.toml] [--set section.key=value ...]
//! valet ml    [--kind logreg|kmeans|textrank|gboost|rf] [--fit 0.5]
//!             [--steps N] [--artifacts DIR]
//! valet serve [--backend valet] [--shards N] [--writes N] [--reads N]
//! valet info  — print config defaults, artifact status, cluster shape
//! ```

use std::process::ExitCode;

use valet::bench::experiments;
use valet::cluster::Cluster;
use valet::config::{BackendKind, Config, Value};
use valet::runtime::Runtime;
use valet::sim::ms;
use valet::util::fmt;
use valet::workloads::{
    run_kv, run_ml, App, KvRunConfig, Mix, MlKind, MlRunConfig, StoreModel,
};

struct Args {
    positional: Vec<String>,
    flags: std::collections::HashMap<String, String>,
    sets: Vec<(String, String, String)>,
}

fn parse_args(argv: &[String]) -> Result<Args, String> {
    let mut a = Args {
        positional: Vec::new(),
        flags: std::collections::HashMap::new(),
        sets: Vec::new(),
    };
    let mut i = 0;
    while i < argv.len() {
        let arg = &argv[i];
        if let Some(name) = arg.strip_prefix("--") {
            let value = if i + 1 < argv.len() && !argv[i + 1].starts_with("--")
            {
                i += 1;
                argv[i].clone()
            } else {
                "true".to_string()
            };
            if name == "set" {
                let (path, v) = value
                    .split_once('=')
                    .ok_or_else(|| format!("--set wants k=v, got {value}"))?;
                let (sec, key) = path
                    .split_once('.')
                    .ok_or_else(|| format!("--set wants section.key, got {path}"))?;
                a.sets.push((sec.into(), key.into(), v.into()));
            } else {
                a.flags.insert(name.to_string(), value);
            }
        } else {
            a.positional.push(arg.clone());
        }
        i += 1;
    }
    Ok(a)
}

fn build_config(a: &Args) -> Result<Config, String> {
    let mut cfg = match a.flags.get("config") {
        Some(path) => Config::from_file(path)?,
        None => Config::default(),
    };
    for (sec, key, v) in &a.sets {
        cfg.set(sec, key, &Value::parse(v)?)?;
    }
    Ok(cfg)
}

fn cmd_run(a: &Args) -> Result<(), String> {
    let cfg = build_config(a)?;
    let kind = a
        .flags
        .get("backend")
        .map(|s| BackendKind::parse(s).ok_or(format!("bad backend {s}")))
        .transpose()?
        .unwrap_or(BackendKind::Valet);
    let app = a
        .flags
        .get("app")
        .map(|s| App::parse(s).ok_or(format!("bad app {s}")))
        .transpose()?
        .unwrap_or(App::Redis);
    let mix = a
        .flags
        .get("mix")
        .map(|s| Mix::parse(s).ok_or(format!("bad mix {s}")))
        .transpose()?
        .unwrap_or(Mix::Sys);
    let fit: f64 = a
        .flags
        .get("fit")
        .map(|s| s.parse().map_err(|_| format!("bad fit {s}")))
        .transpose()?
        .unwrap_or(0.5);
    let records: u64 = a
        .flags
        .get("records")
        .map(|s| s.parse().map_err(|_| format!("bad records {s}")))
        .transpose()?
        .unwrap_or(60_000);
    let ops: u64 = a
        .flags
        .get("ops")
        .map(|s| s.parse().map_err(|_| format!("bad ops {s}")))
        .transpose()?
        .unwrap_or(30_000);

    let store = StoreModel::new(app, 1024);
    let rc = KvRunConfig {
        concurrency: 8,
        seed: cfg.cluster.seed,
        ..KvRunConfig::new(store, mix, records, ops)
    }
    .with_fit(fit);
    eprintln!(
        "running {} {} fit={fit} records={records} ops={ops} on {}",
        app.name(),
        mix.name(),
        kind.name()
    );
    let mut cluster = Cluster::new(&cfg, kind);
    let r = run_kv(&mut cluster, &rc);
    let m = &r.metrics;
    println!("backend           : {}", kind.name());
    println!("completion        : {}", fmt::ns(r.completion));
    println!("throughput        : {:.0} ops/s", m.throughput());
    println!(
        "op latency        : mean {} p50 {} p99 {}",
        fmt::ns(m.op_latency.mean() as u64),
        fmt::ns(m.op_latency.p50()),
        fmt::ns(m.op_latency.p99())
    );
    println!(
        "reads             : local {} remote {} disk {} (hit {:.1}%)",
        m.local_hits,
        m.remote_hits,
        m.disk_reads,
        m.local_hit_ratio() * 100.0
    );
    println!("page faults       : {}", r.faults);
    Ok(())
}

fn cmd_ml(a: &Args) -> Result<(), String> {
    let cfg = build_config(a)?;
    let kind = a
        .flags
        .get("backend")
        .map(|s| BackendKind::parse(s).ok_or(format!("bad backend {s}")))
        .transpose()?
        .unwrap_or(BackendKind::Valet);
    let ml_kind = match a.flags.get("kind").map(String::as_str) {
        None | Some("logreg") => MlKind::LogReg,
        Some("kmeans") => MlKind::KMeans,
        Some("textrank") => MlKind::TextRank,
        Some("gboost") => MlKind::GBoost,
        Some("rf") => MlKind::RandomForest,
        Some(other) => return Err(format!("bad ml kind {other}")),
    };
    let fit: f64 = a
        .flags
        .get("fit")
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.5);
    let steps: u64 = a
        .flags
        .get("steps")
        .and_then(|s| s.parse().ok())
        .unwrap_or(50);
    let dir = a
        .flags
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(Runtime::default_dir);
    // measure the real per-step compute from the AOT artifact
    let rt = Runtime::load(&dir).map_err(|e| e.to_string())?;
    let step_ns = match rt.get(ml_kind.artifact()) {
        Ok(exe) => {
            let inputs = valet::runtime::random_inputs(exe.spec)
                .map_err(|e| e.to_string())?;
            let t0 = std::time::Instant::now();
            exe.run(&inputs).map_err(|e| e.to_string())?;
            t0.elapsed().as_nanos() as u64
        }
        Err(e) => {
            eprintln!("warning: {e}; using 25 ms per step");
            ms(25)
        }
    };
    eprintln!(
        "{} on {}: measured step compute {}",
        ml_kind.name(),
        kind.name(),
        fmt::ns(step_ns)
    );
    let mut cluster = Cluster::new(&cfg, kind);
    let rc = MlRunConfig::new(ml_kind, 192 << 20, steps, fit);
    let r = run_ml(&mut cluster, &rc, |_| step_ns);
    println!("workload          : {}", ml_kind.name());
    println!("completion        : {}", fmt::ns(r.completion));
    println!("compute           : {}", fmt::ns(r.compute));
    println!(
        "paging            : {}",
        fmt::ns(r.completion.saturating_sub(r.compute))
    );
    println!(
        "reads             : local {} remote {} disk {}",
        r.metrics.local_hits, r.metrics.remote_hits, r.metrics.disk_reads
    );
    Ok(())
}

fn cmd_serve(a: &Args) -> Result<(), String> {
    use valet::serve::{spawn, spawn_sharded, Reply, Request};

    // Drive the demo load through any front-end: `writes` sequential
    // 64 KB blocks, then `reads` over the written range. Returns
    // accumulated (wall, virtual) nanoseconds.
    fn drive_demo(
        call: &mut dyn FnMut(Request) -> Option<Reply>,
        writes: u64,
        reads: u64,
    ) -> Result<(u64, u64), String> {
        let mut wall = 0u64;
        let mut virt = 0u64;
        for i in 0..writes {
            let r = call(Request::Write { page: i * 16, bytes: 65536 })
                .ok_or("serve channel closed")?;
            wall += r.wall_ns;
            virt += r.virtual_ns;
        }
        let span = (writes * 16).max(1); // avoid % 0 when --writes 0
        for i in 0..reads {
            let r = call(Request::Read { page: (i * 37) % span })
                .ok_or("serve channel closed")?;
            wall += r.wall_ns;
            virt += r.virtual_ns;
        }
        Ok((wall, virt))
    }

    let cfg = build_config(a)?;
    let kind = a
        .flags
        .get("backend")
        .map(|s| BackendKind::parse(s).ok_or(format!("bad backend {s}")))
        .transpose()?
        .unwrap_or(BackendKind::Valet);
    let writes: u64 = a
        .flags
        .get("writes")
        .and_then(|s| s.parse().ok())
        .unwrap_or(1000);
    let reads: u64 = a
        .flags
        .get("reads")
        .and_then(|s| s.parse().ok())
        .unwrap_or(1000);
    let shards: usize = a
        .flags
        .get("shards")
        .and_then(|s| s.parse().ok())
        .unwrap_or(1);
    if shards > 1 {
        if kind != BackendKind::Valet {
            return Err("--shards requires the valet backend".into());
        }
        eprintln!(
            "serving Valet across {shards} shard workers \
             (demo load: {writes} writes, {reads} reads)"
        );
        let h = spawn_sharded(&cfg, shards);
        let (wall, virt) = drive_demo(&mut |req| h.call(req), writes, reads)?;
        let n = writes + reads;
        println!("requests          : {n} (page-striped over {shards} shards)");
        println!("mean wall service : {}", fmt::ns(wall / n.max(1)));
        println!("mean virtual lat  : {}", fmt::ns(virt / n.max(1)));
        let out = h.shutdown().ok_or("join failed")?;
        let m = out.engine.combined_metrics();
        println!(
            "reads             : local {} remote {} disk {}",
            m.local_hits, m.remote_hits, m.disk_reads
        );
        for (i, s) in out.engine.shards().iter().enumerate() {
            println!(
                "shard {i}           : {} local hits, {} write sets",
                s.metrics.local_hits,
                s.metrics.write_latency.count()
            );
        }
        return Ok(());
    }
    eprintln!("serving {} (demo load: {writes} writes, {reads} reads)", kind.name());
    let h = spawn(&cfg, kind);
    let (wall, virt) = drive_demo(&mut |req| h.call(req), writes, reads)?;
    let n = writes + reads;
    println!("requests          : {n}");
    println!("mean wall service : {}", fmt::ns(wall / n.max(1)));
    println!("mean virtual lat  : {}", fmt::ns(virt / n.max(1)));
    let cluster = h.shutdown().ok_or("join failed")?;
    let m = cluster.backend.metrics();
    println!(
        "reads             : local {} remote {} disk {}",
        m.local_hits, m.remote_hits, m.disk_reads
    );
    Ok(())
}

fn cmd_info(a: &Args) -> Result<(), String> {
    let cfg = build_config(a)?;
    println!("valet-rs — Valet (MemSys '20) reproduction");
    println!(
        "cluster           : {} nodes × {} RAM",
        cfg.cluster.nodes,
        fmt::bytes(cfg.cluster.node_mem_bytes)
    );
    println!(
        "valet             : block_io {} rdma_msg {} mr_block {} replicas {}",
        fmt::bytes(cfg.valet.block_io_bytes),
        fmt::bytes(cfg.valet.rdma_msg_bytes),
        fmt::bytes(cfg.valet.mr_block_bytes),
        cfg.valet.replicas
    );
    println!(
        "latency (µs)      : radix_ins 23.9 rdma_wr {} rdma_rd {} connect {} map {}",
        cfg.latency.rdma_write(cfg.valet.rdma_msg_bytes) / 1000,
        cfg.latency.rdma_read(4096) / 1000,
        cfg.latency.connect / 1000,
        cfg.latency.map_mr / 1000
    );
    let dir = Runtime::default_dir();
    match Runtime::load(&dir) {
        Ok(rt) => println!("artifacts         : {:?} in {}", rt.loaded(), dir.display()),
        Err(e) => println!("artifacts         : unavailable ({e})"),
    }
    println!("experiments       : {}", experiments::all_ids().join(" "));
    Ok(())
}

fn main() -> ExitCode {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        eprintln!("usage: valet <run|ml|serve|info> [flags]  (see --help in README)");
        return ExitCode::from(2);
    }
    let a = match parse_args(&argv[1..]) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            return ExitCode::from(2);
        }
    };
    let r = match argv[0].as_str() {
        "run" => cmd_run(&a),
        "ml" => cmd_ml(&a),
        "serve" => cmd_serve(&a),
        "info" => cmd_info(&a),
        other => Err(format!("unknown command {other}")),
    };
    match r {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}
