//! Experiment harness: one function per paper table/figure, shared by the
//! `valet-bench` binary and the `cargo bench` targets. Each experiment
//! builds scaled-down but shape-preserving versions of the paper's §6
//! runs (records/ops scaled; latency model identical) and returns a
//! printable report plus machine-readable rows.

pub mod experiments;
pub mod timing;

/// A regenerated table/figure.
#[derive(Clone, Debug)]
pub struct Report {
    /// Experiment id ("table1", "fig21", ...).
    pub id: &'static str,
    /// Human title (matches the paper artifact).
    pub title: &'static str,
    /// Column header.
    pub header: Vec<&'static str>,
    /// Data rows.
    pub rows: Vec<Vec<String>>,
    /// Free-form notes (observations the paper calls out).
    pub notes: Vec<String>,
}

impl Report {
    /// Render as an ASCII table with title + notes.
    pub fn render(&self) -> String {
        let mut s = format!("== {} — {} ==\n", self.id, self.title);
        s.push_str(&crate::util::fmt::table(&self.header, &self.rows));
        for n in &self.notes {
            s.push_str(&format!("note: {n}\n"));
        }
        s
    }

    /// Render as CSV (for plotting).
    pub fn to_csv(&self) -> String {
        let mut s = self.header.join(",");
        s.push('\n');
        for r in &self.rows {
            s.push_str(&r.join(","));
            s.push('\n');
        }
        s
    }
}
