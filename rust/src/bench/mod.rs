//! Experiment harness: one function per paper table/figure, shared by the
//! `valet-bench` binary and the `cargo bench` targets. Each experiment
//! builds scaled-down but shape-preserving versions of the paper's §6
//! runs (records/ops scaled; latency model identical) and returns a
//! printable report plus machine-readable rows.

pub mod experiments;
pub mod timing;

/// A regenerated table/figure.
#[derive(Clone, Debug, Default)]
pub struct Report {
    /// Experiment id ("table1", "fig21", ...).
    pub id: &'static str,
    /// Human title (matches the paper artifact).
    pub title: &'static str,
    /// Column header.
    pub header: Vec<&'static str>,
    /// Data rows.
    pub rows: Vec<Vec<String>>,
    /// Free-form notes (observations the paper calls out).
    pub notes: Vec<String>,
    /// Machine-readable headline metrics `(metric, value)` — dumped as
    /// `{id, metric, value}` records by `valet-bench --json` so the perf
    /// trajectory can be tracked per PR.
    pub kv: Vec<(String, f64)>,
}

impl Report {
    /// Record one machine-readable headline metric.
    pub fn push_kv(&mut self, metric: impl Into<String>, value: f64) {
        self.kv.push((metric.into(), value));
    }

    /// Render this report's headline metrics as JSON records
    /// `[{"id":…,"metric":…,"value":…}, …]` (one line per record, no
    /// enclosing brackets — callers concatenate reports).
    pub fn json_records(&self) -> Vec<String> {
        self.kv
            .iter()
            .map(|(metric, value)| {
                format!(
                    "{{\"id\":\"{}\",\"metric\":\"{}\",\"value\":{}}}",
                    self.id,
                    metric.replace('"', "'"),
                    if value.is_finite() {
                        format!("{value}")
                    } else {
                        "null".to_string()
                    }
                )
            })
            .collect()
    }
    /// Render as an ASCII table with title + notes.
    pub fn render(&self) -> String {
        let mut s = format!("== {} — {} ==\n", self.id, self.title);
        s.push_str(&crate::util::fmt::table(&self.header, &self.rows));
        for n in &self.notes {
            s.push_str(&format!("note: {n}\n"));
        }
        s
    }

    /// Render as CSV (for plotting).
    pub fn to_csv(&self) -> String {
        let mut s = self.header.join(",");
        s.push('\n');
        for r in &self.rows {
            s.push_str(&r.join(","));
            s.push('\n');
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_records_render_id_metric_value() {
        let mut r = Report {
            id: "x",
            ..Default::default()
        };
        r.push_kv("tp", 1.5);
        r.push_kv("bad", f64::NAN);
        let recs = r.json_records();
        assert_eq!(
            recs[0],
            "{\"id\":\"x\",\"metric\":\"tp\",\"value\":1.5}"
        );
        assert!(recs[1].ends_with("\"value\":null}"), "{}", recs[1]);
        assert!(Report::default().json_records().is_empty());
    }
}
