//! One function per paper table/figure (ARCHITECTURE.md §10 experiment index).
//!
//! Scaling: the paper runs 10 M records / 10 M ops on 32 real machines;
//! we run the identical pipeline with records/ops scaled by `Scale` so
//! every experiment finishes in seconds of wall time. Latency constants
//! are NOT scaled, so latency-composition results (Tables 1/7, Figures
//! 9/10) are directly comparable and throughput/completion *ratios*
//! (who wins, by how much) preserve the paper's shape.

use super::Report;
use crate::cluster::{Cluster, ClusterEvent};
use crate::config::{BackendKind, Config};
use crate::sim::{ms, secs, Ns};
use crate::workloads::{
    run_fio, run_kv, run_ml, App, FioJob, KvRunConfig, Mix, MlKind,
    MlRunConfig, StoreModel,
};

/// Experiment scale knobs.
#[derive(Clone, Copy, Debug)]
pub struct Scale {
    /// Records in KV runs (paper: 10 M).
    pub records: u64,
    /// Measured operations (paper: 10 M).
    pub ops: u64,
    /// ML dataset bytes (paper: 9–34 GB).
    pub ml_dataset: u64,
    /// ML steps.
    pub ml_steps: u64,
}

impl Default for Scale {
    fn default() -> Self {
        Scale {
            records: 60_000,
            ops: 30_000,
            ml_dataset: 192 << 20,
            ml_steps: 60,
        }
    }
}

impl Scale {
    /// Smaller scale for smoke tests / cargo bench.
    pub fn small() -> Self {
        Scale {
            records: 12_000,
            ops: 5_000,
            ml_dataset: 48 << 20,
            ml_steps: 20,
        }
    }
}

/// Base config shared by the experiments: 1 sender + 6 peers (Figure 4),
/// MR unit scaled down with the workload so placement/eviction dynamics
/// keep the same block-count shape as 1 GB units at 10 M records.
pub fn base_config() -> Config {
    let mut cfg = Config::default();
    cfg.cluster.nodes = 7;
    cfg.valet.mr_block_bytes = 16 << 20; // scaled "1 GB" unit
    cfg.valet.min_pool_pages = 2_048; // 8 MB floor
    cfg.valet.max_pool_pages = 1 << 20; // 4 GB cap
    cfg
}

fn kv_config(scale: &Scale, app: App, mix: Mix, fit: f64) -> KvRunConfig {
    let store = StoreModel::new(app, 1024);
    KvRunConfig {
        concurrency: 8,
        seed: 42,
        ..KvRunConfig::new(store, mix, scale.records, scale.ops)
    }
    .with_fit(fit)
}

/// Config whose Valet mempool is capped by realistic host idle memory:
/// the paper's sender hosts 2–3 containers, so only ~a quarter of a
/// workload's working set fits in host idle memory (the mempool grows and
/// shrinks under that ceiling). Without this cap the scaled-down runs
/// would let the mempool absorb the entire paged set and hide the
/// local/remote dynamics the figures measure.
fn cfg_for(rc: &KvRunConfig) -> Config {
    let mut cfg = base_config();
    let ws = rc.store.working_set_pages(rc.records);
    cfg.valet.max_pool_pages = (ws / 4).max(64);
    cfg.valet.min_pool_pages = (ws / 32).max(64);
    cfg
}

fn run_one(
    cfg: &Config,
    kind: BackendKind,
    rc: &KvRunConfig,
) -> (crate::workloads::KvResult, Cluster) {
    let mut cl = Cluster::new(cfg, kind);
    let r = run_kv(&mut cl, rc);
    (r, cl)
}

fn fmt_ms(ns: Ns) -> String {
    format!("{:.1}", ns as f64 / 1e6)
}

fn fmt_us(ns: f64) -> String {
    format!("{:.2}", ns / 1e3)
}

// ---------------------------------------------------------------------
// Table 1 — latency impact on the critical path of a typical design
// ---------------------------------------------------------------------

/// Table 1: run FIO on the Infiniswap-like baseline and attribute each
/// operation class's average latency, with its share of total time.
pub fn table1(_scale: &Scale) -> Report {
    let cfg = base_config();
    let mut cl = Cluster::new(&cfg, BackendKind::Infiniswap);
    // write phase at FIO queue depth 64 (the paper's convoying bursts);
    // read phase at depth 2 (reads arrive spread out in their run).
    let _ = run_fio(
        &mut cl,
        &FioJob {
            write_bytes: 64 * 1024,
            writes: 3_000,
            reads: 0,
            iodepth: 64,
            ..Default::default()
        },
    );
    let m = run_fio(
        &mut cl,
        &FioJob {
            write_bytes: 64 * 1024,
            writes: 0,
            reads: 3_000,
            iodepth: 2,
            file_pages: 3_000 * 16, // the file laid out by phase one
            ..Default::default()
        },
    );
    // connection+mapping cost from the fabric's counters (per event)
    let lat = cfg.latency;
    let mut rows = Vec::new();
    let mut entries: Vec<(&str, f64)> = Vec::new();
    let disk_wr = m.write_parts.mean("disk");
    let disk_rd = m.read_parts.mean("disk");
    let conn = lat.connect as f64;
    let map = lat.map_mr as f64;
    let rdma_wr = m.write_parts.mean("rdma");
    let copy = m.write_parts.mean("copy");
    let rdma_rd = m.read_parts.mean("rdma");
    entries.push(("Disk WR", disk_wr));
    entries.push(("Connection", conn));
    entries.push(("Mapping", map));
    entries.push(("Disk RD", disk_rd));
    entries.push(("RDMA WRITE", rdma_wr));
    entries.push(("COPY", copy));
    entries.push(("RDMA READ", rdma_rd));
    let total: f64 = entries.iter().map(|e| e.1).sum();
    for (name, v) in &entries {
        rows.push(vec![
            name.to_string(),
            fmt_us(*v),
            format!("{:.1}%", 100.0 * v / total),
        ]);
    }
    Report {
        kv: Vec::new(),
        id: "table1",
        title: "Latency impact on the critical path (typical RDMA block device)",
        header: vec!["Operation", "Latency (µs)", "Share"],
        rows,
        notes: vec![
            format!(
                "disk writes {} / disk reads {} during connection+mapping windows",
                m.disk_writes, m.disk_reads
            ),
            "paper: Disk WR 58.5%, Connection 29.2%, Mapping 9%, Disk RD 3%, RDMA+copy 0.3%".into(),
        ],
    }
}

// ---------------------------------------------------------------------
// Figure 2/3 — container-wide memory imbalance
// ---------------------------------------------------------------------

/// Figure 2: three containers on one 64 GB node; container 1 (10 GB
/// limit) runs a growing workload and starts swapping while the node has
/// free memory. Series: used memory per container + node free.
pub fn fig2(_scale: &Scale) -> Report {
    let node_gb = 64u64;
    let c1_limit_gb = 10u64;
    let mut rows = Vec::new();
    // container 1's demand grows 0..18 GB; 2 and 3 idle at 4 GB each
    for minute in 0..=18u64 {
        let demand = minute;
        let used1 = demand.min(c1_limit_gb);
        let swapped = demand.saturating_sub(c1_limit_gb);
        let used2 = 4;
        let used3 = 4;
        let free = node_gb - used1 - used2 - used3;
        rows.push(vec![
            minute.to_string(),
            used1.to_string(),
            swapped.to_string(),
            used2.to_string(),
            used3.to_string(),
            free.to_string(),
        ]);
    }
    Report {
        kv: Vec::new(),
        id: "fig2",
        title: "Container-wide memory imbalance (container 1 limited to 10 GB)",
        header: vec![
            "t (min)",
            "c1 used GB",
            "c1 swapped GB",
            "c2 GB",
            "c3 GB",
            "node free GB",
        ],
        rows,
        notes: vec![
            "container 1 swaps after 10 GB while ~46 GB stays free on the node".into(),
        ],
    }
}

/// Figure 3: KV ops/sec vs container memory limit under conventional OS
/// swap — the swap cliff that motivates the whole system.
pub fn fig3(scale: &Scale) -> Report {
    let cfg = base_config();
    let mut rows = Vec::new();
    for app in App::all() {
        for mix in [Mix::Etc, Mix::Sys] {
            let mut cells = vec![format!("{} {}", app.name(), mix.name())];
            for fit in [1.0, 0.75, 0.5, 0.25] {
                let rc = kv_config(scale, app, mix, fit);
                let (r, _) = run_one(&cfg, BackendKind::LinuxSwap, &rc);
                cells.push(format!("{:.0}", r.metrics.throughput()));
            }
            rows.push(cells);
        }
    }
    Report {
        kv: Vec::new(),
        id: "fig3",
        title: "Throughput vs container memory limit (conventional OS swap)",
        header: vec!["workload", "100% fit", "75%", "50%", "25%"],
        rows,
        notes: vec![
            "performance collapses once the working set exceeds the limit, \
             while unused memory remains in other containers"
                .into(),
        ],
    }
}

// ---------------------------------------------------------------------
// Figure 5 — remote eviction impact (delete-based)
// ---------------------------------------------------------------------

/// Figure 5: Redis/SYS paged onto 6 peers; M peers (1..=6) evict all
/// donated memory by deletion. Line = normalized throughput, bar =
/// fraction of the sender's remote data surviving in cluster memory.
pub fn fig5(scale: &Scale) -> Report {
    let mut rows = Vec::new();
    let mut base_tp = 0.0;
    for evicting in 0..=6usize {
        let rc = kv_config(scale, App::Redis, Mix::Sys, 0.25);
        let cfg = cfg_for(&rc);
        let mut cl = Cluster::new(&cfg, BackendKind::Infiniswap);
        let mut session = crate::workloads::KvSession::new(rc);
        session.load(&mut cl);
        let donated_before: u64 =
            cl.state.peers().map(|n| cl.state.mrpools[n].registered_bytes()).sum();
        // M peers' native apps claim all their memory -> delete eviction
        let peers: Vec<_> = cl.state.peers().collect();
        for &p in peers.iter().take(evicting) {
            let total = cl.state.monitors[p].total_bytes;
            cl.schedule(session.t, ClusterEvent::NativeAlloc {
                node: p,
                bytes: total,
            });
        }
        session.t += secs(1);
        cl.advance(session.t);
        let donated_after: u64 =
            cl.state.peers().map(|n| cl.state.mrpools[n].registered_bytes()).sum();
        let r = session.run(&mut cl, scale.ops);
        let tp = r.metrics.throughput();
        if evicting == 0 {
            base_tp = tp;
        }
        let surviving = if donated_before == 0 {
            0.0
        } else {
            donated_after as f64 / donated_before as f64
        };
        rows.push(vec![
            evicting.to_string(),
            format!("{:.2}", tp / base_tp.max(1e-9)),
            format!("{:.0}%", surviving * 100.0),
            format!("{}", r.metrics.disk_reads),
        ]);
    }
    Report {
        kv: Vec::new(),
        id: "fig5",
        title: "Remote eviction impact (delete-based) + surviving remote memory",
        header: vec![
            "peers evicting",
            "normalized throughput",
            "remote data surviving",
            "disk reads",
        ],
        rows,
        notes: vec![
            "paper: 1 evicting peer already halves sender throughput while idle memory remains".into(),
        ],
    }
}

// ---------------------------------------------------------------------
// Figure 8 — local/remote hit ratio vs mempool size
// ---------------------------------------------------------------------

/// Figure 8: sweep the (fixed) local mempool size; report local vs
/// remote hit ratio.
pub fn fig8(scale: &Scale) -> Report {
    let mut rows = Vec::new();
    let mut kv = Vec::new();
    let rc0 = kv_config(scale, App::Redis, Mix::Sys, 0.5);
    let ws_pages =
        rc0.store.working_set_pages(rc0.records);
    for frac in [0.05, 0.1, 0.2, 0.4, 0.6, 0.8] {
        let mut cfg = base_config();
        let pool = ((ws_pages as f64) * frac) as u64;
        cfg.valet.min_pool_pages = pool.max(64);
        cfg.valet.max_pool_pages = pool.max(64);
        let (r, _) = run_one(&cfg, BackendKind::Valet, &rc0);
        let local = r.metrics.local_hit_ratio();
        kv.push((
            format!("local_hit_pct_ws{:.0}", frac * 100.0),
            local * 100.0,
        ));
        rows.push(vec![
            format!("{:.0}% of WS", frac * 100.0),
            format!("{:.1}%", local * 100.0),
            format!("{:.1}%", (1.0 - local) * 100.0),
        ]);
    }
    Report {
        kv,
        id: "fig8",
        title: "Local vs remote hit ratio vs local mempool size",
        header: vec!["mempool size", "local hit", "remote hit"],
        rows,
        notes: vec!["local hit ratio increases with mempool size".into()],
    }
}

// ---------------------------------------------------------------------
// Figure 9 — write latency vs block I/O size
// ---------------------------------------------------------------------

/// Figure 9: Valet application write latency as block-I/O size sweeps
/// 32/64/128 KB (RDMA message size fixed at 512 KB).
pub fn fig9(_scale: &Scale) -> Report {
    let mut rows = Vec::new();
    let mut kv = Vec::new();
    for kb in [32u64, 64, 128] {
        let mut cfg = base_config();
        cfg.valet.block_io_bytes = kb << 10;
        let mut cl = Cluster::new(&cfg, BackendKind::Valet);
        let m = run_fio(
            &mut cl,
            &FioJob {
                write_bytes: kb << 10,
                writes: 2_000,
                reads: 0,
                ..Default::default()
            },
        );
        kv.push((
            format!("write_mean_us_{kb}kb"),
            m.write_latency.mean() / 1e3,
        ));
        rows.push(vec![
            format!("{kb} KB"),
            fmt_us(m.write_latency.mean()),
            fmt_us(m.write_latency.p99() as f64),
        ]);
    }
    Report {
        kv,
        id: "fig9",
        title: "Write latency vs block I/O size (Valet, 512 KB RDMA message)",
        header: vec!["block I/O", "mean write µs", "p99 µs"],
        rows,
        notes: vec![
            "only the local copy remains in the critical path, so latency \
             scales with block size"
                .into(),
        ],
    }
}

// ---------------------------------------------------------------------
// Figure 10 — critical-path optimization across local:remote ratios
// ---------------------------------------------------------------------

/// Figure 10: VoltDB/SYS latency with and without the critical-path
/// optimization across local:remote working-set splits.
pub fn fig10(scale: &Scale) -> Report {
    let mut rows = Vec::new();
    let rc = kv_config(scale, App::VoltDb, Mix::Sys, 0.5);
    let ws_pages = rc.store.working_set_pages(rc.records);
    for (label, local_frac) in [
        ("10:0", 1.0),
        ("7:3", 0.7),
        ("5:5", 0.5),
        ("3:7", 0.3),
        ("0:10", 0.0),
    ] {
        // with optimization: mempool sized to the local fraction
        let mut cfg = base_config();
        let pool = ((ws_pages as f64) * local_frac) as u64;
        cfg.valet.min_pool_pages = pool.max(1);
        cfg.valet.max_pool_pages = pool.max(1);
        if local_frac == 0.0 {
            cfg.valet.min_pool_pages = 0;
            cfg.valet.max_pool_pages = 0; // sync mode
        }
        let (with_opt, _) = run_one(&cfg, BackendKind::Valet, &rc);
        // without optimization: synchronous remote writes (sync mode)
        let mut cfg2 = base_config();
        cfg2.valet.min_pool_pages = 0;
        cfg2.valet.max_pool_pages = 0;
        let (without, _) = run_one(&cfg2, BackendKind::Valet, &rc);
        rows.push(vec![
            label.to_string(),
            fmt_us(with_opt.metrics.op_latency.mean()),
            fmt_us(without.metrics.op_latency.mean()),
        ]);
    }
    Report {
        kv: Vec::new(),
        id: "fig10",
        title: "Latency with / without critical-path optimization (VoltDB SYS)",
        header: vec![
            "local:remote",
            "with opt (µs/op)",
            "without opt (µs/op)",
        ],
        rows,
        notes: vec![
            "with the optimization, latency stays stable regardless of the \
             local:remote ratio"
                .into(),
        ],
    }
}

// ---------------------------------------------------------------------
// Figures 18/19 + Table 5 — BigData workloads across all systems
// ---------------------------------------------------------------------

/// Figures 18/19 + Table 5: completion time and average latency of
/// Memcached/Redis/VoltDB × ETC/SYS × fit % on all four systems, plus
/// the improvement-ratio summary.
pub fn bigdata(scale: &Scale) -> Report {
    let mut rows = Vec::new();
    let mut sums: std::collections::HashMap<(&str, u32), (f64, f64)> =
        std::collections::HashMap::new();
    for app in App::all() {
        for mix in [Mix::Etc, Mix::Sys] {
            for fit_pct in [100u32, 75, 50, 25] {
                let rc =
                    kv_config(scale, app, mix, fit_pct as f64 / 100.0);
                let cfg = cfg_for(&rc);
                let mut cells =
                    vec![format!("{} {} {fit_pct}%", app.name(), mix.name())];
                let mut per_system: Vec<(f64, f64)> = Vec::new();
                for kind in [
                    BackendKind::Nbdx,
                    BackendKind::Infiniswap,
                    BackendKind::Valet,
                    BackendKind::LinuxSwap,
                ] {
                    let (r, _) = run_one(&cfg, kind, &rc);
                    let comp = r.completion as f64 / 1e9;
                    let lat = r.metrics.op_latency.mean();
                    per_system.push((comp, lat));
                    cells.push(format!("{comp:.2}s/{:.0}µs", lat / 1e3));
                }
                // accumulate improvement ratios vs valet (index 2)
                let valet = per_system[2].0.max(1e-9);
                for (i, name) in
                    ["nbdX", "Infiniswap", "Linux"].iter().enumerate()
                {
                    let other = per_system[if i < 2 { i } else { 3 }].0;
                    let e = sums
                        .entry((name, fit_pct))
                        .or_insert((0.0, 0.0));
                    e.0 += other / valet;
                    e.1 += 1.0;
                }
                rows.push(cells);
            }
        }
    }
    let mut notes = vec![
        "cells: completion seconds / mean op latency".into(),
        "Table 5 (avg improvement of Valet, this run):".into(),
    ];
    for fit in [75u32, 50, 25] {
        let g = |n: &str| {
            sums.get(&(n, fit))
                .map(|(s, c)| s / c)
                .unwrap_or(0.0)
        };
        notes.push(format!(
            "  {fit}% fit: Linux {:.0}x, nbdX {:.2}x, Infiniswap {:.2}x  \
             (paper: {} )",
            g("Linux"),
            g("nbdX"),
            g("Infiniswap"),
            match fit {
                75 => "124x, 1.5x, 1.6x",
                50 => "242x, 2.4x, 2.5x",
                _ => "438x, 3.5x, 3.7x",
            }
        ));
    }
    Report {
        kv: Vec::new(),
        id: "bigdata",
        title: "BigData workloads: completion + latency (Figs 18/19, Table 5)",
        header: vec!["workload", "nbdX", "Infiniswap", "Valet", "Linux"],
        rows,
        notes,
    }
}

// ---------------------------------------------------------------------
// Figure 20 + Table 6 — ML workloads
// ---------------------------------------------------------------------

/// Figure 20 + Table 6: five ML workloads × fit % × four systems,
/// completion time (compute cost constant per-step, the paging differs).
pub fn ml(scale: &Scale) -> Report {
    let mut rows = Vec::new();
    let mut sums: std::collections::HashMap<(&str, u32), (f64, f64)> =
        std::collections::HashMap::new();
    for kind in MlKind::all() {
        for fit_pct in [100u32, 75, 50, 25] {
            let mut cells =
                vec![format!("{} {fit_pct}%", kind.name())];
            let mut per_system = Vec::new();
            for be in [
                BackendKind::Nbdx,
                BackendKind::Infiniswap,
                BackendKind::Valet,
                BackendKind::LinuxSwap,
            ] {
                let mut cfg = base_config();
                let ws = scale.ml_dataset / crate::PAGE_SIZE;
                cfg.valet.max_pool_pages = (ws / 4).max(64);
                cfg.valet.min_pool_pages = (ws / 32).max(64);
                let mut cl = Cluster::new(&cfg, be);
                let rc = MlRunConfig {
                    batch_bytes: 4 << 20,
                    ..MlRunConfig::new(
                        kind,
                        scale.ml_dataset,
                        scale.ml_steps,
                        fit_pct as f64 / 100.0,
                    )
                };
                let r = run_ml(&mut cl, &rc, |_| ms(25));
                per_system.push(r.completion as f64 / 1e9);
                cells.push(format!(
                    "{:.2}s",
                    r.completion as f64 / 1e9
                ));
            }
            let valet = per_system[2].max(1e-9);
            for (i, name) in
                ["nbdX", "Infiniswap", "Linux"].iter().enumerate()
            {
                let other = per_system[if i < 2 { i } else { 3 }];
                let e =
                    sums.entry((name, fit_pct)).or_insert((0.0, 0.0));
                e.0 += other / valet;
                e.1 += 1.0;
            }
            rows.push(cells);
        }
    }
    let mut notes =
        vec!["Table 6 (avg improvement of Valet, this run):".into()];
    for fit in [75u32, 50, 25] {
        let g = |n: &str| {
            sums.get(&(n, fit)).map(|(s, c)| s / c).unwrap_or(0.0)
        };
        notes.push(format!(
            "  {fit}% fit: Linux {:.0}x, nbdX {:.2}x, Infiniswap {:.2}x  \
             (paper: {})",
            g("Linux"),
            g("nbdX"),
            g("Infiniswap"),
            match fit {
                75 => "107x, 1.32x, 1.4x",
                50 => "161x, 1.52x, 1.76x",
                _ => "230x, 1.81x, 2.16x",
            }
        ));
    }
    notes.push(
        "K-Means' early-block reuse keeps its completion flat (§6.2)".into(),
    );
    Report {
        kv: Vec::new(),
        id: "ml",
        title: "ML workloads: completion time (Fig 20, Table 6)",
        header: vec!["workload", "nbdX", "Infiniswap", "Valet", "Linux"],
        rows,
        notes,
    }
}

// ---------------------------------------------------------------------
// Figure 21 — host/remote memory distribution
// ---------------------------------------------------------------------

/// Figure 21: throughput of Valet-LocalOnly / 75:25 / 50:50 / 25:75 /
/// RemoteOnly vs Linux, nbdX, Infiniswap (25 % container fit).
pub fn fig21(scale: &Scale) -> Report {
    let mut rows = Vec::new();
    for app in App::all() {
        let rc = kv_config(scale, app, Mix::Sys, 0.25);
        let ws_pages = rc.store.working_set_pages(rc.records);
        let mut cells = vec![app.name().to_string()];
        // baselines
        for kind in [
            BackendKind::LinuxSwap,
            BackendKind::Nbdx,
            BackendKind::Infiniswap,
        ] {
            let (r, _) = run_one(&base_config(), kind, &rc);
            cells.push(format!("{:.0}", r.metrics.throughput()));
        }
        // Valet variants: mempool sized for the local share of the
        // *paged* portion (75% of WS is beyond the container limit)
        for (_label, local_frac) in [
            ("RemoteOnly", 0.0),
            ("25:75", 0.25),
            ("50:50", 0.5),
            ("75:25", 0.75),
            ("LocalOnly", 1.0),
        ] {
            let mut cfg = base_config();
            let paged = (ws_pages as f64) * 0.75;
            let pool = (paged * local_frac) as u64;
            if local_frac == 0.0 {
                cfg.valet.min_pool_pages = 0;
                cfg.valet.max_pool_pages = 0;
            } else {
                cfg.valet.min_pool_pages = pool.max(64);
                cfg.valet.max_pool_pages = pool.max(64);
            }
            let (r, _) = run_one(&cfg, BackendKind::Valet, &rc);
            cells.push(format!("{:.0}", r.metrics.throughput()));
        }
        rows.push(cells);
    }
    Report {
        kv: Vec::new(),
        id: "fig21",
        title: "Host/remote memory distribution (ops/sec, SYS, 25% fit)",
        header: vec![
            "app",
            "Linux",
            "nbdX",
            "Infiniswap",
            "V-RemoteOnly",
            "V-25:75",
            "V-50:50",
            "V-75:25",
            "V-LocalOnly",
        ],
        rows,
        notes: vec![
            "paper headline: Valet-LocalOnly up to 226x over Linux, 5.5x \
             over Infiniswap; largest jump is RemoteOnly → 25:75 (the \
             mempool entering the critical path)"
                .into(),
        ],
    }
}

// ---------------------------------------------------------------------
// Table 7 — latency breakdown Valet vs Infiniswap
// ---------------------------------------------------------------------

/// Table 7: per-component read/write latency breakdown at the 25:75
/// setting (VoltDB SYS), Valet with disk backup for fairness.
pub fn table7(scale: &Scale) -> Report {
    let rc = kv_config(scale, App::VoltDb, Mix::Sys, 0.25);
    let ws_pages = rc.store.working_set_pages(rc.records);
    let mut rows = Vec::new();
    for kind in [BackendKind::Valet, BackendKind::Infiniswap] {
        let mut cfg = base_config();
        if kind == BackendKind::Valet {
            let pool = ((ws_pages as f64) * 0.75 * 0.25) as u64;
            cfg.valet.min_pool_pages = pool.max(64);
            cfg.valet.max_pool_pages = pool.max(64);
            cfg.valet.disk_backup = true;
        }
        let (r, _) = run_one(&cfg, kind, &rc);
        let m = &r.metrics;
        for (dir, hist, parts) in [
            ("read", &m.read_latency, &m.read_parts),
            ("write", &m.write_latency, &m.write_parts),
        ] {
            let mut comp = String::new();
            for (name, _total, _count) in parts.iter() {
                comp.push_str(&format!(
                    "{name} {:.2} ({:.0}%)  ",
                    parts.mean(name) / 1e3,
                    parts.share(name) * 100.0
                ));
            }
            rows.push(vec![
                format!("{} {dir}", kind.name()),
                fmt_us(hist.mean()),
                comp.trim_end().to_string(),
            ]);
        }
        rows.push(vec![
            format!("{} hits", kind.name()),
            String::new(),
            format!(
                "local {} / remote {} / disk {} (disk writes {})",
                m.local_hits, m.remote_hits, m.disk_reads, m.disk_writes
            ),
        ]);
    }
    Report {
        kv: Vec::new(),
        id: "table7",
        title: "Latency breakdown: Valet vs Infiniswap (VoltDB SYS, 25:75)",
        header: vec!["path", "avg µs", "components (mean µs, share)"],
        rows,
        notes: vec![
            "paper: Valet read avg 29.75 µs / write 35.31 µs; Infiniswap \
             read avg 4578 µs / write avg 19.8 ms, dominated by disk \
             redirects"
                .into(),
        ],
    }
}

// ---------------------------------------------------------------------
// Figure 22 — scalability with workload size
// ---------------------------------------------------------------------

/// Figure 22: VoltDB throughput + p99 latency as workload grows, Valet
/// with a small fixed mempool (so the benefit is the critical path, not
/// extra caching).
pub fn fig22(scale: &Scale) -> Report {
    let mut rows = Vec::new();
    for mult in [1u64, 2, 4, 8] {
        let records = scale.records * mult;
        let ops = scale.ops; // constant measurement window
        let store = StoreModel::new(App::VoltDb, 1024);
        let rc = KvRunConfig {
            concurrency: 8,
            seed: 42,
            ..KvRunConfig::new(store, Mix::Sys, records, ops)
        }
        .with_fit(0.25);
        let mut cells = vec![format!("{}k recs", records / 1000)];
        for kind in
            [BackendKind::Nbdx, BackendKind::Infiniswap, BackendKind::Valet]
        {
            let mut cfg = base_config();
            if kind == BackendKind::Valet {
                // 500 MB fixed mempool in the paper; scale to ~2% of WS
                cfg.valet.min_pool_pages = 4_096;
                cfg.valet.max_pool_pages = 4_096;
            }
            let (r, _) = run_one(&cfg, kind, &rc);
            cells.push(format!(
                "{:.0} ops/s p99={}ms",
                r.metrics.throughput(),
                fmt_ms(r.metrics.op_latency.p99())
            ));
        }
        rows.push(cells);
    }
    Report {
        kv: Vec::new(),
        id: "fig22",
        title: "Scalability with workload size (VoltDB SYS, fixed small mempool)",
        header: vec!["workload", "nbdX", "Infiniswap", "Valet"],
        rows,
        notes: vec![
            "paper: Valet up to 7.8x Infiniswap / 12.65x nbdX throughput; \
             nbdX unstable at large workloads (message pool)"
                .into(),
        ],
    }
}

// ---------------------------------------------------------------------
// Figure 23 — migration vs eviction
// ---------------------------------------------------------------------

/// Figure 23: throughput after reclaiming N bytes of remote memory —
/// Valet's activity-based migration vs delete-based eviction.
pub fn fig23(scale: &Scale) -> Report {
    let mut rows = Vec::new();
    for evict_frac in [0.0f64, 0.1, 0.25, 0.5, 0.8] {
        let mut cells = vec![format!("{:.0}%", evict_frac * 100.0)];
        for kind in [BackendKind::Valet, BackendKind::Infiniswap] {
            let rc = kv_config(scale, App::Redis, Mix::Sys, 0.25);
            let cfg = cfg_for(&rc);
            let mut cl = Cluster::new(&cfg, kind);
            let mut session = crate::workloads::KvSession::new(rc);
            session.load(&mut cl);
            // trigger reclamation of evict_frac of donated memory on the
            // most loaded peer
            let peer = cl
                .state
                .peers()
                .max_by_key(|&n| cl.state.mrpools[n].registered_bytes())
                .expect("configs here always build multi-node clusters");
            let donated = cl.state.mrpools[peer].registered_bytes();
            let need = ((donated as f64) * evict_frac) as u64;
            if need > 0 {
                let total = cl.state.monitors[peer].total_bytes;
                let reserve = cl.state.monitors[peer].reserve_bytes;
                cl.schedule(session.t, ClusterEvent::NativeAlloc {
                    node: peer,
                    bytes: total - reserve - (donated - need),
                });
            }
            session.t += secs(1);
            cl.advance(session.t);
            let r = session.run(&mut cl, scale.ops);
            let migrated: u32 =
                cl.pressure_log.iter().map(|p| p.2.migrated).sum();
            let deleted: u32 =
                cl.pressure_log.iter().map(|p| p.2.deleted).sum();
            cells.push(format!(
                "{:.0} ops/s (mig {migrated}/del {deleted})",
                r.metrics.throughput()
            ));
        }
        rows.push(cells);
    }
    Report {
        kv: Vec::new(),
        id: "fig23",
        title: "Migration vs delete-eviction: sender throughput after reclaim",
        header: vec!["remote memory reclaimed", "Valet (migration)", "Infiniswap (delete)"],
        rows,
        notes: vec![
            "paper: no throughput impact with migration; delete-based \
             eviction of ~8% of the workload already halves throughput"
                .into(),
        ],
    }
}

// ---------------------------------------------------------------------
// Ablations — the design choices ARCHITECTURE.md calls out
// ---------------------------------------------------------------------

/// Ablation study over Valet's design knobs:
/// 1. message coalescing on/off (§3.3 WQE-cache argument),
/// 2. activity-based vs batched-query victim selection (§3.5),
/// 3. replication factor cost (§5.3),
/// 4. power-of-two vs round-robin placement (§4.3),
/// 5. LRU vs MRU mempool replacement on the K-Means pattern (§6.2
///    future work, implemented here).
pub fn ablations(scale: &Scale) -> Report {
    let mut rows: Vec<Vec<String>> = Vec::new();

    // 1. coalescing -------------------------------------------------
    for coalescing in [true, false] {
        let mut cfg = base_config();
        cfg.valet.coalescing = coalescing;
        let mut cl = Cluster::new(&cfg, BackendKind::Valet);
        let m = run_fio(
            &mut cl,
            &FioJob {
                write_bytes: 4 * 1024, // small block I/O: many messages
                writes: 60_000,
                reads: 0,
                iodepth: 128, // heavy burst: message rate beyond the
                              // RNIC's WQE drain rate when un-coalesced
                ..Default::default()
            },
        );
        // drain the staging queue to quiescence (the writes finish long
        // before the first mapping window opens)
        let _ = m;
        cl.advance(secs(120));
        let misses = cl.state.fabric.wqe_misses(0);
        let miss_cost =
            misses * base_config().latency.wqe_miss_penalty / 1_000_000;
        rows.push(vec![
            format!(
                "coalescing {}",
                if coalescing { "ON" } else { "OFF" }
            ),
            format!(
                "{} RDMA messages, WQE misses {} (+{} ms NIC time)",
                cl.state.fabric.verbs_posted(0),
                misses,
                miss_cost
            ),
        ]);
    }

    // 2. victim selection -------------------------------------------
    {
        use crate::eviction::{ActivityBased, BatchedQueryRandom, VictimPolicy};
        use crate::mrpool::MrBlockPool;
        use crate::util::Rng;
        let mut pool = MrBlockPool::new();
        let mut rng = Rng::new(9);
        for _ in 0..64 {
            let id = pool.register(0, 1 << 30, 0);
            pool.touch_write(id, rng.below(1_000_000_000));
        }
        let now = 2_000_000_000;
        let optimal = pool
            .least_active(now)
            .expect("64 blocks were registered above")
            .id;
        let a = ActivityBased
            .select(&pool, now)
            .expect("64 blocks were registered above");
        rows.push(vec![
            "victim: activity-based".into(),
            format!(
                "cost 0 µs, 0 queries, optimal victim: {}",
                a.block == optimal
            ),
        ]);
        let mut hits = 0;
        let mut cost = 0;
        let trials = 32;
        for seed in 0..trials {
            let mut p = BatchedQueryRandom::new(
                seed,
                4,
                2 * base_config().latency.rdma_write_base
                    + base_config().latency.two_sided_extra,
            );
            let c = p
                .select(&pool, now)
                .expect("64 blocks were registered above");
            cost += c.selection_cost;
            if c.block == optimal {
                hits += 1;
            }
        }
        rows.push(vec![
            "victim: batched-query (4)".into(),
            format!(
                "cost {:.1} µs, 4 queries, optimal victim: {}/{} trials",
                cost as f64 / trials as f64 / 1e3,
                hits,
                trials
            ),
        ]);
    }

    // 3. replication factor ------------------------------------------
    for replicas in [1usize, 2, 3] {
        let rc = kv_config(scale, App::Redis, Mix::Sys, 0.25);
        let mut cfg = cfg_for(&rc);
        cfg.valet.replicas = replicas;
        let (r, cl) = run_one(&cfg, BackendKind::Valet, &rc);
        let remote: u64 = cl
            .state
            .peers()
            .map(|n| cl.state.mrpools[n].registered_bytes())
            .sum();
        rows.push(vec![
            format!("replication x{replicas}"),
            format!(
                "{:.0} ops/s, remote space {} MiB",
                r.metrics.throughput(),
                remote >> 20
            ),
        ]);
    }

    // 4. placement ----------------------------------------------------
    {
        use crate::placement::{Candidate, Placement, PowerOfTwo, RoundRobin};
        let balls = 2_000u64;
        let n = 6;
        for (name, mut policy) in [
            (
                "placement: power-of-two",
                Box::new(PowerOfTwo::new(3)) as Box<dyn Placement>,
            ),
            (
                "placement: round-robin",
                Box::new(RoundRobin::new()) as Box<dyn Placement>,
            ),
        ] {
            // heterogeneous peers: two have half the free memory
            let mut loads = vec![0u64; n];
            let caps = [4u64, 4, 2, 4, 2, 4].map(|g| g << 30);
            for _ in 0..balls {
                let cands: Vec<Candidate> = (0..n)
                    .map(|i| {
                        Candidate::new(
                            i,
                            caps[i].saturating_sub(loads[i] * (1 << 20)),
                        )
                    })
                    .collect();
                let pick = policy
                    .pick(&cands)
                    .expect("candidate list is non-empty (n nodes)")
                    .node;
                loads[pick] += 1;
            }
            let imbalance = *loads
                .iter()
                .max()
                .expect("n >= 1 load buckets") as f64
                / (balls as f64 / n as f64);
            rows.push(vec![
                name.into(),
                format!("max/mean load {imbalance:.2} (loads {loads:?})"),
            ]);
        }
    }

    // 5. LRU vs MRU on the K-Means pattern ----------------------------
    for (name, repl) in [
        ("replacement: LRU (kmeans)", crate::config::Replacement::Lru),
        ("replacement: MRU (kmeans)", crate::config::Replacement::Mru),
    ] {
        let mut cfg = base_config();
        let ws = scale.ml_dataset / crate::PAGE_SIZE;
        cfg.valet.max_pool_pages = (ws / 4).max(64);
        cfg.valet.min_pool_pages = (ws / 32).max(64);
        cfg.valet.replacement = repl;
        let mut cl = Cluster::new(&cfg, BackendKind::Valet);
        let rc = MlRunConfig {
            batch_bytes: 4 << 20,
            ..MlRunConfig::new(MlKind::KMeans, scale.ml_dataset, scale.ml_steps, 0.5)
        };
        let r = run_ml(&mut cl, &rc, |_| ms(25));
        rows.push(vec![
            name.into(),
            format!(
                "completion {:.2}s, local hit {:.1}%",
                r.completion as f64 / 1e9,
                r.metrics.local_hit_ratio() * 100.0
            ),
        ]);
    }

    Report {
        kv: Vec::new(),
        id: "ablations",
        title: "Design-choice ablations (coalescing, victim policy, replication, placement, replacement)",
        header: vec!["knob", "result"],
        rows,
        notes: vec![
            "coalescing exists to avoid WQE-cache thrash [12]".into(),
            "activity-based selection is free AND optimal by construction; \
             batched random queries pay linear latency and usually miss \
             the least-active block (§2.3/§3.5)"
                .into(),
            "N-way replication costs N× remote space (§5.3)".into(),
            "MRU is the paper's §6.2 future-work suggestion for \
             repetitive patterns"
                .into(),
        ],
    }
}

// ---------------------------------------------------------------------
// Sharded serve scaling — beyond the paper: the parallel front-end
// ---------------------------------------------------------------------

/// Sharded serve front-end scaling: wall-clock throughput of a
/// read-heavy mixed workload (8 clients, 90% reads / 10% writes over a
/// cached hot set) against the single-driver baseline and `S ∈ {1,2,4}`
/// sharded front-ends. The baseline funnels every request — including
/// pure local-cache read hits — through one mpsc leader thread; the
/// sharded front-end serves hits lock-free on one worker per shard
/// (§4.1 "parallel reads"), so throughput scales with `S` until the
/// shared slow path saturates.
pub fn scaling(scale: &Scale) -> Report {
    use crate::serve::{spawn, spawn_sharded, Reply, Request};
    use std::time::Instant;

    let mut cfg = base_config();
    cfg.valet.mr_block_bytes = 16 << 20;
    // the hot set fits the pool, so measured reads are local-cache hits
    let hot_blocks: u64 = 256; // 256 × 64 KB = 16 MB hot set
    cfg.valet.min_pool_pages = hot_blocks * 16 * 2;
    cfg.valet.max_pool_pages = hot_blocks * 16 * 2;
    let clients = 8usize;
    let ops_per_client = (scale.ops / 2).max(1_000);

    // deterministic 90/10 mixed loop over the hot set
    fn mixed_loop(
        call: &mut dyn FnMut(Request) -> Option<Reply>,
        seed: u64,
        ops: u64,
        hot_blocks: u64,
    ) {
        let mut x = seed.wrapping_mul(0x9E37_79B9_7F4A_7C15) | 1;
        for i in 0..ops {
            x = x
                .wrapping_mul(6364136223846793005)
                .wrapping_add(1442695040888963407);
            let blk = (x >> 33) % hot_blocks;
            let req = if i % 10 == 0 {
                Request::Write { page: blk * 16, bytes: 64 * 1024 }
            } else {
                Request::Read { page: blk * 16 + ((x >> 21) % 16) }
            };
            call(req).expect("serve call failed");
        }
    }

    // run one client thread per submitter; returns wall ops/sec
    fn measure<C>(cs: Vec<C>, ops: u64, hot_blocks: u64) -> f64
    where
        C: FnMut(Request) -> Option<Reply> + Send + 'static,
    {
        let n = cs.len() as u64;
        let t0 = Instant::now();
        let joins: Vec<_> = cs
            .into_iter()
            .enumerate()
            .map(|(ci, mut call)| {
                std::thread::spawn(move || {
                    mixed_loop(&mut call, ci as u64 + 1, ops, hot_blocks)
                })
            })
            .collect();
        for j in joins {
            j.join().expect("client thread");
        }
        (n * ops) as f64 / t0.elapsed().as_secs_f64().max(1e-9)
    }

    let mut rows = Vec::new();
    let mut kv = Vec::new();

    // single-driver baseline: one leader thread owns every request
    let h = spawn(&cfg, BackendKind::Valet);
    for blk in 0..hot_blocks {
        h.call(Request::Write { page: blk * 16, bytes: 64 * 1024 })
            .expect("prefill writes cannot fail: the serve worker is alive");
    }
    let cs: Vec<_> = (0..clients)
        .map(|_| {
            let c = h.client();
            move |req: Request| c.call(req)
        })
        .collect();
    let base_tp = measure(cs, ops_per_client, hot_blocks);
    drop(h);
    rows.push(vec![
        "single-driver baseline".into(),
        format!("{base_tp:.0}"),
        "1.00x".into(),
    ]);
    kv.push(("baseline_ops_per_sec".to_string(), base_tp));

    let mut s4_tp = 0.0;
    for shards in [1usize, 2, 4] {
        let h = spawn_sharded(&cfg, shards);
        for blk in 0..hot_blocks {
            h.call(Request::Write { page: blk * 16, bytes: 64 * 1024 })
                .expect("prefill writes cannot fail: the serve worker is alive");
        }
        let cs: Vec<_> = (0..clients)
            .map(|_| {
                let c = h.client();
                move |req: Request| c.call(req)
            })
            .collect();
        let tp = measure(cs, ops_per_client, hot_blocks);
        let out = h.shutdown().expect("sharded shutdown");
        let m = out.engine.combined_metrics();
        rows.push(vec![
            format!("sharded S={shards}"),
            format!("{tp:.0}"),
            format!("{:.2}x", tp / base_tp.max(1e-9)),
        ]);
        kv.push((format!("s{shards}_ops_per_sec"), tp));
        if shards == 4 {
            s4_tp = tp;
            kv.push((
                "s4_local_hit_ratio".to_string(),
                m.local_hit_ratio(),
            ));
        }
    }
    kv.push((
        "s4_speedup_vs_baseline".to_string(),
        s4_tp / base_tp.max(1e-9),
    ));

    // Lane-count axis (virtual time, deterministic): submission
    // throughput while one peer maps a fresh unit. A batch holds its
    // sender lane from send until the unit's `ready` clock (Table 1's
    // 62 ms MR map), so on the single pre-split timeline one mapping
    // peer stalls every other peer's submissions for the whole map;
    // per-peer lanes drain them in microseconds (the NIC wire slots
    // pipeline either way). Unlike the wall-clock rows above this
    // ratio is exact and ci.sh gates it numerically.
    fn lane_drain(cfg: &Config) -> (f64, usize) {
        use crate::backends::ClusterState;
        use crate::engine::ShardedEngine;
        use crate::placement::RoundRobin;
        use crate::sim::us;
        let mut cl = ClusterState::new(cfg);
        let mut e = ShardedEngine::new(cfg, 1);
        e.sender_mut().set_placement(Box::new(RoundRobin::new()));
        let ppu = cfg.valet.mr_block_bytes / 4096; // pages per unit
        // setup (uncounted): connect + map one unit on each peer, then
        // drain fully so the NIC and every lane are idle
        let mut t: Ns = 0;
        for u in 0..4u64 {
            t = e.write(&mut cl, t, u * ppu, 64 * 1024).end;
        }
        let mut iters = 0u32;
        while e.pending_write_sets() > 0 && iters < 1_000_000 {
            t += ms(1);
            e.pump(&mut cl, t);
            iters += 1;
        }
        // measured: one fresh unit (peer 1 maps again) racing 45 cheap
        // sets to the already-mapped units on peers 2–4 (15 per unit,
        // distinct 64 KB stripes inside each 256-page unit)
        let t_start = t;
        let mut ops = 1u64;
        t = e.write(&mut cl, t, 4 * ppu, 64 * 1024).end;
        for i in 0..45u64 {
            let page = (1 + i % 3) * ppu + (1 + i / 3) * 16;
            t = e.write(&mut cl, t, page, 64 * 1024).end;
            ops += 1;
        }
        // throughput = ops over the time for every set to leave staging
        // (be posted to a lane) — the submission-layer drain
        let mut iters = 0u32;
        while e.staged_bytes() > 0 && iters < 10_000_000 {
            t += us(100);
            e.pump(&mut cl, t);
            iters += 1;
        }
        let secs = ((t - t_start) as f64 / 1e9).max(1e-9);
        (ops as f64 / secs, e.sender().lane_count())
    }

    let mut lcfg = Config::default();
    lcfg.cluster.nodes = 5; // 1 sender + 4 peers → 4 auto lanes
    lcfg.valet.mr_block_bytes = 1 << 20;
    lcfg.valet.min_pool_pages = 4096;
    lcfg.valet.max_pool_pages = 4096;
    lcfg.valet.sender_lanes = 1; // the pre-split single timeline
    let (lane1_tp, _) = lane_drain(&lcfg);
    lcfg.valet.sender_lanes = 0; // auto: one lane per peer
    let (lane4_tp, nlanes) = lane_drain(&lcfg);
    let lane_speedup = lane4_tp / lane1_tp.max(1e-9);
    rows.push(vec![
        "1 sender lane (virtual)".into(),
        format!("{lane1_tp:.1}"),
        "1.00x".into(),
    ]);
    rows.push(vec![
        format!("{nlanes} sender lanes (virtual)"),
        format!("{lane4_tp:.1}"),
        format!("{lane_speedup:.2}x"),
    ]);
    kv.push(("lane1_ops_per_sec".to_string(), lane1_tp));
    kv.push(("lane4_ops_per_sec".to_string(), lane4_tp));
    kv.push(("lane_speedup".to_string(), lane_speedup));

    // Slow-path threads axis (wall clock): the write-heavy twin of the
    // shard axis. Every client streams fresh 64 KB writes into a
    // private region (mapping a new 1 MB unit every 16th write) with
    // 10% read-backs of its own hot pages. With `slow_path_threads =
    // 1` every write holds the one sequencer lock through staging AND
    // the inline drive — coalescing, placement, unit mapping, wiring —
    // so the 8 clients serialize on that work; with one drain thread
    // per lane the workers stage and admit lock-free and the drains do
    // the same work in 64-entry batches off the request path. ci.sh
    // gates `slow_threads_speedup` numerically.
    fn serve_write_heavy(cfg: &Config, clients: usize, ops: u64) -> f64 {
        let h = spawn_sharded(cfg, 2);
        let t0 = Instant::now();
        let joins: Vec<_> = (0..clients as u64)
            .map(|ci| {
                let c = h.client();
                std::thread::spawn(move || {
                    // private 128 MB-apart regions: every unit is
                    // mapped by exactly one client's stream
                    let base = ci * (1 << 15);
                    let mut written = 0u64;
                    for i in 0..ops {
                        let req = if i % 10 == 9 && written > 0 {
                            Request::Read {
                                page: base + (i * 7919) % (written * 16),
                            }
                        } else {
                            let page = base + written * 16;
                            written += 1;
                            Request::Write { page, bytes: 64 * 1024 }
                        };
                        c.call(req).expect("serve call failed");
                    }
                })
            })
            .collect();
        for j in joins {
            j.join().expect("client thread");
        }
        let tp = (clients as u64 * ops) as f64
            / t0.elapsed().as_secs_f64().max(1e-9);
        let _ = h.shutdown();
        tp
    }

    let mut scfg = Config::default();
    scfg.cluster.nodes = 5; // 1 sender + 4 peers → 4 lanes, 4 rings
    scfg.valet.mr_block_bytes = 1 << 20;
    // room for every client's whole streamed region: the measured axis
    // is slow-path serialization, not eviction
    scfg.valet.min_pool_pages = 1 << 17;
    scfg.valet.max_pool_pages = 1 << 17;
    scfg.valet.sender_lanes = 0;
    let wops = (scale.ops / 4).max(800);
    scfg.valet.slow_path_threads = 1; // every write under the sequencer
    let thr1_tp = serve_write_heavy(&scfg, clients, wops);
    scfg.valet.slow_path_threads = 0; // one drain thread per lane
    let lane_thr_tp = serve_write_heavy(&scfg, clients, wops);
    let slow_threads_speedup = lane_thr_tp / thr1_tp.max(1e-9);
    rows.push(vec![
        "slow-path threads = 1 (write-heavy)".into(),
        format!("{thr1_tp:.0}"),
        "1.00x".into(),
    ]);
    rows.push(vec![
        "one drain thread per lane (write-heavy)".into(),
        format!("{lane_thr_tp:.0}"),
        format!("{slow_threads_speedup:.2}x"),
    ]);
    kv.push(("threads1_ops_per_sec".to_string(), thr1_tp));
    kv.push(("lane_threads_ops_per_sec".to_string(), lane_thr_tp));
    kv.push(("slow_threads_speedup".to_string(), slow_threads_speedup));

    Report {
        kv,
        id: "scaling",
        title: "Sharded serve front-end scaling (wall-clock, 8 clients, 90/10 read-heavy)",
        header: vec!["front-end", "ops/sec (wall)", "speedup"],
        rows,
        notes: vec![
            "wall-clock numbers vary with host load; the headline is \
             S=4 beating the single-driver baseline on read-heavy mixes \
             because local-cache hits never take the shared lock"
                .into(),
            "virtual-time behavior is sharding-invariant for aligned \
             blocks: see tests/sharding.rs for the S=1 bit-for-bit \
             equivalence regression"
                .into(),
            "the sender-lane rows are virtual-time (deterministic): \
             submission drain while one peer maps a fresh unit; on one \
             lane the 62 ms map stalls every peer's submissions, on \
             per-peer lanes only the mapping peer's (ci.sh gates the \
             ratio ≥ 1.5x)"
                .into(),
            "the slow-path-threads rows are wall-clock write-heavy: \
             with threads = 1 every write serializes through the one \
             sequencer lock and its inline drive; per-lane drain \
             threads move that work off the request path (ci.sh gates \
             slow_threads_speedup ≥ 1.3x)"
                .into(),
        ],
    }
}

// ---------------------------------------------------------------------
// Read pipeline — batched block reads, miss coalescing, stride prefetch
// ---------------------------------------------------------------------

/// The miss-path read pipeline experiment (beyond the paper): a
/// remote-resident file — the mempool holds ~1/8 of it, the rest lives
/// on the peers — read back (a) page by page sequentially, (b) as whole
/// 64 KB blocks, and (c) at random, with the stride prefetcher OFF (the
/// pre-pipeline demand miss path, pinned bit-for-bit by
/// `tests/sharding.rs`) and ON. Headline records:
///
/// * `seq_speedup` — sequential mean read latency, prefetcher off/on
///   (the win condition: predicted pages land before demand);
/// * `batch_speedup` — per-block latency, 16 single-page round trips vs
///   one per-unit batched READ;
/// * `rand_regression_pct` — random-mix mean delta with the prefetcher
///   on (the no-harm condition: no majority stride → nothing issued);
/// * `prefetch_coverage` / `prefetch_accuracy` — the prefetcher's own
///   scorecard on the sequential run.
pub fn prefetch(scale: &Scale) -> Report {
    use crate::backends::ClusterState;
    use crate::engine::ShardedEngine;
    use crate::metrics::Histogram;
    use crate::PAGE_SIZE;

    let blocks: u64 = (scale.records / 60).clamp(128, 2_048);
    let file_pages = blocks * 16;
    let pool_pages = (file_pages / 8).max(64);

    let mk_cfg = |prefetch_on: bool| {
        let mut cfg = base_config();
        cfg.valet.mr_block_bytes = 16 << 20;
        cfg.valet.min_pool_pages = pool_pages;
        cfg.valet.max_pool_pages = pool_pages;
        cfg.valet.prefetch = prefetch_on;
        cfg
    };
    // Lay the file out through the write pipeline and drain it remote;
    // the pool retains only the tail.
    let layout = |cfg: &Config| -> (ClusterState, ShardedEngine, Ns) {
        let mut cl = ClusterState::new(cfg);
        let mut e = ShardedEngine::new(cfg, 1);
        let mut t: Ns = 0;
        for blk in 0..blocks {
            t = e.write(&mut cl, t, blk * 16, 16 * PAGE_SIZE).end;
        }
        t += secs(5);
        e.pump(&mut cl, t);
        (cl, e, t)
    };
    // virtual-time ops/sec over a read phase
    let tput = |ops: u64, t0: Ns, t1: Ns| -> f64 {
        ops as f64 / ((t1 - t0).max(1) as f64 / 1e9)
    };

    let mut rows = Vec::new();
    let mut kv = Vec::new();

    // (a) sequential page reads, prefetcher off/on ---------------------
    let mut seq_mean = [0.0f64; 2];
    for (i, on) in [false, true].into_iter().enumerate() {
        let cfg = mk_cfg(on);
        let (mut cl, mut e, t0) = layout(&cfg);
        let mut t = t0;
        for p in 0..file_pages {
            t = e.read(&mut cl, t, p).end;
        }
        let m = e.combined_metrics();
        let tag = if on { "on" } else { "off" };
        seq_mean[i] = m.read_latency.mean();
        kv.push((
            format!("seq_read_mean_us_{tag}"),
            m.read_latency.mean() / 1e3,
        ));
        kv.push((
            format!("seq_read_p99_us_{tag}"),
            m.read_latency.p99() as f64 / 1e3,
        ));
        kv.push((format!("seq_tp_ops_{tag}"), tput(file_pages, t0, t)));
        rows.push(vec![
            format!("sequential, prefetch {tag}"),
            fmt_us(m.read_latency.mean()),
            fmt_us(m.read_latency.p99() as f64),
            format!("{:.0}", tput(file_pages, t0, t)),
            format!(
                "local {} / remote {} / pf hits {} (waste {})",
                m.local_hits, m.remote_hits, m.prefetch_hits,
                m.prefetch_wasted
            ),
        ]);
        if on {
            kv.push((
                "prefetch_coverage".into(),
                m.prefetch_coverage(),
            ));
            kv.push((
                "prefetch_accuracy".into(),
                m.prefetch_accuracy(),
            ));
            kv.push(("prefetch_issued".into(), m.prefetch_issued as f64));
        }
    }
    kv.push(("seq_speedup".into(), seq_mean[0] / seq_mean[1].max(1e-9)));

    // (b) block reads: 16 single-page round trips vs one batched READ --
    let mut block_mean = [0.0f64; 2];
    {
        // per-page baseline: the same blocks read page by page
        let cfg = mk_cfg(false);
        let (mut cl, mut e, t0) = layout(&cfg);
        let mut t = t0;
        let mut per_block = Histogram::new();
        for blk in 0..blocks {
            let b0 = t;
            for p in blk * 16..blk * 16 + 16 {
                t = e.read(&mut cl, t, p).end;
            }
            per_block.record(t - b0);
        }
        block_mean[0] = per_block.mean();
        kv.push((
            "block_perpage_mean_us".into(),
            per_block.mean() / 1e3,
        ));
        rows.push(vec![
            "64 KB block, 16 single reads".into(),
            fmt_us(per_block.mean()),
            fmt_us(per_block.p99() as f64),
            format!("{:.0}", tput(blocks, t0, t)),
            format!("rdma verbs {}", cl.fabric.verbs_posted(cl.sender)),
        ]);
    }
    for (i, on) in [false, true].into_iter().enumerate() {
        let cfg = mk_cfg(on);
        let (mut cl, mut e, t0) = layout(&cfg);
        let mut t = t0;
        for blk in 0..blocks {
            t = e.read_block(&mut cl, t, blk * 16, 16 * PAGE_SIZE).end;
        }
        let m = e.combined_metrics();
        let tag = if on { "on" } else { "off" };
        if i == 0 {
            block_mean[1] = m.read_latency.mean();
        }
        kv.push((
            format!("block_batched_mean_us_{tag}"),
            m.read_latency.mean() / 1e3,
        ));
        rows.push(vec![
            format!("64 KB block, batched, prefetch {tag}"),
            fmt_us(m.read_latency.mean()),
            fmt_us(m.read_latency.p99() as f64),
            format!("{:.0}", tput(blocks, t0, t)),
            format!(
                "batched {} / coalesced {} / rdma verbs {}",
                m.batched_reads,
                m.coalesced_reads,
                cl.fabric.verbs_posted(cl.sender)
            ),
        ]);
    }
    kv.push((
        "batch_speedup".into(),
        block_mean[0] / block_mean[1].max(1e-9),
    ));

    // (c) random page reads: the no-harm condition ---------------------
    let mut rand_mean = [0.0f64; 2];
    let mut rand_issued = 0u64;
    for (i, on) in [false, true].into_iter().enumerate() {
        let cfg = mk_cfg(on);
        let (mut cl, mut e, t0) = layout(&cfg);
        let mut t = t0;
        let mut x = 0x5DEECE66Du64;
        for _ in 0..file_pages {
            x = x
                .wrapping_mul(6364136223846793005)
                .wrapping_add(1442695040888963407);
            t = e.read(&mut cl, t, (x >> 33) % file_pages).end;
        }
        let m = e.combined_metrics();
        rand_mean[i] = m.read_latency.mean();
        if on {
            rand_issued = m.prefetch_issued;
        }
        let tag = if on { "on" } else { "off" };
        kv.push((
            format!("rand_read_mean_us_{tag}"),
            m.read_latency.mean() / 1e3,
        ));
        rows.push(vec![
            format!("random, prefetch {tag}"),
            fmt_us(m.read_latency.mean()),
            fmt_us(m.read_latency.p99() as f64),
            format!("{:.0}", tput(file_pages, t0, t)),
            format!("prefetch issued {}", m.prefetch_issued),
        ]);
    }
    kv.push((
        "rand_regression_pct".into(),
        100.0 * (rand_mean[1] - rand_mean[0]) / rand_mean[0].max(1e-9),
    ));
    kv.push(("rand_prefetch_issued".into(), rand_issued as f64));

    Report {
        kv,
        id: "prefetch",
        title: "Miss-path read pipeline: batched reads + adaptive stride prefetch",
        header: vec![
            "read pattern",
            "mean µs",
            "p99 µs",
            "ops/sec (virtual)",
            "detail",
        ],
        rows,
        notes: vec![
            format!(
                "{blocks} × 64 KB blocks laid out remotely; pool holds \
                 {pool_pages} pages (~1/8 of the file)"
            ),
            "prefetch off = the pre-pipeline demand miss path \
             (tests/sharding.rs pins it bit-for-bit), so every run \
             carries its own PR-3 baseline"
                .into(),
            "the random rows are the auto-disable guarantee: no \
             majority stride → no readahead issued → no regression"
                .into(),
        ],
    }
}

// ---------------------------------------------------------------------
// Reclaim pipeline — pump-driven concurrent migrations under pressure
// ---------------------------------------------------------------------

/// The asynchronous reclaim pipeline experiment (Fig-23-style pressure
/// waves, beyond the paper): a file is laid out remotely through the
/// write pipeline, then a deterministic 3:1 read/write loop hammers the
/// **hot** half of it while native applications on two peers claim
/// their memory back mid-run (and release it later). Four runs:
///
/// * **no pressure** — the baseline the pipeline must not perturb;
/// * **waves / activity** — `ActivityBased` victims (read-tagged, so
///   the hot units are never picked), concurrent migrations;
/// * **waves / query-random** — `BatchedQueryRandom` victims
///   (Infiniswap-style random choice, paid query RTTs): hot units
///   migrate, their writes park, slot recycling stalls;
/// * **waves / serialized** — `max_concurrent_migrations = 1`, the
///   ablation showing why the migration table runs machines
///   concurrently.
///
/// Headline records: `activity_vs_query_speedup` (> 1: picking idle
/// victims keeps demand traffic fast), `overlap_ratio` (> 0:
/// migrations actually overlap in flight), `no_pressure_regression_pct`
/// (|·| < 5: reclaim overlapped with demand costs ~nothing — the
/// paper's Figure-23 claim) and `serialized_vs_overlapped_speedup`
/// (> 1: the wave drains faster concurrently).
pub fn reclaim(scale: &Scale) -> Report {
    use crate::cluster::ShardedCluster;
    use crate::eviction::BatchedQueryRandom;
    use crate::migration::ctrl_rtt;
    use crate::PAGE_SIZE;

    let blocks: u64 = (scale.records / 40).clamp(256, 768);
    let hot_blocks = blocks / 2;
    let pool_pages = (blocks * 16 / 8).max(256);
    let ops: u64 = (scale.ops / 4).clamp(2_000, 10_000);

    // 256 KB units: many migratable blocks per peer, so a wave demands
    // several victims at once — random victim selection then hits hot
    // units with near-certainty while ActivityBased never does.
    let unit_bytes = 1u64 << 18;
    let mk_cfg = |max_migs: usize| {
        let mut cfg = base_config();
        cfg.cluster.nodes = 5; // sender + 4 peers: ≥2 cold units/peer
        cfg.valet.mr_block_bytes = unit_bytes;
        cfg.valet.min_pool_pages = pool_pages;
        cfg.valet.max_pool_pages = pool_pages;
        cfg.valet.max_concurrent_migrations = max_migs;
        cfg
    };
    // units below this hold hot pages (the traffic loop's target set);
    // round UP so a unit straddling the hot/cold boundary counts as
    // hot — it receives hot writes and must never be wave-targeted
    let hot_unit_limit = (hot_blocks * 16 * PAGE_SIZE).div_ceil(unit_bytes);

    // cold (never-touched-again) units per peer, by primary placement
    let cold_units_of =
        |cl: &ShardedCluster| -> Vec<(crate::NodeId, u64)> {
            let mut per_peer: Vec<(crate::NodeId, u64)> = cl
                .state
                .peers()
                .map(|n| (n, 0u64))
                .collect();
            for (id, u) in cl.engine.sender().units().iter() {
                if u.alive && *id >= hot_unit_limit {
                    if let Some(e) = per_peer
                        .iter_mut()
                        .find(|(n, _)| *n == u.nodes[0])
                    {
                        e.1 += 1;
                    }
                }
            }
            per_peer
        };

    // One measured run: lay the file out, then `ops` operations over
    // the hot half (3 reads : 1 write), with optional pressure waves
    // driven by op index. Returns (virtual ops/s, the cluster).
    let run = |max_migs: usize,
               query_random: bool,
               waves: bool|
     -> (f64, ShardedCluster) {
        let cfg = mk_cfg(max_migs);
        let mut cl = ShardedCluster::new(&cfg, 1);
        if query_random {
            let rtt = ctrl_rtt(&cfg.latency);
            cl.engine.set_victim_policy(Box::new(
                BatchedQueryRandom::new(7, 1, rtt),
            ));
        }
        let mut t: Ns = 0;
        for blk in 0..blocks {
            t = cl.write(t, blk * 16, 16 * PAGE_SIZE).end;
        }
        // 64 units × 62 ms mapping windows serialize on the sender
        // thread: give the layout ample room to drain completely
        t += secs(10);
        cl.advance(t); // layout durable, connections warm
        let t0 = t;
        let mut x = 0x9E37_79B9u64;
        let mut claims: Vec<(crate::NodeId, u64)> = Vec::new();
        for i in 0..ops {
            if i == ops / 4 {
                if waves {
                    // wave: the two peers with the most cold units
                    // demand (cold-1) units back — ActivityBased can
                    // always serve this from idle blocks alone
                    let mut cold = cold_units_of(&cl);
                    cold.sort_by_key(|&(n, c)| {
                        (std::cmp::Reverse(c), n)
                    });
                    for &(peer, cold_units) in cold.iter().take(2) {
                        if cold_units < 2 {
                            continue;
                        }
                        let need = (cold_units - 1) * unit_bytes;
                        let m = &cl.state.monitors[peer];
                        let registered =
                            cl.state.mrpools[peer].registered_bytes();
                        let claim = (m.total_bytes - m.reserve_bytes)
                            .saturating_sub(registered)
                            + need;
                        claims.push((peer, claim));
                        cl.schedule(t, ClusterEvent::NativeAlloc {
                            node: peer,
                            bytes: claim,
                        });
                    }
                }
                // advance in EVERY run at the same op index: the
                // no-pressure baseline must see the identical pump
                // cadence, so the regression record isolates the
                // migrations themselves
                cl.advance(t);
            }
            if i == (3 * ops) / 4 {
                for &(peer, claim) in &claims {
                    cl.schedule(t, ClusterEvent::NativeFree {
                        node: peer,
                        bytes: claim,
                    });
                }
                cl.advance(t);
            }
            x = x
                .wrapping_mul(6364136223846793005)
                .wrapping_add(1442695040888963407);
            let blk = (x >> 33) % hot_blocks;
            let a = if i % 4 == 0 {
                cl.write(t, blk * 16, 16 * PAGE_SIZE)
            } else {
                cl.read(t, blk * 16 + ((x >> 21) % 16))
            };
            t = a.end;
            if i % 16 == 0 {
                cl.advance(t);
            }
        }
        cl.advance(t + secs(5)); // drain every migration + batch
        let tp = ops as f64 / ((t - t0).max(1) as f64 / 1e9);
        (tp, cl)
    };

    let mut rows = Vec::new();
    let mut kv = Vec::new();
    let span = |cl: &ShardedCluster| -> f64 {
        let recs = cl.engine.migration_records();
        if recs.is_empty() {
            return 0.0;
        }
        let first = recs
            .iter()
            .map(|r| r.scheduled)
            .min()
            .expect("recs checked non-empty above");
        let last = recs
            .iter()
            .map(|r| r.done)
            .max()
            .expect("recs checked non-empty above");
        (last - first) as f64
    };

    // (a) no pressure: the path the pipeline must leave unchanged
    let (tp_base, cl_base) = run(4, false, false);
    assert_eq!(cl_base.engine.migration_stats().started, 0);
    rows.push(vec![
        "no pressure".into(),
        format!("{tp_base:.0}"),
        "-".into(),
        "-".into(),
    ]);
    kv.push(("no_pressure_tp".into(), tp_base));

    // (b) waves, activity-based victims, concurrent migrations
    let (tp_act, cl_act) = run(4, false, true);
    let stats = cl_act.engine.migration_stats();
    let durations: f64 = cl_act
        .engine
        .migration_records()
        .iter()
        .map(|r| (r.done - r.activated) as f64)
        .sum();
    let overlap_ratio = if durations > 0.0 {
        stats.overlap_ns as f64 / durations
    } else {
        0.0
    };
    rows.push(vec![
        "waves, activity victims (overlapped)".into(),
        format!("{tp_act:.0}"),
        format!("{} mig / {} del", stats.completed, stats.deleted),
        format!(
            "overlap {:.0}%, parked {} / flushed {}",
            overlap_ratio * 100.0,
            stats.parked_sets,
            stats.flushed_sets
        ),
    ]);
    kv.push(("activity_tp".into(), tp_act));
    kv.push(("overlap_ratio".into(), overlap_ratio));
    kv.push(("migrations_completed".into(), stats.completed as f64));
    kv.push(("parked_sets".into(), stats.parked_sets as f64));
    kv.push(("flushed_sets".into(), stats.flushed_sets as f64));
    kv.push((
        "no_pressure_regression_pct".into(),
        100.0 * (tp_base - tp_act) / tp_base.max(1e-9),
    ));
    let overlapped_span = span(&cl_act);

    // (c) waves, Infiniswap-style random victims (batch=1, paid RTT)
    let (tp_query, cl_query) = run(4, true, true);
    let qstats = cl_query.engine.migration_stats();
    rows.push(vec![
        "waves, query-random victims".into(),
        format!("{tp_query:.0}"),
        format!("{} mig / {} del", qstats.completed, qstats.deleted),
        format!("parked {}", qstats.parked_sets),
    ]);
    kv.push(("query_tp".into(), tp_query));
    kv.push((
        "activity_vs_query_speedup".into(),
        tp_act / tp_query.max(1e-9),
    ));

    // (d) waves, activity victims, serialized migrations (the ablation)
    let (tp_serial, cl_serial) = run(1, false, true);
    let sstats = cl_serial.engine.migration_stats();
    let serial_span = span(&cl_serial);
    rows.push(vec![
        "waves, activity victims (serialized)".into(),
        format!("{tp_serial:.0}"),
        format!("{} mig / {} del", sstats.completed, sstats.deleted),
        format!(
            "overlap {} ns, reclaim span {:.1} ms",
            sstats.overlap_ns,
            serial_span / 1e6
        ),
    ]);
    kv.push(("serialized_tp".into(), tp_serial));
    kv.push(("serialized_overlap_ns".into(), sstats.overlap_ns as f64));
    kv.push((
        "serialized_vs_overlapped_speedup".into(),
        serial_span / overlapped_span.max(1e-9),
    ));
    kv.push(("overlapped_reclaim_span_ms".into(), overlapped_span / 1e6));
    kv.push(("serialized_reclaim_span_ms".into(), serial_span / 1e6));

    Report {
        kv,
        id: "reclaim",
        title: "Asynchronous reclaim pipeline: pressure waves, victim policies, overlapped vs serialized migration",
        header: vec!["run", "ops/sec (virtual)", "migrations", "detail"],
        rows,
        notes: vec![
            format!(
                "{blocks} × 64 KB blocks ({} hot) on 4 peers; pool \
                 holds 1/8 of the file; waves claim (cold-1) units \
                 back on the two coldest peers mid-run",
                hot_blocks
            ),
            "activity victims come from the cold half (read+write \
             tags keep hot units off the list) so demand traffic is \
             untouched; random victims park hot writes behind the \
             migration and stall slot recycling"
                .into(),
            "overlap_ratio > 0 is the concurrency evidence: pairwise \
             in-flight time over summed migration durations (exactly \
             0 when serialized)"
                .into(),
        ],
    }
}

// ---------------------------------------------------------------------
// Three-tier memory — pooled tier, activity promotion, admission control
// ---------------------------------------------------------------------

/// The three-tier memory experiment (beyond the paper; CXL-style pooled
/// tier): a mixed working set — a **warm** quarter written and read
/// back, and a **cold** bulk written once and never read — runs against
/// three configs holding the SAME total remote memory per peer (the
/// flat config folds the pooled slice back into DRAM):
///
/// * **flat (pool off)** — every remote byte is RDMA-remote DRAM; the
///   PR-7 demand path, bit-for-bit (tests/tiering.rs pins it);
/// * **tiered + predictor** — the Pond-style admission predictor keeps
///   the warm (read-inside-window) units in the pooled tier and
///   classifies the cold bulk as latency-insensitive, sending it
///   cold-first to RDMA-remote; the tier pump demotes what leaked in;
/// * **tiered, no predictor** — the ablation: admission is tier-naive,
///   so the warm set starts RDMA-remote and must earn its way into the
///   pool through promotion migrations while the measured loop runs.
///
/// Headline records: `tiered_speedup` (> 1, gated in ci.sh: warm reads
/// at ~NUMA-hop pool latency instead of RDMA READ base latency) and
/// `no_predictor_ablation` (tiered / naive throughput: what admission
/// control buys over promotion-only tiering).
pub fn tiering(scale: &Scale) -> Report {
    use crate::cluster::ShardedCluster;
    use crate::PAGE_SIZE;

    let blocks: u64 = (scale.records / 40).clamp(256, 512);
    let warm_blocks = blocks / 4; // the read-back set
    let ops: u64 = (scale.ops / 4).clamp(2_000, 8_000);
    let unit_bytes = 1u64 << 18; // 4 × 64 KB blocks per unit
    let pool_cap = 4u64 << 20; // per-peer pooled slice
    let dram = 64u64 << 20; // per-peer DRAM under test
    // first demand read of a warm block lags its write by this many
    // blocks — far enough that the page has left the local mempool
    // (so the read is remote and the predictor sees it), near enough
    // to land inside the predictor window
    let lag = 40u64;

    let mk_cfg = |pool_on: bool, predictor: bool| {
        let mut cfg = base_config();
        cfg.cluster.nodes = 5; // sender + 4 peers
        cfg.valet.mr_block_bytes = unit_bytes;
        // local mempool holds 1/4 of the warm pages: most measured
        // reads miss locally and exercise the remote tiers
        let warm_pages = warm_blocks * 16;
        cfg.valet.min_pool_pages = (warm_pages / 4).max(64);
        cfg.valet.max_pool_pages = (warm_pages / 4).max(64);
        // equal total memory: the flat config gets the pooled slice
        // back as DRAM, so no config holds more bytes than another
        cfg.cluster.node_mem_bytes =
            if pool_on { dram } else { dram + pool_cap };
        cfg.valet.pool_tier.enabled = pool_on;
        cfg.valet.pool_tier.capacity_bytes = pool_cap;
        cfg.valet.pool_tier.predictor = predictor;
        // tighten the pump to the experiment's virtual-ms time scale so
        // promotion, demotion and predictor retirement all happen in-run
        cfg.valet.pool_tier.scan_period = ms(5);
        cfg.valet.pool_tier.promote_max_idle = ms(50);
        cfg.valet.pool_tier.demote_after = ms(200);
        cfg.valet.pool_tier.predictor_window = ms(5);
        cfg
    };

    // One run: lay out warm (write + lagged read-back) then cold
    // (write-only bulk), settle a few pump scans, then measure a
    // deterministic random-read loop over the warm set.
    let run = |pool_on: bool, predictor: bool| -> (f64, ShardedCluster) {
        let cfg = mk_cfg(pool_on, predictor);
        let mut cl = ShardedCluster::new(&cfg, 1);
        let mut t: Ns = 0;
        for blk in 0..warm_blocks {
            t = cl.write(t, blk * 16, 16 * PAGE_SIZE).end;
            if blk >= lag {
                t = cl.read(t, (blk - lag) * 16).end;
            }
            if blk % 8 == 0 {
                cl.advance(t);
            }
        }
        for blk in warm_blocks.saturating_sub(lag)..warm_blocks {
            t = cl.read(t, blk * 16).end;
        }
        cl.advance(t);
        for blk in warm_blocks..blocks {
            t = cl.write(t, blk * 16, 16 * PAGE_SIZE).end;
            if blk % 16 == 0 {
                cl.advance(t);
            }
        }
        // short settle — a few tier scans, deliberately NOT long
        // enough for the promotion-only ablation to pull the whole
        // warm set in before the measured loop starts
        t += ms(20);
        cl.advance(t);
        let t0 = t;
        let mut x = 0xD1B5_4A32u64;
        for i in 0..ops {
            x = x
                .wrapping_mul(6364136223846793005)
                .wrapping_add(1442695040888963407);
            let blk = (x >> 33) % warm_blocks;
            t = cl.read(t, blk * 16 + ((x >> 21) % 16)).end;
            if i % 16 == 0 {
                cl.advance(t);
            }
        }
        cl.advance(t + secs(1)); // drain every tier migration
        let tp = ops as f64 / ((t - t0).max(1) as f64 / 1e9);
        (tp, cl)
    };

    let (tp_flat, cl_flat) = run(false, true);
    let (tp_tier, cl_tier) = run(true, true);
    let (tp_naive, cl_naive) = run(true, false);

    // the flat run IS the PR-7 path: no pool verbs, no tier moves
    let m_flat = cl_flat.engine.combined_metrics();
    assert_eq!(m_flat.pool_hits, 0);
    assert_eq!(cl_flat.engine.migration_stats().promotions, 0);

    let m_tier = cl_tier.engine.combined_metrics();
    let s_tier = cl_tier.engine.migration_stats();
    let m_naive = cl_naive.engine.combined_metrics();
    let s_naive = cl_naive.engine.migration_stats();

    let pool_share = |m: &crate::metrics::RunMetrics| {
        100.0 * m.pool_hits as f64 / (m.remote_hits.max(1)) as f64
    };
    let rows = vec![
        vec![
            "flat (pool off)".into(),
            format!("{tp_flat:.0}"),
            "-".into(),
            "every remote read pays the RDMA READ base".into(),
        ],
        vec![
            "tiered + predictor".into(),
            format!("{tp_tier:.0}"),
            format!(
                "{} pool hits ({:.0}% of remote)",
                m_tier.pool_hits,
                pool_share(&m_tier)
            ),
            format!(
                "{} promoted / {} demoted / {} canceled",
                s_tier.promotions, s_tier.demotions, s_tier.tier_canceled
            ),
        ],
        vec![
            "tiered, no predictor".into(),
            format!("{tp_naive:.0}"),
            format!(
                "{} pool hits ({:.0}% of remote)",
                m_naive.pool_hits,
                pool_share(&m_naive)
            ),
            format!(
                "{} promoted / {} demoted / {} canceled",
                s_naive.promotions, s_naive.demotions, s_naive.tier_canceled
            ),
        ],
    ];
    let kv = vec![
        ("flat_tp".into(), tp_flat),
        ("tiered_tp".into(), tp_tier),
        ("no_predictor_tp".into(), tp_naive),
        ("tiered_speedup".into(), tp_tier / tp_flat.max(1e-9)),
        ("no_predictor_ablation".into(), tp_tier / tp_naive.max(1e-9)),
        ("pool_hits".into(), m_tier.pool_hits as f64),
        ("promotions".into(), s_tier.promotions as f64),
        ("demotions".into(), s_tier.demotions as f64),
        ("naive_promotions".into(), s_naive.promotions as f64),
    ];

    Report {
        kv,
        id: "tiering",
        title: "Three-tier memory: pooled tier, activity-driven promotion/demotion, Pond-style admission",
        header: vec!["run", "warm read ops/sec (virtual)", "pool traffic", "tier moves"],
        rows,
        notes: vec![
            format!(
                "{blocks} × 64 KB blocks ({warm_blocks} warm) on 4 \
                 peers; per-peer memory is constant across runs \
                 (flat trades the {} MiB pooled slice for DRAM)",
                pool_cap >> 20
            ),
            "warm units see a demand read inside the predictor \
             window, so admission keeps them in the pool; the cold \
             bulk retires unread and is placed cold-first"
                .into(),
            "the no-predictor run starts the warm set RDMA-remote: \
             promotion migrations recover it, but only at pump \
             cadence — admission control is worth the difference"
                .into(),
        ],
    }
}

// ---------------------------------------------------------------------
// Failure domains — peer crash, failover reads, re-replication, join
// ---------------------------------------------------------------------

/// The churn experiment (beyond the paper; the Table-3 fault-tolerance
/// matrix driven end to end): a YCSB-style wave runs with `replicas = 2`
/// and the failure-domain layer on, a peer is **killed mid-wave**, and
/// the same peer later **rejoins with an empty pool** while traffic
/// continues. Four gated claims:
///
/// * **zero lost acknowledged writes** — after the kill, every page
///   whose write completed is still readable (failover to the
///   surviving replica; disk reads permitted, `lost_writes == 0`);
/// * **bounded recovery** — the re-replication pump restores
///   `replicas` copies for every unit the death thinned, within a
///   virtual-time bound (`recovery_ms`);
/// * **join rebalancing** — the rejoined peer receives migrated units,
///   so the cross-peer load imbalance *improves*
///   (`post_join_balance < pre_join_balance`; 0 = perfectly even);
/// * the whole run holds the full audit law catalog (debug/audit
///   builds enforce at every slow-path crossing).
pub fn churn(scale: &Scale) -> Report {
    use crate::cluster::ShardedCluster;
    use crate::coordinator::sender::Health;
    use crate::PAGE_SIZE;

    let blocks: u64 = (scale.records / 40).clamp(192, 384);
    let ops: u64 = (scale.ops / 4).clamp(2_000, 6_000);

    let mut cfg = base_config();
    cfg.cluster.nodes = 5; // sender + 4 peers
    cfg.valet.mr_block_bytes = 1 << 18; // 4 × 64 KB blocks per unit
    cfg.valet.replicas = 2;
    cfg.valet.disk_backup = false; // survival must come from replicas
    // small local mempool: most reads miss locally, so the wave and the
    // read-back sweep actually exercise remote failover
    let pages = blocks * 16;
    cfg.valet.min_pool_pages = (pages / 8).max(64);
    cfg.valet.max_pool_pages = (pages / 8).max(64);
    cfg.valet.health.enabled = true;
    cfg.valet.health.repair_period = ms(2);
    cfg.valet.health.rebalance_max = 64;

    // Cross-peer load imbalance, 0 = even: (max − min) / max of
    // registered remote bytes over all peers (dead peers count at 0 —
    // an empty rejoined pool is exactly the imbalance rebalancing is
    // supposed to repair).
    let balance = |cl: &ShardedCluster| -> f64 {
        let loads: Vec<u64> = cl
            .state
            .peers()
            .map(|n| cl.state.mrpools[n].registered_bytes())
            .collect();
        let max = loads.iter().copied().max().unwrap_or(0);
        let min = loads.iter().copied().min().unwrap_or(0);
        if max == 0 {
            0.0
        } else {
            (max - min) as f64 / max as f64
        }
    };

    let mut cl = ShardedCluster::new(&cfg, 1);
    let mut t: Ns = 0;
    // Lay down the acknowledged set: every write that returns is acked.
    for blk in 0..blocks {
        t = cl.write(t, blk * 16, 16 * PAGE_SIZE).end;
        if blk % 16 == 0 {
            cl.advance(t);
        }
    }
    cl.advance(t);

    // Kill peer 1 mid-wave; the wave keeps running over it.
    let victim: crate::NodeId = 1;
    let t_kill = t + ms(2);
    cl.schedule(t_kill, ClusterEvent::PeerDown { node: victim });
    let mut x = 0x9E37_79B9u64;
    for i in 0..ops {
        x = x
            .wrapping_mul(6364136223846793005)
            .wrapping_add(1442695040888963407);
        let blk = (x >> 33) % blocks;
        t = cl.read(t, blk * 16 + ((x >> 21) % 16)).end;
        if i % 8 == 0 {
            let wblk = (x >> 13) % blocks;
            t = cl.write(t, wblk * 16, PAGE_SIZE).end;
        }
        if i % 16 == 0 {
            cl.advance(t);
        }
    }
    cl.advance(t.max(t_kill));
    assert_eq!(cl.engine.sender().peer_health(victim), Health::Dead);

    // Recovery clock: virtual time from the kill until the repair
    // backlog and every in-flight machine drain — each damaged unit is
    // back at full copies then.
    let mut tr = t.max(t_kill);
    let mut stalled = 0u32;
    while (cl.engine.sender().repair_backlog() > 0
        || cl.engine.migrations_inflight() > 0)
        && stalled < 5_000
    {
        tr += ms(1);
        cl.advance(tr);
        stalled += 1;
    }
    let recovery_ms = (tr - t_kill) as f64 / 1e6;

    // The dead peer rejoins with an empty pool; rebalancing should
    // migrate units onto it and shrink the imbalance.
    let pre_join = balance(&cl);
    let t_join = tr + ms(2);
    cl.schedule(t_join, ClusterEvent::PeerJoin { node: victim });
    tr = t_join;
    cl.advance(tr);
    let mut stalled = 0u32;
    while cl.engine.migrations_inflight() > 0 && stalled < 5_000 {
        tr += ms(1);
        cl.advance(tr);
        stalled += 1;
    }
    let post_join = balance(&cl);
    assert_eq!(cl.engine.sender().peer_health(victim), Health::Healthy);

    // Read-back sweep: EVERY acknowledged page must still be served —
    // remote, failover or disk, but never lost.
    for blk in 0..blocks {
        for p in 0..16u64 {
            tr = cl.read(tr, blk * 16 + p).end;
        }
        if blk % 16 == 0 {
            cl.advance(tr);
        }
    }
    cl.advance(tr + secs(1));

    let m = cl.engine.combined_metrics();
    let s = cl.engine.migration_stats();
    let lost_writes = m.lost_reads + s.lost_write_sets;

    let rows = vec![
        vec![
            "kill peer 1 mid-wave".into(),
            fmt_ms(t_kill),
            format!("{} units thinned → repair", s.repairs),
            format!("recovered in {recovery_ms:.1} ms (virtual)"),
        ],
        vec![
            "rejoin with empty pool".into(),
            fmt_ms(t_join),
            format!("{} units rebalanced onto it", s.rebalanced),
            format!("imbalance {pre_join:.2} → {post_join:.2}"),
        ],
        vec![
            "read back every acked page".into(),
            fmt_ms(tr),
            format!("{} disk fallbacks permitted", m.disk_reads),
            format!("lost: {lost_writes}"),
        ],
    ];
    let kv = vec![
        ("lost_writes".into(), lost_writes as f64),
        ("lost_reads".into(), m.lost_reads as f64),
        ("lost_write_sets".into(), s.lost_write_sets as f64),
        ("recovery_ms".into(), recovery_ms),
        ("repairs".into(), s.repairs as f64),
        ("rebalanced".into(), s.rebalanced as f64),
        ("pre_join_balance".into(), pre_join),
        ("post_join_balance".into(), post_join),
        (
            "no_candidate_dead_peers".into(),
            s.no_candidate_dead_peers as f64,
        ),
        ("disk_reads".into(), m.disk_reads as f64),
    ];

    Report {
        kv,
        id: "churn",
        title: "Failure domains: peer crash, failover reads, re-replication, live join",
        header: vec!["event", "t (ms)", "failure-domain work", "outcome"],
        rows,
        notes: vec![
            format!(
                "{blocks} × 64 KB blocks, replicas=2, disk backup OFF \
                 on 4 peers; {ops} mixed ops ride over the crash"
            ),
            "zero lost acknowledged writes: every page written before \
             or after the crash reads back from a surviving replica \
             (the kill wipes one copy; the other serves, and the pump \
             restores the second)"
                .into(),
            "recovery is bounded virtual time, not best-effort: the \
             gate in ci.sh fails the build if the pump leaves backlog"
                .into(),
        ],
    }
}

/// All experiments, in presentation order.
pub fn all_ids() -> Vec<&'static str> {
    vec![
        "table1", "fig2", "fig3", "fig5", "fig8", "fig9", "fig10",
        "bigdata", "ml", "fig21", "table7", "fig22", "fig23",
        "ablations", "scaling", "prefetch", "reclaim", "tiering",
        "churn",
    ]
}

/// Run one experiment by id.
pub fn run(id: &str, scale: &Scale) -> Option<Report> {
    Some(match id {
        "table1" => table1(scale),
        "fig2" => fig2(scale),
        "fig3" => fig3(scale),
        "fig5" => fig5(scale),
        "fig8" => fig8(scale),
        "fig9" => fig9(scale),
        "fig10" => fig10(scale),
        "bigdata" => bigdata(scale),
        "ml" => ml(scale),
        "fig21" => fig21(scale),
        "table7" => table7(scale),
        "fig22" => fig22(scale),
        "fig23" => fig23(scale),
        "ablations" => ablations(scale),
        "scaling" => scaling(scale),
        "prefetch" => prefetch(scale),
        "reclaim" => reclaim(scale),
        "tiering" => tiering(scale),
        "churn" => churn(scale),
        _ => return None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_id_runs_at_small_scale() {
        // smoke: table1 + the two cheapest figures (full set runs in the
        // valet-bench binary / integration tests)
        let scale = Scale::small();
        for id in ["fig2", "fig9"] {
            let r = run(id, &scale).unwrap();
            assert!(!r.rows.is_empty(), "{id}");
            assert!(!r.render().is_empty());
        }
        assert!(run("nope", &scale).is_none());
    }

    #[test]
    fn report_csv_has_header_and_rows() {
        let r = fig2(&Scale::small());
        let csv = r.to_csv();
        assert!(csv.lines().count() > 2);
        assert!(csv.starts_with("t (min)"));
    }
}
