//! Wall-clock micro-benchmark helper (the offline build has no criterion;
//! this provides the same measure-loop-report workflow for the hot-path
//! benches and the §Perf iteration log).

use std::time::Instant;

/// Result of one micro-benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    /// Name.
    pub name: String,
    /// Iterations measured.
    pub iters: u64,
    /// Nanoseconds per iteration (median of 5 samples).
    pub ns_per_iter: f64,
}

impl BenchResult {
    /// "name: 123.4 ns/iter (x iters)".
    pub fn render(&self) -> String {
        format!(
            "{:<40} {:>12.1} ns/iter   ({} iters)",
            self.name, self.ns_per_iter, self.iters
        )
    }
}

/// Run `f` in a measured loop: warm up, then 5 samples of `iters`
/// iterations; report the median sample. `f` should include a
/// `std::hint::black_box` on its result.
pub fn bench(name: &str, iters: u64, mut f: impl FnMut()) -> BenchResult {
    // warmup
    for _ in 0..iters / 10 + 1 {
        f();
    }
    let mut samples = Vec::with_capacity(5);
    for _ in 0..5 {
        let t0 = Instant::now();
        for _ in 0..iters {
            f();
        }
        samples.push(t0.elapsed().as_nanos() as f64 / iters as f64);
    }
    samples.sort_by(|a, b| {
        a.partial_cmp(b)
            .expect("elapsed-time samples are never NaN")
    });
    BenchResult {
        name: name.to_string(),
        iters,
        ns_per_iter: samples[2],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("noop-ish", 10_000, || {
            std::hint::black_box(1 + 1);
        });
        assert!(r.ns_per_iter >= 0.0);
        assert!(r.ns_per_iter < 1_000_000.0);
        assert!(r.render().contains("noop-ish"));
    }
}
