//! Deterministic simulation substrate: a virtual clock in nanoseconds,
//! FIFO resource servers (queueing-model building block for NICs, disks,
//! sender threads and remote CPUs) and a typed event queue for scheduled
//! state changes (evictions, memory-pressure phases, mempool resizes).
//!
//! Why this shape: every figure in the paper is an aggregate over the
//! *latency composition* of a paging pipeline. Modeling each shared
//! resource as a FIFO server with a `next_free` timestamp reproduces the
//! queueing effects that drive those figures (nbdX message-pool
//! exhaustion, disk convoys during Infiniswap connection windows, staging
//! backpressure on the Valet mempool) while keeping the simulator
//! single-threaded, allocation-free on the hot path, and bit-for-bit
//! deterministic under a fixed seed.

mod engine;
mod server;

pub use engine::EventQueue;
pub use server::Server;

/// Virtual time in nanoseconds since simulation start.
pub type Ns = u64;

/// Microseconds → ns.
pub const fn us(v: u64) -> Ns {
    v * 1_000
}

/// Milliseconds → ns.
pub const fn ms(v: u64) -> Ns {
    v * 1_000_000
}

/// Seconds → ns.
pub const fn secs(v: u64) -> Ns {
    v * 1_000_000_000
}

/// Fractional microseconds → ns (for paper-calibrated constants like
/// 51.35 µs).
pub fn us_f(v: f64) -> Ns {
    (v * 1_000.0).round() as Ns
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unit_helpers() {
        assert_eq!(us(51), 51_000);
        assert_eq!(ms(200), 200_000_000);
        assert_eq!(secs(2), 2_000_000_000);
        assert_eq!(us_f(51.35), 51_350);
        assert_eq!(us_f(0.14), 140);
    }
}
