//! FIFO resource server: the single queueing primitive of the simulator.

use super::Ns;

/// A work-conserving FIFO server. `serve(now, dur)` reserves the resource
/// for `dur` ns starting no earlier than `now` and no earlier than the
/// completion of previously accepted work, returning the (start, end)
/// interval. This is exactly an M/G/1-style single server; chains of
/// `serve` calls across servers model a pipeline.
#[derive(Clone, Debug, Default)]
pub struct Server {
    next_free: Ns,
}

impl Server {
    /// A server that is free immediately.
    pub fn new() -> Self {
        Server { next_free: 0 }
    }

    /// When the server will next be idle.
    pub fn busy_until(&self) -> Ns {
        self.next_free
    }

    /// Queue length expressed as time: how long a job arriving at `now`
    /// would wait before starting.
    pub fn backlog(&self, now: Ns) -> Ns {
        self.next_free.saturating_sub(now)
    }

    /// Reserve `dur` ns; returns (start, end).
    pub fn serve(&mut self, now: Ns, dur: Ns) -> (Ns, Ns) {
        let start = self.next_free.max(now);
        let end = start + dur;
        self.next_free = end;
        (start, end)
    }

    /// Reserve only if the wait would not exceed `max_wait`; returns
    /// `Some((start, end))` or `None` (used for bounded message pools —
    /// nbdX rejects/stalls when its pool is exhausted).
    pub fn try_serve(
        &mut self,
        now: Ns,
        dur: Ns,
        max_wait: Ns,
    ) -> Option<(Ns, Ns)> {
        if self.backlog(now) > max_wait {
            None
        } else {
            Some(self.serve(now, dur))
        }
    }

    /// Fast-forward an idle server (e.g. after a simulated reset).
    pub fn reset_to(&mut self, t: Ns) {
        self.next_free = self.next_free.max(t);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn idle_server_starts_immediately() {
        let mut s = Server::new();
        assert_eq!(s.serve(100, 50), (100, 150));
    }

    #[test]
    fn busy_server_queues_fifo() {
        let mut s = Server::new();
        s.serve(0, 100);
        assert_eq!(s.serve(10, 5), (100, 105));
        assert_eq!(s.serve(10, 5), (105, 110));
    }

    #[test]
    fn backlog_reflects_queue() {
        let mut s = Server::new();
        s.serve(0, 100);
        assert_eq!(s.backlog(30), 70);
        assert_eq!(s.backlog(200), 0);
    }

    #[test]
    fn try_serve_rejects_when_backlogged() {
        let mut s = Server::new();
        s.serve(0, 1000);
        assert!(s.try_serve(0, 10, 500).is_none());
        assert!(s.try_serve(0, 10, 1500).is_some());
    }

    #[test]
    fn server_time_never_goes_backwards() {
        let mut s = Server::new();
        let (_, e1) = s.serve(50, 10);
        let (s2, _) = s.serve(0, 10); // arrives "earlier" but queues after
        assert!(s2 >= e1);
    }
}
