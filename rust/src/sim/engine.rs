//! Typed event queue: schedules state-change events (remote memory
//! pressure, eviction triggers, mempool resize checks, migration
//! completions) in virtual time. Stable FIFO order among simultaneous
//! events (insertion sequence breaks ties) keeps runs deterministic.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use super::Ns;

/// A min-heap of (time, seq, event).
#[derive(Debug)]
pub struct EventQueue<E> {
    heap: BinaryHeap<Reverse<(Ns, u64, EventBox<E>)>>,
    seq: u64,
}

// Wrapper so E doesn't need Ord — ordering ignores the payload.
#[derive(Debug)]
struct EventBox<E>(E);

impl<E> PartialEq for EventBox<E> {
    fn eq(&self, _: &Self) -> bool {
        true
    }
}
impl<E> Eq for EventBox<E> {}
impl<E> PartialOrd for EventBox<E> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for EventBox<E> {
    fn cmp(&self, _: &Self) -> std::cmp::Ordering {
        std::cmp::Ordering::Equal
    }
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    /// Empty queue.
    pub fn new() -> Self {
        EventQueue {
            heap: BinaryHeap::new(),
            seq: 0,
        }
    }

    /// Schedule `ev` at time `at`.
    pub fn push(&mut self, at: Ns, ev: E) {
        self.heap.push(Reverse((at, self.seq, EventBox(ev))));
        self.seq += 1;
    }

    /// Time of the next event, if any.
    pub fn peek_time(&self) -> Option<Ns> {
        self.heap.peek().map(|Reverse((t, _, _))| *t)
    }

    /// Pop the next event if it fires at or before `now`.
    pub fn pop_due(&mut self, now: Ns) -> Option<(Ns, E)> {
        match self.peek_time() {
            Some(t) if t <= now => {
                let Reverse((t, _, EventBox(e))) = self
                    .heap
                    .pop()
                    .expect("peek_time just saw a queued event");
                Some((t, e))
            }
            _ => None,
        }
    }

    /// Pop the earliest event regardless of time.
    pub fn pop(&mut self) -> Option<(Ns, E)> {
        self.heap.pop().map(|Reverse((t, _, EventBox(e)))| (t, e))
    }

    /// Number of pending events.
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// True if no events are pending.
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(30, "c");
        q.push(10, "a");
        q.push(20, "b");
        assert_eq!(q.pop(), Some((10, "a")));
        assert_eq!(q.pop(), Some((20, "b")));
        assert_eq!(q.pop(), Some((30, "c")));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn simultaneous_events_preserve_insertion_order() {
        let mut q = EventQueue::new();
        q.push(5, 1);
        q.push(5, 2);
        q.push(5, 3);
        assert_eq!(q.pop(), Some((5, 1)));
        assert_eq!(q.pop(), Some((5, 2)));
        assert_eq!(q.pop(), Some((5, 3)));
    }

    #[test]
    fn pop_due_respects_now() {
        let mut q = EventQueue::new();
        q.push(100, "later");
        q.push(10, "now");
        assert_eq!(q.pop_due(50), Some((10, "now")));
        assert_eq!(q.pop_due(50), None);
        assert_eq!(q.len(), 1);
        assert_eq!(q.pop_due(100), Some((100, "later")));
        assert!(q.is_empty());
    }
}
