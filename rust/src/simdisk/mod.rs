//! Disk model: a FIFO device with seek + per-byte transfer costs
//! (defaults model the paper's 7.2k SATA HDD testbed). Used by the
//! linux_swap baseline, Infiniswap's redirect-to-disk windows and Valet's
//! optional disk-backup path.

use crate::config::LatencyConfig;
use crate::sim::{Ns, Server};

/// A single disk (one per node).
#[derive(Clone, Debug)]
pub struct Disk {
    queue: Server,
    seek: Ns,
    per_byte: f64,
    /// Total I/Os served (stats).
    pub ios: u64,
    /// Total bytes moved (stats).
    pub bytes: u64,
}

impl Disk {
    /// Build from the latency model.
    pub fn new(lat: &LatencyConfig) -> Self {
        Disk {
            queue: Server::new(),
            seek: lat.disk_seek,
            per_byte: lat.disk_per_byte,
            ios: 0,
            bytes: 0,
        }
    }

    /// Service time for one I/O of `bytes` (no queueing).
    pub fn service_time(&self, bytes: u64) -> Ns {
        self.seek + (self.per_byte * bytes as f64) as Ns
    }

    /// Submit a synchronous read; returns completion time (queueing
    /// included — a busy disk convoys requests, which is exactly the
    /// effect behind the paper's Table 1 disk numbers).
    pub fn read(&mut self, now: Ns, bytes: u64) -> Ns {
        self.io(now, bytes)
    }

    /// Submit a synchronous write.
    pub fn write(&mut self, now: Ns, bytes: u64) -> Ns {
        self.io(now, bytes)
    }

    /// Submit an asynchronous background write (Valet disk backup;
    /// Infiniswap's async flush). Modeled as low-priority writeback that
    /// yields to foreground I/O: it does NOT occupy the FIFO that reads
    /// and synchronous writes queue on (kernel writeback runs at idle
    /// priority), so it only counts toward stats. Returns a durability
    /// estimate of now + one service time.
    pub fn write_async(&mut self, now: Ns, bytes: u64) -> Ns {
        self.ios += 1;
        self.bytes += bytes;
        now + self.service_time(bytes)
    }

    fn io(&mut self, now: Ns, bytes: u64) -> Ns {
        let dur = self.service_time(bytes);
        let (_, end) = self.queue.serve(now, dur);
        self.ios += 1;
        self.bytes += bytes;
        end
    }

    /// Pending work, as time.
    pub fn backlog(&self, now: Ns) -> Ns {
        self.queue.backlog(now)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn disk() -> Disk {
        Disk::new(&LatencyConfig::default())
    }

    #[test]
    fn service_time_has_seek_and_transfer() {
        let d = disk();
        let t4k = d.service_time(4096);
        let t128k = d.service_time(128 * 1024);
        assert!(t4k >= 8_000_000); // >= seek
        assert!(t128k > t4k);
        // transfer component ≈ bytes * 10ns
        assert_eq!(t128k - t4k, (10.0 * (128 * 1024 - 4096) as f64) as u64);
    }

    #[test]
    fn disk_queues_fifo() {
        let mut d = disk();
        let a = d.write(0, 4096);
        let b = d.write(0, 4096);
        assert_eq!(b - a, d.service_time(4096));
        assert_eq!(d.ios, 2);
    }

    #[test]
    fn convoy_effect_grows_latency() {
        // 50 writes burst-arriving at t=0: the last one waits ~50 service
        // times — the Table 1 "Disk WR 401 ms" convoy in miniature.
        let mut d = disk();
        let mut last = 0;
        for _ in 0..50 {
            last = d.write(0, 64 * 1024);
        }
        assert!(last >= 50 * d.service_time(64 * 1024));
    }

    #[test]
    fn backlog_drains_with_time() {
        let mut d = disk();
        d.write(0, 4096);
        assert!(d.backlog(0) > 0);
        assert_eq!(d.backlog(d.service_time(4096)), 0);
    }
}
