//! Multi-tenant host memory arbitration (§3, Figure 5): the
//! host-coordinated mempool budget shared across containers.
//!
//! Valet's second contribution "utilizes unused local memory across
//! containers by managing local memory via Valet host-coordinated memory
//! pool, which allows containers to dynamically expand and shrink their
//! memory allocations according to the workload demands". PR 1's
//! [`crate::coordinator::Coordinator`] served exactly one tenant; this
//! module arbitrates the shared host pool between several of them:
//!
//! * [`HostArbiter`] — the pure ledger. It owns the host pool budget (in
//!   pages) and leases it to N tenants with weighted shares. A tenant
//!   under paging pressure borrows idle pages from under-utilized peers
//!   (demand-driven grow); when host free memory drops, the budget
//!   shrinks and leases are reclaimed from the most over-share tenant
//!   first (pressure-driven shrink) — the host-side mirror of the
//!   least-active-chunk idea the coordinator applies remotely.
//! * [`TenantGroup`] — the wiring. One [`crate::coordinator::Coordinator`]
//!   per container, all sharing one [`ClusterState`] substrate, with the
//!   arbiter's leases driving each coordinator's mempool cap (see
//!   [`crate::mempool::Mempool::set_lease`]) and its give-back path
//!   (see [`crate::mempool::Mempool::donate_idle`]).
//!
//! The arbiter is a ledger, not a page allocator: leases bound what each
//! tenant's mempool may grow to, and a lowered lease is enforced
//! gradually by the tenant's next pumps (free-slot shrink first, then
//! donation of idle remote-durable pages). The invariant it maintains is
//! `Σ leases ≤ budget` whenever the budget covers every tenant's
//! `min_pages` floor; floors win when it does not, exactly like the
//! single-tenant mempool's `min_pool_pages` floor.

use std::cmp::Reverse;

use crate::audit::{self, Law, Violation};
use crate::backends::{Access, ClusterState, PressureOutcome};
use crate::config::Config;
use crate::coordinator::Coordinator;
use crate::metrics::RunMetrics;
use crate::sim::Ns;
use crate::{NodeId, PAGE_SIZE};

/// Identifier of a tenant (0-based, dense — the registration order).
pub type TenantId = usize;

/// Owner tag the group assigns to tenant `i`'s MR registrations:
/// `TENANT_OWNER_BASE + i`. Far above any real [`NodeId`], so a tenant's
/// blocks are distinguishable from single-tenant registrations (which use
/// the sender's node id) and from other tenants'.
pub const TENANT_OWNER_BASE: NodeId = 1 << 24;

/// Static description of one tenant: its weight in the fair-share split
/// and its guaranteed mempool floor.
#[derive(Clone, Copy, Debug)]
pub struct TenantSpec {
    /// Relative share weight (fair share = `budget × weight / Σ weights`).
    pub weight: u64,
    /// Guaranteed minimum lease in pages (the tenant's `min_pool_pages`
    /// floor; neither borrowing nor host pressure moves its lease below
    /// this).
    pub min_pages: u64,
}

impl Default for TenantSpec {
    fn default() -> Self {
        TenantSpec {
            weight: 1,
            min_pages: 64,
        }
    }
}

/// A point-in-time load snapshot of one tenant's mempool, fed to
/// [`HostArbiter::rebalance`] each pump.
#[derive(Clone, Copy, Debug, Default)]
pub struct TenantLoad {
    /// Pages currently resident in the tenant's mempool.
    pub used_pages: u64,
    /// Resident pages that are NOT yet remote-durable — they cannot be
    /// donated back to the host pool, so donors must keep a lease floor
    /// above them.
    pub pinned_pages: u64,
    /// Allocation backpressure events (mempool exhausted, caller stalled)
    /// since the last rebalance — the strongest demand signal.
    pub stalled_allocs: u64,
    /// Successful allocations since the last rebalance — distinguishes a
    /// tenant actively growing into its lease from one merely sitting on
    /// a full cache.
    pub recent_allocs: u64,
}

impl TenantLoad {
    /// True when this snapshot signals demand for more lease: the tenant
    /// stalled, or it is actively allocating with usage at or past the
    /// mempool's grow threshold (80 % of its lease).
    fn demanding(&self, lease: u64) -> bool {
        self.stalled_allocs > 0
            || (self.recent_allocs > 0
                && self.used_pages.saturating_mul(5) >= lease.saturating_mul(4))
    }
}

/// Split a page budget (an arbiter lease, a mempool floor/cap, or a host
/// free-memory share) evenly across `parts` shards, distributing the
/// remainder to the lowest-indexed shards so `Σ parts == total` exactly.
/// The unleased sentinel `u64::MAX` splits into all-`u64::MAX`: an
/// unleased tenant's shards are each unleased too, not capped at
/// `MAX / parts`. This is how the [`crate::engine::ShardedEngine`] fans a
/// single-tenant budget out to its per-shard mempools.
pub fn split_pages(total: u64, parts: usize) -> Vec<u64> {
    (0..parts.max(1)).map(|i| share_of(total, parts, i)).collect()
}

/// One shard's slice of [`split_pages`] without allocating the vector —
/// the form the serve hot path uses while holding the shared lock.
pub fn share_of(total: u64, parts: usize, idx: usize) -> u64 {
    let parts = parts.max(1) as u64;
    if total == u64::MAX {
        return u64::MAX;
    }
    total / parts + u64::from((idx as u64) < total % parts)
}

/// Per-tenant ledger entry.
#[derive(Clone, Copy, Debug)]
struct Share {
    weight: u64,
    min_pages: u64,
    lease: u64,
}

/// The host-coordinated pool ledger: budget + weighted leases.
///
/// Pure bookkeeping (no coordinator references), so policies are unit-
/// testable: see the weighted-share convergence and give-back ordering
/// tests in `tests/arbiter.rs`.
#[derive(Clone, Debug)]
pub struct HostArbiter {
    budget: u64,
    shares: Vec<Share>,
    /// Lease grants made to demanding tenants (stats).
    pub grants: u64,
    /// Lease reclaims (fairness claw-backs + host-pressure cuts) (stats).
    pub reclaims: u64,
}

impl HostArbiter {
    /// Ledger over a host pool of `budget_pages`.
    pub fn new(budget_pages: u64) -> Self {
        HostArbiter {
            budget: budget_pages.max(1),
            shares: Vec::new(),
            grants: 0,
            reclaims: 0,
        }
    }

    /// Register a tenant and reset every lease to its fair share, then
    /// trim back under the budget (a floored fair share can push the
    /// raw sum over it — see [`Self::fair_share`]). Registration
    /// happens at group construction, before any rebalancing.
    pub fn register(&mut self, spec: TenantSpec) -> TenantId {
        self.shares.push(Share {
            weight: spec.weight.max(1),
            min_pages: spec.min_pages.max(1),
            lease: 0,
        });
        for i in 0..self.shares.len() {
            self.shares[i].lease = self.fair_share(i);
        }
        self.enforce_budget();
        self.shares.len() - 1
    }

    /// Number of registered tenants.
    pub fn tenants(&self) -> usize {
        self.shares.len()
    }

    /// Current host pool budget in pages.
    pub fn budget_pages(&self) -> u64 {
        self.budget
    }

    /// Tenant's current lease in pages.
    pub fn lease(&self, t: TenantId) -> u64 {
        self.shares[t].lease
    }

    /// All leases, tenant order.
    pub fn leases(&self) -> Vec<u64> {
        self.shares.iter().map(|s| s.lease).collect()
    }

    /// Sum of all leases.
    pub fn leased_total(&self) -> u64 {
        self.shares.iter().map(|s| s.lease).sum()
    }

    /// Tenant's weighted fair share of the current budget, never below
    /// its `min_pages` floor.
    pub fn fair_share(&self, t: TenantId) -> u64 {
        let total_w: u64 = self.shares.iter().map(|s| s.weight).sum();
        let w = self.shares[t].weight;
        let share = ((self.budget as u128 * w as u128) / total_w.max(1) as u128)
            as u64;
        share.max(self.shares[t].min_pages)
    }

    /// The tenant (other than `except`) holding the largest lease above
    /// its fair share — the first to give back.
    fn most_over_share(&self, except: TenantId) -> Option<TenantId> {
        (0..self.shares.len())
            .filter(|&j| j != except)
            .filter(|&j| self.shares[j].lease > self.fair_share(j))
            .max_by_key(|&j| {
                (self.shares[j].lease - self.fair_share(j), Reverse(j))
            })
    }

    /// Pages tenant `j` can donate right now: lease minus what it must
    /// hold (its floor, its pinned pages, and a slack of 1/8 of its lease
    /// so donors are not drained to the bone in one round).
    fn spare(&self, j: TenantId, load: &TenantLoad) -> u64 {
        let s = &self.shares[j];
        let keep = (s.lease / 8).max(32);
        let hold = s.min_pages.max(load.pinned_pages).saturating_add(keep);
        s.lease.saturating_sub(hold)
    }

    /// One arbitration round against a load snapshot (one entry per
    /// tenant, registration order). Two passes:
    ///
    /// 1. **Fairness** — a demanding tenant below its fair share claws
    ///    lease back from tenants above theirs, most over-share first.
    ///    Under sustained contention leases therefore converge to the
    ///    weighted split.
    /// 2. **Idle borrowing** — remaining demand is served from the
    ///    unleased budget, then from cold peers' spare headroom (again
    ///    most over-share donors first).
    ///
    /// Returns the new leases.
    pub fn rebalance(&mut self, loads: &[TenantLoad]) -> Vec<u64> {
        assert_eq!(loads.len(), self.shares.len(), "one load per tenant");
        let n = self.shares.len();
        let demanding: Vec<bool> = (0..n)
            .map(|i| loads[i].demanding(self.shares[i].lease))
            .collect();
        let mut want: Vec<u64> = (0..n)
            .map(|i| {
                if demanding[i] {
                    (self.shares[i].lease / 4).max(64)
                } else {
                    0
                }
            })
            .collect();

        // Pass 1: fairness claw-back.
        for i in 0..n {
            if want[i] == 0 {
                continue;
            }
            let fair_i = self.fair_share(i);
            while self.shares[i].lease < fair_i && want[i] > 0 {
                let need = (fair_i - self.shares[i].lease).min(want[i]);
                let Some(j) = self.most_over_share(i) else { break };
                let over_j = self.shares[j].lease - self.fair_share(j);
                let take = need.min(over_j);
                if take == 0 {
                    break;
                }
                self.shares[j].lease -= take;
                self.shares[i].lease += take;
                want[i] -= take;
                self.reclaims += 1;
            }
        }

        // Pass 2: unleased budget, then idle donors.
        for i in 0..n {
            while want[i] > 0 {
                let unleased = self.budget.saturating_sub(self.leased_total());
                if unleased > 0 {
                    let take = want[i].min(unleased);
                    self.shares[i].lease += take;
                    want[i] -= take;
                    self.grants += 1;
                    continue;
                }
                // Donors are tenants that were cold this round — a
                // demanding tenant whose want was satisfied in pass 1
                // must not be drained right back.
                let donor = (0..n)
                    .filter(|&j| j != i && !demanding[j])
                    .map(|j| (j, self.spare(j, &loads[j])))
                    .filter(|&(_, sp)| sp > 0)
                    .max_by_key(|&(j, _)| {
                        (
                            self.shares[j]
                                .lease
                                .saturating_sub(self.fair_share(j)),
                            Reverse(j),
                        )
                    });
                let Some((j, sp)) = donor else { break };
                let take = want[i].min(sp);
                self.shares[j].lease -= take;
                self.shares[i].lease += take;
                want[i] -= take;
                self.grants += 1;
            }
        }
        self.leases()
    }

    /// Host free memory changed: set the new budget and, if leases now
    /// exceed it, reclaim — most over-share tenant first (down to fair
    /// shares), then largest leases down toward their `min_pages`
    /// floors. Floors are never violated, so an overcommitted budget
    /// leaves `Σ leases > budget` (mirroring the mempool's own
    /// never-below-min rule). Returns the new leases.
    pub fn set_budget(&mut self, budget_pages: u64) -> Vec<u64> {
        self.budget = budget_pages.max(1);
        self.enforce_budget();
        self.leases()
    }

    /// Reclaim leases until `Σ leases ≤ budget` (or every tenant sits
    /// on its floor): most over-share first down to fair shares, then
    /// largest leases down toward `min_pages`.
    fn enforce_budget(&mut self) {
        let n = self.shares.len();
        // Phase 1: cut over-share tenants down to their fair shares.
        loop {
            let excess = self.leased_total().saturating_sub(self.budget);
            if excess == 0 {
                break;
            }
            let over = (0..n)
                .filter(|&j| self.shares[j].lease > self.fair_share(j))
                .max_by_key(|&j| {
                    (self.shares[j].lease - self.fair_share(j), Reverse(j))
                });
            let Some(j) = over else { break };
            let cut =
                excess.min(self.shares[j].lease - self.fair_share(j));
            self.shares[j].lease -= cut;
            self.reclaims += 1;
        }
        // Phase 2: still over (min floors / rounding): cut the largest
        // leases toward their floors.
        loop {
            let excess = self.leased_total().saturating_sub(self.budget);
            if excess == 0 {
                break;
            }
            let big = (0..n)
                .filter(|&j| self.shares[j].lease > self.shares[j].min_pages)
                .max_by_key(|&j| (self.shares[j].lease, Reverse(j)));
            let Some(j) = big else { break };
            let cut =
                excess.min(self.shares[j].lease - self.shares[j].min_pages);
            self.shares[j].lease -= cut;
            self.reclaims += 1;
        }
    }

    // -- the invariant auditor ----------------------------------------

    /// Audit the ledger ([`Law::ArbiterLedger`]): no lease below its
    /// tenant's `min_pages` floor, and `Σ leases ≤ budget` — except in
    /// the documented overcommit regime, where the budget cannot cover
    /// the floors and every lease must then sit exactly AT its floor
    /// (floors win; anything above one while overcommitted is a leak).
    pub fn audit_check(&self) -> Vec<Violation> {
        let mut out = Vec::new();
        let snap = || {
            format!(
                "budget={} leases={:?} floors={:?}",
                self.budget,
                self.leases(),
                self.shares
                    .iter()
                    .map(|s| s.min_pages)
                    .collect::<Vec<_>>()
            )
        };
        for (t, s) in self.shares.iter().enumerate() {
            audit::check(
                &mut out,
                s.lease >= s.min_pages,
                Law::ArbiterLedger,
                None,
                || {
                    format!(
                        "tenant {t} leased {} pages, below its floor {}",
                        s.lease, s.min_pages
                    )
                },
                snap,
            );
        }
        let total = self.leased_total();
        let at_floors =
            self.shares.iter().all(|s| s.lease == s.min_pages);
        audit::check(
            &mut out,
            total <= self.budget || at_floors,
            Law::ArbiterLedger,
            None,
            || {
                format!(
                    "Σ leases = {total} exceeds budget {} with some \
                     tenant above its floor",
                    self.budget
                )
            },
            snap,
        );
        out
    }

    /// Test-only corruption hook for [`Law::ArbiterLedger`]: overwrite
    /// one tenant's lease directly, bypassing the rebalance/budget
    /// machinery.
    #[cfg(any(feature = "audit", debug_assertions))]
    #[doc(hidden)]
    pub fn audit_set_lease(&mut self, t: TenantId, pages: u64) {
        self.shares[t].lease = pages;
    }
}

/// N per-container coordinators behind one arbiter, sharing one
/// simulated substrate — the multi-tenant analogue of a single
/// [`Coordinator`].
///
/// Page spaces are per-tenant (each coordinator owns its own GPT and
/// unit map); MR registrations carry a per-tenant owner tag so victim
/// selection under remote pressure never evicts another tenant's blocks.
pub struct TenantGroup {
    arbiter: HostArbiter,
    coords: Vec<Coordinator>,
    stall_base: Vec<u64>,
    alloc_base: Vec<u64>,
    host_free_pages: u64,
    host_free_fraction: f64,
    max_budget_pages: u64,
}

impl TenantGroup {
    /// Build one coordinator per spec. The host pool budget is
    /// `min(max_pool_pages, host_free_fraction × initial host free)` —
    /// the same effective cap a single-tenant coordinator starts under —
    /// and each tenant's mempool floor comes from its spec.
    pub fn new(cfg: &Config, specs: &[TenantSpec]) -> Self {
        assert!(!specs.is_empty(), "at least one tenant");
        let host_free0 = (cfg.cluster.node_mem_bytes / PAGE_SIZE) / 2;
        let frac_cap =
            (host_free0 as f64 * cfg.valet.host_free_fraction) as u64;
        let budget = cfg.valet.max_pool_pages.min(frac_cap).max(1);
        let mut arbiter = HostArbiter::new(budget);
        let mut coords = Vec::with_capacity(specs.len());
        for (i, spec) in specs.iter().enumerate() {
            let _id = arbiter.register(*spec);
            debug_assert_eq!(_id, i);
            let mut tcfg = cfg.clone();
            tcfg.valet.min_pool_pages = spec.min_pages.max(1);
            tcfg.valet.max_pool_pages = budget.max(spec.min_pages.max(1));
            coords.push(
                Coordinator::new(&tcfg)
                    .with_owner_tag(TENANT_OWNER_BASE + i),
            );
        }
        let leases = arbiter.leases();
        for (co, &l) in coords.iter_mut().zip(leases.iter()) {
            co.set_lease_pages(l);
        }
        TenantGroup {
            arbiter,
            coords,
            stall_base: vec![0; specs.len()],
            alloc_base: vec![0; specs.len()],
            host_free_pages: host_free0,
            host_free_fraction: cfg.valet.host_free_fraction,
            max_budget_pages: cfg.valet.max_pool_pages.max(1),
        }
    }

    /// Number of tenants.
    pub fn tenants(&self) -> usize {
        self.coords.len()
    }

    /// The arbiter ledger (leases, budget, grant/reclaim stats).
    pub fn arbiter(&self) -> &HostArbiter {
        &self.arbiter
    }

    /// Tenant's coordinator (metrics, mempool diagnostics).
    pub fn coordinator(&self, t: TenantId) -> &Coordinator {
        &self.coords[t]
    }

    /// Mutable access to a tenant's coordinator (policy hooks).
    pub fn coordinator_mut(&mut self, t: TenantId) -> &mut Coordinator {
        &mut self.coords[t]
    }

    /// Host free pages last reported via [`Self::host_pressure`].
    pub fn host_free_pages(&self) -> u64 {
        self.host_free_pages
    }

    /// Merged run metrics across all tenants (combined hit split etc.).
    pub fn combined_metrics(&self) -> RunMetrics {
        let mut m = RunMetrics::default();
        for co in &self.coords {
            m.merge(co.metrics());
        }
        m
    }

    /// Swap-out for `tenant` (see [`Coordinator::write`]).
    pub fn write(
        &mut self,
        cl: &mut ClusterState,
        now: Ns,
        tenant: TenantId,
        page: u64,
        bytes: u64,
    ) -> Access {
        self.coords[tenant].write(cl, now, page, bytes)
    }

    /// Swap-in for `tenant` (see [`Coordinator::read`]).
    pub fn read(
        &mut self,
        cl: &mut ClusterState,
        now: Ns,
        tenant: TenantId,
        page: u64,
    ) -> Access {
        self.coords[tenant].read(cl, now, page)
    }

    /// Drive every tenant's background machinery up to `now`, then run
    /// one arbitration round against fresh load snapshots and apply the
    /// resulting leases.
    pub fn pump(&mut self, cl: &mut ClusterState, now: Ns) {
        for co in &mut self.coords {
            co.pump(cl, now);
        }
        let mut loads = Vec::with_capacity(self.coords.len());
        for (i, co) in self.coords.iter().enumerate() {
            let mp = co.mempool();
            let used = mp.used();
            let reclaimable = mp.reclaimable_count() as u64;
            loads.push(TenantLoad {
                used_pages: used,
                pinned_pages: used.saturating_sub(reclaimable),
                stalled_allocs: mp
                    .alloc_stalls
                    .saturating_sub(self.stall_base[i]),
                recent_allocs: mp.allocs.saturating_sub(self.alloc_base[i]),
            });
            self.stall_base[i] = mp.alloc_stalls;
            self.alloc_base[i] = mp.allocs;
        }
        let leases = self.arbiter.rebalance(&loads);
        for (co, &l) in self.coords.iter_mut().zip(leases.iter()) {
            co.set_lease_pages(l);
        }
        if audit::enabled() {
            audit::enforce(&self.arbiter.audit_check());
        }
    }

    /// Host free memory on the sender changed (container churn): shrink
    /// the budget to `min(max_pool_pages, host_free_fraction × free)` and
    /// fan the reclaimed leases out to the coordinators — each enforces
    /// its lowered lease on its next pump (free-slot shrink, then idle
    /// donation).
    pub fn host_pressure(&mut self, free_pages: u64) {
        self.host_free_pages = free_pages;
        let frac_cap =
            (free_pages as f64 * self.host_free_fraction) as u64;
        let budget = self.max_budget_pages.min(frac_cap).max(1);
        let leases = self.arbiter.set_budget(budget);
        for (co, &l) in self.coords.iter_mut().zip(leases.iter()) {
            co.set_lease_pages(l);
            co.set_host_free_pages(free_pages);
        }
        if audit::enabled() {
            audit::enforce(&self.arbiter.audit_check());
        }
    }

    /// A peer node needs `bytes` of its donated memory back: route each
    /// reclamation to the tenant owning the globally least-active block
    /// on that node, so the §3.5 activity order is preserved across
    /// tenants and no tenant ever evicts another's data.
    pub fn remote_pressure(
        &mut self,
        cl: &mut ClusterState,
        now: Ns,
        node: NodeId,
        bytes: u64,
    ) -> PressureOutcome {
        let mut out = PressureOutcome {
            done_at: now,
            ..Default::default()
        };
        let mut t = now;
        while out.reclaimed_bytes < bytes {
            let victim = match cl.mrpools[node].least_active(t) {
                Some(b) => (b.id, b.owner, b.bytes),
                None => break,
            };
            let (block, owner, block_bytes) = victim;
            let tenant = owner
                .checked_sub(TENANT_OWNER_BASE)
                .filter(|&i| i < self.coords.len());
            match tenant {
                Some(tenant) => {
                    let o =
                        self.coords[tenant].remote_pressure(cl, t, node, 1);
                    if o.reclaimed_bytes == 0 {
                        break;
                    }
                    out.reclaimed_bytes += o.reclaimed_bytes;
                    out.migrated += o.migrated;
                    out.deleted += o.deleted;
                    out.done_at = out.done_at.max(o.done_at);
                    t = t.max(o.done_at);
                }
                None => {
                    // Untracked block (registered outside any tenant):
                    // delete, like the single-tenant last resort.
                    cl.mrpools[node].release(block);
                    out.reclaimed_bytes += block_bytes;
                    out.deleted += 1;
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hot(used: u64) -> TenantLoad {
        TenantLoad {
            used_pages: used,
            pinned_pages: used,
            stalled_allocs: 2,
            recent_allocs: 16,
        }
    }

    #[test]
    fn split_pages_is_exact_and_preserves_unleased() {
        assert_eq!(split_pages(10, 4), vec![3, 3, 2, 2]);
        assert_eq!(split_pages(64, 1), vec![64]);
        assert_eq!(split_pages(3, 8).iter().sum::<u64>(), 3);
        assert_eq!(split_pages(u64::MAX, 4), vec![u64::MAX; 4]);
        assert_eq!(split_pages(0, 3), vec![0, 0, 0]);
        // the allocation-free form agrees index-by-index
        for (total, parts) in [(10u64, 4usize), (3, 8), (u64::MAX, 4)] {
            let v = split_pages(total, parts);
            for (i, &s) in v.iter().enumerate() {
                assert_eq!(share_of(total, parts, i), s, "{total}/{parts}");
            }
        }
    }

    #[test]
    fn register_splits_budget_by_weight() {
        let mut arb = HostArbiter::new(4000);
        let a = arb.register(TenantSpec { weight: 3, min_pages: 64 });
        let b = arb.register(TenantSpec { weight: 1, min_pages: 64 });
        assert_eq!(arb.lease(a), 3000);
        assert_eq!(arb.lease(b), 1000);
        assert_eq!(arb.leased_total(), 4000);
    }

    #[test]
    fn fair_share_respects_min_floor() {
        let mut arb = HostArbiter::new(100);
        let a = arb.register(TenantSpec { weight: 1, min_pages: 90 });
        let b = arb.register(TenantSpec { weight: 1, min_pages: 1 });
        assert_eq!(arb.fair_share(a), 90);
        assert_eq!(arb.fair_share(b), 50);
        // a floored fair share must not overcommit the budget: the raw
        // shares (90 + 50) are trimmed back under it at registration
        assert!(arb.leased_total() <= 100, "{:?}", arb.leases());
        assert_eq!(arb.lease(a), 90);
        assert_eq!(arb.lease(b), 10);
    }

    #[test]
    fn idle_peer_donates_to_demanding_tenant() {
        let mut arb = HostArbiter::new(2000);
        let a = arb.register(TenantSpec::default());
        let b = arb.register(TenantSpec::default());
        let cold = TenantLoad::default();
        arb.rebalance(&[cold, hot(1000)]);
        assert!(arb.lease(b) > 1000, "lease {}", arb.lease(b));
        assert!(arb.lease(a) < 1000);
        assert!(arb.leased_total() <= 2000);
        assert!(arb.grants > 0);
    }

    #[test]
    fn cold_full_tenant_is_not_demanding() {
        // A tenant sitting on a full cache with no recent allocations
        // must be a donor, not a demander.
        let full_cold = TenantLoad {
            used_pages: 1000,
            pinned_pages: 0,
            stalled_allocs: 0,
            recent_allocs: 0,
        };
        assert!(!full_cold.demanding(1000));
        assert!(hot(1000).demanding(1000));
    }

    #[test]
    fn sum_of_leases_never_exceeds_budget() {
        let mut arb = HostArbiter::new(3000);
        arb.register(TenantSpec { weight: 2, min_pages: 64 });
        arb.register(TenantSpec { weight: 1, min_pages: 64 });
        arb.register(TenantSpec { weight: 1, min_pages: 64 });
        let loads = [hot(3000), TenantLoad::default(), hot(10)];
        for round in 0..32 {
            arb.rebalance(&loads);
            assert!(
                arb.leased_total() <= 3000,
                "round {round}: {:?}",
                arb.leases()
            );
        }
        arb.set_budget(500);
        assert!(arb.leased_total() <= 500.max(3 * 64));
        for t in 0..3 {
            assert!(arb.lease(t) >= 64, "tenant {t} under floor");
        }
    }

    #[test]
    fn overcommitted_floors_win_over_budget() {
        let mut arb = HostArbiter::new(1000);
        arb.register(TenantSpec { weight: 1, min_pages: 400 });
        arb.register(TenantSpec { weight: 1, min_pages: 400 });
        arb.set_budget(100);
        assert_eq!(arb.lease(0), 400);
        assert_eq!(arb.lease(1), 400);
    }

    #[test]
    fn raised_budget_feeds_demand_from_unleased_pool() {
        let mut arb = HostArbiter::new(1000);
        let a = arb.register(TenantSpec::default());
        arb.set_budget(2000);
        assert_eq!(arb.lease(a), 1000, "raising budget leaves leases");
        arb.rebalance(&[hot(1000)]);
        assert!(arb.lease(a) > 1000, "demand draws from unleased pool");
        assert!(arb.leased_total() <= 2000);
    }
}
