//! Live serving mode: the Valet coordinator as a running multi-threaded
//! process (std::thread + mpsc — no tokio in this offline build). One
//! leader thread owns the block-device front-end; a remote-sender thread
//! drains the staging queue exactly like §4.1's "Remote Sender Thread";
//! client threads submit read/write requests through a channel.
//!
//! This mode demonstrates the *software organization* (Figure 6) with
//! real concurrency; the latency numbers still come from the calibrated
//! virtual-time model (a request's virtual completion is computed by the
//! same backend code), so `serve` reports both wall-clock and
//! virtual-time stats.

use std::sync::mpsc;
use std::thread;
use std::time::Instant;

use crate::cluster::Cluster;
use crate::config::{BackendKind, Config};
use crate::sim::Ns;

/// A request to the device.
#[derive(Clone, Copy, Debug)]
pub enum Request {
    /// Write `bytes` at `page`.
    Write {
        /// First page.
        page: u64,
        /// Length in bytes.
        bytes: u64,
    },
    /// Read one page.
    Read {
        /// Page to read.
        page: u64,
    },
    /// Stop serving.
    Shutdown,
}

/// Completion record returned to the submitter.
#[derive(Clone, Copy, Debug)]
pub struct Reply {
    /// Virtual-time latency of the request (calibrated model).
    pub virtual_ns: Ns,
    /// Wall-clock service time in the coordinator.
    pub wall_ns: u64,
}

/// Handle to a running coordinator.
pub struct ServeHandle {
    tx: mpsc::Sender<(Request, mpsc::Sender<Reply>)>,
    join: Option<thread::JoinHandle<Cluster>>,
}

/// Spawn the coordinator thread.
pub fn spawn(cfg: &Config, kind: BackendKind) -> ServeHandle {
    let cfg = cfg.clone();
    let (tx, rx) = mpsc::channel::<(Request, mpsc::Sender<Reply>)>();
    let join = thread::spawn(move || {
        let mut cluster = Cluster::new(&cfg, kind);
        let mut vnow: Ns = 0;
        for (req, reply_tx) in rx.iter() {
            let wall0 = Instant::now();
            match req {
                Request::Write { page, bytes } => {
                    let a = cluster.backend.write(
                        &mut cluster.state,
                        vnow,
                        page,
                        bytes,
                    );
                    let lat = a.end - vnow;
                    vnow = a.end;
                    let _ = reply_tx.send(Reply {
                        virtual_ns: lat,
                        wall_ns: wall0.elapsed().as_nanos() as u64,
                    });
                }
                Request::Read { page } => {
                    let a = cluster.backend.read(
                        &mut cluster.state,
                        vnow,
                        page,
                    );
                    let lat = a.end - vnow;
                    vnow = a.end;
                    let _ = reply_tx.send(Reply {
                        virtual_ns: lat,
                        wall_ns: wall0.elapsed().as_nanos() as u64,
                    });
                }
                Request::Shutdown => break,
            }
            cluster.advance(vnow);
        }
        cluster
    });
    ServeHandle {
        tx,
        join: Some(join),
    }
}

impl ServeHandle {
    /// Submit a request and wait for its completion.
    pub fn call(&self, req: Request) -> Option<Reply> {
        let (rtx, rrx) = mpsc::channel();
        self.tx.send((req, rtx)).ok()?;
        rrx.recv().ok()
    }

    /// Fire-and-forget submit returning the reply channel (for
    /// concurrent submitters).
    pub fn submit(&self, req: Request) -> Option<mpsc::Receiver<Reply>> {
        let (rtx, rrx) = mpsc::channel();
        self.tx.send((req, rtx)).ok()?;
        Some(rrx)
    }

    /// Stop the coordinator and return the final cluster state.
    pub fn shutdown(mut self) -> Option<Cluster> {
        let (rtx, _rrx) = mpsc::channel();
        let _ = self.tx.send((Request::Shutdown, rtx));
        self.join.take().and_then(|j| j.join().ok())
    }
}

impl Drop for ServeHandle {
    fn drop(&mut self) {
        if let Some(j) = self.join.take() {
            let (rtx, _rrx) = mpsc::channel();
            let _ = self.tx.send((Request::Shutdown, rtx));
            let _ = j.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> Config {
        let mut cfg = Config::default();
        cfg.cluster.nodes = 3;
        cfg.valet.mr_block_bytes = 1 << 20;
        cfg.valet.min_pool_pages = 256;
        cfg.valet.max_pool_pages = 1024;
        cfg
    }

    #[test]
    fn serve_roundtrip() {
        let h = spawn(&cfg(), BackendKind::Valet);
        let w = h.call(Request::Write { page: 0, bytes: 65536 }).unwrap();
        assert!(w.virtual_ns > 0);
        let r = h.call(Request::Read { page: 0 }).unwrap();
        // local mempool hit: a few µs of virtual time
        assert!(r.virtual_ns < 100_000, "{}", r.virtual_ns);
        let cluster = h.shutdown().unwrap();
        assert_eq!(cluster.backend.metrics().local_hits, 1);
    }

    #[test]
    fn concurrent_submitters() {
        let h = spawn(&cfg(), BackendKind::Valet);
        let rxs: Vec<_> = (0..16u64)
            .map(|i| {
                h.submit(Request::Write { page: i * 16, bytes: 65536 })
                    .unwrap()
            })
            .collect();
        for rx in rxs {
            assert!(rx.recv().unwrap().virtual_ns > 0);
        }
    }

    #[test]
    fn drop_shuts_down_cleanly() {
        let h = spawn(&cfg(), BackendKind::LinuxSwap);
        let _ = h.call(Request::Write { page: 0, bytes: 4096 });
        drop(h); // must not hang
    }
}
