//! Live serving mode: the Valet engine as a running multi-threaded
//! process (std::thread + mpsc — no tokio in this offline build).
//!
//! Three front-ends share the same Figure-6 implementation:
//!
//! * [`spawn`] — the single-driver baseline: one leader thread owns the
//!   block-device front-end; a dedicated remote-sender driver thread
//!   keeps the background pipeline (staging drain, mempool resize)
//!   moving exactly like §4.1's "Remote Sender Thread".
//! * [`spawn_sharded`] — the **parallel sharded front-end**: one worker
//!   thread per shard of a [`crate::engine::ShardedEngine`]. Each worker
//!   exclusively owns its shard's fast path
//!   ([`crate::coordinator::fast::ShardFastPath`]), so a local-cache
//!   read hit completes without taking any lock and hit throughput
//!   scales with the shard count — §4.1's "parallel reads" with real
//!   threads. Read misses and pump ticks enter the mutex around the
//!   shared slow path (cluster substrate +
//!   [`crate::coordinator::sender::RemoteSender`]); write *ordering*
//!   remains a per-shard property (each shard's staging queue is FIFO
//!   on its own timeline). A single pump driver broadcasts ticks so all
//!   shards' staging queues keep draining through the shared coalescing
//!   batcher. Writes depend on `valet.slow_path_threads`: with the
//!   default `1` they take the same mutex (the pre-split single-lock
//!   serve, bit-for-bit); any other value turns on **concurrent
//!   slow-path mode** — shard workers stage and coalesce writes
//!   lock-free, push the batches into per-lane bounded admission rings
//!   (ring mutex only, never the sequencer), and dedicated per-lane
//!   drain threads dispatch them under short sequencer-lock holds. The
//!   lock-order contract is sequencer → ring, never ring → sequencer
//!   and never ring → ring; conservation across the hand-off is audit
//!   law #17 (`lane-lock-coherence`).
//! * [`spawn_tenants`] — N containers behind the
//!   [`crate::arbiter::HostArbiter`], rebalancing leases on every tick.
//!
//! Hot-path note: request/response channels are pooled. `call` reuses a
//! per-handle (or per-[`ServeClient`]) reply channel instead of
//! allocating a fresh `mpsc` pair per request — see
//! `benches/hotpath.rs` (`serve/roundtrip`) for the measured win over
//! the allocate-per-call path that [`ServeHandle::submit`] still takes.
//!
//! This mode demonstrates the *software organization* (Figure 6) with
//! real concurrency; the latency numbers still come from the calibrated
//! virtual-time model (a request's virtual completion is computed by the
//! same engine code), so `serve` reports both wall-clock and
//! virtual-time stats.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex, MutexGuard};
use std::thread;
use std::time::{Duration, Instant};

use crate::arbiter::{share_of, TenantId, TenantSpec};
use crate::backends::ClusterState;
use crate::cluster::{Cluster, TenantCluster};
use crate::config::{BackendKind, Config, LatencyConfig};
use crate::coordinator::fast::ShardFastPath;
use crate::coordinator::sender::RemoteSender;
use crate::engine::{self, ShardedEngine};
use crate::sim::{ms, Ns};

/// A request to the device.
#[derive(Clone, Copy, Debug)]
pub enum Request {
    /// Write `bytes` at `page`.
    Write {
        /// First page.
        page: u64,
        /// Length in bytes.
        bytes: u64,
    },
    /// Read one page.
    Read {
        /// Page to read.
        page: u64,
    },
    /// Read a whole block-I/O request (`pages_for(bytes)` pages from
    /// `page`) through the batched miss pipeline: all of a piece's
    /// misses cross into the slow path once and are fetched with one
    /// per-unit coalesced READ. The single-driver baseline serves it
    /// page by page (the comparison point).
    ReadBlock {
        /// First page.
        page: u64,
        /// Length in bytes.
        bytes: u64,
    },
    /// Advance the background pipeline by one virtual tick (issued by
    /// the remote-sender driver thread; also available to tests that
    /// want deterministic background progress). This is also what
    /// drives the reclaim pipeline: live migrations in the sender's
    /// table advance only on these ticks, interleaved with the write
    /// batches they overlap.
    Pump,
    /// Stop serving.
    Shutdown,
}

/// Completion record returned to the submitter.
#[derive(Clone, Copy, Debug)]
pub struct Reply {
    /// Virtual-time latency of the request (calibrated model).
    pub virtual_ns: Ns,
    /// Wall-clock service time in the coordinator.
    pub wall_ns: u64,
}

/// Handle to a running coordinator.
pub struct ServeHandle {
    tx: mpsc::Sender<(Request, mpsc::Sender<Reply>)>,
    /// Pooled reply lane for `call` — no allocation per request.
    reply: Mutex<ReplyLane>,
    join: Option<thread::JoinHandle<Cluster>>,
    pump_stop: Arc<AtomicBool>,
    pump_join: Option<thread::JoinHandle<()>>,
}

/// Virtual time the background pipeline advances per Pump tick.
const PUMP_TICK: Ns = ms(1);

/// Wall-clock interval between the driver thread's Pump ticks.
const PUMP_INTERVAL: Duration = Duration::from_millis(1);

/// Ring entries a slow-path drain thread dispatches per sequencer-lock
/// hold (concurrent mode): large enough to amortize the acquire, small
/// enough that request threads interleave between batches.
const SLOW_DRAIN_BATCH: usize = 64;

/// Spawn the coordinator's leader thread plus the remote-sender driver.
pub fn spawn(cfg: &Config, kind: BackendKind) -> ServeHandle {
    let cfg = cfg.clone();
    let (tx, rx) = mpsc::channel::<(Request, mpsc::Sender<Reply>)>();
    let join = thread::spawn(move || {
        let mut cluster = Cluster::new(&cfg, kind);
        let mut vnow: Ns = 0;
        for (req, reply_tx) in rx.iter() {
            let wall0 = Instant::now();
            match req {
                Request::Write { page, bytes } => {
                    let a = cluster.backend.write(
                        &mut cluster.state,
                        vnow,
                        page,
                        bytes,
                    );
                    let lat = a.end - vnow;
                    vnow = a.end;
                    let _ = reply_tx.send(Reply {
                        virtual_ns: lat,
                        wall_ns: wall0.elapsed().as_nanos() as u64,
                    });
                }
                Request::Read { page } => {
                    let a = cluster.backend.read(
                        &mut cluster.state,
                        vnow,
                        page,
                    );
                    let lat = a.end - vnow;
                    vnow = a.end;
                    let _ = reply_tx.send(Reply {
                        virtual_ns: lat,
                        wall_ns: wall0.elapsed().as_nanos() as u64,
                    });
                }
                Request::ReadBlock { page, bytes } => {
                    let a = cluster.backend.read_block(
                        &mut cluster.state,
                        vnow,
                        page,
                        bytes,
                    );
                    let lat = a.end - vnow;
                    vnow = a.end;
                    let _ = reply_tx.send(Reply {
                        virtual_ns: lat,
                        wall_ns: wall0.elapsed().as_nanos() as u64,
                    });
                }
                Request::Pump => {
                    // The remote-sender driver: wall-clock time passing
                    // maps to virtual time, so staged write sets drain
                    // and in-flight batches complete between requests —
                    // the live analogue of the simulated sender thread.
                    vnow += PUMP_TICK;
                    let _ = reply_tx.send(Reply {
                        virtual_ns: 0,
                        wall_ns: wall0.elapsed().as_nanos() as u64,
                    });
                }
                Request::Shutdown => break,
            }
            cluster.advance(vnow);
        }
        cluster
    });
    // Remote-sender driver: ticks the leader with Pump requests until
    // shutdown, keeping the background pipeline live without clients.
    let pump_stop = Arc::new(AtomicBool::new(false));
    let pump_tx = tx.clone();
    let stop = pump_stop.clone();
    let pump_join = thread::spawn(move || {
        while !stop.load(Ordering::Relaxed) {
            let (rtx, _rrx) = mpsc::channel();
            if pump_tx.send((Request::Pump, rtx)).is_err() {
                break; // leader gone
            }
            thread::sleep(PUMP_INTERVAL);
        }
    });
    ServeHandle {
        tx,
        reply: Mutex::new(ReplyLane::new()),
        join: Some(join),
        pump_stop,
        pump_join: Some(pump_join),
    }
}

/// Upper bound on waiting for a pooled reply. A pooled channel cannot
/// observe server death through disconnection (the caller holds its own
/// reply sender), so a request racing shutdown — enqueued but never
/// processed — would otherwise block its caller forever. Normal replies
/// arrive in microseconds; hitting this bound poisons the lane and the
/// call reports `None`, like the fresh-channel path always has.
const POOLED_RECV_TIMEOUT: Duration = Duration::from_secs(10);

/// One pooled reply lane: a reply channel reused across calls (the
/// hot-path win over allocating an mpsc pair per request). After a
/// receive times out the lane is **poisoned** — the receiver is
/// discarded, so the late reply (and any later piece replies) go to a
/// dead channel instead of sitting in the queue and being misattributed
/// to the next request (which would leave the lane off-by-one forever).
/// A poisoned lane answers every subsequent call with `None`, matching
/// a dead server.
struct ReplyLane {
    tx: mpsc::Sender<Reply>,
    rx: Option<mpsc::Receiver<Reply>>,
}

impl ReplyLane {
    fn new() -> Self {
        let (tx, rx) = mpsc::channel();
        ReplyLane { tx, rx: Some(rx) }
    }

    /// A clonable reply address, or `None` once poisoned.
    fn addr(&self) -> Option<mpsc::Sender<Reply>> {
        self.rx.is_some().then(|| self.tx.clone())
    }

    /// Discard the receiver: in-flight and future replies on this lane
    /// are dropped and every later call returns `None`.
    fn poison(&mut self) {
        self.rx = None;
    }

    /// Await one reply (bounded by [`POOLED_RECV_TIMEOUT`]; a timeout
    /// poisons the lane).
    fn recv(&mut self) -> Option<Reply> {
        // bind first: a match on the expression would hold the shared
        // `rx` borrow across the arm that needs `&mut self` to poison
        let got = self.rx.as_ref()?.recv_timeout(POOLED_RECV_TIMEOUT);
        match got {
            Ok(r) => Some(r),
            Err(_) => {
                self.poison();
                None
            }
        }
    }

    /// Await `sent` piece replies and fold them into the request's
    /// completion: slowest virtual time, slowest wall time.
    fn collect(&mut self, sent: usize) -> Option<Reply> {
        let mut agg: Option<Reply> = None;
        for _ in 0..sent {
            let r = self.recv()?;
            agg = Some(match agg {
                None => r,
                Some(p) => Reply {
                    virtual_ns: p.virtual_ns.max(r.virtual_ns),
                    wall_ns: p.wall_ns.max(r.wall_ns),
                },
            });
        }
        agg
    }
}

/// Send `req` with the pooled reply address and await the reply.
/// `Shutdown` uses a throwaway channel instead: the target exits without
/// replying, and the disconnect turns into a prompt `None`.
fn call_pooled(
    tx: &mpsc::Sender<(Request, mpsc::Sender<Reply>)>,
    lane: &mut ReplyLane,
    req: Request,
) -> Option<Reply> {
    if matches!(req, Request::Shutdown) {
        let (rtx, rrx) = mpsc::channel();
        tx.send((req, rtx)).ok()?;
        return rrx.recv().ok();
    }
    let addr = lane.addr()?;
    tx.send((req, addr)).ok()?;
    lane.recv()
}

impl ServeHandle {
    /// Submit a request and wait for its completion. Reuses the handle's
    /// pooled reply channel (callers are serialized on it); for
    /// concurrent callers take a [`ServeClient`] per thread.
    pub fn call(&self, req: Request) -> Option<Reply> {
        let mut lane = lock_lane(&self.reply)?;
        call_pooled(&self.tx, &mut lane, req)
    }

    /// A cheap per-thread submitter with its own pooled reply channel
    /// (no lock, no per-call allocation). Clients outlive shutdown
    /// harmlessly: their calls just return `None`.
    pub fn client(&self) -> ServeClient {
        ServeClient {
            tx: self.tx.clone(),
            reply: std::cell::RefCell::new(ReplyLane::new()),
        }
    }

    /// Fire-and-forget submit returning the reply channel (for
    /// concurrent submitters). This allocates a fresh channel per call —
    /// the pre-pooling behavior, kept for one-shot pipelining and as the
    /// hot-path comparison point in `benches/hotpath.rs`.
    pub fn submit(&self, req: Request) -> Option<mpsc::Receiver<Reply>> {
        let (rtx, rrx) = mpsc::channel();
        self.tx.send((req, rtx)).ok()?;
        Some(rrx)
    }

    fn stop_threads(&mut self) -> Option<Cluster> {
        self.pump_stop.store(true, Ordering::Relaxed);
        let (rtx, _rrx) = mpsc::channel();
        let _ = self.tx.send((Request::Shutdown, rtx));
        let cluster = self.join.take().and_then(|j| j.join().ok());
        if let Some(p) = self.pump_join.take() {
            let _ = p.join();
        }
        cluster
    }

    /// Stop the coordinator and return the final cluster state.
    pub fn shutdown(mut self) -> Option<Cluster> {
        self.stop_threads()
    }
}

impl Drop for ServeHandle {
    fn drop(&mut self) {
        let _ = self.stop_threads();
    }
}

/// A per-thread submitter for a [`ServeHandle`]: owns its request sender
/// and a private pooled reply channel, so concurrent client threads pay
/// neither a lock nor a channel allocation per call.
pub struct ServeClient {
    tx: mpsc::Sender<(Request, mpsc::Sender<Reply>)>,
    reply: std::cell::RefCell<ReplyLane>,
}

impl ServeClient {
    /// Submit a request and wait for its completion.
    pub fn call(&self, req: Request) -> Option<Reply> {
        call_pooled(&self.tx, &mut self.reply.borrow_mut(), req)
    }
}

// ---------------------------------------------------------------------
// Sharded serving — the parallel front-end over the sharded engine
// ---------------------------------------------------------------------

/// The slow-path state the shard workers share behind one mutex — the
/// **sequencer lock**: the simulated substrate plus the remote sender.
/// Everything else a request touches is shard-local and lock-free.
///
/// Since the sender split into per-peer lanes, the lock's long holds
/// are gone from the background path: the pump driver ticks each lane's
/// completions under its own short hold ([`RemoteSender::tick_lane`])
/// and takes one more for the cross-lane sequencer work (migration
/// scheduling / COMMIT), instead of one hold spanning everything.
/// Request-side holds are unchanged (a write or miss needs the
/// substrate either way); local hits never take the lock at all.
struct SharedSlow {
    cl: ClusterState,
    sender: RemoteSender,
    host_free_pages: u64,
    /// High watermark of the shard workers' virtual clocks — the time
    /// the pump driver's lane ticks run "up to". Each worker raises it
    /// while it already holds the lock for a request, so the driver
    /// never needs to poll every worker to learn where virtual time is.
    vnow_hw: Ns,
}

// ---------------------------------------------------------------------
// Lock-ordering helpers. Every mutex in this module is acquired through
// one of these two functions, and only inside this marked region — the
// `valet-lint` serve-lock rule rejects any bare `.lock(` elsewhere in
// serve/. That pins the module's lock order (a caller holds its reply
// lane OR the shared slow path, and the worker side never acquires the
// lane while holding the slow path) and keeps the poisoning policy in
// one place.
// valet-lint: allow-lock-begin

/// Acquire the shared slow path (cluster + sender + host-free level).
/// Panics if a worker panicked while holding it: the simulation state
/// is unusable from that point on.
fn lock_slow(shared: &Mutex<SharedSlow>) -> MutexGuard<'_, SharedSlow> {
    shared.lock().expect("serve lock poisoned")
}

/// Acquire a handle's pooled reply lane; `None` after a submitter
/// panicked mid-call (the lane may hold a stale reply, so the call is
/// refused rather than misdelivered).
fn lock_lane(lane: &Mutex<ReplyLane>) -> Option<MutexGuard<'_, ReplyLane>> {
    lane.lock().ok()
}

// valet-lint: allow-lock-end
// ---------------------------------------------------------------------

/// Outcome of a sharded serve session: the reassembled engine (merged
/// metrics, per-shard fast paths) plus the final substrate.
pub struct ShardedServeOutcome {
    /// The engine, reassembled from the workers' fast paths and the
    /// shared sender.
    pub engine: ShardedEngine,
    /// Final cluster substrate.
    pub state: ClusterState,
}

/// Handle to a running sharded serve front-end (see [`spawn_sharded`]).
pub struct ShardedServeHandle {
    txs: Vec<mpsc::Sender<(Request, mpsc::Sender<Reply>)>>,
    reply: Mutex<ReplyLane>,
    joins: Vec<Option<thread::JoinHandle<ShardFastPath>>>,
    /// `None` once `stop_threads` has consumed it (shutdown, then Drop).
    shared: Option<Arc<Mutex<SharedSlow>>>,
    pump_stop: Arc<AtomicBool>,
    pump_join: Option<thread::JoinHandle<()>>,
    /// Per-lane slow-path drain threads (empty in single-mutex mode);
    /// they watch the same stop flag as the pump driver.
    slow_joins: Vec<thread::JoinHandle<()>>,
    stripe_pages: u64,
    cfg: Config,
}

/// What a shard worker needs to admit writes lock-free in concurrent
/// slow-path mode (see [`spawn_sharded`]): the lane-ring handle, the
/// policy knobs the coalescer reads, and this shard's precomputed
/// host-free share (fixed for the session — the sharded front-end never
/// rebalances it mid-run). `None` in the default single-mutex mode and
/// in the sync-write ablation, where every write takes the lock.
struct AdmissionCtx {
    rings: crate::coordinator::sender::LaneRings,
    vcfg: crate::config::ValetConfig,
    host: u64,
}

/// One shard worker: exclusively owns its fast path. Local read hits
/// (single-page or whole-block) run lock-free; read misses and pump
/// ticks take the shared sequencer lock. Writes take it too in the
/// default mode — with an [`AdmissionCtx`] they instead stage into the
/// shard's own queue and admit to the lane rings lock-free, falling
/// back to the locked path only on mempool backpressure. After a write
/// the worker rings `bell` (a lock-free MPSC channel to the pump
/// driver) *outside* any lock, so the driver pumps this shard promptly
/// instead of waiting out the broadcast interval.
#[allow(clippy::too_many_arguments)]
fn shard_worker(
    shard: usize,
    shards: usize,
    stripe_pages: u64,
    sync_mode: bool,
    lat: LatencyConfig,
    admission: Option<AdmissionCtx>,
    mut fast: ShardFastPath,
    shared: Arc<Mutex<SharedSlow>>,
    rx: mpsc::Receiver<(Request, mpsc::Sender<Reply>)>,
    bell: mpsc::Sender<usize>,
) -> ShardFastPath {
    let route = engine::ShardRoute {
        shard,
        shards,
        stripe_pages,
    };
    let mut vnow: Ns = 0;
    for (req, reply_tx) in rx.iter() {
        let wall0 = Instant::now();
        match req {
            Request::Write { page, bytes } => {
                // Concurrent mode: stage + admit without the sequencer
                // lock; only backpressure (which *needs* slow-path
                // progress to free a slot) drops to the locked path.
                let staged = admission.as_ref().and_then(|ctx| {
                    engine::shard_stage_write(
                        &mut fast, &lat, vnow, page, bytes, ctx.host,
                    )
                    .map(|a| {
                        // lock-order: ring only — admission never
                        // holds the sequencer
                        crate::coordinator::sender::admit_staged(
                            &ctx.vcfg, &ctx.rings, &mut fast, shard,
                        );
                        a
                    })
                });
                let a = match staged {
                    Some(a) => a,
                    None => {
                        let mut sh = lock_slow(&shared);
                        let host =
                            share_of(sh.host_free_pages, shards, shard);
                        sh.vnow_hw = sh.vnow_hw.max(vnow);
                        let SharedSlow { cl, sender, .. } = &mut *sh;
                        // Valet-RemoteOnly ablation (no mempool):
                        // synchronous remote write, exactly like the
                        // single-driver path.
                        if sync_mode {
                            sender.write_sync(
                                cl, vnow, page, bytes, &mut fast,
                            )
                        } else {
                            engine::shard_write(
                                sender, &mut fast, cl, shard, vnow,
                                page, bytes, host,
                            )
                        }
                    }
                };
                // ring the submission doorbell outside the lock: the
                // pump driver will drive this shard's staging queue
                let _ = bell.send(shard);
                let lat_v = a.end - vnow;
                vnow = a.end;
                let _ = reply_tx.send(Reply {
                    virtual_ns: lat_v,
                    wall_ns: wall0.elapsed().as_nanos() as u64,
                });
            }
            Request::Read { page } => {
                // The payoff: a local-cache hit never takes the lock, so
                // S workers serve hits fully in parallel. (A prefetch
                // hit that wants the readahead window extended takes it
                // briefly — asynchronous work, not request latency.)
                let a = match fast.try_read_local(&lat, vnow, page) {
                    Some(a) => {
                        if fast.readahead_due.is_some() {
                            let mut sh = lock_slow(&shared);
                            let SharedSlow { cl, sender, .. } = &mut *sh;
                            engine::drive_readahead(
                                sender, &mut fast, cl, vnow, route,
                            );
                        }
                        a
                    }
                    None => {
                        let mut sh = lock_slow(&shared);
                        sh.vnow_hw = sh.vnow_hw.max(vnow);
                        let SharedSlow { cl, sender, .. } = &mut *sh;
                        engine::shard_read_miss(
                            sender, &mut fast, cl, vnow, page, route,
                        )
                    }
                };
                let lat_v = a.end - vnow;
                vnow = a.end;
                let _ = reply_tx.send(Reply {
                    virtual_ns: lat_v,
                    wall_ns: wall0.elapsed().as_nanos() as u64,
                });
            }
            Request::ReadBlock { page, bytes } => {
                // An all-cached block completes lock-free; any miss
                // crosses into the slow path exactly once with the
                // whole piece (collect → coalesce → batch).
                let npages = crate::pages_for(bytes).max(1);
                let a = match fast
                    .try_read_block_local(&lat, vnow, page, npages)
                {
                    Some(a) => {
                        if fast.readahead_due.is_some() {
                            let mut sh = lock_slow(&shared);
                            let SharedSlow { cl, sender, .. } = &mut *sh;
                            engine::drive_readahead(
                                sender, &mut fast, cl, vnow, route,
                            );
                        }
                        a
                    }
                    None => {
                        let mut sh = lock_slow(&shared);
                        sh.vnow_hw = sh.vnow_hw.max(vnow);
                        let SharedSlow { cl, sender, .. } = &mut *sh;
                        engine::shard_read_block(
                            sender, &mut fast, cl, vnow, page, npages,
                            route,
                        )
                    }
                };
                let lat_v = a.end - vnow;
                vnow = a.end;
                let _ = reply_tx.send(Reply {
                    virtual_ns: lat_v,
                    wall_ns: wall0.elapsed().as_nanos() as u64,
                });
            }
            Request::Pump => {
                vnow += PUMP_TICK;
                let mut sh = lock_slow(&shared);
                let host = share_of(sh.host_free_pages, shards, shard);
                sh.vnow_hw = sh.vnow_hw.max(vnow);
                let SharedSlow { cl, sender, .. } = &mut *sh;
                engine::drive_shard(sender, &mut fast, cl, vnow, shard);
                drop(sh);
                fast.resize_for_host(host);
                let _ = reply_tx.send(Reply {
                    virtual_ns: 0,
                    wall_ns: wall0.elapsed().as_nanos() as u64,
                });
            }
            Request::Shutdown => break,
        }
    }
    fast
}

/// Spawn the sharded serve front-end: one worker thread per shard of an
/// `S`-shard engine (page-routed: `shard_of(page) = (page / stripe) % S`)
/// plus the single pump/sender driver that broadcasts ticks so every
/// shard's staging queue drains through the shared coalescing batcher.
/// `spawn_sharded(cfg, 1)` is behaviorally the single-driver [`spawn`]
/// with the Valet backend.
pub fn spawn_sharded(cfg: &Config, shards: usize) -> ShardedServeHandle {
    let shards = shards.max(1);
    let engine = ShardedEngine::new(cfg, shards);
    let stripe_pages = engine.stripe_pages();
    let host_free_pages = engine.host_free_pages();
    let sync_mode = engine.is_sync_mode();
    let (fasts, sender) = engine.into_parts();
    let nlanes = sender.lane_count();
    let rings = sender.rings_handle();
    // Concurrent slow-path mode (valet.slow_path_threads): `1` (the
    // default) spawns no drain threads and keeps every write on the
    // single-mutex path — byte-for-byte today's behavior; `0` runs one
    // drain thread per lane; `n` runs n threads over the lanes. The
    // sync-write ablation has no staging queue to admit from, so it
    // always stays locked.
    let nthreads = match cfg.valet.slow_path_threads {
        1 => 0,
        0 => nlanes,
        n => n.min(nlanes),
    };
    let concurrent = nthreads > 0 && !sync_mode;
    let shared = Arc::new(Mutex::new(SharedSlow {
        cl: ClusterState::new(cfg),
        sender,
        host_free_pages,
        vnow_hw: 0,
    }));
    // The submission doorbell: a lock-free MPSC channel every worker
    // rings (outside the sequencer lock) after staging a write, so the
    // pump driver services busy shards promptly between broadcasts.
    let (bell_tx, bell_rx) = mpsc::channel::<usize>();
    let mut txs = Vec::with_capacity(shards);
    let mut joins = Vec::with_capacity(shards);
    for (i, fast) in fasts.into_iter().enumerate() {
        let (tx, rx) = mpsc::channel::<(Request, mpsc::Sender<Reply>)>();
        let sh = shared.clone();
        let lat = cfg.latency.clone();
        let bell = bell_tx.clone();
        let admission = concurrent.then(|| AdmissionCtx {
            rings: rings.clone(),
            vcfg: cfg.valet.clone(),
            host: share_of(host_free_pages, shards, i),
        });
        joins.push(Some(thread::spawn(move || {
            shard_worker(
                i,
                shards,
                stripe_pages,
                sync_mode,
                lat,
                admission,
                fast,
                sh,
                rx,
                bell,
            )
        })));
        txs.push(tx);
    }
    drop(bell_tx); // pump driver owns the only receiver; workers ring
    let pump_stop = Arc::new(AtomicBool::new(false));
    // Per-lane slow-path drain threads (concurrent mode only): thread t
    // owns lanes {l : l % nthreads == t} and for each runs, under one
    // short sequencer hold per lane, the ring drain, the lane's
    // completion tick, and the lane's slice of migration stepping — so
    // a stalled lane (a 62 ms map_mr on a fresh unit) only ever stalls
    // its own thread while other peers' slow-path work keeps flowing.
    let mut slow_joins = Vec::with_capacity(nthreads);
    for t in 0..nthreads {
        let shared_t = shared.clone();
        let stop = pump_stop.clone();
        slow_joins.push(thread::spawn(move || {
            let owned: Vec<usize> =
                (0..nlanes).filter(|l| l % nthreads == t).collect();
            while !stop.load(Ordering::Relaxed) {
                for &lane in &owned {
                    let mut sh = lock_slow(&shared_t);
                    let hw = sh.vnow_hw;
                    let SharedSlow { cl, sender, .. } = &mut *sh;
                    // lock-order: sequencer → ring (the drain takes
                    // the ring mutex inside the sequencer hold)
                    sender.drain_lane_ring(cl, hw, lane, SLOW_DRAIN_BATCH);
                    sender.tick_lane(cl, hw, lane);
                    sender.advance_migrations_lane(cl, hw, lane);
                }
                thread::sleep(PUMP_INTERVAL);
            }
        }));
    }
    // The pump/sender driver. Per cycle: drain the doorbells and pump
    // the shards that rang (targeted, not broadcast); then the
    // background slow-path tick — in concurrent mode just the sequencer
    // scans (lane work belongs to the drain threads above), otherwise
    // each lane's completions under its own short hold plus one
    // cross-lane sequencer tick (migration scheduling / COMMIT); then
    // broadcast a tick so every staging queue keeps draining even when
    // no requests arrive.
    let pump_txs = txs.clone();
    let pump_shared = shared.clone();
    let stop = pump_stop.clone();
    let pump_join = thread::spawn(move || {
        while !stop.load(Ordering::Relaxed) {
            let mut rung = vec![false; pump_txs.len()];
            while let Ok(s) = bell_rx.try_recv() {
                if let Some(r) = rung.get_mut(s) {
                    *r = true;
                }
            }
            for (s, tx) in pump_txs.iter().enumerate() {
                if !rung[s] {
                    continue;
                }
                let (rtx, _rrx) = mpsc::channel();
                if tx.send((Request::Pump, rtx)).is_err() {
                    return; // a worker is gone: shutting down
                }
            }
            if concurrent {
                // one short hold for the cross-lane scan clocks only
                let mut sh = lock_slow(&pump_shared);
                let hw = sh.vnow_hw;
                let SharedSlow { cl, sender, .. } = &mut *sh;
                sender.advance_sequencer(cl, hw);
            } else {
                // per-lane completion ticks: one short hold each, so a
                // request thread can interleave between lanes
                let nlanes = lock_slow(&pump_shared).sender.lane_count();
                for lane in 0..nlanes {
                    let mut sh = lock_slow(&pump_shared);
                    let hw = sh.vnow_hw;
                    let SharedSlow { cl, sender, .. } = &mut *sh;
                    sender.tick_lane(cl, hw, lane);
                }
                {
                    let mut sh = lock_slow(&pump_shared);
                    let hw = sh.vnow_hw;
                    let SharedSlow { cl, sender, .. } = &mut *sh;
                    sender.advance_migrations(cl, hw);
                }
            }
            for tx in &pump_txs {
                let (rtx, _rrx) = mpsc::channel();
                if tx.send((Request::Pump, rtx)).is_err() {
                    return; // a worker is gone: shutting down
                }
            }
            thread::sleep(PUMP_INTERVAL);
        }
    });
    ShardedServeHandle {
        txs,
        reply: Mutex::new(ReplyLane::new()),
        joins,
        shared: Some(shared),
        pump_stop,
        pump_join: Some(pump_join),
        slow_joins,
        stripe_pages,
        cfg: cfg.clone(),
    }
}

impl ShardedServeHandle {
    /// Number of shard workers.
    pub fn shards(&self) -> usize {
        self.txs.len()
    }

    /// The shard worker owning `page` (see
    /// [`crate::engine::shard_of_page`]).
    pub fn shard_of(&self, page: u64) -> usize {
        engine::shard_of_page(page, self.stripe_pages, self.txs.len())
    }

    /// Submit a request and wait for its completion. Reads route to the
    /// owning shard; writes larger than one stripe are split at stripe
    /// boundaries and fan out to their shards in parallel (the reply
    /// aggregates the slowest piece); `Pump` broadcasts to every shard.
    pub fn call(&self, req: Request) -> Option<Reply> {
        let mut lane = lock_lane(&self.reply)?;
        sharded_call(&self.txs, self.stripe_pages, &mut lane, req)
    }

    /// A per-thread submitter with its own pooled reply lane.
    pub fn client(&self) -> ShardedServeClient {
        ShardedServeClient {
            txs: self.txs.clone(),
            reply: std::cell::RefCell::new(ReplyLane::new()),
            stripe_pages: self.stripe_pages,
        }
    }

    fn stop_threads(&mut self) -> Option<ShardedServeOutcome> {
        self.pump_stop.store(true, Ordering::Relaxed);
        for tx in &self.txs {
            let (rtx, _rrx) = mpsc::channel();
            let _ = tx.send((Request::Shutdown, rtx));
        }
        let shared = self.shared.take()?; // None after the first run
        let mut fasts = Vec::with_capacity(self.joins.len());
        for j in &mut self.joins {
            let fast = j.take().and_then(|j| j.join().ok())?;
            fasts.push(fast);
        }
        if let Some(p) = self.pump_join.take() {
            let _ = p.join();
        }
        // the drain threads hold Arc clones of the slow path: they must
        // be joined before try_unwrap below can succeed
        for j in self.slow_joins.drain(..) {
            let _ = j.join();
        }
        // workers + pump + drains are joined: this handle holds the
        // last clone
        let mut slow = Arc::try_unwrap(shared).ok()?.into_inner().ok()?;
        // flush admissions still queued in the rings (a worker staged
        // them lock-free right before shutdown): every admitted write
        // set dispatches — the conservation the lane-lock-coherence law
        // re-proves on the reassembled engine's final audit
        let hw = slow.vnow_hw;
        slow.sender.drain_all_rings(&mut slow.cl, hw);
        Some(ShardedServeOutcome {
            engine: ShardedEngine::from_parts(
                &self.cfg,
                fasts,
                slow.sender,
                slow.host_free_pages,
            ),
            state: slow.cl,
        })
    }

    /// Stop every worker and return the reassembled engine + substrate.
    pub fn shutdown(mut self) -> Option<ShardedServeOutcome> {
        self.stop_threads()
    }
}

impl Drop for ShardedServeHandle {
    fn drop(&mut self) {
        let _ = self.stop_threads();
    }
}

/// A per-thread submitter for a [`ShardedServeHandle`]: owns clones of
/// every shard's request sender plus a private pooled reply channel.
pub struct ShardedServeClient {
    txs: Vec<mpsc::Sender<(Request, mpsc::Sender<Reply>)>>,
    reply: std::cell::RefCell<ReplyLane>,
    stripe_pages: u64,
}

impl ShardedServeClient {
    /// Submit a request and wait for its completion (same routing rules
    /// as [`ShardedServeHandle::call`]).
    pub fn call(&self, req: Request) -> Option<Reply> {
        let mut lane = self.reply.borrow_mut();
        sharded_call(&self.txs, self.stripe_pages, &mut lane, req)
    }
}

/// Shared sharded-call body for handle + client: dispatch to the
/// shard(s), then fold the piece replies. A dispatch failure (dead
/// workers) poisons the lane so already-sent pieces' late replies can
/// never be misattributed to a later request.
fn sharded_call(
    txs: &[mpsc::Sender<(Request, mpsc::Sender<Reply>)>],
    stripe_pages: u64,
    lane: &mut ReplyLane,
    req: Request,
) -> Option<Reply> {
    let addr = lane.addr()?;
    let Some(sent) = dispatch_sharded(txs, stripe_pages, req, &addr)
    else {
        // Shutdown legitimately expects no replies; any other failed
        // dispatch means workers died mid-fan-out.
        if !matches!(req, Request::Shutdown) {
            lane.poison();
        }
        return None;
    };
    lane.collect(sent)
}

/// Shared routing for handle + client: send `req` to its shard(s) and
/// return the number of replies to expect (`None` if a send failed or
/// the request was a no-reply `Shutdown`).
fn dispatch_sharded(
    txs: &[mpsc::Sender<(Request, mpsc::Sender<Reply>)>],
    stripe_pages: u64,
    req: Request,
    reply_tx: &mpsc::Sender<Reply>,
) -> Option<usize> {
    let shard_of =
        |page: u64| engine::shard_of_page(page, stripe_pages, txs.len());
    match req {
        Request::Read { page } => {
            txs[shard_of(page)]
                .send((req, reply_tx.clone()))
                .ok()?;
            Some(1)
        }
        Request::Write { page, bytes } => {
            if txs.len() == 1 {
                // single shard: no split — identical to the baseline
                txs[0].send((req, reply_tx.clone())).ok()?;
                return Some(1);
            }
            let pieces =
                engine::split_stripes(page, bytes, stripe_pages);
            for &(p0, b) in &pieces {
                txs[shard_of(p0)]
                    .send((
                        Request::Write { page: p0, bytes: b },
                        reply_tx.clone(),
                    ))
                    .ok()?;
            }
            Some(pieces.len())
        }
        Request::ReadBlock { page, bytes } => {
            if txs.len() == 1 {
                txs[0].send((req, reply_tx.clone())).ok()?;
                return Some(1);
            }
            // same stripe split as writes: each piece is one shard's
            // block, served through that worker's batched read path
            let pieces =
                engine::split_stripes(page, bytes, stripe_pages);
            for &(p0, b) in &pieces {
                txs[shard_of(p0)]
                    .send((
                        Request::ReadBlock { page: p0, bytes: b },
                        reply_tx.clone(),
                    ))
                    .ok()?;
            }
            Some(pieces.len())
        }
        Request::Pump => {
            for tx in txs {
                tx.send((Request::Pump, reply_tx.clone())).ok()?;
            }
            Some(txs.len())
        }
        Request::Shutdown => {
            for tx in txs {
                let (rtx, _rrx) = mpsc::channel();
                tx.send((Request::Shutdown, rtx)).ok()?;
            }
            None
        }
    }
}

// ---------------------------------------------------------------------
// Multi-tenant serving
// ---------------------------------------------------------------------

/// A request to a multi-tenant device: the same vocabulary as
/// [`Request`] plus the tenant id the block I/O belongs to (see
/// [`spawn_tenants`]).
#[derive(Clone, Copy, Debug)]
pub enum TenantRequest {
    /// Write `bytes` at `page` of `tenant`'s address space.
    Write {
        /// Issuing tenant.
        tenant: TenantId,
        /// First page.
        page: u64,
        /// Length in bytes.
        bytes: u64,
    },
    /// Read one page of `tenant`'s address space.
    Read {
        /// Issuing tenant.
        tenant: TenantId,
        /// Page to read.
        page: u64,
    },
    /// Advance the background pipelines (and one arbitration round) by
    /// one virtual tick.
    Pump,
    /// Stop serving.
    Shutdown,
}

/// Handle to a running multi-tenant coordinator group.
pub struct TenantServeHandle {
    tx: mpsc::Sender<(TenantRequest, mpsc::Sender<Reply>)>,
    reply: Mutex<ReplyLane>,
    /// Registered tenant count — lets `call` reject unknown tenant ids
    /// client-side so the pooled reply lane never blocks on the
    /// leader's drop-the-reply error path.
    tenants: usize,
    join: Option<thread::JoinHandle<TenantCluster>>,
    pump_stop: Arc<AtomicBool>,
    pump_join: Option<thread::JoinHandle<()>>,
}

/// Spawn the leader thread for a [`TenantCluster`] (one coordinator per
/// spec behind the shared [`crate::arbiter::HostArbiter`]) plus the same
/// remote-sender driver thread as [`spawn`]. The arbiter lives behind
/// the leader: every Pump tick drains all tenants and runs one
/// arbitration round, so leases keep following demand even when no
/// requests arrive.
pub fn spawn_tenants(cfg: &Config, specs: &[TenantSpec]) -> TenantServeHandle {
    let cfg = cfg.clone();
    let specs = specs.to_vec();
    let specs_len = specs.len();
    let (tx, rx) = mpsc::channel::<(TenantRequest, mpsc::Sender<Reply>)>();
    let join = thread::spawn(move || {
        let mut cluster = TenantCluster::new(&cfg, &specs);
        let mut vnow: Ns = 0;
        for (req, reply_tx) in rx.iter() {
            let wall0 = Instant::now();
            // An unknown tenant id must not panic the leader: drop the
            // reply channel instead, so the caller's `call` returns
            // None while the server keeps serving valid tenants.
            let tenants = cluster.group.tenants();
            match req {
                TenantRequest::Write { tenant, page, bytes } => {
                    if tenant >= tenants {
                        drop(reply_tx);
                        continue;
                    }
                    let a = cluster.write(vnow, tenant, page, bytes);
                    let lat = a.end - vnow;
                    vnow = a.end;
                    let _ = reply_tx.send(Reply {
                        virtual_ns: lat,
                        wall_ns: wall0.elapsed().as_nanos() as u64,
                    });
                }
                TenantRequest::Read { tenant, page } => {
                    if tenant >= tenants {
                        drop(reply_tx);
                        continue;
                    }
                    let a = cluster.read(vnow, tenant, page);
                    let lat = a.end - vnow;
                    vnow = a.end;
                    let _ = reply_tx.send(Reply {
                        virtual_ns: lat,
                        wall_ns: wall0.elapsed().as_nanos() as u64,
                    });
                }
                TenantRequest::Pump => {
                    vnow += PUMP_TICK;
                    let _ = reply_tx.send(Reply {
                        virtual_ns: 0,
                        wall_ns: wall0.elapsed().as_nanos() as u64,
                    });
                }
                TenantRequest::Shutdown => break,
            }
            cluster.advance(vnow);
        }
        cluster
    });
    let pump_stop = Arc::new(AtomicBool::new(false));
    let pump_tx = tx.clone();
    let stop = pump_stop.clone();
    let pump_join = thread::spawn(move || {
        while !stop.load(Ordering::Relaxed) {
            let (rtx, _rrx) = mpsc::channel();
            if pump_tx.send((TenantRequest::Pump, rtx)).is_err() {
                break; // leader gone
            }
            thread::sleep(PUMP_INTERVAL);
        }
    });
    TenantServeHandle {
        tx,
        reply: Mutex::new(ReplyLane::new()),
        tenants: specs_len,
        join: Some(join),
        pump_stop,
        pump_join: Some(pump_join),
    }
}

impl TenantServeHandle {
    /// Submit a request and wait for its completion (pooled reply
    /// channel — no allocation per call). An unknown tenant id fails
    /// fast with `None` without reaching the leader; the leader keeps
    /// its own guard for `submit` callers.
    pub fn call(&self, req: TenantRequest) -> Option<Reply> {
        match req {
            TenantRequest::Shutdown => {
                // the leader exits without replying; a throwaway channel
                // disconnects so this returns None instead of blocking
                let (rtx, rrx) = mpsc::channel();
                self.tx.send((req, rtx)).ok()?;
                return rrx.recv().ok();
            }
            TenantRequest::Write { tenant, .. }
            | TenantRequest::Read { tenant, .. }
                if tenant >= self.tenants =>
            {
                return None;
            }
            _ => {}
        }
        let mut lane = lock_lane(&self.reply)?;
        let addr = lane.addr()?;
        self.tx.send((req, addr)).ok()?;
        lane.recv()
    }

    /// Fire-and-forget submit returning the reply channel.
    pub fn submit(
        &self,
        req: TenantRequest,
    ) -> Option<mpsc::Receiver<Reply>> {
        let (rtx, rrx) = mpsc::channel();
        self.tx.send((req, rtx)).ok()?;
        Some(rrx)
    }

    fn stop_threads(&mut self) -> Option<TenantCluster> {
        self.pump_stop.store(true, Ordering::Relaxed);
        let (rtx, _rrx) = mpsc::channel();
        let _ = self.tx.send((TenantRequest::Shutdown, rtx));
        let cluster = self.join.take().and_then(|j| j.join().ok());
        if let Some(p) = self.pump_join.take() {
            let _ = p.join();
        }
        cluster
    }

    /// Stop the group and return the final multi-tenant cluster state.
    pub fn shutdown(mut self) -> Option<TenantCluster> {
        self.stop_threads()
    }
}

impl Drop for TenantServeHandle {
    fn drop(&mut self) {
        let _ = self.stop_threads();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> Config {
        let mut cfg = Config::default();
        cfg.cluster.nodes = 3;
        cfg.valet.mr_block_bytes = 1 << 20;
        cfg.valet.min_pool_pages = 256;
        cfg.valet.max_pool_pages = 1024;
        cfg
    }

    #[test]
    fn serve_roundtrip() {
        let h = spawn(&cfg(), BackendKind::Valet);
        let w = h.call(Request::Write { page: 0, bytes: 65536 }).unwrap();
        assert!(w.virtual_ns > 0);
        let r = h.call(Request::Read { page: 0 }).unwrap();
        // local mempool hit: a few µs of virtual time
        assert!(r.virtual_ns < 100_000, "{}", r.virtual_ns);
        let cluster = h.shutdown().unwrap();
        assert_eq!(cluster.backend.metrics().local_hits, 1);
    }

    #[test]
    fn concurrent_submitters() {
        let h = spawn(&cfg(), BackendKind::Valet);
        let rxs: Vec<_> = (0..16u64)
            .map(|i| {
                h.submit(Request::Write { page: i * 16, bytes: 65536 })
                    .unwrap()
            })
            .collect();
        for rx in rxs {
            assert!(rx.recv().unwrap().virtual_ns > 0);
        }
    }

    #[test]
    fn per_thread_clients_share_one_leader() {
        let h = spawn(&cfg(), BackendKind::Valet);
        let _ = h.call(Request::Write { page: 0, bytes: 65536 }).unwrap();
        let clients: Vec<_> = (0..4).map(|_| h.client()).collect();
        let joins: Vec<_> = clients
            .into_iter()
            .map(|c| {
                std::thread::spawn(move || {
                    let mut hits = 0;
                    for _ in 0..50 {
                        let r = c.call(Request::Read { page: 0 }).unwrap();
                        if r.virtual_ns < 100_000 {
                            hits += 1;
                        }
                    }
                    hits
                })
            })
            .collect();
        let total: u64 = joins.into_iter().map(|j| j.join().unwrap()).sum();
        assert_eq!(total, 200, "all reads must be local hits");
        let cluster = h.shutdown().unwrap();
        assert_eq!(cluster.backend.metrics().local_hits, 200);
    }

    #[test]
    fn pump_ticks_advance_background_work() {
        let h = spawn(&cfg(), BackendKind::Valet);
        let _ = h.call(Request::Write { page: 0, bytes: 65536 }).unwrap();
        // drive enough virtual time past the connection+mapping window
        // deterministically (300 ticks × 1 ms > 263 ms)
        for _ in 0..300 {
            let _ = h.call(Request::Pump).unwrap();
        }
        let cluster = h.shutdown().unwrap();
        use crate::backends::valet::ValetBackend;
        let be = cluster
            .backend
            .as_any()
            .downcast_ref::<ValetBackend>()
            .expect("valet backend behind the trait object");
        assert_eq!(be.mapped_units(), 1);
        assert_eq!(be.staged_bytes(), 0, "staging must drain in background");
        assert_eq!(be.coordinator().pending_write_sets(), 0);
    }

    #[test]
    fn drop_shuts_down_cleanly() {
        let h = spawn(&cfg(), BackendKind::LinuxSwap);
        let _ = h.call(Request::Write { page: 0, bytes: 4096 });
        drop(h); // must not hang
    }

    #[test]
    fn sharded_roundtrip_routes_by_page() {
        let h = spawn_sharded(&cfg(), 2);
        assert_eq!(h.shards(), 2);
        // blocks 0 and 1 land on different shards
        assert_ne!(h.shard_of(0), h.shard_of(16));
        let w0 = h.call(Request::Write { page: 0, bytes: 65536 }).unwrap();
        assert!(w0.virtual_ns > 0);
        let w1 = h.call(Request::Write { page: 16, bytes: 65536 }).unwrap();
        assert!(w1.virtual_ns > 0);
        let r0 = h.call(Request::Read { page: 0 }).unwrap();
        assert!(r0.virtual_ns < 100_000, "{}", r0.virtual_ns);
        let r1 = h.call(Request::Read { page: 16 }).unwrap();
        assert!(r1.virtual_ns < 100_000, "{}", r1.virtual_ns);
        let out = h.shutdown().unwrap();
        let m = out.engine.combined_metrics();
        assert_eq!(m.local_hits, 2);
        // each shard served exactly one hit
        for s in out.engine.shards() {
            assert_eq!(s.metrics.local_hits, 1);
        }
    }

    #[test]
    fn sharded_write_spanning_stripes_fans_out() {
        let h = spawn_sharded(&cfg(), 2);
        // 2 stripes in one request → one piece per shard
        let w = h
            .call(Request::Write { page: 0, bytes: 2 * 16 * 4096 })
            .unwrap();
        assert!(w.virtual_ns > 0);
        // both halves read back as local hits from their shards
        let a = h.call(Request::Read { page: 3 }).unwrap();
        let b = h.call(Request::Read { page: 19 }).unwrap();
        assert!(a.virtual_ns < 100_000);
        assert!(b.virtual_ns < 100_000);
        let out = h.shutdown().unwrap();
        for s in out.engine.shards() {
            assert_eq!(s.metrics.write_latency.count(), 1);
        }
    }

    #[test]
    fn sharded_background_drains_via_pump_broadcast() {
        let h = spawn_sharded(&cfg(), 2);
        let _ = h
            .call(Request::Write { page: 0, bytes: 2 * 16 * 4096 })
            .unwrap();
        // deterministically drive both workers past the mapping window
        for _ in 0..300 {
            let _ = h.call(Request::Pump).unwrap();
        }
        let out = h.shutdown().unwrap();
        assert_eq!(out.engine.pending_write_sets(), 0);
        assert_eq!(out.engine.staged_bytes(), 0);
        assert!(out.engine.mapped_units() >= 1);
    }

    #[test]
    fn sharded_parallel_clients_hit_their_shards() {
        let h = spawn_sharded(&cfg(), 2);
        for blk in 0..4u64 {
            let _ = h
                .call(Request::Write { page: blk * 16, bytes: 65536 })
                .unwrap();
        }
        let joins: Vec<_> = (0..4u64)
            .map(|blk| {
                let c = h.client();
                std::thread::spawn(move || {
                    for _ in 0..25 {
                        let r = c
                            .call(Request::Read { page: blk * 16 })
                            .unwrap();
                        assert!(r.virtual_ns < 100_000);
                    }
                })
            })
            .collect();
        for j in joins {
            j.join().unwrap();
        }
        let out = h.shutdown().unwrap();
        assert_eq!(out.engine.combined_metrics().local_hits, 100);
    }

    #[test]
    fn sharded_sync_mode_writes_go_remote() {
        // Valet-RemoteOnly ablation (no mempool): the shard workers must
        // take the synchronous write path like the single-driver spawn,
        // not spin on an unusable 1-slot pool.
        let mut cfg = cfg();
        cfg.valet.min_pool_pages = 0;
        cfg.valet.max_pool_pages = 0;
        let h = spawn_sharded(&cfg, 2);
        let w = h.call(Request::Write { page: 0, bytes: 65536 }).unwrap();
        // the first sync write pays connection + mapping (~263 ms)
        assert!(w.virtual_ns > 200_000_000, "{}", w.virtual_ns);
        drop(h);
    }

    #[test]
    fn sharded_drop_shuts_down_cleanly() {
        let h = spawn_sharded(&cfg(), 4);
        let _ = h.call(Request::Write { page: 0, bytes: 4096 });
        drop(h); // must not hang
    }

    #[test]
    fn tenant_serve_roundtrip_keeps_tenants_separate() {
        let specs = [TenantSpec { weight: 1, min_pages: 64 }; 2];
        let h = spawn_tenants(&cfg(), &specs);
        let w0 = h
            .call(TenantRequest::Write { tenant: 0, page: 0, bytes: 65536 })
            .unwrap();
        assert!(w0.virtual_ns > 0);
        let w1 = h
            .call(TenantRequest::Write { tenant: 1, page: 0, bytes: 65536 })
            .unwrap();
        assert!(w1.virtual_ns > 0);
        let r0 = h.call(TenantRequest::Read { tenant: 0, page: 0 }).unwrap();
        assert!(r0.virtual_ns < 100_000, "{}", r0.virtual_ns);
        // deterministically drive the background past the mapping window
        for _ in 0..300 {
            let _ = h.call(TenantRequest::Pump).unwrap();
        }
        let cluster = h.shutdown().unwrap();
        // page 0 exists in both address spaces, independently
        assert_eq!(cluster.group.coordinator(0).metrics().local_hits, 1);
        assert_eq!(cluster.group.coordinator(1).metrics().local_hits, 0);
        assert_eq!(cluster.group.coordinator(0).pending_write_sets(), 0);
        assert_eq!(cluster.group.coordinator(1).pending_write_sets(), 0);
        assert!(cluster.group.arbiter().leased_total() > 0);
    }

    #[test]
    fn unknown_tenant_id_fails_the_call_not_the_server() {
        let specs = [TenantSpec { weight: 1, min_pages: 64 }; 2];
        let h = spawn_tenants(&cfg(), &specs);
        // invalid tenant: the call fails (None), the leader survives
        assert!(h
            .call(TenantRequest::Write { tenant: 5, page: 0, bytes: 4096 })
            .is_none());
        assert!(h.call(TenantRequest::Read { tenant: 9, page: 0 }).is_none());
        // valid tenants still served afterwards
        let w = h
            .call(TenantRequest::Write { tenant: 1, page: 0, bytes: 4096 })
            .unwrap();
        assert!(w.virtual_ns > 0);
        assert!(h.shutdown().is_some());
    }

    #[test]
    fn tenant_serve_drop_shuts_down_cleanly() {
        let specs = [TenantSpec::default()];
        let h = spawn_tenants(&cfg(), &specs);
        let _ = h.call(TenantRequest::Write {
            tenant: 0,
            page: 0,
            bytes: 4096,
        });
        drop(h); // must not hang
    }
}
