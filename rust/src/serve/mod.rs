//! Live serving mode: the Valet coordinator as a running multi-threaded
//! process (std::thread + mpsc — no tokio in this offline build). One
//! leader thread owns the block-device front-end; a dedicated
//! remote-sender driver thread keeps the coordinator's background
//! pipeline (staging drain, mempool resize) moving exactly like §4.1's
//! "Remote Sender Thread", even when no requests arrive; client threads
//! submit read/write requests through a channel.
//!
//! Both this mode and the simulated experiments drive the SAME
//! implementation of the Figure-6 flow: the leader's requests land in
//! [`crate::coordinator::Coordinator`] via the Valet backend, so there is
//! no separate "live" code path to drift out of sync. The multi-tenant
//! entry ([`spawn_tenants`]) serves N containers the same way: requests
//! carry a tenant id, and the [`crate::arbiter::HostArbiter`] runs
//! behind the same driver thread, rebalancing leases on every Pump tick.
//!
//! This mode demonstrates the *software organization* (Figure 6) with
//! real concurrency; the latency numbers still come from the calibrated
//! virtual-time model (a request's virtual completion is computed by the
//! same coordinator code), so `serve` reports both wall-clock and
//! virtual-time stats.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use crate::arbiter::{TenantId, TenantSpec};
use crate::cluster::{Cluster, TenantCluster};
use crate::config::{BackendKind, Config};
use crate::sim::{ms, Ns};

/// A request to the device.
#[derive(Clone, Copy, Debug)]
pub enum Request {
    /// Write `bytes` at `page`.
    Write {
        /// First page.
        page: u64,
        /// Length in bytes.
        bytes: u64,
    },
    /// Read one page.
    Read {
        /// Page to read.
        page: u64,
    },
    /// Advance the background pipeline by one virtual tick (issued by
    /// the remote-sender driver thread; also available to tests that
    /// want deterministic background progress).
    Pump,
    /// Stop serving.
    Shutdown,
}

/// Completion record returned to the submitter.
#[derive(Clone, Copy, Debug)]
pub struct Reply {
    /// Virtual-time latency of the request (calibrated model).
    pub virtual_ns: Ns,
    /// Wall-clock service time in the coordinator.
    pub wall_ns: u64,
}

/// Handle to a running coordinator.
pub struct ServeHandle {
    tx: mpsc::Sender<(Request, mpsc::Sender<Reply>)>,
    join: Option<thread::JoinHandle<Cluster>>,
    pump_stop: Arc<AtomicBool>,
    pump_join: Option<thread::JoinHandle<()>>,
}

/// Virtual time the background pipeline advances per Pump tick.
const PUMP_TICK: Ns = ms(1);

/// Wall-clock interval between the driver thread's Pump ticks.
const PUMP_INTERVAL: Duration = Duration::from_millis(1);

/// Spawn the coordinator's leader thread plus the remote-sender driver.
pub fn spawn(cfg: &Config, kind: BackendKind) -> ServeHandle {
    let cfg = cfg.clone();
    let (tx, rx) = mpsc::channel::<(Request, mpsc::Sender<Reply>)>();
    let join = thread::spawn(move || {
        let mut cluster = Cluster::new(&cfg, kind);
        let mut vnow: Ns = 0;
        for (req, reply_tx) in rx.iter() {
            let wall0 = Instant::now();
            match req {
                Request::Write { page, bytes } => {
                    let a = cluster.backend.write(
                        &mut cluster.state,
                        vnow,
                        page,
                        bytes,
                    );
                    let lat = a.end - vnow;
                    vnow = a.end;
                    let _ = reply_tx.send(Reply {
                        virtual_ns: lat,
                        wall_ns: wall0.elapsed().as_nanos() as u64,
                    });
                }
                Request::Read { page } => {
                    let a = cluster.backend.read(
                        &mut cluster.state,
                        vnow,
                        page,
                    );
                    let lat = a.end - vnow;
                    vnow = a.end;
                    let _ = reply_tx.send(Reply {
                        virtual_ns: lat,
                        wall_ns: wall0.elapsed().as_nanos() as u64,
                    });
                }
                Request::Pump => {
                    // The remote-sender driver: wall-clock time passing
                    // maps to virtual time, so staged write sets drain
                    // and in-flight batches complete between requests —
                    // the live analogue of the simulated sender thread.
                    vnow += PUMP_TICK;
                    let _ = reply_tx.send(Reply {
                        virtual_ns: 0,
                        wall_ns: wall0.elapsed().as_nanos() as u64,
                    });
                }
                Request::Shutdown => break,
            }
            cluster.advance(vnow);
        }
        cluster
    });
    // Remote-sender driver: ticks the leader with Pump requests until
    // shutdown, keeping the background pipeline live without clients.
    let pump_stop = Arc::new(AtomicBool::new(false));
    let pump_tx = tx.clone();
    let stop = pump_stop.clone();
    let pump_join = thread::spawn(move || {
        while !stop.load(Ordering::Relaxed) {
            let (rtx, _rrx) = mpsc::channel();
            if pump_tx.send((Request::Pump, rtx)).is_err() {
                break; // leader gone
            }
            thread::sleep(PUMP_INTERVAL);
        }
    });
    ServeHandle {
        tx,
        join: Some(join),
        pump_stop,
        pump_join: Some(pump_join),
    }
}

impl ServeHandle {
    /// Submit a request and wait for its completion.
    pub fn call(&self, req: Request) -> Option<Reply> {
        let (rtx, rrx) = mpsc::channel();
        self.tx.send((req, rtx)).ok()?;
        rrx.recv().ok()
    }

    /// Fire-and-forget submit returning the reply channel (for
    /// concurrent submitters).
    pub fn submit(&self, req: Request) -> Option<mpsc::Receiver<Reply>> {
        let (rtx, rrx) = mpsc::channel();
        self.tx.send((req, rtx)).ok()?;
        Some(rrx)
    }

    fn stop_threads(&mut self) -> Option<Cluster> {
        self.pump_stop.store(true, Ordering::Relaxed);
        let (rtx, _rrx) = mpsc::channel();
        let _ = self.tx.send((Request::Shutdown, rtx));
        let cluster = self.join.take().and_then(|j| j.join().ok());
        if let Some(p) = self.pump_join.take() {
            let _ = p.join();
        }
        cluster
    }

    /// Stop the coordinator and return the final cluster state.
    pub fn shutdown(mut self) -> Option<Cluster> {
        self.stop_threads()
    }
}

impl Drop for ServeHandle {
    fn drop(&mut self) {
        let _ = self.stop_threads();
    }
}

// ---------------------------------------------------------------------
// Multi-tenant serving
// ---------------------------------------------------------------------

/// A request to a multi-tenant device: the same vocabulary as
/// [`Request`] plus the tenant id the block I/O belongs to (see
/// [`spawn_tenants`]).
#[derive(Clone, Copy, Debug)]
pub enum TenantRequest {
    /// Write `bytes` at `page` of `tenant`'s address space.
    Write {
        /// Issuing tenant.
        tenant: TenantId,
        /// First page.
        page: u64,
        /// Length in bytes.
        bytes: u64,
    },
    /// Read one page of `tenant`'s address space.
    Read {
        /// Issuing tenant.
        tenant: TenantId,
        /// Page to read.
        page: u64,
    },
    /// Advance the background pipelines (and one arbitration round) by
    /// one virtual tick.
    Pump,
    /// Stop serving.
    Shutdown,
}

/// Handle to a running multi-tenant coordinator group.
pub struct TenantServeHandle {
    tx: mpsc::Sender<(TenantRequest, mpsc::Sender<Reply>)>,
    join: Option<thread::JoinHandle<TenantCluster>>,
    pump_stop: Arc<AtomicBool>,
    pump_join: Option<thread::JoinHandle<()>>,
}

/// Spawn the leader thread for a [`TenantCluster`] (one coordinator per
/// spec behind the shared [`crate::arbiter::HostArbiter`]) plus the same
/// remote-sender driver thread as [`spawn`]. The arbiter lives behind
/// the leader: every Pump tick drains all tenants and runs one
/// arbitration round, so leases keep following demand even when no
/// requests arrive.
pub fn spawn_tenants(cfg: &Config, specs: &[TenantSpec]) -> TenantServeHandle {
    let cfg = cfg.clone();
    let specs = specs.to_vec();
    let (tx, rx) = mpsc::channel::<(TenantRequest, mpsc::Sender<Reply>)>();
    let join = thread::spawn(move || {
        let mut cluster = TenantCluster::new(&cfg, &specs);
        let mut vnow: Ns = 0;
        for (req, reply_tx) in rx.iter() {
            let wall0 = Instant::now();
            // An unknown tenant id must not panic the leader: drop the
            // reply channel instead, so the caller's `call` returns
            // None while the server keeps serving valid tenants.
            let tenants = cluster.group.tenants();
            match req {
                TenantRequest::Write { tenant, page, bytes } => {
                    if tenant >= tenants {
                        drop(reply_tx);
                        continue;
                    }
                    let a = cluster.write(vnow, tenant, page, bytes);
                    let lat = a.end - vnow;
                    vnow = a.end;
                    let _ = reply_tx.send(Reply {
                        virtual_ns: lat,
                        wall_ns: wall0.elapsed().as_nanos() as u64,
                    });
                }
                TenantRequest::Read { tenant, page } => {
                    if tenant >= tenants {
                        drop(reply_tx);
                        continue;
                    }
                    let a = cluster.read(vnow, tenant, page);
                    let lat = a.end - vnow;
                    vnow = a.end;
                    let _ = reply_tx.send(Reply {
                        virtual_ns: lat,
                        wall_ns: wall0.elapsed().as_nanos() as u64,
                    });
                }
                TenantRequest::Pump => {
                    vnow += PUMP_TICK;
                    let _ = reply_tx.send(Reply {
                        virtual_ns: 0,
                        wall_ns: wall0.elapsed().as_nanos() as u64,
                    });
                }
                TenantRequest::Shutdown => break,
            }
            cluster.advance(vnow);
        }
        cluster
    });
    let pump_stop = Arc::new(AtomicBool::new(false));
    let pump_tx = tx.clone();
    let stop = pump_stop.clone();
    let pump_join = thread::spawn(move || {
        while !stop.load(Ordering::Relaxed) {
            let (rtx, _rrx) = mpsc::channel();
            if pump_tx.send((TenantRequest::Pump, rtx)).is_err() {
                break; // leader gone
            }
            thread::sleep(PUMP_INTERVAL);
        }
    });
    TenantServeHandle {
        tx,
        join: Some(join),
        pump_stop,
        pump_join: Some(pump_join),
    }
}

impl TenantServeHandle {
    /// Submit a request and wait for its completion.
    pub fn call(&self, req: TenantRequest) -> Option<Reply> {
        let (rtx, rrx) = mpsc::channel();
        self.tx.send((req, rtx)).ok()?;
        rrx.recv().ok()
    }

    /// Fire-and-forget submit returning the reply channel.
    pub fn submit(
        &self,
        req: TenantRequest,
    ) -> Option<mpsc::Receiver<Reply>> {
        let (rtx, rrx) = mpsc::channel();
        self.tx.send((req, rtx)).ok()?;
        Some(rrx)
    }

    fn stop_threads(&mut self) -> Option<TenantCluster> {
        self.pump_stop.store(true, Ordering::Relaxed);
        let (rtx, _rrx) = mpsc::channel();
        let _ = self.tx.send((TenantRequest::Shutdown, rtx));
        let cluster = self.join.take().and_then(|j| j.join().ok());
        if let Some(p) = self.pump_join.take() {
            let _ = p.join();
        }
        cluster
    }

    /// Stop the group and return the final multi-tenant cluster state.
    pub fn shutdown(mut self) -> Option<TenantCluster> {
        self.stop_threads()
    }
}

impl Drop for TenantServeHandle {
    fn drop(&mut self) {
        let _ = self.stop_threads();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> Config {
        let mut cfg = Config::default();
        cfg.cluster.nodes = 3;
        cfg.valet.mr_block_bytes = 1 << 20;
        cfg.valet.min_pool_pages = 256;
        cfg.valet.max_pool_pages = 1024;
        cfg
    }

    #[test]
    fn serve_roundtrip() {
        let h = spawn(&cfg(), BackendKind::Valet);
        let w = h.call(Request::Write { page: 0, bytes: 65536 }).unwrap();
        assert!(w.virtual_ns > 0);
        let r = h.call(Request::Read { page: 0 }).unwrap();
        // local mempool hit: a few µs of virtual time
        assert!(r.virtual_ns < 100_000, "{}", r.virtual_ns);
        let cluster = h.shutdown().unwrap();
        assert_eq!(cluster.backend.metrics().local_hits, 1);
    }

    #[test]
    fn concurrent_submitters() {
        let h = spawn(&cfg(), BackendKind::Valet);
        let rxs: Vec<_> = (0..16u64)
            .map(|i| {
                h.submit(Request::Write { page: i * 16, bytes: 65536 })
                    .unwrap()
            })
            .collect();
        for rx in rxs {
            assert!(rx.recv().unwrap().virtual_ns > 0);
        }
    }

    #[test]
    fn pump_ticks_advance_background_work() {
        let h = spawn(&cfg(), BackendKind::Valet);
        let _ = h.call(Request::Write { page: 0, bytes: 65536 }).unwrap();
        // drive enough virtual time past the connection+mapping window
        // deterministically (300 ticks × 1 ms > 263 ms)
        for _ in 0..300 {
            let _ = h.call(Request::Pump).unwrap();
        }
        let cluster = h.shutdown().unwrap();
        use crate::backends::valet::ValetBackend;
        let be = cluster
            .backend
            .as_any()
            .downcast_ref::<ValetBackend>()
            .expect("valet backend behind the trait object");
        assert_eq!(be.mapped_units(), 1);
        assert_eq!(be.staged_bytes(), 0, "staging must drain in background");
        assert_eq!(be.coordinator().pending_write_sets(), 0);
    }

    #[test]
    fn drop_shuts_down_cleanly() {
        let h = spawn(&cfg(), BackendKind::LinuxSwap);
        let _ = h.call(Request::Write { page: 0, bytes: 4096 });
        drop(h); // must not hang
    }

    #[test]
    fn tenant_serve_roundtrip_keeps_tenants_separate() {
        let specs = [TenantSpec { weight: 1, min_pages: 64 }; 2];
        let h = spawn_tenants(&cfg(), &specs);
        let w0 = h
            .call(TenantRequest::Write { tenant: 0, page: 0, bytes: 65536 })
            .unwrap();
        assert!(w0.virtual_ns > 0);
        let w1 = h
            .call(TenantRequest::Write { tenant: 1, page: 0, bytes: 65536 })
            .unwrap();
        assert!(w1.virtual_ns > 0);
        let r0 = h.call(TenantRequest::Read { tenant: 0, page: 0 }).unwrap();
        assert!(r0.virtual_ns < 100_000, "{}", r0.virtual_ns);
        // deterministically drive the background past the mapping window
        for _ in 0..300 {
            let _ = h.call(TenantRequest::Pump).unwrap();
        }
        let cluster = h.shutdown().unwrap();
        // page 0 exists in both address spaces, independently
        assert_eq!(cluster.group.coordinator(0).metrics().local_hits, 1);
        assert_eq!(cluster.group.coordinator(1).metrics().local_hits, 0);
        assert_eq!(cluster.group.coordinator(0).pending_write_sets(), 0);
        assert_eq!(cluster.group.coordinator(1).pending_write_sets(), 0);
        assert!(cluster.group.arbiter().leased_total() > 0);
    }

    #[test]
    fn unknown_tenant_id_fails_the_call_not_the_server() {
        let specs = [TenantSpec { weight: 1, min_pages: 64 }; 2];
        let h = spawn_tenants(&cfg(), &specs);
        // invalid tenant: the call fails (None), the leader survives
        assert!(h
            .call(TenantRequest::Write { tenant: 5, page: 0, bytes: 4096 })
            .is_none());
        assert!(h.call(TenantRequest::Read { tenant: 9, page: 0 }).is_none());
        // valid tenants still served afterwards
        let w = h
            .call(TenantRequest::Write { tenant: 1, page: 0, bytes: 4096 })
            .unwrap();
        assert!(w.virtual_ns > 0);
        assert!(h.shutdown().is_some());
    }

    #[test]
    fn tenant_serve_drop_shuts_down_cleanly() {
        let specs = [TenantSpec::default()];
        let h = spawn_tenants(&cfg(), &specs);
        let _ = h.call(TenantRequest::Write {
            tenant: 0,
            page: 0,
            bytes: 4096,
        });
        drop(h); // must not hang
    }
}
