//! Sender-driven migration protocol (§3.5, Figures 12–14): when a peer
//! node needs its memory back, the victim MR block is *moved* to a
//! less-pressured peer instead of deleted.
//!
//! Protocol roles: the **sender** (owner of the data) controls the whole
//! procedure — receivers are passive participants executing remote
//! procedures on control messages, which serializes the message flow and
//! removes ordering concerns. Timeline for one migration:
//!
//! ```text
//! src peer pressure → report to sender
//! sender: pick dest (query candidates; usually pre-connected)
//! sender: STOP writes to the block (park new write sets in mempool
//!         staging); reads continue against src
//! sender → src,dst: PREPARE (dst registers a fresh MR block)
//! src → dst: RDMA copy of the block (reads still allowed at src)
//! src → sender: COPY_DONE
//! sender: COMMIT — remap block to dst, flush parked writes to dst,
//!         src releases the MR block
//! ```
//!
//! The module provides the protocol as an explicit state machine
//! ([`MigrationSm`]) whose transitions are unit/property tested, plus
//! [`simulate`] which drives one instance against the fabric model and
//! returns the virtual-time milestones the backends need.

use crate::config::LatencyConfig;
use crate::mrpool::MrBlockId;
use crate::sim::Ns;
use crate::simnet::Fabric;
use crate::NodeId;

/// Protocol phases, in order.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MigState {
    /// Nothing in flight.
    Idle,
    /// Sender is querying candidate destinations.
    ChoosingDest,
    /// PREPARE sent; waiting for src+dst acks. Writes are parked from
    /// this point on.
    Preparing,
    /// Block copy src→dst in progress; reads allowed at src.
    Copying,
    /// COMMIT sent; waiting for ack; mapping switches on completion.
    Committing,
    /// Migration finished; parked writes flushed to dst.
    Done,
}

/// Events driving the state machine (control messages + local decisions).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MigEvent {
    /// Source peer reported memory pressure naming the victim block.
    PressureReport {
        /// Block to move.
        block: MrBlockId,
        /// Node it currently lives on.
        src: NodeId,
    },
    /// Sender chose the destination.
    DestChosen {
        /// Node the block moves to.
        dst: NodeId,
    },
    /// Both src and dst acknowledged PREPARE.
    PrepareAcked,
    /// Source finished copying the block into dst's new MR.
    CopyDone,
    /// Destination acknowledged COMMIT.
    CommitAcked,
    /// The chosen destination died before COMMIT: drop it and return to
    /// destination selection. Writes already parked stay parked (they
    /// re-park against the next destination and still flush exactly
    /// once, at the eventual COMMIT — the `parked-flush-once` law).
    DestLost,
}

/// Actions the protocol asks its host (the sender module) to perform.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MigAction {
    /// Query candidate peers' free memory (cost: one RTT per candidate
    /// unless pre-connected state is piggybacked).
    QueryCandidates,
    /// Park subsequent writes to the block; reads stay on src.
    StopWrites,
    /// Send PREPARE to src and dst.
    SendPrepare,
    /// Source starts the RDMA copy src→dst.
    StartCopy,
    /// Send COMMIT (remap to dst).
    SendCommit,
    /// Flush parked write sets to dst; resume normal writes.
    FlushParkedWrites,
}

/// Errors from illegal transitions (protocol bugs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BadTransition {
    /// State the machine was in.
    pub state: MigState,
    /// Event that did not apply.
    pub event: MigEvent,
}

/// One migration instance, sender-side.
#[derive(Clone, Debug)]
pub struct MigrationSm {
    state: MigState,
    /// Victim block.
    pub block: Option<MrBlockId>,
    /// Source peer.
    pub src: Option<NodeId>,
    /// Destination peer (chosen in ChoosingDest).
    pub dst: Option<NodeId>,
    /// The block is changing memory *tier*, not (only) node. Cross-tier
    /// moves may legally stay on the same node — a promotion/demotion
    /// between a peer's pooled slice and its DRAM; same-node same-tier
    /// destinations remain a protocol bug.
    cross_tier: bool,
}

impl Default for MigrationSm {
    fn default() -> Self {
        Self::new()
    }
}

impl MigrationSm {
    /// Fresh, idle machine.
    pub fn new() -> Self {
        MigrationSm {
            state: MigState::Idle,
            block: None,
            src: None,
            dst: None,
            cross_tier: false,
        }
    }

    /// Mark this migration as a cross-tier move (promotion/demotion):
    /// the destination may then equal the source node, since the block
    /// changes tier. Must be set before `DestChosen`.
    pub fn set_cross_tier(&mut self) {
        self.cross_tier = true;
    }

    /// Is this machine a cross-tier (promotion/demotion) move?
    pub fn is_cross_tier(&self) -> bool {
        self.cross_tier
    }

    /// Current phase.
    pub fn state(&self) -> MigState {
        self.state
    }

    /// Are writes to the block parked right now? (From PREPARE until the
    /// flush after COMMIT — Figure 12.)
    pub fn writes_parked(&self) -> bool {
        matches!(
            self.state,
            MigState::Preparing | MigState::Copying | MigState::Committing
        )
    }

    /// Are reads to the block served from src? (Any time before Done —
    /// "we allow read requests while migration is in progress".)
    pub fn reads_from_src(&self) -> bool {
        !matches!(self.state, MigState::Done | MigState::Idle)
    }

    /// Apply an event; returns the actions the sender must perform, in
    /// order, or an error on an illegal transition.
    pub fn on_event(
        &mut self,
        ev: MigEvent,
    ) -> Result<Vec<MigAction>, BadTransition> {
        use MigAction::*;
        use MigEvent::*;
        use MigState::*;
        let bad = |s: &Self| BadTransition {
            state: s.state,
            event: ev,
        };
        match (self.state, ev) {
            (Idle, PressureReport { block, src }) => {
                self.block = Some(block);
                self.src = Some(src);
                self.state = ChoosingDest;
                Ok(vec![QueryCandidates])
            }
            (ChoosingDest, DestChosen { dst }) => {
                if Some(dst) == self.src && !self.cross_tier {
                    // must move to a *different* node — unless the move
                    // is a tier change, which legally stays put
                    return Err(bad(self));
                }
                self.dst = Some(dst);
                self.state = Preparing;
                Ok(vec![StopWrites, SendPrepare])
            }
            (Preparing, PrepareAcked) => {
                self.state = Copying;
                Ok(vec![StartCopy])
            }
            (Copying, CopyDone) => {
                self.state = Committing;
                Ok(vec![SendCommit])
            }
            (Committing, CommitAcked) => {
                self.state = Done;
                Ok(vec![FlushParkedWrites])
            }
            (Preparing | Copying | Committing, DestLost) => {
                // crash-consistent re-target: back to destination
                // selection with the same block/src; parked writes are
                // retained by the host (they flush at the new COMMIT)
                self.dst = None;
                self.state = ChoosingDest;
                Ok(vec![QueryCandidates])
            }
            _ => Err(bad(self)),
        }
    }
}

/// Virtual-time milestones of one simulated migration.
#[derive(Clone, Copy, Debug)]
pub struct MigrationOutcome {
    /// Destination the block landed on.
    pub dst: NodeId,
    /// Writes to the block are parked during [park_from, done).
    pub park_from: Ns,
    /// Copy began (after prepare round trips).
    pub copy_start: Ns,
    /// Copy finished.
    pub copy_end: Ns,
    /// Protocol fully committed; parked writes flushed by this time.
    pub done: Ns,
    /// Control-message overhead (everything except the bulk copy).
    pub control_overhead: Ns,
}

/// One control-message round trip of the migration protocol: a small
/// two-sided message (verb base + receiver poke). Shared by [`simulate`]
/// and the pump-driven pipeline in
/// [`crate::coordinator::sender::RemoteSender`], so the oracle and the
/// live machine can never drift on the constant.
pub fn ctrl_rtt(lat: &LatencyConfig) -> Ns {
    2 * lat.rdma_write_base + lat.two_sided_extra
}

/// Drive one migration against the fabric: charges candidate queries,
/// prepare/commit round trips on the sender's NIC, the bulk copy on the
/// source's NIC, and connection setup if src↔dst were not yet connected
/// ("if the number of mapped remote memory block is larger than the
/// number of peer nodes, all connections are likely setup before" — we
/// model both cases).
///
/// Since the pump-driven reclaim pipeline landed this function is the
/// **test oracle**: `tests/reclaim.rs` pins that a single uncontended
/// migration through the live pipeline reproduces these virtual-time
/// milestones bit for bit.
#[allow(clippy::too_many_arguments)]
pub fn simulate(
    fabric: &mut Fabric,
    lat: &LatencyConfig,
    now: Ns,
    sender: NodeId,
    src: NodeId,
    dst: NodeId,
    block_bytes: u64,
    candidates_queried: u32,
) -> MigrationOutcome {
    // Control RTT (see [`ctrl_rtt`]).
    let ctrl_rtt = ctrl_rtt(lat);

    // 1. Candidate queries (serialized, sender → each candidate).
    let mut t = now + ctrl_rtt * candidates_queried as Ns;
    let queries_cost = t - now;

    // 2. Writes parked from here.
    let park_from = t;

    // 3. PREPARE to src and dst (parallel, bounded by the slower ack);
    //    make sure sender is connected to both (usually already true).
    let (c1, _) = fabric.ensure_connected(t, sender, src);
    let (c2, _) = fabric.ensure_connected(t, sender, dst);
    t = c1.max(c2) + ctrl_rtt;

    // 4. src↔dst connection for the copy (may be new).
    let (t_conn, _) = fabric.ensure_connected(t, src, dst);

    // 5. Bulk copy: the block moves in rdma_msg-sized messages from the
    //    source NIC. One big reservation approximates the pipelined send.
    let copy_start = t_conn;
    let copy = fabric.rdma_write(copy_start, src, dst, block_bytes);
    let copy_end = copy.end;

    // 6. COPY_DONE notification + COMMIT + ack.
    let done = copy_end + 2 * ctrl_rtt;

    MigrationOutcome {
        dst,
        park_from,
        copy_start,
        copy_end,
        done,
        control_overhead: queries_cost + (done - copy_end) + ctrl_rtt,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn full_happy_path(sm: &mut MigrationSm) {
        sm.on_event(MigEvent::PressureReport { block: 7, src: 1 })
            .unwrap();
        sm.on_event(MigEvent::DestChosen { dst: 2 }).unwrap();
        sm.on_event(MigEvent::PrepareAcked).unwrap();
        sm.on_event(MigEvent::CopyDone).unwrap();
        sm.on_event(MigEvent::CommitAcked).unwrap();
    }

    #[test]
    fn happy_path_reaches_done_with_expected_actions() {
        let mut sm = MigrationSm::new();
        let a1 = sm
            .on_event(MigEvent::PressureReport { block: 7, src: 1 })
            .unwrap();
        assert_eq!(a1, vec![MigAction::QueryCandidates]);
        assert_eq!(sm.state(), MigState::ChoosingDest);
        let a2 = sm.on_event(MigEvent::DestChosen { dst: 2 }).unwrap();
        assert_eq!(a2, vec![MigAction::StopWrites, MigAction::SendPrepare]);
        assert!(sm.writes_parked());
        assert!(sm.reads_from_src());
        let a3 = sm.on_event(MigEvent::PrepareAcked).unwrap();
        assert_eq!(a3, vec![MigAction::StartCopy]);
        assert!(sm.writes_parked());
        let a4 = sm.on_event(MigEvent::CopyDone).unwrap();
        assert_eq!(a4, vec![MigAction::SendCommit]);
        let a5 = sm.on_event(MigEvent::CommitAcked).unwrap();
        assert_eq!(a5, vec![MigAction::FlushParkedWrites]);
        assert_eq!(sm.state(), MigState::Done);
        assert!(!sm.writes_parked());
        assert!(!sm.reads_from_src());
    }

    #[test]
    fn dest_must_differ_from_src() {
        let mut sm = MigrationSm::new();
        sm.on_event(MigEvent::PressureReport { block: 7, src: 1 })
            .unwrap();
        assert!(sm.on_event(MigEvent::DestChosen { dst: 1 }).is_err());
    }

    #[test]
    fn cross_tier_moves_may_stay_on_the_same_node() {
        // A promotion/demotion between a node's pooled slice and its
        // DRAM is a legal same-node migration; the whole park/copy/
        // commit protocol still applies (the data physically moves).
        let mut sm = MigrationSm::new();
        sm.on_event(MigEvent::PressureReport { block: 7, src: 1 })
            .unwrap();
        sm.set_cross_tier();
        assert!(sm.is_cross_tier());
        let a = sm.on_event(MigEvent::DestChosen { dst: 1 }).unwrap();
        assert_eq!(a, vec![MigAction::StopWrites, MigAction::SendPrepare]);
        assert!(sm.writes_parked());
    }

    #[test]
    fn dest_lost_returns_to_choosing_and_still_commits_once() {
        let mut sm = MigrationSm::new();
        sm.on_event(MigEvent::PressureReport { block: 7, src: 1 })
            .unwrap();
        sm.on_event(MigEvent::DestChosen { dst: 2 }).unwrap();
        sm.on_event(MigEvent::PrepareAcked).unwrap();
        assert_eq!(sm.state(), MigState::Copying);
        // dst dies mid-copy: back to ChoosingDest, dst cleared, writes
        // no longer parked *by the machine* (the host retains them)
        let a = sm.on_event(MigEvent::DestLost).unwrap();
        assert_eq!(a, vec![MigAction::QueryCandidates]);
        assert_eq!(sm.state(), MigState::ChoosingDest);
        assert_eq!(sm.dst, None);
        assert_eq!(sm.block, Some(7));
        assert_eq!(sm.src, Some(1));
        // the machine completes normally against a fresh destination,
        // flushing parked writes exactly once
        sm.on_event(MigEvent::DestChosen { dst: 3 }).unwrap();
        sm.on_event(MigEvent::PrepareAcked).unwrap();
        sm.on_event(MigEvent::CopyDone).unwrap();
        let last = sm.on_event(MigEvent::CommitAcked).unwrap();
        assert_eq!(last, vec![MigAction::FlushParkedWrites]);
        // DestLost is illegal outside the parked window
        assert!(sm.on_event(MigEvent::DestLost).is_err());
        let mut idle = MigrationSm::new();
        assert!(idle.on_event(MigEvent::DestLost).is_err());
    }

    #[test]
    fn out_of_order_events_are_rejected() {
        let mut sm = MigrationSm::new();
        assert!(sm.on_event(MigEvent::CopyDone).is_err());
        sm.on_event(MigEvent::PressureReport { block: 1, src: 0 })
            .unwrap();
        assert!(sm.on_event(MigEvent::PrepareAcked).is_err());
        assert!(sm.on_event(MigEvent::CommitAcked).is_err());
    }

    #[test]
    fn reads_allowed_during_entire_copy() {
        let mut sm = MigrationSm::new();
        sm.on_event(MigEvent::PressureReport { block: 1, src: 0 })
            .unwrap();
        sm.on_event(MigEvent::DestChosen { dst: 2 }).unwrap();
        sm.on_event(MigEvent::PrepareAcked).unwrap();
        assert_eq!(sm.state(), MigState::Copying);
        assert!(sm.reads_from_src());
    }

    #[test]
    fn prop_no_event_sequence_skips_park_window() {
        // Any event sequence that reaches Done must have passed through
        // a state where writes were parked (no lost-write window).
        prop::check("migration park window", |rng| {
            let mut sm = MigrationSm::new();
            let mut parked_seen = false;
            let events = [
                MigEvent::PressureReport { block: 1, src: 0 },
                MigEvent::DestChosen { dst: 2 },
                MigEvent::PrepareAcked,
                MigEvent::CopyDone,
                MigEvent::CommitAcked,
            ];
            for _ in 0..40 {
                let ev = events[rng.below_usize(events.len())];
                let _ = sm.on_event(ev);
                parked_seen |= sm.writes_parked();
                if sm.state() == MigState::Done {
                    break;
                }
            }
            if sm.state() == MigState::Done {
                assert!(parked_seen);
            }
        });
    }

    #[test]
    fn simulate_orders_milestones() {
        use crate::config::LatencyConfig;
        let lat = LatencyConfig::default();
        let mut fabric = Fabric::new(4, lat.clone());
        let out = simulate(&mut fabric, &lat, 1000, 0, 1, 2, 1 << 30, 2);
        assert!(out.park_from >= 1000);
        assert!(out.copy_start >= out.park_from);
        assert!(out.copy_end > out.copy_start);
        assert!(out.done > out.copy_end);
        assert_eq!(out.dst, 2);
        // copying 1 GB dominates control overhead
        assert!(out.copy_end - out.copy_start > out.control_overhead);
    }

    #[test]
    fn simulate_reuses_existing_connections() {
        use crate::config::LatencyConfig;
        let lat = LatencyConfig::default();
        let mut fabric = Fabric::new(4, lat.clone());
        // Pre-connect everything.
        let (mut t, _) = fabric.ensure_connected(0, 0, 1);
        t = fabric.ensure_connected(t, 0, 2).0;
        t = fabric.ensure_connected(t, 1, 2).0;
        let pre = simulate(&mut fabric, &lat, t, 0, 1, 2, 1 << 20, 2);
        let mut fabric2 = Fabric::new(4, lat.clone());
        let cold = simulate(&mut fabric2, &lat, t, 0, 1, 2, 1 << 20, 2);
        assert!(
            pre.done - t < cold.done - t,
            "pre-connected migration must be faster"
        );
        let _ = full_happy_path; // silence unused in some cfgs
    }
}
