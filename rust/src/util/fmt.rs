//! Human-readable formatting for byte sizes, durations and table output —
//! used by the CLI and the `valet-bench` table printers.

/// Format a byte count with binary units ("1.50 GiB").
pub fn bytes(b: u64) -> String {
    const UNITS: [&str; 6] = ["B", "KiB", "MiB", "GiB", "TiB", "PiB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Format nanoseconds adaptively ("12.3 µs", "4.56 ms", "1.23 s").
pub fn ns(t: u64) -> String {
    match t {
        0..=999 => format!("{t} ns"),
        1_000..=999_999 => format!("{:.2} µs", t as f64 / 1e3),
        1_000_000..=999_999_999 => format!("{:.2} ms", t as f64 / 1e6),
        _ => format!("{:.2} s", t as f64 / 1e9),
    }
}

/// Format microseconds as the paper's tables do (µsec, 2 decimals).
pub fn usec(t_ns: u64) -> String {
    format!("{:.2}", t_ns as f64 / 1e3)
}

/// Render rows as a fixed-width ASCII table with a header.
pub fn table(header: &[&str], rows: &[Vec<String>]) -> String {
    let ncol = header.len();
    let mut w: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate().take(ncol) {
            w[i] = w[i].max(cell.len());
        }
    }
    let mut out = String::new();
    let sep = |out: &mut String| {
        for width in &w {
            out.push('+');
            out.push_str(&"-".repeat(width + 2));
        }
        out.push_str("+\n");
    };
    sep(&mut out);
    out.push('|');
    for (i, h) in header.iter().enumerate() {
        out.push_str(&format!(" {:<width$} |", h, width = w[i]));
    }
    out.push('\n');
    sep(&mut out);
    for row in rows {
        out.push('|');
        for (i, cell) in row.iter().enumerate().take(ncol) {
            out.push_str(&format!(" {:<width$} |", cell, width = w[i]));
        }
        out.push('\n');
    }
    sep(&mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_units() {
        assert_eq!(bytes(512), "512 B");
        assert_eq!(bytes(1536), "1.50 KiB");
        assert_eq!(bytes(3 * 1024 * 1024 * 1024), "3.00 GiB");
    }

    #[test]
    fn ns_units() {
        assert_eq!(ns(12), "12 ns");
        assert_eq!(ns(12_300), "12.30 µs");
        assert_eq!(ns(4_560_000), "4.56 ms");
        assert_eq!(ns(1_230_000_000), "1.23 s");
    }

    #[test]
    fn table_renders_all_rows() {
        let t = table(
            &["a", "long header"],
            &[
                vec!["1".into(), "2".into()],
                vec!["333".into(), "4".into()],
            ],
        );
        assert!(t.contains("long header"));
        assert!(t.lines().count() >= 6);
        assert!(t.contains("333"));
    }
}
