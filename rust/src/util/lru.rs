//! Generic O(1) LRU list: HashMap + slab-backed intrusive doubly-linked
//! list. Shared by the container resident-set model and the Valet local
//! mempool replacement policy ("For replacement policy, we use LRU in our
//! prototype", §4.1).

use std::collections::HashMap;
use std::hash::Hash;

const NIL: usize = usize::MAX;

#[derive(Clone, Debug)]
struct Node<K> {
    key: K,
    prev: usize,
    next: usize,
}

/// LRU ordering over keys; front = most recently used.
#[derive(Clone, Debug)]
pub struct Lru<K: Hash + Eq + Copy> {
    map: HashMap<K, usize>,
    nodes: Vec<Node<K>>,
    free: Vec<usize>,
    head: usize,
    tail: usize,
}

impl<K: Hash + Eq + Copy> Default for Lru<K> {
    fn default() -> Self {
        Self::new()
    }
}

impl<K: Hash + Eq + Copy> Lru<K> {
    /// Empty list.
    pub fn new() -> Self {
        Lru {
            map: HashMap::new(),
            nodes: Vec::new(),
            free: Vec::new(),
            head: NIL,
            tail: NIL,
        }
    }

    /// Number of keys tracked.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    /// True if empty.
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Is `k` present?
    pub fn contains(&self, k: &K) -> bool {
        self.map.contains_key(k)
    }

    fn unlink(&mut self, i: usize) {
        let (p, n) = (self.nodes[i].prev, self.nodes[i].next);
        if p != NIL {
            self.nodes[p].next = n;
        } else {
            self.head = n;
        }
        if n != NIL {
            self.nodes[n].prev = p;
        } else {
            self.tail = p;
        }
    }

    fn link_front(&mut self, i: usize) {
        self.nodes[i].prev = NIL;
        self.nodes[i].next = self.head;
        if self.head != NIL {
            self.nodes[self.head].prev = i;
        }
        self.head = i;
        if self.tail == NIL {
            self.tail = i;
        }
    }

    /// Insert `k` as most-recently-used (or move it to front if present).
    /// Returns true if the key was newly inserted.
    pub fn touch(&mut self, k: K) -> bool {
        if let Some(&i) = self.map.get(&k) {
            if self.head != i {
                self.unlink(i);
                self.link_front(i);
            }
            false
        } else {
            let i = if let Some(i) = self.free.pop() {
                self.nodes[i] = Node {
                    key: k,
                    prev: NIL,
                    next: NIL,
                };
                i
            } else {
                self.nodes.push(Node {
                    key: k,
                    prev: NIL,
                    next: NIL,
                });
                self.nodes.len() - 1
            };
            self.map.insert(k, i);
            self.link_front(i);
            true
        }
    }

    /// Remove and return the least-recently-used key.
    pub fn pop_lru(&mut self) -> Option<K> {
        if self.tail == NIL {
            return None;
        }
        let i = self.tail;
        let k = self.nodes[i].key;
        self.unlink(i);
        self.map.remove(&k);
        self.free.push(i);
        Some(k)
    }

    /// Remove and return the MOST-recently-used key (MRU eviction — the
    /// policy the paper's §6.2 suggests for K-Means-like repetitive
    /// access patterns; left as future work there, implemented here).
    pub fn pop_mru(&mut self) -> Option<K> {
        if self.head == NIL {
            return None;
        }
        let i = self.head;
        let k = self.nodes[i].key;
        self.unlink(i);
        self.map.remove(&k);
        self.free.push(i);
        Some(k)
    }

    /// Peek at the least-recently-used key without removing it.
    pub fn peek_lru(&self) -> Option<&K> {
        if self.tail == NIL {
            None
        } else {
            Some(&self.nodes[self.tail].key)
        }
    }

    /// Remove a specific key; returns true if it was present.
    pub fn remove(&mut self, k: &K) -> bool {
        if let Some(i) = self.map.remove(k) {
            self.unlink(i);
            self.free.push(i);
            true
        } else {
            false
        }
    }

    /// Iterate keys from most- to least-recently used.
    pub fn iter_mru(&self) -> impl Iterator<Item = &K> + '_ {
        let mut cur = self.head;
        std::iter::from_fn(move || {
            if cur == NIL {
                None
            } else {
                let k = &self.nodes[cur].key;
                cur = self.nodes[cur].next;
                Some(k)
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn touch_orders_mru_first() {
        let mut l = Lru::new();
        l.touch(1);
        l.touch(2);
        l.touch(3);
        l.touch(1); // 1 becomes MRU
        let order: Vec<_> = l.iter_mru().copied().collect();
        assert_eq!(order, vec![1, 3, 2]);
        assert_eq!(l.pop_lru(), Some(2));
        assert_eq!(l.pop_lru(), Some(3));
        assert_eq!(l.pop_lru(), Some(1));
        assert_eq!(l.pop_lru(), None);
    }

    #[test]
    fn pop_mru_takes_front() {
        let mut l = Lru::new();
        l.touch(1);
        l.touch(2);
        l.touch(3);
        assert_eq!(l.pop_mru(), Some(3));
        assert_eq!(l.pop_mru(), Some(2));
        assert_eq!(l.pop_lru(), Some(1));
        assert_eq!(l.pop_mru(), None);
    }

    #[test]
    fn remove_mid_list() {
        let mut l = Lru::new();
        for k in 0..5 {
            l.touch(k);
        }
        assert!(l.remove(&2));
        assert!(!l.remove(&2));
        let order: Vec<_> = l.iter_mru().copied().collect();
        assert_eq!(order, vec![4, 3, 1, 0]);
    }

    #[test]
    fn slots_are_reused() {
        let mut l = Lru::new();
        for k in 0..100 {
            l.touch(k);
        }
        for _ in 0..100 {
            l.pop_lru();
        }
        for k in 100..200 {
            l.touch(k);
        }
        assert!(l.nodes.len() <= 100, "slab grew: {}", l.nodes.len());
    }

    #[test]
    fn prop_matches_reference_model() {
        // Random ops vs a naive Vec-based reference LRU.
        prop::check("lru vs reference", |rng| {
            let mut lru = Lru::new();
            let mut model: Vec<u64> = Vec::new(); // front = MRU
            for _ in 0..200 {
                match rng.below(4) {
                    0 | 1 => {
                        let k = rng.below(20);
                        lru.touch(k);
                        model.retain(|&x| x != k);
                        model.insert(0, k);
                    }
                    2 => {
                        let got = lru.pop_lru();
                        let want = model.pop();
                        assert_eq!(got, want);
                    }
                    _ => {
                        let k = rng.below(20);
                        let got = lru.remove(&k);
                        let want = model.iter().any(|&x| x == k);
                        model.retain(|&x| x != k);
                        assert_eq!(got, want);
                    }
                }
                assert_eq!(lru.len(), model.len());
                assert_eq!(
                    lru.iter_mru().copied().collect::<Vec<_>>(),
                    model
                );
            }
        });
    }
}
