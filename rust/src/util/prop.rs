//! Minimal in-crate property-testing harness (the offline build has no
//! proptest/quickcheck). Each property runs `CASES` random cases from a
//! fixed base seed; a failure reports the case seed so it can be replayed
//! with [`check_one`].
//!
//! ```ignore
//! check("mempool never exceeds max", |rng| {
//!     let n = rng.below(100);
//!     ... assert!(...);
//! });
//! ```

use super::rng::Rng;

/// Number of random cases per property (tuned so the full suite stays
/// fast; bump locally when hunting bugs).
pub const CASES: u64 = 256;

/// Run `f` on `CASES` independently seeded RNGs; panic with the failing
/// seed on the first failure.
pub fn check(name: &str, mut f: impl FnMut(&mut Rng)) {
    for case in 0..CASES {
        let seed = 0x0A1E7_u64 ^ (case.wrapping_mul(0x9E3779B97F4A7C15));
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
            || f(&mut rng),
        ));
        if let Err(e) = result {
            eprintln!(
                "property '{name}' failed on case {case} (seed {seed:#x}); \
                 replay with check_one({seed:#x}, ..)"
            );
            std::panic::resume_unwind(e);
        }
    }
}

/// Replay a single case by seed.
pub fn check_one(seed: u64, mut f: impl FnMut(&mut Rng)) {
    let mut rng = Rng::new(seed);
    f(&mut rng);
}

/// Random vector of length in [0, max_len) with values from `g`.
pub fn vec_of<T>(
    rng: &mut Rng,
    max_len: usize,
    mut g: impl FnMut(&mut Rng) -> T,
) -> Vec<T> {
    let n = rng.below_usize(max_len.max(1));
    (0..n).map(|_| g(rng)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_runs_all_cases() {
        let mut n = 0;
        check("counting", |_| n += 1);
        assert_eq!(n, CASES);
    }

    #[test]
    fn vec_of_respects_bound() {
        let mut rng = Rng::new(1);
        for _ in 0..100 {
            let v = vec_of(&mut rng, 17, |r| r.below(5));
            assert!(v.len() < 17);
            assert!(v.iter().all(|&x| x < 5));
        }
    }
}
