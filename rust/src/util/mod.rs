//! Small shared utilities: deterministic PRNG, zipfian sampling, an
//! in-crate property-testing harness (no external proptest available in
//! this offline build), and human-readable size/time formatting.

pub mod bitmap;
pub mod fmt;
pub mod lru;
pub mod prop;
pub mod rng;

pub use bitmap::PageBitmap;
pub use lru::Lru;
pub use rng::{Rng, Zipfian};
