//! Growable page bitmap: the §5.2 "bitmap for the remote page indicates
//! that remote page is ready to read" structure. Constant-time set/get
//! over dense page numbers; ~30× less memory and pointer-chasing than a
//! `HashSet<u64>` on the write/read hot paths (see EXPERIMENTS.md §Perf
//! iteration 2).

/// A bitmap over page numbers, growing on demand.
#[derive(Clone, Debug, Default)]
pub struct PageBitmap {
    words: Vec<u64>,
    ones: u64,
}

impl PageBitmap {
    /// Empty bitmap.
    pub fn new() -> Self {
        Self::default()
    }

    /// Number of set bits.
    pub fn count(&self) -> u64 {
        self.ones
    }

    /// Set `page`'s bit; returns true if it was newly set.
    #[inline]
    pub fn set(&mut self, page: u64) -> bool {
        let (w, b) = ((page / 64) as usize, page % 64);
        if w >= self.words.len() {
            self.words.resize(w + 1, 0);
        }
        let mask = 1u64 << b;
        let was = self.words[w] & mask != 0;
        self.words[w] |= mask;
        if !was {
            self.ones += 1;
        }
        !was
    }

    /// Clear `page`'s bit; returns true if it was set.
    #[inline]
    pub fn clear(&mut self, page: u64) -> bool {
        let (w, b) = ((page / 64) as usize, page % 64);
        if w >= self.words.len() {
            return false;
        }
        let mask = 1u64 << b;
        let was = self.words[w] & mask != 0;
        self.words[w] &= !mask;
        if was {
            self.ones -= 1;
        }
        was
    }

    /// Is `page`'s bit set?
    #[inline]
    pub fn get(&self, page: u64) -> bool {
        let (w, b) = ((page / 64) as usize, page % 64);
        self.words.get(w).is_some_and(|word| word & (1 << b) != 0)
    }

    /// Set all bits in [start, start+n).
    pub fn set_range(&mut self, start: u64, n: u64) {
        for p in start..start + n {
            self.set(p);
        }
    }

    /// Clear all bits in [start, start+n).
    pub fn clear_range(&mut self, start: u64, n: u64) {
        for p in start..start + n {
            self.clear(p);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use std::collections::HashSet;

    #[test]
    fn set_get_clear_roundtrip() {
        let mut b = PageBitmap::new();
        assert!(!b.get(1000));
        assert!(b.set(1000));
        assert!(!b.set(1000)); // already set
        assert!(b.get(1000));
        assert_eq!(b.count(), 1);
        assert!(b.clear(1000));
        assert!(!b.clear(1000));
        assert!(!b.get(1000));
        assert_eq!(b.count(), 0);
    }

    #[test]
    fn ranges() {
        let mut b = PageBitmap::new();
        b.set_range(10, 20);
        assert_eq!(b.count(), 20);
        assert!(b.get(10) && b.get(29) && !b.get(30) && !b.get(9));
        b.clear_range(15, 100);
        assert_eq!(b.count(), 5);
    }

    #[test]
    fn prop_matches_hashset_model() {
        prop::check("bitmap vs hashset", |rng| {
            let mut bm = PageBitmap::new();
            let mut hs: HashSet<u64> = HashSet::new();
            for _ in 0..300 {
                let p = rng.below(10_000);
                match rng.below(3) {
                    0 | 1 => {
                        assert_eq!(bm.set(p), hs.insert(p));
                    }
                    _ => {
                        assert_eq!(bm.clear(p), hs.remove(&p));
                    }
                }
                assert_eq!(bm.get(p), hs.contains(&p));
                assert_eq!(bm.count(), hs.len() as u64);
            }
        });
    }
}
