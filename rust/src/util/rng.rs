//! Deterministic PRNG + YCSB-style zipfian generator.
//!
//! The whole simulation must be reproducible from a seed (ARCHITECTURE.md §1:
//! "determinism under same seed" is a tested invariant), so we carry our
//! own xoshiro256** implementation instead of depending on `rand` (not
//! available offline), seeded via splitmix64 like the reference
//! implementation.

/// xoshiro256** PRNG (Blackman & Vigna). Fast, 256-bit state, good enough
/// for workload generation; NOT cryptographic.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create from a seed; any seed (including 0) is fine — state is
    /// expanded with splitmix64.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng {
            s: [next(), next(), next(), next()],
        }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n). n must be > 0.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection-free approximation is fine for
        // workload gen; modulo bias at n << 2^64 is negligible but we use
        // the widening multiply anyway.
        (((self.next_u64() as u128) * (n as u128)) >> 64) as u64
    }

    /// Uniform usize in [0, n).
    #[inline]
    pub fn below_usize(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Bernoulli trial with probability p.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below_usize(i + 1);
            xs.swap(i, j);
        }
    }
}

/// Zipfian generator over [0, n) with skew `theta`, after the YCSB /
/// Gray et al. construction ("Quickly generating billion-record synthetic
/// databases"). `theta = 0.99` matches YCSB's default, which the paper's
/// evaluation uses ("we use zipfian distribution for both workload").
#[derive(Clone, Debug)]
pub struct Zipfian {
    n: u64,
    theta: f64,
    alpha: f64,
    zetan: f64,
    eta: f64,
    zeta2: f64,
}

impl Zipfian {
    /// Build for n items. O(n) once (zeta sum); n up to ~10^8 is fine.
    pub fn new(n: u64, theta: f64) -> Self {
        assert!(n > 0);
        let zetan = Self::zeta(n, theta);
        let zeta2 = Self::zeta(2, theta);
        let alpha = 1.0 / (1.0 - theta);
        let eta =
            (1.0 - (2.0 / n as f64).powf(1.0 - theta)) / (1.0 - zeta2 / zetan);
        Zipfian {
            n,
            theta,
            alpha,
            zetan,
            eta,
            zeta2,
        }
    }

    fn zeta(n: u64, theta: f64) -> f64 {
        let mut sum = 0.0;
        for i in 1..=n {
            sum += 1.0 / (i as f64).powf(theta);
        }
        sum
    }

    /// Number of items.
    pub fn n(&self) -> u64 {
        self.n
    }

    /// Draw the next rank (0 = hottest item).
    pub fn sample(&self, rng: &mut Rng) -> u64 {
        let u = rng.f64();
        let uz = u * self.zetan;
        if uz < 1.0 {
            return 0;
        }
        if uz < 1.0 + 0.5f64.powf(self.theta) {
            return 1;
        }
        let v = (self.n as f64
            * (self.eta * u - self.eta + 1.0).powf(self.alpha))
            as u64;
        v.min(self.n - 1)
    }

    /// Draw and scatter: YCSB hashes the rank so hot items are spread over
    /// the key space instead of clustered at low keys. fnv-style mix.
    pub fn sample_scattered(&self, rng: &mut Rng) -> u64 {
        let r = self.sample(rng);
        // splitmix-style scramble, then reduce
        let mut z = r.wrapping_add(0x9E3779B97F4A7C15);
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        (z ^ (z >> 31)) % self.n
    }

    /// Exposed for tests: theoretical probability of rank k (0-based).
    pub fn prob(&self, k: u64) -> f64 {
        (1.0 / ((k + 1) as f64).powf(self.theta)) / self.zetan
    }

    /// zeta(2, theta), exposed for diagnostics.
    pub fn zeta2(&self) -> f64 {
        self.zeta2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_is_deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn rng_different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn below_mean_is_centered() {
        let mut r = Rng::new(5);
        let n = 100_000;
        let sum: u64 = (0..n).map(|_| r.below(1000)).sum();
        let mean = sum as f64 / n as f64;
        assert!((mean - 499.5).abs() < 5.0, "mean={mean}");
    }

    #[test]
    fn zipf_hot_item_frequency_matches_theory() {
        let z = Zipfian::new(1000, 0.99);
        let mut r = Rng::new(9);
        let n = 200_000;
        let hot = (0..n).filter(|_| z.sample(&mut r) == 0).count();
        let got = hot as f64 / n as f64;
        let want = z.prob(0);
        assert!(
            (got - want).abs() < 0.01,
            "got {got}, theoretical {want}"
        );
    }

    #[test]
    fn zipf_is_monotone_decreasing_in_rank() {
        let z = Zipfian::new(100, 0.99);
        let mut r = Rng::new(11);
        let mut counts = vec![0u64; 100];
        for _ in 0..300_000 {
            counts[z.sample(&mut r) as usize] += 1;
        }
        // aggregate decreasing in broad buckets to dodge sampling noise
        let head: u64 = counts[..10].iter().sum();
        let mid: u64 = counts[10..50].iter().sum();
        let tail: u64 = counts[50..].iter().sum();
        assert!(head > mid && mid > tail, "{head} {mid} {tail}");
    }

    #[test]
    fn zipf_scattered_stays_in_range() {
        let z = Zipfian::new(1234, 0.99);
        let mut r = Rng::new(13);
        for _ in 0..10_000 {
            assert!(z.sample_scattered(&mut r) < 1234);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
