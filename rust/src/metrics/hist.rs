//! Log-bucketed latency histogram, HDR-histogram style: constant-time
//! record, ~1.5 % relative quantile error, fixed 4 KiB footprint. Covers
//! 1 ns ..= ~584 years, which is enough virtual time for anyone.

/// Buckets: 64 octaves × 16 sub-buckets (linear within an octave).
const SUB: usize = 16;
const SUB_SHIFT: u32 = 4;
const NBUCKETS: usize = 64 * SUB;

/// A latency histogram over u64 nanosecond values.
#[derive(Clone)]
pub struct Histogram {
    counts: Vec<u64>,
    total: u64,
    sum: u128,
    min: u64,
    max: u64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl std::fmt::Debug for Histogram {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "Histogram(n={}, mean={:.0}, p50={}, p99={}, max={})",
            self.total,
            self.mean(),
            self.quantile(0.5),
            self.quantile(0.99),
            self.max
        )
    }
}

#[inline]
fn bucket_of(v: u64) -> usize {
    if v < SUB as u64 {
        return v as usize;
    }
    let oct = 63 - v.leading_zeros(); // highest set bit
    let top = oct.saturating_sub(SUB_SHIFT);
    let sub = ((v >> top) as usize) & (SUB - 1);
    ((oct - SUB_SHIFT + 1) as usize) * SUB + sub
}

#[inline]
fn bucket_low(b: usize) -> u64 {
    if b < SUB {
        return b as u64;
    }
    let oct = (b / SUB - 1) as u32 + SUB_SHIFT;
    let sub = (b % SUB) as u64;
    (1u64 << oct) | (sub << (oct - SUB_SHIFT))
}

impl Histogram {
    /// Empty histogram.
    pub fn new() -> Self {
        Histogram {
            counts: vec![0; NBUCKETS],
            total: 0,
            sum: 0,
            min: u64::MAX,
            max: 0,
        }
    }

    /// Record one value (ns).
    #[inline]
    pub fn record(&mut self, v: u64) {
        self.counts[bucket_of(v).min(NBUCKETS - 1)] += 1;
        self.total += 1;
        self.sum += v as u128;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    /// Number of recorded values.
    pub fn count(&self) -> u64 {
        self.total
    }

    /// Arithmetic mean (exact, tracked outside the buckets).
    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum as f64 / self.total as f64
        }
    }

    /// Smallest recorded value (0 if empty).
    pub fn min(&self) -> u64 {
        if self.total == 0 {
            0
        } else {
            self.min
        }
    }

    /// Largest recorded value.
    pub fn max(&self) -> u64 {
        self.max
    }

    /// Approximate quantile (q in [0,1]): lower bound of the bucket
    /// holding the q-th value, exact min/max at the ends.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.total == 0 {
            return 0;
        }
        if q <= 0.0 {
            return self.min();
        }
        if q >= 1.0 {
            return self.max;
        }
        let target = (q * self.total as f64).ceil() as u64;
        let mut seen = 0;
        for (b, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                return bucket_low(b).max(self.min).min(self.max);
            }
        }
        self.max
    }

    /// p50 shorthand.
    pub fn p50(&self) -> u64 {
        self.quantile(0.5)
    }

    /// p99 shorthand.
    pub fn p99(&self) -> u64 {
        self.quantile(0.99)
    }

    /// Merge another histogram into this one.
    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn bucket_roundtrip_error_bounded() {
        // bucket_low(bucket_of(v)) <= v, and within 1/16 relative error.
        for shift in 0..50u32 {
            for off in [0u64, 1, 3, 7] {
                let v = (1u64 << shift).wrapping_add(off * (1 << shift) / 9);
                let lo = bucket_low(bucket_of(v));
                assert!(lo <= v, "v={v} lo={lo}");
                assert!(
                    (v - lo) as f64 <= v as f64 / 8.0 + 1.0,
                    "v={v} lo={lo}"
                );
            }
        }
    }

    #[test]
    fn empty_histogram_is_zeroes() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.quantile(0.5), 0);
        assert_eq!(h.mean(), 0.0);
    }

    #[test]
    fn single_value() {
        let mut h = Histogram::new();
        h.record(12_345);
        assert_eq!(h.count(), 1);
        assert_eq!(h.min(), 12_345);
        assert_eq!(h.max(), 12_345);
        assert_eq!(h.quantile(0.5), h.quantile(0.99));
    }

    #[test]
    fn quantiles_are_monotone_and_accurate() {
        let mut h = Histogram::new();
        let mut rng = Rng::new(3);
        for _ in 0..100_000 {
            h.record(rng.below(1_000_000) + 1);
        }
        let p50 = h.quantile(0.5);
        let p90 = h.quantile(0.9);
        let p99 = h.quantile(0.99);
        assert!(p50 <= p90 && p90 <= p99);
        // uniform distribution: p50 ~ 500k within bucket error
        assert!((p50 as f64 - 500_000.0).abs() < 500_000.0 / 8.0, "{p50}");
        assert!((p99 as f64 - 990_000.0).abs() < 990_000.0 / 8.0, "{p99}");
    }

    #[test]
    fn mean_is_exact() {
        let mut h = Histogram::new();
        for v in [10u64, 20, 30] {
            h.record(v);
        }
        assert_eq!(h.mean(), 20.0);
    }

    #[test]
    fn merge_matches_combined_recording() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        let mut both = Histogram::new();
        let mut rng = Rng::new(5);
        for i in 0..10_000 {
            let v = rng.below(1 << 30);
            if i % 2 == 0 {
                a.record(v);
            } else {
                b.record(v);
            }
            both.record(v);
        }
        a.merge(&b);
        assert_eq!(a.count(), both.count());
        assert_eq!(a.mean(), both.mean());
        assert_eq!(a.quantile(0.99), both.quantile(0.99));
    }
}
