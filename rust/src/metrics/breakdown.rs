//! Per-component latency attribution — the machinery behind the paper's
//! Table 1 ("Comparison of latency impact on the critical path") and
//! Table 7 ("latency breakdown comparison between Valet and Infiniswap").

use std::collections::BTreeMap;

/// Sums time spent per named component; components are static strings
/// ("radix", "copy", "rdma", "disk", "connection", "mapping", ...).
#[derive(Clone, Debug, Default)]
pub struct Breakdown {
    parts: BTreeMap<&'static str, (u128, u64)>, // (sum ns, count)
}

impl Breakdown {
    /// New, empty.
    pub fn new() -> Self {
        Self::default()
    }

    /// Attribute `ns` nanoseconds to `part`.
    #[inline]
    pub fn add(&mut self, part: &'static str, ns: u64) {
        let e = self.parts.entry(part).or_insert((0, 0));
        e.0 += ns as u128;
        e.1 += 1;
    }

    /// Total ns across all components.
    pub fn total(&self) -> u128 {
        self.parts.values().map(|(s, _)| s).sum()
    }

    /// Sum for one component.
    pub fn sum(&self, part: &str) -> u128 {
        self.parts.get(part).map(|(s, _)| *s).unwrap_or(0)
    }

    /// Mean ns per event for one component (0 if absent).
    pub fn mean(&self, part: &str) -> f64 {
        match self.parts.get(part) {
            Some(&(s, c)) if c > 0 => s as f64 / c as f64,
            _ => 0.0,
        }
    }

    /// Event count for one component.
    pub fn count(&self, part: &str) -> u64 {
        self.parts.get(part).map(|(_, c)| *c).unwrap_or(0)
    }

    /// Share of total time for one component, in [0,1].
    pub fn share(&self, part: &str) -> f64 {
        let t = self.total();
        if t == 0 {
            0.0
        } else {
            self.sum(part) as f64 / t as f64
        }
    }

    /// Components sorted by descending total time.
    pub fn ranked(&self) -> Vec<(&'static str, u128, f64)> {
        let t = self.total().max(1);
        let mut v: Vec<_> = self
            .parts
            .iter()
            .map(|(&k, &(s, _))| (k, s, s as f64 / t as f64))
            .collect();
        v.sort_by(|a, b| b.1.cmp(&a.1));
        v
    }

    /// Merge another breakdown.
    pub fn merge(&mut self, other: &Breakdown) {
        for (&k, &(s, c)) in &other.parts {
            let e = self.parts.entry(k).or_insert((0, 0));
            e.0 += s;
            e.1 += c;
        }
    }

    /// Iterate (component, sum, count).
    pub fn iter(&self) -> impl Iterator<Item = (&'static str, u128, u64)> + '_ {
        self.parts.iter().map(|(&k, &(s, c))| (k, s, c))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shares_sum_to_one() {
        let mut b = Breakdown::new();
        b.add("disk", 600);
        b.add("rdma", 300);
        b.add("copy", 100);
        let s: f64 = ["disk", "rdma", "copy"]
            .iter()
            .map(|p| b.share(p))
            .sum();
        assert!((s - 1.0).abs() < 1e-12);
        assert!((b.share("disk") - 0.6).abs() < 1e-12);
    }

    #[test]
    fn ranked_is_descending() {
        let mut b = Breakdown::new();
        b.add("a", 10);
        b.add("b", 30);
        b.add("c", 20);
        let names: Vec<_> = b.ranked().iter().map(|r| r.0).collect();
        assert_eq!(names, vec!["b", "c", "a"]);
    }

    #[test]
    fn mean_counts_events() {
        let mut b = Breakdown::new();
        b.add("x", 10);
        b.add("x", 30);
        assert_eq!(b.mean("x"), 20.0);
        assert_eq!(b.count("x"), 2);
        assert_eq!(b.mean("absent"), 0.0);
    }

    #[test]
    fn merge_accumulates() {
        let mut a = Breakdown::new();
        a.add("x", 5);
        let mut b = Breakdown::new();
        b.add("x", 7);
        b.add("y", 1);
        a.merge(&b);
        assert_eq!(a.sum("x"), 12);
        assert_eq!(a.count("x"), 2);
        assert_eq!(a.sum("y"), 1);
    }
}
