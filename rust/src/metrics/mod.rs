//! Measurement plumbing: latency histograms (log-bucketed, HDR-style),
//! throughput counters and per-component latency breakdowns — everything
//! needed to print the paper's tables (avg / p99 latency, ops/sec,
//! component percentages as in Tables 1 and 7).

mod breakdown;
mod hist;

pub use breakdown::Breakdown;
pub use hist::Histogram;

use crate::sim::Ns;

/// Aggregate metrics for one run of a workload against a backend.
#[derive(Clone, Debug, Default)]
pub struct RunMetrics {
    /// End-to-end latency of application-level operations (e.g. one YCSB
    /// GET/SET), in virtual ns.
    pub op_latency: Histogram,
    /// Latency of swap-in (page read) requests seen by the block device.
    pub read_latency: Histogram,
    /// Latency of swap-out (page write) requests.
    pub write_latency: Histogram,
    /// Per-component time attribution (radix, copy, rdma, disk, ...).
    pub read_parts: Breakdown,
    /// Per-component time attribution on the write path.
    pub write_parts: Breakdown,
    /// Completed application operations.
    pub ops: u64,
    /// Virtual time at which the run finished.
    pub finished_at: Ns,
    /// Local mempool hits / remote reads / disk reads (Figure 8, Table 7).
    pub local_hits: u64,
    /// Reads served by a remote node.
    pub remote_hits: u64,
    /// Subset of `remote_hits` served from a pool-tier (CXL-style
    /// appliance) block rather than a peer's RDMA-remote DRAM. Always 0
    /// with `valet.pool_tier` off.
    pub pool_hits: u64,
    /// Reads that fell through to disk.
    pub disk_reads: u64,
    /// Writes redirected to disk (Infiniswap connection/mapping windows).
    pub disk_writes: u64,
    /// Pages fetched ahead of demand by the stride prefetcher.
    pub prefetch_issued: u64,
    /// Readahead batches posted (≥ 1 page each, per-unit coalesced).
    pub prefetch_batches: u64,
    /// Demand reads served by a prefetched page (local hit that would
    /// have been a remote read).
    pub prefetch_hits: u64,
    /// Prefetched pages evicted (or overwritten) before any read.
    pub prefetch_wasted: u64,
    /// Read misses that piggybacked on an in-flight fetch of the same
    /// page instead of issuing a duplicate RDMA READ.
    pub coalesced_reads: u64,
    /// Block read requests served through the block read pipeline —
    /// at most one slow-path crossing each: either an all-cached
    /// lock-free completion or one collect→coalesce→batch crossing
    /// (`remote_hits`/`coalesced_reads` tell the two apart).
    pub batched_reads: u64,
    /// Reads of data the cluster acknowledged but can no longer serve:
    /// every replica slot died, the unit is gone, and the disk backup
    /// was off. The churn gate requires this to stay 0 whenever
    /// `replicas ≥ 2` or `valet.disk_backup` is on. Always 0 with
    /// `valet.health` off (deaths never happen without the ledger).
    pub lost_reads: u64,
}

impl RunMetrics {
    /// Operations per virtual second.
    pub fn throughput(&self) -> f64 {
        if self.finished_at == 0 {
            return 0.0;
        }
        self.ops as f64 / (self.finished_at as f64 / 1e9)
    }

    /// Local cache hit ratio among all block-device reads.
    pub fn local_hit_ratio(&self) -> f64 {
        let total = self.local_hits + self.remote_hits + self.disk_reads;
        if total == 0 {
            0.0
        } else {
            self.local_hits as f64 / total as f64
        }
    }

    /// Prefetch coverage: the fraction of would-be misses the
    /// prefetcher converted into local hits
    /// (`prefetch_hits / (prefetch_hits + remote_hits + disk_reads)`).
    pub fn prefetch_coverage(&self) -> f64 {
        let would_miss =
            self.prefetch_hits + self.remote_hits + self.disk_reads;
        if would_miss == 0 {
            0.0
        } else {
            self.prefetch_hits as f64 / would_miss as f64
        }
    }

    /// Prefetch accuracy over completed (hit-or-evicted) prefetches;
    /// 1.0 when nothing has completed yet.
    pub fn prefetch_accuracy(&self) -> f64 {
        let done = self.prefetch_hits + self.prefetch_wasted;
        if done == 0 {
            1.0
        } else {
            self.prefetch_hits as f64 / done as f64
        }
    }

    /// Merge another run's numbers (for multi-client aggregation).
    pub fn merge(&mut self, other: &RunMetrics) {
        self.op_latency.merge(&other.op_latency);
        self.read_latency.merge(&other.read_latency);
        self.write_latency.merge(&other.write_latency);
        self.read_parts.merge(&other.read_parts);
        self.write_parts.merge(&other.write_parts);
        self.ops += other.ops;
        self.finished_at = self.finished_at.max(other.finished_at);
        self.local_hits += other.local_hits;
        self.remote_hits += other.remote_hits;
        self.pool_hits += other.pool_hits;
        self.disk_reads += other.disk_reads;
        self.disk_writes += other.disk_writes;
        self.prefetch_issued += other.prefetch_issued;
        self.prefetch_batches += other.prefetch_batches;
        self.prefetch_hits += other.prefetch_hits;
        self.prefetch_wasted += other.prefetch_wasted;
        self.coalesced_reads += other.coalesced_reads;
        self.batched_reads += other.batched_reads;
        self.lost_reads += other.lost_reads;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_is_ops_per_virtual_second() {
        let m = RunMetrics {
            ops: 500,
            finished_at: 2_000_000_000,
            ..Default::default()
        };
        assert!((m.throughput() - 250.0).abs() < 1e-9);
    }

    #[test]
    fn hit_ratio_counts_all_read_sources() {
        let m = RunMetrics {
            local_hits: 25,
            remote_hits: 70,
            disk_reads: 5,
            ..Default::default()
        };
        assert!((m.local_hit_ratio() - 0.25).abs() < 1e-9);
    }

    #[test]
    fn merge_accumulates() {
        let mut a = RunMetrics {
            ops: 10,
            finished_at: 5,
            local_hits: 1,
            ..Default::default()
        };
        let b = RunMetrics {
            ops: 20,
            finished_at: 3,
            local_hits: 2,
            ..Default::default()
        };
        a.merge(&b);
        assert_eq!(a.ops, 30);
        assert_eq!(a.finished_at, 5);
        assert_eq!(a.local_hits, 3);
    }
}
