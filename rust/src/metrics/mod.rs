//! Measurement plumbing: latency histograms (log-bucketed, HDR-style),
//! throughput counters and per-component latency breakdowns — everything
//! needed to print the paper's tables (avg / p99 latency, ops/sec,
//! component percentages as in Tables 1 and 7).

mod breakdown;
mod hist;

pub use breakdown::Breakdown;
pub use hist::Histogram;

use crate::sim::Ns;

/// Aggregate metrics for one run of a workload against a backend.
#[derive(Clone, Debug, Default)]
pub struct RunMetrics {
    /// End-to-end latency of application-level operations (e.g. one YCSB
    /// GET/SET), in virtual ns.
    pub op_latency: Histogram,
    /// Latency of swap-in (page read) requests seen by the block device.
    pub read_latency: Histogram,
    /// Latency of swap-out (page write) requests.
    pub write_latency: Histogram,
    /// Per-component time attribution (radix, copy, rdma, disk, ...).
    pub read_parts: Breakdown,
    /// Per-component time attribution on the write path.
    pub write_parts: Breakdown,
    /// Completed application operations.
    pub ops: u64,
    /// Virtual time at which the run finished.
    pub finished_at: Ns,
    /// Local mempool hits / remote reads / disk reads (Figure 8, Table 7).
    pub local_hits: u64,
    /// Reads served by a remote node.
    pub remote_hits: u64,
    /// Reads that fell through to disk.
    pub disk_reads: u64,
    /// Writes redirected to disk (Infiniswap connection/mapping windows).
    pub disk_writes: u64,
}

impl RunMetrics {
    /// Operations per virtual second.
    pub fn throughput(&self) -> f64 {
        if self.finished_at == 0 {
            return 0.0;
        }
        self.ops as f64 / (self.finished_at as f64 / 1e9)
    }

    /// Local cache hit ratio among all block-device reads.
    pub fn local_hit_ratio(&self) -> f64 {
        let total = self.local_hits + self.remote_hits + self.disk_reads;
        if total == 0 {
            0.0
        } else {
            self.local_hits as f64 / total as f64
        }
    }

    /// Merge another run's numbers (for multi-client aggregation).
    pub fn merge(&mut self, other: &RunMetrics) {
        self.op_latency.merge(&other.op_latency);
        self.read_latency.merge(&other.read_latency);
        self.write_latency.merge(&other.write_latency);
        self.read_parts.merge(&other.read_parts);
        self.write_parts.merge(&other.write_parts);
        self.ops += other.ops;
        self.finished_at = self.finished_at.max(other.finished_at);
        self.local_hits += other.local_hits;
        self.remote_hits += other.remote_hits;
        self.disk_reads += other.disk_reads;
        self.disk_writes += other.disk_writes;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_is_ops_per_virtual_second() {
        let m = RunMetrics {
            ops: 500,
            finished_at: 2_000_000_000,
            ..Default::default()
        };
        assert!((m.throughput() - 250.0).abs() < 1e-9);
    }

    #[test]
    fn hit_ratio_counts_all_read_sources() {
        let m = RunMetrics {
            local_hits: 25,
            remote_hits: 70,
            disk_reads: 5,
            ..Default::default()
        };
        assert!((m.local_hit_ratio() - 0.25).abs() < 1e-9);
    }

    #[test]
    fn merge_accumulates() {
        let mut a = RunMetrics {
            ops: 10,
            finished_at: 5,
            local_hits: 1,
            ..Default::default()
        };
        let b = RunMetrics {
            ops: 20,
            finished_at: 3,
            local_hits: 2,
            ..Default::default()
        };
        a.merge(&b);
        assert_eq!(a.ops, 30);
        assert_eq!(a.finished_at, 5);
        assert_eq!(a.local_hits, 3);
    }
}
