//! Fault tolerance (§5.1, Table 3): replication across remote nodes
//! and/or local disk backup, and the read-fallback semantics of each
//! combination.
//!
//! | | w/ Replication | w/o Replication |
//! |---|---|---|
//! | **w/ Disk Backup** | replica first, disk if replica fails | local disk |
//! | **w/o Disk Backup** | replica | remote data loss (caching use case) |

use crate::NodeId;

/// Fault-tolerance configuration of a Valet device.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FtPolicy {
    /// Total remote copies (1 = primary only, 2 = primary + 1 replica…).
    pub copies: usize,
    /// Write pages to local disk as well.
    pub disk_backup: bool,
}

impl FtPolicy {
    /// Replication without disk (the paper's default for all experiments:
    /// "We use replication for all experiments in evaluation").
    pub fn replicated(copies: usize) -> Self {
        FtPolicy {
            copies: copies.max(1),
            disk_backup: false,
        }
    }

    /// Extra remote space factor: N replication needs N× remote memory
    /// ("It requires N time larger remote memory space with N
    /// replication", §5.3).
    pub fn space_factor(&self) -> usize {
        self.copies
    }
}

/// Where a read for remotely-stored data is served from, given which
/// copies survive (Table 3).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReadSource {
    /// A remote copy on this node.
    Remote(NodeId),
    /// Local disk backup.
    Disk,
    /// Data is lost — acceptable only for caching semantics.
    Lost,
}

/// Pick the read source: first surviving remote copy, then disk if
/// enabled, else the data is gone.
pub fn read_source(
    policy: FtPolicy,
    copies: &[(NodeId, bool)], // (node, alive)
) -> ReadSource {
    for &(node, alive) in copies {
        if alive {
            return ReadSource::Remote(node);
        }
    }
    if policy.disk_backup {
        ReadSource::Disk
    } else {
        ReadSource::Lost
    }
}

/// Choose distinct replica nodes for a block: the primary plus
/// `copies-1` follower nodes, skipping the sender itself. Deterministic
/// given the candidate order (placement policy orders candidates).
pub fn choose_replicas(
    sender: NodeId,
    primary: NodeId,
    candidates: &[NodeId],
    copies: usize,
) -> Vec<NodeId> {
    let mut out = vec![primary];
    for &c in candidates {
        if out.len() >= copies {
            break;
        }
        if c != sender && !out.contains(&c) {
            out.push(c);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_all_four_quadrants() {
        let with_repl_disk = FtPolicy {
            copies: 2,
            disk_backup: true,
        };
        let with_repl = FtPolicy {
            copies: 2,
            disk_backup: false,
        };
        let disk_only = FtPolicy {
            copies: 1,
            disk_backup: true,
        };
        let none = FtPolicy {
            copies: 1,
            disk_backup: false,
        };

        // both replicas alive → remote
        assert_eq!(
            read_source(with_repl_disk, &[(1, true), (2, true)]),
            ReadSource::Remote(1)
        );
        // primary dead, replica alive → the replica
        assert_eq!(
            read_source(with_repl, &[(1, false), (2, true)]),
            ReadSource::Remote(2)
        );
        // all remote dead + disk backup → disk
        assert_eq!(
            read_source(with_repl_disk, &[(1, false), (2, false)]),
            ReadSource::Disk
        );
        assert_eq!(
            read_source(disk_only, &[(1, false)]),
            ReadSource::Disk
        );
        // all remote dead, no disk → lost (caching semantics)
        assert_eq!(read_source(none, &[(1, false)]), ReadSource::Lost);
        assert_eq!(
            read_source(with_repl, &[(1, false), (2, false)]),
            ReadSource::Lost
        );
    }

    #[test]
    fn space_factor_is_copies() {
        assert_eq!(FtPolicy::replicated(3).space_factor(), 3);
        assert_eq!(FtPolicy::replicated(0).copies, 1);
    }

    #[test]
    fn replicas_are_distinct_and_skip_sender() {
        let r = choose_replicas(0, 2, &[0, 1, 2, 3, 4], 3);
        assert_eq!(r, vec![2, 1, 3]);
        assert!(!r.contains(&0));
        let dedup: std::collections::HashSet<_> = r.iter().collect();
        assert_eq!(dedup.len(), r.len());
    }

    #[test]
    fn replicas_truncate_when_cluster_too_small() {
        let r = choose_replicas(0, 1, &[1], 3);
        assert_eq!(r, vec![1]);
    }
}
