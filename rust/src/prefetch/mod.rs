//! Adaptive per-shard stride prefetcher for the read miss path.
//!
//! Every local-read miss is a datapoint: the prefetcher keeps the last
//! `window` miss-to-miss deltas and runs a Leap-style majority vote over
//! them (Boyer–Moore candidate + verification pass). When a strict
//! majority of recent deltas agree on one non-zero stride, the miss
//! stream is sequential/strided and the next `degree` pages along that
//! stride are worth fetching *before* the demand reads arrive; the
//! engine lands them into the shard's GPT/mempool as prefetch-tagged
//! slots (first in line for reclaim — see
//! [`crate::mempool::Mempool::alloc_prefetched`]) with their RDMA
//! arrival time tracked so a demand read that beats the wire waits only
//! for the remainder.
//!
//! ## Adaptivity
//!
//! The prefetcher judges itself on *completed* prefetches: a landed page
//! either serves a later demand read (a **hit**) or is evicted unused
//! (**waste**). Once at least `min_samples` prefetches have completed,
//! an accuracy (`hits / (hits + wasted)`) below `min_accuracy` disables
//! readahead — no further batches are issued, so a random workload can
//! never be hurt twice. While disabled the detector keeps running in
//! **shadow mode**: each miss is scored against the page the previous
//! vote would have predicted, and when shadow accuracy over a full
//! sample window climbs back above the threshold (the workload turned
//! sequential again) the prefetcher re-enables with fresh counters.
//!
//! The prefetcher holds no clock and issues no I/O itself — it only
//! votes. The engine (see [`crate::engine`]) owns the fetch: filtering
//! candidates to pages this shard owns whose remote copy is valid,
//! allocating prefetch-tagged slots, and posting the coalesced
//! [`crate::coordinator::sender::RemoteSender::read_batch`].

use std::collections::VecDeque;

/// Prefetcher policy knobs (mirrors the `valet.prefetch_*` config keys;
/// see [`crate::config::ValetConfig`]).
#[derive(Clone, Debug)]
pub struct PrefetchConfig {
    /// Master switch: a disabled prefetcher observes nothing and never
    /// proposes readahead (the PR-3 miss path, bit for bit).
    pub enabled: bool,
    /// Miss-delta window the majority vote runs over.
    pub window: usize,
    /// Pages proposed per readahead batch.
    pub degree: u64,
    /// Auto-disable below this accuracy over completed prefetches.
    pub min_accuracy: f64,
    /// Completed prefetches required before accuracy is judged (and
    /// shadow samples required before a re-enable).
    pub min_samples: u64,
}

impl Default for PrefetchConfig {
    fn default() -> Self {
        PrefetchConfig {
            enabled: false,
            window: 8,
            degree: 8,
            min_accuracy: 0.5,
            min_samples: 32,
        }
    }
}

impl PrefetchConfig {
    /// Build from the Valet policy knobs.
    pub fn from_valet(v: &crate::config::ValetConfig) -> Self {
        PrefetchConfig {
            enabled: v.prefetch,
            window: v.prefetch_window.max(2),
            degree: v.prefetch_degree.max(1),
            min_accuracy: v.prefetch_min_accuracy,
            min_samples: v.prefetch_min_samples.max(1),
        }
    }
}

/// A readahead proposal: fetch `degree` pages at `stride` beyond the
/// missed page.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Readahead {
    /// Detected page stride (may be negative — descending scans).
    pub stride: i64,
    /// Number of pages to fetch along the stride.
    pub degree: u64,
}

/// The per-shard stride detector + accuracy governor (module docs).
#[derive(Clone, Debug)]
pub struct StridePrefetcher {
    cfg: PrefetchConfig,
    /// Page of the previous miss (delta source).
    last_miss: Option<u64>,
    /// Last `cfg.window` miss deltas.
    deltas: VecDeque<i64>,
    /// Completed prefetches that served a demand read.
    hits: u64,
    /// Completed prefetches evicted unused.
    wasted: u64,
    /// Pages handed to the fetch engine.
    issued: u64,
    /// Readahead suppressed by the accuracy governor.
    disabled: bool,
    /// Shadow mode: the page the previous vote predicted next.
    shadow_next: Option<u64>,
    /// Shadow predictions that matched the next miss.
    shadow_hits: u64,
    /// Shadow predictions scored.
    shadow_total: u64,
}

impl StridePrefetcher {
    /// Build with the given policy.
    pub fn new(cfg: PrefetchConfig) -> Self {
        let window = cfg.window;
        StridePrefetcher {
            cfg,
            last_miss: None,
            deltas: VecDeque::with_capacity(window),
            hits: 0,
            wasted: 0,
            issued: 0,
            disabled: false,
            shadow_next: None,
            shadow_hits: 0,
            shadow_total: 0,
        }
    }

    // -- accuracy feedback (driven by the fetch engine) ---------------

    /// A prefetched page served a demand read.
    pub fn record_hit(&mut self) {
        self.hits += 1;
    }

    /// `n` prefetched pages were evicted (or overwritten) unused.
    pub fn record_waste(&mut self, n: u64) {
        self.wasted += n;
    }

    /// `n` pages were actually fetched from a proposal.
    pub fn note_issued(&mut self, n: u64) {
        self.issued += n;
    }

    // -- introspection ------------------------------------------------

    /// Completed prefetches (hit or wasted).
    pub fn completed(&self) -> u64 {
        self.hits + self.wasted
    }

    /// Fraction of completed prefetches that served a read (1.0 before
    /// any completion — innocent until proven wasteful).
    pub fn accuracy(&self) -> f64 {
        let done = self.completed();
        if done == 0 {
            1.0
        } else {
            self.hits as f64 / done as f64
        }
    }

    /// True while the accuracy governor suppresses readahead.
    pub fn is_disabled(&self) -> bool {
        self.disabled
    }

    /// Pages handed to the fetch engine so far.
    pub fn issued(&self) -> u64 {
        self.issued
    }

    /// Prefetched pages that served demand reads.
    pub fn hits(&self) -> u64 {
        self.hits
    }

    /// Prefetched pages evicted unused.
    pub fn wasted(&self) -> u64 {
        self.wasted
    }

    // -- the vote -----------------------------------------------------

    /// Majority stride over the delta window, if the window is full and
    /// a strict majority agrees on one non-zero delta.
    fn majority_stride(&self) -> Option<i64> {
        if self.deltas.len() < self.cfg.window {
            return None;
        }
        // Boyer–Moore majority candidate…
        let (mut cand, mut cnt) = (0i64, 0usize);
        for &d in &self.deltas {
            if cnt == 0 {
                cand = d;
                cnt = 1;
            } else if d == cand {
                cnt += 1;
            } else {
                cnt -= 1;
            }
        }
        // …verified (the candidate is only guaranteed to be the
        // majority if one exists).
        let votes = self.deltas.iter().filter(|&&d| d == cand).count();
        (cand != 0 && votes * 2 > self.deltas.len()).then_some(cand)
    }

    /// Feed one demand miss into the detector. Returns a readahead
    /// proposal when the stream is confidently strided and the accuracy
    /// governor allows fetching.
    pub fn observe_miss(&mut self, page: u64) -> Option<Readahead> {
        if !self.cfg.enabled {
            return None;
        }
        let prev = match self.last_miss.replace(page) {
            Some(p) => p,
            None => return None,
        };
        let delta = (page as i64).wrapping_sub(prev as i64);
        if delta != 0 {
            if self.deltas.len() == self.cfg.window {
                self.deltas.pop_front();
            }
            self.deltas.push_back(delta);
        }
        let stride = self.majority_stride();
        if self.disabled {
            self.shadow_score(page, stride);
            return None;
        }
        // Judge accuracy before proposing more work.
        if self.completed() >= self.cfg.min_samples
            && self.accuracy() < self.cfg.min_accuracy
        {
            self.disabled = true;
            self.shadow_next = None;
            self.shadow_hits = 0;
            self.shadow_total = 0;
            return None;
        }
        stride.map(|s| Readahead {
            stride: s,
            degree: self.cfg.degree,
        })
    }

    /// Would a demand hit on a prefetched page warrant extending the
    /// readahead window? True while readahead is allowed and the recent
    /// miss stream still votes a stride — the hit is evidence the
    /// stride continues, so the engine keeps the window `degree` pages
    /// ahead instead of stalling until the next miss (Leap's trend
    /// continuation; without it every `degree` pages pay one demand
    /// round trip).
    pub fn wants_continuation(&self) -> bool {
        self.cfg.enabled
            && !self.disabled
            && self.majority_stride().is_some()
    }

    /// The readahead to extend from a prefetch hit (stride from the
    /// standing vote; no state is consumed).
    pub fn continuation(&self) -> Option<Readahead> {
        if !self.wants_continuation() {
            return None;
        }
        self.majority_stride().map(|s| Readahead {
            stride: s,
            degree: self.cfg.degree,
        })
    }

    /// Shadow mode: score the previous prediction against this miss and
    /// re-enable once a full window of shadow samples clears the
    /// accuracy bar.
    fn shadow_score(&mut self, page: u64, stride: Option<i64>) {
        if let Some(pred) = self.shadow_next.take() {
            self.shadow_total += 1;
            if pred == page {
                self.shadow_hits += 1;
            }
        }
        self.shadow_next =
            stride.and_then(|s| page.checked_add_signed(s));
        if self.shadow_total >= self.cfg.min_samples {
            let acc = self.shadow_hits as f64 / self.shadow_total as f64;
            if acc >= self.cfg.min_accuracy {
                // The stream turned predictable again: fresh start.
                self.disabled = false;
                self.hits = 0;
                self.wasted = 0;
            }
            self.shadow_hits = 0;
            self.shadow_total = 0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> PrefetchConfig {
        PrefetchConfig {
            enabled: true,
            window: 8,
            degree: 4,
            min_accuracy: 0.5,
            min_samples: 8,
        }
    }

    fn feed_seq(p: &mut StridePrefetcher, start: u64, n: u64, stride: i64) {
        let mut page = start;
        for _ in 0..n {
            p.observe_miss(page);
            page = page.checked_add_signed(stride).unwrap();
        }
    }

    #[test]
    fn sequential_stream_triggers_after_window_fills() {
        let mut p = StridePrefetcher::new(cfg());
        // 8 misses = 7 deltas: window (8) not yet full
        for page in 0..8u64 {
            assert_eq!(p.observe_miss(page), None, "page {page}");
        }
        // 9th miss fills the window: unanimous stride 1
        assert_eq!(
            p.observe_miss(8),
            Some(Readahead { stride: 1, degree: 4 })
        );
    }

    #[test]
    fn majority_survives_noise_and_negative_strides() {
        let mut p = StridePrefetcher::new(cfg());
        // descending scan with two noise jumps mixed in
        let pages =
            [1000u64, 996, 992, 988, 50, 984, 980, 976, 972, 968];
        let mut last = None;
        for &pg in &pages {
            last = p.observe_miss(pg);
        }
        assert_eq!(last, Some(Readahead { stride: -4, degree: 4 }));
    }

    #[test]
    fn random_stream_never_proposes() {
        let mut p = StridePrefetcher::new(cfg());
        let mut x = 12345u64;
        for _ in 0..200 {
            x = x
                .wrapping_mul(6364136223846793005)
                .wrapping_add(1442695040888963407);
            assert_eq!(p.observe_miss(x >> 40), None);
        }
        assert!(!p.is_disabled(), "no issue → no accuracy penalty");
    }

    #[test]
    fn disabled_config_observes_nothing() {
        let mut p = StridePrefetcher::new(PrefetchConfig {
            enabled: false,
            ..cfg()
        });
        feed_seq(&mut p, 0, 64, 1);
        assert_eq!(p.observe_miss(64), None);
        assert_eq!(p.issued(), 0);
    }

    #[test]
    fn bad_accuracy_disables_then_shadow_reenables() {
        let mut p = StridePrefetcher::new(cfg());
        feed_seq(&mut p, 0, 9, 1); // window full, proposing
        p.note_issued(8);
        p.record_waste(8); // all 8 evicted unused → accuracy 0
        assert!(p.observe_miss(9).is_none(), "governor must trip");
        assert!(p.is_disabled());
        // still strided while disabled: nothing proposed…
        for page in 10..14u64 {
            assert_eq!(p.observe_miss(page), None);
        }
        assert!(p.is_disabled());
        // …but shadow scoring sees min_samples perfect predictions and
        // re-enables (the run above already banked 4 shadow samples)
        feed_seq(&mut p, 14, 6, 1);
        assert!(!p.is_disabled(), "shadow accuracy must re-enable");
        assert_eq!(
            p.observe_miss(20),
            Some(Readahead { stride: 1, degree: 4 })
        );
    }

    #[test]
    fn shadow_stays_disabled_on_random_stream() {
        let mut p = StridePrefetcher::new(cfg());
        feed_seq(&mut p, 0, 9, 1);
        p.note_issued(8);
        p.record_waste(8);
        assert!(p.observe_miss(9).is_none());
        assert!(p.is_disabled());
        let mut x = 777u64;
        for _ in 0..100 {
            x = x
                .wrapping_mul(6364136223846793005)
                .wrapping_add(1442695040888963407);
            assert_eq!(p.observe_miss(x >> 40), None);
        }
        assert!(p.is_disabled(), "random shadow must not re-enable");
    }

    #[test]
    fn accuracy_counts_hits_and_waste() {
        let mut p = StridePrefetcher::new(cfg());
        assert_eq!(p.accuracy(), 1.0);
        p.note_issued(4);
        p.record_hit();
        p.record_hit();
        p.record_hit();
        p.record_waste(1);
        assert_eq!(p.completed(), 4);
        assert!((p.accuracy() - 0.75).abs() < 1e-9);
        assert_eq!(p.issued(), 4);
        assert_eq!(p.hits(), 3);
        assert_eq!(p.wasted(), 1);
    }
}
