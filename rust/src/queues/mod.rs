//! Staging and Reclaimable queues (§4.1, §5.2): the consistency machinery
//! between the local mempool and remote replicas.
//!
//! Lifecycle of a write set (one block-I/O request → one `tree_entry`):
//!
//! ```text
//! write → [Staging queue] → remote sender thread sends (coalesced)
//!       → [Reclaimable queue] → page slots become reusable
//! ```
//!
//! Writes are serialized in arrival order ("Unlike parallel reading,
//! writing is serialized for data consistency"); the two queues have the
//! same size by construction; the multiple-updates-to-one-page race is
//! handled by the mempool's UPDATE flag (see
//! [`crate::mempool::Mempool::mark_reclaimable`]).

use std::collections::VecDeque;

use crate::sim::Ns;

/// One write set: the §4.1 24-byte `tree_entry` tracking the pages of one
/// block-I/O request.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct WriteSet {
    /// First page number covered.
    pub page: u64,
    /// Mempool slots holding the pages, in page order.
    pub slots: Vec<u32>,
    /// Total bytes in this write set.
    pub bytes: u64,
    /// Virtual time the write set entered staging.
    pub enqueued_at: Ns,
}

impl WriteSet {
    /// Number of pages covered.
    pub fn pages(&self) -> u64 {
        self.slots.len() as u64
    }
}

/// FIFO staging queue of write sets not yet remotely durable.
#[derive(Clone, Debug, Default)]
pub struct StagingQueue {
    q: VecDeque<WriteSet>,
    bytes: u64,
    /// Total write sets ever enqueued (stats).
    pub enqueued: u64,
}

impl StagingQueue {
    /// Empty queue.
    pub fn new() -> Self {
        Self::default()
    }

    /// Append a write set (arrival order == send order).
    pub fn push(&mut self, ws: WriteSet) {
        self.bytes += ws.bytes;
        self.enqueued += 1;
        self.q.push_back(ws);
    }

    /// Next write set to send, without removing it.
    pub fn peek(&self) -> Option<&WriteSet> {
        self.q.front()
    }

    /// Virtual time the front write set entered staging — the earliest
    /// moment the remote sender may begin its next batch.
    pub fn front_enqueued_at(&self) -> Option<Ns> {
        self.q.front().map(|w| w.enqueued_at)
    }

    /// Remove the front write set (it has been sent).
    pub fn pop(&mut self) -> Option<WriteSet> {
        let ws = self.q.pop_front()?;
        self.bytes -= ws.bytes;
        Some(ws)
    }

    /// The `i`-th queued write set (0 = front), without removing it.
    /// The per-lane drive loops scan past sets whose lane is busy, so
    /// the queue needs positional access beyond `peek`.
    pub fn get(&self, i: usize) -> Option<&WriteSet> {
        self.q.get(i)
    }

    /// Remove the `i`-th queued write set (0 = front), preserving the
    /// relative order of everything else — per-lane FIFO holds even
    /// when a lane's batch is plucked from the middle of the queue.
    pub fn remove(&mut self, i: usize) -> Option<WriteSet> {
        let ws = self.q.remove(i)?;
        self.bytes -= ws.bytes;
        Some(ws)
    }

    /// Pop up to `max_bytes` of write sets for one coalesced RDMA message
    /// (§3.3 "message coalescing and batch sending with large size of
    /// RDMA MR"). Always returns at least one write set if non-empty.
    pub fn pop_batch(&mut self, max_bytes: u64) -> Vec<WriteSet> {
        let mut out = Vec::new();
        let mut total = 0;
        while let Some(front) = self.q.front() {
            if !out.is_empty() && total + front.bytes > max_bytes {
                break;
            }
            total += front.bytes;
            out.push(self.pop().expect("front() just returned Some"));
        }
        out
    }

    /// Queued write sets.
    pub fn len(&self) -> usize {
        self.q.len()
    }

    /// True if nothing is staged.
    pub fn is_empty(&self) -> bool {
        self.q.is_empty()
    }

    /// Bytes awaiting send — the "memory pressure on the local mempool"
    /// quantity that migration victim selection cares about.
    pub fn bytes(&self) -> u64 {
        self.bytes
    }
}

/// Pick the staging queue whose front write set entered staging first —
/// the shard the shared remote sender should drain next. Ties break to
/// the lowest shard index so the drain order is deterministic across
/// runs (the multi-shard metrics-merge determinism guarantee). Returns
/// `None` when every queue is empty.
pub fn earliest_front<'a, I>(queues: I) -> Option<usize>
where
    I: IntoIterator<Item = &'a StagingQueue>,
{
    queues
        .into_iter()
        .enumerate()
        .filter_map(|(i, q)| q.front_enqueued_at().map(|t| (t, i)))
        .min()
        .map(|(_, i)| i)
}

/// FIFO queue of write sets whose remote copies are durable; their slots
/// feed the mempool's reclaim LRU.
#[derive(Clone, Debug, Default)]
pub struct ReclaimableQueue {
    q: VecDeque<WriteSet>,
    /// Total write sets that became reclaimable (stats).
    pub completed: u64,
}

impl ReclaimableQueue {
    /// Empty queue.
    pub fn new() -> Self {
        Self::default()
    }

    /// A write set's remote send completed.
    pub fn push(&mut self, ws: WriteSet) {
        self.completed += 1;
        self.q.push_back(ws);
    }

    /// Oldest durable write set.
    pub fn pop(&mut self) -> Option<WriteSet> {
        self.q.pop_front()
    }

    /// Queued write sets.
    pub fn len(&self) -> usize {
        self.q.len()
    }

    /// True if empty.
    pub fn is_empty(&self) -> bool {
        self.q.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ws(page: u64, bytes: u64, at: Ns) -> WriteSet {
        WriteSet {
            page,
            slots: vec![page as u32],
            bytes,
            enqueued_at: at,
        }
    }

    #[test]
    fn staging_is_fifo() {
        let mut s = StagingQueue::new();
        s.push(ws(1, 10, 0));
        s.push(ws(2, 10, 1));
        assert_eq!(s.pop().unwrap().page, 1);
        assert_eq!(s.pop().unwrap().page, 2);
        assert!(s.pop().is_none());
    }

    #[test]
    fn front_enqueued_at_tracks_front() {
        let mut s = StagingQueue::new();
        assert_eq!(s.front_enqueued_at(), None);
        s.push(ws(1, 10, 5));
        s.push(ws(2, 10, 9));
        assert_eq!(s.front_enqueued_at(), Some(5));
        s.pop();
        assert_eq!(s.front_enqueued_at(), Some(9));
    }

    #[test]
    fn bytes_tracks_queue_content() {
        let mut s = StagingQueue::new();
        s.push(ws(1, 100, 0));
        s.push(ws(2, 50, 0));
        assert_eq!(s.bytes(), 150);
        s.pop();
        assert_eq!(s.bytes(), 50);
    }

    #[test]
    fn remove_from_middle_keeps_order_and_bytes() {
        let mut s = StagingQueue::new();
        for i in 0..4 {
            s.push(ws(i, 10 + i, i));
        }
        assert_eq!(s.get(2).unwrap().page, 2);
        assert_eq!(s.remove(2).unwrap().page, 2);
        assert_eq!(s.bytes(), 10 + 11 + 13);
        assert!(s.remove(5).is_none());
        let rest: Vec<_> =
            std::iter::from_fn(|| s.pop()).map(|w| w.page).collect();
        assert_eq!(rest, vec![0, 1, 3]);
    }

    #[test]
    fn batch_coalesces_up_to_max_bytes() {
        let mut s = StagingQueue::new();
        for i in 0..10 {
            s.push(ws(i, 64, 0));
        }
        let batch = s.pop_batch(256);
        assert_eq!(batch.len(), 4);
        assert_eq!(s.len(), 6);
    }

    #[test]
    fn batch_always_returns_one_even_if_oversized() {
        let mut s = StagingQueue::new();
        s.push(ws(1, 10_000, 0));
        let batch = s.pop_batch(256);
        assert_eq!(batch.len(), 1);
    }

    #[test]
    fn batch_preserves_order() {
        let mut s = StagingQueue::new();
        for i in 0..6 {
            s.push(ws(i, 64, i));
        }
        let batch = s.pop_batch(10_000);
        let pages: Vec<_> = batch.iter().map(|w| w.page).collect();
        assert_eq!(pages, vec![0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn earliest_front_picks_oldest_then_lowest_index() {
        let mut a = StagingQueue::new();
        let mut b = StagingQueue::new();
        let mut c = StagingQueue::new();
        assert_eq!(earliest_front([&a, &b, &c]), None);
        b.push(ws(1, 10, 5));
        c.push(ws(2, 10, 3));
        assert_eq!(earliest_front([&a, &b, &c]), Some(2));
        a.push(ws(3, 10, 3)); // same time as c: lowest index wins
        assert_eq!(earliest_front([&a, &b, &c]), Some(0));
    }

    #[test]
    fn reclaimable_counts_completions() {
        let mut r = ReclaimableQueue::new();
        r.push(ws(1, 10, 0));
        r.push(ws(2, 10, 0));
        assert_eq!(r.completed, 2);
        assert_eq!(r.pop().unwrap().page, 1);
        assert_eq!(r.len(), 1);
    }
}
