//! Container memory-limit model (§2.2): each container has a page limit
//! and an LRU resident set. Touching a non-resident page past the limit
//! raises a fault that evicts the LRU page — the swap-out/swap-in traffic
//! that feeds the paging backends. This is the substrate behind the
//! working-set-fit experiments (100/75/50/25 % in Figures 18–21).

use crate::util::Lru;
use crate::PAGE_SIZE;

/// Result of touching one page.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Access {
    /// Page was resident — pure DRAM access.
    Hit,
    /// Page was not resident and fit under the limit (cold fault, no
    /// eviction — first touch of a growing working set).
    ColdFault,
    /// Page was not resident and the limit is full: the returned LRU
    /// victim page must be swapped out (if dirty) and the new page
    /// swapped in.
    Fault {
        /// Page evicted to make room.
        victim: u64,
        /// Whether the victim had been written since it was loaded
        /// (dirty pages must be written back to the paging backend).
        victim_dirty: bool,
    },
}

/// One container: limit + resident set + dirty tracking.
#[derive(Clone, Debug)]
pub struct Container {
    limit_pages: u64,
    resident: Lru<u64>,
    dirty: std::collections::HashSet<u64>,
    /// Faults taken (stats).
    pub faults: u64,
    /// Total page touches (stats).
    pub touches: u64,
}

impl Container {
    /// Container with a memory limit in bytes.
    pub fn new(limit_bytes: u64) -> Self {
        Container {
            limit_pages: (limit_bytes / PAGE_SIZE).max(1),
            resident: Lru::new(),
            dirty: std::collections::HashSet::new(),
            faults: 0,
            touches: 0,
        }
    }

    /// Memory limit in pages.
    pub fn limit_pages(&self) -> u64 {
        self.limit_pages
    }

    /// Currently resident pages.
    pub fn resident_pages(&self) -> u64 {
        self.resident.len() as u64
    }

    /// Touch `page`; `write` marks it dirty. Returns what happened.
    pub fn touch(&mut self, page: u64, write: bool) -> Access {
        self.touches += 1;
        if self.resident.contains(&page) {
            self.resident.touch(page);
            if write {
                self.dirty.insert(page);
            }
            return Access::Hit;
        }
        self.faults += 1;
        let result = if (self.resident.len() as u64) < self.limit_pages {
            Access::ColdFault
        } else {
            let victim = self
                .resident
                .pop_lru()
                .expect("limit_pages >= 1, resident full");
            let victim_dirty = self.dirty.remove(&victim);
            Access::Fault {
                victim,
                victim_dirty,
            }
        };
        self.resident.touch(page);
        if write {
            self.dirty.insert(page);
        }
        result
    }

    /// Is the page resident right now?
    pub fn is_resident(&self, page: u64) -> bool {
        self.resident.contains(&page)
    }

    /// Shrink the limit (the Figure 3 "vary the memory limitation"
    /// experiment); evicts LRU pages until under the new limit, returning
    /// the evicted (page, dirty) pairs in eviction order.
    pub fn set_limit_bytes(&mut self, limit_bytes: u64) -> Vec<(u64, bool)> {
        self.limit_pages = (limit_bytes / PAGE_SIZE).max(1);
        let mut evicted = Vec::new();
        while self.resident.len() as u64 > self.limit_pages {
            let p = self
                .resident
                .pop_lru()
                .expect("resident set is non-empty: len() > limit >= 1");
            let dirty = self.dirty.remove(&p);
            evicted.push((p, dirty));
        }
        evicted
    }

    /// Resident pages currently dirty, in ascending page order (for the
    /// workload drivers' post-load writeback flush).
    pub fn dirty_pages(&self) -> Vec<u64> {
        let mut v: Vec<u64> = self.dirty.iter().copied().collect();
        v.sort_unstable();
        v
    }

    /// Mark a page clean (its data has been written back).
    pub fn clean(&mut self, page: u64) {
        self.dirty.remove(&page);
    }

    /// Fault ratio so far.
    pub fn fault_ratio(&self) -> f64 {
        if self.touches == 0 {
            0.0
        } else {
            self.faults as f64 / self.touches as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn c(pages: u64) -> Container {
        Container::new(pages * PAGE_SIZE)
    }

    #[test]
    fn hits_until_limit_then_faults() {
        let mut ct = c(3);
        assert_eq!(ct.touch(1, false), Access::ColdFault);
        assert_eq!(ct.touch(2, false), Access::ColdFault);
        assert_eq!(ct.touch(3, false), Access::ColdFault);
        assert_eq!(ct.touch(1, false), Access::Hit);
        // 4 faults out LRU=2
        assert_eq!(
            ct.touch(4, false),
            Access::Fault {
                victim: 2,
                victim_dirty: false
            }
        );
        assert!(ct.is_resident(4));
        assert!(!ct.is_resident(2));
    }

    #[test]
    fn dirty_victims_are_flagged() {
        let mut ct = c(2);
        ct.touch(1, true);
        ct.touch(2, false);
        match ct.touch(3, false) {
            Access::Fault {
                victim: 1,
                victim_dirty: true,
            } => {}
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn rewritten_page_is_dirty_once_resident() {
        let mut ct = c(2);
        ct.touch(1, false);
        ct.touch(1, true); // hit that dirties
        ct.touch(2, false);
        match ct.touch(3, false) {
            Access::Fault {
                victim: 1,
                victim_dirty: true,
            } => {}
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn working_set_within_limit_never_faults_after_warmup() {
        let mut ct = c(10);
        for p in 0..10 {
            ct.touch(p, false);
        }
        let faults_before = ct.faults;
        for _ in 0..100 {
            for p in 0..10 {
                ct.touch(p, false);
            }
        }
        assert_eq!(ct.faults, faults_before);
    }

    #[test]
    fn fault_ratio_tracks_overcommit() {
        // Working set 2x the limit with uniform cycling => ~100% faults.
        let mut ct = c(5);
        for round in 0..20 {
            for p in 0..10 {
                ct.touch(p, false);
            }
            let _ = round;
        }
        assert!(ct.fault_ratio() > 0.9);
    }

    #[test]
    fn shrinking_limit_evicts_lru_first() {
        let mut ct = c(4);
        for p in [1, 2, 3, 4] {
            ct.touch(p, p == 1); // page 1 dirty
        }
        ct.touch(1, false); // 1 becomes MRU
        let evicted = ct.set_limit_bytes(2 * PAGE_SIZE);
        assert_eq!(evicted, vec![(2, false), (3, false)]);
        assert_eq!(ct.resident_pages(), 2);
        assert!(ct.is_resident(1) && ct.is_resident(4));
    }
}
