//! Whole-system invariant auditor: the conservation laws the paper's
//! guarantees rest on, checked as data instead of prose.
//!
//! The headline properties — read-your-writes across a migration COMMIT
//! remap, Table-3 replica semantics (a unit dies only when its last copy
//! is gone), lease-bounded host memory per container — are distributed
//! across five interacting subsystems (mempool, arbiter, sharded engine,
//! sender/migration table, prefetcher). Each subsystem owns the checker
//! for the laws over its private state (`Mempool::audit_check`,
//! `RemoteSender::audit_check`, `HostArbiter::audit_check`,
//! `PressureLog::audit_check`, and the cross-structure sweep in
//! [`crate::engine::ShardedEngine::audit_check`]); this module owns the
//! shared vocabulary: the law catalog ([`Law`]), the structured report
//! ([`Violation`]), and the panicking enforcement used at the slow-path
//! crossings.
//!
//! Cost model: checks run when [`enabled`] — `--features audit` or any
//! `debug_assertions` build (so plain `cargo test` is audited). In a
//! release build without the feature every enforcement site is
//! `if false`, compiled away entirely; the auditor only ever *reads*
//! state, so enabling it cannot change virtual-time results either —
//! ci.sh asserts the experiment metrics are bit-identical with the
//! feature on and off.
//!
//! The catalog (the table in ARCHITECTURE.md §"The audit layer" mirrors
//! this, and every law has a firing negative test in `tests/audit.rs`):
//!
//! | law | conserved quantity |
//! |---|---|
//! | [`Law::MempoolAccounting`] | slot/free/retired partition exactness |
//! | [`Law::MempoolCapGrowth`] | growth never lands above the effective cap |
//! | [`Law::MempoolQueueCoherence`] | reclaim/prefetch queues ⟷ slot flags |
//! | [`Law::LeaseSplit`] | Σ shard leases == engine lease |
//! | [`Law::ArbiterLedger`] | Σ leases ≤ budget; floors never violated |
//! | [`Law::ReplicaDistinct`] | unit replicas re-derive via `choose_replicas` |
//! | [`Law::MigrationLegality`] | migration table states/milestones legal |
//! | [`Law::MigratingNotReselected`] | `Migrating` blocks owned by one entry |
//! | [`Law::ParkedFlushOnce`] | parked write sets flushed exactly once |
//! | [`Law::PrefetchIsolation`] | speculative slots never shadow demand data |
//! | [`Law::TimeMonotonic`] | virtual time never runs backwards |
//! | [`Law::PressureLogBounds`] | pressure ring bounded, time-ordered |
//! | [`Law::GptCoherence`] | GPT entries ⟷ resident mempool slots |
//! | [`Law::LaneSequencer`] | cross-lane COMMIT ledger conserved |
//! | [`Law::LaneLockCoherence`] | ring-admitted sets conserved: drained + queued |
//! | [`Law::TierAccounting`] | pool-tier bytes ⟷ resident blocks; tier moves conserved |
//! | [`Law::ReplicaHealth`] | live replica slots never on a Dead peer; damage queued for repair |

use std::fmt;

/// True when audit checks should run: the `audit` feature or any build
/// with debug assertions (tests, dev profile). Call sites guard with
/// `if audit::enabled()` so the checks — and the state walks feeding
/// them — vanish from optimized release builds.
#[inline(always)]
pub const fn enabled() -> bool {
    cfg!(any(feature = "audit", debug_assertions))
}

/// One conservation law in the catalog. Display gives the short name
/// used in reports and negative tests.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Law {
    /// Mempool slot accounting: `capacity == slots - retired`, the
    /// free/retired lists hold distinct `Free` slots, and
    /// `used + free == capacity` with `min_pages ≤ capacity ≤ max_pages`.
    MempoolAccounting,
    /// A mempool grow operation never lands above the effective cap
    /// (`min(max_pages, host_free·fraction, lease)`) in force at grow
    /// time. (A *lowered* cap may lag behind capacity until the next
    /// shrink — that is legal; growing past the cap never is.)
    MempoolCapGrowth,
    /// Queue/flag coherence: a used slot is in the reclaim LRU iff
    /// flagged `reclaimable` and not `prefetched`; in the prefetch queue
    /// iff flagged `prefetched`.
    MempoolQueueCoherence,
    /// The engine's per-shard mempool leases re-split exactly to the
    /// engine-level lease (`u64::MAX` sentinel splits to all-`MAX`).
    LeaseSplit,
    /// The host arbiter ledger: every lease at or above its tenant's
    /// floor, and `Σ leases ≤ budget` whenever the budget covers the
    /// floors.
    ArbiterLedger,
    /// Unit-map replica lists re-validate against
    /// [`crate::replication::choose_replicas`]: distinct nodes, sender
    /// excluded, primary first, one registered block per replica.
    ReplicaDistinct,
    /// Migration-table legality: at most one live entry per unit, state
    /// implies its fields (an activated entry has a destination; a
    /// copying entry has a registered destination block), and the
    /// milestone clocks are ordered
    /// (`scheduled ≤ park_from ≤ copy_start ≤ copy_end`).
    MigrationLegality,
    /// An MR block in [`crate::mrpool::MrState::Migrating`] is owned by
    /// exactly one live migration entry as its source — victim selection
    /// can never re-select it, and no block migrates twice at once.
    MigratingNotReselected,
    /// Parked write sets are flushed exactly once at COMMIT:
    /// `parked_sets == flushed_sets + Σ currently-parked`.
    ParkedFlushOnce,
    /// Prefetch isolation: every prefetch-tagged slot is reclaimable
    /// (its remote copy is valid by construction), so speculation can
    /// always be displaced and never pins out live demand data.
    PrefetchIsolation,
    /// Simulated time is monotone at every audited crossing: a shard is
    /// never driven at a `now` earlier than its last crossing.
    TimeMonotonic,
    /// The pressure-episode ring stays within its bound, entries are
    /// time-ordered, and drops are only counted once the ring is full.
    PressureLogBounds,
    /// GPT ⟷ mempool bijection per shard: `gpt.len()` equals the used
    /// slot count and every used slot's page maps back to that slot.
    GptCoherence,
    /// The cross-lane sequencer's COMMIT ledger is conserved: tickets
    /// issued == migrations completed == records pushed. Lanes retire
    /// their machines independently; this three-way equality proves no
    /// COMMIT bypassed the sequencer or was double-counted by two
    /// lanes.
    LaneSequencer,
    /// Per-lane admission-ring conservation: every write set admitted
    /// to a lane's slow-path ring was either drained (dispatched into
    /// the lane — in flight, parked, or completed to a mailbox) or is
    /// still queued in the ring: `admitted == drained + Σ queued`. No
    /// set is ever lost (or double-counted) between the lock-free
    /// admission side and the locked dispatch side.
    LaneLockCoherence,
    /// Tier accounting: every node's cached pool-tier byte ledger
    /// equals a recount over its resident pool-tier blocks, and
    /// `promotions + demotions` equals the number of committed
    /// cross-tier migration records — no block changes tier outside
    /// the migration pipeline, and none is double-counted.
    TierAccounting,
    /// Failure-domain ledger coherence: no live replica slot references
    /// a Dead peer (the death sweep purged them in the same event
    /// application that declared the death), a unit with no slots is
    /// dead, and — with health on — every under-replicated live unit
    /// is queued for the re-replication pump, owned by a live
    /// migration machine, or covered by the disk backup.
    ReplicaHealth,
}

impl Law {
    /// Short stable identifier (used by reports and negative tests).
    pub fn name(self) -> &'static str {
        match self {
            Law::MempoolAccounting => "mempool-accounting",
            Law::MempoolCapGrowth => "mempool-cap-growth",
            Law::MempoolQueueCoherence => "mempool-queue-coherence",
            Law::LeaseSplit => "lease-split",
            Law::ArbiterLedger => "arbiter-ledger",
            Law::ReplicaDistinct => "replica-distinct",
            Law::MigrationLegality => "migration-legality",
            Law::MigratingNotReselected => "migrating-not-reselected",
            Law::ParkedFlushOnce => "parked-flush-once",
            Law::PrefetchIsolation => "prefetch-isolation",
            Law::TimeMonotonic => "time-monotonic",
            Law::PressureLogBounds => "pressure-log-bounds",
            Law::GptCoherence => "gpt-coherence",
            Law::LaneSequencer => "lane-sequencer",
            Law::LaneLockCoherence => "lane-lock-coherence",
            Law::TierAccounting => "tier-accounting",
            Law::ReplicaHealth => "replica-health",
        }
    }
}

impl fmt::Display for Law {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// A violated conservation law: which law, where, and the state that
/// contradicts it. `Display` renders the full report line the fuzzer
/// and the enforcement panic print.
#[derive(Clone, Debug)]
pub struct Violation {
    /// The broken law.
    pub law: Law,
    /// Shard the violation was observed on (`None` for engine-global,
    /// arbiter or cluster state).
    pub shard: Option<usize>,
    /// What exactly is inconsistent.
    pub detail: String,
    /// Snapshot of the relevant counters/fields at detection time.
    pub snapshot: String,
}

impl Violation {
    /// Build a violation report.
    pub fn new(
        law: Law,
        shard: Option<usize>,
        detail: impl Into<String>,
        snapshot: impl Into<String>,
    ) -> Self {
        Violation {
            law,
            shard,
            detail: detail.into(),
            snapshot: snapshot.into(),
        }
    }
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.shard {
            Some(s) => write!(
                f,
                "audit violation [{}] shard {}: {} (state: {})",
                self.law, s, self.detail, self.snapshot
            ),
            None => write!(
                f,
                "audit violation [{}]: {} (state: {})",
                self.law, self.detail, self.snapshot
            ),
        }
    }
}

/// Panic with a full report if any violation was collected — the
/// enforcement half used at slow-path crossings, cluster-event
/// application and migration milestones. (Tests that want to *observe*
/// violations call the non-panicking `audit_check` methods directly.)
pub fn enforce(violations: &[Violation]) {
    if violations.is_empty() {
        return;
    }
    let mut msg = String::from("invariant audit failed:\n");
    for v in violations {
        msg.push_str(&format!("  {v}\n"));
    }
    panic!("{msg}");
}

/// Convenience for checkers: push a violation when `ok` is false.
pub(crate) fn check(
    out: &mut Vec<Violation>,
    ok: bool,
    law: Law,
    shard: Option<usize>,
    detail: impl FnOnce() -> String,
    snapshot: impl FnOnce() -> String,
) {
    if !ok {
        out.push(Violation::new(law, shard, detail(), snapshot()));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_names_are_stable() {
        assert_eq!(Law::MempoolAccounting.to_string(), "mempool-accounting");
        assert_eq!(Law::GptCoherence.name(), "gpt-coherence");
    }

    #[test]
    fn violation_report_names_law_shard_and_state() {
        let v = Violation::new(
            Law::LeaseSplit,
            Some(3),
            "shard lease sum 100 != engine lease 128",
            "leases=[25,25,25,25]",
        );
        let s = v.to_string();
        assert!(s.contains("lease-split"));
        assert!(s.contains("shard 3"));
        assert!(s.contains("leases="));
    }

    #[test]
    #[should_panic(expected = "invariant audit failed")]
    fn enforce_panics_with_report() {
        enforce(&[Violation::new(
            Law::TimeMonotonic,
            None,
            "now 5 < last 9",
            "",
        )]);
    }

    #[test]
    fn enforce_is_silent_when_clean() {
        enforce(&[]);
    }
}
