//! PJRT runtime bridge: loads the AOT-compiled HLO artifacts produced by
//! `python/compile/aot.py` and executes them — the L3↔L2 bridge. Python
//! never runs at request time.
//!
//! The offline toolchain image carries **no crate registry**, so this
//! module has two build modes:
//!
//! * default (no features): a dependency-free stub. [`Literal`] is an
//!   in-crate host tensor, the literal builders and spec plumbing all
//!   work, but [`Runtime::load`] registers no executables — callers see
//!   "artifact not loaded" from [`Runtime::get`] and fall back (the CLI's
//!   `valet ml` substitutes a constant per-step cost and says so).
//! * `--features pjrt`: the real PJRT CPU client via an `xla` crate
//!   (xla_extension 0.5.x; interchange format is HLO **text** because
//!   jax ≥ 0.5 emits HloModuleProto with 64-bit instruction ids that
//!   xla_extension 0.5.1 rejects — the text parser reassigns ids). The
//!   dependency must be patched into `Cargo.toml` where a registry is
//!   available; see the manifest's feature note.

mod artifacts;

pub use artifacts::{ArtifactSpec, ARTIFACT_SPECS, GBOOST_D, GBOOST_N, KMEANS_D, KMEANS_K, KMEANS_N, LOGREG_D, LOGREG_N, RF_D, RF_K, RF_N, TEXTRANK_N};

use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Runtime error: a message chain (the offline build carries no `anyhow`;
/// this covers the same "context + cause" reporting the module needs).
#[derive(Clone, Debug)]
pub struct RuntimeError(String);

impl RuntimeError {
    /// Build from any displayable message.
    pub fn msg(m: impl std::fmt::Display) -> Self {
        RuntimeError(m.to_string())
    }

    /// Wrap with leading context ("context: cause").
    pub fn context(self, c: impl std::fmt::Display) -> Self {
        RuntimeError(format!("{c}: {}", self.0))
    }
}

impl std::fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for RuntimeError {}

/// Result alias used across the runtime API.
pub type Result<T> = std::result::Result<T, RuntimeError>;

// ---------------------------------------------------------------------
// Host tensor literal
// ---------------------------------------------------------------------

/// A host-side tensor literal (f32 payload + shape). In the default
/// build this is the in-crate stand-in for `xla::Literal`; the pjrt
/// feature converts at the execution boundary.
#[derive(Clone, Debug, PartialEq)]
pub struct Literal {
    data: Vec<f32>,
    dims: Vec<i64>,
}

impl Literal {
    /// Dimensions (empty = scalar).
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    /// Element count.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True for a zero-element literal.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
}

/// Build a rank-N f32 literal from a flat slice.
pub fn f32_literal(data: &[f32], dims: &[i64]) -> Result<Literal> {
    let n: i64 = dims.iter().product();
    if n as usize != data.len() {
        return Err(RuntimeError::msg(format!(
            "shape {:?} != len {}",
            dims,
            data.len()
        )));
    }
    Ok(Literal {
        data: data.to_vec(),
        dims: dims.to_vec(),
    })
}

/// Build a scalar f32 literal (rank 0).
pub fn f32_scalar(v: f32) -> Result<Literal> {
    Ok(Literal {
        data: vec![v],
        dims: Vec::new(),
    })
}

/// Extract an f32 vector from a literal.
pub fn to_f32_vec(lit: &Literal) -> Result<Vec<f32>> {
    Ok(lit.data.clone())
}

/// Extract an i32 vector from a literal (rounded element-wise — PJRT
/// returns integer outputs in their own literals; the stub stores f32).
pub fn to_i32_vec(lit: &Literal) -> Result<Vec<i32>> {
    Ok(lit.data.iter().map(|&v| v as i32).collect())
}

/// Random (seeded) input literals matching a spec — used by examples and
/// benches to measure step compute without real data.
pub fn random_inputs(spec: &ArtifactSpec) -> Result<Vec<Literal>> {
    let mut rng = crate::util::Rng::new(0xA07);
    spec.inputs
        .iter()
        .map(|inp| {
            let n: i64 = inp.dims.iter().product::<i64>().max(1);
            let data: Vec<f32> = (0..n)
                .map(|_| (rng.f64() as f32) * 2.0 - 1.0)
                .collect();
            if inp.dims.is_empty() {
                f32_scalar(data[0].abs() * 0.1)
            } else {
                f32_literal(&data, inp.dims)
            }
        })
        .collect()
}

// ---------------------------------------------------------------------
// Executable + Runtime
// ---------------------------------------------------------------------

/// A loaded, compiled artifact.
pub struct Executable {
    #[cfg(feature = "pjrt")]
    exe: xla::PjRtLoadedExecutable,
    /// Spec (name + input shapes) for validation.
    pub spec: &'static ArtifactSpec,
}

impl Executable {
    /// Execute with the given literals; returns the flattened tuple of
    /// outputs (aot.py lowers with `return_tuple=True`).
    pub fn run(&self, inputs: &[Literal]) -> Result<Vec<Literal>> {
        if inputs.len() != self.spec.inputs.len() {
            return Err(RuntimeError::msg(format!(
                "{}: expected {} inputs, got {}",
                self.spec.name,
                self.spec.inputs.len(),
                inputs.len()
            )));
        }
        self.run_inner(inputs)
    }

    #[cfg(feature = "pjrt")]
    fn run_inner(&self, inputs: &[Literal]) -> Result<Vec<Literal>> {
        let xla_inputs: Vec<xla::Literal> = inputs
            .iter()
            .map(|l| {
                let lit = xla::Literal::vec1(&l.data);
                if l.dims.is_empty() {
                    Ok(lit.reshape(&[])?)
                } else {
                    Ok(lit.reshape(&l.dims)?)
                }
            })
            .collect::<std::result::Result<_, xla::Error>>()
            .map_err(RuntimeError::msg)?;
        let mut result = self
            .exe
            .execute::<xla::Literal>(&xla_inputs)
            .map_err(RuntimeError::msg)?[0][0]
            .to_literal_sync()
            .map_err(RuntimeError::msg)?;
        let tuple = result.decompose_tuple().map_err(RuntimeError::msg)?;
        tuple
            .iter()
            .map(|t| {
                // Outputs may be F32 or S32 (kmeans_step's assignment
                // vector is S32); the host Literal stores f32, which is
                // exact for the index-sized integers the artifacts emit
                // and round-trips through to_i32_vec. Output shapes are
                // flattened to rank 1 — callers consume flat vectors via
                // to_f32_vec / to_i32_vec.
                let data: Vec<f32> = match t.to_vec::<f32>() {
                    Ok(v) => v,
                    Err(_) => t
                        .to_vec::<i32>()
                        .map_err(RuntimeError::msg)?
                        .into_iter()
                        .map(|v| v as f32)
                        .collect(),
                };
                f32_literal(&data, &[data.len() as i64])
            })
            .collect()
    }

    #[cfg(not(feature = "pjrt"))]
    fn run_inner(&self, _inputs: &[Literal]) -> Result<Vec<Literal>> {
        Err(RuntimeError::msg(format!(
            "{}: PJRT execution unavailable (build with --features pjrt)",
            self.spec.name
        )))
    }
}

/// The runtime: the compiled executables (+ the PJRT CPU client when the
/// pjrt feature is on).
pub struct Runtime {
    #[cfg(feature = "pjrt")]
    #[allow(dead_code)]
    client: xla::PjRtClient,
    exes: HashMap<&'static str, Executable>,
    /// Where artifacts were loaded from.
    pub dir: PathBuf,
}

impl Runtime {
    /// Create the runtime over `dir`. With the pjrt feature, compiles
    /// every artifact found there that matches a known spec; without it,
    /// nothing loads (callers check [`Runtime::get`] and fall back).
    /// Missing artifacts are always skipped, never an error.
    #[cfg(feature = "pjrt")]
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let client = xla::PjRtClient::cpu()
            .map_err(RuntimeError::msg)
            .map_err(|e| e.context("creating PJRT CPU client"))?;
        let mut exes = HashMap::new();
        for spec in ARTIFACT_SPECS {
            let path = dir.join(format!("{}.hlo.txt", spec.name));
            if !path.exists() {
                continue;
            }
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().expect(
                    "artifact paths are ASCII spec names under `dir`",
                ),
            )
            .map_err(RuntimeError::msg)
            .map_err(|e| e.context(format!("parsing {}", path.display())))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(RuntimeError::msg)
                .map_err(|e| e.context(format!("compiling {}", spec.name)))?;
            exes.insert(spec.name, Executable { exe, spec });
        }
        Ok(Runtime { client, exes, dir })
    }

    /// Stub load: records the directory, registers nothing.
    #[cfg(not(feature = "pjrt"))]
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        Ok(Runtime {
            exes: HashMap::new(),
            dir: dir.as_ref().to_path_buf(),
        })
    }

    /// Default artifact location (repo-root `artifacts/`), overridable
    /// via the VALET_ARTIFACTS environment variable.
    pub fn default_dir() -> PathBuf {
        if let Ok(p) = std::env::var("VALET_ARTIFACTS") {
            return PathBuf::from(p);
        }
        PathBuf::from("artifacts")
    }

    /// Fetch a compiled artifact by name.
    pub fn get(&self, name: &str) -> Result<&Executable> {
        self.exes.get(name).ok_or_else(|| {
            RuntimeError::msg(format!(
                "artifact '{name}' not loaded (run `make artifacts` and \
                 build with --features pjrt)"
            ))
        })
    }

    /// Names of everything loaded.
    pub fn loaded(&self) -> Vec<&'static str> {
        let mut v: Vec<_> = self.exes.keys().copied().collect();
        v.sort();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Runtime tests that need artifacts + PJRT live in rust/tests/
    // (integration, pjrt feature); here we check spec + literal plumbing.

    #[test]
    fn specs_are_wellformed() {
        assert!(ARTIFACT_SPECS.len() >= 5);
        for s in ARTIFACT_SPECS {
            assert!(!s.inputs.is_empty(), "{}", s.name);
        }
    }

    #[test]
    fn literal_builders() {
        let l = f32_literal(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        assert_eq!(to_f32_vec(&l).unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(l.dims(), &[2, 2]);
        assert!(f32_literal(&[1.0], &[2]).is_err());
        let s = f32_scalar(7.5).unwrap();
        assert_eq!(to_f32_vec(&s).unwrap(), vec![7.5]);
        assert!(s.dims().is_empty());
        assert_eq!(to_i32_vec(&s).unwrap(), vec![7]);
    }

    #[test]
    fn random_inputs_match_spec_shapes() {
        for spec in ARTIFACT_SPECS {
            let ins = random_inputs(spec).unwrap();
            assert_eq!(ins.len(), spec.inputs.len(), "{}", spec.name);
            for (lit, want) in ins.iter().zip(spec.inputs) {
                let n: i64 = want.dims.iter().product::<i64>().max(1);
                assert_eq!(lit.len() as i64, n, "{}", spec.name);
            }
        }
    }

    #[test]
    fn missing_artifact_is_reported() {
        let rt = Runtime::load("/nonexistent-dir").unwrap();
        assert!(rt.get("logreg_step").is_err());
        assert!(rt.loaded().is_empty());
    }

    #[test]
    fn error_context_chains() {
        let e = RuntimeError::msg("cause").context("context");
        assert_eq!(e.to_string(), "context: cause");
    }
}
