//! PJRT runtime: loads the AOT-compiled HLO artifacts produced by
//! `python/compile/aot.py` and executes them on the CPU PJRT client —
//! the L3↔L2 bridge. Python never runs at request time; the rust binary
//! is self-contained once `artifacts/` exists.
//!
//! Interchange format is HLO **text** (see /opt/xla-example/README.md):
//! jax ≥ 0.5 emits HloModuleProto with 64-bit instruction ids that the
//! crate's xla_extension 0.5.1 rejects; the text parser reassigns ids.

mod artifacts;

pub use artifacts::{ArtifactSpec, ARTIFACT_SPECS, GBOOST_D, GBOOST_N, KMEANS_D, KMEANS_K, KMEANS_N, LOGREG_D, LOGREG_N, RF_D, RF_K, RF_N, TEXTRANK_N};

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

/// A loaded, compiled artifact.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    /// Spec (name + input shapes) for validation.
    pub spec: &'static ArtifactSpec,
}

impl Executable {
    /// Execute with the given literals; returns the flattened tuple of
    /// outputs (aot.py lowers with `return_tuple=True`).
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        if inputs.len() != self.spec.inputs.len() {
            return Err(anyhow!(
                "{}: expected {} inputs, got {}",
                self.spec.name,
                self.spec.inputs.len(),
                inputs.len()
            ));
        }
        let mut result = self.exe.execute::<xla::Literal>(inputs)?[0][0]
            .to_literal_sync()?;
        let tuple = result.decompose_tuple()?;
        Ok(tuple)
    }
}

/// The runtime: one PJRT CPU client + the compiled executables.
pub struct Runtime {
    #[allow(dead_code)]
    client: xla::PjRtClient,
    exes: HashMap<&'static str, Executable>,
    /// Where artifacts were loaded from.
    pub dir: PathBuf,
}

impl Runtime {
    /// Create the CPU client and compile every artifact found in `dir`
    /// that matches a known spec. Missing artifacts are skipped (callers
    /// check [`Runtime::get`]).
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let client =
            xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let mut exes = HashMap::new();
        for spec in ARTIFACT_SPECS {
            let path = dir.join(format!("{}.hlo.txt", spec.name));
            if !path.exists() {
                continue;
            }
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().unwrap(),
            )
            .with_context(|| format!("parsing {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compiling {}", spec.name))?;
            exes.insert(spec.name, Executable { exe, spec });
        }
        Ok(Runtime { client, exes, dir })
    }

    /// Default artifact location (repo-root `artifacts/`), overridable
    /// via the VALET_ARTIFACTS environment variable.
    pub fn default_dir() -> PathBuf {
        if let Ok(p) = std::env::var("VALET_ARTIFACTS") {
            return PathBuf::from(p);
        }
        PathBuf::from("artifacts")
    }

    /// Fetch a compiled artifact by name.
    pub fn get(&self, name: &str) -> Result<&Executable> {
        self.exes.get(name).ok_or_else(|| {
            anyhow!("artifact '{name}' not loaded (run `make artifacts`)")
        })
    }

    /// Names of everything loaded.
    pub fn loaded(&self) -> Vec<&'static str> {
        let mut v: Vec<_> = self.exes.keys().copied().collect();
        v.sort();
        v
    }
}

/// Build a rank-N f32 literal from a flat slice.
pub fn f32_literal(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    if n as usize != data.len() {
        return Err(anyhow!("shape {:?} != len {}", dims, data.len()));
    }
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

/// Build a scalar f32 literal (rank 0).
pub fn f32_scalar(v: f32) -> Result<xla::Literal> {
    Ok(xla::Literal::scalar(v))
}

/// Extract an f32 vector from a literal.
pub fn to_f32_vec(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

/// Extract an i32 vector from a literal.
pub fn to_i32_vec(lit: &xla::Literal) -> Result<Vec<i32>> {
    Ok(lit.to_vec::<i32>()?)
}


/// Random (seeded) input literals matching a spec — used by examples and
/// benches to measure step compute without real data.
pub fn random_inputs(spec: &ArtifactSpec) -> Result<Vec<xla::Literal>> {
    let mut rng = crate::util::Rng::new(0xA07);
    spec.inputs
        .iter()
        .map(|inp| {
            let n: i64 = inp.dims.iter().product::<i64>().max(1);
            let data: Vec<f32> = (0..n)
                .map(|_| (rng.f64() as f32) * 2.0 - 1.0)
                .collect();
            if inp.dims.is_empty() {
                f32_scalar(data[0].abs() * 0.1)
            } else {
                f32_literal(&data, inp.dims)
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    // Runtime tests that need artifacts live in rust/tests/ (integration,
    // after `make artifacts`); here we only check spec plumbing.

    #[test]
    fn specs_are_wellformed() {
        assert!(ARTIFACT_SPECS.len() >= 5);
        for s in ARTIFACT_SPECS {
            assert!(!s.inputs.is_empty(), "{}", s.name);
        }
    }

    #[test]
    fn literal_builders() {
        let l = f32_literal(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(f32_literal(&[1.0], &[2]).is_err());
        let s = f32_scalar(7.5).unwrap();
        assert_eq!(s.to_vec::<f32>().unwrap(), vec![7.5]);
    }

    #[test]
    fn missing_artifact_is_reported() {
        let rt = Runtime::load("/nonexistent-dir").unwrap();
        assert!(rt.get("logreg_step").is_err());
    }
}
