//! Artifact registry: the rust mirror of `python/compile/model.py`'s
//! `ARTIFACTS` dict. Shapes must match the AOT lowering exactly (they are
//! baked into the executables); `python/tests/test_model.py` checks the
//! python side, `rust/tests/runtime_roundtrip.rs` checks this side.

/// Shapes for the logistic-regression step (model.py LOGREG_N/D).
pub const LOGREG_N: usize = 4096;
/// Feature dimension.
pub const LOGREG_D: usize = 256;
/// K-Means sample count.
pub const KMEANS_N: usize = 4096;
/// K-Means feature dimension.
pub const KMEANS_D: usize = 64;
/// K-Means cluster count.
pub const KMEANS_K: usize = 16;
/// TextRank graph size.
pub const TEXTRANK_N: usize = 1024;
/// Gradient-boosting sample count.
pub const GBOOST_N: usize = 4096;
/// Gradient-boosting feature count.
pub const GBOOST_D: usize = 64;
/// Random-forest sample count.
pub const RF_N: usize = 4096;
/// Random-forest feature count.
pub const RF_D: usize = 64;
/// Random-forest prototype count.
pub const RF_K: usize = 32;

/// Dtype of an artifact input.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    /// 32-bit float.
    F32,
}

/// One input's shape.
#[derive(Clone, Debug)]
pub struct InputSpec {
    /// Dimensions (empty = scalar).
    pub dims: &'static [i64],
    /// Element type.
    pub dtype: Dtype,
}

/// One artifact: name + ordered inputs.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    /// Artifact name (= python ARTIFACTS key = file stem).
    pub name: &'static str,
    /// Input shapes in call order.
    pub inputs: &'static [InputSpec],
}

const F32: Dtype = Dtype::F32;

/// All artifacts `aot.py` emits.
pub static ARTIFACT_SPECS: &[ArtifactSpec] = &[
    ArtifactSpec {
        name: "logreg_step",
        inputs: &[
            InputSpec { dims: &[LOGREG_D as i64], dtype: F32 },
            InputSpec {
                dims: &[LOGREG_N as i64, LOGREG_D as i64],
                dtype: F32,
            },
            InputSpec { dims: &[LOGREG_N as i64], dtype: F32 },
            InputSpec { dims: &[], dtype: F32 },
        ],
    },
    ArtifactSpec {
        name: "kmeans_step",
        inputs: &[
            InputSpec {
                dims: &[KMEANS_N as i64, KMEANS_D as i64],
                dtype: F32,
            },
            InputSpec {
                dims: &[KMEANS_K as i64, KMEANS_D as i64],
                dtype: F32,
            },
        ],
    },
    ArtifactSpec {
        name: "textrank_step",
        inputs: &[
            InputSpec {
                dims: &[TEXTRANK_N as i64, TEXTRANK_N as i64],
                dtype: F32,
            },
            InputSpec { dims: &[TEXTRANK_N as i64], dtype: F32 },
            InputSpec { dims: &[1], dtype: F32 },
        ],
    },
    ArtifactSpec {
        name: "gboost_stump_step",
        inputs: &[
            InputSpec {
                dims: &[GBOOST_N as i64, GBOOST_D as i64],
                dtype: F32,
            },
            InputSpec { dims: &[GBOOST_N as i64], dtype: F32 },
        ],
    },
    ArtifactSpec {
        name: "rf_proximity_step",
        inputs: &[
            InputSpec { dims: &[RF_N as i64, RF_D as i64], dtype: F32 },
            InputSpec { dims: &[RF_K as i64, RF_D as i64], dtype: F32 },
        ],
    },
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_are_unique() {
        let mut names: Vec<_> =
            ARTIFACT_SPECS.iter().map(|s| s.name).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), ARTIFACT_SPECS.len());
    }

    #[test]
    fn logreg_batch_is_8mb_of_paged_data() {
        // sanity: one logreg step consumes N*D floats = 4 MB of X
        assert_eq!(LOGREG_N * LOGREG_D * 4, 4 << 20);
    }
}
