//! RDMA fabric model: per-node NICs with queue pairs (connections),
//! registered memory regions, one-sided and two-sided verbs, and a WQE
//! cache occupancy model (FaRM [12] observed that flooding the RNIC with
//! work-queue entries thrashes its on-NIC cache; Valet's message
//! coalescing exists to avoid exactly that).
//!
//! Latencies come from [`LatencyConfig`], which defaults to the paper's
//! Table 1 measurements. The fabric is a pure virtual-time model: verbs
//! reserve time on the initiator NIC's TX server (and, for two-sided
//! verbs, the target's RX/CPU server), so saturation and queueing emerge
//! naturally.

use std::collections::HashSet;

use crate::config::LatencyConfig;
use crate::sim::{Ns, Server};
use crate::NodeId;

/// Outcome of a verb: when it started on the wire and when the initiator
/// observed completion (WC polled from the CQ).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct VerbDone {
    /// Time the NIC began servicing the verb.
    pub start: Ns,
    /// Completion time as seen by the initiator.
    pub end: Ns,
}

/// Per-node NIC state.
#[derive(Clone, Debug, Default)]
struct Nic {
    /// TX pipeline (posting + wire time for initiated verbs).
    tx: Server,
    /// RX/CPU server — only two-sided verbs consume receiver CPU; this is
    /// the "receiver-side CPU involvement" the paper calls out in §1.
    rx_cpu: Server,
    /// Established queue-pair connections (peer node ids).
    connected: HashSet<NodeId>,
    /// Outstanding WQEs modeled as a decaying counter: each posted verb
    /// bumps it; it drains as virtual time passes (see `wqe_pressure`).
    wqe_outstanding: u64,
    /// Last time the WQE counter was decayed.
    wqe_last: Ns,
    /// Verbs posted (stats).
    verbs_posted: u64,
    /// WQE cache misses charged (stats).
    wqe_misses: u64,
    /// Pool-tier loads/stores initiated (stats; these post no WQE).
    pool_accesses: u64,
}

/// The cluster-wide RDMA fabric.
#[derive(Clone, Debug)]
pub struct Fabric {
    nics: Vec<Nic>,
    lat: LatencyConfig,
    /// Connections established (stats).
    pub connections_made: u64,
    /// MR mappings performed (stats).
    pub mappings_made: u64,
}

impl Fabric {
    /// A fabric over `nodes` nodes with the given latency model.
    pub fn new(nodes: usize, lat: LatencyConfig) -> Self {
        Fabric {
            nics: vec![Nic::default(); nodes],
            lat,
            connections_made: 0,
            mappings_made: 0,
        }
    }

    /// Latency model in use.
    pub fn latency(&self) -> &LatencyConfig {
        &self.lat
    }

    /// Is `from` connected to `to`?
    pub fn is_connected(&self, from: NodeId, to: NodeId) -> bool {
        self.nics[from].connected.contains(&to)
    }

    /// Ensure a QP between `from` and `to` exists. Returns the time the
    /// connection becomes usable and whether a new connection was set up
    /// (address/route resolution + establishment, Table 1's 200 ms).
    pub fn ensure_connected(
        &mut self,
        now: Ns,
        from: NodeId,
        to: NodeId,
    ) -> (Ns, bool) {
        if self.is_connected(from, to) {
            return (now, false);
        }
        let dur = self.lat.connect;
        let (_, end) = self.nics[from].tx.serve(now, dur);
        self.nics[from].connected.insert(to);
        self.nics[to].connected.insert(from);
        self.connections_made += 1;
        (end, true)
    }

    /// Map a remote MR block: query candidates, exchange addr/rkey
    /// (Table 1's 62 ms). Charged on the initiator's TX pipeline.
    pub fn map_mr(&mut self, now: Ns, from: NodeId) -> Ns {
        let dur = self.lat.map_mr;
        let (_, end) = self.nics[from].tx.serve(now, dur);
        self.mappings_made += 1;
        end
    }

    /// Decay + bump the WQE occupancy counter; returns the penalty to add
    /// if the RNIC's WQE cache is thrashing. Model: outstanding WQEs
    /// drain at ~1 per µs (completion rate of small verbs); posting more
    /// than `wqe_cache_entries` in flight causes misses [12].
    fn wqe_pressure(&mut self, node: NodeId, now: Ns) -> Ns {
        let nic = &mut self.nics[node];
        let elapsed_us = now.saturating_sub(nic.wqe_last) / 1_000;
        nic.wqe_outstanding = nic.wqe_outstanding.saturating_sub(elapsed_us);
        nic.wqe_last = now;
        nic.wqe_outstanding += 1;
        if nic.wqe_outstanding > self.lat.wqe_cache_entries as u64 {
            nic.wqe_misses += 1;
            self.lat.wqe_miss_penalty
        } else {
            0
        }
    }

    /// One-sided RDMA WRITE of `bytes` from `from` into `to`'s MR.
    /// Completion = WC polled from the CQ; the remote CPU is NOT involved.
    ///
    /// Queueing model: only the wire time (bytes × per-byte rate) occupies
    /// the initiator's TX pipeline — verbs from concurrent requesters
    /// pipeline on the NIC; the base latency (posting + fabric RTT) is
    /// added on top of the occupancy slot. An isolated 512 KB write still
    /// lands on Table 1's 51.35 µs.
    ///
    /// Requires an established connection (callers go through
    /// [`Fabric::ensure_connected`] first; debug-asserted here).
    pub fn rdma_write(
        &mut self,
        now: Ns,
        from: NodeId,
        to: NodeId,
        bytes: u64,
    ) -> VerbDone {
        debug_assert!(self.is_connected(from, to), "write w/o connection");
        let penalty = self.wqe_pressure(from, now);
        let occupancy = (self.lat.rdma_per_byte * bytes as f64) as Ns;
        let (start, occ_end) = self.nics[from].tx.serve(now, occupancy);
        let end = occ_end + self.lat.rdma_write_base + penalty;
        self.nics[from].verbs_posted += 1;
        VerbDone { start, end }
    }

    /// One-sided RDMA READ of `bytes` from `to`'s MR into `from`. Same
    /// occupancy/latency split as [`Fabric::rdma_write`]; the read base
    /// carries the full round trip (Table 1: 36.48 µs @ 4 KB).
    pub fn rdma_read(
        &mut self,
        now: Ns,
        from: NodeId,
        to: NodeId,
        bytes: u64,
    ) -> VerbDone {
        debug_assert!(self.is_connected(from, to), "read w/o connection");
        let penalty = self.wqe_pressure(from, now);
        let occupancy = (self.lat.rdma_per_byte * bytes as f64) as Ns;
        let (start, occ_end) = self.nics[from].tx.serve(now, occupancy);
        let end = occ_end + self.lat.rdma_read_base + penalty;
        self.nics[from].verbs_posted += 1;
        VerbDone { start, end }
    }

    /// Pool-tier WRITE of `bytes` from `from` into `to`'s slice of the
    /// CXL-style pooled appliance. Load/store semantics: no queue pair
    /// is required and no WQE is posted (so no cache-thrash penalty) —
    /// but the payload still crosses the initiator's pipe, so wire
    /// occupancy charges on `from`'s TX server exactly like the RDMA
    /// verbs and backlog modeling keeps holding. The base latency is
    /// ~a NUMA hop (`pool_write_base`), an order of magnitude below
    /// the fabric round trip.
    pub fn pool_write(
        &mut self,
        now: Ns,
        from: NodeId,
        to: NodeId,
        bytes: u64,
    ) -> VerbDone {
        let _ = to; // capacity is the receiver's; latency is not
        let occupancy = (self.lat.pool_per_byte * bytes as f64) as Ns;
        let (start, occ_end) = self.nics[from].tx.serve(now, occupancy);
        let end = occ_end + self.lat.pool_write_base;
        self.nics[from].pool_accesses += 1;
        VerbDone { start, end }
    }

    /// Pool-tier READ of `bytes` from `to`'s slice of the pooled
    /// appliance into `from`. Same occupancy/latency split as
    /// [`Fabric::pool_write`].
    pub fn pool_read(
        &mut self,
        now: Ns,
        from: NodeId,
        to: NodeId,
        bytes: u64,
    ) -> VerbDone {
        let _ = to;
        let occupancy = (self.lat.pool_per_byte * bytes as f64) as Ns;
        let (start, occ_end) = self.nics[from].tx.serve(now, occupancy);
        let end = occ_end + self.lat.pool_read_base;
        self.nics[from].pool_accesses += 1;
        VerbDone { start, end }
    }

    /// Attach `from` to a pool-tier slice (HDM-decoder programming +
    /// address-window setup). Far cheaper than `map_mr`'s full MR
    /// exchange; charged on the initiator's TX pipeline.
    pub fn pool_map(&mut self, now: Ns, from: NodeId) -> Ns {
        let dur = self.lat.pool_map;
        let (_, end) = self.nics[from].tx.serve(now, dur);
        self.mappings_made += 1;
        end
    }

    /// Two-sided SEND/RECV of `bytes` (nbdX-style): the receiver's CPU
    /// must post a RECV, copy the payload and send a response, so the
    /// target's rx_cpu server is on the critical path. Returns completion
    /// at the initiator (response received).
    pub fn send_recv(
        &mut self,
        now: Ns,
        from: NodeId,
        to: NodeId,
        bytes: u64,
        receiver_cpu: Ns,
    ) -> VerbDone {
        debug_assert!(self.is_connected(from, to), "send w/o connection");
        let penalty = self.wqe_pressure(from, now);
        let occupancy = (self.lat.rdma_per_byte * bytes as f64) as Ns;
        let (start, occ_end) = self.nics[from].tx.serve(now, occupancy);
        let arrived = occ_end
            + self.lat.rdma_write_base
            + self.lat.two_sided_extra
            + penalty;
        // receiver CPU processes the message (copy into ramdisk etc.)
        let (_, processed) = self.nics[to].rx_cpu.serve(arrived, receiver_cpu);
        // response message back (small)
        let resp = self.lat.rdma_write_base + self.lat.two_sided_extra;
        let end = processed + resp;
        self.nics[from].verbs_posted += 1;
        VerbDone { start, end }
    }

    /// Backlog (ns of queued work) on a node's TX pipeline — used by nbdX
    /// message-pool modeling and by backpressure-aware placement.
    pub fn tx_backlog(&self, node: NodeId, now: Ns) -> Ns {
        self.nics[node].tx.backlog(now)
    }

    /// Backlog on a node's receive CPU.
    pub fn rx_backlog(&self, node: NodeId, now: Ns) -> Ns {
        self.nics[node].rx_cpu.backlog(now)
    }

    /// Verbs posted by a node (stats).
    pub fn verbs_posted(&self, node: NodeId) -> u64 {
        self.nics[node].verbs_posted
    }

    /// WQE cache misses charged to a node (stats).
    pub fn wqe_misses(&self, node: NodeId) -> u64 {
        self.nics[node].wqe_misses
    }

    /// Pool-tier accesses initiated by a node (stats).
    pub fn pool_accesses(&self, node: NodeId) -> u64 {
        self.nics[node].pool_accesses
    }

    /// Number of nodes.
    pub fn nodes(&self) -> usize {
        self.nics.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::us;

    fn fabric() -> Fabric {
        Fabric::new(4, LatencyConfig::default())
    }

    #[test]
    fn connection_is_expensive_and_once() {
        let mut f = fabric();
        let (t1, new1) = f.ensure_connected(0, 0, 1);
        assert!(new1);
        assert_eq!(t1, LatencyConfig::default().connect);
        let (t2, new2) = f.ensure_connected(t1, 0, 1);
        assert!(!new2);
        assert_eq!(t2, t1);
        assert_eq!(f.connections_made, 1);
        // symmetric
        assert!(f.is_connected(1, 0));
    }

    #[test]
    fn rdma_write_latency_matches_table1() {
        let mut f = fabric();
        let (t, _) = f.ensure_connected(0, 0, 1);
        let done = f.rdma_write(t, 0, 1, 512 * 1024);
        let lat = done.end - done.start;
        assert!((lat as f64 - 51_350.0).abs() < 300.0, "{lat}");
    }

    #[test]
    fn rdma_read_page_matches_table1() {
        let mut f = fabric();
        let (t, _) = f.ensure_connected(0, 0, 1);
        let done = f.rdma_read(t, 0, 1, 4096);
        let lat = done.end - done.start;
        assert!((lat as f64 - 36_480.0).abs() < 500.0, "{lat}");
    }

    #[test]
    fn verbs_pipeline_on_tx_wire_time() {
        let mut f = fabric();
        let (t, _) = f.ensure_connected(0, 0, 1);
        let a = f.rdma_write(t, 0, 1, 512 * 1024);
        let b = f.rdma_write(t, 0, 1, 512 * 1024);
        // back-to-back messages are spaced by wire occupancy, not the
        // full verb latency: reads/writes pipeline on the NIC
        let occupancy =
            (LatencyConfig::default().rdma_per_byte * 512.0 * 1024.0) as u64;
        assert_eq!(b.end - a.end, occupancy);
        assert!(b.start < a.end, "second verb posts before first WC");
    }

    #[test]
    fn concurrent_small_reads_pipeline() {
        // 8 concurrent 4 KB reads: each sees ~base latency, not 8×36 µs.
        let mut f = fabric();
        let (t, _) = f.ensure_connected(0, 0, 1);
        let mut ends = Vec::new();
        for _ in 0..8 {
            ends.push(f.rdma_read(t, 0, 1, 4096).end);
        }
        let worst = ends.iter().max().unwrap() - t;
        assert!(worst < us(45), "worst concurrent read {worst}");
    }

    #[test]
    fn pool_read_sits_between_local_and_rdma() {
        // The tier ladder: a pooled-page load is far cheaper than the
        // 36 µs fabric round trip but still a real (NUMA-hop-scale)
        // cost — the whole point of a middle tier.
        let mut f = fabric();
        let pool = f.pool_read(0, 0, 1, 4096);
        let pool_lat = pool.end - pool.start;
        let mut f2 = fabric();
        let (t, _) = f2.ensure_connected(0, 0, 1);
        let rdma = f2.rdma_read(t, 0, 1, 4096);
        let rdma_lat = rdma.end - rdma.start;
        assert!(pool_lat > 0, "pool access is not free");
        assert!(
            pool_lat * 10 < rdma_lat,
            "pool {pool_lat} should be an order below rdma {rdma_lat}"
        );
        assert_eq!(f.pool_accesses(0), 1);
    }

    #[test]
    fn pool_verbs_need_no_connection_and_post_no_wqe() {
        // CXL load/store semantics: no queue pair, no WQE cache
        // pressure — but wire occupancy still charges the sender's TX
        // pipe, so backlog modeling holds.
        let mut f = fabric();
        assert!(!f.is_connected(0, 1));
        let mut last = 0;
        for _ in 0..1000 {
            last = f.pool_write(0, 0, 1, 4096).end;
        }
        assert_eq!(f.wqe_misses(0), 0, "pool stores post no WQEs");
        assert_eq!(f.verbs_posted(0), 0);
        assert_eq!(f.pool_accesses(0), 1000);
        assert!(f.tx_backlog(0, 0) > 0, "occupancy queues on the pipe");
        let _ = last;
    }

    #[test]
    fn pool_writes_share_the_tx_pipe_with_rdma() {
        // A pool store and an RDMA write issued at the same instant
        // serialize their wire occupancy on the shared sender pipe.
        let mut f = fabric();
        let (t, _) = f.ensure_connected(0, 0, 1);
        let a = f.rdma_write(t, 0, 1, 512 * 1024);
        let b = f.pool_write(t, 0, 1, 512 * 1024);
        assert!(b.start >= a.start + 1, "second access queues behind");
        assert!(b.end > a.start);
    }

    #[test]
    fn pool_map_is_cheaper_than_map_mr() {
        let mut f = fabric();
        let m = f.pool_map(0, 0);
        let mut f2 = fabric();
        let mr = f2.map_mr(0, 0);
        assert!(m * 10 < mr, "pool attach {m} vs MR map {mr}");
        assert_eq!(f.mappings_made, 1);
    }

    #[test]
    fn two_sided_involves_receiver_cpu() {
        let mut f = fabric();
        let (t, _) = f.ensure_connected(0, 0, 1);
        let one = f.rdma_write(t, 0, 1, 4096);
        let mut f2 = fabric();
        let (t2, _) = f2.ensure_connected(0, 0, 1);
        let two = f2.send_recv(t2, 0, 1, 4096, us(20));
        assert!(
            two.end - two.start > one.end - one.start,
            "two-sided must cost more than one-sided"
        );
    }

    #[test]
    fn receiver_cpu_serializes_senders() {
        let mut f = fabric();
        let (t0, _) = f.ensure_connected(0, 0, 2);
        let (t1, _) = f.ensure_connected(0, 1, 2);
        let start = t0.max(t1);
        let a = f.send_recv(start, 0, 2, 4096, us(100));
        let b = f.send_recv(start, 1, 2, 4096, us(100));
        // both messages hit node 2's rx cpu; the second finishes later
        assert!(b.end > a.end);
    }

    #[test]
    fn wqe_flood_adds_penalty() {
        let mut f = fabric();
        let (t, _) = f.ensure_connected(0, 0, 1);
        // Post far more WQEs than the cache holds at the same instant.
        let mut last = 0;
        for _ in 0..1000 {
            last = f.rdma_write(t, 0, 1, 4096).end;
        }
        assert!(f.wqe_misses(0) > 0, "expected WQE cache misses");
        let _ = last;
    }

    #[test]
    fn coalescing_beats_many_small_wqes() {
        // 2 MB as 4 × 512 KB messages vs 512 × 4 KB writes: the flood of
        // small WQEs overruns the RNIC's WQE cache [12] and pays miss
        // penalties, so the coalesced path finishes sooner (Valet's §3.3
        // batching argument).
        let mut f1 = fabric();
        let (t, _) = f1.ensure_connected(0, 0, 1);
        let mut coalesced = 0;
        for _ in 0..4 {
            coalesced = f1.rdma_write(t, 0, 1, 512 * 1024).end;
        }
        let mut f2 = fabric();
        let (t, _) = f2.ensure_connected(0, 0, 1);
        let mut scattered = 0;
        for _ in 0..512 {
            scattered = f2.rdma_write(t, 0, 1, 4096).end;
        }
        assert_eq!(f1.wqe_misses(0), 0);
        assert!(f2.wqe_misses(0) > 0);
        assert!(coalesced < scattered, "{coalesced} vs {scattered}");
    }
}
