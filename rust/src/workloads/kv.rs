//! The KV workload driver: runs YCSB over a store model inside a
//! memory-limited container, paging through the cluster's backend — the
//! engine behind Figures 3, 18, 19, 21, 22 and Tables 5/7.
//!
//! Closed-loop with `concurrency` logical clients: each client issues its
//! next operation when its previous one completes; shared resources (NIC,
//! disk, receiver CPUs) queue naturally, so saturation effects (disk
//! convoys, nbdX pool exhaustion) emerge at high load.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashSet};

use super::stores::StoreModel;
use super::ycsb::{Mix, YcsbGen};
use crate::cluster::Cluster;
use crate::container::{Access as CtAccess, Container};
use crate::metrics::RunMetrics;
use crate::sim::Ns;
use crate::PAGE_SIZE;

/// Parameters of one KV run.
#[derive(Clone, Debug)]
pub struct KvRunConfig {
    /// Store model (app + value size).
    pub store: StoreModel,
    /// GET/SET mix.
    pub mix: Mix,
    /// Number of records.
    pub records: u64,
    /// Operations to run (measured phase).
    pub ops: u64,
    /// Container memory limit in bytes.
    pub container_limit: u64,
    /// Concurrent logical clients.
    pub concurrency: usize,
    /// Seed.
    pub seed: u64,
    /// DRAM access cost per resident page touch.
    pub dram_ns: Ns,
}

impl KvRunConfig {
    /// Reasonable defaults for a store + mix + fit fraction.
    pub fn new(store: StoreModel, mix: Mix, records: u64, ops: u64) -> Self {
        KvRunConfig {
            store,
            mix,
            records,
            ops,
            container_limit: u64::MAX,
            concurrency: 8,
            seed: 1,
            dram_ns: 200,
        }
    }

    /// Set the container limit so that `fit` (0..=1] of the working set
    /// is memory-resident — the paper's 100/75/50/25 % configurations.
    pub fn with_fit(mut self, fit: f64) -> Self {
        let ws = self.store.working_set_pages(self.records) * PAGE_SIZE;
        self.container_limit = ((ws as f64) * fit).ceil() as u64;
        self
    }
}

/// Outcome of a run.
#[derive(Clone, Debug)]
pub struct KvResult {
    /// Merged metrics (op latencies + the backend's internals).
    pub metrics: RunMetrics,
    /// Virtual completion time of the measured phase.
    pub completion: Ns,
    /// Page faults taken during the measured phase.
    pub faults: u64,
}

/// A persistent KV workload session: load once, measure any number of
/// phases (the eviction experiments — Figures 5 and 23 — evict remote
/// memory *between* phases, which a single populate+run call would wash
/// out by re-populating).
pub struct KvSession {
    rc: KvRunConfig,
    container: Container,
    swapped: HashSet<u64>,
    /// Current virtual time (advances across phases).
    pub t: Ns,
    loaded: bool,
}

impl KvSession {
    /// New session (no pages touched yet).
    pub fn new(rc: KvRunConfig) -> Self {
        KvSession {
            container: Container::new(rc.container_limit),
            swapped: HashSet::new(),
            t: 0,
            loaded: false,
            rc,
        }
    }

    /// Load phase: touch every working-set page once (write), like
    /// YCSB's load phase; then flush dirty residents (steady-state
    /// writeback) and idle until the background pipelines drain.
    pub fn load(&mut self, cluster: &mut Cluster) {
        let ws_pages = self.rc.store.working_set_pages(self.rc.records);
        for page in 0..ws_pages {
            self.t = touch_page(
                cluster,
                &mut self.container,
                &mut self.swapped,
                self.t,
                page,
                true,
                self.rc.dram_ns,
            );
            if page % 8192 == 0 {
                cluster.advance(self.t);
            }
        }
        // Writeback flush: the load phase leaves the resident set dirty;
        // flush it so measured dirty evictions reflect the GET/SET mix.
        for page in self.container.dirty_pages() {
            let a = cluster.backend.write(
                &mut cluster.state,
                self.t,
                page,
                PAGE_SIZE,
            );
            self.t = a.end;
            self.swapped.insert(page);
            self.container.clean(page);
        }
        // idle gap: reach steady state (virtual time is free)
        self.t += crate::sim::secs(30);
        cluster.advance(self.t);
        self.loaded = true;
    }

    /// One measured phase of `ops` operations.
    pub fn run(&mut self, cluster: &mut Cluster, ops: u64) -> KvResult {
        assert!(self.loaded, "call load() first");
        *cluster.backend.metrics_mut() = RunMetrics::default();
        let t0 = self.t;
        let faults0 = self.container.faults;
        let rc = self.rc.clone();
        let mut gen = YcsbGen::new(rc.records, rc.mix, rc.seed);
        let mut heap: BinaryHeap<Reverse<(Ns, usize)>> = (0..rc.concurrency)
            .map(|c| Reverse((t0 + c as Ns, c)))
            .collect();
        let mut op_lat = crate::metrics::Histogram::new();
        let mut issued = 0u64;
        let mut finished_at = t0;
        while issued < ops {
            let Reverse((t_cl, client)) = heap
                .pop()
                .expect("one heap entry per client, clients >= 1");
            cluster.advance(t_cl);
            let op = gen.next_op();
            let mut rng_scratch = crate::util::Rng::new(rc.seed ^ issued);
            let pages = rc.store.pages_for_op(
                op.key,
                op.is_get,
                rc.records,
                &mut rng_scratch,
            );
            let mut t_op = t_cl + rc.store.op_cpu;
            for (page, write) in pages {
                t_op = touch_page(
                    cluster,
                    &mut self.container,
                    &mut self.swapped,
                    t_op,
                    page,
                    write,
                    rc.dram_ns,
                );
            }
            op_lat.record(t_op - t_cl);
            finished_at = finished_at.max(t_op);
            issued += 1;
            heap.push(Reverse((t_op, client)));
        }
        self.t = finished_at;
        let mut metrics = cluster.backend.metrics().clone();
        metrics.op_latency = op_lat;
        metrics.ops = ops;
        metrics.finished_at = finished_at - t0;
        KvResult {
            metrics,
            completion: finished_at - t0,
            faults: self.container.faults - faults0,
        }
    }
}

/// Populate + run once (the common case).
pub fn run_kv(cluster: &mut Cluster, rc: &KvRunConfig) -> KvResult {
    let ops = rc.ops;
    let mut session = KvSession::new(rc.clone());
    session.load(cluster);
    session.run(cluster, ops)
}

/// Touch one page inside the container, paging via the backend on
/// faults. Returns the completion time.
fn touch_page(
    cluster: &mut Cluster,
    container: &mut Container,
    swapped: &mut HashSet<u64>,
    now: Ns,
    page: u64,
    write: bool,
    dram_ns: Ns,
) -> Ns {
    match container.touch(page, write) {
        CtAccess::Hit | CtAccess::ColdFault => now + dram_ns,
        CtAccess::Fault {
            victim,
            victim_dirty,
        } => {
            let mut t = now;
            if victim_dirty {
                let a = cluster.backend.write(
                    &mut cluster.state,
                    t,
                    victim,
                    PAGE_SIZE,
                );
                t = a.end;
            }
            swapped.insert(victim);
            if swapped.contains(&page) {
                let a = cluster.backend.read(&mut cluster.state, t, page);
                t = a.end;
            } else {
                t += dram_ns;
            }
            t
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{BackendKind, Config};
    use crate::workloads::stores::{App, StoreModel};

    fn small_cfg() -> Config {
        let mut cfg = Config::default();
        cfg.cluster.nodes = 4;
        cfg.valet.mr_block_bytes = 4 << 20; // 4 MB units
        cfg.valet.min_pool_pages = 512;
        cfg.valet.max_pool_pages = 4096;
        cfg
    }

    fn small_rc(fit: f64) -> KvRunConfig {
        let store = StoreModel::new(App::Redis, 1024);
        KvRunConfig {
            concurrency: 4,
            ops: 2_000,
            ..KvRunConfig::new(store, Mix::Sys, 20_000, 2_000)
        }
        .with_fit(fit)
    }

    #[test]
    fn full_fit_never_pages() {
        let cfg = small_cfg();
        let mut cl = Cluster::new(&cfg, BackendKind::Valet);
        let r = run_kv(&mut cl, &small_rc(1.0));
        assert_eq!(r.faults, 0);
        assert_eq!(r.metrics.disk_reads, 0);
        assert!(r.metrics.throughput() > 0.0);
    }

    #[test]
    fn partial_fit_pages_through_backend() {
        let cfg = small_cfg();
        let mut cl = Cluster::new(&cfg, BackendKind::Valet);
        let r = run_kv(&mut cl, &small_rc(0.5));
        assert!(r.faults > 0);
        assert!(
            r.metrics.local_hits + r.metrics.remote_hits > 0,
            "{:?}",
            r.metrics
        );
    }

    #[test]
    fn lower_fit_is_slower_for_linux_swap() {
        let cfg = small_cfg();
        let mut c1 = Cluster::new(&cfg, BackendKind::LinuxSwap);
        let hi = run_kv(&mut c1, &small_rc(1.0));
        let mut c2 = Cluster::new(&cfg, BackendKind::LinuxSwap);
        let lo = run_kv(&mut c2, &small_rc(0.25));
        assert!(
            lo.completion > hi.completion * 5,
            "lo {} hi {}",
            lo.completion,
            hi.completion
        );
    }

    #[test]
    fn valet_beats_linux_swap_under_pressure() {
        let cfg = small_cfg();
        let mut cv = Cluster::new(&cfg, BackendKind::Valet);
        let v = run_kv(&mut cv, &small_rc(0.25));
        let mut cl = Cluster::new(&cfg, BackendKind::LinuxSwap);
        let l = run_kv(&mut cl, &small_rc(0.25));
        assert!(
            v.completion * 10 < l.completion,
            "valet {} linux {}",
            v.completion,
            l.completion
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = small_cfg();
        let mut c1 = Cluster::new(&cfg, BackendKind::Valet);
        let a = run_kv(&mut c1, &small_rc(0.5));
        let mut c2 = Cluster::new(&cfg, BackendKind::Valet);
        let b = run_kv(&mut c2, &small_rc(0.5));
        assert_eq!(a.completion, b.completion);
        assert_eq!(a.faults, b.faults);
    }
}
