//! In-memory store models: Memcached, Redis and VoltDB as
//! *page-access-pattern generators* with the paper's measured memory
//! footprints (§6.1: a 10 GB dataset yields a 15 GB working set in
//! Memcached and 22 GB in Redis/VoltDB — "its complicated data structure
//! in VoltDB requires more memory").
//!
//! What matters for the paging experiments is (a) the total page
//! footprint, (b) how many pages one operation touches and (c) per-op CPU
//! cost; the models encode exactly those.

use crate::sim::{us, Ns};
use crate::util::Rng;
use crate::PAGE_SIZE;

/// Which application.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum App {
    /// memcached: flat hash, slab allocation — leanest footprint.
    Memcached,
    /// redis: dict + robj overhead, fragmentation — 2.2× footprint.
    Redis,
    /// VoltDB: ACID transactional tables + indexes — 2.2× footprint and
    /// extra index-page touches per op.
    VoltDb,
}

impl App {
    /// All three, figure order.
    pub fn all() -> [App; 3] {
        [App::Memcached, App::Redis, App::VoltDb]
    }

    /// Parse CLI name.
    pub fn parse(s: &str) -> Option<App> {
        match s.to_ascii_lowercase().as_str() {
            "memcached" => Some(App::Memcached),
            "redis" => Some(App::Redis),
            "voltdb" => Some(App::VoltDb),
            _ => None,
        }
    }

    /// Display name.
    pub fn name(&self) -> &'static str {
        match self {
            App::Memcached => "Memcached",
            App::Redis => "Redis",
            App::VoltDb => "VoltDB",
        }
    }
}

/// The store model.
#[derive(Clone, Debug)]
pub struct StoreModel {
    /// Which app this models.
    pub app: App,
    /// Bytes of value payload per record (dataset bytes / records).
    pub value_bytes: u64,
    /// Working-set amplification over the raw dataset (1.5× / 2.2×).
    pub footprint_factor: f64,
    /// Extra (index/metadata) pages touched per GET.
    pub index_pages_get: u64,
    /// Extra pages touched per SET (index update + allocation metadata).
    pub index_pages_set: u64,
    /// In-memory CPU time per operation.
    pub op_cpu: Ns,
}

impl StoreModel {
    /// Model for `app` with `records` records of `value_bytes` each.
    pub fn new(app: App, value_bytes: u64) -> Self {
        match app {
            App::Memcached => StoreModel {
                app,
                value_bytes,
                footprint_factor: 1.5,
                index_pages_get: 0,
                index_pages_set: 0,
                op_cpu: us(8),
            },
            App::Redis => StoreModel {
                app,
                value_bytes,
                footprint_factor: 2.2,
                index_pages_get: 1,
                index_pages_set: 1,
                op_cpu: us(10),
            },
            App::VoltDb => StoreModel {
                app,
                value_bytes,
                footprint_factor: 2.2,
                index_pages_get: 2,
                index_pages_set: 3,
                op_cpu: us(25),
            },
        }
    }

    /// Effective bytes one record occupies in memory.
    pub fn record_footprint(&self) -> u64 {
        ((self.value_bytes as f64) * self.footprint_factor).ceil() as u64
    }

    /// Pages in the record data region.
    pub fn data_region_pages(&self, records: u64) -> u64 {
        (records * self.record_footprint()).div_ceil(PAGE_SIZE)
    }

    /// Total working set in pages: index/metadata region + data region.
    pub fn working_set_pages(&self, records: u64) -> u64 {
        self.index_region_pages(records) + self.data_region_pages(records)
    }

    /// Data page(s) holding record `key`. Records are laid out
    /// sequentially in the data region (pages [index_region …)).
    pub fn data_page(&self, key: u64, records: u64) -> u64 {
        let idx = self.index_region_pages(records);
        idx + key * self.record_footprint() / PAGE_SIZE
    }

    /// Size of the index/metadata region (first pages of the space).
    pub fn index_region_pages(&self, records: u64) -> u64 {
        // ~3% of the data region, at least one page
        (self.data_region_pages(records) * 3 / 100).max(1)
    }

    /// Pages touched by one op, data page first. Index touches hash into
    /// the index region (deterministic per key, spread by `rng` over the
    /// B-tree levels for VoltDB).
    pub fn pages_for_op(
        &self,
        key: u64,
        is_get: bool,
        records: u64,
        rng: &mut Rng,
    ) -> Vec<(u64, bool)> {
        let mut out = Vec::with_capacity(4);
        // data page: GET reads, SET writes
        out.push((self.data_page(key, records), !is_get));
        let extra = if is_get {
            self.index_pages_get
        } else {
            self.index_pages_set
        };
        let idx_pages = self.index_region_pages(records);
        for level in 0..extra {
            // mix key + level into the index region; upper levels of the
            // tree (level 0) concentrate on few pages (hot, resident)
            let span = (idx_pages >> (extra - 1 - level).min(10)).max(1);
            let mut z = key
                .wrapping_mul(0x9E3779B97F4A7C15)
                .wrapping_add(level * 0x1000193);
            z ^= z >> 29;
            let page = z % span;
            // index writes only on SET's last level
            let write = !is_get && level + 1 == extra;
            out.push((page, write));
            let _ = rng;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn footprints_match_paper() {
        // 10 GB dataset / 10 M records = 1 KB values (paper §6.1)
        let records = 10_000_000u64;
        let mc = StoreModel::new(App::Memcached, 1024);
        let rd = StoreModel::new(App::Redis, 1024);
        let vd = StoreModel::new(App::VoltDb, 1024);
        let gb = |pages: u64| {
            (pages * PAGE_SIZE) as f64 / (1u64 << 30) as f64
        };
        // Memcached ≈ 15 GB; Redis/VoltDB ≈ 22 GB
        let m = gb(mc.working_set_pages(records));
        let r = gb(rd.working_set_pages(records));
        let v = gb(vd.working_set_pages(records));
        assert!((14.0..16.5).contains(&m), "{m}");
        assert!((21.0..23.5).contains(&r), "{r}");
        assert!((21.0..23.5).contains(&v), "{v}");
    }

    #[test]
    fn voltdb_touches_more_pages() {
        let mut rng = Rng::new(1);
        let mc = StoreModel::new(App::Memcached, 1024);
        let vd = StoreModel::new(App::VoltDb, 1024);
        let m = mc.pages_for_op(5, true, 1000, &mut rng);
        let v = vd.pages_for_op(5, true, 1000, &mut rng);
        assert_eq!(m.len(), 1);
        assert_eq!(v.len(), 3);
    }

    #[test]
    fn get_reads_set_writes_data_page() {
        let mut rng = Rng::new(1);
        let rd = StoreModel::new(App::Redis, 1024);
        let g = rd.pages_for_op(5, true, 1000, &mut rng);
        let s = rd.pages_for_op(5, false, 1000, &mut rng);
        assert!(!g[0].1, "GET must not dirty the data page");
        assert!(s[0].1, "SET must dirty the data page");
        assert_eq!(g[0].0, s[0].0, "same record, same page");
    }

    #[test]
    fn distinct_keys_spread_over_pages() {
        let rd = StoreModel::new(App::Redis, 1024);
        let records = 100_000;
        let p1 = rd.data_page(0, records);
        let p2 = rd.data_page(records - 1, records);
        assert!(p2 > p1);
        assert!(p2 - p1 >= records * 2048 / PAGE_SIZE);
    }

    #[test]
    fn index_pages_stay_in_index_region() {
        let mut rng = Rng::new(2);
        let vd = StoreModel::new(App::VoltDb, 1024);
        let records = 1_000_000;
        let idx = vd.index_region_pages(records);
        for key in [0u64, 17, 999_999] {
            for (page, _) in
                vd.pages_for_op(key, true, records, &mut rng)[1..].iter()
            {
                assert!(*page < idx);
            }
        }
    }
}
