//! ML workload driver (Figure 20 / Table 6): the paper's five ML
//! applications as paging workloads whose *compute* is the real
//! AOT-compiled JAX/Pallas step executed through the PJRT runtime.
//!
//! Each step (1) sweeps its batch's dataset pages through the container
//! (read faults page data in via the backend) and (2) runs the model
//! step; the per-step compute time is supplied by the caller — measured
//! once from the real HLO executable by examples/benches, constant in
//! unit tests.
//!
//! Access patterns follow §6.2: most workloads sweep the dataset
//! sequentially per epoch (completion time grows superlinearly once the
//! working set exceeds the limit), while **K-Means "intensively accesses
//! certain MR blocks that are mapped in early stage of running"** — its
//! batches concentrate on the first quarter of the dataset, which is why
//! the paper sees it behave differently.

use std::collections::HashSet;

use crate::cluster::Cluster;
use crate::container::{Access as CtAccess, Container};
use crate::metrics::RunMetrics;
use crate::sim::Ns;
use crate::util::Rng;
use crate::PAGE_SIZE;

/// Which ML application (Table 4).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MlKind {
    /// Logistic Regression (scikit-learn, 87 M samples).
    LogReg,
    /// K-Means clustering (PowerGraph, 4 M samples).
    KMeans,
    /// TextRank (1.4 M words).
    TextRank,
    /// Gradient Boosting classifier (87 M samples).
    GBoost,
    /// Random Forest classifier (50 M samples).
    RandomForest,
}

impl MlKind {
    /// All five, figure order.
    pub fn all() -> [MlKind; 5] {
        [
            MlKind::GBoost,
            MlKind::KMeans,
            MlKind::LogReg,
            MlKind::RandomForest,
            MlKind::TextRank,
        ]
    }

    /// Display name.
    pub fn name(&self) -> &'static str {
        match self {
            MlKind::LogReg => "LogisticRegression",
            MlKind::KMeans => "Kmeans",
            MlKind::TextRank => "TextRank",
            MlKind::GBoost => "GradientBoosting",
            MlKind::RandomForest => "RandomForest",
        }
    }

    /// Matching AOT artifact name.
    pub fn artifact(&self) -> &'static str {
        match self {
            MlKind::LogReg => "logreg_step",
            MlKind::KMeans => "kmeans_step",
            MlKind::TextRank => "textrank_step",
            MlKind::GBoost => "gboost_stump_step",
            MlKind::RandomForest => "rf_proximity_step",
        }
    }
}

/// Parameters of one ML run.
#[derive(Clone, Debug)]
pub struct MlRunConfig {
    /// Application.
    pub kind: MlKind,
    /// Steps (batches) to run.
    pub steps: u64,
    /// Total dataset size in bytes.
    pub dataset_bytes: u64,
    /// Bytes consumed per step (one batch).
    pub batch_bytes: u64,
    /// Container memory limit.
    pub container_limit: u64,
    /// Seed.
    pub seed: u64,
    /// DRAM cost per resident page touch.
    pub dram_ns: Ns,
}

impl MlRunConfig {
    /// Defaults for a kind + dataset, fitting `fit` of it in memory.
    pub fn new(kind: MlKind, dataset_bytes: u64, steps: u64, fit: f64) -> Self {
        MlRunConfig {
            kind,
            steps,
            dataset_bytes,
            batch_bytes: 4 << 20,
            container_limit: ((dataset_bytes as f64) * fit).ceil() as u64,
            seed: 3,
            dram_ns: 200,
        }
    }
}

/// Outcome.
#[derive(Clone, Debug)]
pub struct MlResult {
    /// Merged metrics.
    pub metrics: RunMetrics,
    /// Virtual completion time (paging + compute).
    pub completion: Ns,
    /// Total compute time folded in.
    pub compute: Ns,
}

/// Run: `compute(step)` returns the step's compute time (measure it from
/// the real PJRT executable; see examples/ml_training.rs).
pub fn run_ml(
    cluster: &mut Cluster,
    rc: &MlRunConfig,
    mut compute: impl FnMut(u64) -> Ns,
) -> MlResult {
    let ds_pages = rc.dataset_bytes.div_ceil(PAGE_SIZE);
    let batch_pages = (rc.batch_bytes / PAGE_SIZE).max(1);
    let mut container = Container::new(rc.container_limit);
    let mut swapped: HashSet<u64> = HashSet::new();
    let mut rng = Rng::new(rc.seed);
    let mut t: Ns = 0;

    // ---- data loading (writes the dataset once) ----
    for page in 0..ds_pages {
        t = touch(cluster, &mut container, &mut swapped, t, page, true, rc);
        if page % 8192 == 0 {
            cluster.advance(t);
        }
    }
    // writeback flush (see kv.rs): training reads shouldn't pay for
    // load-phase dirtiness
    for page in container.dirty_pages() {
        let a = cluster
            .backend
            .write(&mut cluster.state, t, page, PAGE_SIZE);
        t = a.end;
        swapped.insert(page);
        container.clean(page);
    }
    // idle gap: drain background pipelines before measuring
    t += crate::sim::secs(30);
    cluster.advance(t);
    *cluster.backend.metrics_mut() = RunMetrics::default();
    let t0 = t;
    let mut total_compute = 0;

    // ---- training steps ----
    for step in 0..rc.steps {
        // pick this step's batch start page by access pattern
        let start = match rc.kind {
            MlKind::KMeans => {
                // §6.2 anomaly: 80 % of batches hit the first quarter
                let hot = (ds_pages / 4).max(batch_pages);
                if rng.chance(0.8) {
                    rng.below(hot.saturating_sub(batch_pages).max(1))
                } else {
                    rng.below(ds_pages.saturating_sub(batch_pages).max(1))
                }
            }
            MlKind::RandomForest => {
                // bootstrap sampling: random batch positions
                rng.below(ds_pages.saturating_sub(batch_pages).max(1))
            }
            _ => {
                // sequential epoch sweep
                (step * batch_pages) % ds_pages.max(1)
            }
        };
        for p in start..(start + batch_pages).min(ds_pages) {
            t = touch(cluster, &mut container, &mut swapped, t, p, false, rc);
        }
        cluster.advance(t);
        let c = compute(step);
        total_compute += c;
        t += c;
    }

    let mut metrics = cluster.backend.metrics().clone();
    metrics.ops = rc.steps;
    metrics.finished_at = t - t0;
    MlResult {
        metrics,
        completion: t - t0,
        compute: total_compute,
    }
}

fn touch(
    cluster: &mut Cluster,
    container: &mut Container,
    swapped: &mut HashSet<u64>,
    now: Ns,
    page: u64,
    write: bool,
    rc: &MlRunConfig,
) -> Ns {
    match container.touch(page, write) {
        CtAccess::Hit | CtAccess::ColdFault => now + rc.dram_ns,
        CtAccess::Fault {
            victim,
            victim_dirty,
        } => {
            let mut t = now;
            if victim_dirty {
                let a = cluster.backend.write(
                    &mut cluster.state,
                    t,
                    victim,
                    PAGE_SIZE,
                );
                t = a.end;
            }
            swapped.insert(victim);
            if swapped.contains(&page) {
                let a = cluster.backend.read(&mut cluster.state, t, page);
                t = a.end;
            } else {
                t += rc.dram_ns;
            }
            t
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{BackendKind, Config};
    use crate::sim::ms;

    fn cfg() -> Config {
        let mut cfg = Config::default();
        cfg.cluster.nodes = 4;
        cfg.valet.mr_block_bytes = 4 << 20;
        cfg.valet.min_pool_pages = 512;
        cfg.valet.max_pool_pages = 4096;
        cfg
    }

    fn rc(kind: MlKind, fit: f64) -> MlRunConfig {
        MlRunConfig {
            batch_bytes: 1 << 20,
            ..MlRunConfig::new(kind, 64 << 20, 50, fit)
        }
    }

    #[test]
    fn full_fit_cost_is_compute_dominated() {
        let mut cl = Cluster::new(&cfg(), BackendKind::Valet);
        let r = run_ml(&mut cl, &rc(MlKind::LogReg, 1.0), |_| ms(10));
        assert_eq!(r.compute, 50 * ms(10));
        // paging adds only dram touches
        assert!(r.completion < r.compute + ms(100), "{}", r.completion);
    }

    #[test]
    fn paging_dominates_at_low_fit_on_disk() {
        let mut cl = Cluster::new(&cfg(), BackendKind::LinuxSwap);
        let r = run_ml(&mut cl, &rc(MlKind::LogReg, 0.25), |_| ms(10));
        assert!(r.completion > 2 * r.compute, "{} vs {}", r.completion, r.compute);
        assert!(r.metrics.disk_reads > 0);
    }

    #[test]
    fn kmeans_pattern_has_higher_hit_ratio_than_sweep() {
        // K-Means concentrates on early pages → fewer faults at the same
        // fit than a sequential sweep (the paper's §6.2 observation).
        let mut c1 = Cluster::new(&cfg(), BackendKind::Valet);
        let km = run_ml(&mut c1, &rc(MlKind::KMeans, 0.5), |_| ms(1));
        let mut c2 = Cluster::new(&cfg(), BackendKind::Valet);
        let lr = run_ml(&mut c2, &rc(MlKind::LogReg, 0.5), |_| ms(1));
        let km_reads =
            km.metrics.remote_hits + km.metrics.local_hits + km.metrics.disk_reads;
        let lr_reads =
            lr.metrics.remote_hits + lr.metrics.local_hits + lr.metrics.disk_reads;
        assert!(
            km_reads < lr_reads,
            "kmeans {km_reads} vs sweep {lr_reads}"
        );
    }

    #[test]
    fn artifact_names_match_registry() {
        use crate::runtime::ARTIFACT_SPECS;
        for kind in MlKind::all() {
            assert!(
                ARTIFACT_SPECS.iter().any(|s| s.name == kind.artifact()),
                "{}",
                kind.artifact()
            );
        }
    }
}
