//! FIO-style block-device microbenchmark (§2.1: "We set our block device
//! as a partition and run FIO microbenchmark on it … Write size can be
//! from 4KB up to 128KB and read size is 4KB"). Drives a backend
//! directly, bypassing the container — the workload behind Table 1 and
//! Figure 9.

use crate::cluster::Cluster;
use crate::metrics::RunMetrics;
use crate::sim::Ns;
use crate::util::Rng;
use crate::PAGE_SIZE;

/// FIO job description.
#[derive(Clone, Debug)]
pub struct FioJob {
    /// Write block size in bytes (4 KB – 128 KB in the paper).
    pub write_bytes: u64,
    /// Number of write requests.
    pub writes: u64,
    /// Number of 4 KB read requests (over previously written pages).
    pub reads: u64,
    /// Mean think time between requests (0 = back-to-back).
    pub think_ns: Ns,
    /// Randomize read offsets (sequential otherwise).
    pub random_reads: bool,
    /// Outstanding requests (FIO iodepth). Depth > 1 creates the disk
    /// convoys behind Table 1's 401 ms "Disk WR" number.
    pub iodepth: usize,
    /// Page span reads draw from (0 = derive from this job's writes; set
    /// explicitly for read-only jobs over a previously-written file).
    pub file_pages: u64,
    /// Seed.
    pub seed: u64,
}

impl Default for FioJob {
    fn default() -> Self {
        FioJob {
            write_bytes: 64 * 1024,
            writes: 2_000,
            reads: 2_000,
            think_ns: 0,
            random_reads: true,
            iodepth: 1,
            file_pages: 0,
            seed: 7,
        }
    }
}

/// Run the job; returns backend metrics including read/write latency
/// histograms and component breakdowns.
pub fn run_fio(cluster: &mut Cluster, job: &FioJob) -> RunMetrics {
    use std::cmp::Reverse;
    use std::collections::BinaryHeap;
    let pages_per_write = (job.write_bytes / PAGE_SIZE).max(1);
    let depth = job.iodepth.max(1);
    // sequential writes laying out the file, `iodepth` outstanding
    let mut heap: BinaryHeap<Reverse<(Ns, usize)>> =
        (0..depth).map(|q| Reverse((q as Ns, q))).collect();
    let mut t: Ns = 0;
    for i in 0..job.writes {
        let Reverse((t_q, q)) = heap.pop().expect("queue slots");
        cluster.advance(t_q);
        let page = i * pages_per_write;
        let a = cluster.backend.write(
            &mut cluster.state,
            t_q,
            page,
            job.write_bytes,
        );
        t = t.max(a.end);
        heap.push(Reverse((a.end + job.think_ns, q)));
    }
    cluster.advance(t);
    // reads over the written range, same depth
    let total_pages = if job.file_pages > 0 {
        job.file_pages
    } else {
        job.writes * pages_per_write
    };
    let mut rng = Rng::new(job.seed);
    let mut heap: BinaryHeap<Reverse<(Ns, usize)>> =
        (0..depth).map(|q| Reverse((t + q as Ns, q))).collect();
    for i in 0..job.reads {
        let Reverse((t_q, q)) = heap.pop().expect("queue slots");
        cluster.advance(t_q);
        let page = if job.random_reads {
            rng.below(total_pages.max(1))
        } else {
            i % total_pages.max(1)
        };
        let a = cluster.backend.read(&mut cluster.state, t_q, page);
        t = t.max(a.end);
        heap.push(Reverse((a.end + job.think_ns, q)));
    }
    let mut m = cluster.backend.metrics().clone();
    m.ops = job.writes + job.reads;
    m.finished_at = t;
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{BackendKind, Config};

    fn cfg() -> Config {
        let mut cfg = Config::default();
        cfg.cluster.nodes = 4;
        cfg.valet.mr_block_bytes = 4 << 20;
        cfg.valet.min_pool_pages = 1024;
        cfg.valet.max_pool_pages = 8192;
        cfg
    }

    #[test]
    fn valet_write_latency_independent_of_connection_windows() {
        let mut cl = Cluster::new(&cfg(), BackendKind::Valet);
        let m = run_fio(
            &mut cl,
            &FioJob {
                writes: 500,
                reads: 100,
                ..Default::default()
            },
        );
        // p99 write stays in the tens of µs (no 263 ms outliers)
        assert!(m.write_latency.p99() < crate::sim::ms(1));
    }

    #[test]
    fn infiniswap_writes_show_disk_outliers() {
        let mut cl = Cluster::new(&cfg(), BackendKind::Infiniswap);
        let m = run_fio(
            &mut cl,
            &FioJob {
                writes: 500,
                reads: 100,
                ..Default::default()
            },
        );
        // redirected writes during mapping windows hit disk → max ≫ p50
        assert!(m.write_latency.max() > crate::sim::ms(5));
        assert!(m.disk_writes > 0);
    }

    #[test]
    fn smaller_blocks_give_lower_valet_write_latency() {
        // Figure 9's effect: only the copy remains in the critical path,
        // so smaller block I/O → lower write latency.
        let mut lat = Vec::new();
        for bytes in [32 * 1024u64, 64 * 1024, 128 * 1024] {
            let mut cl = Cluster::new(&cfg(), BackendKind::Valet);
            let m = run_fio(
                &mut cl,
                &FioJob {
                    write_bytes: bytes,
                    writes: 300,
                    reads: 0,
                    ..Default::default()
                },
            );
            lat.push(m.write_latency.mean());
        }
        assert!(lat[0] < lat[1] && lat[1] < lat[2], "{lat:?}");
    }
}
