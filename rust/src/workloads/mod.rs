//! Workloads: YCSB generation, in-memory store models, the KV and ML
//! paging drivers, and the FIO-style block-device microbenchmark —
//! everything the paper's evaluation (§6) runs on top of the backends.

pub mod fio;
pub mod kv;
pub mod ml;
pub mod stores;
pub mod ycsb;

pub use fio::{run_fio, FioJob};
pub use kv::{run_kv, KvResult, KvRunConfig, KvSession};
pub use ml::{run_ml, MlKind, MlResult, MlRunConfig};
pub use stores::{App, StoreModel};
pub use ycsb::{Mix, Op, YcsbGen};
