//! YCSB-style workload generation (§6: "we use Facebook simulated
//! workload ETC (95% GET and 5% SET) and SYS (75% GET and 25% SET) by
//! using YCSB … zipfian distribution for both").

use crate::util::{Rng, Zipfian};

/// GET/SET mix.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mix {
    /// Facebook ETC: 95 % GET, 5 % SET.
    Etc,
    /// Facebook SYS: 75 % GET, 25 % SET.
    Sys,
    /// 100 % GET (warm-read ablations).
    ReadOnly,
    /// 100 % SET (write-path ablations, Figure 9).
    WriteOnly,
}

impl Mix {
    /// Fraction of GETs.
    pub fn get_fraction(&self) -> f64 {
        match self {
            Mix::Etc => 0.95,
            Mix::Sys => 0.75,
            Mix::ReadOnly => 1.0,
            Mix::WriteOnly => 0.0,
        }
    }

    /// Parse CLI name.
    pub fn parse(s: &str) -> Option<Mix> {
        match s.to_ascii_lowercase().as_str() {
            "etc" => Some(Mix::Etc),
            "sys" => Some(Mix::Sys),
            "read" | "readonly" => Some(Mix::ReadOnly),
            "write" | "writeonly" => Some(Mix::WriteOnly),
            _ => None,
        }
    }

    /// Display name.
    pub fn name(&self) -> &'static str {
        match self {
            Mix::Etc => "ETC",
            Mix::Sys => "SYS",
            Mix::ReadOnly => "READ",
            Mix::WriteOnly => "WRITE",
        }
    }
}

/// One application-level operation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Op {
    /// Record key in [0, records).
    pub key: u64,
    /// true = GET, false = SET.
    pub is_get: bool,
}

/// The generator: zipfian keys (scattered over the key space as YCSB
/// does) + Bernoulli GET/SET mix.
#[derive(Clone, Debug)]
pub struct YcsbGen {
    zipf: Zipfian,
    mix: Mix,
    rng: Rng,
}

impl YcsbGen {
    /// Build over `records` keys with YCSB's default 0.99 skew.
    pub fn new(records: u64, mix: Mix, seed: u64) -> Self {
        YcsbGen {
            zipf: Zipfian::new(records, 0.99),
            mix,
            rng: Rng::new(seed),
        }
    }

    /// Number of records.
    pub fn records(&self) -> u64 {
        self.zipf.n()
    }

    /// Draw the next operation.
    pub fn next_op(&mut self) -> Op {
        let key = self.zipf.sample_scattered(&mut self.rng);
        let is_get = self.rng.chance(self.mix.get_fraction());
        Op { key, is_get }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mix_fractions() {
        assert_eq!(Mix::Etc.get_fraction(), 0.95);
        assert_eq!(Mix::Sys.get_fraction(), 0.75);
        assert_eq!(Mix::parse("sys"), Some(Mix::Sys));
        assert_eq!(Mix::parse("bogus"), None);
    }

    #[test]
    fn op_mix_matches_fraction() {
        let mut g = YcsbGen::new(1000, Mix::Sys, 42);
        let n = 100_000;
        let gets = (0..n).filter(|_| g.next_op().is_get).count();
        let frac = gets as f64 / n as f64;
        assert!((frac - 0.75).abs() < 0.01, "{frac}");
    }

    #[test]
    fn keys_in_range_and_skewed() {
        let mut g = YcsbGen::new(10_000, Mix::Etc, 7);
        let mut counts = std::collections::HashMap::new();
        for _ in 0..50_000 {
            let op = g.next_op();
            assert!(op.key < 10_000);
            *counts.entry(op.key).or_insert(0u64) += 1;
        }
        // zipfian: the most popular key should carry a few % of traffic
        let max = counts.values().max().copied().unwrap();
        assert!(max > 1_000, "hottest key count {max}");
        // but traffic must not be concentrated on a single key only
        assert!(counts.len() > 1_000);
    }

    #[test]
    fn deterministic_under_seed() {
        let mut a = YcsbGen::new(1000, Mix::Sys, 5);
        let mut b = YcsbGen::new(1000, Mix::Sys, 5);
        for _ in 0..100 {
            assert_eq!(a.next_op(), b.next_op());
        }
    }
}
