//! Victim selection for remote memory reclamation (§3.5).
//!
//! Two policies:
//! * [`ActivityBased`] — Valet's contribution: pick the MR block with the
//!   largest Non-Activity-Duration using only the local tags of
//!   Figure 11. Zero communication; the chosen block is very likely in
//!   its idle phase, so parking its writes in the sender's
//!   mempool during migration is cheap. The tags cover *both*
//!   directions since the reclaim-pipeline refactor: batched demand
//!   reads and consumed prefetches stamp
//!   [`crate::mrpool::MrBlock::last_read`], so a block in a read-only
//!   phase is shielded exactly like a written one, while
//!   prefetched-but-never-used blocks (no demand stamp at all) rank
//!   first as victims.
//! * [`BatchedQueryRandom`] — the baseline the paper describes ("Typical
//!   way of handling this is to query write/read activity to multiple
//!   sender nodes"): sample random blocks, query each block's sender for
//!   recent activity, pay a round trip per query, and evict the best of
//!   the batch (or a random one — Infiniswap evicts randomly).

use crate::mrpool::{MrBlockId, MrBlockPool};
use crate::sim::Ns;
use crate::util::Rng;

/// A victim decision: which block, and how much communication latency the
/// selection itself cost (charged to the eviction path).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct VictimChoice {
    /// Chosen block.
    pub block: MrBlockId,
    /// Selection overhead (query round trips etc.).
    pub selection_cost: Ns,
    /// Queries sent to sender nodes during selection.
    pub queries: u32,
}

/// Strategy interface.
pub trait VictimPolicy {
    /// Choose a victim among the pool's Active blocks (None if empty).
    fn select(
        &mut self,
        pool: &MrBlockPool,
        now: Ns,
    ) -> Option<VictimChoice>;
    /// Display name.
    fn name(&self) -> &'static str;
}

/// Valet's activity-based selection: local metadata only, zero queries.
#[derive(Clone, Debug, Default)]
pub struct ActivityBased;

impl VictimPolicy for ActivityBased {
    fn select(
        &mut self,
        pool: &MrBlockPool,
        now: Ns,
    ) -> Option<VictimChoice> {
        pool.least_active(now).map(|b| VictimChoice {
            block: b.id,
            selection_cost: 0,
            queries: 0,
        })
    }

    fn name(&self) -> &'static str {
        "activity_based"
    }
}

/// Baseline: query `batch` random blocks' senders (one round trip each,
/// serialized — §2.3: "communication latency increases linearly"), then
/// evict the least-recently-written of the queried batch.
#[derive(Clone, Debug)]
pub struct BatchedQueryRandom {
    rng: Rng,
    /// Blocks sampled per eviction.
    pub batch: usize,
    /// One query round trip (sender-side lookup included).
    pub query_rtt: Ns,
}

impl BatchedQueryRandom {
    /// Seeded, with batch size and per-query round-trip cost.
    pub fn new(seed: u64, batch: usize, query_rtt: Ns) -> Self {
        BatchedQueryRandom {
            rng: Rng::new(seed),
            batch: batch.max(1),
            query_rtt,
        }
    }
}

impl VictimPolicy for BatchedQueryRandom {
    fn select(
        &mut self,
        pool: &MrBlockPool,
        now: Ns,
    ) -> Option<VictimChoice> {
        let active: Vec<_> = pool
            .blocks()
            .iter()
            .filter(|b| b.state == crate::mrpool::MrState::Active)
            .collect();
        if active.is_empty() {
            return None;
        }
        let k = self.batch.min(active.len());
        // sample k distinct indices
        let mut idx: Vec<usize> = (0..active.len()).collect();
        self.rng.shuffle(&mut idx);
        let sampled = &idx[..k];
        let best = sampled
            .iter()
            .map(|&i| active[i])
            .max_by_key(|b| (b.non_activity_duration(now), b.id))
            .expect("k >= 1: the active list was checked non-empty");
        Some(VictimChoice {
            block: best.id,
            selection_cost: self.query_rtt * k as Ns,
            queries: k as u32,
        })
    }

    fn name(&self) -> &'static str {
        "batched_query_random"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::us;

    fn pool_with_stamps(stamps: &[Ns]) -> MrBlockPool {
        let mut p = MrBlockPool::new();
        for &s in stamps {
            let id = p.register(0, 1 << 30, 0);
            p.touch_write(id, s);
        }
        p
    }

    #[test]
    fn activity_based_picks_oldest_with_zero_cost() {
        let p = pool_with_stamps(&[15, 9, 3, 12]);
        let mut policy = ActivityBased;
        let c = policy.select(&p, 100).unwrap();
        assert_eq!(c.block, 2); // stamp 3 = least active
        assert_eq!(c.selection_cost, 0);
        assert_eq!(c.queries, 0);
    }

    #[test]
    fn batched_query_pays_per_query() {
        let p = pool_with_stamps(&[15, 9, 3, 12, 7, 1]);
        let mut policy = BatchedQueryRandom::new(1, 4, us(30));
        let c = policy.select(&p, 100).unwrap();
        assert_eq!(c.queries, 4);
        assert_eq!(c.selection_cost, 4 * us(30));
    }

    #[test]
    fn batched_query_cost_scales_linearly() {
        // §2.3: "If the number of queries gets bigger to find the victim
        // well, communication latency increases linearly."
        let p = pool_with_stamps(&[1, 2, 3, 4, 5, 6, 7, 8]);
        let c2 = BatchedQueryRandom::new(1, 2, us(30))
            .select(&p, 100)
            .unwrap();
        let c8 = BatchedQueryRandom::new(1, 8, us(30))
            .select(&p, 100)
            .unwrap();
        assert_eq!(c8.selection_cost, 4 * c2.selection_cost);
    }

    #[test]
    fn batched_random_misses_global_optimum_sometimes() {
        // With batch=1 the baseline picks a random block; over many trials
        // it must sometimes differ from the true least-active block, while
        // ActivityBased never does.
        let p = pool_with_stamps(&[100, 200, 300, 5, 400, 500]);
        let mut diverged = false;
        for seed in 0..32 {
            let mut policy = BatchedQueryRandom::new(seed, 1, us(30));
            if policy.select(&p, 1000).unwrap().block != 3 {
                diverged = true;
                break;
            }
        }
        assert!(diverged);
        assert_eq!(ActivityBased.select(&p, 1000).unwrap().block, 3);
    }

    #[test]
    fn empty_pool_yields_none() {
        let p = MrBlockPool::new();
        assert!(ActivityBased.select(&p, 0).is_none());
        assert!(BatchedQueryRandom::new(1, 3, us(30))
            .select(&p, 0)
            .is_none());
    }
}
