//! Global Page Table (§4.1): maps a page offset in the block device's
//! linear address space to the page's slot in the local mempool.
//!
//! Per the paper: "Radix Tree is used to implement GPT. Radix Tree is wide
//! and shallow … Unlike array-based GPT, RadixTree-based GPT does not need
//! to allocate the whole structure in advance. It can grow and shrink
//! dynamically." Presence in the tree *is* the residency marker ("If a
//! page reference exists in the GPT, it points to the local page.
//! Otherwise … it needs to read from remote memory"), which avoids a
//! separate existence bitmap and its lock contention.
//!
//! Implementation: 64-way (6 bits/level) radix tree over an arena of
//! nodes, height grows on demand; empty nodes are freed on removal so the
//! structure shrinks too.

use std::cell::Cell;

const FANOUT: usize = 64;
const BITS: u32 = 6;
const EMPTY: u32 = u32::MAX;

#[derive(Clone)]
struct Node {
    slots: [u32; FANOUT],
    used: u16,
}

impl Node {
    fn new() -> Self {
        Node {
            slots: [EMPTY; FANOUT],
            used: 0,
        }
    }
}

/// Radix-tree page table: key = page number (u64), value = mempool slot
/// (u32, `!= u32::MAX`).
///
/// A one-entry *leaf cache* short-circuits the descent for consecutive
/// pages sharing a leaf (block-I/O requests touch 16 consecutive pages;
/// leaves span 64) — see EXPERIMENTS.md §Perf. The cache is interior-
/// mutable (`Cell`) so the shared-reference read path ([`Self::get`])
/// warms it too: a shard worker holding only `&self` no longer redoes
/// the full descent for every page of a dense block.
#[derive(Clone)]
pub struct RadixGpt {
    nodes: Vec<Node>,
    free: Vec<u32>,
    root: u32,
    /// Number of 6-bit levels below (and including) the root.
    height: u32,
    len: usize,
    /// Leaf cache: page-group (page >> 6) of the cached leaf.
    cache_group: Cell<u64>,
    /// Cached leaf node index (EMPTY = invalid).
    cache_leaf: Cell<u32>,
}

impl Default for RadixGpt {
    fn default() -> Self {
        Self::new()
    }
}

impl RadixGpt {
    /// Empty table.
    pub fn new() -> Self {
        RadixGpt {
            nodes: Vec::new(),
            free: Vec::new(),
            root: EMPTY,
            height: 0,
            len: 0,
            cache_group: Cell::new(u64::MAX),
            cache_leaf: Cell::new(EMPTY),
        }
    }

    /// Number of mapped pages.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True if no pages are mapped.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Allocated radix nodes (diagnostics: tree really does shrink).
    pub fn node_count(&self) -> usize {
        self.nodes.len() - self.free.len()
    }

    fn alloc_node(&mut self) -> u32 {
        if let Some(i) = self.free.pop() {
            self.nodes[i as usize] = Node::new();
            i
        } else {
            self.nodes.push(Node::new());
            (self.nodes.len() - 1) as u32
        }
    }

    /// Max key representable at current height.
    fn capacity(&self) -> u64 {
        if self.height == 0 {
            0
        } else if self.height * BITS >= 64 {
            u64::MAX
        } else {
            (1u64 << (self.height * BITS)) - 1
        }
    }

    /// Map `page` → `slot`, returning the previous slot if any.
    pub fn insert(&mut self, page: u64, slot: u32) -> Option<u32> {
        assert_ne!(slot, EMPTY, "slot value reserved");
        // Leaf-cache fast path: same 64-page group as the last access.
        if page >> BITS == self.cache_group.get()
            && self.cache_leaf.get() != EMPTY
        {
            let node = self.cache_leaf.get();
            let idx = (page & (FANOUT as u64 - 1)) as usize;
            let prev = self.nodes[node as usize].slots[idx];
            self.nodes[node as usize].slots[idx] = slot;
            return if prev == EMPTY {
                self.nodes[node as usize].used += 1;
                self.len += 1;
                None
            } else {
                Some(prev)
            };
        }
        // Grow height until the key fits.
        if self.root == EMPTY {
            self.root = self.alloc_node();
            self.height = 1;
        }
        while page > self.capacity() {
            let new_root = self.alloc_node();
            let old_root = self.root;
            self.nodes[new_root as usize].slots[0] = old_root;
            self.nodes[new_root as usize].used = 1;
            self.root = new_root;
            self.height += 1;
        }
        // Descend, creating nodes.
        let mut node = self.root;
        for level in (1..self.height).rev() {
            let idx = ((page >> (level * BITS as u32)) & (FANOUT as u64 - 1))
                as usize;
            let child = self.nodes[node as usize].slots[idx];
            let child = if child == EMPTY {
                let c = self.alloc_node();
                self.nodes[node as usize].slots[idx] = c;
                self.nodes[node as usize].used += 1;
                c
            } else {
                child
            };
            node = child;
        }
        let idx = (page & (FANOUT as u64 - 1)) as usize;
        let prev = self.nodes[node as usize].slots[idx];
        self.nodes[node as usize].slots[idx] = slot;
        self.cache_group.set(page >> BITS);
        self.cache_leaf.set(node);
        if prev == EMPTY {
            self.nodes[node as usize].used += 1;
            self.len += 1;
            None
        } else {
            Some(prev)
        }
    }

    /// Look up the slot mapped for `page`, warming the interior-mutable
    /// leaf cache on the way down: the next access in the same 64-page
    /// group — from `&self` or `&mut self` alike — is O(1). This is the
    /// dense-block pattern (16 consecutive pages per block-I/O request)
    /// shard workers run with only a shared reference.
    #[inline]
    pub fn get(&self, page: u64) -> Option<u32> {
        // Leaf-cache fast path: same 64-page group as the last access.
        if page >> BITS == self.cache_group.get()
            && self.cache_leaf.get() != EMPTY
        {
            let v = self.nodes[self.cache_leaf.get() as usize].slots
                [(page & (FANOUT as u64 - 1)) as usize];
            return if v == EMPTY { None } else { Some(v) };
        }
        if self.root == EMPTY || page > self.capacity() {
            return None;
        }
        let mut node = self.root;
        for level in (1..self.height).rev() {
            let idx = ((page >> (level * BITS as u32)) & (FANOUT as u64 - 1))
                as usize;
            node = self.nodes[node as usize].slots[idx];
            if node == EMPTY {
                return None;
            }
        }
        // Warm the cache (Cell: allowed from &self): the next access in
        // this 64-page group skips the descent.
        self.cache_group.set(page >> BITS);
        self.cache_leaf.set(node);
        let v = self.nodes[node as usize].slots
            [(page & (FANOUT as u64 - 1)) as usize];
        if v == EMPTY {
            None
        } else {
            Some(v)
        }
    }

    /// Look up the slot mapped for `page`. Since the leaf cache became
    /// interior-mutable, this is identical to [`Self::get`] — kept for
    /// the call sites that hold `&mut self` and predate the `Cell`
    /// cache.
    #[inline]
    pub fn lookup(&mut self, page: u64) -> Option<u32> {
        self.get(page)
    }

    /// Unmap `page`, returning its slot if it was mapped. Frees nodes
    /// that become empty (the "shrink dynamically" half).
    pub fn remove(&mut self, page: u64) -> Option<u32> {
        // removal can free the cached leaf — invalidate up front
        self.cache_group.set(u64::MAX);
        self.cache_leaf.set(EMPTY);
        if self.root == EMPTY || page > self.capacity() {
            return None;
        }
        // Record the descent path for cleanup.
        let mut path = [(EMPTY, 0usize); 11]; // height ≤ ceil(64/6)+1
        let mut node = self.root;
        let mut depth = 0;
        for level in (1..self.height).rev() {
            let idx = ((page >> (level * BITS as u32)) & (FANOUT as u64 - 1))
                as usize;
            path[depth] = (node, idx);
            depth += 1;
            node = self.nodes[node as usize].slots[idx];
            if node == EMPTY {
                return None;
            }
        }
        let idx = (page & (FANOUT as u64 - 1)) as usize;
        let v = self.nodes[node as usize].slots[idx];
        if v == EMPTY {
            return None;
        }
        self.nodes[node as usize].slots[idx] = EMPTY;
        self.nodes[node as usize].used -= 1;
        self.len -= 1;
        // Free empty nodes bottom-up.
        let mut child = node;
        while self.nodes[child as usize].used == 0 && depth > 0 {
            depth -= 1;
            let (parent, pidx) = path[depth];
            self.nodes[parent as usize].slots[pidx] = EMPTY;
            self.nodes[parent as usize].used -= 1;
            self.free.push(child);
            child = parent;
        }
        if self.nodes[self.root as usize].used == 0 {
            self.free.push(self.root);
            self.root = EMPTY;
            self.height = 0;
        }
        Some(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use std::collections::HashMap;

    #[test]
    fn insert_get_remove_roundtrip() {
        let mut t = RadixGpt::new();
        assert_eq!(t.get(42), None);
        assert_eq!(t.insert(42, 7), None);
        assert_eq!(t.get(42), Some(7));
        assert_eq!(t.insert(42, 9), Some(7));
        assert_eq!(t.get(42), Some(9));
        assert_eq!(t.remove(42), Some(9));
        assert_eq!(t.get(42), None);
        assert!(t.is_empty());
    }

    #[test]
    fn sparse_keys_grow_height() {
        let mut t = RadixGpt::new();
        t.insert(0, 1);
        t.insert(u64::MAX / 2, 2);
        t.insert(1 << 40, 3);
        assert_eq!(t.get(0), Some(1));
        assert_eq!(t.get(u64::MAX / 2), Some(2));
        assert_eq!(t.get(1 << 40), Some(3));
        assert_eq!(t.len(), 3);
    }

    #[test]
    fn tree_shrinks_after_removal() {
        let mut t = RadixGpt::new();
        for p in 0..10_000u64 {
            t.insert(p * 64, p as u32);
        }
        let peak = t.node_count();
        for p in 0..10_000u64 {
            assert_eq!(t.remove(p * 64), Some(p as u32));
        }
        assert_eq!(t.len(), 0);
        assert_eq!(t.node_count(), 0, "peak was {peak}");
    }

    #[test]
    fn dense_range_lookups() {
        let mut t = RadixGpt::new();
        for p in 0..4096u64 {
            t.insert(p, (p * 3) as u32);
        }
        for p in 0..4096u64 {
            assert_eq!(t.get(p), Some((p * 3) as u32));
        }
        assert_eq!(t.get(4096), None);
    }

    #[test]
    fn lookup_matches_get_and_warms_cache() {
        let mut t = RadixGpt::new();
        for p in (0..2048u64).step_by(3) {
            t.insert(p, p as u32);
        }
        // invalidate the insert-time cache, then lookup from cold
        t.remove(10_000_000);
        for p in 0..2048u64 {
            assert_eq!(t.lookup(p), t.get(p), "page {p}");
        }
        // after a lookup in a group, reads in that group hit the cache
        assert_eq!(t.lookup(63), t.get(63));
        assert_eq!(t.lookup(0), t.get(0));
    }

    #[test]
    fn prop_matches_hashmap_model() {
        prop::check("radix vs hashmap", |rng| {
            let mut t = RadixGpt::new();
            let mut m: HashMap<u64, u32> = HashMap::new();
            for _ in 0..300 {
                // keys from mixed ranges to exercise height growth
                let key = match rng.below(3) {
                    0 => rng.below(100),
                    1 => rng.below(1 << 20),
                    _ => rng.next_u64() >> rng.below(30),
                };
                match rng.below(3) {
                    0 | 1 => {
                        let v = rng.below(1 << 30) as u32;
                        assert_eq!(t.insert(key, v), m.insert(key, v));
                    }
                    _ => {
                        assert_eq!(t.remove(key), m.remove(&key));
                    }
                }
                assert_eq!(t.get(key), m.get(&key).copied());
                assert_eq!(t.len(), m.len());
            }
            // final full sweep
            for (&k, &v) in &m {
                assert_eq!(t.get(k), Some(v));
            }
        });
    }
}
