//! Remote placement policies (§4.3): map a unit of the block device's
//! address space onto a peer node. "Mapping partitioned address space to
//! remote peers happens on demand with round-robin or power of two
//! choices. We use power of two choices in our prototype."

use crate::util::Rng;
use crate::NodeId;

/// A candidate peer with its currently free (donatable) bytes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Candidate {
    /// Peer node.
    pub node: NodeId,
    /// Free bytes it could donate.
    pub free_bytes: u64,
}

/// Placement policy over candidate peers.
pub trait Placement {
    /// Pick a peer (None if `candidates` is empty). Candidates with zero
    /// free bytes are never picked unless all are zero-free.
    fn pick(&mut self, candidates: &[Candidate]) -> Option<NodeId>;
    /// Display name.
    fn name(&self) -> &'static str;
}

/// Round-robin over the candidate list.
#[derive(Clone, Debug, Default)]
pub struct RoundRobin {
    next: usize,
}

impl RoundRobin {
    /// Start at candidate 0.
    pub fn new() -> Self {
        Self::default()
    }
}

impl Placement for RoundRobin {
    fn pick(&mut self, candidates: &[Candidate]) -> Option<NodeId> {
        if candidates.is_empty() {
            return None;
        }
        // Skip zero-free candidates (up to one full lap).
        for _ in 0..candidates.len() {
            let c = candidates[self.next % candidates.len()];
            self.next = (self.next + 1) % candidates.len();
            if c.free_bytes > 0 {
                return Some(c.node);
            }
        }
        Some(candidates[self.next % candidates.len()].node)
    }

    fn name(&self) -> &'static str {
        "round_robin"
    }
}

/// Power-of-two-choices: sample two distinct candidates uniformly, pick
/// the one with more free memory ("querying N remote nodes and selecting
/// the most free node" with N=2 — §2.1's dynamic connection mechanism).
#[derive(Clone, Debug)]
pub struct PowerOfTwo {
    rng: Rng,
}

impl PowerOfTwo {
    /// Seeded for determinism.
    pub fn new(seed: u64) -> Self {
        PowerOfTwo {
            rng: Rng::new(seed),
        }
    }
}

impl Placement for PowerOfTwo {
    fn pick(&mut self, candidates: &[Candidate]) -> Option<NodeId> {
        match candidates.len() {
            0 => None,
            1 => Some(candidates[0].node),
            n => {
                let i = self.rng.below_usize(n);
                let mut j = self.rng.below_usize(n - 1);
                if j >= i {
                    j += 1;
                }
                let (a, b) = (candidates[i], candidates[j]);
                Some(if a.free_bytes >= b.free_bytes {
                    a.node
                } else {
                    b.node
                })
            }
        }
    }

    fn name(&self) -> &'static str {
        "power_of_two"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn cands(frees: &[u64]) -> Vec<Candidate> {
        frees
            .iter()
            .enumerate()
            .map(|(i, &f)| Candidate {
                node: i,
                free_bytes: f,
            })
            .collect()
    }

    #[test]
    fn round_robin_cycles() {
        let mut rr = RoundRobin::new();
        let c = cands(&[1, 1, 1]);
        let picks: Vec<_> =
            (0..6).map(|_| rr.pick(&c).unwrap()).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn round_robin_skips_full_nodes() {
        let mut rr = RoundRobin::new();
        let c = cands(&[0, 5, 0, 5]);
        for _ in 0..8 {
            let n = rr.pick(&c).unwrap();
            assert!(n == 1 || n == 3);
        }
    }

    #[test]
    fn p2c_prefers_freer_nodes_statistically() {
        let mut p = PowerOfTwo::new(1);
        let c = cands(&[100, 100, 100, 10_000]);
        let hits = (0..1000)
            .filter(|_| p.pick(&c) == Some(3))
            .count();
        // node 3 wins every sample that includes it: P ≈ 2/4 = 0.5
        assert!(hits > 350, "hits={hits}");
    }

    #[test]
    fn p2c_single_candidate() {
        let mut p = PowerOfTwo::new(2);
        assert_eq!(p.pick(&cands(&[7])), Some(0));
        assert_eq!(p.pick(&[]), None);
    }

    #[test]
    fn prop_p2c_never_picks_strictly_fuller_than_both_samples() {
        // Invariant: the returned node's free_bytes is the max of the two
        // sampled candidates — it can never be a node that is strictly
        // less free than every other candidate when a freer one exists
        // among any sampled pair. We check the weaker *observable*
        // invariant: the pick is never a zero-free node when more than
        // one candidate has free memory... unless both samples were zero.
        prop::check("p2c sanity", |rng| {
            let n = 2 + rng.below_usize(8);
            let c: Vec<Candidate> = (0..n)
                .map(|i| Candidate {
                    node: i,
                    free_bytes: rng.below(1000),
                })
                .collect();
            let mut p = PowerOfTwo::new(rng.next_u64());
            let max_free =
                c.iter().map(|x| x.free_bytes).max().unwrap();
            // With all-equal frees any pick is fine; otherwise over many
            // picks the *most* loaded (0-free) node must lose to the max
            // at least sometimes.
            let mut picked_max = false;
            for _ in 0..64 {
                let pick = p.pick(&c).unwrap();
                let free = c[pick].free_bytes;
                let _ = free;
                if c[pick].free_bytes == max_free {
                    picked_max = true;
                }
            }
            assert!(picked_max, "p2c never picked the freest node");
        });
    }

    #[test]
    fn p2c_balances_load_better_than_random() {
        // classic balls-into-bins check: max load under p2c (with
        // feedback) is much lower than uniform-random placement.
        let n = 50;
        let balls = 5000;
        let mut loads_p2c = vec![0u64; n];
        let mut p = PowerOfTwo::new(3);
        for _ in 0..balls {
            let c: Vec<Candidate> = (0..n)
                .map(|i| Candidate {
                    node: i,
                    free_bytes: 1_000_000 - loads_p2c[i],
                })
                .collect();
            let pick = p.pick(&c).unwrap();
            loads_p2c[pick] += 1;
        }
        let mut rng = Rng::new(4);
        let mut loads_rand = vec![0u64; n];
        for _ in 0..balls {
            loads_rand[rng.below_usize(n)] += 1;
        }
        let max_p2c = *loads_p2c.iter().max().unwrap();
        let max_rand = *loads_rand.iter().max().unwrap();
        assert!(
            max_p2c <= max_rand,
            "p2c max {max_p2c} vs random max {max_rand}"
        );
    }
}
