//! Remote placement policies (§4.3): map a unit of the block device's
//! address space onto a peer node. "Mapping partitioned address space to
//! remote peers happens on demand with round-robin or power of two
//! choices. We use power of two choices in our prototype."
//!
//! Beyond the paper: every candidate also carries a **pressure score**
//! (an EWMA of the peer's memory occupancy, fed by the activity
//! monitors — see [`crate::backends::ClusterState::refresh_pressure`]).
//! [`PowerOfTwo`] compares *pressure-adjusted* free bytes, and the
//! reclaim pipeline's destination choice defaults to [`LeastPressured`]
//! so migrations drain toward the calmest peer instead of the one that
//! merely has the most free bytes this instant — the imbalance the
//! memory-disaggregation literature (Pond, the Yelam survey) identifies
//! as the pooling bottleneck.

use crate::mrpool::MemTier;
use crate::util::Rng;
use crate::NodeId;

/// A candidate **(peer, tier)** slot with its currently free bytes in
/// that tier and the tier's smoothed pressure score. With the pool tier
/// disabled only Remote-tier candidates exist and the list is
/// byte-identical to the pre-tier system.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Candidate {
    /// Peer node.
    pub node: NodeId,
    /// Free bytes it could donate in this tier.
    pub free_bytes: u64,
    /// Smoothed occupancy pressure of this tier in thousandths (0 =
    /// idle, 1000 = fully claimed); see the module docs.
    pub pressure_milli: u32,
    /// The memory tier this candidacy offers.
    pub tier: MemTier,
}

impl Candidate {
    /// A Remote-tier candidate with no recorded pressure (tests,
    /// synthetic sweeps).
    pub fn new(node: NodeId, free_bytes: u64) -> Self {
        Candidate {
            node,
            free_bytes,
            pressure_milli: 0,
            tier: MemTier::Remote,
        }
    }

    /// A pool-tier candidate with no recorded pressure.
    pub fn pool(node: NodeId, free_bytes: u64) -> Self {
        Candidate {
            node,
            free_bytes,
            pressure_milli: 0,
            tier: MemTier::Pool,
        }
    }

    /// Free bytes discounted by the pressure score: the comparison key
    /// the load-feedback policies use.
    pub fn adjusted_free(&self) -> u64 {
        let keep = 1000u64.saturating_sub(self.pressure_milli as u64);
        (self.free_bytes / 1000).saturating_mul(keep)
            + (self.free_bytes % 1000) * keep / 1000
    }
}

/// A placement decision: which peer, and which of its memory tiers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Placed {
    /// Chosen peer node.
    pub node: NodeId,
    /// Chosen memory tier on that peer.
    pub tier: MemTier,
}

/// Placement policy over candidate (peer, tier) slots.
pub trait Placement {
    /// Pick a slot (None if `candidates` is empty). Candidates with zero
    /// free bytes are never picked unless all are zero-free.
    fn pick(&mut self, candidates: &[Candidate]) -> Option<Placed>;
    /// Display name.
    fn name(&self) -> &'static str;
}

/// The decision a candidate turns into when picked.
fn placed(c: &Candidate) -> Placed {
    Placed {
        node: c.node,
        tier: c.tier,
    }
}

/// Round-robin over the candidate list.
#[derive(Clone, Debug, Default)]
pub struct RoundRobin {
    next: usize,
}

impl RoundRobin {
    /// Start at candidate 0.
    pub fn new() -> Self {
        Self::default()
    }
}

impl Placement for RoundRobin {
    fn pick(&mut self, candidates: &[Candidate]) -> Option<Placed> {
        if candidates.is_empty() {
            return None;
        }
        // Skip zero-free candidates (up to one full lap).
        for _ in 0..candidates.len() {
            let c = candidates[self.next % candidates.len()];
            self.next = (self.next + 1) % candidates.len();
            if c.free_bytes > 0 {
                return Some(placed(&c));
            }
        }
        Some(placed(&candidates[self.next % candidates.len()]))
    }

    fn name(&self) -> &'static str {
        "round_robin"
    }
}

/// Power-of-two-choices: sample two distinct candidates uniformly, pick
/// the one with more free memory ("querying N remote nodes and selecting
/// the most free node" with N=2 — §2.1's dynamic connection mechanism).
#[derive(Clone, Debug)]
pub struct PowerOfTwo {
    rng: Rng,
}

impl PowerOfTwo {
    /// Seeded for determinism.
    pub fn new(seed: u64) -> Self {
        PowerOfTwo {
            rng: Rng::new(seed),
        }
    }
}

impl Placement for PowerOfTwo {
    fn pick(&mut self, candidates: &[Candidate]) -> Option<Placed> {
        match candidates.len() {
            0 => None,
            1 => Some(placed(&candidates[0])),
            n => {
                let i = self.rng.below_usize(n);
                let mut j = self.rng.below_usize(n - 1);
                if j >= i {
                    j += 1;
                }
                // compare pressure-adjusted free bytes: a peer whose
                // monitor shows sustained occupancy loses the duel even
                // with momentarily more free memory
                let (a, b) = (candidates[i], candidates[j]);
                Some(if a.adjusted_free() >= b.adjusted_free() {
                    placed(&a)
                } else {
                    placed(&b)
                })
            }
        }
    }

    fn name(&self) -> &'static str {
        "power_of_two"
    }
}

/// Deterministic least-pressured choice: minimum pressure score, ties
/// broken by most free bytes, then lowest node id. The default
/// destination policy of the reclaim pipeline (§3.5 "migrate … to a
/// less-pressured peer"): a migration should land where the native
/// applications are quietest, or it will just be squeezed out again.
#[derive(Clone, Copy, Debug, Default)]
pub struct LeastPressured;

impl LeastPressured {
    /// Stateless.
    pub fn new() -> Self {
        LeastPressured
    }
}

impl Placement for LeastPressured {
    fn pick(&mut self, candidates: &[Candidate]) -> Option<Placed> {
        candidates
            .iter()
            .min_by_key(|c| {
                (
                    c.pressure_milli,
                    u64::MAX - c.free_bytes,
                    c.node,
                    // a (node, pressure, free) tie across tiers resolves
                    // to the faster tier (Pool < Remote in enum order)
                    c.tier,
                )
            })
            .map(placed)
    }

    fn name(&self) -> &'static str {
        "least_pressured"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn cands(frees: &[u64]) -> Vec<Candidate> {
        frees
            .iter()
            .enumerate()
            .map(|(i, &f)| Candidate::new(i, f))
            .collect()
    }

    #[test]
    fn round_robin_cycles() {
        let mut rr = RoundRobin::new();
        let c = cands(&[1, 1, 1]);
        let picks: Vec<_> =
            (0..6).map(|_| rr.pick(&c).unwrap().node).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn round_robin_skips_full_nodes() {
        let mut rr = RoundRobin::new();
        let c = cands(&[0, 5, 0, 5]);
        for _ in 0..8 {
            let n = rr.pick(&c).unwrap().node;
            assert!(n == 1 || n == 3);
        }
    }

    #[test]
    fn p2c_prefers_freer_nodes_statistically() {
        let mut p = PowerOfTwo::new(1);
        let c = cands(&[100, 100, 100, 10_000]);
        let hits = (0..1000)
            .filter(|_| p.pick(&c).map(|x| x.node) == Some(3))
            .count();
        // node 3 wins every sample that includes it: P ≈ 2/4 = 0.5
        assert!(hits > 350, "hits={hits}");
    }

    #[test]
    fn p2c_single_candidate() {
        let mut p = PowerOfTwo::new(2);
        let only = p.pick(&cands(&[7])).unwrap();
        assert_eq!((only.node, only.tier), (0, MemTier::Remote));
        assert_eq!(p.pick(&[]), None);
    }

    #[test]
    fn prop_p2c_never_picks_strictly_fuller_than_both_samples() {
        // Invariant: the returned node's free_bytes is the max of the two
        // sampled candidates — it can never be a node that is strictly
        // less free than every other candidate when a freer one exists
        // among any sampled pair. We check the weaker *observable*
        // invariant: the pick is never a zero-free node when more than
        // one candidate has free memory... unless both samples were zero.
        prop::check("p2c sanity", |rng| {
            let n = 2 + rng.below_usize(8);
            let c: Vec<Candidate> = (0..n)
                .map(|i| Candidate::new(i, rng.below(1000)))
                .collect();
            let mut p = PowerOfTwo::new(rng.next_u64());
            let max_free =
                c.iter().map(|x| x.free_bytes).max().unwrap();
            // With all-equal frees any pick is fine; otherwise over many
            // picks the *most* loaded (0-free) node must lose to the max
            // at least sometimes.
            let mut picked_max = false;
            for _ in 0..64 {
                let pick = p.pick(&c).unwrap().node;
                let free = c[pick].free_bytes;
                let _ = free;
                if c[pick].free_bytes == max_free {
                    picked_max = true;
                }
            }
            assert!(picked_max, "p2c never picked the freest node");
        });
    }

    #[test]
    fn p2c_balances_load_better_than_random() {
        // classic balls-into-bins check: max load under p2c (with
        // feedback) is much lower than uniform-random placement.
        let n = 50;
        let balls = 5000;
        let mut loads_p2c = vec![0u64; n];
        let mut p = PowerOfTwo::new(3);
        for _ in 0..balls {
            let c: Vec<Candidate> = (0..n)
                .map(|i| Candidate::new(i, 1_000_000 - loads_p2c[i]))
                .collect();
            let pick = p.pick(&c).unwrap().node;
            loads_p2c[pick] += 1;
        }
        let mut rng = Rng::new(4);
        let mut loads_rand = vec![0u64; n];
        for _ in 0..balls {
            loads_rand[rng.below_usize(n)] += 1;
        }
        let max_p2c = *loads_p2c.iter().max().unwrap();
        let max_rand = *loads_rand.iter().max().unwrap();
        assert!(
            max_p2c <= max_rand,
            "p2c max {max_p2c} vs random max {max_rand}"
        );
    }

    #[test]
    fn p2c_pressure_overrides_raw_free_bytes() {
        // Two candidates: one slightly freer but heavily pressured, one
        // slightly fuller but idle. Every duel that samples both must
        // pick the idle one.
        let pressured = Candidate {
            node: 0,
            free_bytes: 1_100,
            pressure_milli: 900,
            tier: MemTier::Remote,
        };
        let idle = Candidate {
            node: 1,
            free_bytes: 1_000,
            pressure_milli: 0,
            tier: MemTier::Remote,
        };
        assert!(idle.adjusted_free() > pressured.adjusted_free());
        let mut p = PowerOfTwo::new(11);
        for _ in 0..64 {
            assert_eq!(
                p.pick(&[pressured, idle]).map(|x| x.node),
                Some(1)
            );
        }
    }

    #[test]
    fn least_pressured_orders_by_pressure_then_free_then_node() {
        let mut lp = LeastPressured::new();
        assert_eq!(lp.pick(&[]), None);
        let c = vec![
            Candidate {
                node: 0,
                free_bytes: 500,
                pressure_milli: 700,
                tier: MemTier::Remote,
            },
            Candidate {
                node: 1,
                free_bytes: 100,
                pressure_milli: 100,
                tier: MemTier::Remote,
            },
            Candidate {
                node: 2,
                free_bytes: 900,
                pressure_milli: 100,
                tier: MemTier::Remote,
            },
        ];
        // lowest pressure wins; among the 100-milli pair the freer node
        assert_eq!(lp.pick(&c).map(|x| x.node), Some(2));
        // exact tie falls back to the lowest node id
        let tie = vec![
            Candidate::new(4, 64),
            Candidate::new(3, 64),
        ];
        assert_eq!(lp.pick(&tie).map(|x| x.node), Some(3));
        assert_eq!(lp.name(), "least_pressured");
    }

    #[test]
    fn policies_carry_the_candidate_tier_through_the_pick() {
        // A pool-tier candidacy picked by any policy yields a pool-tier
        // decision: tier rides the candidate, never a separate guess.
        let c = vec![Candidate::pool(2, 1 << 20)];
        let mut rr = RoundRobin::new();
        assert_eq!(
            rr.pick(&c),
            Some(Placed {
                node: 2,
                tier: MemTier::Pool
            })
        );
        let mut lp = LeastPressured::new();
        assert_eq!(lp.pick(&c).unwrap().tier, MemTier::Pool);
        let mut p2 = PowerOfTwo::new(9);
        assert_eq!(p2.pick(&c).unwrap().tier, MemTier::Pool);
        // a full (node, pressure, free) tie resolves to the faster tier
        let mut lp2 = LeastPressured::new();
        let tie = vec![Candidate::new(1, 64), Candidate::pool(1, 64)];
        assert_eq!(lp2.pick(&tie).unwrap().tier, MemTier::Pool);
    }

    #[test]
    fn adjusted_free_scales_without_overflow() {
        let c = Candidate {
            node: 0,
            free_bytes: u64::MAX,
            pressure_milli: 0,
            tier: MemTier::Remote,
        };
        assert_eq!(c.adjusted_free(), u64::MAX);
        let half = Candidate {
            node: 0,
            free_bytes: 10_000,
            pressure_milli: 500,
            tier: MemTier::Remote,
        };
        assert_eq!(half.adjusted_free(), 5_000);
        let full = Candidate {
            node: 0,
            free_bytes: 10_000,
            pressure_milli: 1000,
            tier: MemTier::Remote,
        };
        assert_eq!(full.adjusted_free(), 0);
    }
}
