//! The shared **slow path**: the Remote Sender Thread (§4.1) plus every
//! piece of state the shards share — the unit map, placement, in-flight
//! RDMA batches, and the §3.5 eviction/migration machinery.
//!
//! One [`RemoteSender`] serves all shards: it drains their staging
//! queues through the coalescing batcher one batch at a time (the single
//! sender-thread timeline the paper describes), and hands completed
//! write sets back through per-shard mailboxes so each shard worker can
//! apply them to its own mempool without sharing it. Writes are thereby
//! serialized only within a shard; the sender serializes nothing but its
//! own CPU time.

use std::collections::HashMap;

use crate::backends::{ClusterState, PressureOutcome, Unit, UnitMap};
use crate::config::{Config, LatencyConfig, ValetConfig};
use crate::coordinator::fast::ShardFastPath;
use crate::eviction::{ActivityBased, VictimPolicy};
use crate::migration::{self, MigAction, MigEvent, MigState, MigrationSm};
use crate::mrpool::MrState;
use crate::placement::{Placement, PowerOfTwo};
use crate::queues::WriteSet;
use crate::replication::choose_replicas;
use crate::sim::{Ns, Server};
use crate::{NodeId, PAGE_SIZE};

/// One coalesced RDMA message in flight: completion time, the shard its
/// write sets belong to, and the sets themselves.
#[derive(Clone, Debug)]
struct Inflight {
    done: Ns,
    shard: usize,
    sets: Vec<WriteSet>,
}

/// The shared remote-sender slow path (see module docs).
pub struct RemoteSender {
    lat: LatencyConfig,
    vcfg: ValetConfig,
    /// Remote sender thread's timeline (one batch in service at a time;
    /// batches pipeline on the NIC beneath it).
    thread: Server,
    units: UnitMap,
    /// Pluggable placement hook (§4.3; power-of-two choices by default).
    placement: Box<dyn Placement + Send>,
    inflight: Vec<Inflight>,
    /// Per-shard completion mailboxes: durable write sets waiting for
    /// their owning shard to apply them (FIFO per shard).
    done: Vec<Vec<WriteSet>>,
    /// Pluggable eviction hook (§3.5; activity-based by default).
    victim_policy: Box<dyn VictimPolicy + Send>,
    /// Owner id stamped on MR registrations (multi-tenant arbitration);
    /// `None` registers as the sender node.
    owner_tag: Option<NodeId>,
    /// In-flight remote reads, page → completion time: a miss that
    /// overlaps an outstanding fetch of the same page *in virtual time*
    /// (queue-depth > 1 block I/O, simulated multi-client runs)
    /// piggybacks on it (miss coalescing) instead of posting a
    /// duplicate RDMA READ, and a readahead proposal covering the page
    /// free-rides on it without posting any wire work. Note the sharded
    /// serve front-end routes a page to one worker whose virtual clock
    /// advances past each completion before the next request, so
    /// cross-request coalescing there is rare by construction — the
    /// table's main consumers are overlapping in-flight windows and the
    /// prefetcher. Entries whose completion has passed are pruned
    /// lazily.
    inflight_reads: HashMap<u64, Ns>,
}

/// Prune the in-flight read table once it reaches this size (stale
/// entries — completions in the past — are dropped; live ones kept).
const INFLIGHT_READS_PRUNE: usize = 4096;

impl RemoteSender {
    /// Build the slow path for `shards` fast paths.
    pub fn new(cfg: &Config, shards: usize) -> Self {
        RemoteSender {
            lat: cfg.latency.clone(),
            vcfg: cfg.valet.clone(),
            thread: Server::new(),
            units: UnitMap::new(cfg.valet.mr_block_bytes),
            placement: Box::new(PowerOfTwo::new(cfg.cluster.seed)),
            inflight: Vec::new(),
            done: vec![Vec::new(); shards.max(1)],
            victim_policy: Box::new(ActivityBased),
            owner_tag: None,
            inflight_reads: HashMap::new(),
        }
    }

    // -- configuration hooks ------------------------------------------

    /// Tag MR registrations with a distinct owner id (multi-tenant
    /// arbitration: victim selection under remote pressure then only
    /// ever sees this tenant's blocks).
    pub fn set_owner_tag(&mut self, owner: NodeId) {
        self.owner_tag = Some(owner);
    }

    /// Swap in a different eviction policy (the §3.5 hook).
    pub fn set_victim_policy(&mut self, policy: Box<dyn VictimPolicy + Send>) {
        self.victim_policy = policy;
    }

    /// Swap in a different placement policy (the §4.3 hook).
    pub fn set_placement(&mut self, placement: Box<dyn Placement + Send>) {
        self.placement = placement;
    }

    // -- diagnostics --------------------------------------------------

    /// The latency model the whole pipeline is calibrated to.
    pub fn lat(&self) -> &LatencyConfig {
        &self.lat
    }

    /// Valet policy knobs.
    pub fn vcfg(&self) -> &ValetConfig {
        &self.vcfg
    }

    /// The remote address-space unit map.
    pub fn units(&self) -> &UnitMap {
        &self.units
    }

    /// Name of the active eviction policy.
    pub fn victim_policy_name(&self) -> &'static str {
        self.victim_policy.name()
    }

    /// When the sender thread is next idle.
    pub fn busy_until(&self) -> Ns {
        self.thread.busy_until()
    }

    /// Write sets carried by in-flight RDMA batches plus durable sets
    /// not yet applied by their shard.
    pub fn inflight_write_sets(&self) -> usize {
        self.inflight.iter().map(|f| f.sets.len()).sum::<usize>()
            + self.done.iter().map(|d| d.len()).sum::<usize>()
    }

    /// Earliest completion among in-flight batches carrying `shard`'s
    /// write sets.
    pub fn inflight_min_done(&self, shard: usize) -> Option<Ns> {
        self.inflight
            .iter()
            .filter(|f| f.shard == shard)
            .map(|f| f.done)
            .min()
    }

    // -- the sender-thread pipeline -----------------------------------

    /// Ensure `unit` has a remote mapping; returns when it is usable.
    /// Charged on the *sender thread* timeline — never the request path.
    fn ensure_unit(&mut self, cl: &mut ClusterState, now: Ns, unit: u64) -> Ns {
        if let Some(u) = self.units.get(unit) {
            if u.alive {
                return u.ready_at;
            }
        }
        // (Re)map: pick primary via the placement hook, then replicas.
        let cands = cl.candidates();
        let primary = self
            .placement
            .pick(&cands)
            .expect("cluster has at least one peer");
        let cand_nodes: Vec<NodeId> = cands.iter().map(|c| c.node).collect();
        let nodes = choose_replicas(
            cl.sender,
            primary,
            &cand_nodes,
            self.vcfg.replicas.max(1),
        );
        // Connection (if new) + mapping, charged sequentially per node.
        let mut t = now;
        for &n in &nodes {
            let (tc, _newc) = cl.fabric.ensure_connected(t, cl.sender, n);
            t = cl.fabric.map_mr(tc, cl.sender);
        }
        let owner = self.owner_tag.unwrap_or(cl.sender);
        let blocks = nodes
            .iter()
            .map(|&n| cl.mrpools[n].register(owner, self.units.unit_bytes, t))
            .collect();
        self.units.insert(
            unit,
            Unit {
                nodes,
                blocks,
                ready_at: t,
                wlocked_until: 0,
                alive: true,
            },
        );
        t
    }

    /// Apply completions of in-flight RDMA batches up to `now`: stamp
    /// activity tags on the primary blocks and move each completed write
    /// set into its shard's mailbox (the owning shard applies it via
    /// [`ShardFastPath::apply_durable`] when it next drains the mailbox).
    pub fn complete_inflight(&mut self, cl: &mut ClusterState, now: Ns) {
        let mut i = 0;
        while i < self.inflight.len() {
            if self.inflight[i].done <= now {
                let inflight = self.inflight.swap_remove(i);
                for ws in inflight.sets {
                    // stamp activity tags on the primary block
                    let unit = self.units.unit_of(ws.page);
                    if let Some(u) = self.units.get(unit) {
                        if let (Some(&n), Some(&b)) =
                            (u.nodes.first(), u.blocks.first())
                        {
                            cl.mrpools[n].touch_write(b, inflight.done);
                        }
                    }
                    self.done[inflight.shard].push(ws);
                }
            } else {
                i += 1;
            }
        }
    }

    /// Drain `shard`'s completion mailbox (FIFO).
    pub fn take_done(&mut self, shard: usize) -> Vec<WriteSet> {
        std::mem::take(&mut self.done[shard])
    }

    // -- the read-side pipeline ---------------------------------------

    /// If `page` has an outstanding remote fetch completing *after*
    /// `now`, return its completion time — the caller piggybacks on it
    /// (miss coalescing) instead of posting a duplicate READ. An entry
    /// whose completion has passed is pruned and `None` returned: the
    /// fetched data was never installed locally (remote reads are
    /// read-through), so a later miss must fetch again.
    pub fn inflight_read_done(&mut self, page: u64, now: Ns) -> Option<Ns> {
        match self.inflight_reads.get(&page) {
            Some(&done) if done > now => Some(done),
            Some(_) => {
                self.inflight_reads.remove(&page);
                None
            }
            None => None,
        }
    }

    /// Record an outstanding remote read of `page` completing at
    /// `done`, so overlapping misses on the same page can coalesce.
    pub fn note_inflight_read(&mut self, now: Ns, page: u64, done: Ns) {
        if self.inflight_reads.len() >= INFLIGHT_READS_PRUNE {
            self.inflight_reads.retain(|_, d| *d > now);
        }
        self.inflight_reads.insert(page, done);
    }

    /// Outstanding remote reads tracked for coalescing (diagnostics;
    /// includes entries not yet lazily pruned).
    pub fn inflight_read_count(&self) -> usize {
        self.inflight_reads.len()
    }

    /// Batched remote read: fetch `pages` (grouped into runs that share
    /// an address-space unit) with **one** RDMA READ per unit — one
    /// base round trip plus per-page wire time, mirroring the write
    /// side's coalescing batcher — and register every page in the
    /// in-flight read table. `out` is filled (cleared first) with each
    /// page's completion time, in input order; a page whose unit is
    /// unmapped or dead completes "immediately" at `t0` (the caller
    /// filters those up front — this keeps the batch robust). Returns
    /// the completion time of the slowest run, `t0` when `pages` is
    /// empty.
    ///
    /// Callers decide what the batch means: the demand block-read path
    /// waits on the result; the prefetcher treats it as asynchronous
    /// readahead and only records the arrival times.
    pub fn read_batch(
        &mut self,
        cl: &mut ClusterState,
        t0: Ns,
        pages: &[u64],
        out: &mut Vec<(u64, Ns)>,
    ) -> Ns {
        out.clear();
        let mut slowest = t0;
        let mut i = 0;
        while i < pages.len() {
            // one run = consecutive input pages sharing a unit
            let unit = self.units.unit_of(pages[i]);
            let mut j = i + 1;
            while j < pages.len() && self.units.unit_of(pages[j]) == unit {
                j += 1;
            }
            let run = &pages[i..j];
            let (primary, ready) = match self.units.get(unit) {
                Some(u) if u.alive => (u.nodes[0], u.ready_at),
                _ => {
                    for &p in run {
                        out.push((p, t0));
                    }
                    i = j;
                    continue;
                }
            };
            let t = t0.max(ready) + self.lat.mrpool_get;
            let bytes = run.len() as u64 * PAGE_SIZE;
            let verb = cl.fabric.rdma_read(t, cl.sender, primary, bytes);
            for &p in run {
                self.note_inflight_read(t0, p, verb.end);
                out.push((p, verb.end));
            }
            slowest = slowest.max(verb.end);
            i = j;
        }
        slowest
    }

    /// Send one coalesced batch from `fast`'s staging queue at (no
    /// earlier than) `t0`; returns its completion time. Coalescing only
    /// merges write sets that target the same address-space unit (one
    /// RDMA message lands in one MR block).
    pub fn send_one_batch(
        &mut self,
        cl: &mut ClusterState,
        t0: Ns,
        shard: usize,
        fast: &mut ShardFastPath,
    ) -> Ns {
        debug_assert!(!fast.staging.is_empty());
        let max = if self.vcfg.coalescing {
            self.vcfg.rdma_msg_bytes
        } else {
            1 // force single write set per message
        };
        let unit = self
            .units
            .unit_of(fast.staging.peek().expect("non-empty").page);
        let mut batch = Vec::new();
        let mut bytes = 0u64;
        while let Some(front) = fast.staging.peek() {
            let same_unit = self.units.unit_of(front.page) == unit;
            if !batch.is_empty() && (bytes + front.bytes > max || !same_unit)
            {
                break;
            }
            let ws = fast.staging.pop().unwrap();
            bytes += ws.bytes;
            batch.push(ws);
        }
        // mapping (behind the mempool — charged here, on sender thread)
        let ready = self.ensure_unit(cl, t0, unit);
        let u = self.units.get(unit).unwrap();
        let mut t = t0.max(ready).max(u.wlocked_until);
        // mrpool get + one-sided write per replica (queue on our NIC)
        t += self.lat.mrpool_get;
        let nodes = u.nodes.clone();
        let mut done = t;
        for &n in &nodes {
            let verb = cl.fabric.rdma_write(t, cl.sender, n, bytes);
            done = done.max(verb.end);
        }
        // optional disk backup, off the critical path
        if self.vcfg.disk_backup {
            cl.disks[cl.sender].write_async(t, bytes);
            for ws in &batch {
                for p in ws.page..ws.page + ws.pages() {
                    fast.disk_valid.set(p);
                }
            }
            fast.metrics.disk_writes += 1;
        }
        // The sender thread is busy only for its CPU work (mapping waits
        // + mrpool get + posting the WQE, ~300 ns); the verb completes
        // asynchronously on the NIC (tracked via `inflight`), so many
        // messages pipeline — and un-coalesced small messages flood the
        // WQE cache, which is exactly the §3.3 argument for batching.
        let post_done = t + 300;
        self.thread.serve(t0, post_done.saturating_sub(t0));
        self.inflight.push(Inflight {
            done,
            shard,
            sets: batch,
        });
        done
    }

    /// Synchronous write (Valet-RemoteOnly ablation): radix + copy + wait
    /// for the RDMA send like Infiniswap, but keep coalescing disabled
    /// and no disk redirect (mapping stalls the request instead).
    pub fn write_sync(
        &mut self,
        cl: &mut ClusterState,
        now: Ns,
        page: u64,
        bytes: u64,
        fast: &mut ShardFastPath,
    ) -> crate::backends::Access {
        use crate::backends::{Access, Source};
        let mut t = now + self.lat.radix_insert;
        fast.metrics.write_parts.add("radix", self.lat.radix_insert);
        let unit = self.units.unit_of(page);
        let ready = self.ensure_unit(cl, t, unit);
        if ready > t {
            fast.metrics.write_parts.add("mapping", ready - t);
            t = ready;
        }
        let copy = self.lat.copy(bytes);
        t += copy;
        fast.metrics.write_parts.add("copy", copy);
        let u = self.units.get(unit).unwrap();
        let nodes = u.nodes.clone();
        let mut done = t + self.lat.mrpool_get;
        for &n in &nodes {
            let verb = cl.fabric.rdma_write(t, cl.sender, n, bytes);
            done = done.max(verb.end);
        }
        fast.metrics.write_parts.add("rdma", done - t);
        for p in page..page + crate::pages_for(bytes) {
            fast.remote_ready.set(p);
        }
        fast.metrics.write_latency.record(done - now);
        Access {
            end: done,
            source: Source::Remote,
        }
    }

    // -- remote pressure (§3.5) ---------------------------------------

    /// A peer needs `bytes` of its donated memory back: select victims
    /// via the pluggable policy and migrate each one through the
    /// sender-driven protocol state machine; delete only as a last
    /// resort (no destination with room). Entirely slow-path state, so
    /// pressure handling never blocks shard fast paths.
    pub fn remote_pressure(
        &mut self,
        cl: &mut ClusterState,
        now: Ns,
        node: NodeId,
        bytes: u64,
    ) -> PressureOutcome {
        let mut out = PressureOutcome {
            done_at: now,
            ..Default::default()
        };
        let owner = self.owner_tag.unwrap_or(cl.sender);
        let mut t = now;
        while out.reclaimed_bytes < bytes {
            // Victim selection ON the pressured node via the pluggable
            // policy — activity-based by default: purely local metadata,
            // zero sender queries (§3.5). A tenant-tagged sender selects
            // only among its own blocks.
            let choice = {
                let selected = match self.owner_tag {
                    Some(tag) => {
                        let view = cl.mrpools[node].owned_by(tag);
                        self.victim_policy.select(&view, t)
                    }
                    None => self.victim_policy.select(&cl.mrpools[node], t),
                };
                match selected {
                    Some(c) => c,
                    None => break,
                }
            };
            t += choice.selection_cost; // zero for ActivityBased
            let block_bytes = cl.mrpools[node]
                .get(choice.block)
                .map(|b| b.bytes)
                .unwrap_or(self.units.unit_bytes);
            let unit_id = self.units.unit_of_block(node, choice.block);
            // Pick a destination: least-pressured other peer.
            let cands: Vec<_> = cl
                .candidates()
                .into_iter()
                .filter(|c| c.node != node && c.free_bytes >= block_bytes)
                .collect();
            let dst = cands
                .iter()
                .max_by_key(|c| c.free_bytes)
                .map(|c| c.node);
            match (unit_id, dst) {
                (Some(unit_id), Some(dst)) => {
                    // Drive the Figure-14 protocol state machine; every
                    // transition below mirrors an action the sender
                    // actually performs against the fabric model.
                    let mut sm = MigrationSm::new();
                    sm.on_event(MigEvent::PressureReport {
                        block: choice.block,
                        src: node,
                    })
                    .expect("fresh machine accepts a pressure report");
                    // QueryCandidates was performed above (cl.candidates).
                    let actions = sm
                        .on_event(MigEvent::DestChosen { dst })
                        .expect("destination differs from source");
                    let park_writes =
                        actions.contains(&MigAction::StopWrites);
                    debug_assert!(sm.writes_parked());
                    if let Some(b) = cl.mrpools[node].get_mut(choice.block) {
                        b.state = MrState::Migrating;
                    }
                    sm.on_event(MigEvent::PrepareAcked)
                        .expect("preparing accepts ack");
                    let mig = migration::simulate(
                        &mut cl.fabric,
                        &self.lat,
                        t,
                        cl.sender,
                        node,
                        dst,
                        block_bytes,
                        2,
                    );
                    // destination registers the block when the copy starts
                    let new_block = cl.mrpools[dst].register(
                        owner,
                        block_bytes,
                        mig.copy_start,
                    );
                    cl.mrpools[node].release(choice.block);
                    sm.on_event(MigEvent::CopyDone)
                        .expect("copying accepts copy-done");
                    let final_actions = sm
                        .on_event(MigEvent::CommitAcked)
                        .expect("committing accepts ack");
                    debug_assert!(final_actions
                        .contains(&MigAction::FlushParkedWrites));
                    debug_assert_eq!(sm.state(), MigState::Done);
                    // COMMIT: remap the unit's replica slot to dst; the
                    // parked-writes flush is modeled by the write lock
                    // expiring at mig.done.
                    let u = self.units.get_mut(unit_id).unwrap();
                    for (n, b) in
                        u.nodes.iter_mut().zip(u.blocks.iter_mut())
                    {
                        if *n == node && *b == choice.block {
                            *n = dst;
                            *b = new_block;
                        }
                    }
                    if park_writes {
                        u.wlocked_until = u.wlocked_until.max(mig.done);
                    }
                    out.migrated += 1;
                    out.reclaimed_bytes += block_bytes;
                    // source's memory is free once the copy is out
                    t = mig.copy_end;
                    out.done_at = out.done_at.max(mig.done);
                }
                _ => {
                    // No destination with room (or untracked block):
                    // last resort — delete like the baselines would.
                    cl.mrpools[node].release(choice.block);
                    if let Some(unit_id) = unit_id {
                        if let Some(u) = self.units.get_mut(unit_id) {
                            u.alive = false;
                        }
                    }
                    out.deleted += 1;
                    out.reclaimed_bytes += block_bytes;
                    out.done_at = out.done_at.max(t);
                }
            }
        }
        out
    }
}
