//! The shared **slow path**: the Remote Sender Thread (§4.1) plus every
//! piece of state the shards share — the unit map, placement, in-flight
//! RDMA batches, and the §3.5 eviction/migration machinery.
//!
//! One [`RemoteSender`] serves all shards: it drains their staging
//! queues through the coalescing batcher one batch at a time (the single
//! sender-thread timeline the paper describes), and hands completed
//! write sets back through per-shard mailboxes so each shard worker can
//! apply them to its own mempool without sharing it. Writes are thereby
//! serialized only within a shard; the sender serializes nothing but its
//! own CPU time.
//!
//! ## The reclaim pipeline (§3.5, pump-driven)
//!
//! Remote pressure no longer runs a migration start-to-finish inside the
//! pressure event. [`RemoteSender::remote_pressure`] only *selects*
//! victims and enqueues live [`MigrationSm`] instances into the
//! **migration table**; [`RemoteSender::advance_migrations`] — called
//! from every pump tick, interleaved with write batches — walks each
//! machine through PREPARE → copy → COMMIT at its own virtual-time
//! milestones. Up to `valet.max_concurrent_migrations` migrations (on
//! distinct blocks/peers) proceed concurrently; while one is in flight,
//! reads keep hitting the source (the unit map still points there until
//! COMMIT) and write batches targeting the migrating unit are parked in
//! the table and flushed to the destination when COMMIT lands. Delete
//! remains the last resort when no destination has room.
//! [`crate::migration::simulate`] survives as the test oracle for the
//! single-migration timeline (`tests/reclaim.rs`).

use std::collections::HashMap;

use crate::audit::{self, Law, Violation};
use crate::backends::{ClusterState, PressureOutcome, Unit, UnitMap};
use crate::config::{Config, LatencyConfig, ValetConfig};
use crate::coordinator::fast::ShardFastPath;
use crate::eviction::{ActivityBased, VictimPolicy};
use crate::migration::{ctrl_rtt, MigAction, MigEvent, MigState, MigrationSm};
use crate::mrpool::{MrBlockId, MrState};
use crate::placement::{Candidate, LeastPressured, Placement, PowerOfTwo};
use crate::queues::WriteSet;
use crate::replication::choose_replicas;
use crate::sim::{Ns, Server};
use crate::{NodeId, PAGE_SIZE};

/// One coalesced RDMA message in flight: completion time, the shard its
/// write sets belong to, and the sets themselves.
#[derive(Clone, Debug)]
struct Inflight {
    done: Ns,
    shard: usize,
    sets: Vec<WriteSet>,
}

/// Candidate peers the sender polls before choosing a migration
/// destination (the power-of-two query model the old one-shot path also
/// charged — one control RTT each, before writes park).
const MIG_QUERIES: u32 = 2;

/// One live migration in the sender's migration table: a [`MigrationSm`]
/// plus the virtual-time milestones of the phase it is currently in.
/// Advanced only by [`RemoteSender::advance_migrations`] (pump ticks).
struct ActiveMigration {
    /// The Figure-14 protocol machine.
    sm: MigrationSm,
    /// Address-space unit whose replica slot is moving.
    unit: u64,
    /// Node losing the block.
    src: NodeId,
    /// Victim MR block on `src`.
    src_block: MrBlockId,
    /// Block size (bytes copied, bytes reclaimed).
    block_bytes: u64,
    /// Victim selected / machine enqueued at this time.
    scheduled: Ns,
    /// Destination, chosen at activation (pressure-aware placement).
    dst: Option<NodeId>,
    /// Fresh MR block on `dst`, registered when the copy starts.
    dst_block: Option<MrBlockId>,
    /// Left the queue (got a concurrency slot) at this time.
    activated: Ns,
    /// Writes park from here (candidate queries done, PREPARE sent).
    park_from: Ns,
    /// Bulk copy src→dst milestones.
    copy_start: Ns,
    copy_end: Ns,
    /// Current phase's work completes at this time.
    phase_done: Ns,
    /// Write sets parked while the block migrates, with their owning
    /// shard; flushed to the destination at COMMIT.
    parked: Vec<(usize, WriteSet)>,
    /// Total bytes parked (sizing the flush message).
    parked_bytes: u64,
}

impl ActiveMigration {
    /// Holds a concurrency slot: the machine left `ChoosingDest` (its
    /// destination is chosen, PREPARE is out). Derived from the state
    /// machine so it can never drift from the protocol.
    fn is_active(&self) -> bool {
        self.sm.state() != MigState::ChoosingDest
    }
}

/// Milestones of one completed migration (diagnostics + the
/// `tests/reclaim.rs` oracle pin against [`crate::migration::simulate`]).
#[derive(Clone, Copy, Debug)]
pub struct MigrationRecord {
    /// Address-space unit that moved.
    pub unit: u64,
    /// Source peer.
    pub src: NodeId,
    /// Destination peer.
    pub dst: NodeId,
    /// Bytes moved.
    pub block_bytes: u64,
    /// Victim selected at this time.
    pub scheduled: Ns,
    /// Concurrency slot acquired (candidate queries start here).
    pub activated: Ns,
    /// Writes parked from here (Figure 12's window opens).
    pub park_from: Ns,
    /// Bulk copy milestones.
    pub copy_start: Ns,
    /// Copy finished; source memory free from here.
    pub copy_end: Ns,
    /// COMMIT acked; unit remapped, parked writes flushed.
    pub done: Ns,
    /// Write sets that parked against this migration and flushed at
    /// COMMIT.
    pub parked_flushed: u64,
}

/// Aggregate reclaim-pipeline counters (slow-path global — migrations
/// belong to the shared sender, not to any one shard's `RunMetrics`).
#[derive(Clone, Copy, Debug, Default)]
pub struct MigStats {
    /// Migrations enqueued by pressure episodes.
    pub started: u64,
    /// Migrations that reached COMMIT.
    pub completed: u64,
    /// Victims deleted instead (no destination with room).
    pub deleted: u64,
    /// Write sets parked against in-flight migrations.
    pub parked_sets: u64,
    /// Parked write sets flushed to their destination at COMMIT.
    pub flushed_sets: u64,
    /// Virtual time two migrations spent concurrently in flight, summed
    /// pairwise — the `reclaim` experiment's overlap evidence (0 under
    /// `max_concurrent_migrations = 1`).
    pub overlap_ns: Ns,
}

/// The shared remote-sender slow path (see module docs).
pub struct RemoteSender {
    lat: LatencyConfig,
    vcfg: ValetConfig,
    /// Remote sender thread's timeline (one batch in service at a time;
    /// batches pipeline on the NIC beneath it).
    thread: Server,
    units: UnitMap,
    /// Pluggable placement hook (§4.3; power-of-two choices by default).
    placement: Box<dyn Placement + Send>,
    inflight: Vec<Inflight>,
    /// Per-shard completion mailboxes: durable write sets waiting for
    /// their owning shard to apply them (FIFO per shard).
    done: Vec<Vec<WriteSet>>,
    /// Pluggable eviction hook (§3.5; activity-based by default).
    victim_policy: Box<dyn VictimPolicy + Send>,
    /// Owner id stamped on MR registrations (multi-tenant arbitration);
    /// `None` registers as the sender node.
    owner_tag: Option<NodeId>,
    /// In-flight remote reads, page → completion time: a miss that
    /// overlaps an outstanding fetch of the same page *in virtual time*
    /// (queue-depth > 1 block I/O, simulated multi-client runs)
    /// piggybacks on it (miss coalescing) instead of posting a
    /// duplicate RDMA READ, and a readahead proposal covering the page
    /// free-rides on it without posting any wire work. Note the sharded
    /// serve front-end routes a page to one worker whose virtual clock
    /// advances past each completion before the next request, so
    /// cross-request coalescing there is rare by construction — the
    /// table's main consumers are overlapping in-flight windows and the
    /// prefetcher. Entries whose completion has passed are pruned
    /// lazily.
    inflight_reads: HashMap<u64, Ns>,
    /// The migration table: live protocol machines advanced on pump
    /// ticks (see the module docs).
    migs: Vec<ActiveMigration>,
    /// Milestones of completed migrations, in completion order.
    mig_records: Vec<MigrationRecord>,
    /// Aggregate reclaim counters.
    mig_stats: MigStats,
    /// Destination policy for migrations (§3.5 "less-pressured peer");
    /// defaults to [`LeastPressured`], separate from the unit-mapping
    /// placement hook so swapping one never perturbs the other.
    reclaim_placement: Box<dyn Placement + Send>,
    /// A queued migration may activate no earlier than this (the last
    /// time a concurrency slot freed) — keeps serialized mode
    /// (`max_concurrent_migrations = 1`) strictly back-to-back.
    mig_slot_free: Ns,
    /// Audit crossings seen (drives the every-Nth thorough sweep; only
    /// advanced when [`audit::enabled`]).
    audit_tick: u64,
}

/// Prune the in-flight read table once it reaches this size (stale
/// entries — completions in the past — are dropped; live ones kept).
const INFLIGHT_READS_PRUNE: usize = 4096;

impl RemoteSender {
    /// Build the slow path for `shards` fast paths.
    pub fn new(cfg: &Config, shards: usize) -> Self {
        RemoteSender {
            lat: cfg.latency.clone(),
            vcfg: cfg.valet.clone(),
            thread: Server::new(),
            units: UnitMap::new(cfg.valet.mr_block_bytes),
            placement: Box::new(PowerOfTwo::new(cfg.cluster.seed)),
            inflight: Vec::new(),
            done: vec![Vec::new(); shards.max(1)],
            victim_policy: Box::new(ActivityBased),
            owner_tag: None,
            inflight_reads: HashMap::new(),
            migs: Vec::new(),
            mig_records: Vec::new(),
            mig_stats: MigStats::default(),
            reclaim_placement: Box::new(LeastPressured::new()),
            mig_slot_free: 0,
            audit_tick: 0,
        }
    }

    // -- configuration hooks ------------------------------------------

    /// Tag MR registrations with a distinct owner id (multi-tenant
    /// arbitration: victim selection under remote pressure then only
    /// ever sees this tenant's blocks).
    pub fn set_owner_tag(&mut self, owner: NodeId) {
        self.owner_tag = Some(owner);
    }

    /// Swap in a different eviction policy (the §3.5 hook).
    pub fn set_victim_policy(&mut self, policy: Box<dyn VictimPolicy + Send>) {
        self.victim_policy = policy;
    }

    /// Swap in a different placement policy (the §4.3 hook).
    pub fn set_placement(&mut self, placement: Box<dyn Placement + Send>) {
        self.placement = placement;
    }

    /// Swap in a different migration-destination policy (the §3.5
    /// "less-pressured peer" hook; [`LeastPressured`] by default).
    pub fn set_reclaim_placement(
        &mut self,
        placement: Box<dyn Placement + Send>,
    ) {
        self.reclaim_placement = placement;
    }

    // -- diagnostics --------------------------------------------------

    /// The latency model the whole pipeline is calibrated to.
    pub fn lat(&self) -> &LatencyConfig {
        &self.lat
    }

    /// Valet policy knobs.
    pub fn vcfg(&self) -> &ValetConfig {
        &self.vcfg
    }

    /// The remote address-space unit map.
    pub fn units(&self) -> &UnitMap {
        &self.units
    }

    /// Name of the active eviction policy.
    pub fn victim_policy_name(&self) -> &'static str {
        self.victim_policy.name()
    }

    /// When the sender thread is next idle.
    pub fn busy_until(&self) -> Ns {
        self.thread.busy_until()
    }

    /// Write sets carried by in-flight RDMA batches plus durable sets
    /// not yet applied by their shard.
    pub fn inflight_write_sets(&self) -> usize {
        self.inflight.iter().map(|f| f.sets.len()).sum::<usize>()
            + self.done.iter().map(|d| d.len()).sum::<usize>()
    }

    /// Earliest completion among in-flight batches carrying `shard`'s
    /// write sets.
    pub fn inflight_min_done(&self, shard: usize) -> Option<Ns> {
        self.inflight
            .iter()
            .filter(|f| f.shard == shard)
            .map(|f| f.done)
            .min()
    }

    /// Migrations currently in the table (queued + in flight).
    pub fn migrations_inflight(&self) -> usize {
        self.migs.len()
    }

    /// Aggregate reclaim-pipeline counters.
    pub fn migration_stats(&self) -> MigStats {
        self.mig_stats
    }

    /// Milestones of completed migrations, in completion order.
    pub fn migration_records(&self) -> &[MigrationRecord] {
        &self.mig_records
    }

    // -- the sender-thread pipeline -----------------------------------

    /// Ensure `unit` has a remote mapping; returns when it is usable.
    /// Charged on the *sender thread* timeline — never the request path.
    fn ensure_unit(&mut self, cl: &mut ClusterState, now: Ns, unit: u64) -> Ns {
        if let Some(u) = self.units.get(unit) {
            if u.alive {
                return u.ready_at;
            }
        }
        // (Re)map: pick primary via the placement hook, then replicas.
        let cands = cl.candidates();
        let primary = self
            .placement
            .pick(&cands)
            .expect("cluster has at least one peer");
        let cand_nodes: Vec<NodeId> = cands.iter().map(|c| c.node).collect();
        let nodes = choose_replicas(
            cl.sender,
            primary,
            &cand_nodes,
            self.vcfg.replicas.max(1),
        );
        // Connection (if new) + mapping, charged sequentially per node.
        let mut t = now;
        for &n in &nodes {
            let (tc, _newc) = cl.fabric.ensure_connected(t, cl.sender, n);
            t = cl.fabric.map_mr(tc, cl.sender);
        }
        let owner = self.owner_tag.unwrap_or(cl.sender);
        let blocks = nodes
            .iter()
            .map(|&n| cl.mrpools[n].register(owner, self.units.unit_bytes, t))
            .collect();
        self.units.insert(
            unit,
            Unit {
                nodes,
                blocks,
                ready_at: t,
                wlocked_until: 0,
                alive: true,
            },
        );
        t
    }

    /// Apply completions of in-flight RDMA batches up to `now`: stamp
    /// activity tags on the primary blocks and move each completed write
    /// set into its shard's mailbox (the owning shard applies it via
    /// [`ShardFastPath::apply_durable`] when it next drains the mailbox).
    pub fn complete_inflight(&mut self, cl: &mut ClusterState, now: Ns) {
        let mut i = 0;
        while i < self.inflight.len() {
            if self.inflight[i].done <= now {
                let inflight = self.inflight.swap_remove(i);
                for ws in inflight.sets {
                    // stamp activity tags on the primary block
                    let unit = self.units.unit_of(ws.page);
                    if let Some(u) = self.units.get(unit) {
                        if let (Some(&n), Some(&b)) =
                            (u.nodes.first(), u.blocks.first())
                        {
                            cl.mrpools[n].touch_write(b, inflight.done);
                        }
                    }
                    self.done[inflight.shard].push(ws);
                }
            } else {
                i += 1;
            }
        }
    }

    /// Drain `shard`'s completion mailbox (FIFO).
    pub fn take_done(&mut self, shard: usize) -> Vec<WriteSet> {
        std::mem::take(&mut self.done[shard])
    }

    // -- the read-side pipeline ---------------------------------------

    /// If `page` has an outstanding remote fetch completing *after*
    /// `now`, return its completion time — the caller piggybacks on it
    /// (miss coalescing) instead of posting a duplicate READ. An entry
    /// whose completion has passed is pruned and `None` returned: the
    /// fetched data was never installed locally (remote reads are
    /// read-through), so a later miss must fetch again.
    pub fn inflight_read_done(&mut self, page: u64, now: Ns) -> Option<Ns> {
        match self.inflight_reads.get(&page) {
            Some(&done) if done > now => Some(done),
            Some(_) => {
                self.inflight_reads.remove(&page);
                None
            }
            None => None,
        }
    }

    /// Record an outstanding remote read of `page` completing at
    /// `done`, so overlapping misses on the same page can coalesce.
    pub fn note_inflight_read(&mut self, now: Ns, page: u64, done: Ns) {
        if self.inflight_reads.len() >= INFLIGHT_READS_PRUNE {
            self.inflight_reads.retain(|_, d| *d > now);
        }
        self.inflight_reads.insert(page, done);
    }

    /// Outstanding remote reads tracked for coalescing (diagnostics;
    /// includes entries not yet lazily pruned).
    pub fn inflight_read_count(&self) -> usize {
        self.inflight_reads.len()
    }

    /// Batched remote read: fetch `pages` (grouped into runs that share
    /// an address-space unit) with **one** RDMA READ per unit — one
    /// base round trip plus per-page wire time, mirroring the write
    /// side's coalescing batcher — and register every page in the
    /// in-flight read table. `out` is filled (cleared first) with each
    /// page's completion time, in input order; a page whose unit is
    /// unmapped or dead completes "immediately" at `t0` (the caller
    /// filters those up front — this keeps the batch robust). Returns
    /// the completion time of the slowest run, `t0` when `pages` is
    /// empty.
    ///
    /// Callers decide what the batch means: the demand block-read path
    /// (`demand = true`) waits on the result and stamps the primary
    /// block's read-activity tag — §3.5's victim ranking then sees read
    /// phases — while the prefetcher (`demand = false`) treats it as
    /// asynchronous readahead, records only the arrival times, and
    /// leaves the tag alone: a speculative fetch becomes activity only
    /// when a later demand hit consumes it, so prefetched-but-unused
    /// blocks stay first in line as victims.
    pub fn read_batch(
        &mut self,
        cl: &mut ClusterState,
        t0: Ns,
        pages: &[u64],
        demand: bool,
        out: &mut Vec<(u64, Ns)>,
    ) -> Ns {
        out.clear();
        let mut slowest = t0;
        let mut i = 0;
        while i < pages.len() {
            // one run = consecutive input pages sharing a unit
            let unit = self.units.unit_of(pages[i]);
            let mut j = i + 1;
            while j < pages.len() && self.units.unit_of(pages[j]) == unit {
                j += 1;
            }
            let run = &pages[i..j];
            let (primary, block, ready) = match self.units.get(unit) {
                Some(u) if u.alive => (u.nodes[0], u.blocks[0], u.ready_at),
                _ => {
                    for &p in run {
                        out.push((p, t0));
                    }
                    i = j;
                    continue;
                }
            };
            let t = t0.max(ready) + self.lat.mrpool_get;
            let bytes = run.len() as u64 * PAGE_SIZE;
            let verb = cl.fabric.rdma_read(t, cl.sender, primary, bytes);
            if demand {
                cl.mrpools[primary].touch_read(block, verb.end);
            }
            for &p in run {
                self.note_inflight_read(t0, p, verb.end);
                out.push((p, verb.end));
            }
            slowest = slowest.max(verb.end);
            i = j;
        }
        slowest
    }

    /// Send one coalesced batch from `fast`'s staging queue at (no
    /// earlier than) `t0`; returns its completion time. Coalescing only
    /// merges write sets that target the same address-space unit (one
    /// RDMA message lands in one MR block).
    pub fn send_one_batch(
        &mut self,
        cl: &mut ClusterState,
        t0: Ns,
        shard: usize,
        fast: &mut ShardFastPath,
    ) -> Ns {
        debug_assert!(!fast.staging.is_empty());
        let max = if self.vcfg.coalescing {
            self.vcfg.rdma_msg_bytes
        } else {
            1 // force single write set per message
        };
        let unit = self
            .units
            .unit_of(
                fast.staging
                    .peek()
                    .expect("caller checked staging is non-empty")
                    .page,
            );
        // §3.5 write parking: a batch whose unit is mid-migration (STOP
        // writes sent with PREPARE) moves into the migration table
        // instead of the wire, and flushes to the destination at COMMIT.
        // Costs queue movement only — no sender-thread time, no verb.
        if let Some(mig_idx) = self
            .migs
            .iter()
            .position(|m| m.unit == unit && m.sm.writes_parked())
        {
            let mut parked = 0u64;
            let mut parked_bytes = 0u64;
            while let Some(front) = fast.staging.peek() {
                if self.units.unit_of(front.page) != unit {
                    break;
                }
                let ws = fast
                    .staging
                    .pop()
                    .expect("peek just returned this front");
                if self.vcfg.disk_backup {
                    for p in ws.page..ws.page + ws.pages() {
                        fast.disk_valid.set(p);
                    }
                }
                parked_bytes += ws.bytes;
                let m = &mut self.migs[mig_idx];
                m.parked_bytes += ws.bytes;
                m.parked.push((shard, ws));
                parked += 1;
            }
            // Table 3: the disk backup covers parked batches exactly
            // like sent ones — the backup write goes out now, off the
            // critical path, not at the COMMIT flush
            if parked > 0 && self.vcfg.disk_backup {
                cl.disks[cl.sender].write_async(t0, parked_bytes);
                fast.metrics.disk_writes += 1;
            }
            self.mig_stats.parked_sets += parked;
            return t0;
        }
        let mut batch = Vec::new();
        let mut bytes = 0u64;
        while let Some(front) = fast.staging.peek() {
            let same_unit = self.units.unit_of(front.page) == unit;
            if !batch.is_empty() && (bytes + front.bytes > max || !same_unit)
            {
                break;
            }
            let ws = fast.staging.pop().expect("peeked front exists");
            bytes += ws.bytes;
            batch.push(ws);
        }
        // mapping (behind the mempool — charged here, on sender thread)
        let ready = self.ensure_unit(cl, t0, unit);
        let u = self
            .units
            .get(unit)
            .expect("ensure_unit mapped this unit");
        let mut t = t0.max(ready).max(u.wlocked_until);
        // mrpool get + one-sided write per replica (queue on our NIC)
        t += self.lat.mrpool_get;
        let nodes = u.nodes.clone();
        let mut done = t;
        for &n in &nodes {
            let verb = cl.fabric.rdma_write(t, cl.sender, n, bytes);
            done = done.max(verb.end);
        }
        // optional disk backup, off the critical path
        if self.vcfg.disk_backup {
            cl.disks[cl.sender].write_async(t, bytes);
            for ws in &batch {
                for p in ws.page..ws.page + ws.pages() {
                    fast.disk_valid.set(p);
                }
            }
            fast.metrics.disk_writes += 1;
        }
        // The sender thread is busy only for its CPU work (mapping waits
        // + mrpool get + posting the WQE, ~300 ns); the verb completes
        // asynchronously on the NIC (tracked via `inflight`), so many
        // messages pipeline — and un-coalesced small messages flood the
        // WQE cache, which is exactly the §3.3 argument for batching.
        let post_done = t + 300;
        self.thread.serve(t0, post_done.saturating_sub(t0));
        self.inflight.push(Inflight {
            done,
            shard,
            sets: batch,
        });
        done
    }

    /// Synchronous write (Valet-RemoteOnly ablation): radix + copy + wait
    /// for the RDMA send like Infiniswap, but keep coalescing disabled
    /// and no disk redirect (mapping stalls the request instead).
    pub fn write_sync(
        &mut self,
        cl: &mut ClusterState,
        now: Ns,
        page: u64,
        bytes: u64,
        fast: &mut ShardFastPath,
    ) -> crate::backends::Access {
        use crate::backends::{Access, Source};
        let mut t = now + self.lat.radix_insert;
        fast.metrics.write_parts.add("radix", self.lat.radix_insert);
        let unit = self.units.unit_of(page);
        let ready = self.ensure_unit(cl, t, unit);
        if ready > t {
            fast.metrics.write_parts.add("mapping", ready - t);
            t = ready;
        }
        let copy = self.lat.copy(bytes);
        t += copy;
        fast.metrics.write_parts.add("copy", copy);
        let u = self
            .units
            .get(unit)
            .expect("ensure_unit mapped this unit");
        let nodes = u.nodes.clone();
        let mut done = t + self.lat.mrpool_get;
        for &n in &nodes {
            let verb = cl.fabric.rdma_write(t, cl.sender, n, bytes);
            done = done.max(verb.end);
        }
        fast.metrics.write_parts.add("rdma", done - t);
        for p in page..page + crate::pages_for(bytes) {
            fast.remote_ready.set(p);
        }
        fast.metrics.write_latency.record(done - now);
        Access {
            end: done,
            source: Source::Remote,
        }
    }

    // -- remote pressure (§3.5): the reclaim pipeline -----------------

    /// A peer needs `bytes` of its donated memory back: select victims
    /// via the pluggable policy and **enqueue** one live [`MigrationSm`]
    /// per victim into the migration table — the pump drives the
    /// protocol from here ([`Self::advance_migrations`]); this call
    /// never blocks on wire time. Delete stays the synchronous last
    /// resort when no destination has room. The returned outcome counts
    /// bytes *committed to reclaim* (blocks are victim-marked
    /// immediately, so the pressured node's pool stops considering
    /// them); `done_at` is when victim selection finished. A queued
    /// migration whose destinations all fill up before it activates
    /// degrades to delete at activation — `migrated` counts
    /// initiations; [`Self::migration_stats`] reconciles the final
    /// split.
    pub fn remote_pressure(
        &mut self,
        cl: &mut ClusterState,
        now: Ns,
        node: NodeId,
        bytes: u64,
    ) -> PressureOutcome {
        let mut out = PressureOutcome {
            done_at: now,
            ..Default::default()
        };
        // Bytes already committed to reclaim on this node by earlier
        // episodes but not yet released (the source block frees only
        // when its copy completes, so the caller's `registered_bytes`-
        // based demand still counts them — without this credit a
        // second pressure wave arriving mid-copy would select surplus
        // victims for memory that is already on its way out).
        let pending: u64 = self
            .migs
            .iter()
            .filter(|m| {
                m.src == node
                    && matches!(
                        m.sm.state(),
                        MigState::ChoosingDest
                            | MigState::Preparing
                            | MigState::Copying
                    )
            })
            .map(|m| m.block_bytes)
            .sum();
        let bytes = bytes.saturating_sub(pending);
        let mut t = now;
        while out.reclaimed_bytes < bytes {
            // Victim selection ON the pressured node via the pluggable
            // policy — activity-based by default: purely local metadata,
            // zero sender queries (§3.5). A tenant-tagged sender selects
            // only among its own blocks. Blocks already migrating are
            // never re-selected (their MrState filters them out).
            let choice = {
                let selected = match self.owner_tag {
                    Some(tag) => {
                        let view = cl.mrpools[node].owned_by(tag);
                        self.victim_policy.select(&view, t)
                    }
                    None => self.victim_policy.select(&cl.mrpools[node], t),
                };
                match selected {
                    Some(c) => c,
                    None => break,
                }
            };
            t += choice.selection_cost; // zero for ActivityBased
            let block_bytes = cl.mrpools[node]
                .get(choice.block)
                .map(|b| b.bytes)
                .unwrap_or(self.units.unit_bytes);
            let unit_id = self.units.unit_of_block(node, choice.block);
            let has_dst = unit_id
                .map(|u| self.has_reclaim_candidate(cl, u, node, block_bytes))
                .unwrap_or(false);
            match unit_id {
                Some(unit_id) if has_dst => {
                    // Enqueue a live protocol machine; destination
                    // choice (pressure-aware) happens at activation,
                    // when the migration takes a concurrency slot.
                    let mut sm = MigrationSm::new();
                    sm.on_event(MigEvent::PressureReport {
                        block: choice.block,
                        src: node,
                    })
                    .expect("fresh machine accepts a pressure report");
                    if let Some(b) = cl.mrpools[node].get_mut(choice.block)
                    {
                        b.state = MrState::Migrating;
                    }
                    self.migs.push(ActiveMigration {
                        sm,
                        unit: unit_id,
                        src: node,
                        src_block: choice.block,
                        block_bytes,
                        scheduled: t,
                        dst: None,
                        dst_block: None,
                        activated: 0,
                        park_from: 0,
                        copy_start: 0,
                        copy_end: 0,
                        phase_done: 0,
                        parked: Vec::new(),
                        parked_bytes: 0,
                    });
                    self.mig_stats.started += 1;
                    out.migrated += 1;
                    out.reclaimed_bytes += block_bytes;
                    out.done_at = out.done_at.max(t);
                }
                _ => {
                    // No destination with room (or untracked block):
                    // last resort — delete like the baselines would.
                    self.delete_victim(cl, node, choice.block, unit_id);
                    out.deleted += 1;
                    out.reclaimed_bytes += block_bytes;
                    out.done_at = out.done_at.max(t);
                }
            }
        }
        out
    }

    /// The delete last-resort (§3.5 "delete like the baselines"):
    /// release the victim block and drop its replica slot from the unit
    /// map. Surviving replicas keep serving reads (Table 3: replica
    /// first); only when the last copy is gone does the unit die and
    /// reads fall through to the disk backup (or are lost).
    fn delete_victim(
        &mut self,
        cl: &mut ClusterState,
        node: NodeId,
        block: MrBlockId,
        unit_id: Option<u64>,
    ) {
        cl.mrpools[node].release(block);
        if let Some(uid) = unit_id {
            if let Some(u) = self.units.get_mut(uid) {
                if let Some(pos) = u
                    .nodes
                    .iter()
                    .zip(u.blocks.iter())
                    .position(|(&n, &b)| n == node && b == block)
                {
                    u.nodes.remove(pos);
                    u.blocks.remove(pos);
                }
                if u.nodes.is_empty() {
                    u.alive = false;
                }
            }
        }
        self.mig_stats.deleted += 1;
    }

    /// Bytes other pending migrations have promised to `node` (their MR
    /// blocks register only when their copy starts, so raw free bytes
    /// would over-commit a popular peer).
    fn reserved_on(&self, node: NodeId) -> u64 {
        self.migs
            .iter()
            .filter(|m| m.dst == Some(node) && m.dst_block.is_none())
            .map(|m| m.block_bytes)
            .sum()
    }

    /// THE destination filter, shared by the list builder and the
    /// cheap existence check so the two can never drift: a candidate
    /// must not be the source or one of the unit's replica holders,
    /// must not already be the destination of another in-flight
    /// migration of the same unit (replica distinctness), and must
    /// have room for the block after reservations.
    fn reclaim_candidate_ok(
        &self,
        c: &Candidate,
        unit: u64,
        src: NodeId,
        block_bytes: u64,
        holders: &[NodeId],
    ) -> bool {
        c.node != src
            && !holders.contains(&c.node)
            && !self
                .migs
                .iter()
                .any(|m| m.unit == unit && m.dst == Some(c.node))
            && c.free_bytes.saturating_sub(self.reserved_on(c.node))
                >= block_bytes
    }

    fn unit_holders(&self, unit: u64) -> &[NodeId] {
        self.units
            .get(unit)
            .map(|u| u.nodes.as_slice())
            .unwrap_or(&[])
    }

    /// Admission check `remote_pressure` runs per victim: some peer
    /// must fit this block, AND the candidates' aggregate spare
    /// capacity must also cover every *queued* migration that has not
    /// chosen a destination yet (those reserve nothing per-peer, so
    /// without the aggregate term N victims could all be admitted
    /// against one slot of free space and N−1 would silently degrade
    /// to deletes at activation).
    fn has_reclaim_candidate(
        &self,
        cl: &ClusterState,
        unit: u64,
        src: NodeId,
        block_bytes: u64,
    ) -> bool {
        let holders = self.unit_holders(unit);
        let queued: u64 = self
            .migs
            .iter()
            .filter(|m| m.dst.is_none())
            .map(|m| m.block_bytes)
            .sum();
        let mut fits_somewhere = false;
        let mut spare = 0u64;
        for c in cl.candidates() {
            if !self.reclaim_candidate_ok(&c, unit, src, 0, holders) {
                continue;
            }
            let free = c.free_bytes.saturating_sub(self.reserved_on(c.node));
            if free >= block_bytes {
                fits_somewhere = true;
            }
            spare += free;
        }
        fits_somewhere && spare >= queued.saturating_add(block_bytes)
    }

    /// Destination candidates for migrating `unit` off `src` (see
    /// [`Self::reclaim_candidate_ok`] for the filter), with the
    /// reserved bytes already subtracted so the placement policy ranks
    /// peers by what they can actually still take.
    fn reclaim_candidates(
        &self,
        cl: &ClusterState,
        unit: u64,
        src: NodeId,
        block_bytes: u64,
    ) -> Vec<Candidate> {
        let holders = self.unit_holders(unit);
        cl.candidates()
            .into_iter()
            .filter(|c| {
                self.reclaim_candidate_ok(c, unit, src, block_bytes, holders)
            })
            .map(|mut c| {
                c.free_bytes =
                    c.free_bytes.saturating_sub(self.reserved_on(c.node));
                c
            })
            .collect()
    }

    /// The migration table's earliest actionable event: `(time, index,
    /// is_activation)` — a queued machine that could take a free
    /// concurrency slot, or the active machine whose phase completes
    /// first. THE selection rule, shared by the advance loop and the
    /// backpressure probe so the two can never drift.
    fn next_migration_action(&self) -> Option<(Ns, usize, bool)> {
        let cap = self.vcfg.max_concurrent_migrations.max(1);
        let active = self.migs.iter().filter(|m| m.is_active()).count();
        let mut next: Option<(Ns, usize, bool)> = None;
        if active < cap {
            if let Some(i) =
                self.migs.iter().position(|m| !m.is_active())
            {
                let t = self.migs[i].scheduled.max(self.mig_slot_free);
                next = Some((t, i, true));
            }
        }
        for (i, m) in self.migs.iter().enumerate() {
            if !m.is_active() {
                continue;
            }
            let earlier = match next {
                Some((t, _, _)) => m.phase_done < t,
                None => true,
            };
            if earlier {
                next = Some((m.phase_done, i, false));
            }
        }
        next
    }

    /// Earliest virtual time at which the migration table has work to
    /// do (a queued machine that could activate, or an active phase
    /// completing). `None` when the table is empty. Used by the
    /// backpressure path to force progress instead of spinning.
    pub fn next_migration_event(&self) -> Option<Ns> {
        self.next_migration_action().map(|(t, _, _)| t)
    }

    /// Advance every migration in the table up to `now`: activate
    /// queued machines while concurrency slots are free, and walk each
    /// active machine through its due phase transitions (PREPARE ack →
    /// copy → COPY_DONE → COMMIT). Called from the pump/driver paths,
    /// interleaved with write batches, so reclaim overlaps demand
    /// traffic instead of blocking it. No-op when the table is empty.
    pub fn advance_migrations(&mut self, cl: &mut ClusterState, now: Ns) {
        let mut stepped = false;
        while let Some((t, i, activation)) = self.next_migration_action() {
            if t > now {
                break;
            }
            if activation {
                self.activate_migration(cl, i, t);
            } else {
                self.step_migration(cl, i);
            }
            stepped = true;
        }
        // Migration-milestone audit: every activation/phase/commit that
        // just fired re-proves the table's conservation laws. The
        // replica sweep over the whole unit map piggybacks on every
        // 64th crossing (see `audit_check`). Compiled away in release
        // builds without the `audit` feature.
        if audit::enabled() && (stepped || !self.migs.is_empty()) {
            self.audit_tick = self.audit_tick.wrapping_add(1);
            let thorough = self.audit_tick % 64 == 0;
            audit::enforce(&self.audit_check(cl, thorough));
        }
    }

    /// Give migration `i` its concurrency slot at `t_act`: poll
    /// candidates (one control RTT each), choose the destination
    /// through the pressure-aware placement hook, park writes
    /// (StopWrites fires with the DestChosen transition) and send
    /// PREPARE. Falls back to delete if every candidate filled up while
    /// the migration was queued.
    fn activate_migration(
        &mut self,
        cl: &mut ClusterState,
        i: usize,
        t_act: Ns,
    ) {
        let rtt = ctrl_rtt(&self.lat);
        let (unit, src, block_bytes) = {
            let m = &self.migs[i];
            (m.unit, m.src, m.block_bytes)
        };
        let cands = self.reclaim_candidates(cl, unit, src, block_bytes);
        let dst = self.reclaim_placement.pick(&cands);
        let Some(dst) = dst else {
            // every candidate filled up while we were queued: delete
            // (surviving replicas, if any, keep serving reads)
            let m = self.migs.remove(i);
            self.delete_victim(cl, m.src, m.src_block, Some(m.unit));
            self.mig_slot_free = self.mig_slot_free.max(t_act);
            return;
        };
        let m = &mut self.migs[i];
        let actions = m
            .sm
            .on_event(MigEvent::DestChosen { dst })
            .expect("destination differs from source");
        debug_assert!(actions.contains(&MigAction::StopWrites));
        debug_assert!(m.sm.writes_parked());
        m.dst = Some(dst);
        m.activated = t_act;
        // candidate queries (serialized control RTTs), then PREPARE to
        // src and dst in parallel, bounded by the slower ack — the
        // identical charge sequence as the `migration::simulate` oracle
        m.park_from = t_act + rtt * MIG_QUERIES as Ns;
        let (c1, _) = cl.fabric.ensure_connected(m.park_from, cl.sender, src);
        let (c2, _) = cl.fabric.ensure_connected(m.park_from, cl.sender, dst);
        m.phase_done = c1.max(c2) + rtt;
    }

    /// Fire the phase transition of active migration `i` that completes
    /// at `migs[i].phase_done`.
    fn step_migration(&mut self, cl: &mut ClusterState, i: usize) {
        let rtt = ctrl_rtt(&self.lat);
        let owner = self.owner_tag.unwrap_or(cl.sender);
        let state = self.migs[i].sm.state();
        match state {
            MigState::Preparing => {
                let m = &mut self.migs[i];
                m.sm
                    .on_event(MigEvent::PrepareAcked)
                    .expect("preparing accepts ack");
                let dst = m.dst.expect("active migration has dst");
                // src↔dst connection for the copy (may be new), then
                // the bulk copy on the source's NIC; the destination
                // registers its fresh MR block when the copy starts
                let (t_conn, _) =
                    cl.fabric.ensure_connected(m.phase_done, m.src, dst);
                m.copy_start = t_conn;
                m.dst_block = Some(cl.mrpools[dst].register(
                    owner,
                    m.block_bytes,
                    m.copy_start,
                ));
                let verb = cl.fabric.rdma_write(
                    m.copy_start,
                    m.src,
                    dst,
                    m.block_bytes,
                );
                m.copy_end = verb.end;
                m.phase_done = m.copy_end;
            }
            MigState::Copying => {
                let m = &mut self.migs[i];
                m.sm
                    .on_event(MigEvent::CopyDone)
                    .expect("copying accepts copy-done");
                // source's memory is free once the copy is out
                cl.mrpools[m.src].release(m.src_block);
                m.phase_done = m.copy_end + 2 * rtt;
            }
            MigState::Committing => self.commit_migration(cl, i),
            s => unreachable!("active migration in phase {s:?}"),
        }
    }

    /// COMMIT acked: remap the unit's replica slot to the destination,
    /// validate the replica set through [`choose_replicas`], flush
    /// parked write sets to the new location and retire the machine.
    fn commit_migration(&mut self, cl: &mut ClusterState, i: usize) {
        let mut m = self.migs.remove(i);
        let done = m.phase_done;
        let actions = m
            .sm
            .on_event(MigEvent::CommitAcked)
            .expect("committing accepts ack");
        debug_assert!(actions.contains(&MigAction::FlushParkedWrites));
        debug_assert_eq!(m.sm.state(), MigState::Done);
        let dst = m.dst.expect("active migration has dst");
        let dst_block = m.dst_block.expect("copy registered the block");
        let mut flush_nodes = vec![dst];
        if let Some(u) = self.units.get_mut(m.unit) {
            for (n, b) in u.nodes.iter_mut().zip(u.blocks.iter_mut()) {
                if *n == m.src && *b == m.src_block {
                    *n = dst;
                    *b = dst_block;
                }
            }
            // Remap validated through the §5.1 chooser: same primary,
            // distinct followers, sender skipped. The destination
            // filter in `reclaim_candidates` guarantees the swapped
            // set already satisfies it; pinning it to choose_replicas
            // keeps this path and the mapping path on one invariant.
            debug_assert_eq!(
                choose_replicas(cl.sender, u.nodes[0], &u.nodes, u.nodes.len()),
                u.nodes,
                "replica set must stay distinct across a remap"
            );
            u.wlocked_until = u.wlocked_until.max(done);
            flush_nodes = u.nodes.clone();
        }
        // FlushParkedWrites: one coalesced message per replica carrying
        // everything that parked during the migration; completions land
        // in the owning shards' mailboxes like any other batch.
        let parked_flushed = m.parked.len() as u64;
        if !m.parked.is_empty() {
            let t = done + self.lat.mrpool_get;
            let mut flush_done = t;
            for &n in &flush_nodes {
                let verb =
                    cl.fabric.rdma_write(t, cl.sender, n, m.parked_bytes);
                flush_done = flush_done.max(verb.end);
            }
            self.mig_stats.flushed_sets += m.parked.len() as u64;
            let mut by_shard: Vec<(usize, Vec<WriteSet>)> = Vec::new();
            for (shard, ws) in m.parked.drain(..) {
                match by_shard.iter_mut().find(|(s, _)| *s == shard) {
                    Some((_, sets)) => sets.push(ws),
                    None => by_shard.push((shard, vec![ws])),
                }
            }
            for (shard, sets) in by_shard {
                self.inflight.push(Inflight {
                    done: flush_done,
                    shard,
                    sets,
                });
            }
        }
        // pairwise overlap accounting: credit each concurrent pair once,
        // at the earlier completion (the other machine is still active)
        for other in self.migs.iter().filter(|o| o.is_active()) {
            let both_from = m.activated.max(other.activated);
            if done > both_from {
                self.mig_stats.overlap_ns += done - both_from;
            }
        }
        self.mig_stats.completed += 1;
        self.mig_slot_free = self.mig_slot_free.max(done);
        self.mig_records.push(MigrationRecord {
            unit: m.unit,
            src: m.src,
            dst,
            block_bytes: m.block_bytes,
            scheduled: m.scheduled,
            activated: m.activated,
            park_from: m.park_from,
            copy_start: m.copy_start,
            copy_end: m.copy_end,
            done,
            parked_flushed,
        });
    }

    // -- the invariant auditor ----------------------------------------

    /// Audit the slow path's conservation laws; returns every violation
    /// found (empty = clean). Always checks the migration table
    /// ([`Law::MigrationLegality`], [`Law::MigratingNotReselected`],
    /// [`Law::ParkedFlushOnce`]); with `thorough` it also re-validates
    /// every live unit's replica set against
    /// [`choose_replicas`] ([`Law::ReplicaDistinct`]) — the sweep the
    /// crossing hooks sample and the fuzzer/tests run in full.
    pub fn audit_check(
        &self,
        cl: &ClusterState,
        thorough: bool,
    ) -> Vec<Violation> {
        let mut out = Vec::new();

        // -- migration-legality: table states imply their fields and
        // the milestone clocks are ordered.
        for (i, m) in self.migs.iter().enumerate() {
            let snap = || {
                format!(
                    "unit={} src={} state={:?} scheduled={} activated={} \
                     park_from={} copy_start={} copy_end={} phase_done={}",
                    m.unit,
                    m.src,
                    m.sm.state(),
                    m.scheduled,
                    m.activated,
                    m.park_from,
                    m.copy_start,
                    m.copy_end,
                    m.phase_done,
                )
            };
            let dup = self.migs[i + 1..].iter().any(|o| o.unit == m.unit);
            audit::check(
                &mut out,
                !dup,
                Law::MigrationLegality,
                None,
                || format!("unit {} has two live migration entries", m.unit),
                snap,
            );
            audit::check(
                &mut out,
                !matches!(m.sm.state(), MigState::Idle | MigState::Done),
                Law::MigrationLegality,
                None,
                || {
                    format!(
                        "table entry for unit {} is in terminal/idle state",
                        m.unit
                    )
                },
                snap,
            );
            if m.is_active() {
                audit::check(
                    &mut out,
                    m.dst.is_some(),
                    Law::MigrationLegality,
                    None,
                    || {
                        format!(
                            "active migration of unit {} has no destination",
                            m.unit
                        )
                    },
                    snap,
                );
                audit::check(
                    &mut out,
                    m.scheduled <= m.activated && m.activated <= m.park_from,
                    Law::MigrationLegality,
                    None,
                    || {
                        format!(
                            "milestones out of order for unit {} \
                             (scheduled ≤ activated ≤ park_from)",
                            m.unit
                        )
                    },
                    snap,
                );
            }
            if matches!(
                m.sm.state(),
                MigState::Copying | MigState::Committing
            ) {
                audit::check(
                    &mut out,
                    m.dst_block.is_some(),
                    Law::MigrationLegality,
                    None,
                    || {
                        format!(
                            "copying/committing unit {} never registered \
                             its destination block",
                            m.unit
                        )
                    },
                    snap,
                );
                audit::check(
                    &mut out,
                    m.park_from <= m.copy_start
                        && m.copy_start <= m.copy_end,
                    Law::MigrationLegality,
                    None,
                    || {
                        format!(
                            "copy milestones out of order for unit {} \
                             (park_from ≤ copy_start ≤ copy_end)",
                            m.unit
                        )
                    },
                    snap,
                );
            }
        }

        // -- migrating-not-reselected: every `Migrating` block on every
        // peer is the source of exactly one live table entry (and a
        // table entry whose source block is still registered must have
        // marked it).
        for (node, pool) in cl.mrpools.iter().enumerate() {
            for b in pool.blocks() {
                if b.state != MrState::Migrating {
                    continue;
                }
                let refs = self
                    .migs
                    .iter()
                    .filter(|m| m.src == node && m.src_block == b.id)
                    .count();
                // A tenant-tagged sender audits only its own blocks:
                // another tenant's migrations live in another sender.
                if self.owner_tag.is_some_and(|tag| tag != b.owner) {
                    continue;
                }
                audit::check(
                    &mut out,
                    refs == 1,
                    Law::MigratingNotReselected,
                    None,
                    || {
                        format!(
                            "block {} on node {node} is Migrating but has \
                             {refs} owning migration entries",
                            b.id
                        )
                    },
                    || format!("table_len={}", self.migs.len()),
                );
            }
        }

        // -- parked-flush-once: every set that ever parked is either
        // still parked or was flushed — never both, never neither.
        let parked_now: u64 =
            self.migs.iter().map(|m| m.parked.len() as u64).sum();
        audit::check(
            &mut out,
            self.mig_stats.parked_sets
                == self.mig_stats.flushed_sets + parked_now,
            Law::ParkedFlushOnce,
            None,
            || {
                format!(
                    "parked {} != flushed {} + in-table {}",
                    self.mig_stats.parked_sets,
                    self.mig_stats.flushed_sets,
                    parked_now
                )
            },
            || format!("{:?}", self.mig_stats),
        );

        // -- replica-distinct (thorough sweep): the §5.1 chooser is the
        // oracle — re-deriving the replica list from itself must be a
        // fixed point (distinct nodes, sender excluded, primary first).
        if thorough {
            for (id, u) in self.units.iter() {
                if !u.alive || u.nodes.is_empty() {
                    continue;
                }
                let snap = || {
                    format!(
                        "unit={id} nodes={:?} blocks={:?} alive={}",
                        u.nodes, u.blocks, u.alive
                    )
                };
                audit::check(
                    &mut out,
                    u.nodes.len() == u.blocks.len(),
                    Law::ReplicaDistinct,
                    None,
                    || {
                        format!(
                            "unit {id} has {} replica nodes but {} blocks",
                            u.nodes.len(),
                            u.blocks.len()
                        )
                    },
                    snap,
                );
                let rederived = choose_replicas(
                    cl.sender,
                    u.nodes[0],
                    &u.nodes,
                    u.nodes.len(),
                );
                audit::check(
                    &mut out,
                    rederived == u.nodes,
                    Law::ReplicaDistinct,
                    None,
                    || {
                        format!(
                            "unit {id} replica set {:?} is not a \
                             choose_replicas fixed point ({rederived:?})",
                            u.nodes
                        )
                    },
                    snap,
                );
            }
        }
        out
    }

    /// Test-only corruption hook for [`Law::ReplicaDistinct`]:
    /// duplicate a replica slot on the first live unit. Returns false
    /// when no unit exists to corrupt.
    #[cfg(any(feature = "audit", debug_assertions))]
    #[doc(hidden)]
    pub fn audit_corrupt_replicas(&mut self) -> bool {
        for (_, u) in self.units.iter_mut() {
            if !u.alive || u.nodes.is_empty() {
                continue;
            }
            let n = u.nodes[0];
            let b = u.blocks[0];
            if u.nodes.len() >= 2 {
                u.nodes[1] = n;
                u.blocks[1] = b;
            } else {
                u.nodes.push(n);
                u.blocks.push(b);
            }
            return true;
        }
        false
    }

    /// Test-only corruption hook for [`Law::MigrationLegality`]: inject
    /// a fabricated table entry in an active state with no destination.
    #[cfg(any(feature = "audit", debug_assertions))]
    #[doc(hidden)]
    pub fn audit_inject_bogus_migration(&mut self, unit: u64) {
        let mut sm = MigrationSm::new();
        sm.on_event(MigEvent::PressureReport { block: 0, src: 1 })
            .expect("fresh machine accepts a pressure report");
        sm.on_event(MigEvent::DestChosen { dst: 2 })
            .expect("choosing-dest accepts a destination");
        self.migs.push(ActiveMigration {
            sm,
            unit,
            src: 1,
            src_block: 0,
            block_bytes: 0,
            scheduled: 10,
            dst: None, // the corruption: active yet destination-less
            dst_block: None,
            activated: 5, // and activated before it was scheduled
            park_from: 1,
            copy_start: 0,
            copy_end: 0,
            phase_done: 0,
            parked: Vec::new(),
            parked_bytes: 0,
        });
    }

    /// Test-only corruption hook for [`Law::ParkedFlushOnce`]: claim a
    /// parked set that never existed.
    #[cfg(any(feature = "audit", debug_assertions))]
    #[doc(hidden)]
    pub fn audit_corrupt_parked_stats(&mut self) {
        self.mig_stats.parked_sets += 1;
    }
}
