//! The unified orchestration layer — the paper's L3 contribution as one
//! first-class subsystem instead of a flow inlined into a backend.
//!
//! [`Coordinator`] owns the Figure-6 software organization end to end and
//! is shared by the simulated path ([`crate::backends::valet`] delegates
//! its entire hot path here) and the live serving path ([`crate::serve`]
//! runs its leader + remote-sender threads against the same type), so
//! there is exactly one implementation of the critical-path redesign.
//!
//! ## Stage map (Figure 6, §3.4–§3.5)
//!
//! | stage | paper | implementation |
//! |---|---|---|
//! | front-end request | block-I/O entry (Fig. 6 top) | [`Coordinator::write`] / [`Coordinator::read`] |
//! | GPT lookup | radix-tree Global Page Table (§4.1) | [`crate::gpt::RadixGpt`] via `slot_of` |
//! | mempool hit / miss | host-coordinated pool, grow/shrink (§3.4, Table 2) | [`crate::mempool::Mempool`] alloc + backpressure |
//! | staging-queue push | "request ends" after enqueue (Fig. 7) | [`crate::queues::StagingQueue`] |
//! | remote-sender drain | Remote Sender Thread (§4.1) | `drive_sender` / `send_one_batch` on a [`Server`] timeline |
//! | reclaimable recycle | Update/Reclaimable flags (§5.2) | [`crate::queues::ReclaimableQueue`] + slot flags |
//! | eviction hook | activity-based victim selection (§3.5) | pluggable [`VictimPolicy`] (`with_victim_policy`) |
//! | migration hook | sender-driven protocol (§3.5, Fig. 14) | [`MigrationSm`] driven event-by-event in `remote_pressure` |
//!
//! ### Write path (critical path = first three stages only, Figure 7)
//! 1. radix-tree insert into the GPT,
//! 2. copy block-I/O buffer → local mempool,
//! 3. enqueue the write set into the staging queue — **request ends**.
//! The remote sender timeline later coalesces staged write sets into
//! RDMA-MR-sized messages and sends them one-sided to the mapped peers
//! (+ replicas); completion moves each write set to the reclaimable queue
//! and frees its slots for reuse. Connection setup and MR mapping happen
//! entirely behind the mempool.
//!
//! ### Read path
//! GPT hit → serve from mempool (local cache); miss → one-sided RDMA READ
//! from the unit's primary; disk only if every remote copy is gone and
//! disk backup is on (Table 3).
//!
//! ### Remote pressure (§3.5)
//! The pressured peer picks a victim with the pluggable [`VictimPolicy`]
//! (activity-based by default: local tags, zero queries), then the
//! coordinator drives one [`MigrationSm`] instance through the Figure-14
//! protocol — PressureReport → DestChosen → PrepareAcked → CopyDone →
//! CommitAcked — performing each emitted [`MigAction`] against the fabric
//! model. Writes to the migrating unit stay parked (write-locked) until
//! commit; reads keep hitting the source.

use crate::backends::{Access, ClusterState, PressureOutcome, Source, Unit, UnitMap};
use crate::config::{Config, LatencyConfig, ValetConfig};
use crate::eviction::{ActivityBased, VictimPolicy};
use crate::gpt::RadixGpt;
use crate::mempool::{AllocFail, Mempool};
use crate::metrics::RunMetrics;
use crate::migration::{self, MigAction, MigEvent, MigState, MigrationSm};
use crate::mrpool::MrState;
use crate::placement::{Placement, PowerOfTwo};
use crate::queues::{ReclaimableQueue, StagingQueue, WriteSet};
use crate::replication::choose_replicas;
use crate::sim::{Ns, Server};
use crate::util::PageBitmap;
use crate::{pages_for, NodeId, PAGE_SIZE};

/// One coalesced RDMA message in flight: completion time + the write sets
/// it carries.
#[derive(Clone, Debug)]
struct Inflight {
    done: Ns,
    sets: Vec<WriteSet>,
}

/// The unified Valet orchestration layer (see module docs for the stage
/// map). One instance drives the whole Figure-6 pipeline; both the
/// simulated backend and the live serve mode own exactly one, and the
/// multi-tenant [`crate::arbiter::TenantGroup`] owns one per container.
///
/// Quickstart (the write → local-hit → background-drain cycle):
///
/// ```
/// use valet::backends::{ClusterState, Source};
/// use valet::config::Config;
/// use valet::coordinator::Coordinator;
/// use valet::sim::secs;
///
/// let mut cfg = Config::default();
/// cfg.cluster.nodes = 4;
/// cfg.valet.mr_block_bytes = 1 << 20;
/// cfg.valet.min_pool_pages = 64;
/// cfg.valet.max_pool_pages = 64;
///
/// let mut cl = ClusterState::new(&cfg);
/// let mut co = Coordinator::new(&cfg);
///
/// // Write 64 KB: the critical path ends at the staging queue (~35 µs);
/// // connection, mapping and RDMA all happen in the background.
/// let w = co.write(&mut cl, 0, 0, 64 * 1024);
/// assert_eq!(w.source, Source::LocalPool);
///
/// // Read it back: a local mempool hit, far below the write latency.
/// let r = co.read(&mut cl, w.end, 0);
/// assert_eq!(r.source, Source::LocalPool);
/// assert!(r.end - w.end < w.end);
///
/// // Drive the remote sender thread: the staged write set becomes
/// // remotely durable and its slots turn reclaimable.
/// co.pump(&mut cl, secs(2));
/// assert_eq!(co.pending_write_sets(), 0);
/// ```
pub struct Coordinator {
    lat: LatencyConfig,
    vcfg: ValetConfig,
    gpt: RadixGpt,
    mempool: Mempool,
    staging: StagingQueue,
    reclaim_q: ReclaimableQueue,
    /// Remote sender thread's timeline (one batch in service at a time;
    /// batches pipeline on the NIC beneath it).
    sender_thread: Server,
    units: UnitMap,
    /// Pluggable placement hook (§4.3; power-of-two choices by default).
    placement: Box<dyn Placement + Send>,
    /// Pages whose remote copy is valid (the §5.2 per-page bitmap).
    remote_ready: PageBitmap,
    /// Pages with a disk-backup copy.
    disk_valid: PageBitmap,
    inflight: Vec<Inflight>,
    /// Pluggable eviction hook (§3.5; activity-based by default).
    victim_policy: Box<dyn VictimPolicy + Send>,
    metrics: RunMetrics,
    /// Host free pages available to the mempool (updated by the cluster
    /// driver as containers allocate/free).
    host_free_pages: u64,
    /// Owner id stamped on this coordinator's MR registrations. `None`
    /// (single-tenant) registers as the sender node, exactly as before;
    /// the multi-tenant arbiter assigns each tenant a distinct tag so
    /// victim selection never crosses tenants.
    owner_tag: Option<NodeId>,
    /// True when configured with no mempool (Valet-RemoteOnly ablation in
    /// Figure 21): writes go synchronously to remote memory.
    sync_mode: bool,
}

impl Coordinator {
    /// Build from config.
    pub fn new(cfg: &Config) -> Self {
        let sync_mode =
            cfg.valet.min_pool_pages == 0 && cfg.valet.max_pool_pages == 0;
        Coordinator {
            lat: cfg.latency.clone(),
            vcfg: cfg.valet.clone(),
            gpt: RadixGpt::new(),
            mempool: Mempool::new(
                cfg.valet.min_pool_pages.max(1),
                cfg.valet.max_pool_pages.max(1),
                cfg.valet.grow_threshold,
                cfg.valet.host_free_fraction,
            )
            .with_replacement(cfg.valet.replacement),
            staging: StagingQueue::new(),
            reclaim_q: ReclaimableQueue::new(),
            sender_thread: Server::new(),
            units: UnitMap::new(cfg.valet.mr_block_bytes),
            placement: Box::new(PowerOfTwo::new(cfg.cluster.seed)),
            remote_ready: PageBitmap::new(),
            disk_valid: PageBitmap::new(),
            inflight: Vec::new(),
            victim_policy: Box::new(ActivityBased),
            metrics: RunMetrics::default(),
            host_free_pages: (cfg.cluster.node_mem_bytes / PAGE_SIZE) / 2,
            owner_tag: None,
            sync_mode,
        }
    }

    /// Tag this coordinator's MR registrations with a distinct owner id
    /// (multi-tenant arbitration: victim selection under remote pressure
    /// then only ever sees this tenant's blocks). Single-tenant setups
    /// leave this unset and register blocks as the sender node.
    pub fn with_owner_tag(mut self, owner: NodeId) -> Self {
        self.owner_tag = Some(owner);
        self
    }

    /// Swap in a different eviction policy (the §3.5 hook; the default is
    /// [`ActivityBased`]).
    pub fn with_victim_policy(
        mut self,
        policy: Box<dyn VictimPolicy + Send>,
    ) -> Self {
        self.victim_policy = policy;
        self
    }

    /// Swap in a different placement policy (the §4.3 hook; the default
    /// is power-of-two choices).
    pub fn with_placement(
        mut self,
        placement: Box<dyn Placement + Send>,
    ) -> Self {
        self.placement = placement;
        self
    }

    // -- diagnostics / introspection ----------------------------------

    /// Mempool occupancy/capacity diagnostics.
    pub fn mempool(&self) -> &Mempool {
        &self.mempool
    }

    /// The staging queue (write sets not yet remotely durable).
    pub fn staging(&self) -> &StagingQueue {
        &self.staging
    }

    /// The reclaimable queue (write sets whose remote copy is durable).
    pub fn reclaimable(&self) -> &ReclaimableQueue {
        &self.reclaim_q
    }

    /// The remote address-space unit map.
    pub fn units(&self) -> &UnitMap {
        &self.units
    }

    /// Staged (not yet remotely durable) bytes.
    pub fn staged_bytes(&self) -> u64 {
        self.staging.bytes()
    }

    /// Number of mapped address-space units.
    pub fn mapped_units(&self) -> usize {
        self.units.len()
    }

    /// Mempool slot currently holding `page`, if it is locally cached
    /// (GPT lookup without charging latency — diagnostics only).
    pub fn slot_of(&self, page: u64) -> Option<u32> {
        self.gpt.get(page)
    }

    /// Write sets not yet durable: staged + carried by in-flight RDMA.
    pub fn pending_write_sets(&self) -> usize {
        self.staging.len()
            + self.inflight.iter().map(|f| f.sets.len()).sum::<usize>()
    }

    /// Name of the active eviction policy.
    pub fn victim_policy_name(&self) -> &'static str {
        self.victim_policy.name()
    }

    /// Host free pages currently granted to the mempool's cap.
    pub fn host_free_pages(&self) -> u64 {
        self.host_free_pages
    }

    /// Update host free memory (container churn on the sender node); the
    /// next pump's grow/shrink check runs against this value.
    pub fn set_host_free_pages(&mut self, pages: u64) {
        self.host_free_pages = pages;
    }

    /// Pages the host arbiter currently leases to this tenant's mempool
    /// (`u64::MAX` when unleased — single-tenant operation).
    pub fn lease_pages(&self) -> u64 {
        self.mempool.lease()
    }

    /// Update the arbiter lease: the mempool's effective cap becomes
    /// `min(max_pool_pages, host_free_fraction × host free, lease)`.
    /// The next pump enforces a lowered lease by shrinking free slots
    /// and, if that is not enough, donating idle remote-durable pages
    /// back to the host pool (see [`Self::donate_idle_pages`]).
    pub fn set_lease_pages(&mut self, pages: u64) {
        self.mempool.set_lease(pages);
    }

    /// Give back up to `want` idle (remote-durable, least-recently-used)
    /// pages to the host pool, dropping their GPT entries — subsequent
    /// reads of those pages are served remotely. Returns pages donated.
    pub fn donate_idle_pages(&mut self, want: u64) -> u64 {
        let evicted = self.mempool.donate_idle(want);
        for p in &evicted {
            self.gpt.remove(*p);
        }
        evicted.len() as u64
    }

    /// Run metrics.
    pub fn metrics(&self) -> &RunMetrics {
        &self.metrics
    }

    /// Mutable run metrics.
    pub fn metrics_mut(&mut self) -> &mut RunMetrics {
        &mut self.metrics
    }

    // -- background machinery (remote sender timeline) ----------------

    /// Ensure `unit` has a remote mapping; returns when it is usable.
    /// Charged on the *sender thread* timeline — never the request path.
    fn ensure_unit(&mut self, cl: &mut ClusterState, now: Ns, unit: u64) -> Ns {
        if let Some(u) = self.units.get(unit) {
            if u.alive {
                return u.ready_at;
            }
        }
        // (Re)map: pick primary via the placement hook, then replicas.
        let cands = cl.candidates();
        let primary = self
            .placement
            .pick(&cands)
            .expect("cluster has at least one peer");
        let cand_nodes: Vec<NodeId> = cands.iter().map(|c| c.node).collect();
        let nodes = choose_replicas(
            cl.sender,
            primary,
            &cand_nodes,
            self.vcfg.replicas.max(1),
        );
        // Connection (if new) + mapping, charged sequentially per node.
        let mut t = now;
        for &n in &nodes {
            let (tc, _newc) = cl.fabric.ensure_connected(t, cl.sender, n);
            t = cl.fabric.map_mr(tc, cl.sender);
        }
        let owner = self.owner_tag.unwrap_or(cl.sender);
        let blocks = nodes
            .iter()
            .map(|&n| cl.mrpools[n].register(owner, self.units.unit_bytes, t))
            .collect();
        self.units.insert(
            unit,
            Unit {
                nodes,
                blocks,
                ready_at: t,
                wlocked_until: 0,
                alive: true,
            },
        );
        t
    }

    /// Apply completions of in-flight RDMA batches up to `now`: each
    /// completed write set moves to the reclaimable queue and its slots
    /// become recyclable (unless superseded — §5.2 UPDATE flag).
    fn complete_inflight(&mut self, cl: &mut ClusterState, now: Ns) {
        let mut i = 0;
        while i < self.inflight.len() {
            if self.inflight[i].done <= now {
                let inflight = self.inflight.swap_remove(i);
                for ws in inflight.sets {
                    for &slot in &ws.slots {
                        // marks the slot reclaimable unless a newer write
                        // set superseded it (§5.2); the page itself stays
                        // cached locally until the slot is recycled
                        let _ = self.mempool.mark_reclaimable(slot);
                    }
                    for p in ws.page..ws.page + ws.pages() {
                        self.remote_ready.set(p);
                    }
                    // stamp activity tags on the primary block
                    let unit = self.units.unit_of(ws.page);
                    if let Some(u) = self.units.get(unit) {
                        if let (Some(&n), Some(&b)) =
                            (u.nodes.first(), u.blocks.first())
                        {
                            cl.mrpools[n].touch_write(b, inflight.done);
                        }
                    }
                    self.reclaim_q.push(ws);
                }
            } else {
                i += 1;
            }
        }
    }

    /// Drive the remote sender thread: send coalesced batches whose
    /// service can start at or before `now`.
    fn drive_sender(&mut self, cl: &mut ClusterState, now: Ns) {
        self.complete_inflight(cl, now);
        while !self.staging.is_empty() && self.sender_thread.busy_until() <= now
        {
            let start = self
                .sender_thread
                .busy_until()
                .max(self.staging.front_enqueued_at().unwrap_or(0));
            if start > now {
                break;
            }
            self.send_one_batch(cl, start);
        }
    }

    /// Send one coalesced batch at (no earlier than) `t0`; returns its
    /// completion time. Coalescing only merges write sets that target the
    /// same address-space unit (one RDMA message lands in one MR block).
    fn send_one_batch(&mut self, cl: &mut ClusterState, t0: Ns) -> Ns {
        debug_assert!(!self.staging.is_empty());
        let max = if self.vcfg.coalescing {
            self.vcfg.rdma_msg_bytes
        } else {
            1 // force single write set per message
        };
        let unit = self
            .units
            .unit_of(self.staging.peek().expect("non-empty").page);
        let mut batch = Vec::new();
        let mut bytes = 0u64;
        while let Some(front) = self.staging.peek() {
            let same_unit = self.units.unit_of(front.page) == unit;
            if !batch.is_empty() && (bytes + front.bytes > max || !same_unit)
            {
                break;
            }
            let ws = self.staging.pop().unwrap();
            bytes += ws.bytes;
            batch.push(ws);
        }
        // mapping (behind the mempool — charged here, on sender thread)
        let ready = self.ensure_unit(cl, t0, unit);
        let u = self.units.get(unit).unwrap();
        let mut t = t0.max(ready).max(u.wlocked_until);
        // mrpool get + one-sided write per replica (queue on our NIC)
        t += self.lat.mrpool_get;
        let nodes = u.nodes.clone();
        let mut done = t;
        for &n in &nodes {
            let verb = cl.fabric.rdma_write(t, cl.sender, n, bytes);
            done = done.max(verb.end);
        }
        // optional disk backup, off the critical path
        if self.vcfg.disk_backup {
            cl.disks[cl.sender].write_async(t, bytes);
            for ws in &batch {
                for p in ws.page..ws.page + ws.pages() {
                    self.disk_valid.set(p);
                }
            }
            self.metrics.disk_writes += 1;
        }
        // The sender thread is busy only for its CPU work (mapping waits
        // + mrpool get + posting the WQE, ~300 ns); the verb completes
        // asynchronously on the NIC (tracked via `inflight`), so many
        // messages pipeline — and un-coalesced small messages flood the
        // WQE cache, which is exactly the §3.3 argument for batching.
        let post_done = t + 300;
        self.sender_thread.serve(t0, post_done.saturating_sub(t0));
        self.inflight.push(Inflight { done, sets: batch });
        done
    }

    /// Block until at least one mempool slot can be recycled: force the
    /// sender pipeline forward and apply the earliest completion.
    /// Returns the time the caller may retry.
    fn wait_for_reclaimable(&mut self, cl: &mut ClusterState, now: Ns) -> Ns {
        // Earliest in-flight completion?
        if let Some(min_done) =
            self.inflight.iter().map(|f| f.done).min()
        {
            let t = min_done.max(now);
            self.complete_inflight(cl, min_done);
            return t;
        }
        if !self.staging.is_empty() {
            let start = self.sender_thread.busy_until().max(now);
            let done = self.send_one_batch(cl, start);
            self.complete_inflight(cl, done);
            return done.max(now);
        }
        // Nothing pending: caller's alloc should succeed after growth or
        // is genuinely out of memory; avoid infinite loops by advancing.
        now + 1
    }

    /// Synchronous write (Valet-RemoteOnly ablation): radix + copy + wait
    /// for the RDMA send like Infiniswap, but keep coalescing disabled
    /// and no disk redirect (mapping stalls the request instead).
    fn write_sync(
        &mut self,
        cl: &mut ClusterState,
        now: Ns,
        page: u64,
        bytes: u64,
    ) -> Access {
        let mut t = now + self.lat.radix_insert;
        self.metrics.write_parts.add("radix", self.lat.radix_insert);
        let unit = self.units.unit_of(page);
        let ready = self.ensure_unit(cl, t, unit);
        if ready > t {
            self.metrics.write_parts.add("mapping", ready - t);
            t = ready;
        }
        let copy = self.lat.copy(bytes);
        t += copy;
        self.metrics.write_parts.add("copy", copy);
        let u = self.units.get(unit).unwrap();
        let nodes = u.nodes.clone();
        let mut done = t + self.lat.mrpool_get;
        for &n in &nodes {
            let verb = cl.fabric.rdma_write(t, cl.sender, n, bytes);
            done = done.max(verb.end);
        }
        self.metrics.write_parts.add("rdma", done - t);
        for p in page..page + pages_for(bytes) {
            self.remote_ready.set(p);
        }
        self.metrics.write_latency.record(done - now);
        Access {
            end: done,
            source: Source::Remote,
        }
    }

    // -- the front-end request path -----------------------------------

    /// Front-end write (swap-out): the Figure-7 critical path — GPT
    /// insert, copy into the mempool (with grow/backpressure per §3.4),
    /// staging-queue push — then the request ends; the remote sender
    /// drains in the background.
    pub fn write(
        &mut self,
        cl: &mut ClusterState,
        now: Ns,
        page: u64,
        bytes: u64,
    ) -> Access {
        if self.sync_mode {
            return self.write_sync(cl, now, page, bytes);
        }
        let npages = pages_for(bytes);
        let mut t = now + self.lat.radix_insert;
        self.metrics.write_parts.add("radix", self.lat.radix_insert);

        let mut slots = Vec::with_capacity(npages as usize);
        for p in page..page + npages {
            if let Some(slot) = self.gpt.get(p) {
                // Overwrite in place (§5.2): newer write set supersedes.
                let flags = self.mempool.flags(slot);
                if flags.reclaimable {
                    self.mempool.unmark_reclaimable(slot);
                } else {
                    self.mempool.bump_update(slot);
                }
                self.remote_ready.clear(p); // remote copy now stale
                slots.push(slot);
                continue;
            }
            // Allocate a slot, stalling on backpressure if required.
            loop {
                match self.mempool.alloc(p, self.host_free_pages) {
                    Ok(a) => {
                        if let Some(evicted) = a.evicted_page {
                            self.gpt.remove(evicted);
                        }
                        self.gpt.insert(p, a.slot);
                        slots.push(a.slot);
                        break;
                    }
                    Err(AllocFail::NoReclaimable) => {
                        let retry = self.wait_for_reclaimable(cl, t);
                        if retry > t {
                            self.metrics
                                .write_parts
                                .add("stall", retry - t);
                            t = retry;
                        }
                    }
                }
            }
        }

        let copy = self.lat.copy(bytes);
        t += copy;
        self.metrics.write_parts.add("copy", copy);
        t += self.lat.staging_enqueue;
        self.metrics
            .write_parts
            .add("enqueue", self.lat.staging_enqueue);

        self.staging.push(WriteSet {
            page,
            slots,
            bytes,
            enqueued_at: t,
        });
        self.metrics.write_latency.record(t - now);
        // opportunistically push the background pipeline forward
        self.drive_sender(cl, t);
        Access {
            end: t,
            source: Source::LocalPool,
        }
    }

    /// Front-end read (swap-in): GPT lookup → mempool hit, else one-sided
    /// RDMA READ from the unit's primary, else disk (Table 3 fallback).
    pub fn read(&mut self, cl: &mut ClusterState, now: Ns, page: u64) -> Access {
        let mut t = now + self.lat.radix_lookup;
        self.metrics.read_parts.add("radix", self.lat.radix_lookup);
        if let Some(slot) = self.gpt.get(page) {
            // Local mempool hit — the redesigned critical path's payoff.
            t += self.lat.copy_read_page;
            self.metrics
                .read_parts
                .add("copy", self.lat.copy_read_page);
            self.mempool.touch(slot);
            self.metrics.local_hits += 1;
            self.metrics.read_latency.record(t - now);
            return Access {
                end: t,
                source: Source::LocalPool,
            };
        }
        let unit_id = self.units.unit_of(page);
        let remote_ok = self
            .units
            .get(unit_id)
            .map(|u| u.alive && self.remote_ready.get(page))
            .unwrap_or(false);
        if remote_ok {
            let u = self.units.get(unit_id).unwrap();
            let primary = u.nodes[0];
            let ready_at = u.ready_at;
            t = t.max(ready_at);
            t += self.lat.mrpool_get;
            self.metrics
                .read_parts
                .add("mrpool", self.lat.mrpool_get);
            let verb = cl.fabric.rdma_read(t, cl.sender, primary, PAGE_SIZE);
            self.metrics.read_parts.add("rdma", verb.end - t);
            t = verb.end + self.lat.copy_read_page;
            self.metrics
                .read_parts
                .add("copy", self.lat.copy_read_page);
            self.metrics.remote_hits += 1;
            self.metrics.read_latency.record(t - now);
            return Access {
                end: t,
                source: Source::Remote,
            };
        }
        // Remote copy unavailable: disk (Table 3 fallback).
        let end = cl.disks[cl.sender].read(t, PAGE_SIZE);
        self.metrics.read_parts.add("disk", end - t);
        self.metrics.disk_reads += 1;
        self.metrics.read_latency.record(end - now);
        Access {
            end,
            source: Source::Disk,
        }
    }

    /// Drive background machinery up to `now`: remote-sender drain plus
    /// the mempool's shrink check against current host pressure (§3.4).
    /// When free-slot shrinking cannot reach the effective cap (a
    /// lowered arbiter lease or collapsed host free memory with a full
    /// pool), idle remote-durable pages are donated back to the host.
    pub fn pump(&mut self, cl: &mut ClusterState, now: Ns) {
        self.drive_sender(cl, now);
        self.mempool.shrink(self.host_free_pages);
        let cap = self.mempool.effective_cap(self.host_free_pages);
        let capacity = self.mempool.capacity();
        if capacity > cap {
            self.donate_idle_pages(capacity - cap);
        }
    }

    /// A peer needs `bytes` of its donated memory back (§3.5): select
    /// victims via the pluggable policy and migrate each one through the
    /// sender-driven protocol state machine; delete only as a last
    /// resort (no destination with room).
    pub fn remote_pressure(
        &mut self,
        cl: &mut ClusterState,
        now: Ns,
        node: NodeId,
        bytes: u64,
    ) -> PressureOutcome {
        let mut out = PressureOutcome {
            done_at: now,
            ..Default::default()
        };
        let owner = self.owner_tag.unwrap_or(cl.sender);
        let mut t = now;
        while out.reclaimed_bytes < bytes {
            // Victim selection ON the pressured node via the pluggable
            // policy — activity-based by default: purely local metadata,
            // zero sender queries (§3.5). A tenant-tagged coordinator
            // selects only among its own blocks.
            let choice = {
                let selected = match self.owner_tag {
                    Some(tag) => {
                        let view = cl.mrpools[node].owned_by(tag);
                        self.victim_policy.select(&view, t)
                    }
                    None => self.victim_policy.select(&cl.mrpools[node], t),
                };
                match selected {
                    Some(c) => c,
                    None => break,
                }
            };
            t += choice.selection_cost; // zero for ActivityBased
            let block_bytes = cl.mrpools[node]
                .get(choice.block)
                .map(|b| b.bytes)
                .unwrap_or(self.units.unit_bytes);
            let unit_id = self.units.unit_of_block(node, choice.block);
            // Pick a destination: least-pressured other peer.
            let cands: Vec<_> = cl
                .candidates()
                .into_iter()
                .filter(|c| c.node != node && c.free_bytes >= block_bytes)
                .collect();
            let dst = cands
                .iter()
                .max_by_key(|c| c.free_bytes)
                .map(|c| c.node);
            match (unit_id, dst) {
                (Some(unit_id), Some(dst)) => {
                    // Drive the Figure-14 protocol state machine; every
                    // transition below mirrors an action the coordinator
                    // actually performs against the fabric model.
                    let mut sm = MigrationSm::new();
                    sm.on_event(MigEvent::PressureReport {
                        block: choice.block,
                        src: node,
                    })
                    .expect("fresh machine accepts a pressure report");
                    // QueryCandidates was performed above (cl.candidates).
                    let actions = sm
                        .on_event(MigEvent::DestChosen { dst })
                        .expect("destination differs from source");
                    let park_writes =
                        actions.contains(&MigAction::StopWrites);
                    debug_assert!(sm.writes_parked());
                    if let Some(b) = cl.mrpools[node].get_mut(choice.block) {
                        b.state = MrState::Migrating;
                    }
                    sm.on_event(MigEvent::PrepareAcked)
                        .expect("preparing accepts ack");
                    let mig = migration::simulate(
                        &mut cl.fabric,
                        &self.lat,
                        t,
                        cl.sender,
                        node,
                        dst,
                        block_bytes,
                        2,
                    );
                    // destination registers the block when the copy starts
                    let new_block = cl.mrpools[dst].register(
                        owner,
                        block_bytes,
                        mig.copy_start,
                    );
                    cl.mrpools[node].release(choice.block);
                    sm.on_event(MigEvent::CopyDone)
                        .expect("copying accepts copy-done");
                    let final_actions = sm
                        .on_event(MigEvent::CommitAcked)
                        .expect("committing accepts ack");
                    debug_assert!(final_actions
                        .contains(&MigAction::FlushParkedWrites));
                    debug_assert_eq!(sm.state(), MigState::Done);
                    // COMMIT: remap the unit's replica slot to dst; the
                    // parked-writes flush is modeled by the write lock
                    // expiring at mig.done.
                    let u = self.units.get_mut(unit_id).unwrap();
                    for (n, b) in
                        u.nodes.iter_mut().zip(u.blocks.iter_mut())
                    {
                        if *n == node && *b == choice.block {
                            *n = dst;
                            *b = new_block;
                        }
                    }
                    if park_writes {
                        u.wlocked_until = u.wlocked_until.max(mig.done);
                    }
                    out.migrated += 1;
                    out.reclaimed_bytes += block_bytes;
                    // source's memory is free once the copy is out
                    t = mig.copy_end;
                    out.done_at = out.done_at.max(mig.done);
                }
                _ => {
                    // No destination with room (or untracked block):
                    // last resort — delete like the baselines would.
                    cl.mrpools[node].release(choice.block);
                    if let Some(unit_id) = unit_id {
                        if let Some(u) = self.units.get_mut(unit_id) {
                            u.alive = false;
                        }
                    }
                    out.deleted += 1;
                    out.reclaimed_bytes += block_bytes;
                    out.done_at = out.done_at.max(t);
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::eviction::BatchedQueryRandom;
    use crate::placement::RoundRobin;
    use crate::sim::{ms, secs, us};

    fn setup() -> (Config, ClusterState, Coordinator) {
        let mut cfg = Config::default();
        cfg.cluster.nodes = 4;
        cfg.valet.min_pool_pages = 64;
        cfg.valet.max_pool_pages = 64;
        cfg.valet.mr_block_bytes = 1 << 20; // 1 MB units for fast tests
        let cl = ClusterState::new(&cfg);
        let co = Coordinator::new(&cfg);
        (cfg, cl, co)
    }

    #[test]
    fn write_completes_locally_in_microseconds() {
        let (_cfg, mut cl, mut co) = setup();
        let a = co.write(&mut cl, 0, 0, 64 * 1024);
        assert_eq!(a.source, Source::LocalPool);
        // Table 7a: write total ≈ 35.31 µs (radix 23.9 + copy 9.73 +
        // enqueue 1.68)
        let total = a.end;
        assert!(
            (total as f64 - 35_310.0).abs() < 500.0,
            "write latency {total}"
        );
        // connection/mapping must NOT be on the critical path
        assert!(total < ms(1));
    }

    #[test]
    fn read_after_write_hits_local_pool() {
        let (_cfg, mut cl, mut co) = setup();
        let w = co.write(&mut cl, 0, 0, 64 * 1024);
        let r = co.read(&mut cl, w.end, 0);
        assert_eq!(r.source, Source::LocalPool);
        // Table 7a: local hit = radix 1.39 + copy 2.11 = 3.5 µs
        let lat = r.end - w.end;
        assert!((lat as f64 - 3_500.0).abs() < 200.0, "local read {lat}");
    }

    #[test]
    fn evicted_pages_read_from_remote() {
        let (_cfg, mut cl, mut co) = setup();
        // Fill the 64-page pool far beyond capacity so early pages get
        // recycled after their batches complete.
        let mut t = 0;
        for blk in 0..40u64 {
            let a = co.write(&mut cl, t, blk * 16, 16 * PAGE_SIZE);
            t = a.end;
        }
        // let background sending finish
        t += secs(2);
        co.pump(&mut cl, t);
        // force reclaim of everything reclaimable by writing more
        for blk in 40..44u64 {
            let a = co.write(&mut cl, t, blk * 16, 16 * PAGE_SIZE);
            t = a.end;
        }
        t += secs(2);
        co.pump(&mut cl, t);
        // page 0 should long be evicted from the pool → remote read
        let r = co.read(&mut cl, t, 0);
        assert_eq!(r.source, Source::Remote, "metrics: {:?}", co.metrics());
        // Table 7a remote read ≈ 36.5 rdma + 2.13 copy + 0.14 mrpool
        let lat = r.end - t;
        assert!((lat as f64 - 41_000.0).abs() < 5_000.0, "remote {lat}");
        assert!(co.metrics().remote_hits > 0);
    }

    #[test]
    fn connection_mapping_hidden_from_write_path() {
        let (_cfg, mut cl, mut co) = setup();
        // First-ever write triggers connection (200 ms) + mapping (62 ms)
        // on the background; the write itself returns in ~35 µs.
        let a = co.write(&mut cl, 0, 0, 64 * 1024);
        assert!(a.end < us(100));
        assert!(co.mapped_units() <= 1); // mapping may lag the write
        // after pumping past the window the unit exists
        co.pump(&mut cl, ms(400));
        assert_eq!(co.mapped_units(), 1);
        assert_eq!(cl.fabric.connections_made, 1);
    }

    #[test]
    fn migration_drives_state_machine_and_keeps_data_readable() {
        let (_cfg, mut cl, mut co) = setup();
        let mut t = 0;
        for blk in 0..40u64 {
            let a = co.write(&mut cl, t, blk * 16, 16 * PAGE_SIZE);
            t = a.end;
        }
        t += secs(2);
        co.pump(&mut cl, t);
        // find which node holds unit 0 and pressure it
        let holder = co.units().get(0).map(|u| u.nodes[0]).unwrap();
        let out = co.remote_pressure(&mut cl, t, holder, 1);
        assert!(out.migrated >= 1);
        assert_eq!(out.deleted, 0);
        // the migrated unit is write-locked until the protocol committed
        let relocated = co
            .units()
            .iter()
            .any(|(_, u)| u.wlocked_until >= out.done_at);
        assert!(relocated, "a unit must carry the park-window lock");
        // reads of migrated data still come from remote (never disk)
        let before = co.metrics().disk_reads;
        let mut tt = out.done_at;
        for p in [0u64, 1, 17, 33, 65, 129] {
            let rr = co.read(&mut cl, tt, p);
            tt = rr.end;
            assert_ne!(rr.source, Source::Disk, "page {p}");
        }
        assert_eq!(co.metrics().disk_reads, before);
    }

    #[test]
    fn victim_policy_hook_is_pluggable() {
        let mut cfg = Config::default();
        cfg.cluster.nodes = 4;
        cfg.valet.min_pool_pages = 64;
        cfg.valet.max_pool_pages = 64;
        cfg.valet.mr_block_bytes = 1 << 20;
        let mut cl = ClusterState::new(&cfg);
        let mut co = Coordinator::new(&cfg)
            .with_victim_policy(Box::new(BatchedQueryRandom::new(
                7,
                2,
                us(30),
            )))
            .with_placement(Box::new(RoundRobin::new()));
        assert_eq!(co.victim_policy_name(), "batched_query_random");
        let mut t = 0;
        for blk in 0..40u64 {
            let a = co.write(&mut cl, t, blk * 16, 16 * PAGE_SIZE);
            t = a.end;
        }
        t += secs(2);
        co.pump(&mut cl, t);
        let holder = co.units().get(0).map(|u| u.nodes[0]).unwrap();
        let out = co.remote_pressure(&mut cl, t, holder, 1);
        // the batched-query baseline pays per-query latency on selection
        assert!(out.migrated + out.deleted >= 1);
        assert!(out.done_at > t, "selection cost must be charged");
    }

    #[test]
    fn sync_mode_waits_for_rdma() {
        let mut cfg = Config::default();
        cfg.cluster.nodes = 3;
        cfg.valet.min_pool_pages = 0;
        cfg.valet.max_pool_pages = 0;
        cfg.valet.mr_block_bytes = 1 << 20;
        let mut cl = ClusterState::new(&cfg);
        let mut co = Coordinator::new(&cfg);
        let a = co.write(&mut cl, 0, 0, 64 * 1024);
        assert_eq!(a.source, Source::Remote);
        // first write pays connection + mapping synchronously
        assert!(a.end > ms(200));
        let b = co.write(&mut cl, a.end, 16, 64 * 1024);
        // subsequent writes still pay RDMA round trip
        assert!(b.end - a.end > us(40));
    }

    #[test]
    fn pending_write_sets_counts_staged_and_inflight() {
        let (_cfg, mut cl, mut co) = setup();
        assert_eq!(co.pending_write_sets(), 0);
        let a = co.write(&mut cl, 0, 0, 64 * 1024);
        // the opportunistic drive already moved it into flight
        assert_eq!(co.pending_write_sets(), 1);
        co.pump(&mut cl, a.end + secs(2));
        assert_eq!(co.pending_write_sets(), 0);
        assert_eq!(co.reclaimable().completed, 1);
    }

    #[test]
    fn host_pressure_shrinks_pool_but_never_below_min() {
        let mut cfg = Config::default();
        cfg.cluster.nodes = 4;
        cfg.valet.min_pool_pages = 64;
        cfg.valet.max_pool_pages = 4096;
        cfg.valet.mr_block_bytes = 1 << 20;
        let mut cl = ClusterState::new(&cfg);
        let mut co = Coordinator::new(&cfg);
        let mut t = 0;
        // grow the pool well past its floor
        for blk in 0..64u64 {
            let a = co.write(&mut cl, t, blk * 16, 16 * PAGE_SIZE);
            t = a.end;
        }
        assert!(co.mempool().capacity() > 64);
        // host free memory collapses: every subsequent pump shrinks
        // toward the floor but never below it
        co.set_host_free_pages(0);
        for step in 0..64 {
            t += secs(1);
            co.pump(&mut cl, t);
            assert!(
                co.mempool().capacity() >= co.mempool().min_pages(),
                "step {step}: capacity {} under floor",
                co.mempool().capacity()
            );
        }
    }
}
