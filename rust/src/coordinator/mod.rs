//! The unified orchestration layer — the paper's L3 contribution as one
//! first-class subsystem instead of a flow inlined into a backend.
//!
//! Since the sharded-engine refactor this module is **layered**:
//!
//! * [`fast::ShardFastPath`] — the shard-local fast path (GPT + mempool
//!   + staging/reclaimable queues + §5.2 bitmaps + metrics). A local
//!   read hit never leaves it.
//! * [`sender::RemoteSender`] — the shared slow path (remote sender
//!   thread timeline, coalescing batcher, unit map, placement,
//!   migration/eviction machinery, per-shard completion mailboxes).
//! * [`crate::engine::ShardedEngine`] — `S` fast paths behind one slow
//!   path, page-space interleaved by stripe.
//!
//! [`Coordinator`] is the single-context view: a thin wrapper over a
//! one-shard engine that keeps the PR-1 API (and, bit for bit, the PR-1
//! behavior — see `tests/sharding.rs`). The simulated path
//! ([`crate::backends::valet`] delegates its entire hot path here), the
//! live serving path ([`crate::serve`]) and the multi-tenant
//! [`crate::arbiter::TenantGroup`] all drive this same implementation,
//! so there is exactly one realization of the critical-path redesign.
//!
//! ## Stage map (Figure 6, §3.4–§3.5)
//!
//! | stage | paper | implementation |
//! |---|---|---|
//! | front-end request | block-I/O entry (Fig. 6 top) | [`Coordinator::write`] / [`Coordinator::read`] |
//! | GPT lookup | radix-tree Global Page Table (§4.1) | [`crate::gpt::RadixGpt`] via `slot_of` |
//! | mempool hit / miss | host-coordinated pool, grow/shrink (§3.4, Table 2) | [`crate::mempool::Mempool`] alloc + backpressure |
//! | staging-queue push | "request ends" after enqueue (Fig. 7) | [`crate::queues::StagingQueue`] |
//! | remote-sender drain | Remote Sender Thread (§4.1) | [`sender::RemoteSender`] on a [`crate::sim::Server`] timeline |
//! | reclaimable recycle | Update/Reclaimable flags (§5.2) | [`crate::queues::ReclaimableQueue`] + slot flags |
//! | eviction hook | activity-based victim selection (§3.5) | pluggable [`VictimPolicy`] (`with_victim_policy`) |
//! | migration hook | sender-driven protocol (§3.5, Fig. 14) | live [`crate::migration::MigrationSm`] instances in the sender's migration table, advanced on pump ticks |
//!
//! ### Write path (critical path = first three stages only, Figure 7)
//! 1. radix-tree insert into the GPT,
//! 2. copy block-I/O buffer → local mempool,
//! 3. enqueue the write set into the staging queue — **request ends**.
//! The remote sender timeline later coalesces staged write sets into
//! RDMA-MR-sized messages and sends them one-sided to the mapped peers
//! (+ replicas); completion moves each write set to the reclaimable queue
//! and frees its slots for reuse. Connection setup and MR mapping happen
//! entirely behind the mempool.
//!
//! ### Read path
//! GPT hit → serve from mempool (local cache); miss → one-sided RDMA READ
//! from the unit's primary; disk only if every remote copy is gone and
//! disk backup is on (Table 3).
//!
//! ### Remote pressure (§3.5): the reclaim pipeline
//! The pressured peer picks a victim with the pluggable [`VictimPolicy`]
//! (activity-based by default: local tags, zero queries — and the tags
//! now cover *read* activity too, including consumed prefetches), then
//! the sender **enqueues** one migration state machine per victim into
//! its migration table. Pump ticks drive each machine through the
//! Figure-14 protocol — PressureReport → DestChosen (pressure-aware
//! placement, [`crate::placement::LeastPressured`]) → PrepareAcked →
//! CopyDone → CommitAcked — interleaved with write batches, several
//! machines at a time (`valet.max_concurrent_migrations`). Writes to a
//! migrating unit park in the table and flush to the destination at
//! COMMIT; reads keep hitting the source until the remap. See
//! ARCHITECTURE.md §6 for the timeline diagram.

pub mod fast;
pub mod sender;

use crate::backends::{Access, ClusterState, PressureOutcome, UnitMap};
use crate::config::Config;
use crate::engine::ShardedEngine;
use crate::eviction::VictimPolicy;
use crate::mempool::Mempool;
use crate::metrics::RunMetrics;
use crate::placement::Placement;
use crate::queues::{ReclaimableQueue, StagingQueue};
use crate::sim::Ns;
use crate::NodeId;

/// The unified Valet orchestration layer (see module docs for the stage
/// map): the single-context view of a one-shard
/// [`crate::engine::ShardedEngine`]. One instance drives the whole
/// Figure-6 pipeline; both the simulated backend and the live serve mode
/// own exactly one, and the multi-tenant [`crate::arbiter::TenantGroup`]
/// owns one per container.
///
/// Quickstart (the write → local-hit → background-drain cycle):
///
/// ```
/// use valet::backends::{ClusterState, Source};
/// use valet::config::Config;
/// use valet::coordinator::Coordinator;
/// use valet::sim::secs;
///
/// let mut cfg = Config::default();
/// cfg.cluster.nodes = 4;
/// cfg.valet.mr_block_bytes = 1 << 20;
/// cfg.valet.min_pool_pages = 64;
/// cfg.valet.max_pool_pages = 64;
///
/// let mut cl = ClusterState::new(&cfg);
/// let mut co = Coordinator::new(&cfg);
///
/// // Write 64 KB: the critical path ends at the staging queue (~35 µs);
/// // connection, mapping and RDMA all happen in the background.
/// let w = co.write(&mut cl, 0, 0, 64 * 1024);
/// assert_eq!(w.source, Source::LocalPool);
///
/// // Read it back: a local mempool hit, far below the write latency.
/// let r = co.read(&mut cl, w.end, 0);
/// assert_eq!(r.source, Source::LocalPool);
/// assert!(r.end - w.end < w.end);
///
/// // Drive the remote sender thread: the staged write set becomes
/// // remotely durable and its slots turn reclaimable.
/// co.pump(&mut cl, secs(2));
/// assert_eq!(co.pending_write_sets(), 0);
/// ```
pub struct Coordinator {
    engine: ShardedEngine,
}

impl Coordinator {
    /// Build from config.
    pub fn new(cfg: &Config) -> Self {
        Coordinator {
            engine: ShardedEngine::new(cfg, 1),
        }
    }

    /// Tag this coordinator's MR registrations with a distinct owner id
    /// (multi-tenant arbitration: victim selection under remote pressure
    /// then only ever sees this tenant's blocks). Single-tenant setups
    /// leave this unset and register blocks as the sender node.
    pub fn with_owner_tag(mut self, owner: NodeId) -> Self {
        self.engine.set_owner_tag(owner);
        self
    }

    /// Swap in a different eviction policy (the §3.5 hook; the default is
    /// [`crate::eviction::ActivityBased`]).
    pub fn with_victim_policy(
        mut self,
        policy: Box<dyn VictimPolicy + Send>,
    ) -> Self {
        self.engine.set_victim_policy(policy);
        self
    }

    /// Swap in a different placement policy (the §4.3 hook; the default
    /// is power-of-two choices).
    pub fn with_placement(
        mut self,
        placement: Box<dyn Placement + Send>,
    ) -> Self {
        self.engine.set_placement(placement);
        self
    }

    // -- diagnostics / introspection ----------------------------------

    /// The one-shard engine behind this coordinator.
    pub fn engine(&self) -> &ShardedEngine {
        &self.engine
    }

    /// Mempool occupancy/capacity diagnostics.
    pub fn mempool(&self) -> &Mempool {
        &self.engine.shard(0).mempool
    }

    /// The staging queue (write sets not yet remotely durable).
    pub fn staging(&self) -> &StagingQueue {
        &self.engine.shard(0).staging
    }

    /// The reclaimable queue (write sets whose remote copy is durable).
    pub fn reclaimable(&self) -> &ReclaimableQueue {
        &self.engine.shard(0).reclaim_q
    }

    /// The remote address-space unit map.
    pub fn units(&self) -> &UnitMap {
        self.engine.sender().units()
    }

    /// Staged (not yet remotely durable) bytes.
    pub fn staged_bytes(&self) -> u64 {
        self.engine.staged_bytes()
    }

    /// Number of mapped address-space units.
    pub fn mapped_units(&self) -> usize {
        self.engine.mapped_units()
    }

    /// Mempool slot currently holding `page`, if it is locally cached
    /// (GPT lookup without charging latency — diagnostics only).
    pub fn slot_of(&self, page: u64) -> Option<u32> {
        self.engine.slot_of(page)
    }

    /// Write sets not yet durable: staged + carried by in-flight RDMA.
    pub fn pending_write_sets(&self) -> usize {
        self.engine.pending_write_sets()
    }

    /// Name of the active eviction policy.
    pub fn victim_policy_name(&self) -> &'static str {
        self.engine.sender().victim_policy_name()
    }

    /// Migrations currently in the sender's table (queued + in flight).
    pub fn migrations_inflight(&self) -> usize {
        self.engine.migrations_inflight()
    }

    /// Aggregate reclaim-pipeline counters.
    pub fn migration_stats(&self) -> crate::coordinator::sender::MigStats {
        self.engine.migration_stats()
    }

    /// Milestones of completed migrations, in completion order.
    pub fn migration_records(
        &self,
    ) -> &[crate::coordinator::sender::MigrationRecord] {
        self.engine.migration_records()
    }

    /// Host free pages currently granted to the mempool's cap.
    pub fn host_free_pages(&self) -> u64 {
        self.engine.host_free_pages()
    }

    /// Update host free memory (container churn on the sender node); the
    /// next pump's grow/shrink check runs against this value.
    pub fn set_host_free_pages(&mut self, pages: u64) {
        self.engine.set_host_free_pages(pages);
    }

    /// Pages the host arbiter currently leases to this tenant's mempool
    /// (`u64::MAX` when unleased — single-tenant operation).
    pub fn lease_pages(&self) -> u64 {
        self.engine.lease_pages()
    }

    /// Update the arbiter lease: the mempool's effective cap becomes
    /// `min(max_pool_pages, host_free_fraction × host free, lease)`.
    /// The next pump enforces a lowered lease by shrinking free slots
    /// and, if that is not enough, donating idle remote-durable pages
    /// back to the host pool (see [`Self::donate_idle_pages`]).
    pub fn set_lease_pages(&mut self, pages: u64) {
        self.engine.set_lease_pages(pages);
    }

    /// Give back up to `want` idle (remote-durable, least-recently-used)
    /// pages to the host pool, dropping their GPT entries — subsequent
    /// reads of those pages are served remotely. Returns pages donated.
    pub fn donate_idle_pages(&mut self, want: u64) -> u64 {
        self.engine.donate_idle_pages(want)
    }

    /// Run metrics.
    pub fn metrics(&self) -> &RunMetrics {
        &self.engine.shard(0).metrics
    }

    /// Mutable run metrics.
    pub fn metrics_mut(&mut self) -> &mut RunMetrics {
        &mut self.engine.shard_mut(0).metrics
    }

    // -- the front-end request path -----------------------------------

    /// Front-end write (swap-out): the Figure-7 critical path — GPT
    /// insert, copy into the mempool (with grow/backpressure per §3.4),
    /// staging-queue push — then the request ends; the remote sender
    /// drains in the background.
    pub fn write(
        &mut self,
        cl: &mut ClusterState,
        now: Ns,
        page: u64,
        bytes: u64,
    ) -> Access {
        self.engine.write(cl, now, page, bytes)
    }

    /// Front-end read (swap-in): GPT lookup → mempool hit, else the
    /// miss pipeline — coalesce with an in-flight fetch of the same
    /// page, else one-sided RDMA READ from the unit's primary, else
    /// disk (Table 3 fallback) — with the stride prefetcher watching
    /// the miss stream when enabled (`valet.prefetch`).
    pub fn read(&mut self, cl: &mut ClusterState, now: Ns, page: u64) -> Access {
        self.engine.read(cl, now, page)
    }

    /// Front-end block read: every page of the request served in one
    /// slow-path crossing, missing pages fetched with one per-unit
    /// batched RDMA READ instead of one round trip per page (see
    /// [`crate::engine::ShardedEngine::read_block`]).
    pub fn read_block(
        &mut self,
        cl: &mut ClusterState,
        now: Ns,
        page: u64,
        bytes: u64,
    ) -> Access {
        self.engine.read_block(cl, now, page, bytes)
    }

    /// Drive background machinery up to `now`: remote-sender drain plus
    /// the mempool's shrink check against current host pressure (§3.4).
    /// When free-slot shrinking cannot reach the effective cap (a
    /// lowered arbiter lease or collapsed host free memory with a full
    /// pool), idle remote-durable pages are donated back to the host.
    pub fn pump(&mut self, cl: &mut ClusterState, now: Ns) {
        self.engine.pump(cl, now);
    }

    /// A peer needs `bytes` of its donated memory back (§3.5): select
    /// victims via the pluggable policy and enqueue a live migration
    /// state machine per victim; the machines advance on subsequent
    /// [`Self::pump`] calls, overlapping demand traffic. Delete stays
    /// the synchronous last resort (no destination with room).
    pub fn remote_pressure(
        &mut self,
        cl: &mut ClusterState,
        now: Ns,
        node: NodeId,
        bytes: u64,
    ) -> PressureOutcome {
        self.engine.remote_pressure(cl, now, node, bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backends::Source;
    use crate::config::Config;
    use crate::eviction::BatchedQueryRandom;
    use crate::placement::RoundRobin;
    use crate::sim::{ms, secs, us};
    use crate::PAGE_SIZE;

    fn setup() -> (Config, ClusterState, Coordinator) {
        let mut cfg = Config::default();
        cfg.cluster.nodes = 4;
        cfg.valet.min_pool_pages = 64;
        cfg.valet.max_pool_pages = 64;
        cfg.valet.mr_block_bytes = 1 << 20; // 1 MB units for fast tests
        let cl = ClusterState::new(&cfg);
        let co = Coordinator::new(&cfg);
        (cfg, cl, co)
    }

    #[test]
    fn write_completes_locally_in_microseconds() {
        let (_cfg, mut cl, mut co) = setup();
        let a = co.write(&mut cl, 0, 0, 64 * 1024);
        assert_eq!(a.source, Source::LocalPool);
        // Table 7a: write total ≈ 35.31 µs (radix 23.9 + copy 9.73 +
        // enqueue 1.68)
        let total = a.end;
        assert!(
            (total as f64 - 35_310.0).abs() < 500.0,
            "write latency {total}"
        );
        // connection/mapping must NOT be on the critical path
        assert!(total < ms(1));
    }

    #[test]
    fn read_after_write_hits_local_pool() {
        let (_cfg, mut cl, mut co) = setup();
        let w = co.write(&mut cl, 0, 0, 64 * 1024);
        let r = co.read(&mut cl, w.end, 0);
        assert_eq!(r.source, Source::LocalPool);
        // Table 7a: local hit = radix 1.39 + copy 2.11 = 3.5 µs
        let lat = r.end - w.end;
        assert!((lat as f64 - 3_500.0).abs() < 200.0, "local read {lat}");
    }

    #[test]
    fn evicted_pages_read_from_remote() {
        let (_cfg, mut cl, mut co) = setup();
        // Fill the 64-page pool far beyond capacity so early pages get
        // recycled after their batches complete.
        let mut t = 0;
        for blk in 0..40u64 {
            let a = co.write(&mut cl, t, blk * 16, 16 * PAGE_SIZE);
            t = a.end;
        }
        // let background sending finish
        t += secs(2);
        co.pump(&mut cl, t);
        // force reclaim of everything reclaimable by writing more
        for blk in 40..44u64 {
            let a = co.write(&mut cl, t, blk * 16, 16 * PAGE_SIZE);
            t = a.end;
        }
        t += secs(2);
        co.pump(&mut cl, t);
        // page 0 should long be evicted from the pool → remote read
        let r = co.read(&mut cl, t, 0);
        assert_eq!(r.source, Source::Remote, "metrics: {:?}", co.metrics());
        // Table 7a remote read ≈ 36.5 rdma + 2.13 copy + 0.14 mrpool
        let lat = r.end - t;
        assert!((lat as f64 - 41_000.0).abs() < 5_000.0, "remote {lat}");
        assert!(co.metrics().remote_hits > 0);
    }

    #[test]
    fn connection_mapping_hidden_from_write_path() {
        let (_cfg, mut cl, mut co) = setup();
        // First-ever write triggers connection (200 ms) + mapping (62 ms)
        // on the background; the write itself returns in ~35 µs.
        let a = co.write(&mut cl, 0, 0, 64 * 1024);
        assert!(a.end < us(100));
        assert!(co.mapped_units() <= 1); // mapping may lag the write
        // after pumping past the window the unit exists
        co.pump(&mut cl, ms(400));
        assert_eq!(co.mapped_units(), 1);
        assert_eq!(cl.fabric.connections_made, 1);
    }

    #[test]
    fn migration_drives_state_machine_and_keeps_data_readable() {
        let (_cfg, mut cl, mut co) = setup();
        let mut t = 0;
        for blk in 0..40u64 {
            let a = co.write(&mut cl, t, blk * 16, 16 * PAGE_SIZE);
            t = a.end;
        }
        t += secs(2);
        co.pump(&mut cl, t);
        // find which node holds unit 0 and pressure it
        let holder = co.units().get(0).map(|u| u.nodes[0]).unwrap();
        let out = co.remote_pressure(&mut cl, t, holder, 1);
        assert!(out.migrated >= 1);
        assert_eq!(out.deleted, 0);
        // the machine is enqueued, not driven: only pump ticks move it
        assert_eq!(co.migrations_inflight(), out.migrated as usize);
        assert_eq!(co.migration_stats().completed, 0);
        t += secs(2);
        co.pump(&mut cl, t);
        assert_eq!(co.migrations_inflight(), 0);
        let stats = co.migration_stats();
        assert_eq!(stats.completed, out.migrated as u64);
        // the migrated unit carries the park-window write lock and its
        // milestones are ordered like the protocol demands
        let rec = co.migration_records()[0];
        assert!(rec.park_from >= rec.activated);
        assert!(rec.copy_start >= rec.park_from);
        assert!(rec.copy_end > rec.copy_start);
        assert!(rec.done > rec.copy_end);
        assert_ne!(rec.dst, rec.src);
        let relocated = co
            .units()
            .iter()
            .any(|(_, u)| u.wlocked_until >= rec.done);
        assert!(relocated, "a unit must carry the park-window lock");
        // reads of migrated data still come from remote (never disk)
        let before = co.metrics().disk_reads;
        let mut tt = t;
        for p in [0u64, 1, 17, 33, 65, 129] {
            let rr = co.read(&mut cl, tt, p);
            tt = rr.end;
            assert_ne!(rr.source, Source::Disk, "page {p}");
        }
        assert_eq!(co.metrics().disk_reads, before);
    }

    #[test]
    fn victim_policy_hook_is_pluggable() {
        let mut cfg = Config::default();
        cfg.cluster.nodes = 4;
        cfg.valet.min_pool_pages = 64;
        cfg.valet.max_pool_pages = 64;
        cfg.valet.mr_block_bytes = 1 << 20;
        let mut cl = ClusterState::new(&cfg);
        let mut co = Coordinator::new(&cfg)
            .with_victim_policy(Box::new(BatchedQueryRandom::new(
                7,
                2,
                us(30),
            )))
            .with_placement(Box::new(RoundRobin::new()));
        assert_eq!(co.victim_policy_name(), "batched_query_random");
        let mut t = 0;
        for blk in 0..40u64 {
            let a = co.write(&mut cl, t, blk * 16, 16 * PAGE_SIZE);
            t = a.end;
        }
        t += secs(2);
        co.pump(&mut cl, t);
        let holder = co.units().get(0).map(|u| u.nodes[0]).unwrap();
        let out = co.remote_pressure(&mut cl, t, holder, 1);
        // the batched-query baseline pays per-query latency on selection
        assert!(out.migrated + out.deleted >= 1);
        assert!(out.done_at > t, "selection cost must be charged");
    }

    #[test]
    fn sync_mode_waits_for_rdma() {
        let mut cfg = Config::default();
        cfg.cluster.nodes = 3;
        cfg.valet.min_pool_pages = 0;
        cfg.valet.max_pool_pages = 0;
        cfg.valet.mr_block_bytes = 1 << 20;
        let mut cl = ClusterState::new(&cfg);
        let mut co = Coordinator::new(&cfg);
        let a = co.write(&mut cl, 0, 0, 64 * 1024);
        assert_eq!(a.source, Source::Remote);
        // first write pays connection + mapping synchronously
        assert!(a.end > ms(200));
        let b = co.write(&mut cl, a.end, 16, 64 * 1024);
        // subsequent writes still pay RDMA round trip
        assert!(b.end - a.end > us(40));
    }

    #[test]
    fn pending_write_sets_counts_staged_and_inflight() {
        let (_cfg, mut cl, mut co) = setup();
        assert_eq!(co.pending_write_sets(), 0);
        let a = co.write(&mut cl, 0, 0, 64 * 1024);
        // the opportunistic drive already moved it into flight
        assert_eq!(co.pending_write_sets(), 1);
        co.pump(&mut cl, a.end + secs(2));
        assert_eq!(co.pending_write_sets(), 0);
        assert_eq!(co.reclaimable().completed, 1);
    }

    #[test]
    fn host_pressure_shrinks_pool_but_never_below_min() {
        let mut cfg = Config::default();
        cfg.cluster.nodes = 4;
        cfg.valet.min_pool_pages = 64;
        cfg.valet.max_pool_pages = 4096;
        cfg.valet.mr_block_bytes = 1 << 20;
        let mut cl = ClusterState::new(&cfg);
        let mut co = Coordinator::new(&cfg);
        let mut t = 0;
        // grow the pool well past its floor
        for blk in 0..64u64 {
            let a = co.write(&mut cl, t, blk * 16, 16 * PAGE_SIZE);
            t = a.end;
        }
        assert!(co.mempool().capacity() > 64);
        // host free memory collapses: every subsequent pump shrinks
        // toward the floor but never below it
        co.set_host_free_pages(0);
        for step in 0..64 {
            t += secs(1);
            co.pump(&mut cl, t);
            assert!(
                co.mempool().capacity() >= co.mempool().min_pages(),
                "step {step}: capacity {} under floor",
                co.mempool().capacity()
            );
        }
    }
}
