//! The shard-local **fast path**: everything a request touches before it
//! hands off to the shared remote sender — GPT, mempool, staging queue,
//! reclaimable queue, the §5.2 page bitmaps and this shard's metrics.
//!
//! One [`ShardFastPath`] is the state a single serve worker thread owns
//! exclusively (see [`crate::serve::spawn_sharded`]): a local-cache read
//! hit completes entirely inside it, with no lock and no access to the
//! shared slow path. The single-shard [`crate::coordinator::Coordinator`]
//! owns exactly one; the [`crate::engine::ShardedEngine`] owns `S` of
//! them, page-space interleaved by stripe (see
//! [`crate::engine::ShardedEngine::shard_of`]).

use std::collections::{HashMap, VecDeque};

use crate::backends::{Access, Source};
use crate::config::LatencyConfig;
use crate::gpt::RadixGpt;
use crate::mempool::Mempool;
use crate::metrics::RunMetrics;
use crate::prefetch::{PrefetchConfig, StridePrefetcher};
use crate::queues::{ReclaimableQueue, StagingQueue, WriteSet};
use crate::sim::Ns;
use crate::util::PageBitmap;

/// Deferred activity stamps a shard buffers between slow-path
/// crossings (see [`ShardFastPath::activity_due`]); newest wins on
/// overflow.
const ACTIVITY_DUE_CAP: usize = 1024;

/// Shard-local request state: the first three Figure-7 stages (GPT →
/// mempool → staging) plus the reclaim bookkeeping those stages need.
pub struct ShardFastPath {
    /// Radix-tree Global Page Table for this shard's pages (§4.1).
    pub gpt: RadixGpt,
    /// This shard's slice of the host-coordinated mempool (§3.4).
    pub mempool: Mempool,
    /// Write sets staged for the shared remote sender.
    pub staging: StagingQueue,
    /// Write sets whose remote copies are durable.
    pub reclaim_q: ReclaimableQueue,
    /// Pages whose remote copy is valid (the §5.2 per-page bitmap).
    pub remote_ready: PageBitmap,
    /// Pages with a disk-backup copy.
    pub disk_valid: PageBitmap,
    /// This shard's run metrics (merged across shards for reporting).
    pub metrics: RunMetrics,
    /// This shard's stride prefetcher (watches this shard's miss
    /// stream; see [`crate::prefetch`]).
    pub prefetcher: StridePrefetcher,
    /// RDMA arrival times of prefetched pages not yet demanded: a
    /// demand read that beats the wire waits only for the remainder
    /// (shard-local, so the serve fast path stays lock-free). Entries
    /// are removed on first hit, overwrite, or eviction.
    pub pending_arrivals: HashMap<u64, Ns>,
    /// Prefetch-waste counter value already fed back to the prefetcher
    /// (cursor into `mempool.prefetch_evicted`).
    waste_seen: u64,
    /// A prefetch hit asked for the readahead window to be extended
    /// from this page (trend continuation). Set on the lock-free hit
    /// path; consumed by the engine's
    /// [`crate::engine::drive_readahead`] at the next opportunity that
    /// may touch the slow path.
    pub(crate) readahead_due: Option<u64>,
    /// Deferred MR-block read-activity stamps: a consumed prefetch is
    /// demand activity (§3.5), but the lock-free hit path cannot reach
    /// the cluster's MR pools — `(page, time)` pairs park here and
    /// every slow-path crossing drains them via
    /// [`crate::engine::flush_activity`]. Bounded: the oldest buffered
    /// stamp is dropped when full (O(1) on the ring) — newer stamps
    /// dominate older ones for the max-based tag, so the incoming
    /// stamp is always kept.
    pub(crate) activity_due: VecDeque<(u64, Ns)>,
    /// Reusable buffer for idle-page donation (the arbiter tick path
    /// must not allocate).
    donate_buf: Vec<u64>,
    /// Miss-path scratch: block-miss collection
    /// ([`crate::engine::shard_read_block`] pass 1).
    pub(crate) scratch_misses: Vec<u64>,
    /// Miss-path scratch: pages to batch-fetch (block pass 2 and
    /// readahead landing).
    pub(crate) scratch_fetch: Vec<u64>,
    /// Miss-path scratch: per-page completion times from
    /// [`crate::coordinator::sender::RemoteSender::read_batch`].
    pub(crate) scratch_arrivals: Vec<(u64, Ns)>,
    /// Virtual time of this shard's last audited slow-path crossing —
    /// the watermark behind [`crate::audit::Law::TimeMonotonic`]. Only
    /// advanced when [`crate::audit::enabled`].
    pub(crate) audit_last_now: Ns,
    /// Crossing counter driving the sampled deep sweep: cheap checks
    /// run on every crossing, the full O(slots) fast-path catalog every
    /// 32nd (tests and the fuzzer call [`Self::audit_check`] directly,
    /// so sampling never hides a violation from them).
    pub(crate) audit_tick: u64,
}

impl ShardFastPath {
    /// Build a shard over a `[min_pages, max_pages]` mempool slice.
    pub fn new(
        min_pages: u64,
        max_pages: u64,
        grow_threshold: f64,
        host_free_fraction: f64,
        replacement: crate::config::Replacement,
        prefetch: PrefetchConfig,
    ) -> Self {
        ShardFastPath {
            gpt: RadixGpt::new(),
            mempool: Mempool::new(
                min_pages.max(1),
                max_pages.max(1),
                grow_threshold,
                host_free_fraction,
            )
            .with_replacement(replacement),
            staging: StagingQueue::new(),
            reclaim_q: ReclaimableQueue::new(),
            remote_ready: PageBitmap::new(),
            disk_valid: PageBitmap::new(),
            metrics: RunMetrics::default(),
            prefetcher: StridePrefetcher::new(prefetch),
            pending_arrivals: HashMap::new(),
            waste_seen: 0,
            readahead_due: None,
            activity_due: VecDeque::new(),
            donate_buf: Vec::new(),
            scratch_misses: Vec::new(),
            scratch_fetch: Vec::new(),
            scratch_arrivals: Vec::new(),
            audit_last_now: 0,
            audit_tick: 0,
        }
    }

    /// Audit this shard's fast-path laws: the mempool's own catalog
    /// plus [`crate::audit::Law::GptCoherence`] — the GPT and the
    /// resident slot set must be the same bijection (`gpt.len()` equals
    /// the used-slot count and every used slot's page maps back to that
    /// slot, which by pigeonhole pins the exact mapping).
    pub fn audit_check(
        &self,
        shard: Option<usize>,
    ) -> Vec<crate::audit::Violation> {
        use crate::audit::{Law, Violation};
        let mut out = self.mempool.audit_check(shard);
        let used = self.mempool.used();
        if self.gpt.len() as u64 != used {
            out.push(Violation::new(
                Law::GptCoherence,
                shard,
                format!(
                    "GPT holds {} entries but {} mempool slots are resident",
                    self.gpt.len(),
                    used
                ),
                format!("capacity={}", self.mempool.capacity()),
            ));
        }
        self.mempool.for_each_used(|slot, page, _| {
            let mapped = self.gpt.get(page);
            if mapped != Some(slot) {
                out.push(Violation::new(
                    Law::GptCoherence,
                    shard,
                    format!(
                        "resident page {page} in slot {slot} maps to \
                         {mapped:?} in the GPT"
                    ),
                    format!("gpt_len={}", self.gpt.len()),
                ));
            }
        });
        out
    }

    /// Test-only corruption hook for
    /// [`crate::audit::Law::TimeMonotonic`]: jump the crossing
    /// watermark past any plausible virtual time, so the next audited
    /// crossing appears to travel backwards.
    #[cfg(any(feature = "audit", debug_assertions))]
    #[doc(hidden)]
    pub fn audit_warp_clock(&mut self) {
        self.audit_last_now = Ns::MAX;
    }

    /// Serve one locally-cached page: promote/score a prefetched slot
    /// (waiting out the remainder of its RDMA arrival if the demand
    /// read beat the wire) and return the time the page's data is
    /// available, given `t` = completion of the preceding stage.
    pub(crate) fn serve_cached_page(
        &mut self,
        t: Ns,
        page: u64,
        slot: u32,
    ) -> Ns {
        let mut t = t;
        if self.mempool.flags(slot).prefetched {
            match self.pending_arrivals.remove(&page) {
                Some(arrival) if arrival > t => {
                    self.metrics
                        .read_parts
                        .add("prefetch_wait", arrival - t);
                    t = arrival;
                }
                _ => {}
            }
            self.mempool.promote_prefetched(slot);
            self.metrics.prefetch_hits += 1;
            self.prefetcher.record_hit();
            // a consumed prefetch is demand activity for the block's
            // §3.5 tag — stamped on the next slow-path crossing. On
            // overflow drop an OLD buffered stamp (front), never the
            // incoming one: the tag is max-based, so newer stamps
            // strictly dominate older ones for the same block.
            if self.activity_due.len() >= ACTIVITY_DUE_CAP {
                self.activity_due.pop_front();
            }
            self.activity_due.push_back((page, t));
            // the hit confirms the trend: ask the engine to keep the
            // readahead window `degree` pages ahead
            if self.prefetcher.wants_continuation() {
                self.readahead_due = Some(page);
            }
        }
        self.mempool.touch(slot);
        self.metrics.local_hits += 1;
        t
    }

    /// The lock-free read fast path: GPT hit → serve from the mempool.
    /// Returns `None` on a miss — the caller must take the shared slow
    /// path (remote read or disk). This is the only request-path code a
    /// serve worker runs without holding the shared-state lock, which is
    /// exactly why parallel shards scale on read-heavy workloads (§4.1
    /// "parallel reads").
    pub fn try_read_local(
        &mut self,
        lat: &LatencyConfig,
        now: Ns,
        page: u64,
    ) -> Option<Access> {
        let t = now + lat.radix_lookup;
        let slot = self.gpt.lookup(page)?;
        self.metrics.read_parts.add("radix", lat.radix_lookup);
        let t = self.serve_cached_page(t, page, slot);
        let end = t + lat.copy_read_page;
        self.metrics.read_parts.add("copy", lat.copy_read_page);
        self.metrics.read_latency.record(end - now);
        Some(Access {
            end,
            source: Source::LocalPool,
        })
    }

    /// The lock-free *block* read fast path: succeeds only when every
    /// page of the block is locally cached (side-effect-free probe
    /// first, so a partial block leaves no stray metrics behind) —
    /// otherwise the caller crosses into the slow path **once** with
    /// the whole block (see [`crate::engine::shard_read_block`]). One
    /// radix descent is charged for the block: the leaf cache makes the
    /// per-page lookups O(1) (see [`RadixGpt::get`]).
    pub fn try_read_block_local(
        &mut self,
        lat: &LatencyConfig,
        now: Ns,
        page: u64,
        npages: u64,
    ) -> Option<Access> {
        for p in page..page + npages {
            self.gpt.get(p)?;
        }
        let mut t = now + lat.radix_lookup;
        self.metrics.read_parts.add("radix", lat.radix_lookup);
        for p in page..page + npages {
            let slot = self.gpt.get(p).expect("probed above");
            t = self.serve_cached_page(t, p, slot);
        }
        let copy = npages * lat.copy_read_page;
        let end = t + copy;
        self.metrics.read_parts.add("copy", copy);
        self.metrics.read_latency.record(end - now);
        self.metrics.batched_reads += 1;
        Some(Access {
            end,
            source: Source::LocalPool,
        })
    }

    /// Prefetch waste observed by the mempool but not yet folded into
    /// this shard's metrics/governor (it syncs on the next miss or
    /// readahead event; aggregate readers add this on top — see
    /// [`crate::engine::ShardedEngine::combined_metrics`]).
    pub fn unsynced_prefetch_waste(&self) -> u64 {
        self.mempool.prefetch_evicted - self.waste_seen
    }

    /// Feed newly-observed prefetch waste (pages evicted or overwritten
    /// unused since the last call) back into the prefetcher's accuracy
    /// governor and this shard's metrics.
    pub fn sync_prefetch_waste(&mut self) {
        let total = self.mempool.prefetch_evicted;
        let new = total - self.waste_seen;
        if new > 0 {
            self.waste_seen = total;
            self.metrics.prefetch_wasted += new;
            self.prefetcher.record_waste(new);
        }
    }

    /// Apply one remotely-durable write set to this shard: slots become
    /// recyclable (unless superseded — §5.2 UPDATE flag), the pages'
    /// remote copies become readable, and the set enters the reclaimable
    /// queue. Called when the owning worker drains its completion
    /// mailbox from the shared sender.
    pub fn apply_durable(&mut self, ws: WriteSet) {
        for &slot in &ws.slots {
            // marks the slot reclaimable unless a newer write set
            // superseded it (§5.2); the page itself stays cached locally
            // until the slot is recycled
            let _ = self.mempool.mark_reclaimable(slot);
        }
        for p in ws.page..ws.page + ws.pages() {
            self.remote_ready.set(p);
        }
        self.reclaim_q.push(ws);
    }

    /// Give back up to `want` idle (prefetched-unused first, then
    /// remote-durable least-recently-used) pages to the host pool,
    /// dropping their GPT entries — subsequent reads of those pages are
    /// served remotely. Returns pages donated. Allocation-free in
    /// steady state: the eviction list lives in a reusable buffer (the
    /// arbiter calls this every tick).
    pub fn donate_idle_pages(&mut self, want: u64) -> u64 {
        let ShardFastPath {
            mempool,
            gpt,
            pending_arrivals,
            donate_buf,
            ..
        } = self;
        let donated = mempool.donate_idle(want, donate_buf);
        for &p in donate_buf.iter() {
            gpt.remove(p);
            pending_arrivals.remove(&p);
        }
        donated
    }

    /// Mempool shrink check + idle donation against this shard's slice of
    /// host free memory (§3.4): free slots release first; if that cannot
    /// reach the effective cap (lowered lease / collapsed host free),
    /// idle remote-durable pages are donated back.
    pub fn resize_for_host(&mut self, host_free_pages: u64) {
        self.mempool.shrink(host_free_pages);
        let cap = self.mempool.effective_cap(host_free_pages);
        let capacity = self.mempool.capacity();
        if capacity > cap {
            self.donate_idle_pages(capacity - cap);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{LatencyConfig, Replacement};
    use crate::prefetch::PrefetchConfig;

    fn shard() -> ShardFastPath {
        ShardFastPath::new(
            8,
            64,
            0.8,
            1.0,
            Replacement::Lru,
            PrefetchConfig::default(),
        )
    }

    #[test]
    fn local_hit_needs_no_slow_path() {
        let lat = LatencyConfig::default();
        let mut s = shard();
        assert!(s.try_read_local(&lat, 0, 7).is_none());
        let a = s.mempool.alloc(7, 1 << 20).unwrap();
        s.gpt.insert(7, a.slot);
        let hit = s.try_read_local(&lat, 0, 7).unwrap();
        assert_eq!(hit.source, Source::LocalPool);
        assert_eq!(hit.end, lat.radix_lookup + lat.copy_read_page);
        assert_eq!(s.metrics.local_hits, 1);
    }

    #[test]
    fn apply_durable_reclaims_and_marks_remote_ready() {
        let mut s = shard();
        let a = s.mempool.alloc(3, 1 << 20).unwrap();
        s.gpt.insert(3, a.slot);
        s.apply_durable(WriteSet {
            page: 3,
            slots: vec![a.slot],
            bytes: 4096,
            enqueued_at: 0,
        });
        assert!(s.mempool.flags(a.slot).reclaimable);
        assert!(s.remote_ready.get(3));
        assert_eq!(s.reclaim_q.completed, 1);
    }

    #[test]
    fn block_fast_path_needs_every_page_cached() {
        let lat = LatencyConfig::default();
        let mut s = shard();
        for p in 0..4u64 {
            let a = s.mempool.alloc(p, 1 << 20).unwrap();
            s.gpt.insert(p, a.slot);
        }
        // page 4 missing: the probe must fail without touching metrics
        assert!(s.try_read_block_local(&lat, 0, 0, 5).is_none());
        assert_eq!(s.metrics.local_hits, 0);
        assert_eq!(s.metrics.read_latency.count(), 0);
        // all four cached: one radix charge + four copies
        let hit = s.try_read_block_local(&lat, 0, 0, 4).unwrap();
        assert_eq!(hit.source, Source::LocalPool);
        assert_eq!(
            hit.end,
            lat.radix_lookup + 4 * lat.copy_read_page
        );
        assert_eq!(s.metrics.local_hits, 4);
        assert_eq!(s.metrics.batched_reads, 1);
        assert_eq!(s.metrics.read_latency.count(), 1);
    }

    #[test]
    fn prefetched_hit_waits_out_arrival_and_promotes() {
        let lat = LatencyConfig::default();
        let mut s = shard();
        let a = s.mempool.alloc_prefetched(9).unwrap();
        s.gpt.insert(9, a.slot);
        s.pending_arrivals.insert(9, 50_000);
        // demand read at t=0 beats the wire: waits until 50 µs
        let hit = s.try_read_local(&lat, 0, 9).unwrap();
        assert_eq!(hit.end, 50_000 + lat.copy_read_page);
        assert_eq!(s.metrics.prefetch_hits, 1);
        assert!(s.pending_arrivals.is_empty());
        assert!(!s.mempool.flags(a.slot).prefetched, "promoted");
        // second read: plain local hit, no wait
        let again = s.try_read_local(&lat, hit.end, 9).unwrap();
        assert_eq!(
            again.end - hit.end,
            lat.radix_lookup + lat.copy_read_page
        );
        assert_eq!(s.metrics.prefetch_hits, 1);
    }

    #[test]
    fn donate_idle_drops_gpt_entries() {
        let mut s = shard();
        for p in 0..4u64 {
            let a = s.mempool.alloc(p, 1 << 20).unwrap();
            s.gpt.insert(p, a.slot);
            s.mempool.mark_reclaimable(a.slot);
        }
        let donated = s.donate_idle_pages(2);
        assert_eq!(donated, 2);
        assert_eq!(s.gpt.len(), 2);
    }
}
