//! The shard-local **fast path**: everything a request touches before it
//! hands off to the shared remote sender — GPT, mempool, staging queue,
//! reclaimable queue, the §5.2 page bitmaps and this shard's metrics.
//!
//! One [`ShardFastPath`] is the state a single serve worker thread owns
//! exclusively (see [`crate::serve::spawn_sharded`]): a local-cache read
//! hit completes entirely inside it, with no lock and no access to the
//! shared slow path. The single-shard [`crate::coordinator::Coordinator`]
//! owns exactly one; the [`crate::engine::ShardedEngine`] owns `S` of
//! them, page-space interleaved by stripe (see
//! [`crate::engine::ShardedEngine::shard_of`]).

use crate::backends::{Access, Source};
use crate::config::LatencyConfig;
use crate::gpt::RadixGpt;
use crate::mempool::Mempool;
use crate::metrics::RunMetrics;
use crate::queues::{ReclaimableQueue, StagingQueue, WriteSet};
use crate::sim::Ns;
use crate::util::PageBitmap;

/// Shard-local request state: the first three Figure-7 stages (GPT →
/// mempool → staging) plus the reclaim bookkeeping those stages need.
pub struct ShardFastPath {
    /// Radix-tree Global Page Table for this shard's pages (§4.1).
    pub gpt: RadixGpt,
    /// This shard's slice of the host-coordinated mempool (§3.4).
    pub mempool: Mempool,
    /// Write sets staged for the shared remote sender.
    pub staging: StagingQueue,
    /// Write sets whose remote copies are durable.
    pub reclaim_q: ReclaimableQueue,
    /// Pages whose remote copy is valid (the §5.2 per-page bitmap).
    pub remote_ready: PageBitmap,
    /// Pages with a disk-backup copy.
    pub disk_valid: PageBitmap,
    /// This shard's run metrics (merged across shards for reporting).
    pub metrics: RunMetrics,
}

impl ShardFastPath {
    /// Build a shard over a `[min_pages, max_pages]` mempool slice.
    pub fn new(
        min_pages: u64,
        max_pages: u64,
        grow_threshold: f64,
        host_free_fraction: f64,
        replacement: crate::config::Replacement,
    ) -> Self {
        ShardFastPath {
            gpt: RadixGpt::new(),
            mempool: Mempool::new(
                min_pages.max(1),
                max_pages.max(1),
                grow_threshold,
                host_free_fraction,
            )
            .with_replacement(replacement),
            staging: StagingQueue::new(),
            reclaim_q: ReclaimableQueue::new(),
            remote_ready: PageBitmap::new(),
            disk_valid: PageBitmap::new(),
            metrics: RunMetrics::default(),
        }
    }

    /// The lock-free read fast path: GPT hit → serve from the mempool.
    /// Returns `None` on a miss — the caller must take the shared slow
    /// path (remote read or disk). This is the only request-path code a
    /// serve worker runs without holding the shared-state lock, which is
    /// exactly why parallel shards scale on read-heavy workloads (§4.1
    /// "parallel reads").
    pub fn try_read_local(
        &mut self,
        lat: &LatencyConfig,
        now: Ns,
        page: u64,
    ) -> Option<Access> {
        let t = now + lat.radix_lookup;
        let slot = self.gpt.lookup(page)?;
        self.metrics.read_parts.add("radix", lat.radix_lookup);
        let end = t + lat.copy_read_page;
        self.metrics.read_parts.add("copy", lat.copy_read_page);
        self.mempool.touch(slot);
        self.metrics.local_hits += 1;
        self.metrics.read_latency.record(end - now);
        Some(Access {
            end,
            source: Source::LocalPool,
        })
    }

    /// Apply one remotely-durable write set to this shard: slots become
    /// recyclable (unless superseded — §5.2 UPDATE flag), the pages'
    /// remote copies become readable, and the set enters the reclaimable
    /// queue. Called when the owning worker drains its completion
    /// mailbox from the shared sender.
    pub fn apply_durable(&mut self, ws: WriteSet) {
        for &slot in &ws.slots {
            // marks the slot reclaimable unless a newer write set
            // superseded it (§5.2); the page itself stays cached locally
            // until the slot is recycled
            let _ = self.mempool.mark_reclaimable(slot);
        }
        for p in ws.page..ws.page + ws.pages() {
            self.remote_ready.set(p);
        }
        self.reclaim_q.push(ws);
    }

    /// Give back up to `want` idle (remote-durable, least-recently-used)
    /// pages to the host pool, dropping their GPT entries — subsequent
    /// reads of those pages are served remotely. Returns pages donated.
    pub fn donate_idle_pages(&mut self, want: u64) -> u64 {
        let evicted = self.mempool.donate_idle(want);
        for p in &evicted {
            self.gpt.remove(*p);
        }
        evicted.len() as u64
    }

    /// Mempool shrink check + idle donation against this shard's slice of
    /// host free memory (§3.4): free slots release first; if that cannot
    /// reach the effective cap (lowered lease / collapsed host free),
    /// idle remote-durable pages are donated back.
    pub fn resize_for_host(&mut self, host_free_pages: u64) {
        self.mempool.shrink(host_free_pages);
        let cap = self.mempool.effective_cap(host_free_pages);
        let capacity = self.mempool.capacity();
        if capacity > cap {
            self.donate_idle_pages(capacity - cap);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{LatencyConfig, Replacement};

    fn shard() -> ShardFastPath {
        ShardFastPath::new(8, 64, 0.8, 1.0, Replacement::Lru)
    }

    #[test]
    fn local_hit_needs_no_slow_path() {
        let lat = LatencyConfig::default();
        let mut s = shard();
        assert!(s.try_read_local(&lat, 0, 7).is_none());
        let a = s.mempool.alloc(7, 1 << 20).unwrap();
        s.gpt.insert(7, a.slot);
        let hit = s.try_read_local(&lat, 0, 7).unwrap();
        assert_eq!(hit.source, Source::LocalPool);
        assert_eq!(hit.end, lat.radix_lookup + lat.copy_read_page);
        assert_eq!(s.metrics.local_hits, 1);
    }

    #[test]
    fn apply_durable_reclaims_and_marks_remote_ready() {
        let mut s = shard();
        let a = s.mempool.alloc(3, 1 << 20).unwrap();
        s.gpt.insert(3, a.slot);
        s.apply_durable(WriteSet {
            page: 3,
            slots: vec![a.slot],
            bytes: 4096,
            enqueued_at: 0,
        });
        assert!(s.mempool.flags(a.slot).reclaimable);
        assert!(s.remote_ready.get(3));
        assert_eq!(s.reclaim_q.completed, 1);
    }

    #[test]
    fn donate_idle_drops_gpt_entries() {
        let mut s = shard();
        for p in 0..4u64 {
            let a = s.mempool.alloc(p, 1 << 20).unwrap();
            s.gpt.insert(p, a.slot);
            s.mempool.mark_reclaimable(a.slot);
        }
        let donated = s.donate_idle_pages(2);
        assert_eq!(donated, 2);
        assert_eq!(s.gpt.len(), 2);
    }
}
