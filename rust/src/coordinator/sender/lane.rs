//! One **sender lane**: the per-remote-peer slice of the slow path.
//!
//! Each lane owns exactly the state whose ordering is per-peer in the
//! real system — the peer's sender-thread timeline (its QP's submission
//! clock), the in-flight RDMA batches posted on it, the in-flight read
//! table for pages resident on the peer, and the migration machines
//! whose *source* block lives there. Everything whose ordering is
//! genuinely cross-peer (unit map, placement, commit ledger, per-shard
//! completion mailboxes) lives in the [`super::seq::Sequencer`]
//! instead; the [`super::RemoteSender`] facade routes between them.
//!
//! A lane never looks at another lane: all cross-lane iteration (global
//! migration scheduling, diagnostics sums) happens in the facade, which
//! is what keeps "one peer ↔ one timeline" an enforceable ownership
//! boundary rather than a convention.

use std::collections::{HashMap, VecDeque};

use crate::backends::{ClusterState, UnitMap};
use crate::migration::{MigState, MigrationSm};
use crate::mrpool::{MemTier, MrBlockId};
use crate::queues::WriteSet;
use crate::sim::{Ns, Server};
use crate::NodeId;

/// One coalesced RDMA message in flight on a lane: completion time, the
/// shard its write sets belong to, and the sets themselves.
#[derive(Clone, Debug)]
pub(crate) struct Inflight {
    pub(crate) done: Ns,
    pub(crate) shard: usize,
    pub(crate) sets: Vec<WriteSet>,
}

/// One live migration in a lane's migration table: a [`MigrationSm`]
/// plus the virtual-time milestones of the phase it is currently in.
/// The machine lives in the lane of its *source* peer (write batches
/// route by primary, so parking finds it without a cross-lane search),
/// but activation order, the concurrency cap and the commit ledger stay
/// global in the sequencer — `seq` is the global submission stamp that
/// keeps cross-lane scheduling identical to the pre-split single table.
pub(crate) struct ActiveMigration {
    /// The Figure-14 protocol machine.
    pub(crate) sm: MigrationSm,
    /// Address-space unit whose replica slot is moving.
    pub(crate) unit: u64,
    /// Node losing the block.
    pub(crate) src: NodeId,
    /// Victim MR block on `src`.
    pub(crate) src_block: MrBlockId,
    /// Memory tier the victim block lives in on `src`.
    pub(crate) src_tier: MemTier,
    /// Memory tier the replacement block is registered in on `dst`.
    pub(crate) dst_tier: MemTier,
    /// Block size (bytes copied, bytes reclaimed).
    pub(crate) block_bytes: u64,
    /// Victim selected / machine enqueued at this time.
    pub(crate) scheduled: Ns,
    /// Destination, chosen at activation (pressure-aware placement).
    pub(crate) dst: Option<NodeId>,
    /// Fresh MR block on `dst`, registered when the copy starts.
    pub(crate) dst_block: Option<MrBlockId>,
    /// Left the queue (got a concurrency slot) at this time.
    pub(crate) activated: Ns,
    /// Writes park from here (candidate queries done, PREPARE sent).
    pub(crate) park_from: Ns,
    /// Bulk copy src→dst milestones.
    pub(crate) copy_start: Ns,
    pub(crate) copy_end: Ns,
    /// Current phase's work completes at this time.
    pub(crate) phase_done: Ns,
    /// Write sets parked while the block migrates, with their owning
    /// shard; flushed to the destination at COMMIT.
    pub(crate) parked: Vec<(usize, WriteSet)>,
    /// Total bytes parked (sizing the flush message).
    pub(crate) parked_bytes: u64,
    /// Global submission stamp (sequencer-issued, monotone): the
    /// cross-lane activation and stepping order.
    pub(crate) seq: u64,
    /// A re-replication copy (failure-domain layer): the source block
    /// is *not* released at COMMIT and the destination is **appended**
    /// as a new replica slot instead of remapping the source slot —
    /// the unit gains a copy rather than moving one.
    pub(crate) repair: bool,
    /// Pinned destination (join rebalancing): activation tries this
    /// node's candidate first instead of the placement policy's pick.
    pub(crate) forced_dst: Option<NodeId>,
}

impl ActiveMigration {
    /// Holds a concurrency slot: the machine left `ChoosingDest` (its
    /// destination is chosen, PREPARE is out). Derived from the state
    /// machine so it can never drift from the protocol.
    pub(crate) fn is_active(&self) -> bool {
        self.sm.state() != MigState::ChoosingDest
    }
}

/// Prune a lane's in-flight read table once it reaches this size (stale
/// entries — completions in the past — are dropped; live ones kept).
const INFLIGHT_READS_PRUNE: usize = 4096;

/// Capacity of one lane's admission ring, in entries. An admission that
/// would overflow is refused (the caller leaves its sets staged and the
/// pump's locked drive path sends them), so the ring is a bounded queue
/// with graceful fallback, never a loss point.
pub(crate) const RING_CAP: usize = 1024;

/// One entry in a lane's slow-path **admission ring**: a pre-coalesced
/// same-unit write batch handed from a shard worker (which owns the
/// staging queue) to the lane's slow-path drain. All fast-path
/// bookkeeping (staging pops, disk-valid stamping, shard metrics)
/// happened at admission time, on the side that owns the fast path; the
/// drain side needs only the cluster substrate and the sender.
#[derive(Clone, Debug)]
pub(crate) struct RingEntry {
    /// Shard whose staging queue produced the batch (completion
    /// mailbox routing).
    pub(crate) shard: usize,
    /// Address-space unit every set in the batch targets.
    pub(crate) unit: u64,
    /// Total payload bytes (one coalesced RDMA message).
    pub(crate) bytes: u64,
    /// Latest `enqueued_at` among the sets: the batch may not be wired
    /// before this virtual time (mirrors the staged-send gate).
    pub(crate) enq: Ns,
    /// The write sets themselves, staging order.
    pub(crate) sets: Vec<WriteSet>,
}

/// One lane's bounded admission ring plus its conservation counters
/// (in **sets**, monotone): `admitted == drained + Σ queued` at every
/// consistent point — [`crate::audit::Law::LaneLockCoherence`]. This is
/// the per-lane *locked* state of the concurrent serve slow path: shard
/// workers push under the ring's own mutex (never holding the
/// sequencer), the per-lane drain pops under sequencer → ring order.
#[derive(Debug, Default)]
pub(crate) struct LaneRing {
    /// Queued batches, admission order.
    pub(crate) q: VecDeque<RingEntry>,
    /// Write sets ever admitted (monotone).
    pub(crate) admitted: u64,
    /// Write sets ever popped for dispatch (monotone; a popped set is
    /// synchronously wired, parked, or completed before the ring lock
    /// is released).
    pub(crate) drained: u64,
}

impl LaneRing {
    /// Fresh empty ring.
    pub(crate) fn new() -> Self {
        LaneRing::default()
    }

    /// Admit a batch; at capacity the entry is handed back untouched
    /// (`Some`) and the caller keeps its sets.
    pub(crate) fn admit(&mut self, e: RingEntry) -> Option<RingEntry> {
        if self.q.len() >= RING_CAP {
            return Some(e);
        }
        self.admitted += e.sets.len() as u64;
        self.q.push_back(e);
        None
    }

    /// Write sets currently queued (the audit recount).
    pub(crate) fn queued_sets(&self) -> u64 {
        self.q.iter().map(|e| e.sets.len() as u64).sum()
    }
}

/// Per-peer lane state (see the module docs for the ownership split).
pub(crate) struct SenderLane {
    /// This peer's sender-timeline clock (one batch in service at a
    /// time; batches pipeline on the NIC beneath it). Lanes advance
    /// independently — the single-channel serialization the pre-split
    /// sender imposed across peers is gone by construction.
    pub(crate) thread: Server,
    /// In-flight coalesced RDMA batches posted on this lane.
    pub(crate) inflight: Vec<Inflight>,
    /// In-flight remote reads on this peer, page → completion time: a
    /// miss that overlaps an outstanding fetch of the same page *in
    /// virtual time* piggybacks on it (miss coalescing) instead of
    /// posting a duplicate RDMA READ, and a readahead proposal covering
    /// the page free-rides on it without posting any wire work.
    /// Entries whose completion has passed are pruned lazily.
    pub(crate) inflight_reads: HashMap<u64, Ns>,
    /// Migration machines whose source block lives on this lane's peer.
    pub(crate) migs: Vec<ActiveMigration>,
}

impl SenderLane {
    /// Fresh idle lane.
    pub(crate) fn new() -> Self {
        SenderLane {
            thread: Server::new(),
            inflight: Vec::new(),
            inflight_reads: HashMap::new(),
            migs: Vec::new(),
        }
    }

    /// When this lane's sender timeline is next idle.
    pub(crate) fn busy_until(&self) -> Ns {
        self.thread.busy_until()
    }

    /// Earliest completion among this lane's in-flight batches carrying
    /// `shard`'s write sets.
    pub(crate) fn inflight_min_done(&self, shard: usize) -> Option<Ns> {
        self.inflight
            .iter()
            .filter(|f| f.shard == shard)
            .map(|f| f.done)
            .min()
    }

    /// Apply completions of this lane's in-flight batches up to `now`:
    /// stamp activity tags on the primary blocks and move each
    /// completed write set into its shard's sequencer mailbox (the
    /// owning shard applies it via
    /// [`crate::coordinator::fast::ShardFastPath::apply_durable`] when
    /// it next drains the mailbox).
    pub(crate) fn complete_inflight(
        &mut self,
        units: &UnitMap,
        done: &mut [Vec<WriteSet>],
        cl: &mut ClusterState,
        now: Ns,
    ) {
        let mut i = 0;
        while i < self.inflight.len() {
            if self.inflight[i].done <= now {
                let inflight = self.inflight.swap_remove(i);
                for ws in inflight.sets {
                    // stamp activity tags on the primary block
                    let unit = units.unit_of(ws.page);
                    if let Some(u) = units.get(unit) {
                        if let (Some(&n), Some(&b)) =
                            (u.nodes.first(), u.blocks.first())
                        {
                            cl.mrpools[n].touch_write(b, inflight.done);
                        }
                    }
                    done[inflight.shard].push(ws);
                }
            } else {
                i += 1;
            }
        }
    }

    /// If `page` has an outstanding remote fetch on this lane
    /// completing *after* `now`, return its completion time. An entry
    /// whose completion has passed is pruned and `None` returned: the
    /// fetched data was never installed locally (remote reads are
    /// read-through), so a later miss must fetch again.
    pub(crate) fn inflight_read_done(
        &mut self,
        page: u64,
        now: Ns,
    ) -> Option<Ns> {
        match self.inflight_reads.get(&page) {
            Some(&done) if done > now => Some(done),
            Some(_) => {
                self.inflight_reads.remove(&page);
                None
            }
            None => None,
        }
    }

    /// Record an outstanding remote read of `page` completing at
    /// `done`, so overlapping misses on the same page can coalesce.
    pub(crate) fn note_inflight_read(&mut self, now: Ns, page: u64, done: Ns) {
        if self.inflight_reads.len() >= INFLIGHT_READS_PRUNE {
            self.inflight_reads.retain(|_, d| *d > now);
        }
        self.inflight_reads.insert(page, done);
    }
}
