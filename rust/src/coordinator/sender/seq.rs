//! The **global sequencer**: the thin cross-peer remainder of the slow
//! path after the per-peer lane split.
//!
//! Only state whose ordering is genuinely cross-peer lives here:
//!
//! * the **unit map** and the placement / replication decisions that
//!   write it (a unit's replica set spans peers, so two lanes mapping
//!   concurrently must agree through one map);
//! * the per-shard **completion mailboxes** (a shard drains one FIFO
//!   regardless of which lane completed the batch);
//! * the migration **commit ledger** — submission stamps, the global
//!   concurrency-slot clock (`mig_slot_free`), the COMMIT ticket
//!   counter, completed-migration records and aggregate stats. COMMIT
//!   remaps the unit's replica slot, which is a cross-peer operation by
//!   definition (src lane loses the block, dst lane gains it).
//!
//! Everything else — timelines, in-flight batches, read tables, live
//! migration machines — is lane-local ([`super::lane::SenderLane`]).
//! The ledger invariant (`commit_seq == completed == records`) is the
//! [`crate::audit::Law::LaneSequencer`] law.
//!
//! Under `serve::spawn_sharded` the whole sequencer (this struct plus
//! every lane) lives behind **one** mutex — the "sequencer lock" of the
//! concurrent slow path. The per-lane admission rings
//! ([`super::lane::LaneRing`]) sit *outside* it, each behind its own
//! small mutex, so shard workers can hand off write sets without
//! touching cross-peer state. The lock order is fixed: sequencer first,
//! then at most one ring (the drain side); never ring → sequencer and
//! never ring → ring. [`crate::audit::Law::LaneLockCoherence`] pins the
//! hand-off conservation (`admitted == drained + queued`) per ring.

use std::collections::HashMap;

use crate::backends::{ClusterState, Unit, UnitMap};
use crate::config::Config;
use crate::eviction::{ActivityBased, VictimPolicy};
use crate::mrpool::{MemTier, MrBlockId};
use crate::placement::{Candidate, LeastPressured, Placed, Placement, PowerOfTwo};
use crate::queues::WriteSet;
use crate::replication::choose_replicas;
use crate::sim::Ns;
use crate::NodeId;

/// Milestones of one completed migration (diagnostics + the
/// `tests/reclaim.rs` oracle pin against [`crate::migration::simulate`]).
#[derive(Clone, Copy, Debug)]
pub struct MigrationRecord {
    /// Address-space unit that moved.
    pub unit: u64,
    /// Source peer.
    pub src: NodeId,
    /// Destination peer.
    pub dst: NodeId,
    /// Memory tier the victim block lived in on `src`.
    pub src_tier: MemTier,
    /// Memory tier the replacement block was registered in on `dst`.
    pub dst_tier: MemTier,
    /// Bytes moved.
    pub block_bytes: u64,
    /// Victim selected at this time.
    pub scheduled: Ns,
    /// Concurrency slot acquired (candidate queries start here).
    pub activated: Ns,
    /// Writes parked from here (Figure 12's window opens).
    pub park_from: Ns,
    /// Bulk copy milestones.
    pub copy_start: Ns,
    /// Copy finished; source memory free from here.
    pub copy_end: Ns,
    /// COMMIT acked; unit remapped, parked writes flushed.
    pub done: Ns,
    /// Write sets that parked against this migration and flushed at
    /// COMMIT.
    pub parked_flushed: u64,
}

/// Peer liveness states of the keep-alive ledger (failure-domain
/// layer). Transitions happen only inside the single cluster-event
/// application loop, so every lane observes one global timestamp order
/// of deaths and joins.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Health {
    /// Events from this peer arrive on schedule.
    Healthy,
    /// Missed `health.max_missed` expected cluster events: excluded
    /// from *new* placement, but its replicas still serve reads.
    Suspect,
    /// Missed `2 × max_missed` events, or explicitly killed
    /// ([`crate::cluster::ClusterEvent::PeerDown`]): its memory is
    /// gone — slots purge, reads fail over, migrations re-target.
    Dead,
}

/// The keep-alive ledger: per-peer [`Health`] driven by cluster-event
/// arrivals. Every applied event is one "expected event" tick — the
/// originating peer proves itself alive, everyone else ages by one
/// missed event. Disabled (`valet.health.enabled = false`, the
/// default), the ledger never ticks and every peer stays Healthy:
/// bit-for-bit the PR-8 system.
pub(crate) struct HealthLedger {
    /// Master switch (`valet.health.enabled`).
    pub(crate) enabled: bool,
    /// Missed-event threshold for Healthy → Suspect (Dead at double).
    max_missed: u64,
    /// Per-node `(state, missed count)`; the sender never ages.
    states: Vec<(Health, u64)>,
}

impl HealthLedger {
    fn new(cfg: &Config) -> Self {
        HealthLedger {
            enabled: cfg.valet.health.enabled,
            max_missed: cfg.valet.health.max_missed.max(1),
            states: vec![(Health::Healthy, 0); cfg.cluster.nodes],
        }
    }

    /// Current state of `node` (Healthy for any out-of-range id, so
    /// diagnostics can probe freely).
    pub(crate) fn state(&self, node: NodeId) -> Health {
        self.states.get(node).map_or(Health::Healthy, |s| s.0)
    }

    /// May `node`'s replicas serve reads? (Not Dead — a Suspect peer's
    /// data is still there until it is declared gone.)
    pub(crate) fn alive(&self, node: NodeId) -> bool {
        self.state(node) != Health::Dead
    }

    /// May *new* data be placed on `node`? (Healthy only — placing on
    /// a Suspect peer gambles fresh writes on a likely death.)
    pub(crate) fn placeable(&self, node: NodeId) -> bool {
        self.state(node) == Health::Healthy
    }

    /// One applied cluster event: `origin` (if any) resets its missed
    /// counter (Suspect recovers; Dead stays dead until an explicit
    /// join), every other peer ages one missed event. Returns the
    /// peers that crossed into Dead on this tick, in node order.
    pub(crate) fn tick(
        &mut self,
        sender: NodeId,
        origin: Option<NodeId>,
    ) -> Vec<NodeId> {
        if !self.enabled {
            return Vec::new();
        }
        let mut newly_dead = Vec::new();
        for (n, entry) in self.states.iter_mut().enumerate() {
            if n == sender || entry.0 == Health::Dead {
                continue;
            }
            if origin == Some(n) {
                *entry = (Health::Healthy, 0);
                continue;
            }
            entry.1 += 1;
            if entry.1 >= 2 * self.max_missed {
                entry.0 = Health::Dead;
                newly_dead.push(n);
            } else if entry.1 >= self.max_missed {
                entry.0 = Health::Suspect;
            }
        }
        newly_dead
    }

    /// Explicit kill ([`crate::cluster::ClusterEvent::PeerDown`]).
    /// Returns false if the peer was already Dead (idempotent).
    pub(crate) fn kill(&mut self, node: NodeId) -> bool {
        if !self.enabled {
            return false;
        }
        match self.states.get_mut(node) {
            Some(entry) if entry.0 != Health::Dead => {
                *entry = (Health::Dead, 0);
                true
            }
            _ => false,
        }
    }

    /// Explicit join ([`crate::cluster::ClusterEvent::PeerJoin`]).
    /// Returns true when the peer was Dead (a *fresh* join with an
    /// empty pool, triggering rebalance); a join event for a live peer
    /// is just a keep-alive.
    pub(crate) fn revive(&mut self, node: NodeId) -> bool {
        if !self.enabled {
            return false;
        }
        match self.states.get_mut(node) {
            Some(entry) => {
                let was_dead = entry.0 == Health::Dead;
                *entry = (Health::Healthy, 0);
                was_dead
            }
            None => false,
        }
    }

    /// Corruption hook for the negative audit tests: mark `node` Dead
    /// *without* running the death sweep, leaving unit slots pointing
    /// at a dead peer.
    #[cfg(any(feature = "audit", debug_assertions))]
    pub(crate) fn force_dead(&mut self, node: NodeId) {
        if let Some(entry) = self.states.get_mut(node) {
            *entry = (Health::Dead, 0);
        }
    }
}

/// Aggregate reclaim-pipeline counters (sequencer-global — migrations
/// belong to the shared slow path, not to any one shard's `RunMetrics`).
#[derive(Clone, Copy, Debug, Default)]
pub struct MigStats {
    /// Migrations enqueued by pressure episodes.
    pub started: u64,
    /// Migrations that reached COMMIT.
    pub completed: u64,
    /// Victims deleted instead (no destination with room).
    pub deleted: u64,
    /// Write sets parked against in-flight migrations.
    pub parked_sets: u64,
    /// Parked write sets flushed to their destination at COMMIT.
    pub flushed_sets: u64,
    /// Virtual time two migrations spent concurrently in flight, summed
    /// pairwise — the `reclaim` experiment's overlap evidence (0 under
    /// `max_concurrent_migrations = 1`).
    pub overlap_ns: Ns,
    /// Cross-tier moves that landed a block in the pool tier (toward
    /// the host — a hotter tier) and reached COMMIT.
    pub promotions: u64,
    /// Cross-tier moves that landed a block in the RDMA-remote tier
    /// (away from the host — a colder tier) and reached COMMIT.
    pub demotions: u64,
    /// Cross-tier moves abandoned at activation for lack of a
    /// destination with room. Unlike pressure reclaim (which deletes
    /// the victim as a last resort), a failed tier move simply leaves
    /// the block where it was.
    pub tier_canceled: u64,
    /// Pressure episodes where every candidate destination was
    /// excluded as Dead/Suspect — "the cluster is dead", as opposed to
    /// `deleted`'s "the cluster is full". The victim is still released
    /// (the pressured peer needs its memory back either way) but the
    /// episode is surfaced here instead of the generic delete count.
    pub no_candidate_dead_peers: u64,
    /// Re-replication copies committed (a unit regained a replica slot
    /// lost to a dead peer).
    pub repairs: u64,
    /// Units migrated onto a freshly joined peer by join rebalancing.
    pub rebalanced: u64,
    /// Acknowledged write sets lost to a peer death: they were parked
    /// against a migration whose unit had no surviving replica and no
    /// disk backup to flush to. The `churn` experiment gates this (and
    /// the read-side `lost_reads`) to zero under `FtPolicy.copies ≥ 2`.
    pub lost_write_sets: u64,
}

/// Cross-peer slow-path state (see the module docs for what qualifies).
pub(crate) struct Sequencer {
    /// The remote address-space unit map, shared by every lane.
    pub(crate) units: UnitMap,
    /// Pluggable placement hook (§4.3; power-of-two choices by default).
    pub(crate) placement: Box<dyn Placement + Send>,
    /// Pluggable eviction hook (§3.5; activity-based by default).
    pub(crate) victim_policy: Box<dyn VictimPolicy + Send>,
    /// Destination policy for migrations (§3.5 "less-pressured peer");
    /// defaults to [`LeastPressured`], separate from the unit-mapping
    /// placement hook so swapping one never perturbs the other.
    pub(crate) reclaim_placement: Box<dyn Placement + Send>,
    /// Owner id stamped on MR registrations (multi-tenant arbitration);
    /// `None` registers as the sender node.
    pub(crate) owner_tag: Option<NodeId>,
    /// Per-shard completion mailboxes: durable write sets waiting for
    /// their owning shard to apply them (FIFO per shard). Lanes push
    /// completions here; shards drain regardless of lane.
    pub(crate) done: Vec<Vec<WriteSet>>,
    /// Placement picks made at *routing* time for units not yet mapped:
    /// the submission layer must know a set's lane before its first
    /// batch is sent, so the primary is pre-picked here and consumed by
    /// [`Self::ensure_unit`] when the mapping actually happens. With a
    /// single lane the pick is made-and-consumed within one drive step
    /// (routing is only consulted for sendable sets), reproducing the
    /// pre-split pick order exactly. Carries the full `(node, tier)`
    /// pick so the mapping lands the primary in the tier routing chose.
    pub(crate) pending_primary: HashMap<u64, Placed>,
    /// Milestones of completed migrations, in completion order.
    pub(crate) mig_records: Vec<MigrationRecord>,
    /// Aggregate reclaim counters.
    pub(crate) mig_stats: MigStats,
    /// A queued migration may activate no earlier than this (the last
    /// time a concurrency slot freed) — keeps serialized mode
    /// (`max_concurrent_migrations = 1`) strictly back-to-back across
    /// lanes.
    pub(crate) mig_slot_free: Ns,
    /// Next migration submission stamp (monotone): reproduces the
    /// pre-split single-table insertion order across lanes.
    pub(crate) mig_seq: u64,
    /// COMMIT tickets issued. The cross-lane sequencer law
    /// ([`crate::audit::Law::LaneSequencer`]) pins this to
    /// `mig_stats.completed` and `mig_records.len()`.
    pub(crate) commit_seq: u64,
    /// Admission-predictor observation window (Pond-style): units mapped
    /// recently, with the mapping time and whether a demand read has hit
    /// them yet. Entries older than `pool_tier.predictor_window` retire
    /// into `insensitive_score`. Empty unless the pool tier (and the
    /// predictor) is enabled.
    pub(crate) recent_maps: Vec<(u64, Ns, bool)>,
    /// EWMA of the fraction of retired observation-window entries that
    /// never saw a demand read — the predicted probability that a new
    /// write set is latency-insensitive and should be placed cold-first.
    pub(crate) insensitive_score: f64,
    /// Next promotion/demotion scan fires at this virtual time.
    pub(crate) next_tier_scan: Ns,
    /// The keep-alive health ledger (failure-domain layer; inert and
    /// all-Healthy unless `valet.health.enabled`).
    pub(crate) health: HealthLedger,
    /// Units that lost a replica slot to a dead peer and await the
    /// re-replication pump (insertion order; deduplicated on push).
    pub(crate) repair_queue: Vec<u64>,
    /// Freshly joined peers awaiting join rebalancing on the next pump.
    pub(crate) pending_rebalance: Vec<NodeId>,
    /// Next re-replication scan fires at this virtual time.
    pub(crate) next_repair_scan: Ns,
}

impl Sequencer {
    /// Build the sequencer for `shards` fast paths.
    pub(crate) fn new(cfg: &Config, shards: usize) -> Self {
        Sequencer {
            units: UnitMap::new(cfg.valet.mr_block_bytes),
            placement: Box::new(PowerOfTwo::new(cfg.cluster.seed)),
            victim_policy: Box::new(ActivityBased),
            reclaim_placement: Box::new(LeastPressured::new()),
            owner_tag: None,
            done: vec![Vec::new(); shards.max(1)],
            pending_primary: HashMap::new(),
            mig_records: Vec::new(),
            mig_stats: MigStats::default(),
            mig_slot_free: 0,
            mig_seq: 0,
            commit_seq: 0,
            recent_maps: Vec::new(),
            insensitive_score: 0.0,
            next_tier_scan: cfg.valet.pool_tier.scan_period,
            health: HealthLedger::new(cfg),
            repair_queue: Vec::new(),
            pending_rebalance: Vec::new(),
            next_repair_scan: cfg.valet.health.repair_period,
        }
    }

    /// The peer that will hold (or already holds) `unit`'s primary
    /// replica — the lane-routing query. For a mapped live unit this is
    /// its primary; for an unmapped one the placement hook picks now
    /// and the pick is remembered in `pending_primary` until
    /// [`Self::ensure_unit`] consumes it, so routing and mapping can
    /// never disagree about the lane.
    pub(crate) fn primary_for(
        &mut self,
        cl: &ClusterState,
        unit: u64,
    ) -> NodeId {
        if let Some(u) = self.units.get(unit) {
            if u.alive {
                if let Some(&n) = u.nodes.first() {
                    return n;
                }
            }
        }
        if let Some(p) = self.pending_primary.get(&unit) {
            return p.node;
        }
        let primary = self.pick_primary(cl);
        self.pending_primary.insert(unit, primary);
        primary.node
    }

    /// Pick the `(node, tier)` for a new unit's primary replica. With
    /// the pool tier off the candidate list is exactly the pre-tier
    /// remote list, so the placement hook sees identical input (and the
    /// stochastic policies make identical RNG draws). With it on, the
    /// admission predictor first narrows the list.
    fn pick_primary(&mut self, cl: &ClusterState) -> Placed {
        let cands = self.health_candidates(cl.candidates());
        if cl.pool_cfg.enabled {
            let filtered = self.admission_filter(cl, &cands);
            return self
                .placement
                .pick(&filtered)
                .expect("cluster has at least one peer");
        }
        self.placement
            .pick(&cands)
            .expect("cluster has at least one peer")
    }

    /// Pond-style admission filter (pool tier on). Predicted
    /// latency-insensitive write sets are placed cold-first: only
    /// RDMA-remote candidates survive, keeping pool capacity for data
    /// the read path will actually hit. Predicted-sensitive sets prefer
    /// a pool slot with room; if none exists the full list stands. With
    /// the predictor disabled the list is untouched (naive tiering —
    /// the `no_predictor` ablation).
    fn admission_filter(
        &self,
        cl: &ClusterState,
        cands: &[Candidate],
    ) -> Vec<Candidate> {
        if !cl.pool_cfg.predictor {
            return cands.to_vec();
        }
        if self.insensitive_score > 0.5 {
            let cold: Vec<Candidate> = cands
                .iter()
                .filter(|c| c.tier == MemTier::Remote)
                .copied()
                .collect();
            if !cold.is_empty() {
                return cold;
            }
            return cands.to_vec();
        }
        let pool: Vec<Candidate> = cands
            .iter()
            .filter(|c| {
                c.tier == MemTier::Pool
                    && c.free_bytes >= self.units.unit_bytes
            })
            .copied()
            .collect();
        if pool.is_empty() {
            return cands.to_vec();
        }
        pool
    }

    /// Retire observation-window entries older than the predictor
    /// window into the insensitivity EWMA, then start observing `unit`.
    fn observe_mapping(&mut self, cl: &ClusterState, now: Ns, unit: u64) {
        if !cl.pool_cfg.enabled || !cl.pool_cfg.predictor {
            return;
        }
        let window = cl.pool_cfg.predictor_window;
        let mut i = 0;
        while i < self.recent_maps.len() {
            let (_, mapped_at, saw_read) = self.recent_maps[i];
            if mapped_at + window <= now {
                let sample = if saw_read { 0.0 } else { 1.0 };
                self.insensitive_score =
                    0.7 * self.insensitive_score + 0.3 * sample;
                self.recent_maps.remove(i);
            } else {
                i += 1;
            }
        }
        const OBSERVED_CAP: usize = 256;
        if self.recent_maps.len() >= OBSERVED_CAP {
            self.recent_maps.remove(0);
        }
        self.recent_maps.push((unit, now, false));
    }

    /// Tell the admission predictor a demand read hit `unit` — the
    /// evidence that its write set was latency-*sensitive*. No-op
    /// unless the pool tier and the predictor are on.
    pub(crate) fn note_demand_read(&mut self, cl: &ClusterState, unit: u64) {
        if !cl.pool_cfg.enabled || !cl.pool_cfg.predictor {
            return;
        }
        for entry in self.recent_maps.iter_mut() {
            if entry.0 == unit {
                entry.2 = true;
            }
        }
    }

    /// Ensure `unit` has a remote mapping; returns when it is usable.
    /// Charged on the owning *lane's* timeline by the caller — never
    /// the request path. Consumes the routing pre-pick if one exists.
    pub(crate) fn ensure_unit(
        &mut self,
        cl: &mut ClusterState,
        now: Ns,
        unit: u64,
        replicas: usize,
    ) -> Ns {
        if let Some(u) = self.units.get(unit) {
            if u.alive {
                return u.ready_at;
            }
        }
        // (Re)map: primary from the routing pre-pick (or the placement
        // hook if the unit was never routed), then replicas.
        let cands = self.health_candidates(cl.candidates());
        // a routing pre-pick is dropped if its node has since died or
        // turned Suspect — re-place through the hooks instead
        let primary = match self.pending_primary.remove(&unit) {
            Some(p)
                if !self.health.enabled
                    || self.health.placeable(p.node) =>
            {
                p
            }
            _ => self.pick_primary(cl),
        };
        self.observe_mapping(cl, now, unit);
        // Replica candidates are *nodes*: with the pool tier on a peer
        // appears once per tier, so collapse to first occurrence (an
        // identity transform with the tier off).
        let mut cand_nodes: Vec<NodeId> = Vec::with_capacity(cands.len());
        for c in &cands {
            if !cand_nodes.contains(&c.node) {
                cand_nodes.push(c.node);
            }
        }
        let nodes =
            choose_replicas(cl.sender, primary.node, &cand_nodes, replicas);
        // a mapping truncated below its copy target (deaths thinned the
        // candidates) starts life queued for the re-replication pump
        let short = nodes.len() < replicas;
        // Connection (if new) + mapping, charged sequentially per node.
        // A pool-tier primary needs no queue pair: it is mapped through
        // the pooled appliance's fabric manager (cheaper than MAP_MR).
        // Followers always land RDMA-remote — the replica set is the
        // durability story and pool capacity is for hot primaries.
        let mut t = now;
        for (i, &n) in nodes.iter().enumerate() {
            if i == 0 && primary.tier == MemTier::Pool {
                t = cl.fabric.pool_map(t, cl.sender);
            } else {
                let (tc, _newc) = cl.fabric.ensure_connected(t, cl.sender, n);
                t = cl.fabric.map_mr(tc, cl.sender);
            }
        }
        let owner = self.owner_tag.unwrap_or(cl.sender);
        let blocks = nodes
            .iter()
            .enumerate()
            .map(|(i, &n)| {
                let tier = if i == 0 { primary.tier } else { MemTier::Remote };
                cl.mrpools[n].register_tier(
                    owner,
                    self.units.unit_bytes,
                    t,
                    tier,
                )
            })
            .collect();
        self.units.insert(
            unit,
            Unit {
                nodes,
                blocks,
                ready_at: t,
                wlocked_until: 0,
                alive: true,
            },
        );
        if short {
            self.queue_repair(unit);
        }
        t
    }

    /// The delete last-resort (§3.5 "delete like the baselines"):
    /// release the victim block and drop its replica slot from the unit
    /// map. Surviving replicas keep serving reads (Table 3: replica
    /// first); only when the last copy is gone does the unit die and
    /// reads fall through to the disk backup (or are lost). Callers
    /// account the episode themselves (`deleted` for "cluster full",
    /// `no_candidate_dead_peers` for "cluster dead") — the mechanics
    /// here are shared, the diagnosis is not.
    pub(crate) fn delete_victim(
        &mut self,
        cl: &mut ClusterState,
        node: NodeId,
        block: MrBlockId,
        unit_id: Option<u64>,
    ) {
        cl.mrpools[node].release(block);
        if let Some(uid) = unit_id {
            if let Some(u) = self.units.get_mut(uid) {
                if let Some(pos) = u
                    .nodes
                    .iter()
                    .zip(u.blocks.iter())
                    .position(|(&n, &b)| n == node && b == block)
                {
                    u.nodes.remove(pos);
                    u.blocks.remove(pos);
                }
                if u.nodes.is_empty() {
                    u.alive = false;
                }
            }
        }
    }

    /// Queue `unit` for the re-replication pump (deduplicated; no-op
    /// with health off — the pump never runs then anyway).
    pub(crate) fn queue_repair(&mut self, unit: u64) {
        if self.health.enabled && !self.repair_queue.contains(&unit) {
            self.repair_queue.push(unit);
        }
    }

    /// Narrow placement candidates by peer health: Healthy nodes are
    /// the first choice; an all-Suspect cluster falls back to any
    /// non-Dead node (still accepting writes beats refusing them). The
    /// input is returned untouched when health is off — zero extra
    /// work on the default path.
    pub(crate) fn health_candidates(
        &self,
        cands: Vec<Candidate>,
    ) -> Vec<Candidate> {
        if !self.health.enabled {
            return cands;
        }
        let healthy: Vec<Candidate> = cands
            .iter()
            .filter(|c| self.health.placeable(c.node))
            .copied()
            .collect();
        if !healthy.is_empty() {
            return healthy;
        }
        let alive: Vec<Candidate> = cands
            .iter()
            .filter(|c| self.health.alive(c.node))
            .copied()
            .collect();
        if !alive.is_empty() {
            return alive;
        }
        cands
    }

    /// Issue the next migration submission stamp.
    pub(crate) fn next_mig_seq(&mut self) -> u64 {
        let s = self.mig_seq;
        self.mig_seq += 1;
        s
    }
}
