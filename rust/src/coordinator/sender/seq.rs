//! The **global sequencer**: the thin cross-peer remainder of the slow
//! path after the per-peer lane split.
//!
//! Only state whose ordering is genuinely cross-peer lives here:
//!
//! * the **unit map** and the placement / replication decisions that
//!   write it (a unit's replica set spans peers, so two lanes mapping
//!   concurrently must agree through one map);
//! * the per-shard **completion mailboxes** (a shard drains one FIFO
//!   regardless of which lane completed the batch);
//! * the migration **commit ledger** — submission stamps, the global
//!   concurrency-slot clock (`mig_slot_free`), the COMMIT ticket
//!   counter, completed-migration records and aggregate stats. COMMIT
//!   remaps the unit's replica slot, which is a cross-peer operation by
//!   definition (src lane loses the block, dst lane gains it).
//!
//! Everything else — timelines, in-flight batches, read tables, live
//! migration machines — is lane-local ([`super::lane::SenderLane`]).
//! The ledger invariant (`commit_seq == completed == records`) is the
//! [`crate::audit::Law::LaneSequencer`] law.

use std::collections::HashMap;

use crate::backends::{ClusterState, Unit, UnitMap};
use crate::config::Config;
use crate::eviction::{ActivityBased, VictimPolicy};
use crate::mrpool::MrBlockId;
use crate::placement::{LeastPressured, Placement, PowerOfTwo};
use crate::queues::WriteSet;
use crate::replication::choose_replicas;
use crate::sim::Ns;
use crate::NodeId;

/// Milestones of one completed migration (diagnostics + the
/// `tests/reclaim.rs` oracle pin against [`crate::migration::simulate`]).
#[derive(Clone, Copy, Debug)]
pub struct MigrationRecord {
    /// Address-space unit that moved.
    pub unit: u64,
    /// Source peer.
    pub src: NodeId,
    /// Destination peer.
    pub dst: NodeId,
    /// Bytes moved.
    pub block_bytes: u64,
    /// Victim selected at this time.
    pub scheduled: Ns,
    /// Concurrency slot acquired (candidate queries start here).
    pub activated: Ns,
    /// Writes parked from here (Figure 12's window opens).
    pub park_from: Ns,
    /// Bulk copy milestones.
    pub copy_start: Ns,
    /// Copy finished; source memory free from here.
    pub copy_end: Ns,
    /// COMMIT acked; unit remapped, parked writes flushed.
    pub done: Ns,
    /// Write sets that parked against this migration and flushed at
    /// COMMIT.
    pub parked_flushed: u64,
}

/// Aggregate reclaim-pipeline counters (sequencer-global — migrations
/// belong to the shared slow path, not to any one shard's `RunMetrics`).
#[derive(Clone, Copy, Debug, Default)]
pub struct MigStats {
    /// Migrations enqueued by pressure episodes.
    pub started: u64,
    /// Migrations that reached COMMIT.
    pub completed: u64,
    /// Victims deleted instead (no destination with room).
    pub deleted: u64,
    /// Write sets parked against in-flight migrations.
    pub parked_sets: u64,
    /// Parked write sets flushed to their destination at COMMIT.
    pub flushed_sets: u64,
    /// Virtual time two migrations spent concurrently in flight, summed
    /// pairwise — the `reclaim` experiment's overlap evidence (0 under
    /// `max_concurrent_migrations = 1`).
    pub overlap_ns: Ns,
}

/// Cross-peer slow-path state (see the module docs for what qualifies).
pub(crate) struct Sequencer {
    /// The remote address-space unit map, shared by every lane.
    pub(crate) units: UnitMap,
    /// Pluggable placement hook (§4.3; power-of-two choices by default).
    pub(crate) placement: Box<dyn Placement + Send>,
    /// Pluggable eviction hook (§3.5; activity-based by default).
    pub(crate) victim_policy: Box<dyn VictimPolicy + Send>,
    /// Destination policy for migrations (§3.5 "less-pressured peer");
    /// defaults to [`LeastPressured`], separate from the unit-mapping
    /// placement hook so swapping one never perturbs the other.
    pub(crate) reclaim_placement: Box<dyn Placement + Send>,
    /// Owner id stamped on MR registrations (multi-tenant arbitration);
    /// `None` registers as the sender node.
    pub(crate) owner_tag: Option<NodeId>,
    /// Per-shard completion mailboxes: durable write sets waiting for
    /// their owning shard to apply them (FIFO per shard). Lanes push
    /// completions here; shards drain regardless of lane.
    pub(crate) done: Vec<Vec<WriteSet>>,
    /// Placement picks made at *routing* time for units not yet mapped:
    /// the submission layer must know a set's lane before its first
    /// batch is sent, so the primary is pre-picked here and consumed by
    /// [`Self::ensure_unit`] when the mapping actually happens. With a
    /// single lane the pick is made-and-consumed within one drive step
    /// (routing is only consulted for sendable sets), reproducing the
    /// pre-split pick order exactly.
    pub(crate) pending_primary: HashMap<u64, NodeId>,
    /// Milestones of completed migrations, in completion order.
    pub(crate) mig_records: Vec<MigrationRecord>,
    /// Aggregate reclaim counters.
    pub(crate) mig_stats: MigStats,
    /// A queued migration may activate no earlier than this (the last
    /// time a concurrency slot freed) — keeps serialized mode
    /// (`max_concurrent_migrations = 1`) strictly back-to-back across
    /// lanes.
    pub(crate) mig_slot_free: Ns,
    /// Next migration submission stamp (monotone): reproduces the
    /// pre-split single-table insertion order across lanes.
    pub(crate) mig_seq: u64,
    /// COMMIT tickets issued. The cross-lane sequencer law
    /// ([`crate::audit::Law::LaneSequencer`]) pins this to
    /// `mig_stats.completed` and `mig_records.len()`.
    pub(crate) commit_seq: u64,
}

impl Sequencer {
    /// Build the sequencer for `shards` fast paths.
    pub(crate) fn new(cfg: &Config, shards: usize) -> Self {
        Sequencer {
            units: UnitMap::new(cfg.valet.mr_block_bytes),
            placement: Box::new(PowerOfTwo::new(cfg.cluster.seed)),
            victim_policy: Box::new(ActivityBased),
            reclaim_placement: Box::new(LeastPressured::new()),
            owner_tag: None,
            done: vec![Vec::new(); shards.max(1)],
            pending_primary: HashMap::new(),
            mig_records: Vec::new(),
            mig_stats: MigStats::default(),
            mig_slot_free: 0,
            mig_seq: 0,
            commit_seq: 0,
        }
    }

    /// The peer that will hold (or already holds) `unit`'s primary
    /// replica — the lane-routing query. For a mapped live unit this is
    /// its primary; for an unmapped one the placement hook picks now
    /// and the pick is remembered in `pending_primary` until
    /// [`Self::ensure_unit`] consumes it, so routing and mapping can
    /// never disagree about the lane.
    pub(crate) fn primary_for(
        &mut self,
        cl: &ClusterState,
        unit: u64,
    ) -> NodeId {
        if let Some(u) = self.units.get(unit) {
            if u.alive {
                if let Some(&n) = u.nodes.first() {
                    return n;
                }
            }
        }
        if let Some(&n) = self.pending_primary.get(&unit) {
            return n;
        }
        let cands = cl.candidates();
        let primary = self
            .placement
            .pick(&cands)
            .expect("cluster has at least one peer");
        self.pending_primary.insert(unit, primary);
        primary
    }

    /// Ensure `unit` has a remote mapping; returns when it is usable.
    /// Charged on the owning *lane's* timeline by the caller — never
    /// the request path. Consumes the routing pre-pick if one exists.
    pub(crate) fn ensure_unit(
        &mut self,
        cl: &mut ClusterState,
        now: Ns,
        unit: u64,
        replicas: usize,
    ) -> Ns {
        if let Some(u) = self.units.get(unit) {
            if u.alive {
                return u.ready_at;
            }
        }
        // (Re)map: primary from the routing pre-pick (or the placement
        // hook if the unit was never routed), then replicas.
        let cands = cl.candidates();
        let primary = match self.pending_primary.remove(&unit) {
            Some(n) => n,
            None => self
                .placement
                .pick(&cands)
                .expect("cluster has at least one peer"),
        };
        let cand_nodes: Vec<NodeId> = cands.iter().map(|c| c.node).collect();
        let nodes = choose_replicas(cl.sender, primary, &cand_nodes, replicas);
        // Connection (if new) + mapping, charged sequentially per node.
        let mut t = now;
        for &n in &nodes {
            let (tc, _newc) = cl.fabric.ensure_connected(t, cl.sender, n);
            t = cl.fabric.map_mr(tc, cl.sender);
        }
        let owner = self.owner_tag.unwrap_or(cl.sender);
        let blocks = nodes
            .iter()
            .map(|&n| cl.mrpools[n].register(owner, self.units.unit_bytes, t))
            .collect();
        self.units.insert(
            unit,
            Unit {
                nodes,
                blocks,
                ready_at: t,
                wlocked_until: 0,
                alive: true,
            },
        );
        t
    }

    /// The delete last-resort (§3.5 "delete like the baselines"):
    /// release the victim block and drop its replica slot from the unit
    /// map. Surviving replicas keep serving reads (Table 3: replica
    /// first); only when the last copy is gone does the unit die and
    /// reads fall through to the disk backup (or are lost).
    pub(crate) fn delete_victim(
        &mut self,
        cl: &mut ClusterState,
        node: NodeId,
        block: MrBlockId,
        unit_id: Option<u64>,
    ) {
        cl.mrpools[node].release(block);
        if let Some(uid) = unit_id {
            if let Some(u) = self.units.get_mut(uid) {
                if let Some(pos) = u
                    .nodes
                    .iter()
                    .zip(u.blocks.iter())
                    .position(|(&n, &b)| n == node && b == block)
                {
                    u.nodes.remove(pos);
                    u.blocks.remove(pos);
                }
                if u.nodes.is_empty() {
                    u.alive = false;
                }
            }
        }
        self.mig_stats.deleted += 1;
    }

    /// Issue the next migration submission stamp.
    pub(crate) fn next_mig_seq(&mut self) -> u64 {
        let s = self.mig_seq;
        self.mig_seq += 1;
        s
    }
}
