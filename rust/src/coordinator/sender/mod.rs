//! The shared **slow path**: the Remote Sender (§4.1) partitioned into
//! **per-remote-peer sender lanes** behind one facade, plus the thin
//! global [`seq::Sequencer`] for the state whose ordering is genuinely
//! cross-peer.
//!
//! One [`RemoteSender`] serves all shards. Submissions route by the
//! target unit's *primary peer* to that peer's [`lane::SenderLane`]:
//! each lane owns its peer's sender-timeline clock, its in-flight
//! coalesced batches, its in-flight read table and the migration
//! machines sourced on its peer, so batches to different peers overlap
//! — the mapping stall of a unit landing on peer A no longer serializes
//! behind it every send to peers B and C, which was the pre-split
//! single-channel bottleneck. The unit map, placement, per-shard
//! completion mailboxes and the migration commit ledger stay in the
//! sequencer (migration COMMIT / replica remap and cluster-event
//! application are cross-peer by definition).
//!
//! With `valet.sender_lanes = 1` every peer routes to one lane and the
//! engine reproduces the pre-split single-timeline sender **bit for
//! bit** — that configuration is the retained test oracle the
//! `tests/lanes.rs` differential harness pins the lane engine against
//! (the same role [`crate::migration::simulate`] plays for the
//! migration timeline).
//!
//! ## The reclaim pipeline (§3.5, pump-driven)
//!
//! Remote pressure no longer runs a migration start-to-finish inside the
//! pressure event. [`RemoteSender::remote_pressure`] only *selects*
//! victims and enqueues live [`MigrationSm`] instances into the source
//! peer's lane table; [`RemoteSender::advance_migrations`] — called
//! from every pump tick, interleaved with write batches — walks each
//! machine through PREPARE → copy → COMMIT at its own virtual-time
//! milestones. Scheduling stays **global**: sequencer-issued submission
//! stamps order activation across lanes exactly like the pre-split
//! single table, and the concurrency cap / `mig_slot_free` clock are
//! sequencer state. Up to `valet.max_concurrent_migrations` migrations
//! (on distinct blocks/peers) proceed concurrently; while one is in
//! flight, reads keep hitting the source (the unit map still points
//! there until COMMIT) and write batches targeting the migrating unit
//! are parked in the machine and flushed to the destination when COMMIT
//! lands. Delete remains the last resort when no destination has room.
//! [`crate::migration::simulate`] survives as the test oracle for the
//! single-migration timeline (`tests/reclaim.rs`).

//! ## The admission rings (concurrent serve slow path)
//!
//! With `valet.slow_path_threads != 1` every coalesced write batch
//! travels through its lane's bounded **admission ring**
//! ([`lane::LaneRing`]) before it is wired. In the simulated engine the
//! detour is synchronous — admit, then drain in the same call — so
//! virtual-time results are bit-identical to the inline path; under
//! `serve::spawn_sharded` the shard workers admit lock-free (ring mutex
//! only, never the sequencer) and dedicated per-lane slow-path threads
//! drain in batches under sequencer → ring lock order. Conservation
//! across the hand-off is [`Law::LaneLockCoherence`].

mod lane;
mod seq;

use std::sync::{Arc, Mutex};

pub use seq::{Health, MigStats, MigrationRecord};

use crate::audit::{self, Law, Violation};
use crate::backends::{ClusterState, PressureOutcome};
use crate::config::{Config, LatencyConfig, ValetConfig};
use crate::coordinator::fast::ShardFastPath;
use crate::eviction::VictimPolicy;
use crate::migration::{ctrl_rtt, MigAction, MigEvent, MigState, MigrationSm};
use crate::mrpool::{MemTier, MrBlockId, MrState};
use crate::placement::{Candidate, Placed, Placement};
use crate::queues::WriteSet;
use crate::replication::{choose_replicas, read_source, FtPolicy, ReadSource};
use crate::sim::Ns;
use crate::{NodeId, PAGE_SIZE};

use lane::{ActiveMigration, Inflight, LaneRing, RingEntry, SenderLane};
use seq::Sequencer;

/// Shared handle to the per-lane admission rings: the only sender state
/// the serve shard workers may touch without the sequencer lock.
pub(crate) type LaneRings = Arc<Vec<Mutex<LaneRing>>>;

/// Candidate peers the sender polls before choosing a migration
/// destination (the power-of-two query model the old one-shot path also
/// charged — one control RTT each, before writes park).
const MIG_QUERIES: u32 = 2;

/// Lane-count ceiling (the drive loops track seen-lanes in a u64 mask).
const MAX_LANES: usize = 64;

/// A migration machine's address: (lane index, index in that lane's
/// table).
type MigRef = (usize, usize);

/// The shared remote-sender slow path (see module docs): per-peer lanes
/// plus the global sequencer, behind the pre-split public surface.
pub struct RemoteSender {
    lat: LatencyConfig,
    vcfg: ValetConfig,
    /// Per-peer sender lanes; a peer `n` routes to lane `n % lanes.len()`.
    lanes: Vec<SenderLane>,
    /// Per-lane admission rings (see module docs): behind their own
    /// mutexes so serve workers can admit batches without the sequencer
    /// lock. Lock order is fixed — sequencer first, then at most one
    /// ring, never ring → sequencer and never ring → ring.
    rings: LaneRings,
    /// Cross-peer state: unit map, placement, mailboxes, commit ledger.
    seq: Sequencer,
    /// Audit crossings seen (drives the every-Nth thorough sweep; only
    /// advanced when [`audit::enabled`]).
    audit_tick: u64,
}

impl RemoteSender {
    /// Build the slow path for `shards` fast paths. Lane count comes
    /// from `valet.sender_lanes`: `0` means one lane per peer
    /// (`cluster.nodes - 1`); `1` is the pre-split single-timeline
    /// oracle; any other value is used as-is (capped at 64).
    pub fn new(cfg: &Config, shards: usize) -> Self {
        let peers = cfg.cluster.nodes.saturating_sub(1).max(1);
        let nlanes = match cfg.valet.sender_lanes {
            0 => peers,
            n => n,
        }
        .clamp(1, MAX_LANES);
        RemoteSender {
            lat: cfg.latency.clone(),
            vcfg: cfg.valet.clone(),
            lanes: (0..nlanes).map(|_| SenderLane::new()).collect(),
            rings: Arc::new(
                (0..nlanes).map(|_| Mutex::new(LaneRing::new())).collect(),
            ),
            seq: Sequencer::new(cfg, shards),
            audit_tick: 0,
        }
    }

    // -- configuration hooks ------------------------------------------

    /// Tag MR registrations with a distinct owner id (multi-tenant
    /// arbitration: victim selection under remote pressure then only
    /// ever sees this tenant's blocks).
    pub fn set_owner_tag(&mut self, owner: NodeId) {
        self.seq.owner_tag = Some(owner);
    }

    /// Swap in a different eviction policy (the §3.5 hook).
    pub fn set_victim_policy(&mut self, policy: Box<dyn VictimPolicy + Send>) {
        self.seq.victim_policy = policy;
    }

    /// Swap in a different placement policy (the §4.3 hook).
    pub fn set_placement(&mut self, placement: Box<dyn Placement + Send>) {
        self.seq.placement = placement;
    }

    /// Swap in a different migration-destination policy (the §3.5
    /// "less-pressured peer" hook; least-pressured by default).
    pub fn set_reclaim_placement(
        &mut self,
        placement: Box<dyn Placement + Send>,
    ) {
        self.seq.reclaim_placement = placement;
    }

    // -- lane routing -------------------------------------------------

    /// Number of sender lanes.
    pub fn lane_count(&self) -> usize {
        self.lanes.len()
    }

    /// When `lane`'s sender timeline is next idle — the per-lane gate
    /// the drive loops (and the backpressure tests) read.
    pub fn lane_busy_until(&self, lane: usize) -> Ns {
        self.lanes[lane].busy_until()
    }

    /// The lane serving peer `node`.
    fn lane_of(&self, node: NodeId) -> usize {
        node % self.lanes.len()
    }

    /// The lane that will carry `page`'s unit: its primary peer's lane.
    /// For an unmapped unit this pre-picks the primary through the
    /// sequencer (consumed later by the mapping — see
    /// [`seq::Sequencer::primary_for`]).
    pub(crate) fn route_page(
        &mut self,
        cl: &ClusterState,
        page: u64,
    ) -> usize {
        let unit = self.seq.units.unit_of(page);
        let primary = self.seq.primary_for(cl, unit);
        self.lane_of(primary)
    }

    /// The lane holding `page`'s unit if it is mapped and alive.
    fn lane_for_mapped(&self, page: u64) -> Option<usize> {
        let unit = self.seq.units.unit_of(page);
        self.seq
            .units
            .get(unit)
            .and_then(|u| {
                if u.alive {
                    u.nodes.first().copied()
                } else {
                    None
                }
            })
            .map(|n| self.lane_of(n))
    }

    // -- diagnostics --------------------------------------------------

    /// The latency model the whole pipeline is calibrated to.
    pub fn lat(&self) -> &LatencyConfig {
        &self.lat
    }

    /// Valet policy knobs.
    pub fn vcfg(&self) -> &ValetConfig {
        &self.vcfg
    }

    /// The remote address-space unit map.
    pub fn units(&self) -> &crate::backends::UnitMap {
        &self.seq.units
    }

    /// Name of the active eviction policy.
    pub fn victim_policy_name(&self) -> &'static str {
        self.seq.victim_policy.name()
    }

    /// When the *last* lane timeline goes idle (single-lane configs:
    /// exactly the pre-split sender-thread clock). Per-lane gating uses
    /// [`Self::lane_busy_until`] instead.
    pub fn busy_until(&self) -> Ns {
        self.lanes.iter().map(SenderLane::busy_until).max().unwrap_or(0)
    }

    /// Write sets carried by in-flight RDMA batches (all lanes) plus
    /// durable sets not yet applied by their shard.
    pub fn inflight_write_sets(&self) -> usize {
        self.lanes
            .iter()
            .flat_map(|l| l.inflight.iter())
            .map(|f| f.sets.len())
            .sum::<usize>()
            + self.seq.done.iter().map(|d| d.len()).sum::<usize>()
    }

    /// Earliest completion among in-flight batches (any lane) carrying
    /// `shard`'s write sets.
    pub fn inflight_min_done(&self, shard: usize) -> Option<Ns> {
        self.lanes
            .iter()
            .filter_map(|l| l.inflight_min_done(shard))
            .min()
    }

    /// Migrations currently in the lane tables (queued + in flight).
    pub fn migrations_inflight(&self) -> usize {
        self.lanes.iter().map(|l| l.migs.len()).sum()
    }

    /// Aggregate reclaim-pipeline counters.
    pub fn migration_stats(&self) -> MigStats {
        self.seq.mig_stats
    }

    /// Milestones of completed migrations, in completion order.
    pub fn migration_records(&self) -> &[MigrationRecord] {
        &self.seq.mig_records
    }

    /// Current keep-alive state of `node` (always Healthy with health
    /// off — the ledger never ticks then).
    pub fn peer_health(&self, node: NodeId) -> Health {
        self.seq.health.state(node)
    }

    /// Whether the failure-domain layer is on (`valet.health.enabled`).
    pub fn health_on(&self) -> bool {
        self.seq.health.enabled
    }

    /// Units awaiting the re-replication pump (diagnostics; the churn
    /// experiment's recovery clock runs until this and the live repair
    /// machines both drain).
    pub fn repair_backlog(&self) -> usize {
        self.seq.repair_queue.len()
    }

    // -- the sender-lane pipeline -------------------------------------

    /// Apply completions of in-flight RDMA batches up to `now` on every
    /// lane (lane order; write sets land in the sequencer's per-shard
    /// mailboxes and are applied by [`ShardFastPath::apply_durable`]).
    pub fn complete_inflight(&mut self, cl: &mut ClusterState, now: Ns) {
        let seq = &mut self.seq;
        for lane in &mut self.lanes {
            lane.complete_inflight(&seq.units, &mut seq.done, cl, now);
        }
    }

    /// Apply one lane's in-flight completions up to `now` — the
    /// serve-driver entry point that ticks lanes independently under
    /// short sequencer-lock holds.
    pub(crate) fn tick_lane(
        &mut self,
        cl: &mut ClusterState,
        now: Ns,
        lane: usize,
    ) {
        let seq = &mut self.seq;
        self.lanes[lane].complete_inflight(&seq.units, &mut seq.done, cl, now);
    }

    /// Drain `shard`'s completion mailbox (FIFO).
    pub fn take_done(&mut self, shard: usize) -> Vec<WriteSet> {
        std::mem::take(&mut self.seq.done[shard])
    }

    // -- the admission rings (concurrent serve slow path) --------------

    /// Shared handle to the per-lane admission rings — the only sender
    /// state the serve shard workers may touch without holding the
    /// sequencer lock (see [`admit_staged`]).
    pub(crate) fn rings_handle(&self) -> LaneRings {
        Arc::clone(&self.rings)
    }

    /// Drain up to `max_entries` batches from `lane`'s admission ring
    /// and dispatch each — wire, park, or dead-cluster-complete — at no
    /// earlier than `t0` (each batch additionally gated by its own
    /// staging-enqueue time). The caller holds the sequencer (this is
    /// `&mut self`); the ring mutex is taken inside, which is the one
    /// sanctioned sequencer → ring order. Pop and dispatch happen under
    /// a single ring hold, so [`Law::LaneLockCoherence`] holds at every
    /// instant another thread can observe the counters. Returns the
    /// last dispatched batch's completion time (`t0` for an empty
    /// ring).
    pub(crate) fn drain_lane_ring(
        &mut self,
        cl: &mut ClusterState,
        t0: Ns,
        lane: usize,
        max_entries: usize,
    ) -> Ns {
        let rings = Arc::clone(&self.rings);
        let mut ring =
            rings[lane].lock().expect("lane admission ring poisoned");
        let mut done = t0;
        let mut n = 0usize;
        while n < max_entries {
            let Some(e) = ring.q.pop_front() else { break };
            ring.drained += e.sets.len() as u64;
            done = self.send_ring_batch(cl, t0.max(e.enq), e);
            n += 1;
        }
        done
    }

    /// Drain every ring to empty — the serve shutdown path: after the
    /// slow-path threads are joined, whatever admissions were still
    /// queued flush here so no write set is lost across the engine
    /// reassembly.
    pub(crate) fn drain_all_rings(&mut self, cl: &mut ClusterState, now: Ns) {
        for lane in 0..self.rings.len() {
            self.drain_lane_ring(cl, now, lane, usize::MAX);
        }
    }

    // -- the read-side pipeline ---------------------------------------

    /// If `page` has an outstanding remote fetch completing *after*
    /// `now` on any lane, return its completion time — the caller
    /// piggybacks on it (miss coalescing) instead of posting a
    /// duplicate READ. A stale entry (completion passed) is pruned and
    /// `None` returned: the fetched data was never installed locally
    /// (remote reads are read-through), so a later miss must fetch
    /// again.
    pub fn inflight_read_done(&mut self, page: u64, now: Ns) -> Option<Ns> {
        for lane in &mut self.lanes {
            if let Some(done) = lane.inflight_read_done(page, now) {
                return Some(done);
            }
        }
        None
    }

    /// Record an outstanding remote read of `page` completing at
    /// `done`, so overlapping misses on the same page can coalesce. The
    /// entry lands in the lane of the page's current primary (lane 0
    /// for pages whose unit died between fetch and note).
    pub fn note_inflight_read(&mut self, now: Ns, page: u64, done: Ns) {
        let lane = self.lane_for_mapped(page).unwrap_or(0);
        self.lanes[lane].note_inflight_read(now, page, done);
    }

    /// Outstanding remote reads tracked for coalescing across all lanes
    /// (diagnostics; includes entries not yet lazily pruned).
    pub fn inflight_read_count(&self) -> usize {
        self.lanes.iter().map(|l| l.inflight_reads.len()).sum()
    }

    /// The replica slot a read of `unit` should target: the first slot
    /// whose peer can still serve, picked through the Table-3
    /// [`read_source`] ladder over the slot list with per-peer
    /// liveness. With health off this is exactly slot 0 — the
    /// bit-for-bit pin. `None` when the unit is unmapped, dead, or
    /// every replica peer is Dead (the caller falls through to the
    /// disk backup, then to a lost read).
    pub fn read_slot(&self, unit: u64) -> Option<(NodeId, MrBlockId, Ns)> {
        let u = self.seq.units.get(unit)?;
        if !u.alive || u.nodes.is_empty() {
            return None;
        }
        if !self.seq.health.enabled {
            return Some((u.nodes[0], u.blocks[0], u.ready_at));
        }
        let copies: Vec<(NodeId, bool)> = u
            .nodes
            .iter()
            .map(|&n| (n, self.seq.health.alive(n)))
            .collect();
        let policy = FtPolicy {
            copies: copies.len().max(1),
            disk_backup: false, // the disk rung belongs to the caller
        };
        match read_source(policy, &copies) {
            ReadSource::Remote(n) => {
                let i = u.nodes.iter().position(|&x| x == n)?;
                Some((n, u.blocks[i], u.ready_at))
            }
            _ => None,
        }
    }

    /// Batched remote read: fetch `pages` (grouped into runs that share
    /// an address-space unit) with **one** RDMA READ per unit — one
    /// base round trip plus per-page wire time, mirroring the write
    /// side's coalescing batcher — and register every page in its
    /// lane's in-flight read table. `out` is filled (cleared first)
    /// with each page's completion time, in input order; a page whose
    /// unit is unmapped or dead completes "immediately" at `t0` (the
    /// caller filters those up front — this keeps the batch robust).
    /// Returns the completion time of the slowest run, `t0` when
    /// `pages` is empty.
    ///
    /// Callers decide what the batch means: the demand block-read path
    /// (`demand = true`) waits on the result and stamps the primary
    /// block's read-activity tag — §3.5's victim ranking then sees read
    /// phases — while the prefetcher (`demand = false`) treats it as
    /// asynchronous readahead, records only the arrival times, and
    /// leaves the tag alone: a speculative fetch becomes activity only
    /// when a later demand hit consumes it, so prefetched-but-unused
    /// blocks stay first in line as victims.
    pub fn read_batch(
        &mut self,
        cl: &mut ClusterState,
        t0: Ns,
        pages: &[u64],
        demand: bool,
        out: &mut Vec<(u64, Ns)>,
    ) -> Ns {
        out.clear();
        let mut slowest = t0;
        let mut i = 0;
        while i < pages.len() {
            // one run = consecutive input pages sharing a unit
            let unit = self.seq.units.unit_of(pages[i]);
            let mut j = i + 1;
            while j < pages.len() && self.seq.units.unit_of(pages[j]) == unit
            {
                j += 1;
            }
            let run = &pages[i..j];
            let (primary, block, ready) = match self.read_slot(unit) {
                Some(slot) => slot,
                None => {
                    for &p in run {
                        out.push((p, t0));
                    }
                    i = j;
                    continue;
                }
            };
            let t = t0.max(ready) + self.lat.mrpool_get;
            let bytes = run.len() as u64 * PAGE_SIZE;
            let verb = cl.tiered_read(t, primary, block, bytes);
            if demand {
                cl.mrpools[primary].touch_read(block, verb.end);
                self.seq.note_demand_read(cl, unit);
            }
            let lane = self.lane_of(primary);
            for &p in run {
                self.lanes[lane].note_inflight_read(t0, p, verb.end);
                out.push((p, verb.end));
            }
            slowest = slowest.max(verb.end);
            i = j;
        }
        slowest
    }

    /// Feed the admission predictor a demand-read observation for
    /// `unit` (a no-op unless the pool tier and its predictor are on).
    /// The single-page engine miss path posts its verb directly, so it
    /// reports here; the batched path reports inside
    /// [`Self::read_batch`].
    pub(crate) fn note_demand_read(&mut self, cl: &ClusterState, unit: u64) {
        self.seq.note_demand_read(cl, unit);
    }

    /// The migration machine `unit`'s writes park against, if any (at
    /// most one live machine per unit — an audited law).
    fn find_parking_target(&self, unit: u64) -> Option<MigRef> {
        for (li, lane) in self.lanes.iter().enumerate() {
            if let Some(mi) = lane
                .migs
                .iter()
                .position(|m| m.unit == unit && m.sm.writes_parked())
            {
                return Some((li, mi));
            }
        }
        None
    }

    /// Send one coalesced batch from the front of `fast`'s staging
    /// queue at (no earlier than) `t0`; returns its completion time.
    /// Kept as the front-only wrapper over [`Self::send_batch_at`] —
    /// with one lane it IS the pre-split send path.
    pub fn send_one_batch(
        &mut self,
        cl: &mut ClusterState,
        t0: Ns,
        shard: usize,
        fast: &mut ShardFastPath,
    ) -> Ns {
        self.send_batch_at(cl, t0, shard, fast, 0)
    }

    /// Send one coalesced batch starting from staging index `idx` at
    /// (no earlier than) `t0`; returns its completion time. Coalescing
    /// only merges consecutive write sets (from `idx` on) that target
    /// the same address-space unit (one RDMA message lands in one MR
    /// block), so per-lane FIFO is preserved: the drive loops always
    /// pass each lane's *earliest* queued set. The timeline charge and
    /// the in-flight entry land on the unit's primary-peer lane.
    pub(crate) fn send_batch_at(
        &mut self,
        cl: &mut ClusterState,
        t0: Ns,
        shard: usize,
        fast: &mut ShardFastPath,
        idx: usize,
    ) -> Ns {
        debug_assert!(idx < fast.staging.len());
        let max = if self.vcfg.coalescing {
            self.vcfg.rdma_msg_bytes
        } else {
            1 // force single write set per message
        };
        let unit = self.seq.units.unit_of(
            fast.staging
                .get(idx)
                .expect("caller bounds-checked the staging index")
                .page,
        );
        // §3.5 write parking: a batch whose unit is mid-migration (STOP
        // writes sent with PREPARE) moves into the migration machine
        // instead of the wire, and flushes to the destination at COMMIT.
        // Costs queue movement only — no lane-timeline time, no verb.
        if let Some((pl, pm)) = self.find_parking_target(unit) {
            let mut parked = 0u64;
            let mut parked_bytes = 0u64;
            while let Some(next) = fast.staging.get(idx) {
                if self.seq.units.unit_of(next.page) != unit {
                    break;
                }
                let ws = fast
                    .staging
                    .remove(idx)
                    .expect("get just returned this entry");
                if self.vcfg.disk_backup {
                    for p in ws.page..ws.page + ws.pages() {
                        fast.disk_valid.set(p);
                    }
                }
                parked_bytes += ws.bytes;
                let m = &mut self.lanes[pl].migs[pm];
                m.parked_bytes += ws.bytes;
                m.parked.push((shard, ws));
                parked += 1;
            }
            // Table 3: the disk backup covers parked batches exactly
            // like sent ones — the backup write goes out now, off the
            // critical path, not at the COMMIT flush
            if parked > 0 && self.vcfg.disk_backup {
                cl.disks[cl.sender].write_async(t0, parked_bytes);
                fast.metrics.disk_writes += 1;
            }
            self.seq.mig_stats.parked_sets += parked;
            return t0;
        }
        // Failure-domain guard: a (re)mapping with every peer Dead has
        // nowhere to land (`ensure_unit` would pick from an empty live
        // cluster). The sets go to the disk backup (Table 3) or are
        // counted lost — and either way they complete back to their
        // shard, so the fast path never deadlocks on a dead cluster.
        if self.seq.health.enabled
            && self.seq.units.get(unit).map_or(true, |u| !u.alive)
            && !cl.peers().any(|n| self.seq.health.alive(n))
        {
            let mut batch = Vec::new();
            let mut bytes = 0u64;
            while let Some(next) = fast.staging.get(idx) {
                if self.seq.units.unit_of(next.page) != unit {
                    break;
                }
                let ws = fast
                    .staging
                    .remove(idx)
                    .expect("get just returned this entry");
                if self.vcfg.disk_backup {
                    for p in ws.page..ws.page + ws.pages() {
                        fast.disk_valid.set(p);
                    }
                }
                bytes += ws.bytes;
                batch.push(ws);
            }
            if self.vcfg.disk_backup {
                cl.disks[cl.sender].write_async(t0, bytes);
                fast.metrics.disk_writes += 1;
            } else {
                self.seq.mig_stats.lost_write_sets += batch.len() as u64;
            }
            self.lanes[0].inflight.push(Inflight {
                done: t0,
                shard,
                sets: batch,
            });
            return t0;
        }
        let mut batch = Vec::new();
        let mut bytes = 0u64;
        while let Some(next) = fast.staging.get(idx) {
            let same_unit = self.seq.units.unit_of(next.page) == unit;
            if !batch.is_empty() && (bytes + next.bytes > max || !same_unit)
            {
                break;
            }
            let ws = fast
                .staging
                .remove(idx)
                .expect("get just returned this entry");
            bytes += ws.bytes;
            batch.push(ws);
        }
        // disk-backup bookkeeping stays here, where the fast path is in
        // reach — the ring detour below hands the batch to dispatch
        // code that never sees `fast`
        if self.vcfg.disk_backup {
            for ws in &batch {
                for p in ws.page..ws.page + ws.pages() {
                    fast.disk_valid.set(p);
                }
            }
            fast.metrics.disk_writes += 1;
        }
        if self.vcfg.slow_path_threads != 1 {
            // Admission-ring detour: admit, then synchronously drain
            // the same ring — same instant, same sequencer state, so
            // virtual-time results stay bit-identical to the inline
            // path below while the ring machinery (and its conservation
            // law) is exercised on every send. Under serve this drain
            // also flushes batches the shard workers admitted
            // lock-free to the same ring.
            let hint = (unit as usize) % self.rings.len();
            let entry = RingEntry {
                shard,
                unit,
                bytes,
                enq: t0,
                sets: batch,
            };
            let leftover = {
                let rings = Arc::clone(&self.rings);
                let mut ring = rings[hint]
                    .lock()
                    .expect("lane admission ring poisoned");
                ring.admit(entry)
            };
            return match leftover {
                // ring at capacity (a serve backlog): dispatch directly
                Some(e) => self.send_ring_batch(cl, t0, e),
                None => self.drain_lane_ring(cl, t0, hint, usize::MAX),
            };
        }
        self.wire_batch(cl, t0, shard, unit, batch, bytes)
    }

    /// Wire one coalesced same-unit batch: map the unit if needed,
    /// charge the mrpool get plus one tiered RDMA WRITE per replica,
    /// issue the optional disk-backup write, charge the lane timeline
    /// for the posting work and record the in-flight entry. The shared
    /// tail of the inline send path and the ring drain — exactly one
    /// implementation of the wire crossing. Fast-path bookkeeping
    /// (disk-valid stamps, shard metrics) is the caller's job.
    fn wire_batch(
        &mut self,
        cl: &mut ClusterState,
        t0: Ns,
        shard: usize,
        unit: u64,
        batch: Vec<WriteSet>,
        bytes: u64,
    ) -> Ns {
        // mapping (behind the mempool — charged here, on the lane)
        let ready =
            self.seq
                .ensure_unit(cl, t0, unit, self.vcfg.replicas.max(1));
        let u = self
            .seq
            .units
            .get(unit)
            .expect("ensure_unit mapped this unit");
        let mut t = t0.max(ready).max(u.wlocked_until);
        // mrpool get + one-sided write per replica (queue on our NIC);
        // a pool-tier replica takes the pooled-appliance verb instead
        t += self.lat.mrpool_get;
        let nodes = u.nodes.clone();
        let blocks = u.blocks.clone();
        let mut done = t;
        for (&n, &b) in nodes.iter().zip(blocks.iter()) {
            let verb = cl.tiered_write(t, n, b, bytes);
            done = done.max(verb.end);
        }
        // optional disk backup, off the critical path
        if self.vcfg.disk_backup {
            cl.disks[cl.sender].write_async(t, bytes);
        }
        // The lane's timeline is busy only for its CPU work (mapping
        // waits + mrpool get + posting the WQE, ~300 ns); the verb
        // completes asynchronously on the NIC (tracked via the lane's
        // `inflight`), so many messages pipeline — and un-coalesced
        // small messages flood the WQE cache, which is exactly the §3.3
        // argument for batching.
        let lane = self.lane_of(nodes[0]);
        let post_done = t + 300;
        self.lanes[lane].thread.serve(t0, post_done.saturating_sub(t0));
        self.lanes[lane].inflight.push(Inflight {
            done,
            shard,
            sets: batch,
        });
        done
    }

    /// Dispatch one admitted ring batch under the sequencer: the same
    /// three-way branch as [`Self::send_batch_at`] — park against a
    /// live migration of the unit, complete to the disk backup (or
    /// count lost) on a dead cluster, else wire. Fast-path-free by
    /// construction: staging pops, disk-valid stamps and shard metrics
    /// all happened at admission, on the side that owns the fast path.
    fn send_ring_batch(
        &mut self,
        cl: &mut ClusterState,
        t0: Ns,
        e: RingEntry,
    ) -> Ns {
        let RingEntry { shard, unit, bytes, sets, .. } = e;
        // §3.5 write parking (see send_batch_at): the batch's unit went
        // mid-migration between admission and this drain
        if let Some((pl, pm)) = self.find_parking_target(unit) {
            if self.vcfg.disk_backup {
                cl.disks[cl.sender].write_async(t0, bytes);
            }
            let parked = sets.len() as u64;
            let m = &mut self.lanes[pl].migs[pm];
            for ws in sets {
                m.parked_bytes += ws.bytes;
                m.parked.push((shard, ws));
            }
            self.seq.mig_stats.parked_sets += parked;
            return t0;
        }
        // dead-cluster guard (see send_batch_at): nowhere to land, so
        // the sets complete to the disk backup or are counted lost
        if self.seq.health.enabled
            && self.seq.units.get(unit).map_or(true, |u| !u.alive)
            && !cl.peers().any(|n| self.seq.health.alive(n))
        {
            if self.vcfg.disk_backup {
                cl.disks[cl.sender].write_async(t0, bytes);
            } else {
                self.seq.mig_stats.lost_write_sets += sets.len() as u64;
            }
            self.lanes[0].inflight.push(Inflight {
                done: t0,
                shard,
                sets,
            });
            return t0;
        }
        self.wire_batch(cl, t0, shard, unit, sets, bytes)
    }

    /// Synchronous write (Valet-RemoteOnly ablation): radix + copy + wait
    /// for the RDMA send like Infiniswap, but keep coalescing disabled
    /// and no disk redirect (mapping stalls the request instead).
    pub fn write_sync(
        &mut self,
        cl: &mut ClusterState,
        now: Ns,
        page: u64,
        bytes: u64,
        fast: &mut ShardFastPath,
    ) -> crate::backends::Access {
        use crate::backends::{Access, Source};
        let mut t = now + self.lat.radix_insert;
        fast.metrics.write_parts.add("radix", self.lat.radix_insert);
        let unit = self.seq.units.unit_of(page);
        let ready =
            self.seq
                .ensure_unit(cl, t, unit, self.vcfg.replicas.max(1));
        if ready > t {
            fast.metrics.write_parts.add("mapping", ready - t);
            t = ready;
        }
        let copy = self.lat.copy(bytes);
        t += copy;
        fast.metrics.write_parts.add("copy", copy);
        let u = self
            .seq
            .units
            .get(unit)
            .expect("ensure_unit mapped this unit");
        let nodes = u.nodes.clone();
        let blocks = u.blocks.clone();
        let mut done = t + self.lat.mrpool_get;
        for (&n, &b) in nodes.iter().zip(blocks.iter()) {
            let verb = cl.tiered_write(t, n, b, bytes);
            done = done.max(verb.end);
        }
        fast.metrics.write_parts.add("rdma", done - t);
        for p in page..page + crate::pages_for(bytes) {
            fast.remote_ready.set(p);
        }
        fast.metrics.write_latency.record(done - now);
        Access {
            end: done,
            source: Source::Remote,
        }
    }

    // -- failure domains: keep-alive, death sweep, join ---------------

    /// One applied cluster event ticks the keep-alive ledger: the
    /// event's originating peer (if any) proves itself alive, every
    /// other peer ages one expected event. Peers that crossed into
    /// Dead get the full death sweep immediately — transitions happen
    /// inside the single event-application loop, so every lane
    /// observes one global timestamp order of deaths. Strict no-op
    /// with health off.
    pub(crate) fn health_tick(
        &mut self,
        cl: &mut ClusterState,
        now: Ns,
        origin: Option<NodeId>,
    ) {
        if !self.seq.health.enabled {
            return;
        }
        for node in self.seq.health.tick(cl.sender, origin) {
            self.on_peer_dead(cl, now, node);
        }
    }

    /// Explicit peer crash
    /// ([`crate::cluster::ClusterEvent::PeerDown`]): declare `node`
    /// Dead and run the death sweep. Idempotent; with health off the
    /// event is inert (it still refreshes pressure like any event).
    pub(crate) fn peer_down(
        &mut self,
        cl: &mut ClusterState,
        now: Ns,
        node: NodeId,
    ) {
        if self.seq.health.kill(node) {
            self.on_peer_dead(cl, now, node);
        }
    }

    /// Peer (re)join ([`crate::cluster::ClusterEvent::PeerJoin`]): a
    /// Dead peer revives with an empty donated pool (wiped at death)
    /// and is queued for join rebalancing on the next repair scan; a
    /// join event for a live peer is just a keep-alive.
    pub(crate) fn peer_join(
        &mut self,
        _cl: &mut ClusterState,
        _now: Ns,
        node: NodeId,
    ) {
        if self.seq.health.revive(node)
            && !self.seq.pending_rebalance.contains(&node)
        {
            self.seq.pending_rebalance.push(node);
        }
    }

    /// The death sweep for `node`, run exactly once per death at the
    /// event's virtual time: purge its replica slots (survivors shift
    /// left, so a dead primary fails over to its first follower; a
    /// unit whose last copy died is dead), abort or re-target every
    /// migration machine touching it, wipe its MR pool and routing
    /// pre-picks, and queue every damaged unit for the re-replication
    /// pump.
    fn on_peer_dead(&mut self, cl: &mut ClusterState, now: Ns, node: NodeId) {
        // 1. replica slots
        let mut damaged: Vec<u64> = Vec::new();
        for (id, u) in self.seq.units.iter_mut() {
            if !u.alive {
                continue;
            }
            let before = u.nodes.len();
            let mut i = 0;
            while i < u.nodes.len() {
                if u.nodes[i] == node {
                    u.nodes.remove(i);
                    u.blocks.remove(i);
                } else {
                    i += 1;
                }
            }
            if u.nodes.len() < before {
                if u.nodes.is_empty() {
                    u.alive = false;
                } else {
                    damaged.push(*id);
                }
            }
        }
        damaged.sort_unstable();
        // 2. migration machines: src dead → abort (parked sets flush
        //    to the survivors right now — exactly once); dst dead →
        //    DestLost returns the machine to destination selection and
        //    its parked sets stay parked (they flush at the eventual
        //    COMMIT against the new destination).
        for li in 0..self.lanes.len() {
            let mut mi = 0;
            while mi < self.lanes[li].migs.len() {
                if self.lanes[li].migs[mi].src == node {
                    let m = self.lanes[li].migs.remove(mi);
                    self.abort_machine_src_dead(cl, now, li, m);
                    continue;
                }
                if self.lanes[li].migs[mi].dst == Some(node) {
                    let m = &mut self.lanes[li].migs[mi];
                    m.sm
                        .on_event(MigEvent::DestLost)
                        .expect("machine with a destination accepts dest-lost");
                    m.dst = None;
                    m.dst_block = None; // died with its peer (wiped below)
                    self.seq.mig_slot_free = self.seq.mig_slot_free.max(now);
                }
                mi += 1;
            }
        }
        // 3. the dead peer's donated memory is gone
        let gone: Vec<MrBlockId> =
            cl.mrpools[node].blocks().iter().map(|b| b.id).collect();
        for b in gone {
            cl.mrpools[node].release(b);
        }
        // 4. routing pre-picks onto the dead peer re-place at mapping
        self.seq.pending_primary.retain(|_, p| p.node != node);
        // 5. survivors that lost a copy queue for the repair pump
        let want = self.vcfg.replicas.max(1);
        for id in damaged {
            let under = self
                .seq
                .units
                .get(id)
                .map(|u| u.alive && u.nodes.len() < want)
                .unwrap_or(false);
            if under {
                self.seq.queue_repair(id);
            }
        }
        cl.refresh_pressure();
    }

    /// Abort a machine whose *source* peer died mid-protocol: the
    /// source block died with the peer (its pool is wiped by the death
    /// sweep), a destination block already registered on a live peer
    /// is released, and parked sets flush on the way out. A repair
    /// machine's unit goes back in the queue — if a copy survives, the
    /// pump retries from it.
    fn abort_machine_src_dead(
        &mut self,
        cl: &mut ClusterState,
        now: Ns,
        li: usize,
        mut m: ActiveMigration,
    ) {
        if let (Some(d), Some(db)) = (m.dst, m.dst_block) {
            cl.mrpools[d].release(db);
        }
        if m.is_active() {
            self.seq.mig_slot_free = self.seq.mig_slot_free.max(now);
        }
        if m.repair
            && self
                .seq
                .units
                .get(m.unit)
                .map(|u| u.alive)
                .unwrap_or(false)
        {
            self.seq.queue_repair(m.unit);
        }
        self.flush_orphaned_parked(cl, now, li, &mut m);
    }

    /// Queue `unit` for the repair pump if it is alive and below the
    /// configured copy count (no-op with health off) — keeps the
    /// `replica-health` law's "damaged ⇒ queued" clause airtight on
    /// the delete paths too.
    fn queue_repair_if_under(&mut self, unit: Option<u64>) {
        let Some(id) = unit else { return };
        let want = self.vcfg.replicas.max(1);
        let under = self
            .seq
            .units
            .get(id)
            .map(|u| u.alive && u.nodes.len() < want)
            .unwrap_or(false);
        if under {
            self.seq.queue_repair(id);
        }
    }

    /// Flush a departing machine's parked write sets exactly once: to
    /// the unit's surviving replicas, else the disk backup (the sets
    /// stamped `disk_valid` when they parked), else count them lost —
    /// and in every case complete them back to their shards, so the
    /// fast path never waits on a dead migration and the
    /// `parked-flush-once` law holds across aborts, not just COMMITs.
    fn flush_orphaned_parked(
        &mut self,
        cl: &mut ClusterState,
        now: Ns,
        li: usize,
        m: &mut ActiveMigration,
    ) {
        if m.parked.is_empty() {
            return;
        }
        let sets = m.parked.len() as u64;
        let flush_to: Vec<(NodeId, MrBlockId)> = self
            .seq
            .units
            .get(m.unit)
            .filter(|u| u.alive)
            .map(|u| {
                u.nodes
                    .iter()
                    .copied()
                    .zip(u.blocks.iter().copied())
                    .collect()
            })
            .unwrap_or_default();
        let mut flush_done = now;
        if !flush_to.is_empty() {
            let t = now + self.lat.mrpool_get;
            flush_done = t;
            for &(n, b) in &flush_to {
                let verb = cl.tiered_write(t, n, b, m.parked_bytes);
                flush_done = flush_done.max(verb.end);
            }
        } else if self.vcfg.disk_backup {
            cl.disks[cl.sender].write_async(now, m.parked_bytes);
        } else {
            self.seq.mig_stats.lost_write_sets += sets;
        }
        self.seq.mig_stats.flushed_sets += sets;
        let mut by_shard: Vec<(usize, Vec<WriteSet>)> = Vec::new();
        for (shard, ws) in m.parked.drain(..) {
            match by_shard.iter_mut().find(|(s, _)| *s == shard) {
                Some((_, list)) => list.push(ws),
                None => by_shard.push((shard, vec![ws])),
            }
        }
        for (shard, list) in by_shard {
            self.lanes[li].inflight.push(Inflight {
                done: flush_done,
                shard,
                sets: list,
            });
        }
    }

    // -- remote pressure (§3.5): the reclaim pipeline -----------------

    /// A peer needs `bytes` of its donated memory back: select victims
    /// via the pluggable policy and **enqueue** one live [`MigrationSm`]
    /// per victim into the source peer's lane table — the pump drives
    /// the protocol from here ([`Self::advance_migrations`]); this call
    /// never blocks on wire time. Delete stays the synchronous last
    /// resort when no destination has room. The returned outcome counts
    /// bytes *committed to reclaim* (blocks are victim-marked
    /// immediately, so the pressured node's pool stops considering
    /// them); `done_at` is when victim selection finished. A queued
    /// migration whose destinations all fill up before it activates
    /// degrades to delete at activation — `migrated` counts
    /// initiations; [`Self::migration_stats`] reconciles the final
    /// split.
    pub fn remote_pressure(
        &mut self,
        cl: &mut ClusterState,
        now: Ns,
        node: NodeId,
        bytes: u64,
    ) -> PressureOutcome {
        let mut out = PressureOutcome {
            done_at: now,
            ..Default::default()
        };
        // Bytes already committed to reclaim on this node by earlier
        // episodes but not yet released (the source block frees only
        // when its copy completes, so the caller's `registered_bytes`-
        // based demand still counts them — without this credit a
        // second pressure wave arriving mid-copy would select surplus
        // victims for memory that is already on its way out).
        let pending: u64 = self
            .lanes
            .iter()
            .flat_map(|l| l.migs.iter())
            .filter(|m| {
                m.src == node
                    // a pool-tier source frees appliance capacity, not
                    // the DRAM this pressure episode is reclaiming
                    && m.src_tier == MemTier::Remote
                    // a repair *copies from* its source and never
                    // releases it — no bytes are on their way out
                    && !m.repair
                    && matches!(
                        m.sm.state(),
                        MigState::ChoosingDest
                            | MigState::Preparing
                            | MigState::Copying
                    )
            })
            .map(|m| m.block_bytes)
            .sum();
        let bytes = bytes.saturating_sub(pending);
        let mut t = now;
        while out.reclaimed_bytes < bytes {
            // Victim selection ON the pressured node via the pluggable
            // policy — activity-based by default: purely local metadata,
            // zero sender queries (§3.5). A tenant-tagged sender selects
            // only among its own blocks. Blocks already migrating are
            // never re-selected (their MrState filters them out).
            let choice = {
                let selected = match self.seq.owner_tag {
                    Some(tag) => {
                        let view = cl.mrpools[node].owned_by(tag);
                        self.seq.victim_policy.select(&view, t)
                    }
                    None => {
                        self.seq.victim_policy.select(&cl.mrpools[node], t)
                    }
                };
                match selected {
                    Some(c) => c,
                    None => break,
                }
            };
            t += choice.selection_cost; // zero for ActivityBased
            let block_bytes = cl.mrpools[node]
                .get(choice.block)
                .map(|b| b.bytes)
                .unwrap_or(self.seq.units.unit_bytes);
            let unit_id = self.seq.units.unit_of_block(node, choice.block);
            let has_dst = unit_id
                .map(|u| self.has_reclaim_candidate(cl, u, node, block_bytes))
                .unwrap_or(false);
            match unit_id {
                Some(unit_id) if has_dst => {
                    // Enqueue a live protocol machine into the source
                    // peer's lane, stamped with the global submission
                    // sequence; destination choice (pressure-aware)
                    // happens at activation, when the migration takes a
                    // concurrency slot.
                    let mut sm = MigrationSm::new();
                    sm.on_event(MigEvent::PressureReport {
                        block: choice.block,
                        src: node,
                    })
                    .expect("fresh machine accepts a pressure report");
                    if let Some(b) = cl.mrpools[node].get_mut(choice.block)
                    {
                        b.state = crate::mrpool::MrState::Migrating;
                    }
                    let src_tier = cl.mrpools[node]
                        .get(choice.block)
                        .map(|b| b.tier)
                        .unwrap_or(MemTier::Remote);
                    let stamp = self.seq.next_mig_seq();
                    let lane = self.lane_of(node);
                    self.lanes[lane].migs.push(ActiveMigration {
                        sm,
                        unit: unit_id,
                        src: node,
                        src_block: choice.block,
                        src_tier,
                        dst_tier: MemTier::Remote,
                        block_bytes,
                        scheduled: t,
                        dst: None,
                        dst_block: None,
                        activated: 0,
                        park_from: 0,
                        copy_start: 0,
                        copy_end: 0,
                        phase_done: 0,
                        parked: Vec::new(),
                        parked_bytes: 0,
                        seq: stamp,
                        repair: false,
                        forced_dst: None,
                    });
                    self.seq.mig_stats.started += 1;
                    out.migrated += 1;
                    out.reclaimed_bytes += block_bytes;
                    out.done_at = out.done_at.max(t);
                }
                _ => {
                    // No destination with room (or untracked block):
                    // last resort — delete like the baselines would.
                    // Diagnose the episode first: "the cluster is dead"
                    // (a destination would exist if the Dead/Suspect
                    // peers still counted) is surfaced separately from
                    // "the cluster is full".
                    let dead_blocked = unit_id.is_some_and(|u| {
                        self.pressure_blocked_by_dead(cl, u, node, block_bytes)
                    });
                    self.seq.delete_victim(cl, node, choice.block, unit_id);
                    self.queue_repair_if_under(unit_id);
                    if dead_blocked {
                        self.seq.mig_stats.no_candidate_dead_peers += 1;
                    } else {
                        self.seq.mig_stats.deleted += 1;
                    }
                    out.deleted += 1;
                    out.reclaimed_bytes += block_bytes;
                    out.done_at = out.done_at.max(t);
                }
            }
        }
        out
    }

    /// Bytes other pending migrations have promised to `node`'s `tier`
    /// (their MR blocks register only when their copy starts, so raw
    /// free bytes would over-commit a popular peer).
    fn reserved_on(&self, node: NodeId, tier: MemTier) -> u64 {
        self.lanes
            .iter()
            .flat_map(|l| l.migs.iter())
            .filter(|m| {
                m.dst == Some(node)
                    && m.dst_tier == tier
                    && m.dst_block.is_none()
            })
            .map(|m| m.block_bytes)
            .sum()
    }

    /// THE destination filter, shared by the list builder and the
    /// cheap existence check so the two can never drift: a candidate
    /// must be in the wanted tier, must not be the source (unless the
    /// move changes tier — a promotion/demotion may land on the same
    /// node) or one of the unit's *other* replica holders, must not
    /// already be the destination of another in-flight migration of
    /// the same unit (replica distinctness), must have room for the
    /// block after reservations — and, with the health ledger on and
    /// `heed_health`, must be a Healthy peer (a Dead peer cannot take
    /// a copy; a Suspect one is not gambled on). Diagnostics pass
    /// `heed_health = false` to ask "would a destination exist if the
    /// dead peers were alive?" — the `no_candidate_dead_peers` split.
    #[allow(clippy::too_many_arguments)]
    fn reclaim_candidate_ok(
        &self,
        c: &Candidate,
        unit: u64,
        src: NodeId,
        block_bytes: u64,
        holders: &[NodeId],
        dst_tier: MemTier,
        cross_tier: bool,
        heed_health: bool,
    ) -> bool {
        let src_ok = c.node != src || cross_tier;
        let holder_ok = !holders.contains(&c.node)
            || (cross_tier && c.node == src);
        let health_ok = !heed_health
            || !self.seq.health.enabled
            || self.seq.health.placeable(c.node);
        c.tier == dst_tier
            && src_ok
            && holder_ok
            && health_ok
            && !self
                .lanes
                .iter()
                .flat_map(|l| l.migs.iter())
                .any(|m| m.unit == unit && m.dst == Some(c.node))
            && c.free_bytes.saturating_sub(self.reserved_on(c.node, c.tier))
                >= block_bytes
    }

    fn unit_holders(&self, unit: u64) -> &[NodeId] {
        self.seq
            .units
            .get(unit)
            .map(|u| u.nodes.as_slice())
            .unwrap_or(&[])
    }

    /// Admission check `remote_pressure` runs per victim: some peer
    /// must fit this block, AND the candidates' aggregate spare
    /// capacity must also cover every *queued* migration that has not
    /// chosen a destination yet (those reserve nothing per-peer, so
    /// without the aggregate term N victims could all be admitted
    /// against one slot of free space and N−1 would silently degrade
    /// to deletes at activation).
    fn has_reclaim_candidate(
        &self,
        cl: &ClusterState,
        unit: u64,
        src: NodeId,
        block_bytes: u64,
    ) -> bool {
        self.reclaim_admission(cl, unit, src, block_bytes, true)
    }

    /// True when a pressure victim of `unit` is blocked *only by peer
    /// health*: no destination passes the live filter, yet one would
    /// if the Dead/Suspect peers still counted — the
    /// `no_candidate_dead_peers` diagnosis ("the cluster is dead",
    /// not "the cluster is full").
    fn pressure_blocked_by_dead(
        &self,
        cl: &ClusterState,
        unit: u64,
        src: NodeId,
        block_bytes: u64,
    ) -> bool {
        self.seq.health.enabled
            && self.reclaim_admission(cl, unit, src, block_bytes, false)
    }

    /// The admission loop behind [`Self::has_reclaim_candidate`],
    /// parameterized on whether peer health narrows the candidates.
    fn reclaim_admission(
        &self,
        cl: &ClusterState,
        unit: u64,
        src: NodeId,
        block_bytes: u64,
        heed_health: bool,
    ) -> bool {
        let holders = self.unit_holders(unit);
        let queued: u64 = self
            .lanes
            .iter()
            .flat_map(|l| l.migs.iter())
            .filter(|m| m.dst.is_none() && m.dst_tier == MemTier::Remote)
            .map(|m| m.block_bytes)
            .sum();
        let mut fits_somewhere = false;
        let mut spare = 0u64;
        for c in cl.candidates() {
            if !self.reclaim_candidate_ok(
                &c,
                unit,
                src,
                0,
                holders,
                MemTier::Remote,
                false,
                heed_health,
            ) {
                continue;
            }
            let free = c
                .free_bytes
                .saturating_sub(self.reserved_on(c.node, c.tier));
            if free >= block_bytes {
                fits_somewhere = true;
            }
            spare += free;
        }
        fits_somewhere && spare >= queued.saturating_add(block_bytes)
    }

    /// Destination candidates for migrating `unit` off `src` into
    /// `dst_tier` (see [`Self::reclaim_candidate_ok`] for the filter),
    /// with the reserved bytes already subtracted so the placement
    /// policy ranks peers by what they can actually still take.
    fn reclaim_candidates(
        &self,
        cl: &ClusterState,
        unit: u64,
        src: NodeId,
        block_bytes: u64,
        dst_tier: MemTier,
        cross_tier: bool,
    ) -> Vec<Candidate> {
        let holders = self.unit_holders(unit);
        cl.candidates()
            .into_iter()
            .filter(|c| {
                self.reclaim_candidate_ok(
                    c, unit, src, block_bytes, holders, dst_tier, cross_tier,
                    true,
                )
            })
            .map(|mut c| {
                c.free_bytes = c
                    .free_bytes
                    .saturating_sub(self.reserved_on(c.node, c.tier));
                c
            })
            .collect()
    }

    /// The lane tables' earliest actionable event: `(time, machine,
    /// is_activation)` — a queued machine that could take a free
    /// concurrency slot, or the active machine whose phase completes
    /// first. THE selection rule, shared by the advance loop and the
    /// backpressure probe so the two can never drift. Machines are
    /// visited in global submission-stamp order, which reproduces the
    /// pre-split single-table insertion order exactly.
    fn next_migration_action(&self) -> Option<(Ns, MigRef, bool)> {
        let cap = self.vcfg.max_concurrent_migrations.max(1);
        let active = self
            .lanes
            .iter()
            .flat_map(|l| l.migs.iter())
            .filter(|m| m.is_active())
            .count();
        let mut next: Option<(Ns, MigRef, bool)> = None;
        if active < cap {
            // earliest-submitted queued machine across all lanes
            let mut best: Option<(u64, MigRef)> = None;
            for (li, lane) in self.lanes.iter().enumerate() {
                for (mi, m) in lane.migs.iter().enumerate() {
                    if m.is_active() {
                        continue;
                    }
                    let earlier = match best {
                        Some((s, _)) => m.seq < s,
                        None => true,
                    };
                    if earlier {
                        best = Some((m.seq, (li, mi)));
                    }
                }
            }
            if let Some((_, (li, mi))) = best {
                let t = self.lanes[li].migs[mi]
                    .scheduled
                    .max(self.seq.mig_slot_free);
                next = Some((t, (li, mi), true));
            }
        }
        // active machines, visited in submission order (strict `<`
        // keeps ties resolving to the earlier-submitted machine, and to
        // the activation candidate before any active one)
        let mut act: Vec<(u64, MigRef)> = self
            .lanes
            .iter()
            .enumerate()
            .flat_map(|(li, lane)| {
                lane.migs
                    .iter()
                    .enumerate()
                    .filter(|(_, m)| m.is_active())
                    .map(move |(mi, m)| (m.seq, (li, mi)))
            })
            .collect();
        act.sort_unstable();
        for (_, (li, mi)) in act {
            let pd = self.lanes[li].migs[mi].phase_done;
            let earlier = match next {
                Some((t, _, _)) => pd < t,
                None => true,
            };
            if earlier {
                next = Some((pd, (li, mi), false));
            }
        }
        next
    }

    /// Earliest virtual time at which the migration tables have work to
    /// do (a queued machine that could activate, or an active phase
    /// completing). `None` when every table is empty. Used by the
    /// backpressure path to force progress instead of spinning.
    pub fn next_migration_event(&self) -> Option<Ns> {
        self.next_migration_action().map(|(t, _, _)| t)
    }

    /// Advance every migration up to `now`: activate queued machines
    /// while concurrency slots are free (global submission order), and
    /// walk each active machine through its due phase transitions
    /// (PREPARE ack → copy → COPY_DONE → COMMIT). Called from the
    /// pump/driver paths, interleaved with write batches, so reclaim
    /// overlaps demand traffic instead of blocking it. No-op when the
    /// tables are empty. This is the sequencer tick: cross-lane by
    /// design, unlike the per-lane completion ticks.
    pub fn advance_migrations(&mut self, cl: &mut ClusterState, now: Ns) {
        self.advance_tiering(cl, now);
        self.advance_repair(cl, now);
        let mut stepped = false;
        while let Some((t, mref, activation)) = self.next_migration_action()
        {
            if t > now {
                break;
            }
            if activation {
                self.activate_migration(cl, mref, t);
            } else {
                self.step_migration(cl, mref);
            }
            stepped = true;
        }
        // Migration-milestone audit: every activation/phase/commit that
        // just fired re-proves the tables' conservation laws. The
        // replica sweep over the whole unit map piggybacks on every
        // 64th crossing (see `audit_check`). Compiled away in release
        // builds without the `audit` feature.
        if audit::enabled()
            && (stepped || self.lanes.iter().any(|l| !l.migs.is_empty()))
        {
            self.audit_tick = self.audit_tick.wrapping_add(1);
            let thorough = self.audit_tick % 64 == 0;
            audit::enforce(&self.audit_check(cl, thorough));
        }
    }

    /// The per-lane slice of [`Self::advance_migrations`] for the
    /// concurrent serve drivers: step activations and phase transitions
    /// due by `now` only while the globally-oldest due action belongs
    /// to `lane`. Global submission order is preserved exactly — a lane
    /// thread never steps past another lane's older action; that
    /// action's own thread takes it on its next tick (every lane is
    /// owned by exactly one thread, so progress is guaranteed). The
    /// background scans (tiering, repair) stay with the sequencer tick
    /// ([`Self::advance_sequencer`]).
    pub(crate) fn advance_migrations_lane(
        &mut self,
        cl: &mut ClusterState,
        now: Ns,
        lane: usize,
    ) {
        let mut stepped = false;
        while let Some((t, mref, activation)) = self.next_migration_action()
        {
            if t > now || mref.0 != lane {
                break;
            }
            if activation {
                self.activate_migration(cl, mref, t);
            } else {
                self.step_migration(cl, mref);
            }
            stepped = true;
        }
        if audit::enabled() && stepped {
            self.audit_tick = self.audit_tick.wrapping_add(1);
            let thorough = self.audit_tick % 64 == 0;
            audit::enforce(&self.audit_check(cl, thorough));
        }
    }

    /// The sequencer-scoped slice of the background tick for the
    /// concurrent serve pump: run the tiering and repair scan clocks
    /// (which only *enqueue* machines) without stepping any lane's due
    /// actions — those belong to the per-lane drivers
    /// ([`Self::advance_migrations_lane`]).
    pub(crate) fn advance_sequencer(&mut self, cl: &mut ClusterState, now: Ns) {
        self.advance_tiering(cl, now);
        self.advance_repair(cl, now);
    }

    /// Run every promotion/demotion scan due by `now` (the tier pump).
    /// A strict no-op while the pool tier is disabled — the scan clock
    /// never advances and no machine is ever enqueued, which is part of
    /// the off-means-bit-for-bit pin.
    pub fn advance_tiering(&mut self, cl: &mut ClusterState, now: Ns) {
        if !cl.pool_cfg.enabled {
            return;
        }
        let period = cl.pool_cfg.scan_period.max(1);
        while self.seq.next_tier_scan <= now {
            let t = self.seq.next_tier_scan;
            self.scan_tiers(cl, t);
            self.seq.next_tier_scan += period;
        }
    }

    /// Run every re-replication/rebalance scan due by `now` (the
    /// repair pump, riding the same advance path as the tier pump). A
    /// strict no-op with health off — the scan clock never advances
    /// and no machine is ever enqueued, which is part of the
    /// off-means-bit-for-bit pin.
    fn advance_repair(&mut self, cl: &mut ClusterState, now: Ns) {
        if !self.seq.health.enabled {
            return;
        }
        let period = self.vcfg.health.repair_period.max(1);
        while self.seq.next_repair_scan <= now {
            let t = self.seq.next_repair_scan;
            self.scan_repair(cl, t);
            self.seq.next_repair_scan += period;
        }
    }

    /// One repair scan at virtual time `t`: first drain pending joins
    /// (up to `health.rebalance_max` unit moves onto each fresh peer),
    /// then spawn one re-replication machine per queued
    /// under-replicated unit that has a usable source and a
    /// destination today; the rest stay queued for the next scan.
    fn scan_repair(&mut self, cl: &mut ClusterState, t: Ns) {
        let joins = std::mem::take(&mut self.seq.pending_rebalance);
        for node in joins {
            // a joiner that died again before the pump ran is skipped
            if self.seq.health.placeable(node) {
                self.rebalance_onto(cl, t, node);
            }
        }
        let queue = std::mem::take(&mut self.seq.repair_queue);
        for unit in queue {
            if !self.try_spawn_repair(cl, t, unit) {
                // still under-replicated but unserviceable right now
                self.seq.queue_repair(unit);
            }
        }
    }

    /// Try to spawn a re-replication machine for `unit`: copy from its
    /// primary slot toward a fresh peer, *appending* a replica slot at
    /// COMMIT (`repair` machines never release their source). Returns
    /// false when the unit must stay queued — another machine owns the
    /// unit, the source block is busy, or no destination passes the
    /// shared candidate filter today; true when it was spawned or no
    /// longer needs repair.
    fn try_spawn_repair(
        &mut self,
        cl: &mut ClusterState,
        t: Ns,
        unit: u64,
    ) -> bool {
        let want = self.vcfg.replicas.max(1);
        let (src, src_block) = match self.seq.units.get(unit) {
            Some(u) if u.alive && u.nodes.len() < want => {
                (u.nodes[0], u.blocks[0])
            }
            _ => return true, // healed or dead: nothing to repair
        };
        // one live machine per unit is an audited law
        if self
            .lanes
            .iter()
            .flat_map(|l| l.migs.iter())
            .any(|m| m.unit == unit)
        {
            return false;
        }
        let (block_bytes, src_tier) = match cl.mrpools[src].get(src_block) {
            Some(b) if b.state == MrState::Active => (b.bytes, b.tier),
            _ => return false, // source busy — retry next scan
        };
        if self
            .reclaim_candidates(cl, unit, src, block_bytes, MemTier::Remote, false)
            .is_empty()
        {
            return false; // nowhere to put a copy today
        }
        let mut sm = MigrationSm::new();
        sm.on_event(MigEvent::PressureReport { block: src_block, src })
            .expect("fresh machine accepts a pressure report");
        if let Some(b) = cl.mrpools[src].get_mut(src_block) {
            b.state = MrState::Migrating;
        }
        let stamp = self.seq.next_mig_seq();
        let lane = self.lane_of(src);
        self.lanes[lane].migs.push(ActiveMigration {
            sm,
            unit,
            src,
            src_block,
            src_tier,
            dst_tier: MemTier::Remote,
            block_bytes,
            scheduled: t,
            dst: None,
            dst_block: None,
            activated: 0,
            park_from: 0,
            copy_start: 0,
            copy_end: 0,
            phase_done: 0,
            parked: Vec::new(),
            parked_bytes: 0,
            seq: stamp,
            repair: true,
            forced_dst: None,
        });
        true
    }

    /// Join rebalancing: move up to `health.rebalance_max` unit slots
    /// onto freshly joined `node`, sourced from the most-loaded live
    /// peers, as ordinary move machines pinned to the new destination
    /// (`forced_dst` — activation still validates room through the
    /// shared candidate filter, so a pin never overcommits the
    /// joiner).
    fn rebalance_onto(&mut self, cl: &mut ClusterState, t: Ns, node: NodeId) {
        let max_moves = self.vcfg.health.rebalance_max;
        if max_moves == 0 {
            return;
        }
        let busy: Vec<u64> = self
            .lanes
            .iter()
            .flat_map(|l| l.migs.iter())
            .map(|m| m.unit)
            .collect();
        // candidate slots: alive units not already on the joiner, no
        // live machine, Remote-tier Active source block — taken from
        // the fullest donor (unit id breaks ties, so the pick is
        // deterministic despite the map's iteration order)
        let mut cands: Vec<(u64, u64, NodeId, MrBlockId, u64)> = Vec::new();
        for (&id, u) in self.seq.units.iter() {
            if !u.alive || u.nodes.contains(&node) || busy.contains(&id) {
                continue;
            }
            let mut best: Option<(u64, NodeId, MrBlockId, u64)> = None;
            for (&n, &b) in u.nodes.iter().zip(u.blocks.iter()) {
                let Some(blk) = cl.mrpools[n].get(b) else {
                    continue;
                };
                if blk.state != MrState::Active
                    || blk.tier != MemTier::Remote
                {
                    continue;
                }
                let load = cl.mrpools[n].registered_bytes();
                let heavier = best
                    .as_ref()
                    .map(|&(l, _, _, _)| load > l)
                    .unwrap_or(true);
                if heavier {
                    best = Some((load, n, b, blk.bytes));
                }
            }
            if let Some((load, n, b, bytes)) = best {
                cands.push((id, load, n, b, bytes));
            }
        }
        cands.sort_unstable_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        for (unit, _, src, src_block, block_bytes) in
            cands.into_iter().take(max_moves)
        {
            let mut sm = MigrationSm::new();
            sm.on_event(MigEvent::PressureReport { block: src_block, src })
                .expect("fresh machine accepts a pressure report");
            if let Some(b) = cl.mrpools[src].get_mut(src_block) {
                b.state = MrState::Migrating;
            }
            let stamp = self.seq.next_mig_seq();
            let lane = self.lane_of(src);
            self.lanes[lane].migs.push(ActiveMigration {
                sm,
                unit,
                src,
                src_block,
                src_tier: MemTier::Remote,
                dst_tier: MemTier::Remote,
                block_bytes,
                scheduled: t,
                dst: None,
                dst_block: None,
                activated: 0,
                park_from: 0,
                copy_start: 0,
                copy_end: 0,
                phase_done: 0,
                parked: Vec::new(),
                parked_bytes: 0,
                seq: stamp,
                repair: false,
                forced_dst: Some(node),
            });
        }
    }

    /// One promotion/demotion scan at virtual time `t`, driven by the
    /// §3.5 activity tags: a pool-resident block idle past
    /// `demote_after` demotes toward RDMA-remote (freeing appliance
    /// capacity for hotter data); an RDMA-remote block with a demand
    /// read within `promote_max_idle` promotes toward the host into
    /// the pool tier. Moves ride the ordinary migration pipeline —
    /// parked writes, COMMIT remap, audit laws — as cross-tier
    /// machines whose destination may be the same node.
    fn scan_tiers(&mut self, cl: &mut ClusterState, t: Ns) {
        let owner = self.seq.owner_tag.unwrap_or(cl.sender);
        let promote_max_idle = cl.pool_cfg.promote_max_idle;
        let demote_after = cl.pool_cfg.demote_after;
        // cheap admission guard for promotions: some pool slice must
        // have raw room (the precise reservation-aware check runs at
        // activation, which cancels the move if the room evaporated)
        let pool_room: u64 =
            (0..cl.mrpools.len()).map(|n| cl.pool_free(n)).sum();
        let mut moves: Vec<(NodeId, MrBlockId, MemTier, MemTier, u64)> =
            Vec::new();
        for (node, pool) in cl.mrpools.iter().enumerate() {
            for b in pool.blocks() {
                if b.state != MrState::Active || b.owner != owner {
                    continue;
                }
                match b.tier {
                    MemTier::Pool => {
                        if b.non_activity_duration(t) > demote_after {
                            moves.push((
                                node,
                                b.id,
                                MemTier::Pool,
                                MemTier::Remote,
                                b.bytes,
                            ));
                        }
                    }
                    MemTier::Remote => {
                        if b.last_read > 0
                            && t.saturating_sub(b.last_read)
                                <= promote_max_idle
                            && pool_room >= b.bytes
                        {
                            moves.push((
                                node,
                                b.id,
                                MemTier::Remote,
                                MemTier::Pool,
                                b.bytes,
                            ));
                        }
                    }
                }
            }
        }
        for (node, block, src_tier, dst_tier, block_bytes) in moves {
            let Some(unit) = self.seq.units.unit_of_block(node, block)
            else {
                continue;
            };
            // one live machine per unit is an audited law
            if self
                .lanes
                .iter()
                .flat_map(|l| l.migs.iter())
                .any(|m| m.unit == unit)
            {
                continue;
            }
            let mut sm = MigrationSm::new();
            sm.on_event(MigEvent::PressureReport { block, src: node })
                .expect("fresh machine accepts a pressure report");
            sm.set_cross_tier();
            if let Some(b) = cl.mrpools[node].get_mut(block) {
                b.state = MrState::Migrating;
            }
            let stamp = self.seq.next_mig_seq();
            let lane = self.lane_of(node);
            self.lanes[lane].migs.push(ActiveMigration {
                sm,
                unit,
                src: node,
                src_block: block,
                src_tier,
                dst_tier,
                block_bytes,
                scheduled: t,
                dst: None,
                dst_block: None,
                activated: 0,
                park_from: 0,
                copy_start: 0,
                copy_end: 0,
                phase_done: 0,
                parked: Vec::new(),
                parked_bytes: 0,
                seq: stamp,
                repair: false,
                forced_dst: None,
            });
        }
    }

    /// Give the machine at `mref` its concurrency slot at `t_act`: poll
    /// candidates (one control RTT each), choose the destination
    /// through the pressure-aware placement hook, park writes
    /// (StopWrites fires with the DestChosen transition) and send
    /// PREPARE. Falls back to delete if every candidate filled up while
    /// the migration was queued.
    fn activate_migration(
        &mut self,
        cl: &mut ClusterState,
        (li, mi): MigRef,
        t_act: Ns,
    ) {
        let rtt = ctrl_rtt(&self.lat);
        let (unit, src, block_bytes, dst_tier, cross_tier, forced) = {
            let m = &self.lanes[li].migs[mi];
            (
                m.unit,
                m.src,
                m.block_bytes,
                m.dst_tier,
                m.sm.is_cross_tier(),
                m.forced_dst,
            )
        };
        let cands = self
            .reclaim_candidates(cl, unit, src, block_bytes, dst_tier, cross_tier);
        // a pinned destination (join rebalancing) is taken when it
        // passes the shared filter; otherwise the policy picks
        let dst = forced
            .and_then(|f| cands.iter().find(|c| c.node == f).copied())
            .map(|c| Placed {
                node: c.node,
                tier: c.tier,
            })
            .or_else(|| self.seq.reclaim_placement.pick(&cands));
        let Some(placed) = dst else {
            let mut m = self.lanes[li].migs.remove(mi);
            self.seq.mig_slot_free = self.seq.mig_slot_free.max(t_act);
            if m.repair || m.forced_dst.is_some() {
                // a repair/rebalance copy with nowhere to go stands
                // down: the source replica is intact, so restore it —
                // never delete — and, for a repair, go back in the
                // queue for a later scan
                if let Some(b) = cl.mrpools[m.src].get_mut(m.src_block) {
                    b.state = MrState::Active;
                }
                if m.repair {
                    self.seq.queue_repair(m.unit);
                }
            } else if cross_tier {
                // a tier move with nowhere to go is simply abandoned:
                // the block stays where it is and leaves the table
                if let Some(b) = cl.mrpools[m.src].get_mut(m.src_block) {
                    b.state = MrState::Active;
                }
                self.seq.mig_stats.tier_canceled += 1;
            } else {
                // every candidate filled up while we were queued: delete
                // (surviving replicas, if any, keep serving reads)
                self.seq.delete_victim(cl, m.src, m.src_block, Some(m.unit));
                self.queue_repair_if_under(Some(m.unit));
                self.seq.mig_stats.deleted += 1;
            }
            // a machine that lost its first destination to a death may
            // already hold parked sets — they flush exactly once on
            // the way out
            self.flush_orphaned_parked(cl, t_act, li, &mut m);
            return;
        };
        debug_assert_eq!(placed.tier, dst_tier);
        let dst = placed.node;
        let m = &mut self.lanes[li].migs[mi];
        let actions = m
            .sm
            .on_event(MigEvent::DestChosen { dst })
            .expect("destination differs from source");
        debug_assert!(actions.contains(&MigAction::StopWrites));
        debug_assert!(m.sm.writes_parked());
        m.dst = Some(dst);
        m.activated = t_act;
        // candidate queries (serialized control RTTs), then PREPARE to
        // src and dst in parallel, bounded by the slower ack — the
        // identical charge sequence as the `migration::simulate` oracle
        m.park_from = t_act + rtt * MIG_QUERIES as Ns;
        let (c1, _) = cl.fabric.ensure_connected(m.park_from, cl.sender, src);
        let (c2, _) = cl.fabric.ensure_connected(m.park_from, cl.sender, dst);
        m.phase_done = c1.max(c2) + rtt;
    }

    /// Fire the phase transition of the active machine at `mref` that
    /// completes at its `phase_done`.
    fn step_migration(&mut self, cl: &mut ClusterState, (li, mi): MigRef) {
        let rtt = ctrl_rtt(&self.lat);
        let owner = self.seq.owner_tag.unwrap_or(cl.sender);
        let state = self.lanes[li].migs[mi].sm.state();
        match state {
            MigState::Preparing => {
                let m = &mut self.lanes[li].migs[mi];
                m.sm
                    .on_event(MigEvent::PrepareAcked)
                    .expect("preparing accepts ack");
                let dst = m.dst.expect("active migration has dst");
                // src↔dst connection for the copy (may be new), then
                // the bulk copy on the source's NIC; the destination
                // registers its fresh MR block when the copy starts.
                // Copies touching the pooled appliance need no queue
                // pair — the pool is load/store-reachable from every
                // node — so those skip the connection and take pool
                // verbs (a same-node demotion pulls out of the local
                // pool slice with a pool read).
                let pool_copy = m.dst_tier == MemTier::Pool
                    || (dst == m.src && m.src_tier == MemTier::Pool);
                let t_conn = if pool_copy {
                    m.phase_done
                } else {
                    cl.fabric.ensure_connected(m.phase_done, m.src, dst).0
                };
                m.copy_start = t_conn;
                m.dst_block = Some(cl.mrpools[dst].register_tier(
                    owner,
                    m.block_bytes,
                    m.copy_start,
                    m.dst_tier,
                ));
                let verb = if m.dst_tier == MemTier::Pool {
                    cl.fabric.pool_write(
                        m.copy_start,
                        m.src,
                        dst,
                        m.block_bytes,
                    )
                } else if pool_copy {
                    cl.fabric.pool_read(
                        m.copy_start,
                        m.src,
                        dst,
                        m.block_bytes,
                    )
                } else {
                    cl.fabric.rdma_write(
                        m.copy_start,
                        m.src,
                        dst,
                        m.block_bytes,
                    )
                };
                m.copy_end = verb.end;
                m.phase_done = m.copy_end;
            }
            MigState::Copying => {
                let m = &mut self.lanes[li].migs[mi];
                m.sm
                    .on_event(MigEvent::CopyDone)
                    .expect("copying accepts copy-done");
                // source's memory is free once the copy is out — except
                // for a repair, which copies *alongside* its source
                if !m.repair {
                    cl.mrpools[m.src].release(m.src_block);
                }
                m.phase_done = m.copy_end + 2 * rtt;
            }
            MigState::Committing => self.commit_migration(cl, (li, mi)),
            s => unreachable!("active migration in phase {s:?}"),
        }
    }

    /// COMMIT acked: the sequencer's cross-peer step — remap the unit's
    /// replica slot to the destination, validate the replica set
    /// through [`choose_replicas`], issue the COMMIT ticket, flush
    /// parked write sets to the new location and retire the machine.
    fn commit_migration(&mut self, cl: &mut ClusterState, (li, mi): MigRef) {
        let mut m = self.lanes[li].migs.remove(mi);
        let done = m.phase_done;
        let actions = m
            .sm
            .on_event(MigEvent::CommitAcked)
            .expect("committing accepts ack");
        debug_assert!(actions.contains(&MigAction::FlushParkedWrites));
        debug_assert_eq!(m.sm.state(), MigState::Done);
        let dst = m.dst.expect("active migration has dst");
        let dst_block = m.dst_block.expect("copy registered the block");
        let mut flush_to = vec![(dst, dst_block)];
        if m.repair {
            // Re-replication COMMIT: *append* the fresh copy — the
            // source replica survives and its block returns to Active.
            if let Some(b) = cl.mrpools[m.src].get_mut(m.src_block) {
                b.state = MrState::Active;
            }
            if let Some(u) = self.seq.units.get_mut(m.unit) {
                if u.nodes.contains(&dst) {
                    // raced with a remap onto dst — drop the extra copy
                    cl.mrpools[dst].release(dst_block);
                } else {
                    u.nodes.push(dst);
                    u.blocks.push(dst_block);
                }
                debug_assert_eq!(
                    choose_replicas(
                        cl.sender,
                        u.nodes[0],
                        &u.nodes,
                        u.nodes.len()
                    ),
                    u.nodes,
                    "replica set must stay distinct across a repair append"
                );
                u.wlocked_until = u.wlocked_until.max(done);
                flush_to = u
                    .nodes
                    .iter()
                    .copied()
                    .zip(u.blocks.iter().copied())
                    .collect();
            }
            self.seq.mig_stats.repairs += 1;
        } else if let Some(u) = self.seq.units.get_mut(m.unit) {
            for (n, b) in u.nodes.iter_mut().zip(u.blocks.iter_mut()) {
                if *n == m.src && *b == m.src_block {
                    *n = dst;
                    *b = dst_block;
                }
            }
            // Remap validated through the §5.1 chooser: same primary,
            // distinct followers, sender skipped. The destination
            // filter in `reclaim_candidates` guarantees the swapped
            // set already satisfies it; pinning it to choose_replicas
            // keeps this path and the mapping path on one invariant.
            debug_assert_eq!(
                choose_replicas(cl.sender, u.nodes[0], &u.nodes, u.nodes.len()),
                u.nodes,
                "replica set must stay distinct across a remap"
            );
            u.wlocked_until = u.wlocked_until.max(done);
            flush_to = u
                .nodes
                .iter()
                .copied()
                .zip(u.blocks.iter().copied())
                .collect();
        }
        if !m.repair && m.forced_dst == Some(dst) {
            self.seq.mig_stats.rebalanced += 1;
        }
        // FlushParkedWrites: one coalesced message per replica carrying
        // everything that parked during the migration; completions land
        // in the owning shards' mailboxes like any other batch. The
        // in-flight entry stays on the source lane that ran the
        // migration.
        let parked_flushed = m.parked.len() as u64;
        if !m.parked.is_empty() {
            let t = done + self.lat.mrpool_get;
            let mut flush_done = t;
            for &(n, b) in &flush_to {
                let verb = cl.tiered_write(t, n, b, m.parked_bytes);
                flush_done = flush_done.max(verb.end);
            }
            self.seq.mig_stats.flushed_sets += m.parked.len() as u64;
            let mut by_shard: Vec<(usize, Vec<WriteSet>)> = Vec::new();
            for (shard, ws) in m.parked.drain(..) {
                match by_shard.iter_mut().find(|(s, _)| *s == shard) {
                    Some((_, sets)) => sets.push(ws),
                    None => by_shard.push((shard, vec![ws])),
                }
            }
            for (shard, sets) in by_shard {
                self.lanes[li].inflight.push(Inflight {
                    done: flush_done,
                    shard,
                    sets,
                });
            }
        }
        // pairwise overlap accounting: credit each concurrent pair once,
        // at the earlier completion (the other machine is still active)
        for other in self
            .lanes
            .iter()
            .flat_map(|l| l.migs.iter())
            .filter(|o| o.is_active())
        {
            let both_from = m.activated.max(other.activated);
            if done > both_from {
                self.seq.mig_stats.overlap_ns += done - both_from;
            }
        }
        self.seq.mig_stats.completed += 1;
        self.seq.commit_seq += 1;
        self.seq.mig_slot_free = self.seq.mig_slot_free.max(done);
        if m.src_tier != m.dst_tier {
            if m.dst_tier == MemTier::Pool {
                self.seq.mig_stats.promotions += 1;
            } else {
                self.seq.mig_stats.demotions += 1;
            }
        }
        self.seq.mig_records.push(MigrationRecord {
            unit: m.unit,
            src: m.src,
            dst,
            src_tier: m.src_tier,
            dst_tier: m.dst_tier,
            block_bytes: m.block_bytes,
            scheduled: m.scheduled,
            activated: m.activated,
            park_from: m.park_from,
            copy_start: m.copy_start,
            copy_end: m.copy_end,
            done,
            parked_flushed,
        });
    }

    // -- the invariant auditor ----------------------------------------

    /// Audit the slow path's conservation laws; returns every violation
    /// found (empty = clean). Always checks the lane migration tables
    /// ([`Law::MigrationLegality`], [`Law::MigratingNotReselected`],
    /// [`Law::ParkedFlushOnce`] — details carry the owning lane), the
    /// cross-lane commit ledger ([`Law::LaneSequencer`]) and the
    /// per-node pool-tier byte ledger plus promotion/demotion
    /// conservation ([`Law::TierAccounting`]); with
    /// `thorough` it also re-validates every live unit's replica set
    /// against [`choose_replicas`] ([`Law::ReplicaDistinct`]) and the
    /// failure-domain ledger — no live slot on a Dead peer,
    /// under-replication always queued or in repair
    /// ([`Law::ReplicaHealth`]) — the sweeps the crossing hooks sample
    /// and the fuzzer/tests run in full.
    pub fn audit_check(
        &self,
        cl: &ClusterState,
        thorough: bool,
    ) -> Vec<Violation> {
        let mut out = Vec::new();

        // -- lane-lock-coherence: every write set admitted to a lane's
        // ring was drained (dispatched under the sequencer) or still
        // queues. try_lock, not lock: a ring held at audit time can
        // only mean this very thread is mid-drain on it (pop and
        // dispatch happen under one hold, and every drain runs under
        // the sequencer the auditor's caller also holds), so skipping
        // re-proves it at the next sweep instead of self-deadlocking.
        for (li, ring) in self.rings.iter().enumerate() {
            let Ok(g) = ring.try_lock() else { continue };
            let queued = g.queued_sets();
            audit::check(
                &mut out,
                g.admitted == g.drained + queued && g.drained <= g.admitted,
                Law::LaneLockCoherence,
                None,
                || {
                    format!(
                        "lane {li} ring leaks write sets: admitted {} != \
                         drained {} + queued {queued}",
                        g.admitted, g.drained
                    )
                },
                || {
                    format!(
                        "lane={li} admitted={} drained={} queued={queued} \
                         entries={}",
                        g.admitted,
                        g.drained,
                        g.q.len()
                    )
                },
            );
        }

        // -- migration-legality: table states imply their fields and
        // the milestone clocks are ordered. Lane-local sweep, tagged
        // with the lane so a violation names its timeline.
        let all: Vec<(usize, &ActiveMigration)> = self
            .lanes
            .iter()
            .enumerate()
            .flat_map(|(li, l)| l.migs.iter().map(move |m| (li, m)))
            .collect();
        for (i, &(li, m)) in all.iter().enumerate() {
            let snap = || {
                format!(
                    "lane={li} unit={} src={} state={:?} scheduled={} \
                     activated={} park_from={} copy_start={} copy_end={} \
                     phase_done={}",
                    m.unit,
                    m.src,
                    m.sm.state(),
                    m.scheduled,
                    m.activated,
                    m.park_from,
                    m.copy_start,
                    m.copy_end,
                    m.phase_done,
                )
            };
            let dup = all[i + 1..].iter().any(|&(_, o)| o.unit == m.unit);
            audit::check(
                &mut out,
                !dup,
                Law::MigrationLegality,
                None,
                || format!("unit {} has two live migration entries", m.unit),
                snap,
            );
            audit::check(
                &mut out,
                !matches!(m.sm.state(), MigState::Idle | MigState::Done),
                Law::MigrationLegality,
                None,
                || {
                    format!(
                        "lane {li} entry for unit {} is in terminal/idle \
                         state",
                        m.unit
                    )
                },
                snap,
            );
            // lane ownership: a machine lives in its source peer's lane
            audit::check(
                &mut out,
                self.lane_of(m.src) == li,
                Law::MigrationLegality,
                None,
                || {
                    format!(
                        "machine for unit {} (src {}) lives in lane {li}, \
                         not its source lane {}",
                        m.unit,
                        m.src,
                        self.lane_of(m.src)
                    )
                },
                snap,
            );
            if m.is_active() {
                audit::check(
                    &mut out,
                    m.dst.is_some(),
                    Law::MigrationLegality,
                    None,
                    || {
                        format!(
                            "active migration of unit {} has no destination",
                            m.unit
                        )
                    },
                    snap,
                );
                audit::check(
                    &mut out,
                    m.scheduled <= m.activated && m.activated <= m.park_from,
                    Law::MigrationLegality,
                    None,
                    || {
                        format!(
                            "milestones out of order for unit {} \
                             (scheduled ≤ activated ≤ park_from)",
                            m.unit
                        )
                    },
                    snap,
                );
            }
            if matches!(
                m.sm.state(),
                MigState::Copying | MigState::Committing
            ) {
                audit::check(
                    &mut out,
                    m.dst_block.is_some(),
                    Law::MigrationLegality,
                    None,
                    || {
                        format!(
                            "copying/committing unit {} never registered \
                             its destination block",
                            m.unit
                        )
                    },
                    snap,
                );
                audit::check(
                    &mut out,
                    m.park_from <= m.copy_start
                        && m.copy_start <= m.copy_end,
                    Law::MigrationLegality,
                    None,
                    || {
                        format!(
                            "copy milestones out of order for unit {} \
                             (park_from ≤ copy_start ≤ copy_end)",
                            m.unit
                        )
                    },
                    snap,
                );
            }
        }

        // -- migrating-not-reselected: every `Migrating` block on every
        // peer is the source of exactly one live machine across all
        // lanes (and a machine whose source block is still registered
        // must have marked it).
        for (node, pool) in cl.mrpools.iter().enumerate() {
            for b in pool.blocks() {
                if b.state != crate::mrpool::MrState::Migrating {
                    continue;
                }
                let refs = all
                    .iter()
                    .filter(|&&(_, m)| m.src == node && m.src_block == b.id)
                    .count();
                // A tenant-tagged sender audits only its own blocks:
                // another tenant's migrations live in another sender.
                if self.seq.owner_tag.is_some_and(|tag| tag != b.owner) {
                    continue;
                }
                audit::check(
                    &mut out,
                    refs == 1,
                    Law::MigratingNotReselected,
                    None,
                    || {
                        format!(
                            "block {} on node {node} is Migrating but has \
                             {refs} owning migration entries",
                            b.id
                        )
                    },
                    || format!("table_len={}", all.len()),
                );
            }
        }

        // -- parked-flush-once: every set that ever parked is either
        // still parked or was flushed — never both, never neither.
        let parked_now: u64 =
            all.iter().map(|&(_, m)| m.parked.len() as u64).sum();
        audit::check(
            &mut out,
            self.seq.mig_stats.parked_sets
                == self.seq.mig_stats.flushed_sets + parked_now,
            Law::ParkedFlushOnce,
            None,
            || {
                format!(
                    "parked {} != flushed {} + in-table {}",
                    self.seq.mig_stats.parked_sets,
                    self.seq.mig_stats.flushed_sets,
                    parked_now
                )
            },
            || format!("{:?}", self.seq.mig_stats),
        );

        // -- lane-sequencer: the cross-lane commit ledger is
        // conserved — every COMMIT issued exactly one ticket, booked
        // exactly one completion and pushed exactly one record. Lanes
        // retire machines independently; only this three-way equality
        // proves no commit bypassed the sequencer (or was double-
        // counted by two lanes).
        audit::check(
            &mut out,
            self.seq.commit_seq == self.seq.mig_stats.completed
                && self.seq.mig_records.len() as u64 == self.seq.commit_seq,
            Law::LaneSequencer,
            None,
            || {
                format!(
                    "commit tickets {} vs completed {} vs records {}",
                    self.seq.commit_seq,
                    self.seq.mig_stats.completed,
                    self.seq.mig_records.len()
                )
            },
            || format!("{:?}", self.seq.mig_stats),
        );

        // -- tier-accounting: the cached pool-tier byte ledger on every
        // node matches a recount of its resident pool-tier blocks, and
        // the promotion/demotion counters are conserved against the
        // committed cross-tier migration records.
        for (node, pool) in cl.mrpools.iter().enumerate() {
            audit::check(
                &mut out,
                pool.pool_bytes() == pool.pool_bytes_recount(),
                Law::TierAccounting,
                None,
                || {
                    format!(
                        "node {node} pool-tier ledger {} != recount {}",
                        pool.pool_bytes(),
                        pool.pool_bytes_recount()
                    )
                },
                || format!("blocks={}", pool.blocks().len()),
            );
        }
        let tier_moves = self
            .seq
            .mig_records
            .iter()
            .filter(|r| r.src_tier != r.dst_tier)
            .count() as u64;
        audit::check(
            &mut out,
            self.seq.mig_stats.promotions + self.seq.mig_stats.demotions
                == tier_moves,
            Law::TierAccounting,
            None,
            || {
                format!(
                    "promotions {} + demotions {} != cross-tier records {}",
                    self.seq.mig_stats.promotions,
                    self.seq.mig_stats.demotions,
                    tier_moves
                )
            },
            || format!("{:?}", self.seq.mig_stats),
        );

        // -- replica-distinct (thorough sweep): the §5.1 chooser is the
        // oracle — re-deriving the replica list from itself must be a
        // fixed point (distinct nodes, sender excluded, primary first).
        if thorough {
            for (id, u) in self.seq.units.iter() {
                if !u.alive || u.nodes.is_empty() {
                    continue;
                }
                let snap = || {
                    format!(
                        "unit={id} nodes={:?} blocks={:?} alive={}",
                        u.nodes, u.blocks, u.alive
                    )
                };
                audit::check(
                    &mut out,
                    u.nodes.len() == u.blocks.len(),
                    Law::ReplicaDistinct,
                    None,
                    || {
                        format!(
                            "unit {id} has {} replica nodes but {} blocks",
                            u.nodes.len(),
                            u.blocks.len()
                        )
                    },
                    snap,
                );
                let rederived = choose_replicas(
                    cl.sender,
                    u.nodes[0],
                    &u.nodes,
                    u.nodes.len(),
                );
                audit::check(
                    &mut out,
                    rederived == u.nodes,
                    Law::ReplicaDistinct,
                    None,
                    || {
                        format!(
                            "unit {id} replica set {:?} is not a \
                             choose_replicas fixed point ({rederived:?})",
                            u.nodes
                        )
                    },
                    snap,
                );
            }
        }

        // -- replica-health (failure-domain law, thorough sweep): no
        // live replica slot references a Dead peer, a unit with no
        // slots is dead, and (health on) an under-replicated live unit
        // is queued for repair, owned by a live machine, or covered by
        // the disk backup — the zero-lost-writes contract's standing
        // half.
        if thorough {
            let want = self.vcfg.replicas.max(1);
            for (id, u) in self.seq.units.iter() {
                let snap = || {
                    format!(
                        "unit={id} nodes={:?} alive={} repair_queue={:?}",
                        u.nodes, u.alive, self.seq.repair_queue
                    )
                };
                audit::check(
                    &mut out,
                    !(u.alive && u.nodes.is_empty()),
                    Law::ReplicaHealth,
                    None,
                    || format!("unit {id} is alive with no replica slots"),
                    snap,
                );
                if !u.alive {
                    continue;
                }
                for &n in &u.nodes {
                    audit::check(
                        &mut out,
                        self.seq.health.state(n) != Health::Dead,
                        Law::ReplicaHealth,
                        None,
                        || {
                            format!(
                                "unit {id} holds a live replica slot on \
                                 dead peer {n}"
                            )
                        },
                        snap,
                    );
                }
                if self.seq.health.enabled && u.nodes.len() < want {
                    let queued = self.seq.repair_queue.contains(id);
                    let machine = self
                        .lanes
                        .iter()
                        .flat_map(|l| l.migs.iter())
                        .any(|mg| mg.unit == *id);
                    audit::check(
                        &mut out,
                        queued || machine || self.vcfg.disk_backup,
                        Law::ReplicaHealth,
                        None,
                        || {
                            format!(
                                "unit {id} is under-replicated \
                                 ({}/{want}) with no queued repair, live \
                                 machine or disk backup",
                                u.nodes.len()
                            )
                        },
                        snap,
                    );
                }
            }
        }
        out
    }

    /// Test-only corruption hook for [`Law::ReplicaDistinct`]:
    /// duplicate a replica slot on the first live unit. Returns false
    /// when no unit exists to corrupt.
    #[cfg(any(feature = "audit", debug_assertions))]
    #[doc(hidden)]
    pub fn audit_corrupt_replicas(&mut self) -> bool {
        for (_, u) in self.seq.units.iter_mut() {
            if !u.alive || u.nodes.is_empty() {
                continue;
            }
            let n = u.nodes[0];
            let b = u.blocks[0];
            if u.nodes.len() >= 2 {
                u.nodes[1] = n;
                u.blocks[1] = b;
            } else {
                u.nodes.push(n);
                u.blocks.push(b);
            }
            return true;
        }
        false
    }

    /// Test-only corruption hook for [`Law::MigrationLegality`]: inject
    /// a fabricated machine in an active state with no destination.
    #[cfg(any(feature = "audit", debug_assertions))]
    #[doc(hidden)]
    pub fn audit_inject_bogus_migration(&mut self, unit: u64) {
        let mut sm = MigrationSm::new();
        sm.on_event(MigEvent::PressureReport { block: 0, src: 1 })
            .expect("fresh machine accepts a pressure report");
        sm.on_event(MigEvent::DestChosen { dst: 2 })
            .expect("choosing-dest accepts a destination");
        let stamp = self.seq.next_mig_seq();
        let lane = self.lane_of(1);
        self.lanes[lane].migs.push(ActiveMigration {
            sm,
            unit,
            src: 1,
            src_block: 0,
            src_tier: MemTier::Remote,
            dst_tier: MemTier::Remote,
            block_bytes: 0,
            scheduled: 10,
            dst: None, // the corruption: active yet destination-less
            dst_block: None,
            activated: 5, // and activated before it was scheduled
            park_from: 1,
            copy_start: 0,
            copy_end: 0,
            phase_done: 0,
            parked: Vec::new(),
            parked_bytes: 0,
            seq: stamp,
            repair: false,
            forced_dst: None,
        });
    }

    /// Test-only corruption hook for [`Law::ReplicaHealth`]: mark the
    /// first live unit's primary peer Dead *without* running the death
    /// sweep, leaving a live slot pointing at a dead peer. Returns
    /// false when no live unit exists to corrupt.
    #[cfg(any(feature = "audit", debug_assertions))]
    #[doc(hidden)]
    pub fn audit_corrupt_health(&mut self) -> bool {
        let victim = self
            .seq
            .units
            .iter()
            .filter(|(_, u)| u.alive)
            .filter_map(|(_, u)| u.nodes.first().copied())
            .next();
        match victim {
            Some(n) => {
                self.seq.health.force_dead(n);
                true
            }
            None => false,
        }
    }

    /// Test-only corruption hook for [`Law::ParkedFlushOnce`]: claim a
    /// parked set that never existed.
    #[cfg(any(feature = "audit", debug_assertions))]
    #[doc(hidden)]
    pub fn audit_corrupt_parked_stats(&mut self) {
        self.seq.mig_stats.parked_sets += 1;
    }

    /// Test-only corruption hook for [`Law::LaneSequencer`]: issue a
    /// COMMIT ticket no lane's machine ever earned.
    #[cfg(any(feature = "audit", debug_assertions))]
    #[doc(hidden)]
    pub fn audit_corrupt_commit_ledger(&mut self) {
        self.seq.commit_seq += 1;
    }

    /// Test-only corruption hook for [`Law::TierAccounting`]: claim a
    /// promotion no cross-tier migration record backs.
    #[cfg(any(feature = "audit", debug_assertions))]
    #[doc(hidden)]
    pub fn audit_corrupt_tier_ledger(&mut self) {
        self.seq.mig_stats.promotions += 1;
    }

    /// Test-only corruption hook for [`Law::LaneLockCoherence`]: claim
    /// an admitted write set that never entered ring 0.
    #[cfg(any(feature = "audit", debug_assertions))]
    #[doc(hidden)]
    pub fn audit_corrupt_ring(&mut self) {
        self.rings[0]
            .lock()
            .expect("lane admission ring poisoned")
            .admitted += 1;
    }
}

/// Lock-free-side admission for the concurrent serve front-end: pop
/// `fast`'s staged write sets front-to-back, coalesce each consecutive
/// same-unit run under the RDMA message cap exactly like
/// [`RemoteSender::send_batch_at`]'s pop loop, and push every batch into
/// its lane's admission ring — taking only that ring's mutex, never the
/// sequencer (a shard worker therefore never blocks on slow-path work).
/// Disk-backup stamping and the shard's disk-write metric happen here,
/// on the side that owns the fast path. A free function on purpose: its
/// signature *proves* admission needs no `&RemoteSender` and so no
/// sequencer lock. Returns `false` when a full ring stopped admission
/// early — the remaining sets stay staged and the pump's locked drive
/// path sends them (bounded-queue fallback, never a loss point).
pub(crate) fn admit_staged(
    vcfg: &ValetConfig,
    rings: &LaneRings,
    fast: &mut ShardFastPath,
    shard: usize,
) -> bool {
    let unit_bytes = vcfg.mr_block_bytes.max(PAGE_SIZE);
    let max = if vcfg.coalescing { vcfg.rdma_msg_bytes } else { 1 };
    loop {
        let Some(head) = fast.staging.get(0) else { return true };
        let unit = head.page * PAGE_SIZE / unit_bytes;
        // lock-order: ring only — admission never holds the sequencer
        let mut ring = rings[(unit as usize) % rings.len()]
            .lock()
            .expect("lane admission ring poisoned");
        if ring.q.len() >= lane::RING_CAP {
            return false;
        }
        let mut batch = Vec::new();
        let mut bytes = 0u64;
        let mut enq: Ns = 0;
        while let Some(next) = fast.staging.get(0) {
            let same_unit = next.page * PAGE_SIZE / unit_bytes == unit;
            if !batch.is_empty() && (bytes + next.bytes > max || !same_unit)
            {
                break;
            }
            let ws = fast
                .staging
                .remove(0)
                .expect("get just returned this entry");
            bytes += ws.bytes;
            enq = enq.max(ws.enqueued_at);
            batch.push(ws);
        }
        if vcfg.disk_backup {
            for ws in &batch {
                for p in ws.page..ws.page + ws.pages() {
                    fast.disk_valid.set(p);
                }
            }
            fast.metrics.disk_writes += 1;
        }
        let leftover = ring.admit(RingEntry {
            shard,
            unit,
            bytes,
            enq,
            sets: batch,
        });
        debug_assert!(leftover.is_none(), "capacity was checked above");
    }
}
