//! The host-coordinated dynamic local memory pool (§3.4, §4.1, Table 2) —
//! the centerpiece of Valet's critical-path redesign.
//!
//! Semantics (vs Linux mempool, Table 2):
//! * pre-allocated pages are used FIRST (no allocation on the hot path);
//! * the pool grows on demand when usage crosses `grow_threshold` (80 %),
//!   capped by `min(max_pool_pages, host_free_fraction × host free)`;
//! * it shrinks when host free memory drops, but never below
//!   `min_pool_pages`;
//! * freed pages return to the pool instead of the OS.
//!
//! Each slot carries the §5.2 consistency flags: `UPDATE` (a newer write
//! set exists for the same page — skip this slot when its older write set
//! reclaims) and `RECLAIMABLE` (remote copy is durable; safe to reuse).
//! Reclaim order is LRU ("For replacement policy, we use LRU in our
//! prototype").

use crate::audit::{self, Law, Violation};
use crate::config::Replacement;
use crate::util::Lru;

/// Per-slot consistency flags (§5.2). The paper pairs an Update flag with
/// a reference counter (Figure 17 caption); we fold both into a pending-
/// supersede counter: it counts how many *newer* write sets cover the
/// same page, so each older write set's completion decrements instead of
/// reclaiming.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SlotFlags {
    /// Number of newer write sets covering the same page; while > 0 the
    /// slot must NOT be freed when an (older) write set is reclaimed.
    pub update_pending: u16,
    /// The slot's data is durably replicated (remote and/or disk);
    /// eligible for reuse via the reclaimable queue.
    pub reclaimable: bool,
    /// The slot was filled by the stride prefetcher and has not served
    /// a demand read yet: first in line for reclaim, so readahead can
    /// never worsen eviction of demand-cached pages.
    pub prefetched: bool,
}

/// State of one mempool page slot.
#[derive(Clone, Debug, PartialEq, Eq)]
enum Slot {
    Free,
    Used {
        /// Page number in the block device address space.
        page: u64,
        flags: SlotFlags,
    },
}

/// Why an allocation failed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AllocFail {
    /// Pool at capacity and nothing reclaimable — caller must wait for
    /// remote sending to drain (this is the backpressure signal).
    NoReclaimable,
}

/// Outcome of a successful allocation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Alloc {
    /// The slot handed out.
    pub slot: u32,
    /// If the slot was recycled from a reclaimable page, the page that
    /// was evicted from the pool (its GPT entry must be dropped).
    pub evicted_page: Option<u64>,
    /// Whether the pool grew to satisfy this allocation.
    pub grew: bool,
}

/// The dynamic local memory pool.
#[derive(Clone, Debug)]
pub struct Mempool {
    slots: Vec<Slot>,
    free: Vec<u32>,
    /// Slot ids retired by shrink/donation (tombstoned `Slot::Free`
    /// entries); reused first on growth so the slot vec stays bounded
    /// under lease oscillation.
    retired: Vec<u32>,
    /// LRU over *reclaimable* used slots only.
    reclaim_lru: Lru<u32>,
    /// LRU over prefetched-but-unused slots (disjoint from
    /// `reclaim_lru`); always drained before it, so wrong guesses are
    /// the first pages to go under pressure.
    prefetch_q: Lru<u32>,
    capacity: u64,
    min_pages: u64,
    max_pages: u64,
    grow_threshold: f64,
    host_free_fraction: f64,
    /// Arbiter lease: absolute page cap in multi-tenant operation
    /// (`u64::MAX` when unleased — the single-tenant default).
    lease: u64,
    /// Grow events (stats / Figure 8 diagnostics).
    pub grows: u64,
    /// Shrink events (stats).
    pub shrinks: u64,
    /// Pages recycled through the reclaim path (stats).
    pub reclaims: u64,
    /// Successful allocations (stats; the arbiter's activity signal).
    pub allocs: u64,
    /// Failed allocations — pool exhausted, caller stalled (stats; the
    /// arbiter's backpressure signal).
    pub alloc_stalls: u64,
    /// Pages donated back to the host pool (stats).
    pub donations: u64,
    /// Prefetched pages recycled, donated or overwritten before any
    /// demand read touched them (the prefetcher's waste signal).
    pub prefetch_evicted: u64,
    /// Replacement policy for the reclaim list.
    replacement: Replacement,
    /// First cap breach observed at a grow site, if any:
    /// `(effective_cap at grow time, capacity grown to)`. Sticky — set
    /// once, reported by [`Self::audit_check`]
    /// ([`Law::MempoolCapGrowth`]). Only written when
    /// [`audit::enabled`].
    cap_breach: Option<(u64, u64)>,
}

impl Mempool {
    /// Build with the policy knobs from [`crate::config::ValetConfig`].
    pub fn new(
        min_pages: u64,
        max_pages: u64,
        grow_threshold: f64,
        host_free_fraction: f64,
    ) -> Self {
        let cap = min_pages.max(1);
        Mempool {
            slots: vec![Slot::Free; cap as usize],
            free: (0..cap as u32).rev().collect(),
            retired: Vec::new(),
            reclaim_lru: Lru::new(),
            prefetch_q: Lru::new(),
            capacity: cap,
            min_pages: cap,
            max_pages: max_pages.max(cap),
            grow_threshold,
            host_free_fraction,
            lease: u64::MAX,
            grows: 0,
            shrinks: 0,
            reclaims: 0,
            allocs: 0,
            alloc_stalls: 0,
            donations: 0,
            prefetch_evicted: 0,
            replacement: Replacement::Lru,
            cap_breach: None,
        }
    }

    /// Switch the replacement policy (LRU default; MRU per the paper's
    /// §6.2 future-work note for repetitive access patterns).
    pub fn with_replacement(mut self, r: Replacement) -> Self {
        self.replacement = r;
        self
    }

    /// Current pool size in pages.
    pub fn capacity(&self) -> u64 {
        self.capacity
    }

    /// Guaranteed minimum pool size in pages (`min_pool_pages`, §4.1):
    /// grow/shrink never moves `capacity` below this floor.
    pub fn min_pages(&self) -> u64 {
        self.min_pages
    }

    /// Pages currently holding data.
    pub fn used(&self) -> u64 {
        self.capacity - self.free.len() as u64
    }

    /// Usage fraction in [0,1].
    pub fn usage(&self) -> f64 {
        self.used() as f64 / self.capacity.max(1) as f64
    }

    /// Current arbiter lease in pages (`u64::MAX` when unleased — see
    /// [`crate::arbiter::HostArbiter`]).
    pub fn lease(&self) -> u64 {
        self.lease
    }

    /// Update the arbiter lease. [`Self::effective_cap`] takes the
    /// minimum of this, `max_pool_pages` and the host-free cap; a
    /// lowered lease is enforced by the owner's next pump (free-slot
    /// shrink, then [`Self::donate_idle`]).
    pub fn set_lease(&mut self, pages: u64) {
        self.lease = pages;
    }

    /// Effective cap given current host free memory:
    /// `min(max_pool_pages, host_free_fraction × host_free_pages,
    /// lease)`, never below `min_pool_pages`.
    pub fn effective_cap(&self, host_free_pages: u64) -> u64 {
        let host_cap =
            (host_free_pages as f64 * self.host_free_fraction) as u64;
        self.max_pages
            .min(host_cap)
            .min(self.lease)
            .max(self.min_pages)
    }

    fn grow_to(&mut self, new_cap: u64) {
        debug_assert!(new_cap > self.capacity);
        // Reuse retired (tombstoned) ids first, then mint fresh ids at
        // slots.len() — NOT at `capacity`: after a shrink or a donation,
        // capacity and slots.len() diverge, so ids minted from
        // `capacity..` would alias live Used slots.
        for _ in self.capacity..new_cap {
            let id = match self.retired.pop() {
                Some(id) => id,
                None => {
                    self.slots.push(Slot::Free);
                    (self.slots.len() - 1) as u32
                }
            };
            debug_assert!(matches!(
                self.slots[id as usize],
                Slot::Free
            ));
            self.free.push(id);
        }
        self.capacity = new_cap;
        self.grows += 1;
    }

    /// Allocate a slot for `page`. Strategy (§4.1):
    /// 1. use a pre-allocated free page;
    /// 2. if usage ≥ grow_threshold and the effective cap allows, grow;
    /// 3. otherwise recycle a prefetched-but-unused slot (readahead is
    ///    the first thing to go under pressure — it can never worsen
    ///    eviction of demand-cached pages);
    /// 4. otherwise recycle the LRU *reclaimable* slot (a few CPU cycles —
    ///    "reclaiming is just moving a page pointer");
    /// 5. otherwise fail — backpressure until remote sending catches up.
    pub fn alloc(
        &mut self,
        page: u64,
        host_free_pages: u64,
    ) -> Result<Alloc, AllocFail> {
        // Grow proactively when usage crosses the threshold.
        let mut grew = false;
        let cap = self.effective_cap(host_free_pages);
        if self.usage() >= self.grow_threshold && self.capacity < cap {
            // grow by 25% of current size, clamped to the cap
            let step = (self.capacity / 4).max(64);
            self.grow_to((self.capacity + step).min(cap));
            self.note_grow_within(cap);
            grew = true;
        }
        if let Some(slot) = self.free.pop() {
            self.slots[slot as usize] = Slot::Used {
                page,
                flags: SlotFlags::default(),
            };
            self.allocs += 1;
            return Ok(Alloc {
                slot,
                evicted_page: None,
                grew,
            });
        }
        // Recycle: prefetched-but-unused slots first, then the
        // reclaimable list per the replacement policy.
        let victim = match self.prefetch_q.pop_lru() {
            Some(v) => {
                self.prefetch_evicted += 1;
                Some(v)
            }
            None => match self.replacement {
                Replacement::Lru => self.reclaim_lru.pop_lru(),
                Replacement::Mru => self.reclaim_lru.pop_mru(),
            },
        };
        if let Some(victim) = victim {
            let evicted_page = match &self.slots[victim as usize] {
                Slot::Used { page, .. } => *page,
                Slot::Free => unreachable!("recycle lists hold used slots"),
            };
            self.slots[victim as usize] = Slot::Used {
                page,
                flags: SlotFlags::default(),
            };
            self.reclaims += 1;
            self.allocs += 1;
            return Ok(Alloc {
                slot: victim,
                evicted_page: Some(evicted_page),
                grew,
            });
        }
        self.alloc_stalls += 1;
        Err(AllocFail::NoReclaimable)
    }

    /// Allocate a slot for a *prefetched* page. Readahead must never
    /// displace live (non-reclaimable) demand data or grow the pool on
    /// speculation, so only a pre-allocated free slot, an idle
    /// reclaimable (remote-durable) slot, or — last resort — another
    /// prefetched-but-unused slot may hold it; `None` means the pool
    /// has no room for speculation right now and the prefetch is simply
    /// dropped. Idle reclaimable slots are preferred over recycling the
    /// prefetch queue, which would cannibalize the readahead window's
    /// own not-yet-read pages. The slot comes back tagged `prefetched`
    /// + `reclaimable` (its remote copy is valid by construction) and
    /// queued in the prefetch LRU.
    pub fn alloc_prefetched(&mut self, page: u64) -> Option<Alloc> {
        let flags = SlotFlags {
            update_pending: 0,
            reclaimable: true,
            prefetched: true,
        };
        if let Some(slot) = self.free.pop() {
            self.slots[slot as usize] = Slot::Used { page, flags };
            self.prefetch_q.touch(slot);
            self.allocs += 1;
            return Some(Alloc {
                slot,
                evicted_page: None,
                grew: false,
            });
        }
        let reclaim = match self.replacement {
            Replacement::Lru => self.reclaim_lru.pop_lru(),
            Replacement::Mru => self.reclaim_lru.pop_mru(),
        };
        let victim = match reclaim {
            Some(v) => v,
            None => {
                let v = self.prefetch_q.pop_lru()?;
                self.prefetch_evicted += 1;
                v
            }
        };
        let evicted_page = match &self.slots[victim as usize] {
            Slot::Used { page, .. } => *page,
            Slot::Free => unreachable!("recycle lists hold used slots"),
        };
        self.slots[victim as usize] = Slot::Used { page, flags };
        self.prefetch_q.touch(victim);
        self.reclaims += 1;
        self.allocs += 1;
        Some(Alloc {
            slot: victim,
            evicted_page: Some(evicted_page),
            grew: false,
        })
    }

    /// A demand read touched a prefetched slot: clear the tag and move
    /// it from the prefetch queue into the normal reclaim LRU (it stays
    /// reclaimable — its remote copy is still valid). Returns true if
    /// the slot was prefetched.
    pub fn promote_prefetched(&mut self, slot: u32) -> bool {
        match &mut self.slots[slot as usize] {
            Slot::Used { flags, .. } if flags.prefetched => {
                flags.prefetched = false;
                self.prefetch_q.remove(&slot);
                self.reclaim_lru.touch(slot);
                true
            }
            _ => false,
        }
    }

    /// Prefetched pages currently waiting unused in the pool.
    pub fn prefetched_count(&self) -> usize {
        self.prefetch_q.len()
    }

    /// Page stored in `slot` (panics on a free slot — caller bug).
    pub fn page_of(&self, slot: u32) -> u64 {
        match &self.slots[slot as usize] {
            Slot::Used { page, .. } => *page,
            Slot::Free => panic!("page_of on free slot {slot}"),
        }
    }

    /// Flags of `slot`.
    pub fn flags(&self, slot: u32) -> SlotFlags {
        match &self.slots[slot as usize] {
            Slot::Used { flags, .. } => *flags,
            Slot::Free => panic!("flags on free slot {slot}"),
        }
    }

    /// A newer write set now covers this page: bump the pending-supersede
    /// counter so the older write set's completion skips the slot.
    pub fn bump_update(&mut self, slot: u32) {
        if let Slot::Used { flags, .. } = &mut self.slots[slot as usize] {
            flags.update_pending += 1;
        }
    }

    /// Mark `slot` reclaimable (its write set reached the remote copy) and
    /// enter it into the reclaim LRU. Per §5.2, a superseded slot
    /// (`update_pending > 0`) is skipped and the counter decremented: a
    /// newer write set owns the page now and will reclaim it later.
    /// Returns true if the slot became reclaimable.
    pub fn mark_reclaimable(&mut self, slot: u32) -> bool {
        match &mut self.slots[slot as usize] {
            Slot::Used { flags, .. } => {
                if flags.update_pending > 0 {
                    flags.update_pending -= 1;
                    false
                } else {
                    flags.reclaimable = true;
                    self.reclaim_lru.touch(slot);
                    true
                }
            }
            Slot::Free => false,
        }
    }

    /// Touch a slot on read (LRU recency for the cache-replacement order).
    pub fn touch(&mut self, slot: u32) {
        if self.reclaim_lru.contains(&slot) {
            self.reclaim_lru.touch(slot);
        }
    }

    /// A write re-dirtied this slot: it is no longer safe to reclaim until
    /// its new write set is remotely durable. A prefetched slot that gets
    /// overwritten before any read counts as prefetch waste — the stale
    /// remote copy it was fetched from is now superseded.
    pub fn unmark_reclaimable(&mut self, slot: u32) {
        if let Slot::Used { flags, .. } = &mut self.slots[slot as usize] {
            flags.reclaimable = false;
            if flags.prefetched {
                flags.prefetched = false;
                self.prefetch_evicted += 1;
            }
        }
        self.reclaim_lru.remove(&slot);
        self.prefetch_q.remove(&slot);
    }

    /// Free a slot outright (page dropped, e.g. discard/trim).
    pub fn free_slot(&mut self, slot: u32) {
        self.reclaim_lru.remove(&slot);
        if self.prefetch_q.remove(&slot) {
            self.prefetch_evicted += 1;
        }
        if matches!(self.slots[slot as usize], Slot::Used { .. }) {
            self.slots[slot as usize] = Slot::Free;
            self.free.push(slot);
        }
    }

    /// Shrink toward the effective cap for the given host free memory.
    /// Only *free* slots can be released (used ones must first drain via
    /// remote sending); returns how many pages were released to the host.
    pub fn shrink(&mut self, host_free_pages: u64) -> u64 {
        let cap = self.effective_cap(host_free_pages);
        if self.capacity <= cap {
            return 0;
        }
        // Release free slots from the tail of the slot array where
        // possible; slots are logical here (the sim carries no data), so
        // just drop free-list entries.
        let want = self.capacity - cap;
        let can = (self.free.len() as u64).min(want);
        if can == 0 {
            return 0;
        }
        for _ in 0..can {
            let s = self
                .free
                .pop()
                .expect("shrink: `can` is bounded by the free-list length");
            // tombstone: the id leaves the pool with its page of
            // capacity, and is reusable on a later grow
            self.retired.push(s);
        }
        self.capacity -= can;
        self.shrinks += 1;
        can
    }

    /// Donate up to `want` idle pages back to the host pool — the
    /// arbiter's give-back path when a lowered lease cannot be reached
    /// by releasing free slots alone. Recycles prefetched-but-unused
    /// slots first (speculation yields before demand data), then
    /// reclaimable (remote-durable) slots in replacement order,
    /// dropping both the slot and one page of capacity each; never
    /// shrinks below `min_pages`. The evicted pages are appended to the
    /// caller's `evicted` buffer (cleared first) — the caller must drop
    /// their GPT entries (their next read is served remotely) — and the
    /// count is returned. The buffer is caller-owned and reusable, so
    /// the arbiter's per-tick give-back allocates nothing in steady
    /// state.
    pub fn donate_idle(&mut self, want: u64, evicted: &mut Vec<u64>) -> u64 {
        evicted.clear();
        let room = self.capacity.saturating_sub(self.min_pages);
        let idle = self.prefetch_q.len() + self.reclaim_lru.len();
        let take = want.min(room).min(idle as u64);
        for _ in 0..take {
            let victim = match self.prefetch_q.pop_lru() {
                Some(v) => {
                    self.prefetch_evicted += 1;
                    Some(v)
                }
                None => match self.replacement {
                    Replacement::Lru => self.reclaim_lru.pop_lru(),
                    Replacement::Mru => self.reclaim_lru.pop_mru(),
                },
            };
            let Some(victim) = victim else { break };
            if let Slot::Used { page, .. } = &self.slots[victim as usize] {
                evicted.push(*page);
            }
            // The slot leaves the pool entirely (not returned to the
            // free list): its page of capacity goes back to the host,
            // and its id is reusable on a later grow.
            self.slots[victim as usize] = Slot::Free;
            self.retired.push(victim);
            self.capacity -= 1;
            self.donations += 1;
        }
        if !evicted.is_empty() {
            self.shrinks += 1;
        }
        evicted.len() as u64
    }

    /// Number of reclaimable slots waiting in the LRU.
    pub fn reclaimable_count(&self) -> usize {
        self.reclaim_lru.len()
    }

    /// Visit every used slot as `f(slot, page, flags)`, in slot-id
    /// order. Diagnostic/audit helper — the GPT-coherence law walks
    /// this to prove the resident set and the page table agree.
    pub fn for_each_used(&self, mut f: impl FnMut(u32, u64, SlotFlags)) {
        for (i, s) in self.slots.iter().enumerate() {
            if let Slot::Used { page, flags } = s {
                f(i as u32, *page, *flags);
            }
        }
    }

    /// Record a cap breach if the grow that just ran landed above the
    /// effective cap in force at grow time. The real grow path clamps
    /// to the cap, so this fires only if that clamp ever regresses (or
    /// through the test-only [`Self::audit_force_grow`] hook).
    fn note_grow_within(&mut self, cap: u64) {
        if audit::enabled()
            && self.capacity > cap
            && self.cap_breach.is_none()
        {
            self.cap_breach = Some((cap, self.capacity));
        }
    }

    /// Audit this pool's conservation laws; returns every violation
    /// found (empty = clean). Covers [`Law::MempoolAccounting`],
    /// [`Law::MempoolCapGrowth`], [`Law::MempoolQueueCoherence`] and
    /// [`Law::PrefetchIsolation`]. Pure reader — shared by the
    /// crossing-time enforcement in the engine and by the negative
    /// tests, which observe instead of panicking.
    pub fn audit_check(&self, shard: Option<usize>) -> Vec<Violation> {
        let mut out = Vec::new();
        let snapshot = || {
            format!(
                "capacity={} slots={} free={} retired={} reclaim_lru={} \
                 prefetch_q={} min={} max={} lease={}",
                self.capacity,
                self.slots.len(),
                self.free.len(),
                self.retired.len(),
                self.reclaim_lru.len(),
                self.prefetch_q.len(),
                self.min_pages,
                self.max_pages,
                self.lease,
            )
        };

        // -- mempool-accounting: the slot id space partitions exactly
        // into used ∪ free ∪ retired, and capacity tracks it.
        let used_count = self
            .slots
            .iter()
            .filter(|s| matches!(s, Slot::Used { .. }))
            .count() as u64;
        let acct = |out: &mut Vec<Violation>, ok: bool, detail: String| {
            audit::check(
                out,
                ok,
                Law::MempoolAccounting,
                shard,
                move || detail,
                snapshot,
            );
        };
        acct(
            &mut out,
            self.capacity as usize + self.retired.len() == self.slots.len(),
            format!(
                "capacity {} + retired {} != slot array {}",
                self.capacity,
                self.retired.len(),
                self.slots.len()
            ),
        );
        acct(
            &mut out,
            used_count + self.free.len() as u64 == self.capacity,
            format!(
                "used {} + free {} != capacity {}",
                used_count,
                self.free.len(),
                self.capacity
            ),
        );
        acct(
            &mut out,
            self.min_pages <= self.capacity && self.capacity <= self.max_pages,
            format!(
                "capacity {} outside [{}, {}]",
                self.capacity, self.min_pages, self.max_pages
            ),
        );
        let mut seen = vec![false; self.slots.len()];
        for (kind, list) in [("free", &self.free), ("retired", &self.retired)]
        {
            for &id in list {
                let i = id as usize;
                if i >= self.slots.len() {
                    acct(
                        &mut out,
                        false,
                        format!("{kind} list holds out-of-range slot {id}"),
                    );
                    continue;
                }
                acct(
                    &mut out,
                    !seen[i],
                    format!("slot {id} appears twice across free/retired"),
                );
                seen[i] = true;
                acct(
                    &mut out,
                    matches!(self.slots[i], Slot::Free),
                    format!("{kind} list holds used slot {id}"),
                );
            }
        }

        // -- mempool-cap-growth: a grow site exceeded the effective cap.
        if let Some((cap, grew_to)) = self.cap_breach {
            out.push(Violation::new(
                Law::MempoolCapGrowth,
                shard,
                format!("pool grew to {grew_to} pages past effective cap {cap}"),
                snapshot(),
            ));
        }

        // -- mempool-queue-coherence + prefetch-isolation: the recycle
        // queues and the per-slot flags describe the same sets, and a
        // speculative slot is always displaceable.
        let mut reclaim_flagged = 0usize;
        let mut prefetch_flagged = 0usize;
        for (i, s) in self.slots.iter().enumerate() {
            let Slot::Used { flags, page } = s else { continue };
            let slot = i as u32;
            if flags.prefetched {
                prefetch_flagged += 1;
                audit::check(
                    &mut out,
                    self.prefetch_q.contains(&slot),
                    Law::MempoolQueueCoherence,
                    shard,
                    || {
                        format!(
                            "prefetched slot {slot} (page {page}) missing \
                             from the prefetch queue"
                        )
                    },
                    snapshot,
                );
                audit::check(
                    &mut out,
                    flags.reclaimable,
                    Law::PrefetchIsolation,
                    shard,
                    || {
                        format!(
                            "prefetched slot {slot} (page {page}) is not \
                             reclaimable: speculation would pin out demand \
                             data"
                        )
                    },
                    snapshot,
                );
            } else if flags.reclaimable {
                reclaim_flagged += 1;
                audit::check(
                    &mut out,
                    self.reclaim_lru.contains(&slot),
                    Law::MempoolQueueCoherence,
                    shard,
                    || {
                        format!(
                            "reclaimable slot {slot} (page {page}) missing \
                             from the reclaim LRU"
                        )
                    },
                    snapshot,
                );
            }
        }
        audit::check(
            &mut out,
            reclaim_flagged == self.reclaim_lru.len(),
            Law::MempoolQueueCoherence,
            shard,
            || {
                format!(
                    "reclaim LRU holds {} entries but {} slots are flagged \
                     reclaimable",
                    self.reclaim_lru.len(),
                    reclaim_flagged
                )
            },
            snapshot,
        );
        audit::check(
            &mut out,
            prefetch_flagged == self.prefetch_q.len(),
            Law::MempoolQueueCoherence,
            shard,
            || {
                format!(
                    "prefetch queue holds {} entries but {} slots are \
                     flagged prefetched",
                    self.prefetch_q.len(),
                    prefetch_flagged
                )
            },
            snapshot,
        );
        out
    }

    /// Test-only corruption hook for [`Law::MempoolCapGrowth`]: grow
    /// unconditionally past the effective-cap clamp, recording the
    /// breach exactly the way the real grow path would.
    #[cfg(any(feature = "audit", debug_assertions))]
    #[doc(hidden)]
    pub fn audit_force_grow(&mut self, extra: u64, host_free_pages: u64) {
        let cap = self.effective_cap(host_free_pages);
        self.grow_to(self.capacity + extra.max(1));
        self.note_grow_within(cap);
    }

    /// Test-only corruption hook for [`Law::MempoolAccounting`]:
    /// duplicate a free-list entry, breaking the used∪free∪retired
    /// partition.
    #[cfg(any(feature = "audit", debug_assertions))]
    #[doc(hidden)]
    pub fn audit_corrupt_free_list(&mut self) {
        if let Some(&s) = self.free.last() {
            self.free.push(s);
        }
    }

    /// Test-only corruption hook for [`Law::MempoolQueueCoherence`]:
    /// drop a prefetched slot from the prefetch queue while leaving its
    /// `prefetched` flag set. Returns false if there was nothing to
    /// corrupt.
    #[cfg(any(feature = "audit", debug_assertions))]
    #[doc(hidden)]
    pub fn audit_desync_prefetch_queue(&mut self) -> bool {
        self.prefetch_q.pop_lru().is_some()
    }

    /// Test-only corruption hook for [`Law::PrefetchIsolation`]: strip
    /// the `reclaimable` flag off a prefetched slot, leaving pinned
    /// speculation. Returns false if no prefetched slot exists.
    #[cfg(any(feature = "audit", debug_assertions))]
    #[doc(hidden)]
    pub fn audit_pin_prefetched(&mut self) -> bool {
        for s in &mut self.slots {
            if let Slot::Used { flags, .. } = s {
                if flags.prefetched {
                    flags.reclaimable = false;
                    return true;
                }
            }
        }
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn pool() -> Mempool {
        Mempool::new(8, 64, 0.8, 0.5)
    }

    #[test]
    fn uses_preallocated_first() {
        let mut p = pool();
        let a = p.alloc(100, 1 << 20).unwrap();
        assert_eq!(a.evicted_page, None);
        assert_eq!(p.used(), 1);
        assert_eq!(p.capacity(), 8);
    }

    #[test]
    fn grows_at_threshold() {
        let mut p = pool();
        // fill to 7/8 = 87% > 80% threshold triggers growth on next alloc
        for i in 0..7 {
            p.alloc(i, 1 << 20).unwrap();
        }
        let a = p.alloc(7, 1 << 20).unwrap();
        assert!(a.grew);
        assert!(p.capacity() > 8);
    }

    #[test]
    fn growth_respects_max_pages() {
        let mut p = Mempool::new(8, 16, 0.5, 1.0);
        for i in 0..64 {
            match p.alloc(i, 1 << 20) {
                Ok(_) => {}
                Err(_) => break,
            }
        }
        assert!(p.capacity() <= 16);
    }

    #[test]
    fn growth_respects_host_free_fraction() {
        let mut p = Mempool::new(8, 1 << 20, 0.5, 0.5);
        // host has only 40 free pages → cap = 20
        for i in 0..200 {
            if p.alloc(i, 40).is_err() {
                break;
            }
        }
        assert!(p.capacity() <= 20, "cap {}", p.capacity());
    }

    #[test]
    fn alloc_fails_without_reclaimable_then_recycles_lru() {
        let mut p = Mempool::new(4, 4, 0.9, 1.0);
        for i in 0..4 {
            p.alloc(i, 1 << 20).unwrap();
        }
        assert_eq!(p.alloc(99, 1 << 20), Err(AllocFail::NoReclaimable));
        // make pages 0..2 reclaimable (slot ids == insertion order here)
        assert!(p.mark_reclaimable(0));
        assert!(p.mark_reclaimable(1));
        p.touch(0); // 0 becomes MRU; LRU victim should be slot 1
        let a = p.alloc(99, 1 << 20).unwrap();
        assert_eq!(a.evicted_page, Some(1));
        assert_eq!(p.page_of(a.slot), 99);
        assert_eq!(p.reclaims, 1);
    }

    #[test]
    fn update_flag_defers_reclaim() {
        let mut p = pool();
        let a = p.alloc(5, 1 << 20).unwrap();
        p.bump_update(a.slot);
        // older write set completes: slot must NOT become reclaimable,
        // and one pending-update is consumed.
        assert!(!p.mark_reclaimable(a.slot));
        assert_eq!(p.flags(a.slot).update_pending, 0);
        // newer write set completes: now it reclaims.
        assert!(p.mark_reclaimable(a.slot));
        assert!(p.flags(a.slot).reclaimable);
    }

    #[test]
    fn three_updates_same_page_reclaim_only_on_last() {
        // WS1, WS2, WS3 all cover the same page slot; only WS3's
        // completion may free it (Figure 17 generalized).
        let mut p = pool();
        let a = p.alloc(5, 1 << 20).unwrap();
        p.bump_update(a.slot); // WS2 issued
        p.bump_update(a.slot); // WS3 issued
        assert!(!p.mark_reclaimable(a.slot)); // WS1 done
        assert!(!p.mark_reclaimable(a.slot)); // WS2 done
        assert!(p.mark_reclaimable(a.slot)); // WS3 done
    }

    #[test]
    fn rewrite_unmarks_reclaimable() {
        let mut p = pool();
        let a = p.alloc(5, 1 << 20).unwrap();
        p.mark_reclaimable(a.slot);
        assert_eq!(p.reclaimable_count(), 1);
        p.unmark_reclaimable(a.slot);
        assert_eq!(p.reclaimable_count(), 0);
        assert!(!p.flags(a.slot).reclaimable);
    }

    #[test]
    fn shrink_releases_only_free_pages_and_keeps_min() {
        let mut p = Mempool::new(8, 64, 0.5, 0.5);
        // grow the pool, remembering which slots we hold
        let mut held = Vec::new();
        for i in 0..20 {
            held.push(p.alloc(i, 1 << 20).unwrap().slot);
        }
        let cap_before = p.capacity();
        assert!(cap_before > 8);
        // host pressure: free mem collapses to 4 pages → cap = min_pages=8.
        // Only free slots can be released; used ones must drain first.
        let released = p.shrink(4);
        assert!(p.capacity() >= 8);
        assert!(p.capacity() >= p.used());
        assert_eq!(released, cap_before - p.capacity());
        // free everything we hold, then shrink again → min floor
        for s in held {
            p.free_slot(s);
        }
        p.shrink(4);
        assert_eq!(p.capacity(), 8);
        assert!(p.shrinks >= 1);
    }

    #[test]
    fn lease_caps_effective_cap_and_growth() {
        let mut p = Mempool::new(8, 1 << 20, 0.5, 1.0);
        assert_eq!(p.lease(), u64::MAX);
        p.set_lease(20);
        assert_eq!(p.effective_cap(1 << 20), 20);
        for i in 0..200 {
            if p.alloc(i, 1 << 20).is_err() {
                break;
            }
        }
        assert!(p.capacity() <= 20, "lease must cap growth: {}", p.capacity());
        // a lease below the floor is clamped to min_pages
        p.set_lease(1);
        assert_eq!(p.effective_cap(1 << 20), 8);
    }

    #[test]
    fn alloc_counters_track_activity_and_backpressure() {
        let mut p = Mempool::new(4, 4, 0.9, 1.0);
        for i in 0..4 {
            p.alloc(i, 1 << 20).unwrap();
        }
        assert_eq!(p.allocs, 4);
        assert_eq!(p.alloc_stalls, 0);
        assert!(p.alloc(99, 1 << 20).is_err());
        assert_eq!(p.alloc_stalls, 1);
        p.mark_reclaimable(0);
        p.alloc(99, 1 << 20).unwrap();
        assert_eq!(p.allocs, 5);
    }

    #[test]
    fn donate_idle_returns_lru_durable_pages_and_shrinks() {
        let mut p = Mempool::new(2, 64, 0.5, 1.0);
        let mut slots = Vec::new();
        for i in 0..10 {
            slots.push(p.alloc(i, 1 << 20).unwrap().slot);
        }
        let cap = p.capacity();
        // only pages 0..4 are remote-durable; page 0 is touched (MRU)
        for &s in &slots[..4] {
            p.mark_reclaimable(s);
        }
        p.touch(slots[0]);
        let mut evicted = Vec::new();
        assert_eq!(p.donate_idle(3, &mut evicted), 3);
        assert_eq!(evicted, vec![1, 2, 3], "LRU durable pages first");
        assert_eq!(p.capacity(), cap - 3);
        assert_eq!(p.used(), 7);
        assert_eq!(p.donations, 3);
        // nothing else is durable: further donation is a no-op (the
        // reused buffer is cleared either way)
        assert!(p.donate_idle(10, &mut evicted) <= 1);
        assert!(evicted.len() <= 1);
    }

    #[test]
    fn regrow_after_donate_never_aliases_live_slots() {
        // Donation leaves tombstones mid-vec; a later grow must mint
        // fresh slot ids, never ids pointing at live Used entries.
        let mut p = Mempool::new(8, 64, 0.8, 1.0);
        let mut pages = Vec::new();
        for i in 0..16 {
            let a = p.alloc(i, 1 << 20).unwrap();
            pages.push((i, a.slot));
        }
        for &(_, s) in &pages[..4] {
            p.mark_reclaimable(s);
        }
        let mut evicted = Vec::new();
        assert_eq!(p.donate_idle(4, &mut evicted), 4);
        let live: std::collections::HashSet<u32> =
            pages[4..].iter().map(|&(_, s)| s).collect();
        // refill until the pool regrows; every freshly minted slot must
        // be disjoint from the live ones (a recycle, which legitimately
        // reuses a slot, reports its evicted page)
        for i in 100..160 {
            match p.alloc(i, 1 << 20) {
                Ok(a) => {
                    if a.evicted_page.is_none() {
                        assert!(
                            !live.contains(&a.slot),
                            "fresh slot {} aliases a live slot",
                            a.slot
                        );
                    }
                }
                Err(_) => break,
            }
        }
        // the live pages' slots still hold their original pages
        for &(page, slot) in &pages[4..] {
            assert_eq!(p.page_of(slot), page, "slot {slot} clobbered");
        }
    }

    #[test]
    fn donate_idle_never_shrinks_below_min() {
        let mut p = Mempool::new(4, 4, 0.9, 1.0);
        for i in 0..4 {
            let a = p.alloc(i, 1 << 20).unwrap();
            p.mark_reclaimable(a.slot);
        }
        let mut evicted = Vec::new();
        assert_eq!(p.donate_idle(100, &mut evicted), 0);
        assert!(evicted.is_empty());
        assert_eq!(p.capacity(), 4);
    }

    #[test]
    fn prefetched_slots_recycle_before_demand_pages() {
        let mut p = Mempool::new(4, 4, 0.9, 1.0);
        // two demand pages (remote-durable) + two prefetched pages
        let a = p.alloc(0, 1 << 20).unwrap();
        let b = p.alloc(1, 1 << 20).unwrap();
        p.mark_reclaimable(a.slot);
        p.mark_reclaimable(b.slot);
        let pf1 = p.alloc_prefetched(100).unwrap();
        let pf2 = p.alloc_prefetched(101).unwrap();
        assert!(pf1.evicted_page.is_none());
        assert!(p.flags(pf1.slot).prefetched);
        assert!(p.flags(pf1.slot).reclaimable);
        assert_eq!(p.prefetched_count(), 2);
        // demand pressure: the prefetched pages must go first, oldest
        // first — both demand pages survive
        let c = p.alloc(2, 1 << 20).unwrap();
        assert_eq!(c.evicted_page, Some(100));
        let d = p.alloc(3, 1 << 20).unwrap();
        assert_eq!(d.evicted_page, Some(101));
        assert_eq!(p.prefetch_evicted, 2);
        assert_eq!(p.prefetched_count(), 0);
        let _ = pf2;
    }

    #[test]
    fn alloc_prefetched_never_grows_and_can_recycle_idle() {
        // full pool, growth headroom available: prefetch must NOT grow
        let mut p = Mempool::new(4, 64, 0.9, 1.0);
        for i in 0..4 {
            p.alloc(i, 1 << 20).unwrap();
        }
        let cap = p.capacity();
        // nothing reclaimable → speculation is dropped
        assert!(p.alloc_prefetched(100).is_none());
        assert_eq!(p.capacity(), cap, "prefetch must not grow the pool");
        // an idle remote-durable page may be displaced by readahead
        p.mark_reclaimable(0);
        let a = p.alloc_prefetched(100).unwrap();
        assert_eq!(a.evicted_page, Some(0));
        assert!(p.flags(a.slot).prefetched);
        assert_eq!(p.capacity(), cap);
    }

    #[test]
    fn promote_prefetched_moves_to_reclaim_lru() {
        let mut p = Mempool::new(8, 8, 0.9, 1.0);
        let a = p.alloc_prefetched(7).unwrap();
        assert!(p.promote_prefetched(a.slot));
        assert!(!p.flags(a.slot).prefetched);
        assert!(p.flags(a.slot).reclaimable);
        assert_eq!(p.prefetched_count(), 0);
        assert_eq!(p.reclaimable_count(), 1);
        assert!(!p.promote_prefetched(a.slot), "second promote is a no-op");
        assert_eq!(p.prefetch_evicted, 0, "a promoted page is not waste");
    }

    #[test]
    fn overwriting_a_prefetched_slot_counts_waste() {
        let mut p = Mempool::new(8, 8, 0.9, 1.0);
        let a = p.alloc_prefetched(7).unwrap();
        // the write path re-dirties the slot before any read hit it
        p.unmark_reclaimable(a.slot);
        assert!(!p.flags(a.slot).prefetched);
        assert!(!p.flags(a.slot).reclaimable);
        assert_eq!(p.prefetch_evicted, 1);
        assert_eq!(p.prefetched_count(), 0);
    }

    #[test]
    fn donate_idle_drains_prefetched_first() {
        let mut p = Mempool::new(2, 64, 0.5, 1.0);
        let a = p.alloc(0, 1 << 20).unwrap();
        p.mark_reclaimable(a.slot);
        p.alloc_prefetched(50).unwrap();
        p.alloc_prefetched(51).unwrap();
        let mut evicted = Vec::new();
        assert_eq!(p.donate_idle(2, &mut evicted), 2);
        assert_eq!(evicted, vec![50, 51], "speculation yields first");
        assert_eq!(p.prefetch_evicted, 2);
    }

    #[test]
    fn prop_capacity_always_within_bounds() {
        prop::check("mempool bounds", |rng| {
            let min = 4 + rng.below(16);
            let max = min + rng.below(64);
            let mut p = Mempool::new(min, max, 0.5 + rng.f64() * 0.4, 0.5);
            let mut next_page = 0u64;
            for _ in 0..200 {
                let host_free = rng.below(256);
                match rng.below(4) {
                    0 | 1 => {
                        next_page += 1;
                        if let Ok(a) = p.alloc(next_page, host_free) {
                            if rng.chance(0.5) {
                                p.mark_reclaimable(a.slot);
                            }
                        }
                    }
                    2 => {
                        let _ = p.shrink(host_free);
                    }
                    _ => {
                        let s = rng.below(p.capacity()) as u32;
                        if (s as usize) < p.slots.len()
                            && matches!(
                                p.slots[s as usize],
                                Slot::Used { .. }
                            )
                        {
                            p.touch(s);
                        }
                    }
                }
                assert!(p.capacity() >= min);
                assert!(p.capacity() <= max);
                assert!(p.used() <= p.capacity());
            }
        });
    }
}
