//! Cluster assembly: binds the simulated substrate, a paging backend and
//! a timeline of node-level events (native applications allocating and
//! freeing memory on peers — the remote-pressure generator behind the
//! eviction experiments, Figures 4/5/23).
//!
//! Two assemblies share the same event vocabulary: [`Cluster`] runs one
//! paging backend (the paper's single-container evaluation), and
//! [`TenantCluster`] runs a multi-tenant [`TenantGroup`] whose host and
//! remote pressure events fan out through the
//! [`crate::arbiter::HostArbiter`].

use std::collections::VecDeque;

use crate::arbiter::{TenantGroup, TenantId, TenantSpec};
use crate::audit::{self, Law, Violation};
use crate::backends::{
    self, Access, ClusterState, PagingBackend, PressureOutcome,
};
use crate::config::{BackendKind, Config};
use crate::engine::ShardedEngine;
use crate::sim::{EventQueue, Ns};
use crate::NodeId;

/// One resolved pressure episode: when, which node, what happened.
pub type PressureEntry = (Ns, NodeId, PressureOutcome);

/// Entries a [`PressureLog`] retains before dropping its oldest.
const PRESSURE_LOG_CAP: usize = 4096;

/// Bounded log of pressure episodes: a drop-oldest ring so multi-hour
/// pressure-wave runs (the `reclaim` experiment's bread and butter)
/// never grow memory without bound. Dropped entries are counted, not
/// silently forgotten.
#[derive(Clone, Debug)]
pub struct PressureLog {
    entries: VecDeque<PressureEntry>,
    cap: usize,
    /// Oldest entries dropped to stay within the cap.
    pub dropped: u64,
}

impl Default for PressureLog {
    fn default() -> Self {
        Self::new(PRESSURE_LOG_CAP)
    }
}

impl PressureLog {
    /// An empty log retaining at most `cap` entries.
    pub fn new(cap: usize) -> Self {
        PressureLog {
            entries: VecDeque::new(),
            cap: cap.max(1),
            dropped: 0,
        }
    }

    /// Append an episode, dropping the oldest entry when full.
    pub fn push(&mut self, entry: PressureEntry) {
        if self.entries.len() >= self.cap {
            self.entries.pop_front();
            self.dropped += 1;
        }
        self.entries.push_back(entry);
    }

    /// Episodes currently retained.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when no episode has been retained.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Iterate retained episodes, oldest first.
    pub fn iter(&self) -> impl Iterator<Item = &PressureEntry> {
        self.entries.iter()
    }

    /// The most recent episode, if any.
    pub fn last(&self) -> Option<&PressureEntry> {
        self.entries.back()
    }

    /// Audit the ring's conservation laws
    /// ([`crate::audit::Law::PressureLogBounds`]): never over capacity,
    /// episode times non-decreasing (events apply in time order), and
    /// entries are only dropped once the ring is full — `dropped > 0`
    /// with a slack ring means episodes were lost for no reason.
    pub fn audit_check(&self) -> Vec<Violation> {
        let mut out = Vec::new();
        let snap = || {
            format!(
                "len={} cap={} dropped={}",
                self.entries.len(),
                self.cap,
                self.dropped
            )
        };
        audit::check(
            &mut out,
            self.entries.len() <= self.cap,
            Law::PressureLogBounds,
            None,
            || {
                format!(
                    "ring holds {} entries over its cap {}",
                    self.entries.len(),
                    self.cap
                )
            },
            snap,
        );
        audit::check(
            &mut out,
            self.dropped == 0 || self.entries.len() >= self.cap,
            Law::PressureLogBounds,
            None,
            || {
                format!(
                    "{} episodes dropped while the ring has slack",
                    self.dropped
                )
            },
            snap,
        );
        let ordered = self
            .entries
            .iter()
            .zip(self.entries.iter().skip(1))
            .all(|(a, b)| a.0 <= b.0);
        audit::check(
            &mut out,
            ordered,
            Law::PressureLogBounds,
            None,
            || "episode times are not non-decreasing".to_string(),
            snap,
        );
        out
    }
}

impl std::ops::Index<usize> for PressureLog {
    type Output = PressureEntry;

    fn index(&self, i: usize) -> &PressureEntry {
        &self.entries[i]
    }
}

/// Timeline events applied to the cluster as virtual time advances.
#[derive(Clone, Copy, Debug)]
pub enum ClusterEvent {
    /// A native application on `node` allocates `bytes`.
    NativeAlloc {
        /// Target node.
        node: NodeId,
        /// Bytes claimed.
        bytes: u64,
    },
    /// A native application on `node` frees `bytes`.
    NativeFree {
        /// Target node.
        node: NodeId,
        /// Bytes released.
        bytes: u64,
    },
    /// Host free memory on the sender changes (container churn) — drives
    /// the mempool grow/shrink behavior.
    SenderHostFree {
        /// New free-page count available to the mempool.
        pages: u64,
    },
    /// `node` crashes (power loss, fabric partition): its donated MR
    /// blocks and any data on them are gone instantly. With health
    /// tracking enabled ([`crate::config::HealthConfig`]) the failure
    /// domain layer fails reads over to surviving replicas, re-targets
    /// in-flight migrations and queues re-replication; without it the
    /// event is ignored (the PR-8 world has no failure vocabulary).
    PeerDown {
        /// The crashing node.
        node: NodeId,
    },
    /// `node` (re)joins the cluster with a fresh, empty memory pool.
    /// With health tracking enabled the join triggers rebalancing that
    /// migrates units onto the fresh peer; without it, ignored.
    PeerJoin {
        /// The joining node.
        node: NodeId,
    },
}

/// Who handles the backend-facing half of a [`ClusterEvent`]: all three
/// cluster assemblies share one event semantics (below, in
/// `apply_events`) and differ only in this pair of hooks.
trait EventTarget {
    /// A peer node needs `bytes` of its donated memory back.
    fn on_remote_pressure(
        &mut self,
        cl: &mut ClusterState,
        now: Ns,
        node: NodeId,
        bytes: u64,
    ) -> PressureOutcome;
    /// Host free memory on the sender changed to `pages`.
    fn on_host_free(&mut self, pages: u64);
    /// Keep-alive observation: one cluster event was applied, originated
    /// by `origin` (`None` for sender-local events). Default no-op —
    /// only the sharded engine keeps a health ledger.
    fn on_cluster_tick(
        &mut self,
        _cl: &mut ClusterState,
        _now: Ns,
        _origin: Option<NodeId>,
    ) {
    }
    /// `node` was explicitly declared dead. Default no-op.
    fn on_peer_down(
        &mut self,
        _cl: &mut ClusterState,
        _now: Ns,
        _node: NodeId,
    ) {
    }
    /// `node` (re)joined with a fresh pool. Default no-op.
    fn on_peer_join(
        &mut self,
        _cl: &mut ClusterState,
        _now: Ns,
        _node: NodeId,
    ) {
    }
}

impl EventTarget for dyn PagingBackend {
    fn on_remote_pressure(
        &mut self,
        cl: &mut ClusterState,
        now: Ns,
        node: NodeId,
        bytes: u64,
    ) -> PressureOutcome {
        self.remote_pressure(cl, now, node, bytes)
    }

    fn on_host_free(&mut self, pages: u64) {
        self.host_pressure(pages);
    }
}

impl EventTarget for TenantGroup {
    fn on_remote_pressure(
        &mut self,
        cl: &mut ClusterState,
        now: Ns,
        node: NodeId,
        bytes: u64,
    ) -> PressureOutcome {
        TenantGroup::remote_pressure(self, cl, now, node, bytes)
    }

    fn on_host_free(&mut self, pages: u64) {
        TenantGroup::host_pressure(self, pages);
    }
}

impl EventTarget for ShardedEngine {
    fn on_remote_pressure(
        &mut self,
        cl: &mut ClusterState,
        now: Ns,
        node: NodeId,
        bytes: u64,
    ) -> PressureOutcome {
        ShardedEngine::remote_pressure(self, cl, now, node, bytes)
    }

    fn on_host_free(&mut self, pages: u64) {
        self.set_host_free_pages(pages);
    }

    fn on_cluster_tick(
        &mut self,
        cl: &mut ClusterState,
        now: Ns,
        origin: Option<NodeId>,
    ) {
        self.sender_mut().health_tick(cl, now, origin);
    }

    fn on_peer_down(
        &mut self,
        cl: &mut ClusterState,
        now: Ns,
        node: NodeId,
    ) {
        self.sender_mut().peer_down(cl, now, node);
    }

    fn on_peer_join(
        &mut self,
        cl: &mut ClusterState,
        now: Ns,
        node: NodeId,
    ) {
        self.sender_mut().peer_join(cl, now, node);
    }
}

/// Apply all events due at or before `now` — THE event semantics, shared
/// by every assembly: native allocations raise remote pressure when they
/// squeeze a peer's MR pool, native frees relax it, and sender host-free
/// changes update the sender's monitor before reaching the target.
///
/// Ordering contract with the sender-lane split: cluster events are
/// applied in one global timestamp order, *never* per-lane — a pressure
/// episode on peer A may enqueue migrations whose destination choice
/// depends on state a prior event changed on peer B, so event
/// application is sequencer work (one of the three cross-peer
/// operations, with migration COMMIT and replica remap; see
/// `coordinator/sender/seq.rs`). Lanes only ever observe the cluster
/// through the sequencer-ordered state this loop leaves behind.
fn apply_events<T: EventTarget + ?Sized>(
    state: &mut ClusterState,
    events: &mut EventQueue<ClusterEvent>,
    pressure_log: &mut PressureLog,
    target: &mut T,
    now: Ns,
) {
    while let Some((t, ev)) = events.pop_due(now) {
        // keep-alive first: an event from a peer proves it alive *now*,
        // and silence from the others is what ages them toward Suspect
        // and Dead — so health transitions (including the death sweep)
        // happen in the same global timestamp order as the events.
        let origin = match ev {
            ClusterEvent::NativeAlloc { node, .. }
            | ClusterEvent::NativeFree { node, .. }
            | ClusterEvent::PeerJoin { node } => Some(node),
            ClusterEvent::SenderHostFree { .. }
            | ClusterEvent::PeerDown { .. } => None,
        };
        target.on_cluster_tick(state, t, origin);
        match ev {
            ClusterEvent::NativeAlloc { node, bytes } => {
                state.monitors[node].native_bytes += bytes;
                let pressure = state.monitors[node]
                    .pressure(state.mrpools[node].registered_bytes());
                if pressure > 0 {
                    let out =
                        target.on_remote_pressure(state, t, node, pressure);
                    pressure_log.push((t, node, out));
                }
            }
            ClusterEvent::NativeFree { node, bytes } => {
                let m = &mut state.monitors[node];
                m.native_bytes = m.native_bytes.saturating_sub(bytes);
            }
            ClusterEvent::SenderHostFree { pages } => {
                // Mirror the new free level into the sender's monitor
                // and hand it to the target: Valet's mempool cap follows
                // it on the next pump.
                let sender = state.sender;
                let m = &mut state.monitors[sender];
                m.native_bytes = m
                    .total_bytes
                    .saturating_sub(pages * crate::PAGE_SIZE);
                target.on_host_free(pages);
            }
            ClusterEvent::PeerDown { node } => {
                target.on_peer_down(state, t, node);
            }
            ClusterEvent::PeerJoin { node } => {
                target.on_peer_join(state, t, node);
            }
        }
        // every event moves some monitor: fold the new occupancy into
        // the per-peer pressure EWMA the placement layer reads
        state.refresh_pressure();
    }
    if audit::enabled() {
        audit::enforce(&pressure_log.audit_check());
    }
}

/// A running cluster: substrate + backend + event timeline.
pub struct Cluster {
    /// Shared simulated substrate.
    pub state: ClusterState,
    /// The paging backend under test.
    pub backend: Box<dyn PagingBackend>,
    /// Scheduled node events.
    pub events: EventQueue<ClusterEvent>,
    /// Pressure episodes resolved so far (bounded drop-oldest ring).
    pub pressure_log: PressureLog,
}

impl Cluster {
    /// Build a cluster running `kind` under `cfg`.
    pub fn new(cfg: &Config, kind: BackendKind) -> Self {
        Cluster {
            state: ClusterState::new(cfg),
            backend: backends::build(kind, cfg),
            events: EventQueue::new(),
            pressure_log: PressureLog::default(),
        }
    }

    /// Schedule an event.
    pub fn schedule(&mut self, at: Ns, ev: ClusterEvent) {
        self.events.push(at, ev);
    }

    /// Apply all events due at or before `now` (see `apply_events`),
    /// then pump the backend.
    pub fn advance(&mut self, now: Ns) {
        apply_events(
            &mut self.state,
            &mut self.events,
            &mut self.pressure_log,
            &mut *self.backend,
            now,
        );
        self.backend.pump(&mut self.state, now);
    }

    /// Cluster-wide memory utilization: fraction of donatable memory that
    /// is actually registered as remote memory (the bar series in
    /// Figure 5).
    pub fn cluster_mem_utilization(&self) -> f64 {
        cluster_mem_utilization(&self.state)
    }
}

/// Shared utilization math for both cluster assemblies.
fn cluster_mem_utilization(state: &ClusterState) -> f64 {
    let mut donated = 0u64;
    let mut capacity = 0u64;
    for n in 0..state.disks.len() {
        if n == state.sender {
            continue;
        }
        let reg = state.mrpools[n].registered_bytes();
        donated += reg;
        capacity += reg + state.donatable(n);
    }
    if capacity == 0 {
        0.0
    } else {
        donated as f64 / capacity as f64
    }
}

/// A running multi-tenant cluster: substrate + [`TenantGroup`] + event
/// timeline. The same [`ClusterEvent`] vocabulary as [`Cluster`], but
/// host pressure ([`ClusterEvent::SenderHostFree`]) shrinks the
/// arbiter's budget (reclaiming leases most-over-share-first) and peer
/// pressure routes to the tenant owning the least-active block.
pub struct TenantCluster {
    /// Shared simulated substrate.
    pub state: ClusterState,
    /// Per-container coordinators behind the host arbiter.
    pub group: TenantGroup,
    /// Scheduled node events.
    pub events: EventQueue<ClusterEvent>,
    /// Pressure episodes resolved so far (bounded drop-oldest ring).
    pub pressure_log: PressureLog,
}

impl TenantCluster {
    /// Build a cluster hosting one tenant per spec under `cfg`.
    pub fn new(cfg: &Config, specs: &[TenantSpec]) -> Self {
        TenantCluster {
            state: ClusterState::new(cfg),
            group: TenantGroup::new(cfg, specs),
            events: EventQueue::new(),
            pressure_log: PressureLog::default(),
        }
    }

    /// Schedule an event.
    pub fn schedule(&mut self, at: Ns, ev: ClusterEvent) {
        self.events.push(at, ev);
    }

    /// Swap-out for `tenant` through its coordinator.
    pub fn write(
        &mut self,
        now: Ns,
        tenant: TenantId,
        page: u64,
        bytes: u64,
    ) -> Access {
        self.group.write(&mut self.state, now, tenant, page, bytes)
    }

    /// Swap-in for `tenant` through its coordinator.
    pub fn read(&mut self, now: Ns, tenant: TenantId, page: u64) -> Access {
        self.group.read(&mut self.state, now, tenant, page)
    }

    /// Apply all events due at or before `now` (see `apply_events`;
    /// pressure fans out via the arbiter), then pump every tenant
    /// (drain + one arbitration round).
    pub fn advance(&mut self, now: Ns) {
        apply_events(
            &mut self.state,
            &mut self.events,
            &mut self.pressure_log,
            &mut self.group,
            now,
        );
        self.group.pump(&mut self.state, now);
    }

    /// Cluster-wide memory utilization (see
    /// [`Cluster::cluster_mem_utilization`]).
    pub fn cluster_mem_utilization(&self) -> f64 {
        cluster_mem_utilization(&self.state)
    }
}

/// A running sharded cluster: substrate + [`ShardedEngine`] + event
/// timeline — the simulation-side assembly of the sharded request
/// engine, mirroring [`Cluster`] (whose backend is a one-shard engine
/// behind the `Coordinator` wrapper). Used by the shard-equivalence
/// regression tests and the sharded experiments.
pub struct ShardedCluster {
    /// Shared simulated substrate.
    pub state: ClusterState,
    /// The sharded engine under test.
    pub engine: ShardedEngine,
    /// Scheduled node events.
    pub events: EventQueue<ClusterEvent>,
    /// Pressure episodes resolved so far (bounded drop-oldest ring).
    pub pressure_log: PressureLog,
}

impl ShardedCluster {
    /// Build a cluster running an `S`-shard engine under `cfg`.
    pub fn new(cfg: &Config, shards: usize) -> Self {
        ShardedCluster {
            state: ClusterState::new(cfg),
            engine: ShardedEngine::new(cfg, shards),
            events: EventQueue::new(),
            pressure_log: PressureLog::default(),
        }
    }

    /// Schedule an event.
    pub fn schedule(&mut self, at: Ns, ev: ClusterEvent) {
        self.events.push(at, ev);
    }

    /// Swap-out through the engine (see [`ShardedEngine::write`]).
    pub fn write(&mut self, now: Ns, page: u64, bytes: u64) -> Access {
        self.engine.write(&mut self.state, now, page, bytes)
    }

    /// Swap-in through the engine (see [`ShardedEngine::read`]).
    pub fn read(&mut self, now: Ns, page: u64) -> Access {
        self.engine.read(&mut self.state, now, page)
    }

    /// Apply all events due at or before `now` (see `apply_events`),
    /// then pump the engine.
    pub fn advance(&mut self, now: Ns) {
        apply_events(
            &mut self.state,
            &mut self.events,
            &mut self.pressure_log,
            &mut self.engine,
            now,
        );
        self.engine.pump(&mut self.state, now);
    }

    /// Cluster-wide memory utilization (see
    /// [`Cluster::cluster_mem_utilization`]).
    pub fn cluster_mem_utilization(&self) -> f64 {
        cluster_mem_utilization(&self.state)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{ms, secs};

    #[test]
    fn native_alloc_triggers_pressure_handling() {
        let mut cfg = Config::default();
        cfg.cluster.nodes = 3;
        cfg.valet.mr_block_bytes = 1 << 20;
        cfg.valet.min_pool_pages = 64;
        cfg.valet.max_pool_pages = 64;
        let mut cl = Cluster::new(&cfg, BackendKind::Valet);
        // put some data on peers
        let mut t = 0;
        for blk in 0..64u64 {
            let a = cl.backend.write(&mut cl.state, t, blk * 16, 16 * 4096);
            t = a.end;
        }
        cl.advance(t + secs(2));
        let total_blocks: usize =
            cl.state.mrpools.iter().map(|p| p.len()).sum();
        assert!(total_blocks > 0);
        // now a peer's native app claims everything
        let peer = (0..3).find(|&n| cl.state.mrpools[n].len() > 0).unwrap();
        let mem = cl.state.monitors[peer].total_bytes;
        cl.schedule(t + secs(3), ClusterEvent::NativeAlloc {
            node: peer,
            bytes: mem,
        });
        cl.advance(t + secs(4));
        assert_eq!(cl.pressure_log.len(), 1);
        let (_, n, out) = cl.pressure_log[0];
        assert_eq!(n, peer);
        assert!(out.reclaimed_bytes > 0);
    }

    #[test]
    fn native_free_reverses_pressure() {
        let cfg = Config::default();
        let mut cl = Cluster::new(&cfg, BackendKind::LinuxSwap);
        cl.schedule(ms(1), ClusterEvent::NativeAlloc {
            node: 1,
            bytes: 1 << 30,
        });
        cl.schedule(ms(2), ClusterEvent::NativeFree {
            node: 1,
            bytes: 1 << 30,
        });
        cl.advance(ms(3));
        assert_eq!(cl.state.monitors[1].native_bytes, 0);
    }

    #[test]
    fn sender_host_free_reaches_valet_coordinator() {
        use crate::backends::valet::ValetBackend;
        let mut cfg = Config::default();
        cfg.cluster.nodes = 3;
        cfg.valet.min_pool_pages = 64;
        cfg.valet.max_pool_pages = 1 << 20;
        let mut cl = Cluster::new(&cfg, BackendKind::Valet);
        cl.schedule(ms(1), ClusterEvent::SenderHostFree { pages: 77 });
        cl.advance(ms(2));
        let be = cl
            .backend
            .as_any()
            .downcast_ref::<ValetBackend>()
            .expect("valet backend");
        assert_eq!(be.coordinator().host_free_pages(), 77);
    }

    #[test]
    fn sender_host_free_fans_out_through_the_arbiter() {
        let mut cfg = Config::default();
        cfg.cluster.nodes = 3;
        cfg.valet.mr_block_bytes = 1 << 20;
        cfg.valet.min_pool_pages = 64;
        cfg.valet.max_pool_pages = 1024;
        let specs = [TenantSpec { weight: 1, min_pages: 64 }; 2];
        let mut cl = TenantCluster::new(&cfg, &specs);
        assert_eq!(cl.group.arbiter().budget_pages(), 1024);
        assert_eq!(cl.group.arbiter().lease(0), 512);
        // host free memory collapses: the budget shrinks and both
        // leases are reclaimed down to their floors
        cl.schedule(ms(1), ClusterEvent::SenderHostFree { pages: 0 });
        cl.advance(ms(2));
        assert_eq!(cl.group.arbiter().lease(0), 64);
        assert_eq!(cl.group.arbiter().lease(1), 64);
        assert_eq!(cl.group.coordinator(0).lease_pages(), 64);
        assert!(cl.group.arbiter().reclaims > 0);
    }

    #[test]
    fn peer_pressure_routes_to_the_owning_tenant() {
        let mut cfg = Config::default();
        cfg.cluster.nodes = 4;
        cfg.valet.mr_block_bytes = 1 << 20;
        cfg.valet.min_pool_pages = 64;
        cfg.valet.max_pool_pages = 256;
        let specs = [TenantSpec { weight: 1, min_pages: 64 }; 2];
        let mut cl = TenantCluster::new(&cfg, &specs);
        // both tenants put data on the peers (disjoint page spaces)
        let mut t = 0;
        for blk in 0..24u64 {
            let a = cl.write(t, 0, blk * 16, 16 * 4096);
            let b = cl.write(a.end, 1, (1 << 20) + blk * 16, 16 * 4096);
            t = b.end;
        }
        cl.advance(t + secs(2));
        t += secs(2);
        // a native app squeezes the busiest peer
        let peer = (1..4)
            .max_by_key(|&n| cl.state.mrpools[n].registered_bytes())
            .unwrap();
        assert!(!cl.state.mrpools[peer].is_empty());
        let mem = cl.state.monitors[peer].total_bytes;
        cl.schedule(
            t + secs(1),
            ClusterEvent::NativeAlloc { node: peer, bytes: mem },
        );
        cl.advance(t + secs(2));
        assert_eq!(cl.pressure_log.len(), 1);
        let (_, n, out) = cl.pressure_log[0];
        assert_eq!(n, peer);
        assert!(out.reclaimed_bytes > 0);
        // no cross-tenant damage: every page of both tenants is still
        // served from memory (local or remote), never disk
        let mut tt = t + secs(3);
        for blk in 0..24u64 {
            let a = cl.read(tt, 0, blk * 16);
            let b = cl.read(a.end, 1, (1 << 20) + blk * 16);
            tt = b.end;
            assert_ne!(a.source, crate::backends::Source::Disk);
            assert_ne!(b.source, crate::backends::Source::Disk);
        }
    }

    #[test]
    fn sharded_cluster_mirrors_single_cluster_events() {
        let mut cfg = Config::default();
        cfg.cluster.nodes = 4;
        cfg.valet.mr_block_bytes = 1 << 20;
        cfg.valet.min_pool_pages = 256;
        cfg.valet.max_pool_pages = 256;
        let mut cl = ShardedCluster::new(&cfg, 4);
        let mut t = 0;
        for blk in 0..32u64 {
            let a = cl.write(t, blk * 16, 16 * 4096);
            t = a.end;
        }
        cl.advance(t + secs(2));
        t += secs(2);
        assert_eq!(cl.engine.pending_write_sets(), 0);
        // a peer's native app claims everything → pressure on the engine
        let peer = (1..4)
            .max_by_key(|&n| cl.state.mrpools[n].registered_bytes())
            .unwrap();
        let mem = cl.state.monitors[peer].total_bytes;
        cl.schedule(t, ClusterEvent::NativeAlloc { node: peer, bytes: mem });
        cl.advance(t + secs(1));
        assert_eq!(cl.pressure_log.len(), 1);
        assert!(cl.pressure_log[0].2.reclaimed_bytes > 0);
        // host-free collapse reaches the engine
        cl.schedule(t + secs(2), ClusterEvent::SenderHostFree { pages: 99 });
        cl.advance(t + secs(3));
        assert_eq!(cl.engine.host_free_pages(), 99);
    }

    #[test]
    fn pressure_log_ring_drops_oldest_and_counts() {
        let mut log = PressureLog::new(3);
        assert!(log.is_empty());
        for i in 0..5u64 {
            log.push((i, 0, PressureOutcome::default()));
        }
        assert_eq!(log.len(), 3);
        assert_eq!(log.dropped, 2);
        // oldest two (t=0, t=1) were dropped; index 0 is now t=2
        assert_eq!(log[0].0, 2);
        assert_eq!(log[2].0, 4);
        let times: Vec<u64> = log.iter().map(|e| e.0).collect();
        assert_eq!(times, vec![2, 3, 4]);
    }

    #[test]
    fn utilization_counts_registered_fraction() {
        let cfg = Config::default();
        let mut cl = Cluster::new(&cfg, BackendKind::Valet);
        assert_eq!(cl.cluster_mem_utilization(), 0.0);
        cl.state.mrpools[1].register(0, 10 << 30, 0);
        assert!(cl.cluster_mem_utilization() > 0.0);
    }
}
