//! Paging backends: the pluggable swap targets the container model pages
//! against. Four implementations, matching the paper's evaluation:
//!
//! * [`valet::ValetBackend`] — the paper's system.
//! * [`infiniswap::InfiniswapBackend`] — one-sided RDMA on the critical
//!   path, disk redirect during connection/mapping windows, random
//!   delete-on-eviction (Infiniswap [6]).
//! * [`nbdx::NbdxBackend`] — two-sided verbs with bounded message pools
//!   and a remote ramdisk (nbdX [11]).
//! * [`linux_swap::LinuxSwapBackend`] — conventional OS swap to disk.
//!
//! All backends run against the same [`ClusterState`] substrate (fabric +
//! disks + MR pools + activity monitors), so comparisons are
//! apples-to-apples.

pub mod infiniswap;
pub mod linux_swap;
pub mod nbdx;
pub mod valet;

use std::collections::HashMap;

use crate::config::{BackendKind, Config};
use crate::metrics::RunMetrics;
use crate::mrpool::{ActivityMonitor, MrBlockId, MrBlockPool};
use crate::sim::Ns;
use crate::simdisk::Disk;
use crate::simnet::Fabric;
use crate::NodeId;

/// Where a completed access was ultimately served from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Source {
    /// The sender's local mempool (Valet only).
    LocalPool,
    /// A remote node's MR memory.
    Remote,
    /// Local disk.
    Disk,
}

/// Completion of one block-device request.
#[derive(Clone, Copy, Debug)]
pub struct Access {
    /// Virtual completion time.
    pub end: Ns,
    /// Serving tier.
    pub source: Source,
}

/// The shared simulated substrate every backend runs on.
#[derive(Clone, Debug)]
pub struct ClusterState {
    /// RDMA fabric between all nodes.
    pub fabric: Fabric,
    /// One disk per node.
    pub disks: Vec<Disk>,
    /// One MR block pool per node (receiver module state).
    pub mrpools: Vec<MrBlockPool>,
    /// One activity monitor per node.
    pub monitors: Vec<ActivityMonitor>,
    /// The sender node (our container host).
    pub sender: NodeId,
    /// Per-node pressure score: an EWMA of memory occupancy
    /// (native + registered + reserve over total), fed by the activity
    /// monitors via [`ClusterState::refresh_pressure`] whenever a
    /// cluster event lands. The placement layer reads it through
    /// [`ClusterState::candidates`].
    pressure_score: Vec<f64>,
    /// Per-node **pool-tier** pressure score: the PR-5 EWMA generalized
    /// per tier — an EWMA of each node's pooled-slice occupancy
    /// (`pool_bytes / capacity`). Empty unless the pool tier is on.
    pool_pressure: Vec<f64>,
    /// EWMA weight (`valet.pressure_ewma`).
    pressure_alpha: f64,
    /// The pool-tier shape (`valet.pool_tier`): candidate emission and
    /// capacity accounting read it on every placement decision.
    pub pool_cfg: crate::config::PoolTierConfig,
}

impl ClusterState {
    /// Build from config: `cfg.cluster.nodes` nodes, node 0 the sender.
    pub fn new(cfg: &Config) -> Self {
        let n = cfg.cluster.nodes.max(2);
        let mut cl = ClusterState {
            fabric: Fabric::new(n, cfg.latency.clone()),
            disks: (0..n).map(|_| Disk::new(&cfg.latency)).collect(),
            mrpools: (0..n).map(|_| MrBlockPool::new()).collect(),
            monitors: (0..n)
                .map(|_| {
                    ActivityMonitor::new(
                        cfg.cluster.node_mem_bytes,
                        cfg.cluster.node_mem_bytes / 32, // 2 GB reserve @64 GB
                    )
                })
                .collect(),
            sender: 0,
            pressure_score: vec![0.0; n],
            pool_pressure: if cfg.valet.pool_tier.enabled {
                vec![0.0; n]
            } else {
                Vec::new()
            },
            pressure_alpha: cfg.valet.pressure_ewma.clamp(0.0, 1.0),
            pool_cfg: cfg.valet.pool_tier.clone(),
        };
        cl.seed_pressure();
        cl
    }

    fn occupancy(&self, node: NodeId) -> f64 {
        let m = &self.monitors[node];
        let used = m
            .native_bytes
            .saturating_add(m.reserve_bytes)
            .saturating_add(self.mrpools[node].registered_bytes());
        if m.total_bytes == 0 {
            1.0
        } else {
            (used as f64 / m.total_bytes as f64).clamp(0.0, 1.0)
        }
    }

    fn seed_pressure(&mut self) {
        for n in 0..self.pressure_score.len() {
            let occ = self.occupancy(n);
            self.pressure_score[n] = occ;
        }
    }

    /// Pooled-slice occupancy of a node (0 when the tier is off).
    fn pool_occupancy(&self, node: NodeId) -> f64 {
        let cap = self.pool_cfg.capacity_bytes;
        if cap == 0 {
            return 1.0;
        }
        (self.mrpools[node].pool_bytes() as f64 / cap as f64).clamp(0.0, 1.0)
    }

    /// Fold the monitors' current occupancy into the per-node pressure
    /// EWMA (and, with the pool tier on, each node's pooled-slice
    /// occupancy into the per-tier score). The cluster assemblies call
    /// this on every timeline event (native alloc/free, host churn) so
    /// the score tracks sustained load, not instants.
    pub fn refresh_pressure(&mut self) {
        let a = self.pressure_alpha;
        for n in 0..self.pressure_score.len() {
            let now = self.occupancy(n);
            let prev = self.pressure_score[n];
            self.pressure_score[n] = prev + a * (now - prev);
        }
        for n in 0..self.pool_pressure.len() {
            let now = self.pool_occupancy(n);
            let prev = self.pool_pressure[n];
            self.pool_pressure[n] = prev + a * (now - prev);
        }
    }

    /// The smoothed pressure score of a node in thousandths (0 = idle,
    /// 1000 = fully claimed).
    pub fn pressure_milli(&self, node: NodeId) -> u32 {
        (self.pressure_score[node].clamp(0.0, 1.0) * 1000.0) as u32
    }

    /// The smoothed pool-tier pressure score of a node in thousandths
    /// (0 when the tier is off).
    pub fn pool_pressure_milli(&self, node: NodeId) -> u32 {
        match self.pool_pressure.get(node) {
            Some(p) => (p.clamp(0.0, 1.0) * 1000.0) as u32,
            None => 0,
        }
    }

    /// Free bytes left in a node's pooled slice (0 when the tier is
    /// off, so pool candidates never look placeable by accident).
    pub fn pool_free(&self, node: NodeId) -> u64 {
        if !self.pool_cfg.enabled {
            return 0;
        }
        self.pool_cfg
            .capacity_bytes
            .saturating_sub(self.mrpools[node].pool_bytes())
    }

    /// Peer nodes (everyone but the sender).
    pub fn peers(&self) -> impl Iterator<Item = NodeId> + '_ {
        (0..self.disks.len()).filter(move |&n| n != self.sender)
    }

    /// Free bytes a peer can donate right now.
    pub fn donatable(&self, node: NodeId) -> u64 {
        self.monitors[node].free_for_mr(self.mrpools[node].registered_bytes())
    }

    /// Placement candidates over all peers, carrying both the
    /// instantaneous free bytes and the smoothed pressure score — one
    /// Remote-tier candidate per peer, plus (with the pool tier on) one
    /// Pool-tier candidate per peer with its own capacity and its own
    /// pressure score. With the tier off the list is exactly the
    /// pre-tier list, so every policy draws the same samples.
    pub fn candidates(&self) -> Vec<crate::placement::Candidate> {
        use crate::mrpool::MemTier;
        let mut out: Vec<crate::placement::Candidate> = self
            .peers()
            .map(|n| crate::placement::Candidate {
                node: n,
                free_bytes: self.donatable(n),
                pressure_milli: self.pressure_milli(n),
                tier: MemTier::Remote,
            })
            .collect();
        if self.pool_cfg.enabled {
            out.extend(self.peers().map(|n| crate::placement::Candidate {
                node: n,
                free_bytes: self.pool_free(n),
                pressure_milli: self.pool_pressure_milli(n),
                tier: MemTier::Pool,
            }));
        }
        out
    }

    /// The memory tier `block` on `node` lives in (RDMA-remote for an
    /// unknown block, so tier dispatch degrades to the classic verb).
    pub fn block_tier(
        &self,
        node: NodeId,
        block: crate::mrpool::MrBlockId,
    ) -> crate::mrpool::MemTier {
        self.mrpools[node]
            .get(block)
            .map(|b| b.tier)
            .unwrap_or(crate::mrpool::MemTier::Remote)
    }

    /// Read `bytes` from `block` on `node` with the verb of its tier:
    /// a pool access for a pool-resident block (NUMA-hop base latency,
    /// no queue pair), an RDMA READ otherwise. With the pool tier off
    /// every block is RDMA-remote and this IS `rdma_read` — part of
    /// the off-means-bit-for-bit pin.
    pub fn tiered_read(
        &mut self,
        now: crate::sim::Ns,
        node: NodeId,
        block: crate::mrpool::MrBlockId,
        bytes: u64,
    ) -> crate::simnet::VerbDone {
        if self.block_tier(node, block) == crate::mrpool::MemTier::Pool {
            self.fabric.pool_read(now, self.sender, node, bytes)
        } else {
            self.fabric.rdma_read(now, self.sender, node, bytes)
        }
    }

    /// Write `bytes` into `block` on `node` with the verb of its tier
    /// (see [`Self::tiered_read`]).
    pub fn tiered_write(
        &mut self,
        now: crate::sim::Ns,
        node: NodeId,
        block: crate::mrpool::MrBlockId,
        bytes: u64,
    ) -> crate::simnet::VerbDone {
        if self.block_tier(node, block) == crate::mrpool::MemTier::Pool {
            self.fabric.pool_write(now, self.sender, node, bytes)
        } else {
            self.fabric.rdma_write(now, self.sender, node, bytes)
        }
    }
}

/// Outcome of a remote-pressure (eviction) episode.
#[derive(Clone, Copy, Debug, Default)]
pub struct PressureOutcome {
    /// Bytes reclaimed on the pressured node.
    pub reclaimed_bytes: u64,
    /// Blocks migrated (Valet).
    pub migrated: u32,
    /// Blocks deleted (baselines).
    pub deleted: u32,
    /// Virtual time the reclamation finished.
    pub done_at: Ns,
}

/// A paging backend: the swap device the container faults against.
/// `Send` so the serve mode can own one on a coordinator thread.
pub trait PagingBackend: Send {
    /// Swap OUT: persist `bytes` starting at `page` (dirty eviction from
    /// the container). Returns completion as observed by the faulting
    /// thread.
    fn write(
        &mut self,
        cl: &mut ClusterState,
        now: Ns,
        page: u64,
        bytes: u64,
    ) -> Access;

    /// Swap IN: fetch one page (4 KB) at `page`.
    fn read(&mut self, cl: &mut ClusterState, now: Ns, page: u64) -> Access;

    /// Swap IN a whole block-I/O request (`pages_for(bytes)` pages from
    /// `page`). The default serves it page by page — one round trip per
    /// missing page, which is exactly how the baseline systems behave;
    /// Valet overrides this with its batched miss pipeline (collect all
    /// misses, one per-unit coalesced fetch).
    fn read_block(
        &mut self,
        cl: &mut ClusterState,
        now: Ns,
        page: u64,
        bytes: u64,
    ) -> Access {
        let npages = crate::pages_for(bytes).max(1);
        let mut t = now;
        let mut source = Source::LocalPool;
        for p in page..page + npages {
            let a = self.read(cl, t, p);
            t = a.end;
            source = crate::engine::worse_source(source, a.source);
        }
        Access { end: t, source }
    }

    /// Drive background machinery (remote sender thread, pool resize) up
    /// to virtual time `now`.
    fn pump(&mut self, cl: &mut ClusterState, now: Ns);

    /// A peer node needs `bytes` of its donated memory back.
    fn remote_pressure(
        &mut self,
        cl: &mut ClusterState,
        now: Ns,
        node: NodeId,
        bytes: u64,
    ) -> PressureOutcome;

    /// Host free memory on the sender changed (container churn): `pages`
    /// are now available to backend-local caches. Only Valet reacts (its
    /// mempool cap follows host free memory, §3.4); the default is a
    /// no-op.
    fn host_pressure(&mut self, _free_pages: u64) {}

    /// Run metrics.
    fn metrics(&self) -> &RunMetrics;

    /// Mutable run metrics (workload drivers record op latencies here).
    fn metrics_mut(&mut self) -> &mut RunMetrics;

    /// Downcast support, so integration tests and diagnostics can reach
    /// a concrete backend (e.g. the Valet coordinator) behind the trait
    /// object a [`crate::cluster::Cluster`] owns.
    fn as_any(&self) -> &dyn std::any::Any;

    /// Display name matching the paper's figures.
    fn name(&self) -> &'static str;
}

/// Build a backend by kind.
pub fn build(kind: BackendKind, cfg: &Config) -> Box<dyn PagingBackend> {
    match kind {
        BackendKind::Valet => Box::new(valet::ValetBackend::new(cfg)),
        BackendKind::Infiniswap => {
            Box::new(infiniswap::InfiniswapBackend::new(cfg))
        }
        BackendKind::Nbdx => Box::new(nbdx::NbdxBackend::new(cfg)),
        BackendKind::LinuxSwap => {
            Box::new(linux_swap::LinuxSwapBackend::new(cfg))
        }
    }
}

// ---------------------------------------------------------------------
// Shared remote-address-space bookkeeping
// ---------------------------------------------------------------------

/// State of one unit of the device's address space on the remote side.
#[derive(Clone, Debug)]
pub struct Unit {
    /// Replica locations, primary first.
    pub nodes: Vec<NodeId>,
    /// MR block ids, parallel to `nodes`.
    pub blocks: Vec<MrBlockId>,
    /// Mapping (incl. connection) completes at this time; I/O targeting
    /// the unit before then must detour (mempool for Valet, disk for
    /// Infiniswap).
    pub ready_at: Ns,
    /// While migrating, writes may not be sent until this time.
    pub wlocked_until: Ns,
    /// Set false when a baseline deletes the remote copy (reads fall to
    /// disk afterwards).
    pub alive: bool,
}

/// Maps address-space units (of `unit_bytes` each) to remote placements —
/// the §4.3 "global page address … dynamically mapped" table.
#[derive(Clone, Debug)]
pub struct UnitMap {
    /// Unit granularity (the remote MR block size).
    pub unit_bytes: u64,
    units: HashMap<u64, Unit>,
}

impl UnitMap {
    /// Empty map with the given unit size.
    pub fn new(unit_bytes: u64) -> Self {
        UnitMap {
            unit_bytes: unit_bytes.max(crate::PAGE_SIZE),
            units: HashMap::new(),
        }
    }

    /// Unit index of a page.
    pub fn unit_of(&self, page: u64) -> u64 {
        page * crate::PAGE_SIZE / self.unit_bytes
    }

    /// Look up a unit.
    pub fn get(&self, unit: u64) -> Option<&Unit> {
        self.units.get(&unit)
    }

    /// Mutable lookup.
    pub fn get_mut(&mut self, unit: u64) -> Option<&mut Unit> {
        self.units.get_mut(&unit)
    }

    /// Insert a mapping.
    pub fn insert(&mut self, unit: u64, u: Unit) {
        self.units.insert(unit, u);
    }

    /// Iterate all mapped units.
    pub fn iter(&self) -> impl Iterator<Item = (&u64, &Unit)> {
        self.units.iter()
    }

    /// Mutable iteration.
    pub fn iter_mut(&mut self) -> impl Iterator<Item = (&u64, &mut Unit)> {
        self.units.iter_mut()
    }

    /// Units mapped.
    pub fn len(&self) -> usize {
        self.units.len()
    }

    /// True if nothing mapped yet.
    pub fn is_empty(&self) -> bool {
        self.units.is_empty()
    }

    /// Find the unit (id) whose primary block is `block` on `node`.
    pub fn unit_of_block(
        &self,
        node: NodeId,
        block: MrBlockId,
    ) -> Option<u64> {
        self.units.iter().find_map(|(&u, unit)| {
            unit.nodes
                .iter()
                .zip(&unit.blocks)
                .any(|(&n, &b)| n == node && b == block)
                .then_some(u)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;

    #[test]
    fn cluster_state_shape() {
        let cfg = Config::default();
        let cl = ClusterState::new(&cfg);
        assert_eq!(cl.disks.len(), cfg.cluster.nodes);
        assert_eq!(cl.peers().count(), cfg.cluster.nodes - 1);
        assert!(cl.donatable(1) > 0);
    }

    #[test]
    fn pressure_ewma_tracks_native_load() {
        let cfg = Config::default();
        let mut cl = ClusterState::new(&cfg);
        let idle = cl.pressure_milli(1);
        assert!(idle < 100, "reserve-only occupancy: {idle}");
        // a native app claims most of the node: the score climbs toward
        // occupancy at the EWMA rate, monotonically
        cl.monitors[1].native_bytes = cl.monitors[1].total_bytes;
        let mut prev = idle;
        for _ in 0..20 {
            cl.refresh_pressure();
            let s = cl.pressure_milli(1);
            assert!(s >= prev, "score must rise: {prev} -> {s}");
            prev = s;
        }
        assert!(prev > 800, "sustained load converges: {prev}");
        // the candidates view carries the score
        let c = cl.candidates();
        let node1 = c.iter().find(|c| c.node == 1).unwrap();
        assert_eq!(node1.pressure_milli, prev);
        // releasing the memory decays the score back down
        cl.monitors[1].native_bytes = 0;
        cl.refresh_pressure();
        assert!(cl.pressure_milli(1) < prev);
    }

    #[test]
    fn pool_candidates_appear_only_when_enabled() {
        use crate::mrpool::MemTier;
        let cfg = Config::default();
        let cl = ClusterState::new(&cfg);
        assert!(
            cl.candidates().iter().all(|c| c.tier == MemTier::Remote),
            "pool off: the candidate list is the pre-tier list"
        );
        assert_eq!(cl.pool_free(1), 0);
        let mut cfg2 = Config::default();
        cfg2.valet.pool_tier.enabled = true;
        let mut cl2 = ClusterState::new(&cfg2);
        let c = cl2.candidates();
        assert_eq!(c.len(), 2 * (cfg2.cluster.nodes - 1));
        assert!(c.iter().any(|x| x.tier == MemTier::Pool));
        let cap = cfg2.valet.pool_tier.capacity_bytes;
        assert_eq!(cl2.pool_free(1), cap);
        // a resident pool block shrinks the slice and raises its
        // (tier-local) pressure EWMA; other nodes are untouched
        cl2.mrpools[1].register_tier(0, 1 << 30, 0, MemTier::Pool);
        assert_eq!(cl2.pool_free(1), cap - (1 << 30));
        cl2.refresh_pressure();
        assert!(cl2.pool_pressure_milli(1) > 0);
        assert_eq!(cl2.pool_pressure_milli(2), 0);
    }

    #[test]
    fn unit_map_page_math() {
        let m = UnitMap::new(1 << 20); // 1 MB units = 256 pages
        assert_eq!(m.unit_of(0), 0);
        assert_eq!(m.unit_of(255), 0);
        assert_eq!(m.unit_of(256), 1);
    }

    #[test]
    fn unit_of_block_reverse_lookup() {
        let mut m = UnitMap::new(1 << 20);
        m.insert(
            3,
            Unit {
                nodes: vec![2, 4],
                blocks: vec![11, 12],
                ready_at: 0,
                wlocked_until: 0,
                alive: true,
            },
        );
        assert_eq!(m.unit_of_block(2, 11), Some(3));
        assert_eq!(m.unit_of_block(4, 12), Some(3));
        assert_eq!(m.unit_of_block(2, 12), None);
    }

    #[test]
    fn build_constructs_all_kinds() {
        let cfg = Config::default();
        for kind in BackendKind::all() {
            let b = build(kind, &cfg);
            assert_eq!(b.name(), kind.name());
        }
    }
}
